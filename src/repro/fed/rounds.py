"""Federated AdaLD round orchestration (paper Algorithm 1 + §IV setup).

One communication round (Fig. 1's 10 steps):
  1. server broadcasts global knowledge {K_g, h_g} (downlink accounted);
  2. selected clients distill locally against it (lines 5-7);
  3. clients fine-tune on private data (line 8);
  4. clients infer the public set, adaptively Top-k by live channel state
     (lines 9-10) and upload sparse logits + LoRA projections (line 11);
  5. server aggregates (line 15), distills into the LLM (line 16).

Four method presets reproduce the paper's comparison (§IV):
  adald      — adaptive Top-k + adaptive aggregation + LoRA-projection loss
  adaptive   — adaptive Top-k + adaptive aggregation, logits-only
  zeropad    — adaptive Top-k + zero-padding mean aggregation, logits-only
  all_logits — full logits (k = vocab), mean aggregation, logits-only
"""

from __future__ import annotations

import dataclasses
import json
from typing import Literal, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt_io
from repro.configs.base import ModelConfig
from repro.core.channel import ChannelConfig, ChannelSimulator
from repro.core.faults import FaultConfig, FaultSimulator, get_faults, validate_dense
from repro.core.scenario import ScenarioConfig, get_scenario
from repro.core.protocol import CommLedger, RoundStats, downlink_bits
from repro.data.partition import dirichlet_partition, iid_partition, split_public_private
from repro.data.synthetic import IntentDataset
from repro.fed.client import Client, make_upload_payload
from repro.fed.engine import BroadcastState, cohort_budgets, make_engine
from repro.fed.server import Server
from repro.fed.steps import EVAL_BATCH, make_eval_fn

__all__ = ["FedConfig", "FedRun", "run_federated", "METHODS"]

Method = Literal["adald", "adaptive", "zeropad", "all_logits"]
Engine = Literal["sequential", "batched", "fused", "fused_e2e"]

METHODS: dict[str, dict] = {
    "adald": dict(aggregation="adaptive", send_h=True, adaptive_k=True),
    "adaptive": dict(aggregation="adaptive", send_h=False, adaptive_k=True),
    "zeropad": dict(aggregation="zeropad", send_h=False, adaptive_k=True),
    "all_logits": dict(aggregation="zeropad", send_h=False, adaptive_k=False),
}


@dataclasses.dataclass
class FedConfig:
    """Paper Table I defaults (reduced-scale knobs exposed)."""

    method: Method = "adald"
    # Round executor: "batched" stacks the selected cohort along a leading
    # client axis and runs each phase as one vmapped/jitted step; "fused"
    # additionally collapses the whole CLIENT phase into ONE jitted round
    # body (adaptive k as data); "fused_e2e" folds the SERVER phase in too
    # (sparse-wire aggregation + server distillation + broadcast — a whole
    # round is one compiled call); "sequential" is the bit-compatible
    # one-client-at-a-time reference.
    engine: Engine = "batched"
    # Compute the LM head (class/public/distill logits) on the LAST position
    # only — the task reads nothing else; cuts head FLOPs ~seq_len×.  False
    # restores the seed behaviour of materialising (B, T, V).
    last_only: bool = True
    # Fused engines: place the client axis over jax devices (shard_map).  For
    # "fused_e2e" the placement lives INSIDE the whole-round executable (the
    # server phase stays replicated); odd cohorts are padded with masked
    # k = 0 rows.
    shard_clients: bool = False
    # fused_e2e only: run ALL rounds as ONE compiled lax.scan dispatch
    # (FusedE2EEngine.run_rounds) with the per-round eval tapped inside the
    # scan — the R-round trajectory (accuracies, distill loss, mean_k) comes
    # back as scanned outputs instead of R host round-trips.
    scan_rounds: bool = False
    # Fleet-state residency (repro.fed.store): "device" keeps the whole
    # fleet's LoRA/opt stacked on the accelerator (bit-identical to the
    # pre-store layout); "host" keeps the fleet in host memory and streams
    # only each round's cohort to the device — O(cohort) device memory at
    # any fleet size, with round r+1's cohort transfer prefetched under
    # round r's compute.  scan_rounds requires "device" (the multi-round
    # scan carries the whole fleet as a donated device operand); with
    # "host" the run falls back to the per-round driver.  Checkpoints are
    # layout-compatible across stores ("host" writes per-client-range
    # shards instead of one fleet tree), so the knob is excluded from the
    # resume fingerprint.
    fleet_store: str = "device"
    num_clients: int = 50
    clients_per_round: int = 10
    rounds: int = 20
    public_size: int = 2000
    non_iid: bool = True
    dirichlet_gamma: float = 0.5
    seed: int = 0
    temperature: float = 2.0
    lam: float = 0.03
    lr: float = 1e-3
    distill_lr: float = 3e-3
    local_steps: int = 4
    distill_steps: int = 2       # client-side distill updates per round
    server_distill_steps: int = 12  # server-side (the LLM learns only here)
    public_batch: int = 256  # samples of the public set used per round
    eval_size: int = 512
    use_kernels: bool = False
    restrict_to_support: bool = False
    # Quantize the sparse uplink wire to int8 values + one fp32 scale per
    # (client, sample) row: (value, index) entries are priced at 8 bits, so
    # the same Shannon budget affords a genuinely larger adaptive k at a
    # fixed SNR (the projection h stays at ``channel.value_bits``).  Served
    # by the batched/fused engines; "sequential" rejects it.
    quantize_wire: bool = False
    # Round-body compute dtype for the fused engines ("float32" |
    # "bfloat16"): forward/backward math runs in the given dtype while the
    # LoRA/optimizer master state stays fp32 (the cast lives inside the
    # differentiated loss, so grads accumulate back to fp32 before AdamW).
    compute_dtype: str = "float32"
    channel: ChannelConfig = dataclasses.field(default_factory=ChannelConfig)
    # Channel-dynamics scenario: a repro.core.scenario preset name
    # ("iid" | "gauss_markov" | "jakes" | "gilbert_elliott" | "mobility"),
    # a ScenarioConfig, or None (i.i.d., bit-identical to the pre-scenario
    # simulator).  When set it overrides ``channel.scenario``; with
    # scan_rounds the channel state additionally evolves INSIDE the
    # compiled multi-round scan (one executable for every scenario) and
    # the per-round realised SNR/outage come back in FedRun.
    scenario: "str | ScenarioConfig | None" = None
    # Fault-injection scenario (repro.core.faults): a preset name
    # ("none" | "corruption" | "crashes" | "bursty" | "lossy"), a
    # FaultConfig, or None.  Drawn from (seed, round, cid)-keyed streams on
    # domains disjoint from the channel simulator's, so enabling faults
    # never perturbs a run's channel realisation; the "none" preset is
    # bit-identical to None on every engine path.  Non-delivering clients
    # (crashed mid-upload / quarantined after exhausting HARQ retries) are
    # excluded from aggregation through the existing k = 0 transmit-mask
    # pattern; their on-air bytes stay on the ledger.
    faults: "str | FaultConfig | None" = None
    # Backbone pretraining (simulates the paper's pretrained GPT-2 W'; the
    # pretrain split is disjoint from public/private/eval).  0 disables.
    # Clients: supervised (they fine-tune on labelled shards anyway);
    # server: LM-only by default — generic features, NO class knowledge, so
    # its accuracy trajectory isolates what distillation transfers (the
    # paper's Fig. 2 server curve).
    pretrain_steps: int = 80
    pretrain_frac: float = 0.12
    pretrain_lr: float = 2e-3
    server_pretrain: str = "lm"  # "lm" | "supervised" | "none"
    server_pretrain_steps: int = 60


@dataclasses.dataclass
class FedRun:
    ledger: CommLedger
    server_acc: list[float]
    client_acc: list[float]
    mean_k: list[float]
    # Per-round list of each selected client's adaptive k (0 = dropped
    # straggler that transmitted nothing).
    per_client_k: list[list[int]] = dataclasses.field(default_factory=list)
    # Per-round final server-distill step loss (NaN when the engine does not
    # expose it — only the fused_e2e engine computes it in-program).
    distill_loss: list[float] = dataclasses.field(default_factory=list)
    # Heterogeneous scan runs only: per-round accuracy per family bucket
    # (fleet bucket order) from the in-scan eval tap.
    family_client_acc: list[list[float]] | None = None
    # Scenario scan runs only: per-round cohort realised SNR (dB, -inf in
    # outage) and outage flags from the in-scan channel tap.
    snr_db: list[list[float]] | None = None
    outage: list[list[bool]] | None = None
    # Fault-injection runs only (None when FedConfig.faults is off):
    # per-round counts of quarantined uploads (corruption that exhausted
    # HARQ retries, plus wire-validation rejections) and mid-upload crashes,
    # the per-round retransmission bytes (on-air cost beyond each delivered
    # payload's first copy — included in the ledger's uplink_bytes), and
    # each selected client's ATTEMPTED adaptive k.  per_client_k/mean_k
    # keep reporting the DELIVERED view (0 for a lost upload), so
    # attempted_k is what separates "budget afforded nothing" from "died on
    # the air".
    num_quarantined: list[int] | None = None
    num_crashed: list[int] | None = None
    retrans_bytes: list[float] | None = None
    attempted_k: list[list[int]] | None = None

    def summary(self) -> dict:
        # NaN-safe best: all-dropped rounds contribute NaN accuracies, and
        # max() over a list with NaN entries is ORDER-DEPENDENT (any NaN
        # encountered after the true max poisons the comparison chain).
        finite = [a for a in self.server_acc if np.isfinite(a)]
        return {
            **self.ledger.summary(),
            "best_server_acc": max(finite) if finite else float("nan"),
        }


def _config_fingerprint(fed: FedConfig) -> dict:
    """A JSON-normalised image of the FedConfig for checkpoint/resume
    compatibility checks.  ``rounds`` is excluded: extending the horizon of
    a checkpointed run is exactly what resume is for.  ``fleet_store`` is
    excluded too: residency does not change the trajectory, and both store
    kinds read either checkpoint layout (monolithic fleet tree or
    per-client shards), so a run may resume under the other store."""
    d = dataclasses.asdict(fed)
    d.pop("rounds")
    d.pop("fleet_store", None)
    return json.loads(json.dumps(d, sort_keys=True, default=str))


def run_federated(
    client_cfg: ModelConfig | Sequence[ModelConfig],
    server_cfg: ModelConfig,
    dataset: IntentDataset,
    fed: FedConfig,
    *,
    verbose: bool = False,
    ckpt_dir: str | None = None,
    resume: bool = False,
) -> FedRun:
    """Run the whole federation.  ``client_cfg`` may be ONE config (the
    homogeneous fleet of the paper's §IV setup) or a sequence of FAMILY
    configs — clients then cycle through the families round-robin (client i
    runs ``client_cfg[i % F]``), and the engines serve the mixed fleet
    through the family-bucketed heterogeneous path (`repro.fed.cohort`).
    Families must share a vocabulary and LoRA rank (the paper's §II
    exchange contracts); with pretraining enabled, one backbone is
    pretrained PER family and shared by that family's clients.

    ``ckpt_dir`` enables crash-safe round-granular checkpoints through
    :mod:`repro.checkpoint` (atomic writes; one ``step_{r}`` file after
    every completed round — after every completed BLOCK with
    ``scan_rounds``, where a round is not a host-visible boundary).
    ``resume=True`` restores the newest valid checkpoint in ``ckpt_dir``
    and continues: host RNG draws and per-client batch streams are
    deterministically replayed through the completed rounds, device state
    is restored losslessly from the checkpoint, and channels/faults replay
    for free from their (seed, round, cid) keying — the resumed ``FedRun``
    is bit-identical to an uninterrupted run.  An empty/missing ``ckpt_dir``
    with ``resume=True`` simply starts from round 0 (idempotent restart).
    """
    preset = METHODS[fed.method]
    rng = np.random.default_rng(fed.seed)

    fault_cfg = get_faults(fed.faults)
    if fault_cfg is not None and not fault_cfg.enabled:
        fault_cfg = None  # the "none" preset is literally no fault machinery
    if fault_cfg is not None and not preset["adaptive_k"]:
        raise ValueError(
            "fault injection requires an adaptive-k method (faulted clients "
            "are excluded through the k = 0 transmit-mask path, which "
            f"method {fed.method!r} never takes)"
        )

    if resume and ckpt_dir is None:
        raise ValueError("resume=True requires ckpt_dir")
    completed = 0
    ckpt_meta: dict = {}
    if resume:
        step = ckpt_io.latest_step(ckpt_dir)
        if step is not None:
            completed = int(step)
            ckpt_meta = ckpt_io.step_metadata(ckpt_dir, step) or {}
            stored = ckpt_meta.get("config")
            now = _config_fingerprint(fed)
            if stored is not None and stored != now:
                diff = sorted(
                    k for k in set(stored) | set(now)
                    if stored.get(k) != now.get(k)
                )
                raise ValueError(
                    f"checkpoint in {ckpt_dir} was written by a different "
                    f"FedConfig (differing fields: {diff}); resuming it "
                    "would not reproduce the original trajectory"
                )
            if completed >= fed.rounds:
                raise ValueError(
                    f"checkpoint already holds {completed} completed rounds "
                    f">= fed.rounds={fed.rounds}; raise fed.rounds to extend "
                    "the run"
                )

    families = (
        [client_cfg] if isinstance(client_cfg, ModelConfig) else list(client_cfg)
    )
    if not families:
        raise ValueError("client_cfg must name at least one model config")
    cfgs = [families[i % len(families)] for i in range(fed.num_clients)]

    # carve a disjoint pretraining split first (simulated pretrained W')
    server_init = None
    client_inits: dict[ModelConfig, object] = {}
    if fed.pretrain_steps > 0:
        from repro.fed.pretrain import pretrain_classifier, pretrain_lm

        n_pre = int(len(dataset) * fed.pretrain_frac)
        pre_idx = np.random.default_rng(fed.seed + 31).permutation(len(dataset))
        pretrain_ds = dataset.subset(pre_idx[:n_pre])
        dataset = dataset.subset(pre_idx[n_pre:])
        # Resuming: every pretrained tensor (client backbones, server init)
        # is restored from the checkpoint below, so the pretrain COMPUTE is
        # skipped — only the data split above must still be applied (it
        # shapes the public/private/eval pools the replayed rounds draw
        # from).
        if completed:
            # Topology-only placeholders: the pretrained run hands every
            # client of a family the SAME param arrays, which the batched
            # engines detect (shared_frozen_backbone) and store unstacked.
            # Resume must reproduce that sharing layout before the restore
            # overwrites the values, or the checkpoint tree shapes mismatch.
            from repro.models import init as model_init

            for fi, fam in enumerate(families):
                client_inits[fam] = model_init(
                    jax.random.PRNGKey(fed.seed + 17 * fi), fam
                )
        else:
            # one pretrained backbone per family; family 0 keeps the
            # historical seed so a homogeneous run is bit-identical to the
            # pre-hetero path
            for fi, fam in enumerate(families):
                client_inits[fam] = pretrain_classifier(
                    fam, pretrain_ds, num_classes=dataset.num_classes,
                    steps=fed.pretrain_steps, lr=fed.pretrain_lr,
                    seed=fed.seed + 17 * fi,
                    last_only=fed.last_only, verbose=verbose,
                )
            if fed.server_pretrain == "supervised":
                server_init = pretrain_classifier(
                    server_cfg, pretrain_ds, num_classes=dataset.num_classes,
                    steps=fed.server_pretrain_steps, lr=fed.pretrain_lr,
                    seed=fed.seed + 999, last_only=fed.last_only,
                    verbose=verbose,
                )
            elif fed.server_pretrain == "lm":
                server_init = pretrain_lm(
                    server_cfg, pretrain_ds, steps=fed.server_pretrain_steps,
                    lr=fed.pretrain_lr, seed=fed.seed + 999, verbose=verbose,
                )

    public, private = split_public_private(dataset, fed.public_size, seed=fed.seed)
    if fed.non_iid:
        parts = dirichlet_partition(
            private.labels, fed.num_clients, gamma=fed.dirichlet_gamma, seed=fed.seed
        )
    else:
        parts = iid_partition(len(private), fed.num_clients, seed=fed.seed)

    clients = [
        Client(
            i,
            cfgs[i],
            private.subset(parts[i]),
            num_classes=dataset.num_classes,
            seed=fed.seed + i,
            lr=fed.lr,
            distill_lr=fed.distill_lr,
            temperature=fed.temperature,
            lam=fed.lam,
            local_steps=fed.local_steps,
            distill_steps=fed.distill_steps,
            restrict_to_support=fed.restrict_to_support,
            last_only=fed.last_only,
            initial_params=client_inits.get(cfgs[i]),
        )
        for i in range(fed.num_clients)
    ]
    server = Server(
        server_cfg,
        seed=fed.seed + 999,
        distill_lr=fed.distill_lr,
        temperature=fed.temperature,
        lam=fed.lam,
        aggregation=preset["aggregation"],
        distill_steps=fed.server_distill_steps,
        use_kernels=fed.use_kernels,
        restrict_to_support=fed.restrict_to_support,
        last_only=fed.last_only,
        initial_params=server_init,
    )
    channel_cfg = fed.channel
    if fed.scenario is not None:
        channel_cfg = dataclasses.replace(
            channel_cfg, scenario=get_scenario(fed.scenario)
        )
    chan_sim = ChannelSimulator(fed.num_clients, channel_cfg, seed=fed.seed)
    fault_sim = (
        FaultSimulator(fed.num_clients, fault_cfg, seed=fed.seed)
        if fault_cfg is not None
        else None
    )

    # held-out eval split (from the private pool tail, disjoint from clients'
    # data only in expectation at reduced scale; standard FedD evaluation)
    eval_idx = rng.permutation(len(private))[: fed.eval_size]
    eval_tokens, eval_labels = private.tokens[eval_idx], private.labels[eval_idx]
    evaluate = make_eval_fn(server_cfg, dataset.num_classes, last_only=fed.last_only)
    # per-family client evaluators (make_eval_fn is lru-cached per config)
    evaluate_client = {
        fam: make_eval_fn(fam, dataset.num_classes, last_only=fed.last_only)
        for fam in families
    }

    engine = make_engine(
        fed.engine,
        clients,
        cfgs[0],
        num_classes=dataset.num_classes,
        lr=fed.lr,
        distill_lr=fed.distill_lr,
        temperature=fed.temperature,
        lam=fed.lam,
        local_steps=fed.local_steps,
        distill_steps=fed.distill_steps,
        restrict_to_support=fed.restrict_to_support,
        value_bits=fed.channel.value_bits,
        k_min=fed.channel.min_k,
        last_only=fed.last_only,
        shard_clients=fed.shard_clients,
        use_kernels=fed.use_kernels,
        quantize_wire=fed.quantize_wire,
        compute_dtype=fed.compute_dtype,
        fleet_store=fed.fleet_store,
        # fused_e2e only: the engine owns the server phase too
        server=server,
        server_distill_steps=fed.server_distill_steps,
        aggregation=preset["aggregation"],
    )
    handles_server = getattr(engine, "handles_server", False)

    ledger = CommLedger()
    run = FedRun(ledger=ledger, server_acc=[], client_acc=[], mean_k=[])
    if fault_sim is not None:
        run.num_quarantined, run.num_crashed = [], []
        run.retrans_bytes, run.attempted_k = [], []

    pub_rng = np.random.default_rng(fed.seed + 7)

    def draw_round(rnd: int):
        """One round's host-rng draws — cohort, public batch, channel
        realisation — in THE canonical order.  The per-round loop and the
        scan_rounds pre-draw both go through here, so the two paths can
        never desynchronize their rng streams."""
        sel = rng.choice(fed.num_clients, size=fed.clients_per_round, replace=False)
        pub_sel = pub_rng.integers(0, len(public), size=fed.public_batch)
        return (
            [int(i) for i in sel],
            jnp.asarray(public.tokens[pub_sel]),
            chan_sim.states_batched(rnd, list(sel)),
        )

    def apply_faults(rnd, sel, states, fault_inputs=None, round_offset=0):
        """Resolve this round's deliveries and force the non-delivering
        clients (crashed mid-upload / HARQ-exhausted corruption) to k = 0
        BEFORE any engine sees the round, by putting their channel entry
        into outage (snr -> -inf, zero bit budget).  Every engine —
        sequential, batched, fused, fused_e2e, hetero, and the multi-round
        scans (where k is already an int32 data operand) — then excludes
        them through the ONE existing transmit-mask path; no fault-specific
        executable exists.  Returns ``(states', attempted_ks, resolution,
        ghost_payloads)`` with the attempted manifests of quarantined
        uploads (their bytes were spent on air) for the ledger.
        """
        n_samples = fed.public_batch
        attempted = cohort_budgets(
            states, cfgs[sel[0]], n_samples, preset["adaptive_k"], len(sel),
            preset["send_h"], value_bits=fed.channel.value_bits,
            k_min=fed.channel.min_k, quantize_wire=fed.quantize_wire,
        )
        specs, payload_bits = [], []
        for i, cid in enumerate(sel):
            if attempted[i] > 0:
                p, _rank = make_upload_payload(
                    cfgs[cid], cid, n_samples, attempted[i],
                    send_h=preset["send_h"], value_bits=fed.channel.value_bits,
                    snr_db=float(states.snr_db[i]), quantize=fed.quantize_wire,
                )
            else:
                p = None
            specs.append(p)
            payload_bits.append(0.0 if p is None else float(p.spec.uplink_bits))
        budget_bits = [float(st.bit_budget) for st in states]
        if fault_inputs is not None:
            res = fault_sim.resolve_from_inputs(
                fault_inputs, round_offset, sel, attempted,
                payload_bits, budget_bits,
            )
        else:
            res = fault_sim.resolve_round(
                rnd, sel, attempted, payload_bits, budget_bits
            )
        failed = [
            i for i, (k, d) in enumerate(zip(attempted, res.delivered))
            if k > 0 and not d
        ]
        if failed:
            snr = np.array(states.snr_db, dtype=np.float64)
            snr[failed] = -np.inf
            states = dataclasses.replace(states, snr_db=snr)
        ghosts = [specs[i] for i in failed if res.reasons[i] == "corrupt"]
        for i in failed:
            if res.reasons[i] == "corrupt":
                specs[i].attempts = res.attempts[i]
                specs[i].delivered = False
        return states, attempted, res, ghosts

    def fault_ledger(sel, res, ghosts, payloads):
        """Price HARQ retries onto the delivered manifests (in place, so the
        engine-reported uplink bytes already include them) and account the
        quarantined attempts.  Returns ``(extra_bytes, retrans_bytes,
        stats_kw)``: bytes to ADD to the engine-reported uplink (the ghost
        manifests' spent attempts), the total on-air cost beyond each
        delivered payload's first copy, and the RoundStats fault taps."""
        by_cid = {p.client_id: p for p in payloads}
        retrans = 0.0
        for i, cid in enumerate(sel):
            if res.delivered[i] and res.attempts[i] > 1:
                p = by_cid.get(cid)
                if p is not None:
                    p.attempts = res.attempts[i]
                    retrans += (res.attempts[i] - 1) * p.spec.uplink_bytes
        extra = float(sum(g.bytes for g in ghosts))
        retrans += extra
        counts: dict[str, int] = {}
        for r in res.reasons:
            if r is not None:
                counts[r] = counts.get(r, 0) + 1
        stats_kw = dict(
            num_quarantined=res.num_quarantined,
            num_crashed=res.num_crashed,
            fault_counts=counts or None,
            retrans_bytes=retrans,
        )
        return extra, retrans, stats_kw

    def record_fault_taps(attempted, res, retrans):
        run.num_quarantined.append(res.num_quarantined)
        run.num_crashed.append(res.num_crashed)
        run.retrans_bytes.append(retrans)
        run.attempted_k.append(list(attempted))

    # -- crash-safe checkpointing ---------------------------------------
    # A host-store fleet checkpoints as per-client-range SHARDS next to the
    # main step npz (never materialised as one tree — the whole point of
    # out-of-core residency); the shards are written FIRST and the main
    # npz LAST, so a valid step file implies complete shards (ckpt.py's
    # ordering contract) and a crash mid-shard-write resumes from the
    # previous step.
    fleet_sharded = (
        getattr(engine, "store_kind", "device") == "host"
        and hasattr(engine, "save_fleet_shards")
    )

    def ckpt_tree(like: bool = False, include_fleet: bool = True):
        """The full federation state as one checkpointable pytree: fleet
        LoRA/opt (+ backbone), server state, and — for server-owning
        engines — the broadcast carry.  ``like=True`` builds the restore
        skeleton on a freshly-constructed engine, where the broadcast carry
        does not exist yet and is shaped from the config instead.  Round
        index and histories ride the JSON metadata sidecar; channel and
        fault trajectories replay for free from (seed, round, cid) keying.
        ``include_fleet=False`` leaves the fleet out (it rides in shards).
        """
        tree = {}
        if include_fleet:
            tree["fleet"] = engine.fleet_state()
        if handles_server:
            tree["server"] = engine.server_state()
            if like:
                bc = {
                    "b_logits": np.zeros(
                        (fed.public_batch, server_cfg.vocab_size), np.float32
                    )
                }
                if server_cfg.lora is not None:
                    bc["b_h"] = np.zeros(
                        (fed.public_batch, server_cfg.lora.rank), np.float32
                    )
            else:
                bc = {"b_logits": engine._b_logits}
                if engine._b_h is not None:
                    bc["b_h"] = engine._b_h
            tree["bcast"] = bc
        else:
            tree["server"] = {"s_params": server.params, "s_opt": server.opt}
        return tree

    def save_ckpt(step: int) -> None:
        meta = dict(
            config=_config_fingerprint(fed),
            server_acc=run.server_acc, client_acc=run.client_acc,
            mean_k=run.mean_k, per_client_k=run.per_client_k,
            distill_loss=run.distill_loss,
            ledger=[dataclasses.asdict(r) for r in ledger.rounds],
        )
        for tap in ("num_quarantined", "num_crashed", "retrans_bytes",
                    "attempted_k", "family_client_acc", "snr_db", "outage"):
            v = getattr(run, tap)
            if v is not None:
                meta[tap] = v
        if fleet_sharded:
            # shards FIRST, main npz LAST: the main file is the atomic
            # completion marker for the whole sharded checkpoint
            engine.save_fleet_shards(ckpt_io.fleet_shard_dir(ckpt_dir, step))
            meta["fleet_sharded"] = True
        ckpt_io.save_step(
            ckpt_dir, step, ckpt_tree(include_fleet=not fleet_sharded), **meta
        )

    resume_bcast: BroadcastState | None = None
    if completed:
        was_sharded = bool(ckpt_meta.get("fleet_sharded"))
        tree, _step = ckpt_io.restore_step(
            ckpt_dir, ckpt_tree(like=True, include_fleet=not was_sharded),
            completed,
        )
        if was_sharded:
            engine.load_fleet_shards(
                ckpt_io.fleet_shard_dir(ckpt_dir, completed)
            )
        else:
            engine.load_fleet_state(tree["fleet"])
        if handles_server:
            engine.load_server_state(tree["server"])
        else:
            server.params = jax.tree.map(jnp.asarray, tree["server"]["s_params"])
            server.opt = jax.tree.map(jnp.asarray, tree["server"]["s_opt"])
        # Deterministic replay of the host-rng chain through the completed
        # rounds: the cohort/public/channel draws and each selected client's
        # private-batch stream advance exactly as the original rounds did,
        # so round `completed` sees the same draws it would have seen
        # uninterrupted.  Device state is restored, not recomputed.
        last_pub = None
        for rnd in range(completed):
            sel, pub_tokens, _states = draw_round(rnd)
            for cid in sel:
                clients[cid].next_train_batches(fed.local_steps)
            last_pub = pub_tokens
        if handles_server:
            engine.load_broadcast(
                last_pub, tree["bcast"]["b_logits"], tree["bcast"].get("b_h")
            )
            resume_bcast = engine.broadcast_state(last_pub)
        else:
            # the broadcast is a pure function of (restored server params,
            # replayed public batch) — recompute it bit-identically
            g_logits, g_h, g_bits = server.broadcast(last_pub)
            resume_bcast = BroadcastState(
                tokens=last_pub, logits=g_logits, h=g_h, bits=g_bits
            )
        # restore the recorded history so the resumed FedRun is the FULL
        # run's record, not just the tail's
        run.server_acc[:] = [float(x) for x in ckpt_meta.get("server_acc", [])]
        run.client_acc[:] = [float(x) for x in ckpt_meta.get("client_acc", [])]
        run.mean_k[:] = [float(x) for x in ckpt_meta.get("mean_k", [])]
        run.per_client_k[:] = [
            [int(k) for k in ks] for ks in ckpt_meta.get("per_client_k", [])
        ]
        run.distill_loss[:] = [
            float(x) for x in ckpt_meta.get("distill_loss", [])
        ]
        for tap in ("num_quarantined", "num_crashed", "retrans_bytes",
                    "attempted_k", "family_client_acc", "snr_db", "outage"):
            if tap in ckpt_meta:
                setattr(run, tap, ckpt_meta[tap])
        for entry in ckpt_meta.get("ledger", []):
            ledger.record(RoundStats(**entry))

    store_kind = getattr(engine, "store_kind", "device")
    if fed.scan_rounds:
        if not handles_server:
            raise ValueError(
                "FedConfig.scan_rounds requires engine='fused_e2e' "
                f"(got {fed.engine!r})"
            )
        if store_kind != "device" and verbose:
            # the scan carries the WHOLE fleet as a donated device operand,
            # which defeats the host store's O(cohort) residency — trade
            # the amortised dispatch for streaming and drive per round
            print(
                "[rounds] scan_rounds needs the device fleet store; "
                f"fleet_store={store_kind!r} falls back to the per-round "
                "driver with cohort prefetch"
            )
    if fed.scan_rounds and store_kind == "device":
        # Pre-draw every remaining round in the same order the per-round
        # loop uses, then run the block as one compiled multi-round dispatch
        # with the eval tap inside the scan.  A resumed run scans only the
        # rounds after the checkpoint (the restored broadcast carry warm-
        # starts it); checkpoint granularity on this path is the BLOCK
        # boundary — a round inside the scan is not a host-visible state.
        start = completed
        n_block = fed.rounds - start
        fault_inputs = (
            # the scan path consumes the fault trajectory through its DATA
            # operands (scan_fault_inputs) — resolved host-side into the
            # int32 k masks the compiled scan already takes, bit-identical
            # to the per-round stream path
            fault_sim.scan_fault_inputs(n_block, start_round=start)
            if fault_sim is not None
            else None
        )
        sels, pubs, states_list, fault_rows = [], [], [], []
        for j, rnd in enumerate(range(start, fed.rounds)):
            sel, pub_tokens, states = draw_round(rnd)
            if fault_sim is not None:
                states, attempted, res, ghosts = apply_faults(
                    rnd, sel, states, fault_inputs, j
                )
                fault_rows.append((attempted, res, ghosts))
            sels.append(sel)
            pubs.append(pub_tokens)
            states_list.append(states)
        # the in-scan tap reads the same samples the host-side batched eval
        # walks (whole eval batches; the remainder is dropped there too)
        seen = (len(eval_tokens) // EVAL_BATCH) * EVAL_BATCH
        eval_kw = {}
        if seen:
            eval_kw = dict(
                eval_tokens=jnp.asarray(eval_tokens[:seen]),
                eval_labels=jnp.asarray(eval_labels[:seen]),
            )
        chan_kw = {}
        if chan_sim.scenario is not None:
            # scenario channel state evolves inside the same compiled scan;
            # budgets above were priced from the identical host chain
            chan_kw = dict(
                channel_scan=chan_sim.scan_channel_inputs(
                    n_block, start_round=start
                )
            )
        traj = engine.run_rounds(
            sels, pubs, states_list,
            adaptive_k=preset["adaptive_k"], send_h=preset["send_h"],
            **eval_kw, **chan_kw,
        )
        engine.sync_server()
        # extend (never clobber) the taps a resumed run restored
        if traj.family_client_acc is not None:
            run.family_client_acc = (
                (run.family_client_acc or []) + traj.family_client_acc
            )
        if traj.snr_db is not None:
            run.snr_db = (run.snr_db or []) + traj.snr_db
            run.outage = (run.outage or []) + traj.outage
        b_rank = server_cfg.lora.rank if server_cfg.lora is not None else None
        b_bits = downlink_bits(fed.public_batch, server_cfg.vocab_size, b_rank)
        for j, rnd in enumerate(range(start, fed.rounds)):
            # an eval split smaller than one batch degenerates to 0.0 on the
            # host path (no whole batch to walk) — mirror it, not NaN
            s_acc = traj.server_acc[j] if traj.server_acc else 0.0
            c_acc = traj.client_acc[j] if traj.client_acc else 0.0
            downlink = b_bits * len(sels[j]) if rnd > 0 else 0
            stats_kw: dict = {}
            extra = 0.0
            if fault_rows:
                attempted, res, ghosts = fault_rows[j]
                extra, retrans, stats_kw = fault_ledger(
                    sels[j], res, ghosts, traj.payloads[j]
                )
                record_fault_taps(attempted, res, retrans)
            # after fault_ledger: delivered manifests carry their HARQ
            # attempts, so p.bytes already prices the retries
            uplink = float(sum(p.bytes for p in traj.payloads[j])) + extra
            run.server_acc.append(s_acc)
            run.client_acc.append(c_acc)
            run.mean_k.append(traj.mean_k[j])
            run.per_client_k.append(list(traj.ks[j]))
            run.distill_loss.append(traj.distill_loss[j])
            ledger.record(
                RoundStats(
                    round_index=rnd,
                    uplink_bytes=uplink,
                    downlink_bytes=downlink / 8.0,
                    server_accuracy=s_acc,
                    client_accuracy=c_acc,
                    distill_loss=traj.distill_loss[j],
                    mean_k=traj.mean_k[j],
                    num_selected=len(sels[j]),
                    num_transmitters=len(traj.payloads[j]),
                    **stats_kw,
                )
            )
            if verbose:
                print(
                    f"[{fed.method}/{fed.engine}+scan] round {rnd:3d}  "
                    f"server_acc={s_acc:.3f} client_acc={c_acc:.3f}  "
                    f"mean_k={traj.mean_k[j]:7.1f}  uplink={uplink/1e6:.2f}MB  "
                    f"tx={len(traj.payloads[j])}/{len(sels[j])}"
                )
        if ckpt_dir is not None:
            save_ckpt(fed.rounds)
        return run

    # Broadcast knowledge carried across rounds: None until the server has
    # distilled once (cold server at round 0 -> no downlink that round); a
    # resumed run re-enters with the checkpointed broadcast.
    bcast: BroadcastState | None = resume_bcast
    # Rounds are pre-drawn ONE round ahead so the store can stage round
    # r+1's cohort (host->device prefetch) under round r's compute.  The
    # draw order is unchanged — draw_round(r) still fires in increasing r,
    # keeping the host-rng chain identical to the non-prefetching loop —
    # and the channel/fault draws are (seed, round, cid)-keyed, so drawing
    # round r+1 before round r's faults resolve changes nothing.
    pending = draw_round(completed) if fed.rounds > completed else None
    for rnd in range(completed, fed.rounds):
        sel, pub_tokens, states = pending
        pending = draw_round(rnd + 1) if rnd + 1 < fed.rounds else None
        if pending is not None:
            engine.prefetch_cohort(pending[0])
        fault_row = None
        if fault_sim is not None:
            states, attempted, res, ghosts = apply_faults(rnd, sel, states)
            fault_row = (attempted, res, ghosts)

        # one broadcast of last round's knowledge per selected client
        downlink = bcast.bits * len(sel) if bcast is not None else 0

        phase = engine.run_round(
            sel, pub_tokens, bcast, states,
            adaptive_k=preset["adaptive_k"], send_h=preset["send_h"],
        )

        stats_kw: dict = {}
        extra = 0.0
        if fault_row is not None:
            attempted, res, ghosts = fault_row
            extra, retrans, stats_kw = fault_ledger(
                sel, res, ghosts, phase.payloads
            )
            record_fault_taps(attempted, res, retrans)

        if handles_server:
            # fused_e2e: aggregation + server distillation + broadcast all
            # happened inside the engine's single compiled round call.
            bcast = engine.broadcast_state(pub_tokens)
            engine.sync_server()
        else:
            dense, h_stack = phase.dense, phase.h
            if fault_sim is not None and dense is not None:
                # server-side integrity gate on the received stack: a
                # transmitter whose payload decodes to non-finite values is
                # quarantined instead of poisoning the eq. 6-7 aggregation
                ok, _reasons = validate_dense(dense, h_stack)
                if not ok.all():
                    n_bad = int((~ok).sum())
                    for i in np.flatnonzero(~ok):
                        phase.payloads[int(i)].delivered = False
                    keep = np.flatnonzero(ok)
                    dense = dense[jnp.asarray(keep)] if len(keep) else None
                    if h_stack is not None:
                        h_stack = (
                            h_stack[jnp.asarray(keep)] if len(keep) else None
                        )
                    stats_kw["num_quarantined"] = (
                        stats_kw.get("num_quarantined") or 0
                    ) + n_bad
                    counts = stats_kw.get("fault_counts") or {}
                    counts["invalid_wire"] = counts.get("invalid_wire", 0) + n_bad
                    stats_kw["fault_counts"] = counts
            if dense is not None:
                k_g, h_g = server.aggregate_dense(dense, h_stack)
                server.distill(pub_tokens, k_g, h_g)
            # else: every selected client dropped this round -> no
            # aggregation, the server's knowledge simply carries over.
            g_logits, g_h, g_bits = server.broadcast(pub_tokens)
            bcast = BroadcastState(tokens=pub_tokens, logits=g_logits, h=g_h, bits=g_bits)

        s_acc = evaluate(server.params, jnp.asarray(eval_tokens), jnp.asarray(eval_labels))
        c_acc = evaluate_client[cfgs[sel[0]]](
            engine.client_params(sel[0]), jnp.asarray(eval_tokens), jnp.asarray(eval_labels)
        )
        uplink = phase.uplink_bytes + extra
        d_loss = (
            engine.last_distill_loss if handles_server else float("nan")
        )
        run.server_acc.append(s_acc)
        run.client_acc.append(c_acc)
        run.mean_k.append(float(np.mean(phase.ks)))
        run.per_client_k.append(list(phase.ks))
        run.distill_loss.append(d_loss)
        ledger.record(
            RoundStats(
                round_index=rnd,
                uplink_bytes=uplink,
                downlink_bytes=downlink / 8.0,
                server_accuracy=s_acc,
                client_accuracy=c_acc,
                distill_loss=d_loss,
                mean_k=float(np.mean(phase.ks)),
                num_selected=len(sel),
                num_transmitters=phase.num_transmitters,
                **stats_kw,
            )
        )
        if verbose:
            print(
                f"[{fed.method}/{fed.engine}] round {rnd:3d}  server_acc={s_acc:.3f} "
                f"client_acc={c_acc:.3f}  mean_k={np.mean(phase.ks):7.1f}  "
                f"uplink={uplink/1e6:.2f}MB  tx={phase.num_transmitters}/{len(sel)}"
            )
        if ckpt_dir is not None:
            save_ckpt(rnd + 1)
    return run
