"""Adaptive aggregation (paper eqs. 6-7) vs baselines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import (
    aggregate,
    aggregate_adaptive,
    aggregate_mean_nonzero,
    aggregate_sparse,
    aggregate_zeropad,
)
from repro.core.topk import topk_sparsify


def _sparse_stack(key, n=5, rows=4, vocab=64, keep=0.2):
    x = jax.random.normal(key, (n, rows, vocab))
    mask = jax.random.uniform(jax.random.fold_in(key, 1), x.shape) < keep
    return jnp.where(mask, x, 0.0)


def test_single_client_identity():
    """With one client, adaptive aggregation returns its logits unchanged."""
    stack = _sparse_stack(jax.random.PRNGKey(0), n=1)
    np.testing.assert_allclose(aggregate_adaptive(stack), stack[0], rtol=1e-5, atol=1e-7)


def test_untouched_dims_stay_zero():
    stack = _sparse_stack(jax.random.PRNGKey(1))
    out = aggregate_adaptive(stack)
    untouched = jnp.all(stack == 0, axis=0)
    assert bool(jnp.all(jnp.where(untouched, out == 0, True)))


def test_adaptive_in_convex_hull():
    """Per dimension, the adaptive aggregate lies within [min, max] of the
    transmitting clients' values (weights are a convex combination)."""
    stack = _sparse_stack(jax.random.PRNGKey(2), n=6)
    out = aggregate_adaptive(stack)
    transmitted = stack != 0
    big = jnp.where(transmitted, stack, jnp.inf).min(axis=0)
    small = jnp.where(transmitted, stack, -jnp.inf).max(axis=0)
    touched = transmitted.any(axis=0)
    assert bool(jnp.all(jnp.where(touched, (out >= big - 1e-5) & (out <= small + 1e-5), True)))


def test_zeropad_shrinks_vs_adaptive():
    """Zero-padding dilutes: |zeropad| <= |adaptive| on touched dims where a
    single client transmitted (the paper's sparsity-bias argument)."""
    stack = _sparse_stack(jax.random.PRNGKey(3), n=8, keep=0.1)
    single = (stack != 0).sum(axis=0) == 1
    zp = jnp.abs(aggregate_zeropad(stack))
    ad = jnp.abs(aggregate_adaptive(stack))
    assert bool(jnp.all(jnp.where(single, zp <= ad + 1e-6, True)))


def test_mean_nonzero_between():
    stack = _sparse_stack(jax.random.PRNGKey(4))
    mn = aggregate_mean_nonzero(stack)
    # all-positive values: adaptive >= mean_nonzero (confidence upweights)
    stack_pos = jnp.abs(stack)
    ad = aggregate_adaptive(stack_pos)
    mn = aggregate_mean_nonzero(stack_pos)
    assert bool(jnp.all(ad >= mn - 1e-5))


def test_sparse_equals_dense_aggregation():
    key = jax.random.PRNGKey(5)
    full = jax.random.normal(key, (4, 6, 50)) + 3.0
    sparse = topk_sparsify(full, 8)
    from repro.core.topk import densify

    stack = densify(sparse)  # (4, 6, 50): leading axis = clients
    for mode in ("adaptive", "zeropad", "mean_nonzero"):
        dense_out = aggregate(stack, mode)
        sparse_out = aggregate_sparse(sparse.values, sparse.indices, 50, mode)
        np.testing.assert_allclose(dense_out, sparse_out, rtol=1e-4, atol=1e-6)


def test_unknown_mode_raises():
    with pytest.raises(ValueError):
        aggregate(jnp.zeros((2, 3, 4)), "bogus")  # type: ignore[arg-type]
