import os
import sys
import tempfile

# tests run against the source tree regardless of install state
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

# The suite is compile-bound on CPU; a persistent compilation cache makes
# warm reruns several times faster (cold runs are unaffected).
_cache_dir = os.environ.get(
    "JAX_COMPILATION_CACHE_DIR",
    os.path.join(tempfile.gettempdir(), "jax_compile_cache_repro"),
)
try:  # pragma: no cover - best effort, older jax may lack these knobs
    jax.config.update("jax_compilation_cache_dir", _cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
except Exception:
    pass
