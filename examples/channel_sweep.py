"""Channel sweep: how wireless conditions drive the adaptive Top-k and the
accuracy/communication trade-off (the paper's §III-A mechanism in isolation).

Sweeps mean uplink SNR; for each condition reports the per-round k chosen by
the Shannon budget, the uplink bytes, and final accuracy after a few rounds.

Run:  PYTHONPATH=src python examples/channel_sweep.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.configs.gpt2_paper import REDUCED_CLIENT, REDUCED_SERVER  # noqa: E402
from repro.core import ChannelConfig  # noqa: E402
from repro.data import make_banking77_like  # noqa: E402
from repro.fed import FedConfig, run_federated  # noqa: E402

client = REDUCED_CLIENT.with_overrides(num_layers=2, d_model=128, num_heads=4, d_ff=512)
server = REDUCED_SERVER.with_overrides(num_layers=2, d_model=192, num_heads=4,
                                       num_kv_heads=4, d_ff=768)
ds = make_banking77_like(vocab_size=client.vocab_size, seq_len=20, total=1500, seed=0)

print(f"{'SNR dB':>8} {'BW MHz':>8} {'mean k':>8} {'uplink MB':>10} {'best acc':>9}")
for snr, bw in [(0, 0.2e6), (5, 0.5e6), (10, 1e6), (20, 2e6), (30, 10e6)]:
    fed = FedConfig(
        method="adald", engine="batched", num_clients=6, clients_per_round=3, rounds=4,
        public_size=256, public_batch=64, eval_size=256, local_steps=3,
        distill_steps=1, seed=0,
        channel=ChannelConfig(bandwidth_hz=bw, mean_snr_db=snr),
    )
    run = run_federated(client, server, ds, fed)
    print(f"{snr:8.0f} {bw/1e6:8.1f} {np.mean(run.mean_k):8.0f} "
          f"{run.ledger.uplink_mb:10.3f} {max(run.server_acc):9.3f}")
print("\nworse channel -> smaller k -> fewer bytes; accuracy degrades gracefully"
      "\n(the adaptive aggregation compensating for sparsity is the paper's point).")

# Straggler scenario: min_k=0 removes the survival floor, dropout_prob puts
# links into outage — dropped clients transmit nothing and are excluded from
# aggregation (never zero-padded in).
print("\n--- straggler/dropout scenario (min_k=0, 30% outage) ---")
fed = FedConfig(
    method="adald", engine="batched", num_clients=6, clients_per_round=3, rounds=4,
    public_size=256, public_batch=64, eval_size=256, local_steps=3,
    distill_steps=1, seed=0,
    channel=ChannelConfig(bandwidth_hz=0.5e6, mean_snr_db=5, min_k=0, dropout_prob=0.3),
)
run = run_federated(client, server, ds, fed)
for r in run.ledger.rounds:
    print(f"round {r.round_index}: transmitters {r.num_transmitters}/{r.num_selected}  "
          f"uplink {r.uplink_bytes/1e6:.3f} MB  server_acc {r.server_accuracy:.3f}")
