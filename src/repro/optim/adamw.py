"""AdamW optimizer as pure pytree functions (no optax dependency).

Moment dtype is configurable (``ModelConfig.optimizer_state_dtype``): the
biggest assigned configs (jamba 398B) store m/v in bfloat16 to fit v5e HBM
(DESIGN §4); the update math always runs in fp32.

Mixed-precision (bf16-buffer) training: when the live params are kept in a
low-precision compute dtype (bf16 round bodies), ``adamw_init(...,
master_dtype="float32")`` stores an fp32 MASTER copy of the params inside
the optimizer state; ``adamw_update`` then reads/updates the master (so
tiny updates are never swallowed by bf16 rounding across steps) and emits
the live params as a cast of it.  With ``master_dtype=None`` (default) the
state and update are exactly the classic master-free AdamW.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "global_norm"]


class AdamWState(NamedTuple):
    m: dict
    v: dict
    count: jax.Array  # () int32
    # fp32 master params for low-precision live params; None -> masterless
    # (the default, and the state every pre-existing checkpoint holds).
    master: dict | None = None


def adamw_init(
    params, *, state_dtype: str = "float32", master_dtype: str | None = None
) -> AdamWState:
    dt = jnp.dtype(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    master = (
        None
        if master_dtype is None
        else jax.tree.map(lambda p: p.astype(jnp.dtype(master_dtype)), params)
    )
    return AdamWState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
        master=master,
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr: jax.Array | float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip: float | None = 1.0,
):
    """Returns (new_params, new_state)."""
    count = state.count + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1**cf
    bc2 = 1.0 - b2**cf

    if grad_clip is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, grad_clip / (gn + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    # NOTE (§Perf iteration 8, refuted): sequencing leaf updates with an
    # optimization_barrier chain to bound fp32 temporaries made peak memory
    # 4.3x WORSE (30 -> 129 GB on jamba train) — the barriers break XLA's
    # donation aliasing of params/moments.  The fused tree_map form below is
    # the better schedule; XLA keeps the per-leaf fp32 temporaries transient.
    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1.0 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1.0 - b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        step = mhat / (jnp.sqrt(vhat) + eps)
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * (step + weight_decay * p32)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    # With a master, the update reads/advances the fp32 copy and the live
    # (possibly bf16) params are re-emitted as its cast; without one, the
    # fp32 math on the live params is bitwise the pre-master behaviour.
    src = params if state.master is None else state.master
    is_tup = lambda x: isinstance(x, tuple)
    flat = jax.tree.map(upd, grads, state.m, state.v, src)
    new_p32 = jax.tree.map(lambda t: t[0], flat, is_leaf=is_tup)
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=is_tup)
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=is_tup)
    new_params = jax.tree.map(lambda np_, p: np_.astype(p.dtype), new_p32, params)
    new_master = (
        None
        if state.master is None
        else jax.tree.map(lambda np_, mp: np_.astype(mp.dtype), new_p32, state.master)
    )
    return new_params, AdamWState(m=new_m, v=new_v, count=count, master=new_master)
