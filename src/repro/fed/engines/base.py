"""Shared engine plumbing: budget math, round dataclasses, the sequential
reference engine, and the server-owner mixin.

See :mod:`repro.fed.engines` for the package overview (this file is the
PR-9 split of the former monolithic ``repro.fed.engine`` module).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.channel import BatchedChannelState, ChannelState, topk_budget_batch
from repro.core.protocol import UplinkPayload, downlink_bits, lora_projection_bits
from repro.core.topk import QUANT_LEVELS, QuantizedWire, SparseWire, densify
from repro.fed.client import Client
from repro.lora import merge_lora, split_lora

__all__ = [
    "BroadcastState",
    "ClientPhase",
    "RoundsTrajectory",
    "SequentialEngine",
    "tree_stack",
    "k_cap_bucket",
    "cohort_budgets",
    "check_unique_cohort",
    "fake_quant_dense",
    "shared_frozen_backbone",
]


def cohort_budgets(
    states,
    cfg: ModelConfig,
    n_samples: int,
    adaptive_k: bool,
    n_cohort: int,
    send_h: bool = False,
    *,
    value_bits: int = 16,
    k_min: int = 1,
    quantize_wire: bool = False,
) -> list[int]:
    """Per-client adaptive k for a cohort — ONE host-side scalar routine
    shared by every engine (and by the fault layer, which must price
    attempted uploads with exactly the engines' k math so HARQ retries and
    quarantine decisions can never drift from what the engine transmits).

    With ``send_h`` the LoRA-projection bits are reserved out of each
    budget first (see :meth:`repro.fed.client.Client.upload`).  Under
    ``quantize_wire`` the (value, index) entries are priced at 8 value
    bits — the same Shannon budget genuinely affords a larger k — while
    the unquantized projection stays at ``value_bits``.
    """
    if not adaptive_k:
        return [cfg.vocab_size] * n_cohort
    reserved = (
        lora_projection_bits(n_samples, cfg.lora.rank, value_bits)
        if (send_h and cfg.lora is not None)
        else 0
    )
    wire_bits = 8 if quantize_wire else value_bits
    return topk_budget_batch(
        states, vocab_size=cfg.vocab_size, num_samples=n_samples,
        value_bits=wire_bits, k_min=k_min, reserved_bits=reserved,
    )


def k_cap_bucket(ks: Sequence[int], vocab: int) -> int:
    """Static sparse-wire width for a round: the next power of two >=
    max(ks), clamped to the vocabulary.  Bucketing keeps the number of
    distinct compiled round executables at O(log2 V) while the adaptive
    budgets themselves stay DATA (the transmit mask)."""
    need = max([k for k in ks] + [1])
    cap = 1
    while cap < need:
        cap *= 2
    return min(cap, vocab)


def check_unique_cohort(sel: Sequence[int]) -> list[int]:
    """Validate a USER-provided cohort selection at the engine boundary.

    The engines' scatter-back is ``.at[sel].set`` (and the host store's
    row writes), where duplicate indices resolve in UNSPECIFIED order —
    a silently nondeterministic fleet.  The internal shard-padding path
    (:meth:`FusedEngine._pad_cohort`) intentionally appends duplicate
    rows AFTER this check and discards their advanced state before any
    write-back, so it stays legal.  Returns the selection as ints."""
    out = [int(i) for i in sel]
    if len(set(out)) != len(out):
        dups = sorted({i for i in out if out.count(i) > 1})
        raise ValueError(
            f"cohort selection contains duplicate client ids {dups}: the "
            "scatter-back (.at[sel].set) would resolve duplicate rows in "
            "unspecified order — select each client at most once per round"
        )
    return out


def _channel_scan_ops(channel_scan: dict, num_rounds: int) -> tuple:
    """Validate + device-stage a ``scan_channel_inputs`` dict for the
    multi-round drivers: (z0, bad0, w, u, base_snr_db, rho, p_gb, p_bg,
    fade_scale).  Every element is DATA — the drivers compile one channel
    program for all scenarios."""
    try:
        w = np.asarray(channel_scan["w"])
    except KeyError as e:
        raise ValueError(f"channel_scan is missing key {e}") from None
    if w.ndim != 2 or w.shape[0] < num_rounds:
        raise ValueError(
            f"channel_scan covers {w.shape[0] if w.ndim == 2 else '?'} "
            f"rounds, need {num_rounds} "
            "(ChannelSimulator.scan_channel_inputs(num_rounds))"
        )
    return (
        jnp.asarray(channel_scan["z0"], jnp.float32),
        jnp.asarray(channel_scan["bad0"], bool),
        jnp.asarray(w[:num_rounds], jnp.float32),
        jnp.asarray(np.asarray(channel_scan["u"])[:num_rounds], jnp.float32),
        jnp.asarray(
            np.asarray(channel_scan["base_snr_db"])[:num_rounds], jnp.float32
        ),
        jnp.asarray(channel_scan["rho"], jnp.float32),
        jnp.asarray(channel_scan["p_gb"], jnp.float32),
        jnp.asarray(channel_scan["p_bg"], jnp.float32),
        jnp.asarray(channel_scan["fade_scale"], jnp.float32),
    )


def fake_quant_dense(dense: jax.Array) -> jax.Array:
    """Quantize-dequantize a densified top-k stack through the int8 wire's
    per-(client, sample)-row symmetric code — what the dense-path engines
    (batched/fused client phase) apply under ``quantize_wire`` so their
    uplink carries exactly the values the 8-bit-per-entry ledger prices.
    Zeros (off-support entries) map to exact zeros, so the support is
    preserved."""
    amax = jnp.max(jnp.abs(dense), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / QUANT_LEVELS, 1.0)
    q = jnp.clip(jnp.round(dense / scale), -QUANT_LEVELS, QUANT_LEVELS)
    return q * scale


def tree_stack(trees: Sequence) -> object:
    """Stack a list of identically-structured pytrees along a new leading
    (client) axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def shared_frozen_backbone(frozens: Sequence) -> bool:
    """True iff every client's frozen tree is literally the same arrays —
    the paper's setting (one pretrained W' under per-client LoRA deltas).
    Identity, not value comparison: O(leaves), no device work."""
    first = jax.tree.leaves(frozens[0])
    for other in frozens[1:]:
        leaves = jax.tree.leaves(other)
        if len(leaves) != len(first) or any(a is not b for a, b in zip(first, leaves)):
            return False
    return True


@dataclasses.dataclass(frozen=True)
class BroadcastState:
    """The server's knowledge broadcast carried across rounds (Fig. 1 step 1).

    Replaces the fragile ``pub_tokens_prev`` / ``g_bits`` forward references:
    the public tokens the knowledge was computed on travel *with* the logits
    they explain, and the downlink cost is accounted from the same object.
    """

    tokens: jax.Array  # (P, L) public batch the knowledge was inferred on
    logits: jax.Array  # (P, V) global logits K_g
    h: jax.Array | None  # (P, r) global LoRA projection h_g
    bits: int  # on-air size of one broadcast to one client


@dataclasses.dataclass
class ClientPhase:
    """Result of one round's client phase, engine-agnostic.

    ``dense``/``h`` hold only the ``num_transmitters`` clients that actually
    uploaded (leading axis), in cohort order; ``ks`` covers every *selected*
    client (0 marks a dropped straggler).  The fused-e2e engine reports the
    uplink as the sparse wire format instead (``sparse``; ``dense`` stays
    None — no (T, P, V) stack exists on that path).
    """

    dense: jax.Array | None  # (T, P, V) densified top-k logits
    h: jax.Array | None  # (T, P, r) LoRA projections
    payloads: list[UplinkPayload]
    ks: list[int]
    # (T, P, k_cap) wire — QuantizedWire under the engines' quantize_wire
    sparse: SparseWire | QuantizedWire | None = None

    @property
    def uplink_bytes(self) -> float:
        return float(sum(p.bytes for p in self.payloads))

    @property
    def num_transmitters(self) -> int:
        return len(self.payloads)


@dataclasses.dataclass
class RoundsTrajectory:
    """Per-round observables of one :meth:`FusedE2EEngine.run_rounds` block.

    ``ks``/``payloads`` are the host-side accounting (identical to what R
    ``run_round`` calls report); ``mean_k``, ``distill_loss`` and — when
    eval data was passed — ``server_acc``/``client_acc`` come from the
    IN-SCAN eval tap: they are scanned outputs of the single compiled
    multi-round dispatch, not host round-trips.  ``distill_loss`` is the
    round's final server-distill step loss (NaN for an all-dropped round —
    the server never distilled).

    Heterogeneous blocks (:meth:`HeteroFusedE2EEngine.run_rounds`)
    additionally fill ``family_client_acc``: per round, one accuracy per
    family bucket (fleet bucket order), each evaluated on that bucket's
    first selected client of the round (or its bucket-local client 0 when
    the family sat the round out).  ``client_acc`` remains the cohort's
    first selected client — the host loop's metric — which is always one of
    those family entries.
    """

    ks: list[list[int]]
    payloads: list[list[UplinkPayload]]
    mean_k: list[float]
    distill_loss: list[float]
    server_acc: list[float] | None = None
    client_acc: list[float] | None = None
    family_client_acc: list[list[float]] | None = None
    # Scenario runs only (``channel_scan`` passed): the in-scan channel
    # replica's per-round realised cohort SNR (dB, -inf in outage) and
    # Gilbert-Elliott outage flags — scanned outputs of the same compiled
    # dispatch, evolved from the channel carry (f32 replica of the host
    # realisation that priced ``ks``/``payloads``).
    snr_db: list[list[float]] | None = None
    outage: list[list[bool]] | None = None


class SequentialEngine:
    """Reference client-phase executor: one client at a time (Algorithm 1
    exactly as written)."""

    name = "sequential"
    store_kind = "device"  # per-client params live on device, unstacked

    def __init__(
        self,
        clients: list[Client],
        cfg: ModelConfig,
        *,
        value_bits: int = 16,
        k_min: int = 1,
        **_unused,
    ):
        self.clients = clients
        self.cfg = cfg
        self.value_bits = value_bits
        self.k_min = k_min

    def client_params(self, cid: int):
        """Current parameters of one client (for evaluation)."""
        return self.clients[cid].params

    def fleet_state(self) -> dict:
        """The whole fleet's trainable state as one checkpointable pytree.
        Per-client subtrees (not a stacked axis): the sequential engine
        serves mixed-architecture fleets natively, so client leaves need
        not share shapes."""
        return {
            f"client{i}": {"params": c.params, "opt": c.opt}
            for i, c in enumerate(self.clients)
        }

    def load_fleet_state(self, state: dict) -> None:
        for i, c in enumerate(self.clients):
            c.params = jax.tree.map(jnp.asarray, state[f"client{i}"]["params"])
            c.opt = jax.tree.map(jnp.asarray, state[f"client{i}"]["opt"])

    def prefetch_cohort(self, sel: Sequence[int]) -> None:
        """No-op: every client's state already lives on device."""

    def run_round(
        self,
        sel: Sequence[int],
        pub_tokens: jax.Array,
        bcast: BroadcastState | None,
        states: BatchedChannelState | Sequence[ChannelState],
        *,
        adaptive_k: bool,
        send_h: bool,
    ) -> ClientPhase:
        sel = check_unique_cohort(sel)
        cohort = [self.clients[i] for i in sel]
        if bcast is not None:
            for c in cohort:
                c.local_distill(bcast.tokens, bcast.logits, bcast.h)
        dense_rows, hs, payloads, ks = [], [], [], []
        for c, st in zip(cohort, states):
            c.local_train()
            up = c.upload(
                pub_tokens,
                st,
                value_bits=self.value_bits,
                k_override=None if adaptive_k else self.cfg.vocab_size,
                send_h=send_h,
                k_min=self.k_min,
            )
            if up is None:  # straggler in outage: transmits nothing
                ks.append(0)
                continue
            ks.append(up.k)
            dense_rows.append(densify(up.sparse))
            if up.h is not None:
                hs.append(up.h)
            payloads.append(up.payload)
        return ClientPhase(
            dense=jnp.stack(dense_rows) if dense_rows else None,
            h=jnp.stack(hs) if hs else None,
            payloads=payloads,
            ks=ks,
        )


class _ServerOwnerMixin:
    """Server-state plumbing shared by the end-to-end engines (homogeneous
    :class:`FusedE2EEngine` and bucketed :class:`HeteroFusedE2EEngine`):
    they own the server LLM's state for the duration of a run, compute the
    broadcast in-program, and sync back for evaluation/checkpointing.

    Expects the owner to maintain ``server``, ``_s_lora``/``_s_frozen``/
    ``_s_opt``, the broadcast carry ``_b_tokens``/``_b_logits``/``_b_h``
    and the observability tap ``_d_loss``.
    """

    handles_server = True

    def _init_server_state(self, server) -> None:
        self.server = server
        self._s_lora, self._s_frozen = split_lora(server.params)
        self._s_opt = server.opt
        # broadcast knowledge computed in-program, carried across rounds
        self._b_tokens: jax.Array | None = None
        self._b_logits: jax.Array | None = None
        self._b_h: jax.Array | None = None
        self._d_loss: jax.Array | None = None

    def _cold_broadcast(self, pub_tokens: jax.Array, n_samples: int):
        """Round-0 placeholder g_* operands (same arg structure as a warm
        round; ``g_valid=False`` discards their effect in-program)."""
        g_logits = jnp.zeros((n_samples, self.server.cfg.vocab_size), jnp.float32)
        if self.server.cfg.lora is not None:
            g_h = jnp.zeros((n_samples, self.server.cfg.lora.rank), jnp.float32)
        else:
            g_h = None
        return pub_tokens, g_logits, g_h

    def broadcast_state(self, pub_tokens: jax.Array) -> BroadcastState:
        """The in-program-refreshed broadcast of the LAST executed round, as
        the host-side carrier (byte accounting identical to
        :meth:`repro.fed.server.Server.broadcast`)."""
        assert self._b_logits is not None, "no round has run yet"
        rank = (
            self.server.cfg.lora.rank
            if (self.server.cfg.lora is not None and self._b_h is not None)
            else None
        )
        bits = downlink_bits(
            int(self._b_logits.shape[0]), int(self._b_logits.shape[-1]), rank
        )
        return BroadcastState(
            tokens=pub_tokens, logits=self._b_logits, h=self._b_h, bits=bits
        )

    @property
    def last_distill_loss(self) -> float:
        """The final server-distill step loss of the last executed round
        (computed in-program; NaN before any round ran or for an all-dropped
        round)."""
        return float("nan") if self._d_loss is None else float(self._d_loss)

    def sync_server(self) -> None:
        """Materialise the engine-held server state back onto the Server
        object (for evaluation / checkpointing)."""
        self.server.params = merge_lora(self._s_lora, self._s_frozen)
        self.server.opt = self._s_opt

    def server_state(self) -> dict:
        """The engine-held server state as one checkpointable pytree."""
        return {
            "s_lora": self._s_lora,
            "s_frozen": self._s_frozen,
            "s_opt": self._s_opt,
        }

    def load_server_state(self, state: dict) -> None:
        as_jax = lambda tree: jax.tree.map(jnp.asarray, tree)  # noqa: E731
        self._s_lora = as_jax(state["s_lora"])
        self._s_frozen = as_jax(state["s_frozen"])
        self._s_opt = as_jax(state["s_opt"])
        self.sync_server()

    def load_broadcast(self, tokens, logits, h=None) -> None:
        """Restore the in-program broadcast carry (the knowledge the NEXT
        round's cohort distills against) from a checkpoint."""
        self._b_tokens = jnp.asarray(tokens)
        self._b_logits = jnp.asarray(logits)
        self._b_h = None if h is None else jnp.asarray(h)
