"""Pallas TPU kernel: fused adaptive logit aggregation (paper eqs. 6-7).

The jnp reference materialises three (N, rows, V) temporaries (|K|, weights,
weighted stack) — four HBM passes over N x rows x V.  This kernel reads each
input tile once and emits the aggregated tile directly:

    out = ( Σ_n |x_n| · x_n ) / ( Σ_n |x_n| + ε )

Grid: (row_blocks, vocab_tiles); each step owns an (N, R_b, V_b) input block
(the client axis N is small — the paper selects 10 clients/round — so it
rides whole in VMEM) and the (R_b, V_b) output tile.  Pure VPU elementwise +
client-axis reduction: the canonical memory-bound fusion.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "sparse_agg_pallas",
    "scatter_wire_sums_pallas",
    "scatter_wire_sums_dequant_pallas",
]

ROWS_BLK = 8
VOCAB_BLK = 2048
EPS = 1e-12

# scatter_wire_sums: rows per grid step, sized so the two dense (rb, V)
# output accumulators stay within ~8 MB of VMEM even at 256k vocabularies.
SCATTER_ROWS_BLK = 8
_SCATTER_VMEM_BUDGET = 8 * 1024 * 1024


def _agg_kernel(stack_ref, out_ref):
    x = stack_ref[...].astype(jnp.float32)  # (N, R_b, V_b)
    s = jnp.abs(x)
    num = jnp.sum(s * x, axis=0)
    den = jnp.sum(s, axis=0)
    out_ref[...] = (num / (den + EPS)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparse_agg_pallas(stack: jax.Array, *, interpret: bool = False) -> jax.Array:
    """(N, rows, vocab) densified sparse logits -> (rows, vocab) fp32."""
    assert stack.ndim == 3
    n, rows, vocab = stack.shape
    rb = min(ROWS_BLK, rows)
    vb = min(VOCAB_BLK, vocab)
    rpad = (-rows) % rb
    vpad = (-vocab) % vb
    x = jnp.pad(stack, ((0, 0), (0, rpad), (0, vpad))) if (rpad or vpad) else stack
    r_all, v_all = x.shape[1:]
    grid = (r_all // rb, v_all // vb)

    out = pl.pallas_call(
        _agg_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n, rb, vb), lambda r, j: (0, r, j))],
        out_specs=pl.BlockSpec((rb, vb), lambda r, j: (r, j)),
        out_shape=jax.ShapeDtypeStruct((r_all, v_all), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:rows, :vocab]


# ---------------------------------------------------------------------------
# PR-3: scatter-accumulate straight from the sparse wire format.
#
# The kernel above still READS a densified (N, rows, V) stack — O(N·rows·V)
# HBM traffic that exists only because the uplink was scattered back to
# dense.  The wire-format kernel skips that entirely: each grid step owns
# one (N, R_b, k) block of (value, index) entries (the actual on-air
# payload) and the two (R_b, V) output accumulators, and scatters each
# client's k entries into VMEM-resident accumulators.  HBM traffic drops
# from O(N·rows·V) reads to O(N·rows·k) reads + O(rows·V) writes — the
# aggregation working set the paper's Top-k sparsification actually implies.
#
# The client loop is a fori_loop (N is the cohort size, ~10); each
# iteration is one k-wide scatter-add into the (R_b, V) accumulator.  The
# kernel is mode-agnostic: callers pre-compute the two per-entry
# contribution channels (adaptive: s·v and s; zeropad/mean_nonzero: v and
# mask), so ONE kernel serves all three aggregation modes.
# ---------------------------------------------------------------------------


def _scatter_wire_kernel(a_ref, b_ref, idx_ref, num_ref, den_ref):
    a = a_ref[...].astype(jnp.float32)  # (N, R_b, k)
    b = b_ref[...].astype(jnp.float32)
    idx = idx_ref[...]  # (N, R_b, k) int32, valid in [0, V)
    n, rb, k = a.shape
    vocab = num_ref.shape[-1]
    row = jax.lax.broadcasted_iota(jnp.int32, (rb, k), 0)

    def body(i, carry):
        num, den = carry
        num = num.at[row, idx[i]].add(a[i])
        den = den.at[row, idx[i]].add(b[i])
        return num, den

    num, den = jax.lax.fori_loop(
        0,
        n,
        body,
        (jnp.zeros((rb, vocab), jnp.float32), jnp.zeros((rb, vocab), jnp.float32)),
    )
    num_ref[...] = num
    den_ref[...] = den


def _scatter_rows_block(vocab: int, rows: int) -> int:
    """Rows per block so the two fp32 (rb, V) accumulators + outputs fit the
    VMEM budget."""
    per_row = 4 * vocab * 4  # 2 accumulators + 2 output tiles, fp32
    return max(1, min(SCATTER_ROWS_BLK, rows, _SCATTER_VMEM_BUDGET // max(1, per_row)))


@functools.partial(jax.jit, static_argnames=("vocab", "interpret"))
def scatter_wire_sums_pallas(
    a: jax.Array,
    b: jax.Array,
    indices: jax.Array,
    vocab: int,
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Two-channel wire scatter: ``a, b, indices (N, rows, k)`` ->
    ``(num, den)`` each ``(rows, vocab)`` fp32, where
    ``num[r, idx[n,r,j]] += a[n,r,j]`` (b into den).  Masked-out entries
    must carry zero contributions (their index may be any valid id)."""
    assert a.ndim == 3 and a.shape == b.shape == indices.shape
    n, rows, k = a.shape
    rb = _scatter_rows_block(vocab, rows)
    rpad = (-rows) % rb
    if rpad:
        pad3 = ((0, 0), (0, rpad), (0, 0))
        a = jnp.pad(a, pad3)
        b = jnp.pad(b, pad3)
        indices = jnp.pad(indices, pad3)  # zero contributions at index 0
    r_all = a.shape[1]
    grid = (r_all // rb,)

    wire_spec = pl.BlockSpec((n, rb, k), lambda r: (0, r, 0))
    out_spec = pl.BlockSpec((rb, vocab), lambda r: (r, 0))
    num, den = pl.pallas_call(
        _scatter_wire_kernel,
        grid=grid,
        in_specs=[wire_spec, wire_spec, wire_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((r_all, vocab), jnp.float32),
            jax.ShapeDtypeStruct((r_all, vocab), jnp.float32),
        ],
        interpret=interpret,
    )(a, b, indices)
    return num[:rows], den[:rows]


# ---------------------------------------------------------------------------
# Dequantize-fused wire scatter: the QuantizedWire's int8 values + per-row
# float32 scale go straight into the kernel — the float values and both
# per-entry contribution channels are reconstructed in-register per grid
# step, so the HBM-side wire stays 1 byte/value (vs 4 for pre-dequantized
# float contributions) and nothing of size O(N·rows·V) ever exists.  Mode
# is static: unlike the float kernel above (mode-agnostic, callers
# pre-compute the channels), the fusion point is exactly that the channels
# are NOT pre-computed, so the kernel must know which ones to build.
# ---------------------------------------------------------------------------


def _scatter_wire_dequant_kernel(
    q_ref, scale_ref, mask_ref, idx_ref, num_ref, den_ref, *, mode: str
):
    q = q_ref[...].astype(jnp.float32)  # (N, R_b, k) int8 -> f32
    sc = scale_ref[...].astype(jnp.float32)  # (N, R_b)
    m = mask_ref[...].astype(jnp.float32)  # (N, R_b, k) int8 in {0, 1}
    idx = idx_ref[...]  # (N, R_b, k) int32, valid in [0, V)
    v = q * sc[:, :, None] * m  # dequantized values, 0 where masked
    if mode == "adaptive":
        a, b = jnp.abs(v) * v, jnp.abs(v)
    else:  # zeropad / mean_nonzero: value and transmit-count channels
        a, b = v, m
    n, rb, k = a.shape
    vocab = num_ref.shape[-1]
    row = jax.lax.broadcasted_iota(jnp.int32, (rb, k), 0)

    def body(i, carry):
        num, den = carry
        num = num.at[row, idx[i]].add(a[i])
        den = den.at[row, idx[i]].add(b[i])
        return num, den

    num, den = jax.lax.fori_loop(
        0,
        n,
        body,
        (jnp.zeros((rb, vocab), jnp.float32), jnp.zeros((rb, vocab), jnp.float32)),
    )
    num_ref[...] = num
    den_ref[...] = den


@functools.partial(jax.jit, static_argnames=("vocab", "mode", "interpret"))
def scatter_wire_sums_dequant_pallas(
    q_values: jax.Array,
    scale: jax.Array,
    mask: jax.Array,
    indices: jax.Array,
    vocab: int,
    mode: str = "adaptive",
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Dequantize-fused two-channel wire scatter.

    ``q_values (N, rows, k) int8``, ``scale (N, rows) f32``,
    ``mask (N, rows, k) int8 in {0, 1}``, ``indices (N, rows, k) int32`` ->
    ``(num, den)`` each ``(rows, vocab)`` fp32 for the given aggregation
    ``mode`` (static).  Masked entries contribute exactly 0 to both
    channels regardless of their index."""
    assert q_values.ndim == 3 and q_values.shape == mask.shape == indices.shape
    assert scale.shape == q_values.shape[:2]
    if mode not in ("adaptive", "zeropad", "mean_nonzero"):
        raise ValueError(f"unknown aggregation mode: {mode!r}")
    n, rows, k = q_values.shape
    rb = _scatter_rows_block(vocab, rows)
    rpad = (-rows) % rb
    if rpad:
        pad3 = ((0, 0), (0, rpad), (0, 0))
        q_values = jnp.pad(q_values, pad3)
        mask = jnp.pad(mask, pad3)  # zero mask -> zero contributions at idx 0
        indices = jnp.pad(indices, pad3)
        scale = jnp.pad(scale, ((0, 0), (0, rpad)))
    r_all = q_values.shape[1]
    grid = (r_all // rb,)

    wire_spec = pl.BlockSpec((n, rb, k), lambda r: (0, r, 0))
    scale_spec = pl.BlockSpec((n, rb), lambda r: (0, r))
    out_spec = pl.BlockSpec((rb, vocab), lambda r: (r, 0))
    num, den = pl.pallas_call(
        functools.partial(_scatter_wire_dequant_kernel, mode=mode),
        grid=grid,
        in_specs=[wire_spec, scale_spec, wire_spec, wire_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((r_all, vocab), jnp.float32),
            jax.ShapeDtypeStruct((r_all, vocab), jnp.float32),
        ],
        interpret=interpret,
    )(q_values, scale, mask, indices)
    return num[:rows], den[:rows]
