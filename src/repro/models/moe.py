"""Mixture-of-Experts MLP with top-k routing and capacity-factor dispatch.

TPU-native design (DESIGN §4): experts are stacked on a leading axis that is
sharded over the ``model`` mesh axis; dispatch/combine are dense einsums over
one-hot routing tensors (GShard/Switch style), which XLA lowers to
all-to-all-shaped collectives between the token-sharded and expert-sharded
operands.  This keeps the MoE layer a single differentiable graph — no
ragged buffers — at the cost of the capacity-factor padding, which the
roofline accounts for explicitly.

Tokens are processed in **groups** (GShard's group dimension): the dispatch
tensor is (G, Tg, E, C) with per-group capacity C = cf·k·Tg/E, so its size
grows as T·Tg·k·cf instead of the ungrouped T²·k·cf — the difference between
335 MB and 21 GB at the train_4k shape.

Routing: softmax router, top-k experts per token, renormalized gates,
position-in-expert via cumulative sum (slot-major, group-local), tokens
beyond capacity dropped (standard Switch behaviour).  An auxiliary
load-balance loss (Switch Transformer eq. 4) is returned for the trainer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

__all__ = ["moe_init", "moe_apply"]

# Tokens per dispatch group.  Chosen so the (G,Tg,E,C) dispatch tensor stays
# O(100MB) at the largest assigned shapes while C stays MXU-aligned-ish.
GROUP_SIZE = 1024


def moe_init(rng: jax.Array, cfg: ModelConfig) -> dict:
    assert cfg.moe is not None
    e, d, f = cfg.moe.num_experts, cfg.d_model, cfg.moe.d_ff
    keys = jax.random.split(rng, 4)

    def stack_init(key, din, dout):
        std = 1.0 / (din**0.5)
        w = jax.random.truncated_normal(key, -2.0, 2.0, (e, din, dout), jnp.float32) * std
        return w.astype(jnp.dtype(cfg.param_dtype))

    params = {
        "router": dense_init(keys[0], d, e, use_bias=False, dtype=cfg.param_dtype),
        "up": stack_init(keys[1], d, f),
        "down": stack_init(keys[2], f, d),
    }
    if cfg.activation == "swiglu":
        params["gate"] = stack_init(keys[3], d, f)
    return params


def moe_apply(
    params: dict, x: jax.Array, cfg: ModelConfig
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (output (B,S,D), aux load-balance loss scalar)."""
    assert cfg.moe is not None
    moe = cfg.moe
    cd = jnp.dtype(cfg.compute_dtype)
    b, s, d = x.shape
    t = b * s
    tg = min(GROUP_SIZE, t)
    assert t % tg == 0, f"token count {t} not divisible by group size {tg}"
    g = t // tg
    tokens = x.reshape(g, tg, d).astype(cd)

    # ---- routing ----
    router_logits = jnp.einsum("gtd,de->gte", tokens, params["router"]["w"].astype(cd))
    router_probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)  # (G,Tg,E)
    gate_vals, expert_idx = jax.lax.top_k(router_probs, moe.top_k)  # (G,Tg,K)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)

    capacity = int(max(4, round(moe.capacity_factor * moe.top_k * tg / moe.num_experts)))
    capacity = min(capacity, tg)

    # one-hot over experts per routing slot: (G, Tg, K, E)
    onehot = jax.nn.one_hot(expert_idx, moe.num_experts, dtype=jnp.float32)
    # position of each (token, slot) within its expert queue, slot-major so
    # every token's first choice is served before any second choice.
    flat = onehot.transpose(0, 2, 1, 3).reshape(g, moe.top_k * tg, moe.num_experts)
    pos_flat = jnp.cumsum(flat, axis=1) - flat  # (G, K*Tg, E)
    position = pos_flat.reshape(g, moe.top_k, tg, moe.num_experts).transpose(0, 2, 1, 3)
    position_in_expert = jnp.sum(position * onehot, axis=-1)  # (G,Tg,K)
    keep = position_in_expert < capacity
    gates = gate_vals * keep.astype(gate_vals.dtype)

    # dispatch/combine tensors (G, Tg, E, C)
    cap_onehot = jax.nn.one_hot(position_in_expert, capacity, dtype=jnp.float32)
    dispatch = jnp.einsum("gtke,gtkc->gtec", onehot * keep[..., None], cap_onehot)
    combine = jnp.einsum("gtk,gtke,gtkc->gtec", gates, onehot, cap_onehot)

    # ---- expert computation (E is the model-sharded axis) ----
    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch.astype(cd), tokens)  # (G,E,C,D)
    up = jnp.einsum("gecd,edf->gecf", expert_in, params["up"].astype(cd))
    if "gate" in params:
        gate_h = jnp.einsum("gecd,edf->gecf", expert_in, params["gate"].astype(cd))
        hidden = jax.nn.silu(gate_h) * up
    else:
        hidden = jax.nn.gelu(up)
    expert_out = jnp.einsum("gecf,efd->gecd", hidden, params["down"].astype(cd))

    out = jnp.einsum("gtec,gecd->gtd", combine.astype(cd), expert_out)  # (G,Tg,D)

    # ---- Switch load-balance auxiliary loss ----
    top1 = jax.nn.one_hot(expert_idx[..., 0], moe.num_experts, dtype=jnp.float32)
    f_e = jnp.mean(top1, axis=(0, 1))
    p_e = jnp.mean(router_probs, axis=(0, 1))
    aux = moe.num_experts * jnp.sum(f_e * p_e)

    return out.reshape(b, s, d).astype(x.dtype), aux.astype(jnp.float32)
