from repro.lora.lora import (
    is_lora_path,
    lora_param_count,
    lora_template,
    map_lora,
    merge_lora,
    path_strings,
    split_lora,
)

__all__ = [
    "is_lora_path",
    "lora_param_count",
    "lora_template",
    "map_lora",
    "merge_lora",
    "path_strings",
    "split_lora",
]
