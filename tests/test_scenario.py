"""Scenario engine: time-correlated channel dynamics (PR 7).

Three layers of guarantees:

1. Statistical correctness of the dynamics themselves — the Gauss-Markov
   chain's lag-1 autocorrelation matches ``rho``, its stationary marginal
   stays the i.i.d. Exp(1) Rayleigh-power law (the Gaussian copula's whole
   point), the Gilbert-Elliott burst-length mean matches the closed form
   1/p_bg, and Jakes' Doppler correlation comes out of the J0 Bessel form.
2. Determinism/keying — ``rho=0`` (and every i.i.d.-equivalent spelling:
   ``scenario=None``, ``ScenarioConfig()``, the ``iid`` preset) is
   bit-identical to the legacy per-round draws; realisations are invariant
   to query call-order and cohort permutation (PR-4's re-keying guarantees
   extended to stateful channels).
3. The golden trajectory — a committed tiny-scenario record
   (tests/data/scenario_golden.json: per-round k / payload bytes / outage
   for the gauss_markov and jakes presets at fixed seed) asserted
   bit-identical between the host round loop and the one-dispatch
   ``run_rounds`` scan, whose in-scan channel tap must replay the host
   simulator.

Regenerate the golden record (only after an intentional format change):

    PYTHONPATH=src python tests/test_scenario.py --regen
"""

import json
import math
import os

import numpy as np
import pytest

from repro.core.channel import ChannelConfig, ChannelSimulator
from repro.core.scenario import (
    ScenarioConfig,
    bessel_j0,
    exp_to_gauss,
    ge_mean_burst,
    ge_stationary_bad,
    get_scenario,
    jakes_rho,
)

_GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data", "scenario_golden.json")
_GOLDEN_PRESETS = ("gauss_markov", "jakes")
_GOLDEN_SELS = [[0, 1], [2, 3], [1, 2]]
_GOLDEN_CHAN = ChannelConfig(
    bandwidth_hz=2e5, mean_snr_db=2.0, min_k=0, dropout_prob=0.25
)


# ---------------------------------------------------------------------------
# presets / config validation / Jakes
# ---------------------------------------------------------------------------


def test_preset_registry():
    for name in ("iid", "gauss_markov", "jakes", "gilbert_elliott", "mobility"):
        sc = get_scenario(name)
        assert isinstance(sc, ScenarioConfig) and sc.name == name
    assert get_scenario(None) is None
    custom = ScenarioConfig(name="mine", rho=0.5)
    assert get_scenario(custom) is custom
    with pytest.raises(ValueError, match="unknown scenario"):
        get_scenario("definitely_not_a_preset")


def test_config_validation():
    with pytest.raises(ValueError, match="rho"):
        ScenarioConfig(rho=1.0)
    with pytest.raises(ValueError, match="rho"):
        ScenarioConfig(rho=-0.1)
    with pytest.raises(ValueError, match="p_gb"):
        ScenarioConfig(p_gb=0.2)  # p_bg missing
    with pytest.raises(ValueError, match=r"p_gb must be in \[0, 1\]"):
        ScenarioConfig(p_gb=1.5, p_bg=0.5)
    with pytest.raises(ValueError, match="period"):
        ScenarioConfig(snr_period_rounds=0.0)


def test_bessel_j0_reference_values():
    # Abramowitz & Stegun table values (scipy.special.j0 cross-checked to
    # <5e-9 during development; scipy itself is not a dependency).
    for x, want in [(0.0, 1.0), (1.0, 0.7651976866), (2.404825558, 0.0),
                    (5.0, -0.1775967713), (10.0, -0.2459357645)]:
        assert bessel_j0(x) == pytest.approx(want, abs=1e-7)


def test_jakes_rho_physics():
    # rho = J0(2 pi f_d T): zero velocity -> full correlation; faster
    # clients decorrelate; the preset's pedestrian 1 m/s @ 2.6 GHz, 5 ms
    # slot sits near 0.98.
    assert jakes_rho(0.0, 2.6e9, 5e-3) == pytest.approx(1.0, abs=1e-6)
    rhos = [jakes_rho(v, 2.6e9, 5e-3) for v in (0.5, 1.0, 3.0, 10.0)]
    assert rhos == sorted(rhos, reverse=True)
    assert jakes_rho(1.0, 2.6e9, 5e-3) == pytest.approx(0.9815, abs=1e-3)
    sc = get_scenario("jakes")
    assert sc.effective_rho == pytest.approx(jakes_rho(1.0, 2.6e9, 5e-3))


# ---------------------------------------------------------------------------
# statistical properties of the dynamics
# ---------------------------------------------------------------------------


def _realise(sim: ChannelSimulator, rounds: int) -> np.ndarray:
    """(rounds, num_clients) realised SNR dB."""
    ids = list(range(sim.num_clients))
    return np.array(
        [[s.snr_db for s in sim.states(r, ids)] for r in range(rounds)]
    )


def _fade_power(sim: ChannelSimulator, snr: np.ndarray) -> np.ndarray:
    """Invert the realised SNR back to the fading power (Exp(1) marginal)."""
    base = sim.config.mean_snr_db + sim._shadowing_db
    return 10.0 ** ((snr - base[None, :]) / 10.0)


def test_gauss_markov_autocorrelation_matches_rho():
    rho = 0.8
    cfg = ChannelConfig(scenario=ScenarioConfig(name="gm", rho=rho))
    sim = ChannelSimulator(8, cfg, seed=3)
    power = _fade_power(sim, _realise(sim, 300))
    z = exp_to_gauss(power)  # back to the underlying AR(1) Gaussian
    a, b = z[:-1].ravel(), z[1:].ravel()
    corr = np.corrcoef(a, b)[0, 1]
    assert corr == pytest.approx(rho, abs=0.05)


def test_gauss_markov_stationary_marginal_is_exp1():
    # The copula construction keeps the per-round marginal EXACTLY the
    # i.i.d. Exp(1) Rayleigh power regardless of rho — check the first two
    # moments and the median against the closed form.
    cfg = ChannelConfig(scenario=ScenarioConfig(name="gm", rho=0.9))
    sim = ChannelSimulator(8, cfg, seed=5)
    p = _fade_power(sim, _realise(sim, 400)).ravel()
    assert np.mean(p) == pytest.approx(1.0, abs=0.08)
    assert np.median(p) == pytest.approx(math.log(2), abs=0.06)
    assert np.mean(p > 3.0) == pytest.approx(math.exp(-3.0), abs=0.02)


def test_gilbert_elliott_burst_statistics():
    p_gb, p_bg = 0.2, 0.25
    cfg = ChannelConfig(
        fast_fading=False,
        scenario=ScenarioConfig(name="ge", p_gb=p_gb, p_bg=p_bg),
    )
    sim = ChannelSimulator(6, cfg, seed=11)
    bad = ~np.isfinite(_realise(sim, 500))
    # stationary occupancy
    assert ge_stationary_bad(p_gb, p_bg) == pytest.approx(p_gb / (p_gb + p_bg))
    assert np.mean(bad) == pytest.approx(ge_stationary_bad(p_gb, p_bg), abs=0.05)
    # mean burst length == 1/p_bg (geometric dwell in the bad state)
    bursts = []
    for c in range(bad.shape[1]):
        run = 0
        for b in bad[:, c]:
            if b:
                run += 1
            elif run:
                bursts.append(run)
                run = 0
        if run:
            bursts.append(run)
    assert ge_mean_burst(p_bg) == pytest.approx(1.0 / p_bg)
    assert np.mean(bursts) == pytest.approx(ge_mean_burst(p_bg), abs=0.6)


# ---------------------------------------------------------------------------
# rho = 0 bit-identity + keying invariances
# ---------------------------------------------------------------------------

_IID_SPELLINGS = [None, ScenarioConfig(), "iid"]


@pytest.mark.parametrize("dropout", [0.0, 0.3])
@pytest.mark.parametrize("spelling", _IID_SPELLINGS[1:], ids=["default", "iid"])
def test_iid_spellings_bit_identical_to_legacy(dropout, spelling):
    base_cfg = ChannelConfig(bandwidth_hz=2e5, mean_snr_db=2.0, dropout_prob=dropout)
    legacy = ChannelSimulator(5, base_cfg, seed=0)
    scen_cfg = ChannelConfig(
        bandwidth_hz=2e5, mean_snr_db=2.0, dropout_prob=dropout,
        scenario=get_scenario(spelling),
    )
    sim = ChannelSimulator(5, scen_cfg, seed=0)
    ids = list(range(5))
    for r in range(6):
        a = [s.snr_db for s in legacy.states(r, ids)]
        b = [s.snr_db for s in sim.states(r, ids)]
        assert a == b  # exact, including -inf outage positions


def test_rho_zero_hypothesis_sweep():
    """rho=0 must be bit-identical to the legacy i.i.d. draws for ANY
    (seed, dropout, round, cohort) — the property, swept by hypothesis."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        dropout=st.sampled_from([0.0, 0.2, 0.7]),
        rnd=st.integers(0, 12),
        cohort=st.lists(st.integers(0, 5), min_size=1, max_size=6, unique=True),
    )
    def prop(seed, dropout, rnd, cohort):
        cfg = dict(bandwidth_hz=2e5, mean_snr_db=2.0, dropout_prob=dropout)
        legacy = ChannelSimulator(6, ChannelConfig(**cfg), seed=seed)
        sim = ChannelSimulator(
            6, ChannelConfig(**cfg, scenario=ScenarioConfig()), seed=seed
        )
        assert [s.snr_db for s in legacy.states(rnd, cohort)] == [
            s.snr_db for s in sim.states(rnd, cohort)
        ]

    prop()


def test_query_order_and_cohort_permutation_invariance():
    """Stateful channels keep PR-4's guarantee: the realisation is a pure
    function of (seed, round, cid) — query order, cohort composition and
    cohort ordering don't change it."""
    cfg = ChannelConfig(
        bandwidth_hz=2e5, mean_snr_db=2.0, dropout_prob=0.2,
        scenario=get_scenario("gauss_markov"),
    )
    in_order = ChannelSimulator(6, cfg, seed=4)
    want = {r: [s.snr_db for s in in_order.states(r, range(6))] for r in range(5)}

    shuffled = ChannelSimulator(6, cfg, seed=4)
    # later round first, then a permuted subset of an earlier round
    got4 = [s.snr_db for s in shuffled.states(4, [5, 0, 3])]
    assert got4 == [want[4][5], want[4][0], want[4][3]]
    got1 = [s.snr_db for s in shuffled.states(1, [2, 1])]
    assert got1 == [want[1][2], want[1][1]]
    # re-query is stable
    assert [s.snr_db for s in shuffled.states(4, range(6))] == want[4]


def test_step_channel_carry_contract():
    cfg = ChannelConfig(scenario=get_scenario("gauss_markov"))
    sim = ChannelSimulator(4, cfg, seed=0)
    carry = sim.init_channel_carry()
    assert carry.round_index == -1
    carry, snr, bad = sim.step_channel(carry, 0)
    assert carry.round_index == 0 and snr.shape == (4,) and bad.shape == (4,)
    with pytest.raises(ValueError, match="contiguous"):
        sim.step_channel(carry, 5)


def test_scan_channel_inputs_operands():
    sim = ChannelSimulator(
        3, ChannelConfig(scenario=get_scenario("iid")), seed=0
    )
    ops = sim.scan_channel_inputs(4)
    assert ops["w"].shape == (4, 3) and ops["u"].shape == (4, 3)
    assert ops["base_snr_db"].shape == (4, 3)
    assert ops["z0"].shape == (3,) and ops["bad0"].shape == (3,)
    # the iid preset is served by the SAME executable via rho=0 data
    assert float(ops["rho"]) == 0.0
    assert float(ops["fade_scale"]) == 1.0
    gm = ChannelSimulator(
        3, ChannelConfig(scenario=get_scenario("gauss_markov")), seed=0
    )
    assert float(gm.scan_channel_inputs(4)["rho"]) == pytest.approx(0.9)


# ---------------------------------------------------------------------------
# hygiene: query validation
# ---------------------------------------------------------------------------


def test_states_rejects_negative_round_and_duplicates():
    sim = ChannelSimulator(4, ChannelConfig(), seed=0)
    with pytest.raises(ValueError, match="round_index"):
        sim.states(-1, [0, 1])
    with pytest.raises(ValueError, match="duplicate"):
        sim.states(0, [1, 1])
    with pytest.raises(ValueError, match="round_index"):
        sim.topk_for(-3, [0], vocab_size=256, num_samples=16)
    with pytest.raises(ValueError, match="duplicate"):
        sim.topk_for(0, [2, 0, 2], vocab_size=256, num_samples=16)
    # valid queries still work
    assert len(sim.states(0, [0, 1])) == 2


# ---------------------------------------------------------------------------
# golden trajectory: host loop vs one-dispatch scan, committed record
# ---------------------------------------------------------------------------


def _golden_run(preset: str):
    """Host-loop and scan runs of the tiny golden scenario, plus the host
    simulator the scan's in-scan tap must replay."""
    import dataclasses

    import jax.numpy as jnp

    from test_engine import _e2e_engine, _shared_cohort

    ds, c_host = _shared_cohort(4)
    _, c_scan = _shared_cohort(4)
    cfg = dataclasses.replace(_GOLDEN_CHAN, scenario=get_scenario(preset))
    sim = ChannelSimulator(4, cfg, seed=0)
    sels = _GOLDEN_SELS
    pubs = [jnp.asarray(ds.tokens[16 * r:16 * (r + 1)]) for r in range(3)]
    states = [sim.states_batched(r, sels[r]) for r in range(3)]

    host = _e2e_engine(c_host, ds, k_min=0)
    bcast, host_ks, host_bytes = None, [], []
    for r in range(3):
        ph = host.run_round(
            sels[r], pubs[r], bcast, states[r], adaptive_k=True, send_h=True
        )
        bcast = host.broadcast_state(pubs[r])
        host_ks.append(ph.ks)
        host_bytes.append([p.bytes for p in ph.payloads])

    scan = _e2e_engine(c_scan, ds, k_min=0)
    traj = scan.run_rounds(
        sels, pubs, states, adaptive_k=True, send_h=True,
        channel_scan=sim.scan_channel_inputs(3),
    )
    return sim, sels, host_ks, host_bytes, traj


def _golden_record(preset: str) -> dict:
    sim, sels, host_ks, host_bytes, traj = _golden_run(preset)
    assert traj.ks == host_ks
    assert [[p.bytes for p in pl] for pl in traj.payloads] == host_bytes
    return {
        "ks": host_ks,
        "payload_bytes": host_bytes,
        "outage": [[bool(o) for o in row] for row in traj.outage],
        "snr_db": [
            [round(s, 3) if math.isfinite(s) else None for s in row]
            for row in traj.snr_db
        ],
    }


@pytest.mark.parametrize("preset", _GOLDEN_PRESETS)
def test_golden_trajectory_host_vs_scan(preset):
    with open(_GOLDEN_PATH) as f:
        golden = json.load(f)[preset]
    sim, sels, host_ks, host_bytes, traj = _golden_run(preset)

    # host loop == scan == the committed record, bit-for-bit on k and bytes
    assert traj.ks == host_ks == golden["ks"]
    assert [[p.bytes for p in pl] for pl in traj.payloads] \
        == host_bytes == golden["payload_bytes"]

    # the in-scan channel tap replays the host simulator's realisation
    for r in range(3):
        host_states = sim.states(r, sels[r])
        for i, st in enumerate(host_states):
            assert bool(traj.outage[r][i]) == (st.snr_db == -math.inf)
            assert bool(traj.outage[r][i]) == golden["outage"][r][i]
            g = golden["snr_db"][r][i]
            if g is None:
                assert not math.isfinite(traj.snr_db[r][i])
            else:
                assert traj.snr_db[r][i] == pytest.approx(g, abs=5e-3)
                assert st.snr_db == pytest.approx(g, abs=5e-3)


def test_golden_record_is_current():
    """The committed record covers exactly the golden presets (catches a
    stale file after an intentional regeneration)."""
    with open(_GOLDEN_PATH) as f:
        golden = json.load(f)
    assert set(golden) == set(_GOLDEN_PRESETS)
    for preset in _GOLDEN_PRESETS:
        rec = golden[preset]
        assert set(rec) == {"ks", "payload_bytes", "outage", "snr_db"}
        assert len(rec["ks"]) == len(_GOLDEN_SELS)


if __name__ == "__main__":
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.dirname(__file__))
    if "--regen" in sys.argv:
        os.makedirs(os.path.dirname(_GOLDEN_PATH), exist_ok=True)
        record = {p: _golden_record(p) for p in _GOLDEN_PRESETS}
        with open(_GOLDEN_PATH, "w") as f:
            json.dump(record, f, indent=1)
        print(f"wrote {_GOLDEN_PATH}")
    else:
        print("usage: PYTHONPATH=src python tests/test_scenario.py --regen")
