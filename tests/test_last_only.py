"""Parity of the ``last_only`` LM-head mode against the full forward.

The federated phases (public inference, fine-tune/distill losses, eval)
read ONLY the last-position logits, so ``forward(..., last_only=True)``
computes the head on the final hidden state — ~seq_len× fewer head FLOPs.
These tests pin the contract across every model family in the zoo smoke
set (dense transformer, MoE, SSM, hybrid), with and without LoRA, and for
the Aux outputs (``moe_aux`` and the pooled projection ``lora_h`` must be
identical to the full forward: eq. 8 pools over the whole sequence).
"""

import jax
import numpy as np
import pytest

from repro.configs import LoRAConfig, get_smoke_config
from repro.configs.gpt2_paper import REDUCED_CLIENT
from repro.fed.steps import public_logits
from repro.models import forward, init, prefill

LORA = LoRAConfig(rank=4, alpha=32.0, dropout=0.0, targets=("q", "v", "head"))

# one representative per family: dense transformer, MoE, SSM, hybrid
FAMILY_ARCHS = [
    "stablelm-1.6b",
    "granite-moe-1b-a400m",
    "mamba2-130m",
    "jamba-1.5-large-398b",
]


def _cfg(arch, lora):
    return get_smoke_config(arch).with_overrides(lora=lora)


def _batch(cfg, b=2, s=16, seed=0):
    tokens = jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab_size)
    return {"tokens": tokens}


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
@pytest.mark.parametrize("lora", [None, LORA], ids=["plain", "lora"])
def test_last_only_matches_full_forward(arch, lora):
    cfg = _cfg(arch, lora)
    params = init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    full, aux_full = forward(params, cfg, batch)
    last, aux_last = forward(params, cfg, batch, last_only=True)
    assert last.shape == (2, cfg.vocab_size)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full[:, -1, :]), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        float(aux_last.moe_aux), float(aux_full.moe_aux), rtol=1e-6
    )
    if lora is None:
        assert aux_full.lora_h is None and aux_last.lora_h is None
    else:
        # the pooled LoRA projection (paper eq. 8) pools over the WHOLE
        # sequence — last_only must not change it (for SSM it comes from the
        # head adapter over the full normalized hidden states)
        assert aux_last.lora_h is not None
        np.testing.assert_allclose(
            np.asarray(aux_last.lora_h), np.asarray(aux_full.lora_h),
            rtol=1e-5, atol=1e-6,
        )


def test_last_only_matches_on_reduced_client_lora():
    """The actual federated client config (GPT-2 family + LoRA head)."""
    cfg = REDUCED_CLIENT.with_overrides(
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
        vocab_size=256, max_seq_len=32, lora=LORA,
    )
    params = init(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg, s=12, seed=3)
    full, aux_full = forward(params, cfg, batch)
    last, aux_last = forward(params, cfg, batch, last_only=True)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full[:, -1, :]), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(aux_last.lora_h), np.asarray(aux_full.lora_h), rtol=1e-5, atol=1e-6
    )


def test_public_logits_modes_agree():
    """public_logits(last_only=True) — the upload content — equals the seed
    path that materialised (B, T, V) and sliced."""
    cfg = REDUCED_CLIENT.with_overrides(
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
        vocab_size=256, max_seq_len=32, lora=LORA,
    )
    params = init(jax.random.PRNGKey(2), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(4), (4, 12), 0, cfg.vocab_size)
    fast_logits, fast_h = public_logits(params, cfg, tokens, last_only=True)
    slow_logits, slow_h = public_logits(params, cfg, tokens, last_only=False)
    np.testing.assert_allclose(
        np.asarray(fast_logits), np.asarray(slow_logits), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(fast_h), np.asarray(slow_h), rtol=1e-5, atol=1e-6
    )


def test_prefill_is_last_only_forward():
    cfg = _cfg("stablelm-1.6b", None)
    params = init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, s=8)
    p_logits, _ = prefill(params, cfg, batch)
    f_logits, _ = forward(params, cfg, batch, last_only=True)
    np.testing.assert_allclose(np.asarray(p_logits), np.asarray(f_logits), atol=0)
    assert p_logits.shape == (2, cfg.vocab_size)
