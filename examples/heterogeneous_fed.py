"""Heterogeneous federated distillation — the paper's core motivation.

Parameter-sharing FL REQUIRES homogeneous architectures; federated
distillation exchanges only (logits, LoRA projections) on a public set, so
clients can run completely different model families.  Here three clients —
a GPT-2-family dense model, a Mamba2 (attention-free SSM!) and a
granite-style MoE — jointly teach one server through the AdaLD pipeline.
The only shared contract is the tokenizer/vocab and the LoRA rank of the
projection exchange.

This example keeps the raw per-client pipeline visible; the fast engines
serve the same scenario family-bucketed (one compiled executable per
family — see README "Heterogeneous fleets", `run_federated` with a list of
family configs, or `python -m repro.launch.fed_train --families ...`).

Run:  PYTHONPATH=src python examples/heterogeneous_fed.py [rounds]
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.configs.base import LoRAConfig  # noqa: E402
from repro.configs.gpt2_paper import REDUCED_SERVER  # noqa: E402
from repro.core import ChannelConfig, ChannelSimulator  # noqa: E402
from repro.data import dirichlet_partition, make_fed_benchmark_dataset, split_public_private  # noqa: E402
from repro.fed.client import Client  # noqa: E402
from repro.fed.pretrain import pretrain_classifier, pretrain_lm  # noqa: E402
from repro.fed.server import Server  # noqa: E402
from repro.fed.steps import make_eval_fn  # noqa: E402

rounds = int(sys.argv[1]) if len(sys.argv) > 1 else 5
VOCAB = 1024
LORA = LoRAConfig(rank=16, targets=("q", "v", "o", "head"))

# --- three DIFFERENT client architectures, one shared vocab ---
dense = get_smoke_config("stablelm-1.6b").with_overrides(
    name="hetero-dense", vocab_size=VOCAB, lora=LORA, max_seq_len=128)
ssm = get_smoke_config("mamba2-130m").with_overrides(
    name="hetero-ssm", vocab_size=VOCAB, lora=LORA, max_seq_len=128)
moe = get_smoke_config("granite-moe-1b-a400m").with_overrides(
    name="hetero-moe", vocab_size=VOCAB, lora=LORA, max_seq_len=128)
client_cfgs = [dense, ssm, moe]
server_cfg = REDUCED_SERVER

ds = make_fed_benchmark_dataset(VOCAB, seed=0, total=1800)
public, private = split_public_private(ds, 256, seed=0)
parts = dirichlet_partition(private.labels, 3, gamma=0.5, seed=0)

# pretrain split (disjoint): supervised for clients, LM-only for the server
pre = private.subset(np.arange(300))
clients = []
for i, cfg in enumerate(client_cfgs):
    init_p = pretrain_classifier(cfg, pre, num_classes=77, steps=60, seed=i)
    clients.append(
        Client(i, cfg, private.subset(parts[i] + 300), num_classes=77, seed=i,
               local_steps=6, distill_steps=1, lr=2e-3,
               initial_params=init_p)
    )
server = Server(server_cfg, aggregation="adaptive", distill_steps=15,
                distill_lr=3e-3, initial_params=pretrain_lm(server_cfg, pre, steps=40))
chan = ChannelSimulator(3, ChannelConfig(), seed=0)
evaluate = make_eval_fn(server_cfg, 77)
eval_tok = jnp.asarray(private.tokens[-256:])
eval_lab = jnp.asarray(private.labels[-256:])

print(f"{'round':>6} {'server acc':>11} " + " ".join(f"{c.name[:12]:>13}" for c in client_cfgs))
g_logits = g_h = None
pub = jnp.asarray(public.tokens[:96])
for rnd in range(rounds):
    ups = []
    accs = []
    for c, st in zip(clients, chan.states(rnd, [0, 1, 2])):
        if g_logits is not None:
            c.local_distill(pub, g_logits, g_h)
        accs.append(c.local_train()["acc"])
        ups.append(c.upload(pub, st))
    k_g, h_g = server.aggregate_uploads(ups)
    server.distill(pub, k_g, h_g)
    g_logits, g_h, _ = server.broadcast(pub)
    s_acc = evaluate(server.params, eval_tok, eval_lab)
    print(f"{rnd:6d} {s_acc:11.3f} " + " ".join(f"{a:13.3f}" for a in accs)
          + f"   (k={[u.k for u in ups]})")

print("\nThree architecture families (dense attention / SSM / MoE) distilled"
      "\ninto one server — impossible for parameter-averaging FL.")
