"""Architecture registry: ``--arch <id>`` resolution for every assigned
architecture (+ the paper's own GPT-2 pair).

``get_config(arch_id)`` / ``get_smoke_config(arch_id)`` accept the public
dashed ids; ``ARCHITECTURES`` lists them in the assignment's order.
"""

from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, LoRAConfig, ModelConfig, MoEConfig, ShapeConfig, SSMConfig

__all__ = [
    "ARCHITECTURES",
    "INPUT_SHAPES",
    "LoRAConfig",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "ShapeConfig",
    "get_config",
    "get_smoke_config",
]

# arch id -> module name
ARCHITECTURES: dict[str, str] = {
    "mamba2-130m": "mamba2_130m",
    "stablelm-1.6b": "stablelm_1_6b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "internvl2-76b": "internvl2_76b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "yi-9b": "yi_9b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "command-r-35b": "command_r_35b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    # the paper's own models
    "gpt2-paper": "gpt2_paper",
}


def _module(arch_id: str):
    if arch_id not in ARCHITECTURES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHITECTURES)}")
    return importlib.import_module(f"repro.configs.{ARCHITECTURES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).SMOKE_CONFIG
