"""Federation -> serving handoff: ``export_adapters`` resolves what a
``fed_train --ckpt-dir`` run left on disk (or a live ``FleetStore``) into
an :class:`repro.serve.cache.AdapterSource` the AdapterCache pages from.

No new on-disk format: the sources read exactly what PR 9's checkpoint
writers produce —

* ``step_N.fleet/`` shard directories (``fleet_{lo:08d}_{hi:08d}.npz`` +
  ``fleet_frozen.npz``), the host-store layout: rows are paged per shard
  with a tiny LRU of open shards, so serving a 100k-tenant fleet never
  materializes the fleet in memory;
* monolithic ``step_N.npz`` checkpoints (device-store layout): the
  ``fleet__lora`` stacked subtree, loaded once into host numpy;
* a live :class:`repro.fed.store.FleetStore` (either kind), read through
  its ``lora_rows`` serving contract.

Each source also exposes ``frozen_tree()`` — the fleet's shared backbone
(split_lora frozen structure) — so a serving process can reconstruct full
params without re-running the federation:

    src = export_adapters(ckpt_dir)
    params = merge_lora(split_lora(model_init(key, cfg))[0], src.frozen_tree())
    cache = AdapterCache(src, like=lora_template(params), slots=8)
"""

from __future__ import annotations

import os
import re
from collections import OrderedDict
from typing import Any

import numpy as np

from repro.checkpoint import ckpt as ckpt_io
from repro.fed.store import FleetStore

__all__ = [
    "export_adapters",
    "serving_params",
    "FleetStoreSource",
    "ShardDirSource",
    "MonolithicSource",
]

_NOT_SHARED = (
    "this fleet checkpoints a PER-CLIENT backbone (no shared frozen tree); "
    "multi-tenant serving stacks adapters against ONE shared backbone — "
    "export a shared-backbone federation instead"
)


class FleetStoreSource:
    """Adapters straight out of a live fleet store (no disk round-trip)."""

    def __init__(self, store: FleetStore):
        self.store = store
        self.num_adapters = store.num_clients

    def lora_row(self, cid: int) -> Any:
        import jax

        return jax.tree.map(lambda x: x[0], self.store.lora_rows([int(cid)]))

    def frozen_tree(self) -> Any:
        if not self.store.shared:
            raise ValueError(_NOT_SHARED)
        return self.store.frozen


class ShardDirSource:
    """Adapters from a ``step_N.fleet/`` shard directory (host-store
    checkpoints).  Rows are read per shard on demand; at most
    ``max_open`` unflattened shard trees stay resident (LRU), so host
    memory is O(shard), not O(fleet)."""

    def __init__(self, dir_path: str, *, prefix: str = "fleet", max_open: int = 2):
        self.dir = dir_path
        self.prefix = prefix
        self._shards = ckpt_io.list_fleet_shards(dir_path, prefix)
        if not self._shards:
            raise FileNotFoundError(
                f"no {prefix!r} shards in {dir_path} — not a fleet shard dir"
            )
        self.num_adapters = max(hi for _, hi, _ in self._shards)
        self._open: OrderedDict[str, Any] = OrderedDict()
        self._max_open = max_open

    def _shard_lora(self, path: str) -> Any:
        tree = self._open.get(path)
        if tree is None:
            tree = ckpt_io.restore_subtree(path, "lora")
            while len(self._open) >= self._max_open:
                self._open.popitem(last=False)
            self._open[path] = tree
        else:
            self._open.move_to_end(path)
        return tree

    def lora_row(self, cid: int) -> Any:
        import jax

        cid = int(cid)
        for lo, hi, path in self._shards:
            if lo <= cid < hi:
                tree = self._shard_lora(path)
                return jax.tree.map(lambda a: a[cid - lo], tree)
        raise IndexError(
            f"tenant {cid} outside the shard ranges of {self.dir} "
            f"(fleet of {self.num_adapters})"
        )

    def frozen_tree(self) -> Any:
        frozen_path = os.path.join(self.dir, f"{self.prefix}_frozen.npz")
        if not os.path.exists(frozen_path):
            raise ValueError(_NOT_SHARED)
        return ckpt_io.restore_subtree(frozen_path, "frozen")


class MonolithicSource:
    """Adapters from a monolithic ``step_N.npz`` (device-store layout):
    the ``fleet__lora`` stacked subtree, loaded once into host numpy."""

    def __init__(self, path: str):
        self.path = path
        self._lora = ckpt_io.restore_subtree(path, "fleet__lora")
        import jax

        sizes = {int(x.shape[0]) for x in jax.tree_util.tree_leaves(self._lora)}
        if len(sizes) != 1:
            raise ValueError(
                f"{path}: fleet__lora leaves disagree on the client axis: {sizes}"
            )
        self.num_adapters = sizes.pop()

    def lora_row(self, cid: int) -> Any:
        import jax

        return jax.tree.map(lambda a: a[int(cid)], self._lora)

    def frozen_tree(self) -> Any:
        frozen = ckpt_io.restore_subtree(self.path, "fleet__frozen")
        import jax

        n_lora = self.num_adapters
        per_client = all(
            x.ndim >= 1 and int(x.shape[0]) == n_lora
            for x in jax.tree_util.tree_leaves(frozen)
        )
        # a shared backbone stores ONE tree; per-client backbones stack N —
        # ambiguous only if every frozen leaf coincidentally has leading
        # dim == num_clients, which real param trees (norm vectors, embed
        # tables) never do
        if per_client and n_lora > 1:
            raise ValueError(_NOT_SHARED)
        return frozen


def serving_params(source, like: Any) -> Any:
    """Full serving params: the source's shared backbone grafted into the
    structure of ``like`` (a freshly-initialized params tree of the same
    model config).  LoRA leaves keep ``like``'s values — they are either
    overridden per request by the AdapterCache slab, or serve as the
    detached-mode fallback adapter.  The npz-backed sources drop the
    None-valued LoRA positions from the frozen tree on disk, so a plain
    ``merge_lora`` cannot reassemble params from them; grafting by path
    can."""
    import jax
    import jax.numpy as jnp

    from repro.lora import is_lora_path, path_strings
    from repro.serve.adapters import _dig

    frozen = source.frozen_tree()

    def pick(path, leaf):
        if is_lora_path(path):
            return leaf
        parts = path_strings(path)
        val = _dig(frozen, parts)
        if val is None:
            raise KeyError(
                f"exported backbone is missing leaf {'/'.join(parts)!r} — "
                "the checkpoint does not match the model config"
            )
        if tuple(val.shape) != tuple(leaf.shape):
            raise ValueError(
                f"backbone leaf {'/'.join(parts)!r} has shape "
                f"{tuple(val.shape)}, model expects {tuple(leaf.shape)}"
            )
        return jnp.asarray(val, dtype=leaf.dtype)

    return jax.tree_util.tree_map_with_path(pick, like)


def export_adapters(src) -> Any:
    """Resolve ``src`` into an AdapterSource:

    * a live :class:`FleetStore`;
    * a ``step_N.fleet/`` shard directory;
    * a checkpoint directory (``fed_train --ckpt-dir``): newest valid step,
      preferring its shard dir over the monolithic fleet subtree.
    """
    if isinstance(src, FleetStore):
        return FleetStoreSource(src)
    if not isinstance(src, (str, os.PathLike)):
        raise TypeError(
            f"export_adapters wants a FleetStore or a path, got {type(src)!r}"
        )
    path = os.fspath(src)
    if os.path.isdir(path):
        # a shard dir itself?
        try:
            return ShardDirSource(path)
        except FileNotFoundError:
            pass
        # a checkpoint dir: newest step, shards preferred
        step = ckpt_io.latest_step(path)
        if step is not None:
            shard_dir = ckpt_io.fleet_shard_dir(path, step)
            if os.path.isdir(shard_dir):
                return ShardDirSource(shard_dir)
            return MonolithicSource(os.path.join(path, f"step_{step:08d}.npz"))
        raise FileNotFoundError(
            f"{path}: neither fleet shards nor step_N.npz checkpoints found"
        )
    if os.path.isfile(path) and re.search(r"\.npz$", path):
        return MonolithicSource(path)
    raise FileNotFoundError(f"export_adapters: no such checkpoint: {path}")
