"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` implements the mathematical spec with no tiling/streaming;
tests sweep shapes/dtypes and ``assert_allclose`` kernel-vs-ref in
``interpret=True`` mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "topk_mask_ref",
    "topk_mask_dynamic_ref",
    "distill_kl_ref",
    "sparse_agg_ref",
    "scatter_wire_sums_ref",
    "scatter_wire_sums_dequant_ref",
    "flash_attention_ref",
]


def topk_mask_ref(logits: jax.Array, k: int) -> jax.Array:
    """Keep every entry >= the k-th largest per row, zero the rest.

    Threshold semantics (ties included) — matches the bisection kernel.  For
    distinct values this is exactly 'keep the top-k'.
    """
    kth = jax.lax.top_k(logits, k)[0][..., -1:]
    return jnp.where(logits >= kth, logits, jnp.zeros_like(logits))


def topk_mask_dynamic_ref(logits: jax.Array, ks: jax.Array) -> jax.Array:
    """Per-row-budget threshold top-k of (rows, vocab); ``ks`` (rows,) int32.

    Same threshold (ties-kept) semantics as :func:`topk_mask_ref`; a zero
    budget zeroes the whole row.
    """
    vocab = logits.shape[-1]
    ks = jnp.clip(ks.astype(jnp.int32), 0, vocab)
    order = jnp.sort(logits, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(order, jnp.clip(ks - 1, 0, vocab - 1)[:, None], axis=-1)
    out = jnp.where(logits >= kth, logits, jnp.zeros_like(logits))
    return jnp.where((ks > 0)[:, None], out, jnp.zeros_like(out))


def distill_kl_ref(
    teacher_logits: jax.Array, student_logits: jax.Array, temperature: float = 2.0
) -> jax.Array:
    """Per-row KL(softmax(t/T) || softmax(s/T)), shape (rows,), fp32.

    No T^2 scaling, no batch mean — callers (repro.core.distill) apply those.
    """
    t = teacher_logits.astype(jnp.float32) / temperature
    s = student_logits.astype(jnp.float32) / temperature
    log_p = t - jax.scipy.special.logsumexp(t, axis=-1, keepdims=True)
    log_q = s - jax.scipy.special.logsumexp(s, axis=-1, keepdims=True)
    return jnp.sum(jnp.exp(log_p) * (log_p - log_q), axis=-1)


def sparse_agg_ref(stack: jax.Array, *, eps: float = 1e-12) -> jax.Array:
    """Paper eqs. 6-7 on a (N, rows, V) stack -> (rows, V), fp32."""
    x = stack.astype(jnp.float32)
    s = jnp.abs(x)
    den = jnp.sum(s, axis=0)
    num = jnp.sum(s * x, axis=0)
    return num / (den + eps)


def scatter_wire_sums_ref(
    a: jax.Array, b: jax.Array, indices: jax.Array, vocab: int
) -> tuple[jax.Array, jax.Array]:
    """Two-channel scatter-accumulate of sparse wire entries, fp32.

    ``a, b, indices: (N, rows, k)`` -> ``(num, den)`` each ``(rows, vocab)``:
    ``num[r, indices[n, r, j]] += a[n, r, j]`` (and b into den).  Indices are
    distinct per (n, r) row (a top-k support); masked-out entries must carry
    zero contributions.  This is the whole aggregation memory contract: only
    the (rows, vocab) OUTPUT is dense — never an (N, rows, vocab) stack.
    """
    n, rows, k = a.shape
    row_ix = jnp.broadcast_to(
        jnp.arange(rows, dtype=jnp.int32)[None, :, None], indices.shape
    )
    num = jnp.zeros((rows, vocab), jnp.float32).at[row_ix, indices].add(
        a.astype(jnp.float32)
    )
    den = jnp.zeros((rows, vocab), jnp.float32).at[row_ix, indices].add(
        b.astype(jnp.float32)
    )
    return num, den


def scatter_wire_sums_dequant_ref(
    q_values: jax.Array,
    scale: jax.Array,
    mask: jax.Array,
    indices: jax.Array,
    vocab: int,
    mode: str = "adaptive",
) -> tuple[jax.Array, jax.Array]:
    """Dequantize-fused wire scatter spec: reconstruct each entry's float
    value (``q * scale`` per row, 0 off the transmit mask), then build the
    mode's two contribution channels and scatter-accumulate as
    :func:`scatter_wire_sums_ref`.

    ``q_values (N, rows, k) int8``, ``scale (N, rows)``, ``mask`` bool or
    {0, 1}, ``indices (N, rows, k)`` -> ``(num, den)`` each ``(rows, vocab)``.
    """
    m = mask.astype(jnp.float32)
    v = q_values.astype(jnp.float32) * scale.astype(jnp.float32)[..., None] * m
    if mode == "adaptive":
        a, b = jnp.abs(v) * v, jnp.abs(v)
    elif mode in ("zeropad", "mean_nonzero"):
        a, b = v, m
    else:
        raise ValueError(f"unknown aggregation mode: {mode!r}")
    return scatter_wire_sums_ref(a, b, indices, vocab)


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True
) -> jax.Array:
    """Plain softmax attention, (B, S, D) per fused head-batch, fp32 math."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        s = q.shape[1]
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bst,btd->bsd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
