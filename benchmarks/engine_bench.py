"""Round-engine benchmark: batched vs sequential client-phase wall-clock.

The paper's Algorithm 1 selects 10 of 50 clients per round; the sequential
reference executes them one jitted call at a time (O(C*steps) dispatches
per round), the batched engine as single vmapped/donated steps (O(steps)).
This benchmark times ONE full client phase (cohort distillation + local
fine-tuning + public inference/top-k upload) at the paper's cohort size on
identical state.

Caveat for CPU readings: XLA's CPU backend lowers cohort-batched matmuls
as loops of per-client GEMMs, so on a small-core CPU box the batched
engine lands at ~0.6-1.0x sequential — the client axis only pays off where
it maps onto hardware batch/device parallelism (TPU/GPU), which is the
regime the engine exists for.  The ratio printed here is an honest
measurement of THIS machine, not the accelerator speedup.

Run:  PYTHONPATH=src python -m benchmarks.run --only engine
  or: PYTHONPATH=src python benchmarks/engine_bench.py
"""

from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def _build(num_clients: int, *, d_model: int, vocab: int, seq_len: int):
    from repro.configs.base import LoRAConfig
    from repro.configs.gpt2_paper import REDUCED_CLIENT
    from repro.data import make_banking77_like
    from repro.fed.client import Client
    from repro.fed.engine import BatchedEngine, BroadcastState, SequentialEngine

    lora = LoRAConfig(rank=8, alpha=32.0, dropout=0.0, targets=("q", "v", "head"))
    cfg = REDUCED_CLIENT.with_overrides(
        num_layers=2, d_model=d_model, num_heads=4, num_kv_heads=4,
        d_ff=2 * d_model, vocab_size=vocab, max_seq_len=max(seq_len, 32), lora=lora,
    )
    ds = make_banking77_like(vocab_size=vocab, seq_len=seq_len, total=60 * num_clients + 200, seed=0)

    # One shared pretrained-like backbone W' under per-client LoRA deltas —
    # the paper's setting, and what run_federated produces after pretraining.
    from repro.models import init as model_init

    backbone = model_init(jax.random.PRNGKey(123), cfg)

    def cohort():
        return [
            Client(i, cfg, ds.subset(np.arange(i * 60, (i + 1) * 60)),
                   num_classes=ds.num_classes, seed=i, local_steps=4, distill_steps=2,
                   initial_params=backbone)
            for i in range(num_clients)
        ]

    pub = jnp.asarray(ds.tokens[-64:])
    g_logits = jax.random.normal(jax.random.PRNGKey(0), (pub.shape[0], vocab))
    g_h = jax.random.normal(jax.random.PRNGKey(1), (pub.shape[0], lora.rank))
    bcast = BroadcastState(tokens=pub, logits=g_logits, h=g_h, bits=0)

    seq = SequentialEngine(cohort(), cfg)
    bat = BatchedEngine(cohort(), cfg, num_classes=ds.num_classes,
                        local_steps=4, distill_steps=2)
    return cfg, seq, bat, pub, bcast


def _time_round(engine, sel, pub, bcast, states, reps: int) -> float:
    # warm-up: compile every step shape this engine will touch
    engine.run_round(sel, pub, bcast, states, adaptive_k=True, send_h=True)
    t0 = time.time()
    for _ in range(reps):
        phase = engine.run_round(sel, pub, bcast, states, adaptive_k=True, send_h=True)
        if phase.dense is not None:
            jax.block_until_ready(phase.dense)
    return (time.time() - t0) / reps * 1e6  # us per client phase


def bench(quick: bool = True):
    """Rows: (name, us_per_round_client_phase, derived)."""
    from repro.core import ChannelConfig, ChannelSimulator

    num_clients = 10  # the paper's clients_per_round
    d_model, vocab, seq_len = (96, 512, 16) if quick else (128, 1024, 16)
    reps = 2 if quick else 3

    cfg, seq_eng, bat_eng, pub, bcast = _build(
        num_clients, d_model=d_model, vocab=vocab, seq_len=seq_len
    )
    sim = ChannelSimulator(num_clients, ChannelConfig(bandwidth_hz=5e5, mean_snr_db=5.0), seed=0)
    sel = list(range(num_clients))
    states = sim.states_batched(0, sel)

    us_seq = _time_round(seq_eng, sel, pub, bcast, states, reps)
    us_bat = _time_round(bat_eng, sel, pub, bcast, states, reps)
    speedup = us_seq / us_bat

    shape = f"C={num_clients};L2;d{d_model};V{vocab};steps=4+2"
    return [
        ("engine_sequential_round", us_seq, shape),
        ("engine_batched_round", us_bat, f"{shape};speedup={speedup:.2f}x"),
    ]


if __name__ == "__main__":
    rows = bench(quick="--quick" in sys.argv)
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    us = {n: v for n, v, _ in rows}
    print(f"speedup: {us['engine_sequential_round'] / us['engine_batched_round']:.2f}x "
          f"(client phase, clients_per_round=10)")
