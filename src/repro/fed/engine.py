"""Compatibility shim: the engines now live in :mod:`repro.fed.engines`.

The former 1,900-line monolith was split in PR 9 into
``repro.fed.engines/{base,batched,fused,e2e,hetero}.py`` (with the fleet
state itself refactored into :mod:`repro.fed.store`).  Every public — and
historically-reached-for private — name keeps importing from here, so
``from repro.fed.engine import FusedEngine`` and friends are unaffected.
"""

from repro.fed.engines import (  # noqa: F401
    BatchedEngine,
    BroadcastState,
    ClientPhase,
    FusedE2EEngine,
    FusedEngine,
    HeteroClientEngine,
    HeteroFusedE2EEngine,
    RoundsTrajectory,
    SequentialEngine,
    check_unique_cohort,
    cohort_budgets,
    k_cap_bucket,
    make_engine,
    tree_stack,
)
from repro.fed.engines.base import (  # noqa: F401
    _channel_scan_ops,
    _ServerOwnerMixin,
    fake_quant_dense,
    shared_frozen_backbone,
)

__all__ = [
    "BroadcastState",
    "ClientPhase",
    "RoundsTrajectory",
    "SequentialEngine",
    "BatchedEngine",
    "FusedEngine",
    "FusedE2EEngine",
    "HeteroClientEngine",
    "HeteroFusedE2EEngine",
    "make_engine",
    "tree_stack",
    "k_cap_bucket",
    "cohort_budgets",
    "check_unique_cohort",
]
