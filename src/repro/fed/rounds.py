"""Federated AdaLD round orchestration (paper Algorithm 1 + §IV setup).

One communication round (Fig. 1's 10 steps):
  1. server broadcasts global knowledge {K_g, h_g} (downlink accounted);
  2. selected clients distill locally against it (lines 5-7);
  3. clients fine-tune on private data (line 8);
  4. clients infer the public set, adaptively Top-k by live channel state
     (lines 9-10) and upload sparse logits + LoRA projections (line 11);
  5. server aggregates (line 15), distills into the LLM (line 16).

Four method presets reproduce the paper's comparison (§IV):
  adald      — adaptive Top-k + adaptive aggregation + LoRA-projection loss
  adaptive   — adaptive Top-k + adaptive aggregation, logits-only
  zeropad    — adaptive Top-k + zero-padding mean aggregation, logits-only
  all_logits — full logits (k = vocab), mean aggregation, logits-only
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.channel import ChannelConfig, ChannelSimulator
from repro.core.scenario import ScenarioConfig, get_scenario
from repro.core.protocol import CommLedger, RoundStats, downlink_bits
from repro.data.partition import dirichlet_partition, iid_partition, split_public_private
from repro.data.synthetic import IntentDataset
from repro.fed.client import Client
from repro.fed.engine import BroadcastState, make_engine
from repro.fed.server import Server
from repro.fed.steps import EVAL_BATCH, make_eval_fn

__all__ = ["FedConfig", "FedRun", "run_federated", "METHODS"]

Method = Literal["adald", "adaptive", "zeropad", "all_logits"]
Engine = Literal["sequential", "batched", "fused", "fused_e2e"]

METHODS: dict[str, dict] = {
    "adald": dict(aggregation="adaptive", send_h=True, adaptive_k=True),
    "adaptive": dict(aggregation="adaptive", send_h=False, adaptive_k=True),
    "zeropad": dict(aggregation="zeropad", send_h=False, adaptive_k=True),
    "all_logits": dict(aggregation="zeropad", send_h=False, adaptive_k=False),
}


@dataclasses.dataclass
class FedConfig:
    """Paper Table I defaults (reduced-scale knobs exposed)."""

    method: Method = "adald"
    # Round executor: "batched" stacks the selected cohort along a leading
    # client axis and runs each phase as one vmapped/jitted step; "fused"
    # additionally collapses the whole CLIENT phase into ONE jitted round
    # body (adaptive k as data); "fused_e2e" folds the SERVER phase in too
    # (sparse-wire aggregation + server distillation + broadcast — a whole
    # round is one compiled call); "sequential" is the bit-compatible
    # one-client-at-a-time reference.
    engine: Engine = "batched"
    # Compute the LM head (class/public/distill logits) on the LAST position
    # only — the task reads nothing else; cuts head FLOPs ~seq_len×.  False
    # restores the seed behaviour of materialising (B, T, V).
    last_only: bool = True
    # Fused engines: place the client axis over jax devices (shard_map).  For
    # "fused_e2e" the placement lives INSIDE the whole-round executable (the
    # server phase stays replicated); odd cohorts are padded with masked
    # k = 0 rows.
    shard_clients: bool = False
    # fused_e2e only: run ALL rounds as ONE compiled lax.scan dispatch
    # (FusedE2EEngine.run_rounds) with the per-round eval tapped inside the
    # scan — the R-round trajectory (accuracies, distill loss, mean_k) comes
    # back as scanned outputs instead of R host round-trips.
    scan_rounds: bool = False
    num_clients: int = 50
    clients_per_round: int = 10
    rounds: int = 20
    public_size: int = 2000
    non_iid: bool = True
    dirichlet_gamma: float = 0.5
    seed: int = 0
    temperature: float = 2.0
    lam: float = 0.03
    lr: float = 1e-3
    distill_lr: float = 3e-3
    local_steps: int = 4
    distill_steps: int = 2       # client-side distill updates per round
    server_distill_steps: int = 12  # server-side (the LLM learns only here)
    public_batch: int = 256  # samples of the public set used per round
    eval_size: int = 512
    use_kernels: bool = False
    restrict_to_support: bool = False
    # Quantize the sparse uplink wire to int8 values + one fp32 scale per
    # (client, sample) row: (value, index) entries are priced at 8 bits, so
    # the same Shannon budget affords a genuinely larger adaptive k at a
    # fixed SNR (the projection h stays at ``channel.value_bits``).  Served
    # by the batched/fused engines; "sequential" rejects it.
    quantize_wire: bool = False
    # Round-body compute dtype for the fused engines ("float32" |
    # "bfloat16"): forward/backward math runs in the given dtype while the
    # LoRA/optimizer master state stays fp32 (the cast lives inside the
    # differentiated loss, so grads accumulate back to fp32 before AdamW).
    compute_dtype: str = "float32"
    channel: ChannelConfig = dataclasses.field(default_factory=ChannelConfig)
    # Channel-dynamics scenario: a repro.core.scenario preset name
    # ("iid" | "gauss_markov" | "jakes" | "gilbert_elliott" | "mobility"),
    # a ScenarioConfig, or None (i.i.d., bit-identical to the pre-scenario
    # simulator).  When set it overrides ``channel.scenario``; with
    # scan_rounds the channel state additionally evolves INSIDE the
    # compiled multi-round scan (one executable for every scenario) and
    # the per-round realised SNR/outage come back in FedRun.
    scenario: "str | ScenarioConfig | None" = None
    # Backbone pretraining (simulates the paper's pretrained GPT-2 W'; the
    # pretrain split is disjoint from public/private/eval).  0 disables.
    # Clients: supervised (they fine-tune on labelled shards anyway);
    # server: LM-only by default — generic features, NO class knowledge, so
    # its accuracy trajectory isolates what distillation transfers (the
    # paper's Fig. 2 server curve).
    pretrain_steps: int = 80
    pretrain_frac: float = 0.12
    pretrain_lr: float = 2e-3
    server_pretrain: str = "lm"  # "lm" | "supervised" | "none"
    server_pretrain_steps: int = 60


@dataclasses.dataclass
class FedRun:
    ledger: CommLedger
    server_acc: list[float]
    client_acc: list[float]
    mean_k: list[float]
    # Per-round list of each selected client's adaptive k (0 = dropped
    # straggler that transmitted nothing).
    per_client_k: list[list[int]] = dataclasses.field(default_factory=list)
    # Per-round final server-distill step loss (NaN when the engine does not
    # expose it — only the fused_e2e engine computes it in-program).
    distill_loss: list[float] = dataclasses.field(default_factory=list)
    # Heterogeneous scan runs only: per-round accuracy per family bucket
    # (fleet bucket order) from the in-scan eval tap.
    family_client_acc: list[list[float]] | None = None
    # Scenario scan runs only: per-round cohort realised SNR (dB, -inf in
    # outage) and outage flags from the in-scan channel tap.
    snr_db: list[list[float]] | None = None
    outage: list[list[bool]] | None = None

    def summary(self) -> dict:
        return {
            **self.ledger.summary(),
            "best_server_acc": max(self.server_acc) if self.server_acc else float("nan"),
        }


def run_federated(
    client_cfg: ModelConfig | Sequence[ModelConfig],
    server_cfg: ModelConfig,
    dataset: IntentDataset,
    fed: FedConfig,
    *,
    verbose: bool = False,
) -> FedRun:
    """Run the whole federation.  ``client_cfg`` may be ONE config (the
    homogeneous fleet of the paper's §IV setup) or a sequence of FAMILY
    configs — clients then cycle through the families round-robin (client i
    runs ``client_cfg[i % F]``), and the engines serve the mixed fleet
    through the family-bucketed heterogeneous path (`repro.fed.cohort`).
    Families must share a vocabulary and LoRA rank (the paper's §II
    exchange contracts); with pretraining enabled, one backbone is
    pretrained PER family and shared by that family's clients."""
    preset = METHODS[fed.method]
    rng = np.random.default_rng(fed.seed)

    families = (
        [client_cfg] if isinstance(client_cfg, ModelConfig) else list(client_cfg)
    )
    if not families:
        raise ValueError("client_cfg must name at least one model config")
    cfgs = [families[i % len(families)] for i in range(fed.num_clients)]

    # carve a disjoint pretraining split first (simulated pretrained W')
    server_init = None
    client_inits: dict[ModelConfig, object] = {}
    if fed.pretrain_steps > 0:
        from repro.fed.pretrain import pretrain_classifier, pretrain_lm

        n_pre = int(len(dataset) * fed.pretrain_frac)
        pre_idx = np.random.default_rng(fed.seed + 31).permutation(len(dataset))
        pretrain_ds = dataset.subset(pre_idx[:n_pre])
        dataset = dataset.subset(pre_idx[n_pre:])
        # one pretrained backbone per family; family 0 keeps the historical
        # seed so a homogeneous run is bit-identical to the pre-hetero path
        for fi, fam in enumerate(families):
            client_inits[fam] = pretrain_classifier(
                fam, pretrain_ds, num_classes=dataset.num_classes,
                steps=fed.pretrain_steps, lr=fed.pretrain_lr,
                seed=fed.seed + 17 * fi,
                last_only=fed.last_only, verbose=verbose,
            )
        if fed.server_pretrain == "supervised":
            server_init = pretrain_classifier(
                server_cfg, pretrain_ds, num_classes=dataset.num_classes,
                steps=fed.server_pretrain_steps, lr=fed.pretrain_lr,
                seed=fed.seed + 999, last_only=fed.last_only, verbose=verbose,
            )
        elif fed.server_pretrain == "lm":
            server_init = pretrain_lm(
                server_cfg, pretrain_ds, steps=fed.server_pretrain_steps,
                lr=fed.pretrain_lr, seed=fed.seed + 999, verbose=verbose,
            )

    public, private = split_public_private(dataset, fed.public_size, seed=fed.seed)
    if fed.non_iid:
        parts = dirichlet_partition(
            private.labels, fed.num_clients, gamma=fed.dirichlet_gamma, seed=fed.seed
        )
    else:
        parts = iid_partition(len(private), fed.num_clients, seed=fed.seed)

    clients = [
        Client(
            i,
            cfgs[i],
            private.subset(parts[i]),
            num_classes=dataset.num_classes,
            seed=fed.seed + i,
            lr=fed.lr,
            distill_lr=fed.distill_lr,
            temperature=fed.temperature,
            lam=fed.lam,
            local_steps=fed.local_steps,
            distill_steps=fed.distill_steps,
            restrict_to_support=fed.restrict_to_support,
            last_only=fed.last_only,
            initial_params=client_inits.get(cfgs[i]),
        )
        for i in range(fed.num_clients)
    ]
    server = Server(
        server_cfg,
        seed=fed.seed + 999,
        distill_lr=fed.distill_lr,
        temperature=fed.temperature,
        lam=fed.lam,
        aggregation=preset["aggregation"],
        distill_steps=fed.server_distill_steps,
        use_kernels=fed.use_kernels,
        restrict_to_support=fed.restrict_to_support,
        last_only=fed.last_only,
        initial_params=server_init,
    )
    channel_cfg = fed.channel
    if fed.scenario is not None:
        channel_cfg = dataclasses.replace(
            channel_cfg, scenario=get_scenario(fed.scenario)
        )
    chan_sim = ChannelSimulator(fed.num_clients, channel_cfg, seed=fed.seed)

    # held-out eval split (from the private pool tail, disjoint from clients'
    # data only in expectation at reduced scale; standard FedD evaluation)
    eval_idx = rng.permutation(len(private))[: fed.eval_size]
    eval_tokens, eval_labels = private.tokens[eval_idx], private.labels[eval_idx]
    evaluate = make_eval_fn(server_cfg, dataset.num_classes, last_only=fed.last_only)
    # per-family client evaluators (make_eval_fn is lru-cached per config)
    evaluate_client = {
        fam: make_eval_fn(fam, dataset.num_classes, last_only=fed.last_only)
        for fam in families
    }

    engine = make_engine(
        fed.engine,
        clients,
        cfgs[0],
        num_classes=dataset.num_classes,
        lr=fed.lr,
        distill_lr=fed.distill_lr,
        temperature=fed.temperature,
        lam=fed.lam,
        local_steps=fed.local_steps,
        distill_steps=fed.distill_steps,
        restrict_to_support=fed.restrict_to_support,
        value_bits=fed.channel.value_bits,
        k_min=fed.channel.min_k,
        last_only=fed.last_only,
        shard_clients=fed.shard_clients,
        use_kernels=fed.use_kernels,
        quantize_wire=fed.quantize_wire,
        compute_dtype=fed.compute_dtype,
        # fused_e2e only: the engine owns the server phase too
        server=server,
        server_distill_steps=fed.server_distill_steps,
        aggregation=preset["aggregation"],
    )
    handles_server = getattr(engine, "handles_server", False)

    ledger = CommLedger()
    run = FedRun(ledger=ledger, server_acc=[], client_acc=[], mean_k=[])

    pub_rng = np.random.default_rng(fed.seed + 7)

    def draw_round(rnd: int):
        """One round's host-rng draws — cohort, public batch, channel
        realisation — in THE canonical order.  The per-round loop and the
        scan_rounds pre-draw both go through here, so the two paths can
        never desynchronize their rng streams."""
        sel = rng.choice(fed.num_clients, size=fed.clients_per_round, replace=False)
        pub_sel = pub_rng.integers(0, len(public), size=fed.public_batch)
        return (
            [int(i) for i in sel],
            jnp.asarray(public.tokens[pub_sel]),
            chan_sim.states_batched(rnd, list(sel)),
        )

    if fed.scan_rounds:
        if not handles_server:
            raise ValueError(
                "FedConfig.scan_rounds requires engine='fused_e2e' "
                f"(got {fed.engine!r})"
            )
        # Pre-draw every round in the same order the per-round loop uses,
        # then run the whole federation as one compiled multi-round dispatch
        # with the eval tap inside the scan.
        sels, pubs, states_list = [], [], []
        for rnd in range(fed.rounds):
            sel, pub_tokens, states = draw_round(rnd)
            sels.append(sel)
            pubs.append(pub_tokens)
            states_list.append(states)
        # the in-scan tap reads the same samples the host-side batched eval
        # walks (whole eval batches; the remainder is dropped there too)
        seen = (len(eval_tokens) // EVAL_BATCH) * EVAL_BATCH
        eval_kw = {}
        if seen:
            eval_kw = dict(
                eval_tokens=jnp.asarray(eval_tokens[:seen]),
                eval_labels=jnp.asarray(eval_labels[:seen]),
            )
        chan_kw = {}
        if chan_sim.scenario is not None:
            # scenario channel state evolves inside the same compiled scan;
            # budgets above were priced from the identical host chain
            chan_kw = dict(channel_scan=chan_sim.scan_channel_inputs(fed.rounds))
        traj = engine.run_rounds(
            sels, pubs, states_list,
            adaptive_k=preset["adaptive_k"], send_h=preset["send_h"],
            **eval_kw, **chan_kw,
        )
        engine.sync_server()
        run.family_client_acc = traj.family_client_acc
        run.snr_db = traj.snr_db
        run.outage = traj.outage
        b_rank = server_cfg.lora.rank if server_cfg.lora is not None else None
        b_bits = downlink_bits(fed.public_batch, server_cfg.vocab_size, b_rank)
        for rnd in range(fed.rounds):
            # an eval split smaller than one batch degenerates to 0.0 on the
            # host path (no whole batch to walk) — mirror it, not NaN
            s_acc = traj.server_acc[rnd] if traj.server_acc else 0.0
            c_acc = traj.client_acc[rnd] if traj.client_acc else 0.0
            downlink = b_bits * len(sels[rnd]) if rnd > 0 else 0
            uplink = float(sum(p.bytes for p in traj.payloads[rnd]))
            run.server_acc.append(s_acc)
            run.client_acc.append(c_acc)
            run.mean_k.append(traj.mean_k[rnd])
            run.per_client_k.append(list(traj.ks[rnd]))
            run.distill_loss.append(traj.distill_loss[rnd])
            ledger.record(
                RoundStats(
                    round_index=rnd,
                    uplink_bytes=uplink,
                    downlink_bytes=downlink / 8.0,
                    server_accuracy=s_acc,
                    client_accuracy=c_acc,
                    distill_loss=traj.distill_loss[rnd],
                    mean_k=traj.mean_k[rnd],
                    num_selected=len(sels[rnd]),
                    num_transmitters=len(traj.payloads[rnd]),
                )
            )
            if verbose:
                print(
                    f"[{fed.method}/{fed.engine}+scan] round {rnd:3d}  "
                    f"server_acc={s_acc:.3f} client_acc={c_acc:.3f}  "
                    f"mean_k={traj.mean_k[rnd]:7.1f}  uplink={uplink/1e6:.2f}MB  "
                    f"tx={len(traj.payloads[rnd])}/{len(sels[rnd])}"
                )
        return run

    # Broadcast knowledge carried across rounds: None until the server has
    # distilled once (cold server at round 0 -> no downlink that round).
    bcast: BroadcastState | None = None
    for rnd in range(fed.rounds):
        sel, pub_tokens, states = draw_round(rnd)

        # one broadcast of last round's knowledge per selected client
        downlink = bcast.bits * len(sel) if bcast is not None else 0

        phase = engine.run_round(
            sel, pub_tokens, bcast, states,
            adaptive_k=preset["adaptive_k"], send_h=preset["send_h"],
        )

        if handles_server:
            # fused_e2e: aggregation + server distillation + broadcast all
            # happened inside the engine's single compiled round call.
            bcast = engine.broadcast_state(pub_tokens)
            engine.sync_server()
        else:
            if phase.dense is not None:
                k_g, h_g = server.aggregate_dense(phase.dense, phase.h)
                server.distill(pub_tokens, k_g, h_g)
            # else: every selected client dropped this round -> no
            # aggregation, the server's knowledge simply carries over.
            g_logits, g_h, g_bits = server.broadcast(pub_tokens)
            bcast = BroadcastState(tokens=pub_tokens, logits=g_logits, h=g_h, bits=g_bits)

        s_acc = evaluate(server.params, jnp.asarray(eval_tokens), jnp.asarray(eval_labels))
        c_acc = evaluate_client[cfgs[sel[0]]](
            engine.client_params(sel[0]), jnp.asarray(eval_tokens), jnp.asarray(eval_labels)
        )
        uplink = phase.uplink_bytes
        d_loss = (
            engine.last_distill_loss if handles_server else float("nan")
        )
        run.server_acc.append(s_acc)
        run.client_acc.append(c_acc)
        run.mean_k.append(float(np.mean(phase.ks)))
        run.per_client_k.append(list(phase.ks))
        run.distill_loss.append(d_loss)
        ledger.record(
            RoundStats(
                round_index=rnd,
                uplink_bytes=uplink,
                downlink_bytes=downlink / 8.0,
                server_accuracy=s_acc,
                client_accuracy=c_acc,
                distill_loss=d_loss,
                mean_k=float(np.mean(phase.ks)),
                num_selected=len(sel),
                num_transmitters=phase.num_transmitters,
            )
        )
        if verbose:
            print(
                f"[{fed.method}/{fed.engine}] round {rnd:3d}  server_acc={s_acc:.3f} "
                f"client_acc={c_acc:.3f}  mean_k={np.mean(phase.ks):7.1f}  "
                f"uplink={uplink/1e6:.2f}MB  tx={phase.num_transmitters}/{len(sel)}"
            )
    return run
