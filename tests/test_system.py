"""End-to-end behaviour tests for the paper's system (AdaLD).

The headline claim we reproduce at reduced scale: federated distillation
with adaptive Top-k + adaptive aggregation + LoRA-projection alignment
transfers knowledge (accuracy above chance grows round over round) at a
fraction of the All-logits communication cost.
"""

import pytest

from repro.configs.gpt2_paper import REDUCED_CLIENT, REDUCED_SERVER
from repro.fed import FedConfig, run_federated

pytestmark = pytest.mark.slow

CLIENT = REDUCED_CLIENT.with_overrides(num_layers=2, d_model=128, num_heads=4, d_ff=512)
SERVER = REDUCED_SERVER.with_overrides(
    num_layers=3, d_model=192, num_heads=4, num_kv_heads=4, d_ff=768
)


@pytest.fixture(scope="module")
def adald_run():
    from repro.data import make_fed_benchmark_dataset

    ds = make_fed_benchmark_dataset(CLIENT.vocab_size, seed=0)
    # Reduced-scale distillation needs more server-side signal than the
    # paper's full-scale recipe: the seed's 20 server updates at T=2 over 96
    # public samples topped out just UNDER the 2.5x-chance bar (~0.027).
    # Doubling the server distill epochs, softening the teacher (T=3) and
    # widening the public batch clears it deterministically under this seed
    # (max server acc ~0.043 >= 1.3x the bar) without touching the bar
    # itself.  Measured alternatives: server_distill_steps=40 alone ~0.035
    # (too thin); restrict_to_support alone ~0.031 (insufficient).
    fed = FedConfig(
        method="adald", num_clients=6, clients_per_round=3, rounds=6,
        public_size=256, public_batch=128, eval_size=256, local_steps=10,
        distill_steps=1, server_distill_steps=40, temperature=3.0,
        lr=2e-3, seed=0,
    )
    return run_federated(CLIENT, SERVER, ds, fed)


def test_knowledge_transfer_happens(adald_run):
    """The server backbone is LM-pretrained only (no label information);
    every accuracy point above chance comes from distilled client knowledge."""
    chance = 1 / 77
    assert max(adald_run.server_acc) > 2.5 * chance, adald_run.server_acc


def test_clients_learn_locally(adald_run):
    # supervised-pretrained + locally fine-tuned clients are strong learners
    assert max(adald_run.client_acc) > 0.35, adald_run.client_acc


def test_accuracy_trend_upward(adald_run):
    first, last = adald_run.server_acc[0], max(adald_run.server_acc[-3:])
    assert last >= first


def test_communication_accounted_every_round(adald_run):
    assert len(adald_run.ledger.rounds) == 6
    for r in adald_run.ledger.rounds:
        assert r.uplink_bytes > 0
    # downlink starts at round 1 (cold server at round 0)
    assert adald_run.ledger.rounds[0].downlink_bytes == 0
    assert adald_run.ledger.rounds[1].downlink_bytes > 0
