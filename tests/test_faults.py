"""Fault injection, wire quarantine and HARQ retransmission (PR 8).

Fast tier: keyed fault-stream determinism and permutation invariance,
host/scan-operand resolution parity, the server-side wire validation gate,
HARQ pricing against the Shannon budget (closed-form), and — on the tiny
no-pretrain configs — the end-to-end contracts: the "none" preset is
bit-identical to faults=None on every engine path, fault realisations agree
engine-for-engine (same k, bytes, quarantine counts), and the corruption
preset actually engages.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LoRAConfig
from repro.configs.gpt2_paper import REDUCED_CLIENT, REDUCED_SERVER
from repro.core import ChannelConfig
from repro.core.faults import (
    FAULTS,
    FaultConfig,
    FaultSimulator,
    corrupt_wire,
    get_faults,
    quarantine_wire,
    validate_dense,
    validate_wire,
)
from repro.core.protocol import PayloadSpec
from repro.core.topk import sparsify_wire, wire_densify
from repro.data import make_banking77_like
from repro.fed import FedConfig, run_federated

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # dev-only dependency; property tests become no-ops
    HAVE_HYPOTHESIS = False

LORA = LoRAConfig(rank=4, alpha=32.0, dropout=0.0, targets=("q", "v", "head"))
CLIENT = REDUCED_CLIENT.with_overrides(
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
    vocab_size=256, max_seq_len=32, lora=LORA,
)
SERVER = REDUCED_SERVER.with_overrides(
    num_layers=2, d_model=96, num_heads=2, num_kv_heads=2, d_ff=192,
    vocab_size=256, max_seq_len=32, lora=LORA,
)
CHAN = ChannelConfig(bandwidth_hz=2e5, mean_snr_db=2.0)


def _dataset():
    return make_banking77_like(vocab_size=CLIENT.vocab_size, seq_len=12, total=500, seed=0)


def _cfg(engine, rounds=3, method="adald", **kw):
    kw.setdefault("pretrain_steps", 0)
    return FedConfig(
        method=method, engine=engine, num_clients=4, clients_per_round=2,
        rounds=rounds, public_size=64, public_batch=16, eval_size=64,
        local_steps=2, distill_steps=1, server_distill_steps=2,
        seed=0, channel=CHAN, **kw,
    )


# ---------------------------------------------------------------------------
# config / presets
# ---------------------------------------------------------------------------


def test_presets_resolve():
    assert get_faults(None) is None
    assert get_faults("corruption") is FAULTS["corruption"]
    cfg = FaultConfig(corrupt_prob=0.5)
    assert get_faults(cfg) is cfg
    with pytest.raises(ValueError):
        get_faults("no_such_preset")
    assert not FAULTS["none"].enabled
    assert all(FAULTS[n].enabled for n in ("corruption", "crashes", "bursty", "lossy"))


def test_config_validation():
    with pytest.raises(ValueError):
        FaultConfig(corrupt_prob=1.5)
    with pytest.raises(ValueError):
        FaultConfig(max_retries=-1)
    with pytest.raises(ValueError):
        FaultConfig(burst_enter=-0.1)


# ---------------------------------------------------------------------------
# keyed streams: determinism / cohort invariance / channel independence
# ---------------------------------------------------------------------------


def test_fault_streams_deterministic():
    """Two simulators with the same seed agree draw-for-draw."""
    cfg = FAULTS["lossy"]
    a = FaultSimulator(8, cfg, seed=3)
    b = FaultSimulator(8, cfg, seed=3)
    for rnd in range(4):
        ra = a.resolve_round(rnd, [0, 3, 5], [10, 10, 10], [100.0] * 3, [1e4] * 3)
        rb = b.resolve_round(rnd, [0, 3, 5], [10, 10, 10], [100.0] * 3, [1e4] * 3)
        assert ra == rb
    c = FaultSimulator(8, cfg, seed=4)
    diff = [
        c.resolve_round(r, list(range(8)), [10] * 8, [100.0] * 8, [1e4] * 8)
        != a.resolve_round(r, list(range(8)), [10] * 8, [100.0] * 8, [1e4] * 8)
        for r in range(8)
    ]
    assert any(diff), "a different seed must change some realisation"


def test_fault_verdict_cohort_invariant():
    """A client's verdict depends only on (seed, round, cid) and its own
    scalars: permuting the cohort or dropping other members cannot move it."""
    cfg = FAULTS["lossy"]
    sim = FaultSimulator(10, cfg, seed=0)
    full = sim.resolve_round(2, [1, 4, 7, 9], [10, 20, 30, 40],
                             [100.0, 200.0, 300.0, 400.0], [1e4] * 4)
    perm = FaultSimulator(10, cfg, seed=0).resolve_round(
        2, [9, 7, 4, 1], [40, 30, 20, 10],
        [400.0, 300.0, 200.0, 100.0], [1e4] * 4)
    assert full.delivered == perm.delivered[::-1]
    assert full.attempts == perm.attempts[::-1]
    assert full.reasons == perm.reasons[::-1]
    solo = FaultSimulator(10, cfg, seed=0).resolve_round(
        2, [4], [20], [200.0], [1e4])
    assert solo.delivered[0] == full.delivered[1]
    assert solo.attempts[0] == full.attempts[1]


def test_fault_rng_domains_disjoint_from_channel():
    """Enabling faults must never perturb the channel realisation: the fault
    simulator draws on stream domains 21-24, the channel on 7-10."""
    from repro.core.channel import ChannelSimulator

    chan = ChannelSimulator(4, CHAN, seed=0)
    baseline = [s.snr_db for s in chan.states_batched(0, [0, 1, 2, 3])]
    sim = FaultSimulator(4, FAULTS["lossy"], seed=0)
    sim.resolve_round(0, [0, 1, 2, 3], [10] * 4, [100.0] * 4, [1e4] * 4)
    chan2 = ChannelSimulator(4, CHAN, seed=0)
    after = [s.snr_db for s in chan2.states_batched(0, [0, 1, 2, 3])]
    assert baseline == after


def test_k_zero_is_not_a_fault():
    """A k = 0 straggler never transmitted: no attempts, no reason."""
    sim = FaultSimulator(4, FAULTS["lossy"], seed=0)
    res = sim.resolve_round(0, [0, 1], [0, 0], [100.0] * 2, [1e4] * 2)
    assert res.delivered == [False, False]
    assert res.attempts == [0, 0]
    assert res.reasons == [None, None]
    assert res.num_crashed == 0 and res.num_quarantined == 0


def test_scan_inputs_parity_with_host_resolution():
    """resolve_from_inputs over scan_fault_inputs operands is bit-identical
    to the per-round host path, including with a start_round offset."""
    cfg = FAULTS["lossy"]
    host = FaultSimulator(6, cfg, seed=5)
    scan = FaultSimulator(6, cfg, seed=5)
    inputs = scan.scan_fault_inputs(4, start_round=2)
    for j, rnd in enumerate(range(2, 6)):
        cohort = [0, 2, 5]
        ks = [7, 0, 31]
        pb = [70.0, 0.0, 310.0]
        bb = [500.0, 500.0, 500.0]
        a = host.resolve_round(rnd, cohort, ks, pb, bb)
        b = scan.resolve_from_inputs(inputs, j, cohort, ks, pb, bb)
        assert a == b


def test_step_faults_requires_contiguity():
    sim = FaultSimulator(4, FAULTS["bursty"], seed=0)
    carry = sim.init_fault_carry()
    with pytest.raises(ValueError, match="contiguous"):
        sim.step_faults(carry, 3)


def test_bursty_episodes_raise_corruption():
    """Inside a Gilbert-Elliott episode the corruption probability jumps to
    burst_corrupt_prob: across many rounds, burst rounds must corrupt more."""
    cfg = FaultConfig(name="t", corrupt_prob=0.02, max_retries=0,
                      burst_enter=0.3, burst_exit=0.3, burst_corrupt_prob=0.95)
    sim = FaultSimulator(16, cfg, seed=1)
    inputs = sim.scan_fault_inputs(40)
    in_burst, out_burst = [], []
    for r in range(40):
        res = sim.resolve_round(r, list(range(16)), [10] * 16,
                                [100.0] * 16, [1e4] * 16)
        for i in range(16):
            (in_burst if inputs["burst"][r][i] else out_burst).append(
                res.reasons[i] == "corrupt"
            )
    assert np.mean(in_burst) > 0.5 > np.mean(out_burst)


# ---------------------------------------------------------------------------
# HARQ pricing vs the Shannon budget
# ---------------------------------------------------------------------------


def _attempts_closed_form(corrupt_u, p, max_retries, payload_bits, budget_bits):
    """Reference HARQ walk: attempts keep re-spending the payload against
    the SAME budget; the first copy always fits."""
    affordable = max(1, int(np.floor(budget_bits / payload_bits)))
    allowed = min(1 + max_retries, affordable)
    for a in range(allowed):
        if not np.float32(corrupt_u[a]) < np.float32(p):
            return True, a + 1
    return False, allowed


def test_harq_budget_caps_retries():
    """With budget < 2 payloads the client gets exactly one attempt no
    matter how many retries the config allows."""
    cfg = FaultConfig(name="t", corrupt_prob=1.0, max_retries=5)
    sim = FaultSimulator(2, cfg, seed=0)
    res = sim.resolve_round(0, [0], [10], [100.0], [150.0])
    assert res.delivered == [False]
    assert res.attempts == [1]
    assert res.reasons == ["corrupt"]


def test_harq_attempts_match_closed_form():
    cfg = FaultConfig(name="t", corrupt_prob=0.6, max_retries=3)
    sim = FaultSimulator(8, cfg, seed=9)
    inputs = sim.scan_fault_inputs(6)
    for rnd in range(6):
        for budget in (100.0, 250.0, 1000.0):
            res = sim.resolve_round(
                rnd, list(range(8)), [10] * 8, [100.0] * 8, [budget] * 8
            )
            for i in range(8):
                d, a = _attempts_closed_form(
                    inputs["corrupt_u"][rnd][i], cfg.corrupt_prob,
                    cfg.max_retries, 100.0, budget,
                )
                assert (res.delivered[i], res.attempts[i]) == (d, a)


def test_harq_bytes_on_ledger():
    """attempts * spec.uplink_bytes is what lands on the wire ledger."""
    spec = PayloadSpec(num_samples=16, vocab=256, k=32, value_bits=16)
    from repro.core.protocol import UplinkPayload

    p = UplinkPayload(client_id=0, spec=spec, attempts=3)
    assert p.bytes == 3 * spec.uplink_bytes
    assert UplinkPayload(client_id=0, spec=spec).bytes == spec.uplink_bytes


if HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(
        p=st.floats(0.0, 1.0),
        max_retries=st.integers(0, 4),
        payload=st.floats(1.0, 1e4),
        budget=st.floats(0.0, 1e5),
        seed=st.integers(0, 1000),
    )
    def test_harq_property(p, max_retries, payload, budget, seed):
        """Property: attempts in [1, min(1+max_retries, affordable)] for any
        transmitter, and delivery implies the LAST attempt was clean."""
        cfg = FaultConfig(name="t", corrupt_prob=p, max_retries=max_retries)
        sim = FaultSimulator(1, cfg, seed=seed)
        res = sim.resolve_round(0, [0], [10], [payload], [budget])
        affordable = max(1, int(np.floor(budget / payload)))
        allowed = min(1 + max_retries, affordable)
        assert 1 <= res.attempts[0] <= allowed
        if not res.delivered[0]:
            assert res.attempts[0] == allowed
            assert res.reasons[0] == "corrupt"


# ---------------------------------------------------------------------------
# server-side wire validation / quarantine
# ---------------------------------------------------------------------------


def _wire(n=3, samples=4, vocab=64, k_cap=8, quantize=False):
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(n, samples, vocab)).astype(np.float32))
    ks = jnp.asarray([k_cap] * n, jnp.int32)
    return sparsify_wire(logits, ks, k_cap, quantize=quantize)


@pytest.mark.parametrize("quantize", [False, True])
def test_validate_wire_accepts_honest(quantize):
    ok, reasons = validate_wire(_wire(quantize=quantize))
    assert ok.all() and all(r is None for r in reasons)


@pytest.mark.parametrize("mode,reason", [
    ("nan", "non_finite"), ("index", "index_range"),
    ("negative_index", "index_range"),
])
def test_validate_wire_rejects(mode, reason):
    wire = corrupt_wire(_wire(), [1], mode=mode)
    ok, reasons = validate_wire(wire)
    assert list(ok) == [True, False, True]
    assert reasons[1] == reason


def test_validate_quantized_wire_nan_scale():
    wire = corrupt_wire(_wire(quantize=True), [0], mode="nan")
    ok, reasons = validate_wire(wire)
    assert list(ok) == [False, True, True]
    assert reasons[0] == "non_finite"


def test_validate_wire_over_budget():
    """A payload claiming more entries than its Shannon budget affords is a
    fits violation."""
    wire = _wire(k_cap=8, samples=4)
    from repro.core.channel import bits_per_entry

    d = bits_per_entry(16, 64)
    honest = 8 * 4 * d  # k_cap entries x samples
    ok, reasons = validate_wire(wire, budget_bits=[honest, honest, honest - 1.0])
    assert list(ok) == [True, True, False]
    assert reasons[2] == "over_budget"


def test_quarantine_wire_is_k0_exclusion():
    """Quarantine == all-False transmit mask == the existing k = 0 path:
    the densified stack of a quarantined row is exactly zero."""
    wire = corrupt_wire(_wire(), [1], mode="nan")
    ok, _ = validate_wire(wire)
    q = quarantine_wire(wire, ok)
    dense = np.asarray(wire_densify(q))
    assert not q.mask[1].any()
    assert (dense[1] == 0).all()
    assert q.mask[0].any() and q.mask[2].any()


def test_validate_dense():
    stack = np.zeros((3, 4, 8), np.float32)
    stack[1, 2, 3] = np.nan
    ok, reasons = validate_dense(stack)
    assert list(ok) == [True, False, True]
    assert reasons[1] == "non_finite"
    h = np.zeros((3, 4, 2), np.float32)
    h[2, 0, 0] = np.inf
    ok2, _ = validate_dense(np.zeros((3, 4, 8), np.float32), h)
    assert list(ok2) == [True, True, False]


def test_server_aggregate_sparse_wire_validates():
    from repro.fed.server import Server

    server = Server(SERVER, seed=0, distill_steps=1)
    wire = corrupt_wire(_wire(n=3, samples=4, vocab=SERVER.vocab_size,
                              k_cap=8), [2], mode="nan")
    k_g, _ = server.aggregate_sparse_wire(wire, validate=True)
    assert np.isfinite(np.asarray(k_g)).all()


# ---------------------------------------------------------------------------
# end-to-end contracts on the engine ladder
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["sequential", "batched", "fused", "fused_e2e"])
def test_none_preset_bit_identical(engine):
    """faults='none' must be indistinguishable from faults=None on every
    engine path — the disabled machinery costs nothing."""
    ds = _dataset()
    base = run_federated(CLIENT, SERVER, ds, _cfg(engine, rounds=2))
    none = run_federated(CLIENT, SERVER, ds, _cfg(engine, rounds=2, faults="none"))
    assert base.server_acc == none.server_acc
    assert base.client_acc == none.client_acc
    assert base.per_client_k == none.per_client_k
    for ra, rb in zip(base.ledger.rounds, none.ledger.rounds):
        assert ra.uplink_bytes == rb.uplink_bytes
    assert none.num_quarantined is None  # disabled config leaves taps off


def test_fault_parity_across_engines():
    """The same fault realisation must hit every engine identically: same
    quarantine/crash counts, same delivered k, same ledger bytes."""
    ds = _dataset()
    runs = {
        e: run_federated(CLIENT, SERVER, ds, _cfg(e, faults="corruption"))
        for e in ("sequential", "batched", "fused_e2e")
    }
    ref = runs["sequential"]
    assert sum(ref.num_quarantined) > 0, "corruption preset must engage"
    for name, run in runs.items():
        assert run.num_quarantined == ref.num_quarantined, name
        assert run.num_crashed == ref.num_crashed, name
        assert run.per_client_k == ref.per_client_k, name
        assert run.attempted_k == ref.attempted_k, name
        assert run.retrans_bytes == ref.retrans_bytes, name
        for ra, rb in zip(run.ledger.rounds, ref.ledger.rounds):
            assert ra.uplink_bytes == rb.uplink_bytes, name
            assert ra.num_transmitters == rb.num_transmitters, name


def test_corruption_retransmission_in_ledger():
    """Retransmission bytes appear in the ledger's uplink: a faulty run's
    uplink equals the fault-free uplink of the DELIVERED payloads plus the
    tapped retrans_bytes."""
    ds = _dataset()
    run = run_federated(CLIENT, SERVER, ds, _cfg("batched", faults="corruption"))
    assert sum(run.retrans_bytes) > 0
    for stats, retrans in zip(run.ledger.rounds, run.retrans_bytes):
        assert stats.retrans_bytes == retrans
        # the on-air total always covers the retransmitted copies
        assert stats.uplink_bytes >= retrans


def test_crashes_are_not_quarantine():
    """The crash path is observable as num_crashed (attempted > 0, zero
    bytes), distinct from both quarantine and the k = 0 budget path."""
    ds = _dataset()
    run = run_federated(
        CLIENT, SERVER, ds,
        _cfg("batched", rounds=4,
             faults=FaultConfig(name="t", crash_prob=0.5)),
    )
    assert sum(run.num_crashed) > 0
    assert sum(run.num_quarantined) == 0
    for rnd, n_crash in enumerate(run.num_crashed):
        # every crash is a client with attempted k > 0 that delivered k = 0
        lost = sum(
            1 for ak, dk in zip(run.attempted_k[rnd], run.per_client_k[rnd])
            if ak > 0 and dk == 0
        )
        assert lost >= n_crash


def test_faults_require_adaptive_k():
    ds = _dataset()
    with pytest.raises(ValueError, match="adaptive"):
        run_federated(CLIENT, SERVER, ds,
                      _cfg("batched", method="all_logits", faults="corruption"))


def test_summary_nan_safe():
    """FedRun.summary() must survive all-dropped rounds (NaN accuracies):
    max() over a NaN-bearing list is order-dependent."""
    from repro.core.protocol import CommLedger
    from repro.fed.rounds import FedRun

    run = FedRun(ledger=CommLedger(), server_acc=[0.5, float("nan"), 0.3],
                 client_acc=[], mean_k=[])
    assert run.summary()["best_server_acc"] == 0.5
    empty = FedRun(ledger=CommLedger(), server_acc=[float("nan")],
                   client_acc=[], mean_k=[])
    assert np.isnan(empty.summary()["best_server_acc"])


def test_fault_config_in_fingerprint():
    """Changing the fault preset must fail a resume fingerprint check."""
    from repro.fed.rounds import _config_fingerprint

    a = _config_fingerprint(_cfg("batched"))
    b = _config_fingerprint(_cfg("batched", faults="corruption"))
    assert a != b
    assert _config_fingerprint(_cfg("batched", rounds=9)) == a  # rounds excluded


def test_scan_rounds_fault_parity():
    """The multi-round lax.scan driver consumes faults as pure data masks:
    same realisation as the per-round host path."""
    ds = _dataset()
    host = run_federated(CLIENT, SERVER, ds, _cfg("fused_e2e", faults="corruption"))
    scan = run_federated(
        CLIENT, SERVER, ds,
        dataclasses.replace(_cfg("fused_e2e", faults="corruption"), scan_rounds=True),
    )
    assert scan.num_quarantined == host.num_quarantined
    assert scan.per_client_k == host.per_client_k
    assert scan.retrans_bytes == host.retrans_bytes
    for ra, rb in zip(scan.ledger.rounds, host.ledger.rounds):
        assert ra.uplink_bytes == rb.uplink_bytes
