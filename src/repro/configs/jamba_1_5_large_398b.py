"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave with MoE.

[arXiv:2403.19887] 72 layers, d_model=8192, 64 q heads / 8 kv heads,
per-expert d_ff=24576, vocab 65536, MoE 16 experts top-2 every other layer,
one attention layer per 8 (attn_every=8; the rest are Mamba blocks with
state N=128, head P=64, expand 2 → d_inner 16384).  398B total params: the
HBM-fit config is bf16 params + bf16 Adam moments + remat (DESIGN §4:
398e9 × 8 B / 256 chips ≈ 12.4 GB/chip).
"""

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24_576,
    vocab_size=65_536,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=24_576, capacity_factor=1.25),
    moe_every=2,
    moe_offset=1,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=128),
    attn_every=8,
    attn_offset=4,  # attention mid-period, as in the released block layout
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optimizer_state_dtype="bfloat16",
    remat=True,
    microbatches=16,
    max_seq_len=1_048_576,  # hybrid: attn layers use the seq-sharded cache
    cite="arXiv:2403.19887",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    name="jamba-smoke", num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=512), moe_every=2, moe_offset=1,
    ssm=SSMConfig(state_dim=32, head_dim=32, expand=2, chunk_size=32),
    attn_every=4, attn_offset=2,
    param_dtype="float32", compute_dtype="float32", optimizer_state_dtype="float32",
    remat=False, max_seq_len=256,
)
