"""Hypothesis property tests on the system's invariants.

Skipped cleanly (not a collection error) when hypothesis isn't installed —
it is a dev-only dependency (see requirements-dev.txt).
"""

import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import aggregate_adaptive, aggregate_zeropad
from repro.core.channel import ChannelState, bits_per_entry, topk_budget
from repro.core.distill import kl_divergence
from repro.core.protocol import PayloadSpec
from repro.core.topk import densify, topk_sparsify

SETTINGS = settings(max_examples=30, deadline=None)


@given(
    bandwidth=st.floats(1e3, 1e9),
    snr_db=st.floats(-20, 40),
    eta=st.floats(0.01, 1.0),
    deadline=st.floats(0.01, 10.0),
    vocab=st.integers(2, 300_000),
    samples=st.integers(1, 5000),
)
@SETTINGS
def test_topk_payload_respects_shannon_budget(bandwidth, snr_db, eta, deadline, vocab, samples):
    """INVARIANT (paper §III-A): the adaptive payload never exceeds the
    channel's bit budget — except via the k_min=1 survival floor."""
    state = ChannelState(bandwidth, snr_db, eta, deadline)
    k = topk_budget(state, vocab_size=vocab, num_samples=samples)
    spec = PayloadSpec(num_samples=samples, vocab=vocab, k=k, lora_rank=None)
    floor_bits = samples * 1 * bits_per_entry(16, vocab)
    assert spec.uplink_bits <= max(state.bit_budget, floor_bits) + 1e-6


@given(
    n=st.integers(1, 8),
    rows=st.integers(1, 4),
    vocab=st.integers(4, 128),
    keep=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**30),
)
@SETTINGS
def test_adaptive_aggregation_convexity(n, rows, vocab, keep, seed):
    """INVARIANT (eqs. 6-7): per dim, output is a convex combination of the
    transmitting clients' values; untouched dims stay exactly zero."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, rows, vocab))
    mask = jax.random.uniform(jax.random.fold_in(key, 1), x.shape) < keep
    stack = jnp.where(mask, x, 0.0)
    out = aggregate_adaptive(stack)
    transmitted = stack != 0
    touched = transmitted.any(axis=0)
    lo = jnp.where(transmitted, stack, jnp.inf).min(axis=0)
    hi = jnp.where(transmitted, stack, -jnp.inf).max(axis=0)
    assert bool(jnp.all(jnp.where(touched, (out >= lo - 1e-4) & (out <= hi + 1e-4), out == 0)))


@given(
    rows=st.integers(1, 4),
    vocab=st.integers(8, 256),
    k=st.integers(1, 64),
    seed=st.integers(0, 2**30),
)
@SETTINGS
def test_sparsify_preserves_topk_and_is_idempotent(rows, vocab, k, seed):
    k = min(k, vocab)
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, vocab)) + 10.0
    d = densify(topk_sparsify(x, k))
    # exactly k nonzeros per row (values are strictly positive)
    assert int(jnp.sum(d != 0)) == rows * k
    d2 = densify(topk_sparsify(d, k))
    np.testing.assert_allclose(d, d2, atol=0)


@given(
    rows=st.integers(1, 4),
    vocab=st.integers(2, 128),
    temp=st.floats(0.5, 10.0),
    seed=st.integers(0, 2**30),
)
@SETTINGS
def test_kl_nonnegative_property(rows, vocab, temp, seed):
    key = jax.random.PRNGKey(seed)
    t = jax.random.normal(key, (rows, vocab)) * 5
    s = jax.random.normal(jax.random.fold_in(key, 1), (rows, vocab)) * 5
    assert float(kl_divergence(t, s, temp)) >= -1e-5


@given(
    n=st.integers(2, 6),
    vocab=st.integers(4, 64),
    seed=st.integers(0, 2**30),
)
@SETTINGS
def test_aggregation_modes_agree_on_dense_stacks(n, vocab, seed):
    """With NO sparsity, adaptive and zeropad agree when all values are equal
    (degenerate case), and both return finite values generally."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 2, vocab))
    assert bool(jnp.all(jnp.isfinite(aggregate_adaptive(x))))
    assert bool(jnp.all(jnp.isfinite(aggregate_zeropad(x))))
    same = jnp.broadcast_to(x[0], x.shape)
    np.testing.assert_allclose(
        aggregate_adaptive(same), aggregate_zeropad(same), rtol=1e-4, atol=1e-5
    )
