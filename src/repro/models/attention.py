"""Grouped-query attention with KV cache, sliding window, LoRA hooks.

Sharding-relevant layout decisions (see DESIGN §4):
  * activations carry explicit head axes: q (B,S,Kv,G,Dh), k/v (B,T,Kv,Dh) —
    the Kv/G axes are what tensor parallelism shards;
  * the decode KV cache is laid out (B, C, Kv, Dh) with C the cache length;
    at decode shapes C is sharded along the **sequence** axis over the
    ``model`` mesh axis (flash-decoding on TPU): every device attends its
    slice, XLA turns the seq-contraction + softmax into partial
    reductions + ``psum``;
  * sliding-window mode stores a ring buffer of C = window entries with an
    absolute-position side array, so a 524k-token stream needs a 4k cache.

RoPE is applied at *write* time for keys (rotation by absolute position),
so cached keys never need re-rotation (relative property preserved).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_apply, dense_init

__all__ = ["KVCache", "attn_init", "attn_apply", "init_kv_cache", "cross_attn_apply"]

_NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array  # (B, C, Kv, Dh) — RoPE already applied
    v: jax.Array  # (B, C, Kv, Dh)
    pos: jax.Array  # (C,) absolute position of each slot, -1 = empty
    length: jax.Array  # () int32 — tokens seen so far (absolute)


def attn_init(rng: jax.Array, cfg: ModelConfig, *, cross: bool = False) -> dict:
    hd = cfg.head_dim
    keys = jax.random.split(rng, 4)
    return {
        "wq": dense_init(keys[0], cfg.d_model, cfg.num_heads * hd, use_bias=cfg.use_bias, dtype=cfg.param_dtype),
        "wk": dense_init(keys[1], cfg.d_model, cfg.num_kv_heads * hd, use_bias=cfg.use_bias, dtype=cfg.param_dtype),
        "wv": dense_init(keys[2], cfg.d_model, cfg.num_kv_heads * hd, use_bias=cfg.use_bias, dtype=cfg.param_dtype),
        "wo": dense_init(keys[3], cfg.num_heads * hd, cfg.d_model, use_bias=cfg.use_bias, dtype=cfg.param_dtype),
    }


def init_kv_cache(
    cfg: ModelConfig, batch: int, cache_len: int, *, dtype: str | None = None
) -> KVCache:
    dt = jnp.dtype(dtype or cfg.compute_dtype)
    hd = cfg.head_dim
    return KVCache(
        k=jnp.zeros((batch, cache_len, cfg.num_kv_heads, hd), dt),
        v=jnp.zeros((batch, cache_len, cfg.num_kv_heads, hd), dt),
        pos=jnp.full((cache_len,), -1, jnp.int32),
        length=jnp.zeros((), jnp.int32),
    )


def _lora_delta(lora_p: dict, x: jax.Array, *, alpha: float, rank: int, compute_dtype: str):
    """x @ A @ B scaled by alpha/r.  Returns (delta, h) with h = x @ A.

    Two adapter layouts (multi-tenant serving, repro.serve):
      * shared  — A (d, r), B (r, o): one adapter for the whole batch;
      * batched — A (B, d, r), B (B, r, o): row b of the batch applies
        adapter b.  Row b's contraction is the SAME einsum over the same
        operands as the shared case, so stacked multi-tenant decode is
        bit-identical to running each request alone with its adapter.
    """
    cd = jnp.dtype(compute_dtype)
    a, b = lora_p["A"].astype(cd), lora_p["B"].astype(cd)
    if a.ndim == 3:  # per-request adapters, leading axis = batch
        h = jnp.einsum("b...i,bir->b...r", x.astype(cd), a)
        delta = jnp.einsum("b...r,bro->b...o", h, b) * (alpha / rank)
    else:
        h = jnp.einsum("...i,ir->...r", x.astype(cd), a)
        delta = jnp.einsum("...r,ro->...o", h, b) * (alpha / rank)
    return delta, h


def _project(
    params: dict,
    x: jax.Array,
    name: str,
    cfg: ModelConfig,
    lora: dict | None,
) -> tuple[jax.Array, jax.Array | None]:
    y = dense_apply(params[f"w{name}"], x, compute_dtype=cfg.compute_dtype)
    h = None
    if lora is not None and name in lora:
        delta, h = _lora_delta(
            lora[name], x, alpha=cfg.lora.alpha, rank=cfg.lora.rank, compute_dtype=cfg.compute_dtype
        )
        y = y + delta
    return y, h


def _repeat_kv(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """(B,T,Kv,Dh) -> (B,T,Hq,Dh): single head axis so tensor parallelism
    shards scores/probs by head (§Perf iteration 1 — the (Kv,G) split axis
    defeated XLA's sharding propagation and replicated the score tensors)."""
    g = cfg.q_per_kv
    if g == 1:
        return x
    return jnp.repeat(x, g, axis=2)


def _gqa_scores(q: jax.Array, k: jax.Array, cfg: ModelConfig) -> jax.Array:
    """q (B,S,Hq,Dh), k (B,T,Kv,Dh) -> scores (B,Hq,S,T), head-sharded."""
    from repro import sharding as _sh

    dh = q.shape[-1]
    k_rep = _repeat_kv(k, cfg)
    scale = dh**-0.5
    scores = jnp.einsum(
        "bshd,bthd->bhst", q.astype(jnp.float32), k_rep.astype(jnp.float32)
    ) * scale
    return _sh.constrain(scores, "batch", "heads", None, None)


def _gqa_output(probs: jax.Array, v: jax.Array, cfg: ModelConfig) -> jax.Array:
    """probs (B,Hq,S,T), v (B,T,Kv,Dh) -> (B,S,Hq*Dh)."""
    v_rep = _repeat_kv(v, cfg)
    out = jnp.einsum("bhst,bthd->bshd", probs, v_rep.astype(jnp.float32))
    b, s, h, dh = out.shape
    return out.reshape(b, s, h * dh)


# q-chunk length for the memory-efficient full-sequence path.  4k-512k
# sequences never materialise (S, T) scores — peak attention memory is
# (B, heads, Q_CHUNK, T) per in-flight chunk, which XLA's scan keeps to one.
Q_CHUNK = 512

# REPRO_UNROLL=1: replace the chunk scan with a python loop so HLO cost
# analysis sees every chunk (XLA counts while-loop bodies ONCE — the dry-run
# cost mode needs fully-materialised op counts; see launch/dryrun.py).
import os as _os

_UNROLL = _os.environ.get("REPRO_UNROLL", "0") == "1"


def _dense_attention(q, k, v, cfg, positions, window, causal) -> jax.Array:
    """Reference O(S·T)-memory attention for short sequences."""
    scores = _gqa_scores(q, k, cfg)  # (B,H,S,T)
    if causal:
        cmask = positions[..., :, None] >= positions[..., None, :]
        if window is not None:
            cmask &= positions[..., :, None] - positions[..., None, :] < window
        mask = cmask if cmask.ndim == 3 else cmask[None]
        scores = jnp.where(mask[:, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_output(probs, v, cfg)


def _chunked_attention(q, k, v, cfg, positions, window, causal) -> jax.Array:
    """Scan over query chunks: memory O(Q_CHUNK · T), exact softmax per row.

    The jnp twin of kernels/flash_attention.py (which is the TPU-compiled
    version for inference prefill); this one is used inside the
    differentiable train path so the backward pass composes with
    ``jax.checkpoint`` over the layer scan.
    """
    b, s, hq, dh = q.shape
    nc = s // Q_CHUNK
    assert s % Q_CHUNK == 0, f"seq {s} not divisible by q-chunk {Q_CHUNK}"
    pos1d = positions if positions.ndim == 1 else positions[0]

    q_chunks = q.reshape(b, nc, Q_CHUNK, hq, dh).transpose(1, 0, 2, 3, 4)
    pos_chunks = pos1d.reshape(nc, Q_CHUNK)

    def one_chunk(args):
        qc, qpos = args  # (B, Cq, Hq, Dh), (Cq,)
        scores = _gqa_scores(qc, k, cfg)  # (B,H,Cq,T)
        if causal:
            m = qpos[:, None] >= pos1d[None, :]  # (Cq, T)
            if window is not None:
                m &= qpos[:, None] - pos1d[None, :] < window
            scores = jnp.where(m[None, None], scores, _NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        return _gqa_output(probs, v, cfg)  # (B, Cq, Hq*Dh)

    if _UNROLL:
        out = jnp.stack([one_chunk((q_chunks[i], pos_chunks[i])) for i in range(nc)])
    else:
        out = jax.lax.map(one_chunk, (q_chunks, pos_chunks))  # (nc, B, Cq, H*D)
    return out.transpose(1, 0, 2, 3).reshape(b, s, hq * dh)


def attn_apply(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    window: int | None = None,
    cache: KVCache | None = None,
    lora: dict | None = None,
    causal: bool = True,
) -> tuple[jax.Array, KVCache | None, jax.Array | None]:
    """Self-attention.  Returns (output, updated_cache, lora_h).

    Full-sequence mode (cache is None): causal (+optional window) mask over
    the input sequence — used by train and prefill steps.

    Decode mode (cache given): x is (B, 1, D); the new K/V is written into
    the ring slot ``length % C`` and the query attends over the whole cache.
    """
    q_flat, h_q = _project(params, x, "q", cfg, lora)
    k_flat, _ = _project(params, x, "k", cfg, lora)
    v_flat, h_v = _project(params, x, "v", cfg, lora)

    b, s, _ = x.shape
    hd = cfg.head_dim
    q = q_flat.reshape(b, s, cfg.num_heads, hd)
    k = k_flat.reshape(b, s, cfg.num_kv_heads, hd)
    v = v_flat.reshape(b, s, cfg.num_kv_heads, hd)
    if cache is None:  # full-seq: anchor head sharding (decode keeps the
        # seq-sharded-cache layout instead — q replicated over model)
        from repro import sharding as _sh

        q = _sh.constrain(q, "batch", None, "heads", None)
        k = _sh.constrain(k, "batch", None, "kv", None)
        v = _sh.constrain(v, "batch", None, "kv", None)

    if cfg.positional == "rope":
        q = apply_rope(q, positions, theta=cfg.rope_theta)
        k = apply_rope(k, positions, theta=cfg.rope_theta)

    lora_h = h_q if h_q is not None else h_v

    if cache is None:
        # ---- full-sequence causal path ----
        if s >= 2 * Q_CHUNK:
            out = _chunked_attention(q, k, v, cfg, positions, window, causal)
        else:
            out = _dense_attention(q, k, v, cfg, positions, window, causal)
        y = dense_apply(params["wo"], out.astype(x.dtype), compute_dtype=cfg.compute_dtype)
        return y, None, lora_h

    # ---- decode path: single new token against the cache ----
    assert s == 1, "decode mode expects one new token"
    cache_len = cache.k.shape[1]
    slot = (cache.length % cache_len).astype(jnp.int32)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), slot, axis=1)
    new_pos = jax.lax.dynamic_update_slice_in_dim(
        cache.pos, cache.length[None].astype(jnp.int32), slot, axis=0
    )
    new_cache = KVCache(k=new_k, v=new_v, pos=new_pos, length=cache.length + 1)

    scores = _gqa_scores(q, new_k, cfg)  # (B,H,1,C)
    valid = new_pos >= 0
    valid &= new_pos <= cache.length  # all written slots qualify
    if window is not None:
        valid &= new_pos > cache.length - window
    scores = jnp.where(valid[None, None, None, :], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_output(probs, new_v, cfg).astype(x.dtype)
    y = dense_apply(params["wo"], out, compute_dtype=cfg.compute_dtype)
    return y, new_cache, lora_h


def cross_attn_apply(
    params: dict,
    x: jax.Array,
    enc_out: jax.Array,
    cfg: ModelConfig,
    *,
    lora: dict | None = None,
) -> jax.Array:
    """Encoder-decoder cross attention (no mask, no cache mutation).

    ``enc_out``: (B, T_enc, D) encoder output; K/V recomputed each call in
    training; serving precomputes them once per request outside this fn.
    """
    q_flat, _ = _project(params, x, "q", cfg, lora)
    k_flat, _ = _project(params, enc_out, "k", cfg, lora)
    v_flat, _ = _project(params, enc_out, "v", cfg, lora)
    b, s, _ = x.shape
    t = enc_out.shape[1]
    hd = cfg.head_dim
    q = q_flat.reshape(b, s, cfg.num_heads, hd)
    k = k_flat.reshape(b, t, cfg.num_kv_heads, hd)
    v = v_flat.reshape(b, t, cfg.num_kv_heads, hd)
    scores = _gqa_scores(q, k, cfg)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_output(probs, v, cfg).astype(x.dtype)
    return dense_apply(params["wo"], out, compute_dtype=cfg.compute_dtype)
