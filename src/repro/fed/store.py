"""Fleet-state stores: WHERE the fleet's per-client LoRA/optimizer trees
live between rounds.

Every fast engine keeps the fleet's trainable state outside the Client
objects and works on the selected cohort per round.  Before PR 9 that
state was hard-wired as jnp stacks on the engine (device memory and
scatter cost O(fleet)); this module factors the ownership out into a
store with a four-call contract the engines route through:

* ``fetch(sel) -> (idx, lora, frozen, opt)`` — the selected cohort's
  device trees, leading axis = cohort.  The returned arrays are FRESH
  (safe to donate into a jitted step).
* ``commit(idx, lora, opt)`` — write the advanced cohort rows back.
* ``prefetch(sel)`` — optional hint: start staging round r+1's cohort
  host->device while round r computes (no-op where state already lives
  on device).
* ``state_dict()/load_state_dict()`` — the checkpointable
  ``{"lora", "opt", "frozen"}`` image, layout-identical across stores
  (a checkpoint written under one store restores under the other).

Two implementations:

* :class:`DeviceFleetStore` — today's layout, bit-identically: the whole
  fleet stacked on device along a leading ``(N, ...)`` axis, fetch is one
  gather per leaf, commit one ``.at[idx].set`` per leaf.  O(N) device
  memory; the only store the scan-carry multi-round drivers accept (the
  fleet rides inside the compiled scan).
* :class:`HostFleetStore` — out-of-core: the fleet lives in host numpy
  (optionally npz-spilled to disk through :mod:`repro.checkpoint`), and
  only the current cohort (+ one prefetch buffer) ever exists on device.
  Device memory is O(cohort), independent of N; a double-buffered
  prefetch thread overlaps the next cohort's host->device transfer with
  the current round's compute, with dirty-row patching so overlapping
  consecutive cohorts still read committed state (the result is
  bit-identical with prefetch on or off).

Sharded persistence: both stores save/restore the fleet as per-client
range shards (``{prefix}_{lo:08d}_{hi:08d}.npz``; shared backbones ride
one ``{prefix}_frozen.npz``) through the atomic
:mod:`repro.checkpoint.ckpt` writers, so a checkpoint of a 100k-client
fleet never materializes as one device tree.
"""

from __future__ import annotations

import os
import threading
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as ckpt_io

__all__ = [
    "FleetStore",
    "DeviceFleetStore",
    "HostFleetStore",
    "make_fleet_store",
]

_NO_STACK = (
    "HostFleetStore keeps the fleet out of device memory: the full stacked "
    "device tree does not exist.  The scan-carry multi-round drivers "
    "(scan_rounds / run_rounds) donate the stacked fleet into one compiled "
    "scan and therefore require fleet_store='device'; the host store runs "
    "the per-round driver instead."
)


def _device_stack(trees: Sequence):
    """Stack pytrees along a new leading (client) axis on device — the
    exact op the engines used pre-refactor (bit-identity anchor)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def _host_stack(trees: Sequence):
    """Stack pytrees along a new leading (client) axis in host numpy,
    without a device-stacked intermediate."""
    return jax.tree.map(lambda *xs: np.stack([np.asarray(x) for x in xs]), *trees)


def _like(tree):
    """Shape/dtype skeleton (no allocation) for :func:`ckpt.restore`."""
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _rows_like(tree, n: int):
    """Skeleton of ``n`` leading-axis rows of a stacked tree."""
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((n,) + tuple(x.shape[1:]), x.dtype), tree
    )


def _check_shard_cover(shards, num_clients: int, dir_path: str) -> None:
    ranges = sorted((lo, hi) for lo, hi, _ in shards)
    expect = 0
    for lo, hi in ranges:
        if lo != expect:
            raise ValueError(
                f"fleet shards in {dir_path} do not cover clients "
                f"[{expect}, {lo}) — checkpoint is incomplete"
            )
        expect = hi
    if expect != num_clients:
        raise ValueError(
            f"fleet shards in {dir_path} cover {expect} clients, "
            f"store holds {num_clients}"
        )


class FleetStore:
    """Abstract fleet-state owner (see module docstring for the contract).

    ``shard_size`` bounds the per-client range of one persisted shard
    file; it is a persistence knob only (any store can read shards
    written at any shard size — names encode the ranges).
    """

    kind: str
    num_clients: int
    shared: bool
    shard_size: int = 1024

    # -- round-loop contract -------------------------------------------
    def fetch(self, sel: Sequence[int]):
        raise NotImplementedError

    def commit(self, idx, lora, opt) -> None:
        raise NotImplementedError

    def prefetch(self, sel: Sequence[int]) -> None:  # pragma: no cover
        """Hint: the NEXT round's cohort.  Default: nothing to stage."""

    # -- serving contract ----------------------------------------------
    def lora_rows(self, sel: Sequence[int]):
        """Fresh device-stacked LoRA rows of the given clients, leading
        axis = len(sel) — the adapter-paging read the serving
        :class:`repro.serve.AdapterCache` issues on a slot miss.  No opt
        state, no frozen rows: an adapter page-in moves adapter bytes
        only.  The returned arrays are fresh (safe to donate)."""
        raise NotImplementedError

    # -- checkpoint contract -------------------------------------------
    def state_dict(self) -> dict:
        raise NotImplementedError

    def load_state_dict(self, state: dict) -> None:
        raise NotImplementedError

    def _rows_host(self, lo: int, hi: int) -> dict:
        """Host-numpy copies of clients [lo, hi): ``{"lora", "opt"}``
        (+ ``"frozen"`` rows for per-client backbones)."""
        raise NotImplementedError

    def _frozen_shared_tree(self):
        raise NotImplementedError

    def save_shards(self, dir_path: str, *, prefix: str = "fleet") -> None:
        """Persist the fleet as per-client-range npz shards (each write
        atomic via :func:`repro.checkpoint.ckpt.save`)."""
        os.makedirs(dir_path, exist_ok=True)
        for lo in range(0, self.num_clients, self.shard_size):
            hi = min(lo + self.shard_size, self.num_clients)
            ckpt_io.save(
                os.path.join(dir_path, ckpt_io.fleet_shard_name(prefix, lo, hi)),
                self._rows_host(lo, hi),
            )
        if self.shared:
            ckpt_io.save(
                os.path.join(dir_path, f"{prefix}_frozen.npz"),
                {"frozen": self._frozen_shared_tree()},
            )

    def load_shards(self, dir_path: str, *, prefix: str = "fleet") -> None:
        raise NotImplementedError

    # -- introspection --------------------------------------------------
    def device_bytes(self) -> int:
        """Device-resident bytes this store holds BETWEEN rounds (the
        fleet-scaling metric: O(N) for the device store, O(1) in N for
        the host store)."""
        raise NotImplementedError


class DeviceFleetStore(FleetStore):
    """The pre-PR-9 layout, bit-identically: whole fleet stacked on
    device; fetch = one gather per leaf, commit = one scatter per leaf."""

    kind = "device"

    def __init__(self, loras: Sequence, frozens: Sequence, opts: Sequence,
                 *, shared: bool):
        self.num_clients = len(loras)
        self.shared = bool(shared)
        self._lora = _device_stack(loras)  # (N, ...)
        self._frozen = frozens[0] if self.shared else _device_stack(frozens)
        self._opt = _device_stack(opts)

    # the stacked trees stay directly addressable: the scan-carry drivers
    # donate them into compiled multi-round scans and write them back
    @property
    def lora(self):
        return self._lora

    @lora.setter
    def lora(self, tree):
        self._lora = tree

    @property
    def opt(self):
        return self._opt

    @opt.setter
    def opt(self, tree):
        self._opt = tree

    @property
    def frozen(self):
        return self._frozen

    @frozen.setter
    def frozen(self, tree):
        self._frozen = tree

    def fetch(self, sel: Sequence[int]):
        idx = jnp.asarray(list(sel))
        lora = jax.tree.map(lambda x: x[idx], self._lora)
        opt = jax.tree.map(lambda x: x[idx], self._opt)
        frozen = (
            self._frozen if self.shared
            else jax.tree.map(lambda x: x[idx], self._frozen)
        )
        return idx, lora, frozen, opt

    def commit(self, idx, lora, opt) -> None:
        self._lora = jax.tree.map(
            lambda full, new: full.at[idx].set(new), self._lora, lora
        )
        self._opt = jax.tree.map(
            lambda full, new: full.at[idx].set(new), self._opt, opt
        )

    def client_row(self, cid: int):
        """One client's (lora, frozen) trees (for evaluation)."""
        lora_i = jax.tree.map(lambda x: x[cid], self._lora)
        frozen_i = (
            self._frozen if self.shared
            else jax.tree.map(lambda x: x[cid], self._frozen)
        )
        return lora_i, frozen_i

    def lora_rows(self, sel: Sequence[int]):
        idx = jnp.asarray(list(sel))
        return jax.tree.map(lambda x: jnp.take(x, idx, axis=0), self._lora)

    def state_dict(self) -> dict:
        return {"lora": self._lora, "opt": self._opt, "frozen": self._frozen}

    def load_state_dict(self, state: dict) -> None:
        # copy=True: these stacks are donated into the scan-carry drivers,
        # so they must be XLA-owned even when restored from numpy buffers
        as_jax = lambda tree: jax.tree.map(  # noqa: E731
            lambda a: jnp.array(a, copy=True), tree
        )
        self._lora = as_jax(state["lora"])
        self._opt = as_jax(state["opt"])
        self._frozen = as_jax(state["frozen"])

    def _rows_host(self, lo: int, hi: int) -> dict:
        rows = {
            "lora": jax.tree.map(lambda x: np.asarray(x[lo:hi]), self._lora),
            "opt": jax.tree.map(lambda x: np.asarray(x[lo:hi]), self._opt),
        }
        if not self.shared:
            rows["frozen"] = jax.tree.map(
                lambda x: np.asarray(x[lo:hi]), self._frozen
            )
        return rows

    def _frozen_shared_tree(self):
        return self._frozen

    def load_shards(self, dir_path: str, *, prefix: str = "fleet") -> None:
        shards = ckpt_io.list_fleet_shards(dir_path, prefix)
        _check_shard_cover(shards, self.num_clients, dir_path)
        keys = ["lora", "opt"] + ([] if self.shared else ["frozen"])
        stacks = {"lora": self._lora, "opt": self._opt}
        if not self.shared:
            stacks["frozen"] = self._frozen
        parts = [
            ckpt_io.restore(
                path, {k: _rows_like(stacks[k], hi - lo) for k in keys}
            )
            for lo, hi, path in sorted(shards)
        ]
        state = {
            k: jax.tree.map(lambda *xs: jnp.concatenate(xs), *[p[k] for p in parts])
            for k in keys
        }
        if self.shared:
            state["frozen"] = ckpt_io.restore(
                os.path.join(dir_path, f"{prefix}_frozen.npz"),
                {"frozen": _like(self._frozen)},
            )["frozen"]
        self.load_state_dict(state)

    def device_bytes(self) -> int:
        return sum(
            int(np.dtype(x.dtype).itemsize) * int(np.prod(x.shape))
            for tree in (self._lora, self._opt, self._frozen)
            for x in jax.tree.leaves(tree)
        )


class HostFleetStore(FleetStore):
    """Out-of-core fleet: host-numpy stacks (optionally npz-spilled),
    device working set = current cohort + one prefetch buffer.

    Prefetch protocol: :meth:`prefetch` snapshots the requested cohort
    and stages its device copy on a worker thread while the round
    computes; every :meth:`commit` after the snapshot marks its rows
    dirty, and a :meth:`fetch` of that cohort patches dirty positions
    from the (by then committed) host rows — so a prefetched fetch
    returns exactly what an unprefetched one would, even when
    consecutive cohorts overlap.  The buffer is DOUBLE: up to two staged
    cohorts are held (the round driver hints round r+1 BEFORE it fetches
    round r's already-staged rows), each with its own dirty set; older
    entries are evicted FIFO.

    ``spill_dir`` pages the host stacks to per-range npz shards under
    the given directory with a small in-memory shard cache
    (write-back on eviction) — host memory then also stays O(cohort·
    shard_size) instead of O(N).

    :meth:`from_template` builds an N-client store from ONE template row
    (every client starts at the template until first commit) in O(1)
    time and O(touched rows) resident memory — the constructor for
    fleet-scale benchmarks where N Client objects cannot exist.
    """

    kind = "host"

    def __init__(self, loras: Sequence, frozens: Sequence, opts: Sequence,
                 *, shared: bool, prefetch: bool = True,
                 spill_dir: str | None = None, shard_size: int = 1024):
        if not shared:
            frozen_rows = _host_stack(frozens)
        else:
            frozen_rows = None
        self._init_common(
            num_clients=len(loras), shared=shared, prefetch=prefetch,
            spill_dir=spill_dir, shard_size=shard_size,
            host={"lora": _host_stack(loras), "opt": _host_stack(opts),
                  **({} if shared else {"frozen": frozen_rows})},
            frozen_shared=frozens[0] if shared else None,
            template=None,
        )

    @classmethod
    def from_template(cls, lora_row, frozen, opt_row, *, num_clients: int,
                      prefetch: bool = True, spill_dir: str | None = None,
                      shard_size: int = 1024):
        self = cls.__new__(cls)
        template = {
            "lora": jax.tree.map(np.asarray, lora_row),
            "opt": jax.tree.map(np.asarray, opt_row),
        }
        # np.zeros is calloc-backed: untouched rows cost virtual address
        # space only — resident memory scales with COMMITTED rows, not N
        host = {
            k: jax.tree.map(
                lambda r: np.zeros((num_clients,) + r.shape, r.dtype), t
            )
            for k, t in template.items()
        }
        self._init_common(
            num_clients=num_clients, shared=True, prefetch=prefetch,
            spill_dir=spill_dir, shard_size=shard_size, host=host,
            frozen_shared=frozen, template=template,
        )
        return self

    def _init_common(self, *, num_clients, shared, prefetch, spill_dir,
                     shard_size, host, frozen_shared, template):
        self.num_clients = int(num_clients)
        self.shared = bool(shared)
        self.shard_size = int(shard_size)
        self.prefetch_enabled = bool(prefetch)
        self._frozen_shared = frozen_shared  # device tree (or None)
        self._template = template
        self._initialized = (
            np.zeros(self.num_clients, bool) if template is not None else None
        )
        self._lock = threading.Lock()
        # double buffer: sel tuple -> [thread, result box, dirty-row set]
        self._pf: dict[tuple, list] = {}
        self._spill_dir = spill_dir
        if spill_dir is None:
            self._host = host
            self._cache = None
        else:
            # page the stacks out now; keep only shape/dtype row skeletons
            self._host = None
            self._row_like = {
                k: jax.tree.map(
                    lambda a: np.zeros(a.shape[1:], a.dtype), t
                )
                for k, t in host.items()
            }
            self._cache: dict[int, dict] = {}
            self._cache_cap = 4
            os.makedirs(spill_dir, exist_ok=True)
            for lo in range(0, self.num_clients, self.shard_size):
                hi = min(lo + self.shard_size, self.num_clients)
                ckpt_io.save(
                    os.path.join(
                        spill_dir, ckpt_io.fleet_shard_name("spill", lo, hi)
                    ),
                    {k: jax.tree.map(lambda a: a[lo:hi], t)
                     for k, t in host.items()},
                )

    # -- the stacked-device API does not exist here ---------------------
    @property
    def lora(self):
        raise RuntimeError(_NO_STACK)

    @property
    def opt(self):
        raise RuntimeError(_NO_STACK)

    @property
    def frozen(self):
        if self.shared:
            return self._frozen_shared
        raise RuntimeError(_NO_STACK)

    # -- spill paging (callers hold self._lock) -------------------------
    def _shard_path(self, si: int) -> str:
        lo = si * self.shard_size
        hi = min(lo + self.shard_size, self.num_clients)
        return os.path.join(
            self._spill_dir, ckpt_io.fleet_shard_name("spill", lo, hi)
        )

    def _shard_tree(self, si: int) -> dict:
        tree = self._cache.get(si)
        if tree is not None:
            return tree
        lo = si * self.shard_size
        hi = min(lo + self.shard_size, self.num_clients)
        path = self._shard_path(si)
        if os.path.exists(path):
            tree = ckpt_io.restore(
                path,
                {k: jax.tree.map(
                    lambda r: jax.ShapeDtypeStruct((hi - lo,) + r.shape, r.dtype),
                    t,
                ) for k, t in self._row_like.items()},
            )
            # restore returns read-only-ish np arrays; ensure writable rows
            tree = {k: jax.tree.map(np.array, t) for k, t in tree.items()}
        else:
            tree = {
                k: jax.tree.map(
                    lambda r: np.zeros((hi - lo,) + r.shape, r.dtype), t
                )
                for k, t in self._row_like.items()
            }
        if len(self._cache) >= self._cache_cap:
            evict = next(iter(self._cache))
            ckpt_io.save(self._shard_path(evict), self._cache.pop(evict))
        self._cache[si] = tree
        return tree

    def _flush_spill(self) -> None:
        for si, tree in self._cache.items():
            ckpt_io.save(self._shard_path(si), tree)

    # -- host row access (callers hold self._lock) ----------------------
    def _row(self, cid: int) -> dict:
        """One client's host row trees (views — callers must copy)."""
        if self._template is not None and not self._initialized[cid]:
            return self._template
        if self._spill_dir is None:
            return {
                k: jax.tree.map(lambda a: a[cid], t)
                for k, t in self._host.items()
            }
        tree = self._shard_tree(cid // self.shard_size)
        local = cid % self.shard_size
        return {k: jax.tree.map(lambda a: a[local], t) for k, t in tree.items()}

    def _gather_rows(self, ids) -> dict:
        """Fresh host stacks of the given client rows, cohort order."""
        with self._lock:
            rows = [self._row(int(i)) for i in ids]
            return {
                k: jax.tree.map(
                    lambda *xs: np.stack(xs), *[r[k] for r in rows]
                )
                for k in rows[0]
            }

    def _write_rows(self, ids, host_trees: dict) -> None:
        with self._lock:
            for j, cid in enumerate(ids):
                if self._spill_dir is None:
                    target = self._host
                    local = cid
                else:
                    target = self._shard_tree(cid // self.shard_size)
                    local = cid % self.shard_size
                for k, new in host_trees.items():
                    jax.tree.map(
                        lambda a, nw: a.__setitem__(local, nw[j]),
                        target[k], new,
                    )
                if self._initialized is not None:
                    self._initialized[cid] = True

    @staticmethod
    def _to_device(host_trees: dict) -> dict:
        # copy=True, NOT asarray: CPU jax may zero-copy ALIAS an aligned
        # numpy buffer, and the engines donate these arrays — XLA reusing
        # a buffer the (freed) numpy temporary also owned corrupts the heap
        return {
            k: jax.tree.map(lambda a: jnp.array(a, copy=True), t)
            for k, t in host_trees.items()
        }

    # -- round-loop contract -------------------------------------------
    def fetch(self, sel: Sequence[int]):
        sel = tuple(int(i) for i in sel)
        idx = jnp.asarray(list(sel))
        dev = self._take_prefetched(sel)
        if dev is None:
            dev = self._to_device(self._gather_rows(sel))
        frozen = self._frozen_shared if self.shared else dev["frozen"]
        return idx, dev["lora"], frozen, dev["opt"]

    def commit(self, idx, lora, opt) -> None:
        ids = [int(i) for i in np.asarray(idx)]
        if len(set(ids)) != len(ids):
            raise ValueError(
                f"commit got duplicate client ids {sorted(ids)}: duplicate "
                "row writes would resolve in unspecified order"
            )
        self._write_rows(ids, {
            "lora": jax.tree.map(np.asarray, lora),
            "opt": jax.tree.map(np.asarray, opt),
        })
        # rows committed after a prefetch snapshot: that staged copy is
        # (possibly) stale — the matching fetch will re-read those rows
        for entry in self._pf.values():
            entry[2].update(ids)

    def prefetch(self, sel: Sequence[int]) -> None:
        if not self.prefetch_enabled:
            return
        sel = tuple(int(i) for i in sel)
        # double buffer: the driver hints round r+1 while round r's staged
        # cohort is still pending — keep both, evict beyond that (FIFO)
        self._pf.pop(sel, None)
        while len(self._pf) >= 2:
            self._pf.pop(next(iter(self._pf)))[0].join()
        box: dict = {}

        def stage():
            box["dev"] = self._to_device(self._gather_rows(sel))

        t = threading.Thread(target=stage, daemon=True)
        self._pf[sel] = [t, box, set()]
        t.start()

    def _drop_prefetch(self) -> None:
        for entry in self._pf.values():
            entry[0].join()
        self._pf.clear()

    def _take_prefetched(self, sel: tuple) -> dict | None:
        entry = self._pf.pop(sel, None)
        if entry is None:
            return None  # no hint for this cohort — cold fetch
        t, box, dirty = entry
        t.join()
        dev = box.get("dev")
        if dev is None:  # staging thread died; fall back to a cold fetch
            return None
        stale = [p for p, cid in enumerate(sel) if cid in dirty]
        if stale:
            fresh = self._to_device(
                self._gather_rows([sel[p] for p in stale])
            )
            pos = jnp.asarray(stale)
            dev = {
                k: jax.tree.map(
                    lambda full, f: full.at[pos].set(f), dev[k], fresh[k]
                )
                for k in dev
            }
        return dev

    def client_row(self, cid: int):
        row = self._to_device(self._gather_rows([int(cid)]))
        lora_i = jax.tree.map(lambda x: x[0], row["lora"])
        frozen_i = (
            self._frozen_shared if self.shared
            else jax.tree.map(lambda x: x[0], row["frozen"])
        )
        return lora_i, frozen_i

    def lora_rows(self, sel: Sequence[int]):
        with self._lock:
            rows = [self._row(int(i))["lora"] for i in sel]
        # _row hands out views; np.stack copies, jnp.array(copy=True) keeps
        # the device buffers XLA-owned (donation-safe, same as _to_device)
        stacked = jax.tree.map(lambda *xs: np.stack(xs), *rows)
        return jax.tree.map(lambda a: jnp.array(a, copy=True), stacked)

    # -- checkpoint contract -------------------------------------------
    def state_dict(self) -> dict:
        """The monolithic checkpoint image (host-numpy leaves; identical
        layout to the device store's).  Materializes O(N) host memory —
        fleet-scale runs should persist through :meth:`save_shards`."""
        self._drop_prefetch()
        full = self._rows_host(0, self.num_clients)
        return {
            "lora": full["lora"],
            "opt": full["opt"],
            "frozen": (
                self._frozen_shared if self.shared else full["frozen"]
            ),
        }

    def load_state_dict(self, state: dict) -> None:
        self._drop_prefetch()
        as_np = lambda tree: jax.tree.map(np.array, tree)  # noqa: E731
        host = {"lora": as_np(state["lora"]), "opt": as_np(state["opt"])}
        if self.shared:
            self._frozen_shared = jax.tree.map(jnp.asarray, state["frozen"])
        else:
            host["frozen"] = as_np(state["frozen"])
        self._template = None
        self._initialized = None
        with self._lock:
            if self._spill_dir is None:
                self._host = host
            else:
                self._cache.clear()
                for lo in range(0, self.num_clients, self.shard_size):
                    hi = min(lo + self.shard_size, self.num_clients)
                    ckpt_io.save(
                        self._shard_path(lo // self.shard_size),
                        {k: jax.tree.map(lambda a: a[lo:hi], t)
                         for k, t in host.items()},
                    )

    def _rows_host(self, lo: int, hi: int) -> dict:
        rows = self._gather_rows(range(lo, hi))
        return rows

    def _frozen_shared_tree(self):
        return self._frozen_shared

    def save_shards(self, dir_path: str, *, prefix: str = "fleet") -> None:
        self._drop_prefetch()
        super().save_shards(dir_path, prefix=prefix)

    def load_shards(self, dir_path: str, *, prefix: str = "fleet") -> None:
        self._drop_prefetch()
        shards = ckpt_io.list_fleet_shards(dir_path, prefix)
        _check_shard_cover(shards, self.num_clients, dir_path)
        probe = self._gather_rows([0])
        keys = list(probe)
        for lo, hi, path in sorted(shards):
            tree = ckpt_io.restore(
                path,
                {k: jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(
                        (hi - lo,) + tuple(x.shape[1:]), x.dtype
                    ), probe[k],
                ) for k in keys},
            )
            self._write_rows(
                range(lo, hi), {k: jax.tree.map(np.array, tree[k]) for k in keys}
            )
        if self.shared:
            frozen = ckpt_io.restore(
                os.path.join(dir_path, f"{prefix}_frozen.npz"),
                {"frozen": _like(self._frozen_shared)},
            )["frozen"]
            self._frozen_shared = jax.tree.map(jnp.asarray, frozen)

    # -- introspection --------------------------------------------------
    def device_bytes(self) -> int:
        """Persistent device residency: the shared backbone only (cohort
        and prefetch buffers are transient per round) — independent of N."""
        if not self.shared:
            return 0
        return sum(
            int(np.dtype(x.dtype).itemsize) * int(np.prod(x.shape))
            for x in jax.tree.leaves(self._frozen_shared)
        )

    def host_bytes(self) -> int:
        """Resident host bytes of the fleet stacks (0 when spilled)."""
        if self._spill_dir is not None or self._host is None:
            return 0
        return sum(
            int(x.nbytes)
            for t in self._host.values()
            for x in jax.tree.leaves(t)
        )


def make_fleet_store(spec, *, loras, frozens, opts, shared: bool) -> FleetStore:
    """Resolve a ``FedConfig.fleet_store`` spec — ``"device"`` /
    ``"host"`` / an already-built :class:`FleetStore` — into a store
    holding the given per-client trees."""
    if isinstance(spec, FleetStore):
        return spec
    if spec in (None, "device"):
        return DeviceFleetStore(loras, frozens, opts, shared=shared)
    if spec == "host":
        return HostFleetStore(loras, frozens, opts, shared=shared)
    raise ValueError(
        f"unknown fleet_store: {spec!r} (expected 'device', 'host', or a "
        "FleetStore instance)"
    )
