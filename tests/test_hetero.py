"""Heterogeneous federated distillation (the paper's FedD motivation):
clients with DIFFERENT architectures interoperate through the logit/
projection exchange — only vocab and LoRA rank are shared contracts."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import LoRAConfig
from repro.configs.gpt2_paper import REDUCED_SERVER
from repro.core import ChannelConfig, ChannelSimulator
from repro.data import make_fed_benchmark_dataset, split_public_private
from repro.fed.client import Client
from repro.fed.server import Server

pytestmark = pytest.mark.slow

VOCAB = 512
LORA = LoRAConfig(rank=8, targets=("q", "v", "head"))


@pytest.fixture(scope="module")
def hetero_round():
    dense = get_smoke_config("yi-9b").with_overrides(
        name="h-dense", vocab_size=VOCAB, lora=LORA, max_seq_len=64)
    ssm = get_smoke_config("mamba2-130m").with_overrides(
        name="h-ssm", vocab_size=VOCAB, lora=LORA, max_seq_len=64)
    moe = get_smoke_config("granite-moe-1b-a400m").with_overrides(
        name="h-moe", vocab_size=VOCAB, lora=LORA, max_seq_len=64)
    ds = make_fed_benchmark_dataset(VOCAB, seed=0, total=600)
    public, private = split_public_private(ds, 96, seed=0)
    clients = [
        Client(i, cfg, private.subset(np.arange(i * 100, (i + 1) * 100)),
               num_classes=77, seed=i, local_steps=1, distill_steps=1)
        for i, cfg in enumerate([dense, ssm, moe])
    ]
    server = Server(REDUCED_SERVER.with_overrides(vocab_size=VOCAB, num_layers=2,
                                                  d_model=128, num_heads=4,
                                                  num_kv_heads=4, d_ff=256,
                                                  lora=LORA),
                    distill_steps=1)
    chan = ChannelSimulator(3, ChannelConfig(), seed=0)
    pub = jnp.asarray(public.tokens[:32])
    ups = []
    for c, st in zip(clients, chan.states(0, [0, 1, 2])):
        c.local_train()
        ups.append(c.upload(pub, st))
    k_g, h_g = server.aggregate_uploads(ups)
    metrics = server.distill(pub, k_g, h_g)
    g_logits, g_h, bits = server.broadcast(pub)
    for c in clients:
        c.local_distill(pub, g_logits, g_h)
    return ups, k_g, h_g, metrics


def test_mixed_families_interoperate(hetero_round):
    ups, k_g, h_g, metrics = hetero_round
    assert k_g.shape == (32, VOCAB)
    assert bool(jnp.all(jnp.isfinite(k_g)))
    assert np.isfinite(metrics["loss"])


def test_projections_align_across_families(hetero_round):
    """h = A·x has the same (batch, rank) shape for every architecture —
    the cross-family exchange contract of paper eq. 8."""
    ups, _, h_g, _ = hetero_round
    for up in ups:
        assert up.h is not None and up.h.shape == (32, LORA.rank)
    assert h_g.shape == (32, LORA.rank)


def test_channel_budgets_differ_per_client(hetero_round):
    ups, _, _, _ = hetero_round
    ks = [u.k for u in ups]
    assert all(1 <= k <= VOCAB for k in ks)
    assert len(set(ks)) > 1  # different fades -> different adaptive k
