"""yi-9b — dense llama-architecture decoder with aggressive GQA (kv=4).

[arXiv:2403.04652] 48 layers, d_model=4096, 32 q heads / 4 kv heads,
d_ff=11008, vocab 64000, RMSNorm + SwiGLU + RoPE, no biases.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11_008,
    vocab_size=64_000,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    microbatches=8,
    max_seq_len=32_768,
    cite="arXiv:2403.04652",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    name="yi-smoke", num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
    d_ff=512, vocab_size=512,
    param_dtype="float32", compute_dtype="float32", remat=False, max_seq_len=256,
)
