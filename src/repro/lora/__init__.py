from repro.lora.lora import is_lora_path, lora_param_count, map_lora, merge_lora, split_lora

__all__ = ["is_lora_path", "lora_param_count", "map_lora", "merge_lora", "split_lora"]
