"""Fault injection, wire validation and HARQ retransmission (PR 8).

The paper's wireless setting assumes every surviving upload arrives intact.
A production federation does not get that luxury: payloads arrive corrupted,
clients die mid-round, and fault episodes cluster in bursts.  This module
makes those failure modes first-class, deterministic and replayable, riding
the same machinery the channel simulator established:

* :class:`FaultConfig` — declarative fault scenario presets (``FAULTS``):
  per-transmission corruption probability, per-(round, client) crash
  probability, and bursty fault episodes driven by the same Gilbert-Elliott
  two-state chain as the channel's outage scenarios.
* :class:`FaultSimulator` — every draw is keyed by ``(seed, domain, round,
  cid)`` exactly like :class:`repro.core.channel.ChannelSimulator`, on
  domains disjoint from the channel's, so fault trajectories are
  deterministic, independent of cohort composition/order, and never perturb
  the channel realisation of a run.  :meth:`FaultSimulator.resolve_round`
  turns one round's attempted uploads into a delivery verdict per client
  (delivered after ``a`` HARQ attempts / quarantined after exhausting
  retries / crashed — upload never arrives), and
  :meth:`FaultSimulator.scan_fault_inputs` exposes the identical draws as
  f32/bool data operands for the multi-round scan path (the per-round
  delivery masks derived from either source are bit-identical —
  parity-tested).
* :func:`validate_wire` / :func:`quarantine_wire` — server-side integrity
  gate on the sparse uplink wire: non-finite values, out-of-range or
  negative indices, and fits-violating byte counts are rejected per client;
  quarantine zeroes the offender's transmit mask, so the EXISTING
  transmit-mask aggregation semantics exclude it (a quarantined client
  looks exactly like a k = 0 straggler to eqs. 6-7).

Crash semantics: a crash models the client dying during TRANSMISSION —
after its local compute (the paper's lines 5-8 already ran on-device) but
before the upload lands, so its local LoRA state still advances while the
server never hears from it.  This keeps crashes pure data masks (one
executable serves faulty and fault-free rounds alike) and is distinct from
the k = 0 "budget afforded nothing" path in the ledger/observability taps:
a crashed client had a nonzero attempted k and a reason of ``"crash"``.

HARQ pricing: every transmission attempt of a payload costs its full
on-air bytes against the SAME Shannon budget that priced the adaptive k —
a client can only retry while the remaining budget affords another full
copy, capped at ``1 + max_retries`` attempts.  Delivered-after-retries
keeps its true k in aggregation but its ledger bytes are
``attempts * payload_bytes``; a client that exhausts retries (or budget)
degrades to k = 0 exclusion with the failed attempts still on the ledger
(the bytes were spent on air even though nothing usable arrived).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.channel import bits_per_entry
from repro.core.scenario import ge_stationary_bad, ge_step

__all__ = [
    "FaultConfig",
    "FAULTS",
    "get_faults",
    "FaultCarry",
    "FaultResolution",
    "FaultSimulator",
    "validate_wire",
    "validate_dense",
    "quarantine_wire",
    "corrupt_wire",
]


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Declarative fault scenario (frozen; presets in :data:`FAULTS`).

    ``corrupt_prob`` is the per-TRANSMISSION corruption probability — each
    HARQ attempt redraws it independently.  ``crash_prob`` is the
    per-(round, client) probability that a selected transmitter dies during
    upload (no bytes land, no retries).  ``max_retries`` caps HARQ
    retransmissions after a corrupted copy (0 = no retransmission: first
    corrupt copy quarantines).  ``burst_enter``/``burst_exit`` enable a
    Gilbert-Elliott episode chain (enter = P(good -> bad), exit =
    P(bad -> good)); while a client is inside an episode its corruption
    probability is ``burst_corrupt_prob`` instead of ``corrupt_prob``.
    """

    name: str = "none"
    corrupt_prob: float = 0.0
    crash_prob: float = 0.0
    max_retries: int = 0
    burst_enter: float | None = None
    burst_exit: float = 0.5
    burst_corrupt_prob: float = 0.9

    def __post_init__(self):
        for field in ("corrupt_prob", "crash_prob", "burst_exit",
                      "burst_corrupt_prob"):
            v = getattr(self, field)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"FaultConfig.{field} must be in [0, 1], got {v}")
        if self.burst_enter is not None and not 0.0 <= self.burst_enter <= 1.0:
            raise ValueError(
                f"FaultConfig.burst_enter must be in [0, 1], got {self.burst_enter}"
            )
        if self.max_retries < 0:
            raise ValueError(
                f"FaultConfig.max_retries must be >= 0, got {self.max_retries}"
            )

    @property
    def enabled(self) -> bool:
        """Whether this config can ever perturb a run (the disabled config
        is the bit-identity contract: a run with ``faults=None`` and one
        with the ``"none"`` preset must be indistinguishable)."""
        return (
            self.corrupt_prob > 0.0
            or self.crash_prob > 0.0
            or (self.burst_enter is not None and self.burst_enter > 0.0)
        )

    @property
    def max_attempts(self) -> int:
        return 1 + self.max_retries


FAULTS: dict[str, FaultConfig] = {
    # bit-identical to faults=None on every engine path (gated in CI)
    "none": FaultConfig(name="none"),
    # i.i.d. per-transmission corruption with HARQ recovery
    "corruption": FaultConfig(name="corruption", corrupt_prob=0.35, max_retries=2),
    # clients die mid-upload; nothing to retry
    "crashes": FaultConfig(name="crashes", crash_prob=0.2),
    # quiet links punctuated by Gilbert-Elliott fault episodes in which
    # most transmissions corrupt (mean episode length 1/burst_exit rounds)
    "bursty": FaultConfig(
        name="bursty", corrupt_prob=0.05, max_retries=1,
        burst_enter=0.15, burst_exit=0.4, burst_corrupt_prob=0.9,
    ),
    # the unreliable-edge kitchen sink: crashes + bursty corruption
    "lossy": FaultConfig(
        name="lossy", corrupt_prob=0.15, crash_prob=0.1, max_retries=1,
        burst_enter=0.1, burst_exit=0.5, burst_corrupt_prob=0.8,
    ),
}


def get_faults(spec: "str | FaultConfig | None") -> FaultConfig | None:
    """Resolve a preset name / config / None (mirrors
    :func:`repro.core.scenario.get_scenario`)."""
    if spec is None:
        return None
    if isinstance(spec, FaultConfig):
        return spec
    if isinstance(spec, str):
        try:
            return FAULTS[spec]
        except KeyError:
            raise ValueError(
                f"unknown fault preset {spec!r}; available: {sorted(FAULTS)}"
            ) from None
    raise TypeError(f"faults spec must be str | FaultConfig | None, got {type(spec)}")


@dataclasses.dataclass(frozen=True)
class FaultCarry:
    """Per-fleet burst-episode state between rounds (pure value, replayed
    contiguously exactly like :class:`repro.core.channel.ChannelCarry`)."""

    round_index: int  # the round this carry has evolved THROUGH (-1 = init)
    burst: np.ndarray  # (N,) bool — inside a fault episode


@dataclasses.dataclass(frozen=True)
class FaultResolution:
    """One round's delivery verdict for a cohort (cohort order).

    ``delivered[i]`` — the upload landed intact (possibly after HARQ
    retries).  ``attempts[i]`` — transmissions actually made (0 for a crash
    or a k = 0 non-transmitter; >= 1 otherwise).  ``reasons[i]`` — ``None``
    for delivered clients and k = 0 non-transmitters, ``"crash"`` /
    ``"corrupt"`` for lost uploads.
    """

    delivered: list[bool]
    attempts: list[int]
    reasons: list[str | None]

    @property
    def num_crashed(self) -> int:
        return sum(1 for r in self.reasons if r == "crash")

    @property
    def num_quarantined(self) -> int:
        return sum(1 for r in self.reasons if r == "corrupt")


class FaultSimulator:
    """Deterministic per-round fault realisation for N clients.

    Every draw is keyed ``(seed, domain, round, cid)`` on stream domains
    disjoint from :class:`repro.core.channel.ChannelSimulator`'s (7-10), so
    enabling faults never perturbs a run's channel realisation, two
    simulators with the same seed agree draw-for-draw, and a client's fault
    trajectory is independent of which other clients were selected and of
    query order.  Uniforms are cast to f32 AT DRAW TIME so the host
    resolution and the scan-operand path (:meth:`scan_fault_inputs`)
    compare bit-identically.
    """

    _CRASH_DOMAIN = 21
    _CORRUPT_DOMAIN = 22
    _BURST_INIT_DOMAIN = 23
    _BURST_DOMAIN = 24

    def __init__(
        self, num_clients: int, config: FaultConfig | None = None, *, seed: int = 0
    ):
        self.num_clients = int(num_clients)
        self.config = config or FaultConfig()
        self.seed = int(seed)
        self._carry: FaultCarry | None = None
        # contiguous replay cache: (crash_u (N,), corrupt_u (N, A), burst (N,))
        self._realised: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []

    def _stream(self, domain: int, round_index: int, cid: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence(
                entropy=self.seed, spawn_key=(domain, int(round_index), int(cid))
            )
        )

    # -- burst-episode dynamics: pure carry API ---------------------------

    def init_fault_carry(self) -> FaultCarry:
        """Fleet episode state BEFORE round 0 (Gilbert-Elliott stationary
        start, own stream domain)."""
        cfg = self.config
        burst = np.zeros(self.num_clients, dtype=bool)
        if cfg.burst_enter is not None and cfg.burst_enter > 0.0:
            pi_bad = ge_stationary_bad(cfg.burst_enter, cfg.burst_exit)
            if pi_bad > 0.0:
                burst = np.array([
                    self._stream(self._BURST_INIT_DOMAIN, 0, cid).random() < pi_bad
                    for cid in range(self.num_clients)
                ])
        return FaultCarry(round_index=-1, burst=burst)

    def step_faults(
        self, carry: FaultCarry, round_index: int
    ) -> tuple[FaultCarry, np.ndarray, np.ndarray, np.ndarray]:
        """Advance the fleet's fault state through one round (pure).

        Returns ``(carry', crash_u, corrupt_u, burst)`` — the f32 crash
        uniforms ``(N,)``, the f32 HARQ-attempt corruption uniforms
        ``(N, 1 + max_retries)`` and the bool episode states ``(N,)`` for
        ``round_index``.  Must be stepped contiguously (the episode chain is
        Markov); random access goes through the replay cache.
        """
        if round_index != carry.round_index + 1:
            raise ValueError(
                f"step_faults must advance contiguously: carry is at round "
                f"{carry.round_index}, got round_index {round_index}"
            )
        cfg = self.config
        n = self.num_clients
        burst = carry.burst
        if cfg.burst_enter is not None and cfg.burst_enter > 0.0:
            u = np.array([
                self._stream(self._BURST_DOMAIN, round_index, cid).random()
                for cid in range(n)
            ])
            burst = ge_step(carry.burst, u, cfg.burst_enter, cfg.burst_exit)
        crash_u = np.array([
            self._stream(self._CRASH_DOMAIN, round_index, cid).random()
            for cid in range(n)
        ], dtype=np.float32)
        corrupt_u = np.array([
            self._stream(self._CORRUPT_DOMAIN, round_index, cid).random(
                cfg.max_attempts
            )
            for cid in range(n)
        ], dtype=np.float32)
        return (
            FaultCarry(round_index=round_index, burst=burst),
            crash_u, corrupt_u, burst.copy(),
        )

    def _ensure_realised(self, round_index: int) -> None:
        if self._carry is None:
            self._carry = self.init_fault_carry()
        while len(self._realised) <= round_index:
            self._carry, crash_u, corrupt_u, burst = self.step_faults(
                self._carry, len(self._realised)
            )
            self._realised.append((crash_u, corrupt_u, burst))

    # -- delivery resolution ----------------------------------------------

    @staticmethod
    def _resolve_one(
        cfg: FaultConfig,
        crash_u: float,
        corrupt_u: np.ndarray,
        burst: bool,
        k: int,
        payload_bits: float,
        budget_bits: float,
    ) -> tuple[bool, int, str | None]:
        """One client's verdict from its round draws (shared by the host
        per-round path and the scan-operand path, so they cannot diverge)."""
        if k <= 0:
            return False, 0, None  # never transmitted; not a fault
        if np.float32(crash_u) < np.float32(cfg.crash_prob):
            return False, 0, "crash"
        p = cfg.burst_corrupt_prob if burst else cfg.corrupt_prob
        p = np.float32(p)
        if payload_bits <= 0.0:
            return True, 1, None
        # each HARQ attempt re-spends the full payload against the SAME
        # Shannon budget; the first copy fits by construction
        affordable = max(1, int(math.floor(budget_bits / payload_bits)))
        allowed = min(cfg.max_attempts, affordable)
        for a in range(allowed):
            if not np.float32(corrupt_u[a]) < p:
                return True, a + 1, None
        return False, allowed, "corrupt"

    def resolve_round(
        self,
        round_index: int,
        client_ids: Sequence[int],
        ks: Sequence[int],
        payload_bits: Sequence[float],
        budget_bits: Sequence[float],
    ) -> FaultResolution:
        """Resolve one round's deliveries for a cohort.

        ``ks``/``payload_bits``/``budget_bits`` are the cohort's ATTEMPTED
        adaptive k, the priced on-air bits of one payload copy, and the
        Shannon bit budget — all in cohort order.  The verdict for a client
        depends only on ``(seed, round, cid)`` and its own scalars, so it is
        invariant under cohort permutation and composition.
        """
        if round_index < 0:
            raise ValueError(f"round_index must be >= 0, got {round_index}")
        self._ensure_realised(round_index)
        crash_u, corrupt_u, burst = self._realised[round_index]
        delivered, attempts, reasons = [], [], []
        for i, cid in enumerate(client_ids):
            cid = int(cid)
            if not 0 <= cid < self.num_clients:
                raise ValueError(
                    f"fault streams track per-fleet state: client_ids must "
                    f"be in [0, {self.num_clients}), got {cid}"
                )
            d, a, r = self._resolve_one(
                self.config, float(crash_u[cid]), corrupt_u[cid],
                bool(burst[cid]), int(ks[i]),
                float(payload_bits[i]), float(budget_bits[i]),
            )
            delivered.append(d)
            attempts.append(a)
            reasons.append(r)
        return FaultResolution(delivered=delivered, attempts=attempts, reasons=reasons)

    # -- scan data operands -----------------------------------------------

    def scan_fault_inputs(self, num_rounds: int, *, start_round: int = 0) -> dict:
        """Host-precomputed fault draws for a multi-round block as f32/bool
        DATA operands (the fault analogue of
        :meth:`repro.core.channel.ChannelSimulator.scan_channel_inputs`).

        The arrays come from the very replay cache
        :meth:`resolve_round` consumes, so delivery masks derived from
        these operands (:meth:`resolve_from_inputs`) are bit-identical to
        the per-round host path — which is what lets the multi-round scan
        drivers consume faults as pure int32 ``k`` data masks (a
        non-delivered client rides the scan at k = 0, the same operand
        shape that already serves stragglers and shard padding).
        """
        if num_rounds < 0 or start_round < 0:
            raise ValueError("num_rounds and start_round must be >= 0")
        cfg = self.config
        n, a = self.num_clients, cfg.max_attempts
        crash = np.zeros((num_rounds, n), dtype=np.float32)
        corrupt = np.zeros((num_rounds, n, a), dtype=np.float32)
        burst = np.zeros((num_rounds, n), dtype=bool)
        if num_rounds:
            self._ensure_realised(start_round + num_rounds - 1)
        for r in range(num_rounds):
            cu, ou, bu = self._realised[start_round + r]
            crash[r], corrupt[r], burst[r] = cu, ou, bu
        return {
            "crash_u": crash,
            "corrupt_u": corrupt,
            "burst": burst,
            "crash_prob": np.float32(cfg.crash_prob),
            "corrupt_prob": np.float32(cfg.corrupt_prob),
            "burst_corrupt_prob": np.float32(cfg.burst_corrupt_prob),
            "max_retries": np.int32(cfg.max_retries),
        }

    def resolve_from_inputs(
        self,
        inputs: dict,
        round_offset: int,
        client_ids: Sequence[int],
        ks: Sequence[int],
        payload_bits: Sequence[float],
        budget_bits: Sequence[float],
    ) -> FaultResolution:
        """The scan-operand twin of :meth:`resolve_round`: same verdicts,
        sourced from a :meth:`scan_fault_inputs` dict instead of the stream
        cache (parity-tested bit-identical)."""
        crash_u = inputs["crash_u"][round_offset]
        corrupt_u = inputs["corrupt_u"][round_offset]
        burst = inputs["burst"][round_offset]
        delivered, attempts, reasons = [], [], []
        for i, cid in enumerate(client_ids):
            d, a, r = self._resolve_one(
                self.config, float(crash_u[int(cid)]), corrupt_u[int(cid)],
                bool(burst[int(cid)]), int(ks[i]),
                float(payload_bits[i]), float(budget_bits[i]),
            )
            delivered.append(d)
            attempts.append(a)
            reasons.append(r)
        return FaultResolution(delivered=delivered, attempts=attempts, reasons=reasons)


# -- server-side wire validation / quarantine -----------------------------


def validate_wire(
    wire,
    *,
    value_bits: int = 16,
    budget_bits: Sequence[float] | None = None,
    reserved_bits: float = 0.0,
) -> tuple[np.ndarray, list[str | None]]:
    """Server-side integrity gate on a sparse uplink wire
    (:class:`repro.core.topk.SparseWire` or ``QuantizedWire``).

    Per client row ``n``, reject when any MASKED-IN entry carries a
    non-finite value (``"non_finite"``; for the int8 wire the check applies
    to the f32 dequant scales of active rows), an index outside
    ``[0, vocab)`` (``"index_range"``), or — when ``budget_bits`` is given —
    when the claimed transmitted entries plus ``reserved_bits`` price above
    the client's Shannon budget at ``value_bits`` per value
    (``"over_budget"``: a fits-violating byte count; honest payloads
    satisfy ``PayloadSpec.fits`` by construction).  A client whose mask is
    all-False transmits nothing and is vacuously valid.

    Returns ``(ok (N,) bool, reasons)`` with ``reasons[n]`` the FIRST
    violated check or None.
    """
    indices = np.asarray(wire.indices)
    mask = np.asarray(wire.mask)
    vocab = int(wire.vocab)
    n = indices.shape[0]
    ok = np.ones(n, dtype=bool)
    reasons: list[str | None] = [None] * n
    flat_mask = mask.reshape(n, -1)
    flat_idx = indices.reshape(n, -1)
    values = np.asarray(wire.values)
    is_quant = values.dtype == np.int8
    flat_scale = np.asarray(wire.scale).reshape(n, -1) if is_quant else None
    flat_values = values.reshape(n, -1)
    d = bits_per_entry(value_bits, vocab)
    for i in range(n):
        m = flat_mask[i]
        if not m.any():
            continue  # nothing transmitted (k = 0 straggler row)
        if is_quant:
            finite = np.isfinite(flat_scale[i]).all()
        else:
            finite = np.isfinite(flat_values[i][m]).all()
        if not finite:
            ok[i], reasons[i] = False, "non_finite"
            continue
        masked_idx = flat_idx[i][m]
        if masked_idx.min() < 0 or masked_idx.max() >= vocab:
            ok[i], reasons[i] = False, "index_range"
            continue
        if budget_bits is not None:
            bits = float(m.sum()) * d + float(reserved_bits)
            if bits > float(budget_bits[i]) + 1e-6:
                ok[i], reasons[i] = False, "over_budget"
    return ok, reasons


def validate_dense(
    stack, h_stack=None
) -> tuple[np.ndarray, list[str | None]]:
    """The densified-path twin of :func:`validate_wire`: per-client finite
    check on an (N, P, V) upload stack (+ optional (N, P, r) projections).
    The dense form has no index/byte channel to violate, so the only
    reachable reason is ``"non_finite"`` — e.g. a client whose local
    training diverged to NaN logits gets quarantined instead of poisoning
    the eq. 6-7 aggregation."""
    arr = np.asarray(stack)
    n = arr.shape[0]
    ok = np.isfinite(arr.reshape(n, -1)).all(axis=1)
    if h_stack is not None:
        h = np.asarray(h_stack)
        ok &= np.isfinite(h.reshape(n, -1)).all(axis=1)
    return ok, [None if o else "non_finite" for o in ok]


def quarantine_wire(wire, ok: np.ndarray):
    """Exclude rejected clients from aggregation through the EXISTING
    transmit-mask pattern: a quarantined row's mask goes all-False, which is
    exactly the representation of a k = 0 straggler — eqs. 6-7 then weight
    it out without any new aggregation semantics.

    The payload CONTENTS are scrubbed too (values/indices to 0, dequant
    scales to 1.0): masked-out entries are weighted by ``values * mask``
    in the scatter path, and ``NaN * 0 == NaN`` would leak a corrupted
    value straight through an all-False mask."""
    import jax.numpy as jnp

    keep = np.asarray(ok, dtype=bool)
    mask = np.asarray(wire.mask).copy()
    values = np.asarray(wire.values).copy()
    indices = np.asarray(wire.indices).copy()
    mask[~keep] = False
    values[~keep] = 0
    indices[~keep] = 0
    fields = dict(
        mask=jnp.asarray(mask),
        values=jnp.asarray(values),
        indices=jnp.asarray(indices),
    )
    if hasattr(wire, "scale"):
        scale = np.asarray(wire.scale).copy()
        scale[~keep] = 1.0
        fields["scale"] = jnp.asarray(scale)
    return wire._replace(**fields)


def corrupt_wire(wire, rows: Sequence[int], mode: str = "nan"):
    """Test/bench fault injector: corrupt the given client rows of a wire
    in-place-shaped (returns a new wire).  ``mode`` is ``"nan"`` (a masked
    value — or dequant scale — becomes NaN), ``"index"`` (an index leaves
    ``[0, vocab)``), or ``"negative_index"``."""
    import jax.numpy as jnp

    values = np.asarray(wire.values).copy()
    indices = np.asarray(wire.indices).copy()
    out = {}
    for r in rows:
        if mode == "nan":
            if values.dtype == np.int8:
                scale = out.get("scale", np.asarray(wire.scale).copy())
                scale.reshape(scale.shape[0], -1)[r, 0] = np.nan
                out["scale"] = scale
            else:
                values.reshape(values.shape[0], -1)[r, 0] = np.nan
                out["values"] = values
        elif mode == "index":
            indices.reshape(indices.shape[0], -1)[r, 0] = wire.vocab
            out["indices"] = indices
        elif mode == "negative_index":
            indices.reshape(indices.shape[0], -1)[r, 0] = -1
            out["indices"] = indices
        else:
            raise ValueError(f"unknown corrupt_wire mode {mode!r}")
    return wire._replace(**{k: jnp.asarray(v) for k, v in out.items()})
