"""Sharding rules: rank consistency + production-mesh divisibility for every
full-size config (pure spec math, no 512 devices needed)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding as sh
from repro.configs import ARCHITECTURES, INPUT_SHAPES, get_config, get_smoke_config
from repro.models import init, init_cache

FULL_ARCHS = [a for a in ARCHITECTURES if a != "gpt2-paper"]


class FakeMesh:
    """Just enough Mesh interface for spec derivation (axis_names/shape)."""

    def __init__(self, shape_by_axis):
        self.axis_names = tuple(shape_by_axis)
        self.shape = dict(shape_by_axis)


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for e in entry:
            n *= mesh.shape[e]
        return n
    return mesh.shape[entry]


@pytest.mark.parametrize("arch", FULL_ARCHS)
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single_pod", "multi_pod"])
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: init(k, cfg), jax.random.PRNGKey(0))
    specs = sh.param_specs(shapes, mesh)

    def check(path, leaf, spec):
        assert isinstance(spec, P)
        assert len(spec) <= len(leaf.shape), (path, leaf.shape, spec)
        for dim, entry in zip(leaf.shape, tuple(spec)):
            size = _axis_size(mesh, entry)
            assert dim % size == 0, (
                f"{arch}: {jax.tree_util.keystr(path)} dim {dim} not divisible "
                f"by {size} ({entry})"
            )

    jax.tree_util.tree_map_with_path(check, shapes, specs)


@pytest.mark.parametrize("arch", ["command-r-35b", "jamba-1.5-large-398b", "mamba2-130m"])
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, shape_name):
    from repro.launch.policy import window_for

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    w = window_for(cfg, shape)
    cache_shape = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len, window=w)
    )
    shardable = shape.global_batch % 16 == 0
    specs = sh.cache_specs(cache_shape, SINGLE, batch_shardable=shardable)

    def check(path, leaf, spec):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            size = _axis_size(SINGLE, entry)
            assert dim % size == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(check, cache_shape, specs)


def test_batch_axes_by_mesh():
    assert sh.batch_axes(SINGLE) == ("data",)
    assert sh.batch_axes(MULTI) == ("pod", "data")


def test_cohort_mesh_covers_all_devices():
    """The federated engines' cohort placement: a 1-D mesh over every
    addressable device under the shared COHORT_AXIS name (the axis contract
    of the fused/fused-e2e shard_map placements)."""
    mesh = sh.cohort_mesh()
    assert mesh.axis_names == (sh.COHORT_AXIS,)
    assert mesh.shape[sh.COHORT_AXIS] == jax.device_count()
    from repro.launch.mesh import make_client_mesh

    assert make_client_mesh().shape == mesh.shape


def test_embed_is_vocab_sharded():
    cfg = get_config("command-r-35b")
    shapes = jax.eval_shape(lambda k: init(k, cfg), jax.random.PRNGKey(0))
    specs = sh.param_specs(shapes, SINGLE)
    assert tuple(specs["embed"])[0] == "model"  # 256k vocab split 16 ways


def test_moe_experts_sharded():
    cfg = get_config("moonshot-v1-16b-a3b")
    shapes = jax.eval_shape(lambda k: init(k, cfg), jax.random.PRNGKey(0))
    specs = sh.param_specs(shapes, SINGLE)
    up_spec = specs["stack"]["pos0"]["mlp"]["up"]
    assert tuple(up_spec)[:2] == (None, "model")  # (layer-stack, experts, ...)


@pytest.mark.parametrize("arch", FULL_ARCHS)
def test_smoke_configs_are_reduced(arch):
    smoke = get_smoke_config(arch)
    assert smoke.num_layers <= 4
    assert smoke.d_model <= 512
    if smoke.moe:
        assert smoke.moe.num_experts <= 4
