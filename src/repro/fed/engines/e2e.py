"""The whole-round (client + server phase) single-executable engine."""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.channel import BatchedChannelState, ChannelState
from repro.core.topk import QuantizedWire, SparseWire
from repro.fed import steps as fed_steps
from repro.fed.client import Client
from repro.fed.engines.base import (
    BroadcastState,
    ClientPhase,
    RoundsTrajectory,
    _channel_scan_ops,
    _ServerOwnerMixin,
    check_unique_cohort,
    k_cap_bucket,
)
from repro.fed.engines.fused import FusedEngine
from repro.fed.store import FleetStore

__all__ = ["FusedE2EEngine"]


class FusedE2EEngine(_ServerOwnerMixin, FusedEngine):
    """Whole-round single-executable engine: client phase AND server phase
    (adaptive aggregation, server distillation, broadcast recomputation) as
    ONE donated, compiled call per round — and the uplink crosses the
    engine/server boundary as the sparse wire format ``(values, indices,
    transmit mask)`` of width ``k_cap`` instead of a densified ``(C, P, V)``
    stack, so the aggregation working set is O(C·P·k_cap).

    The engine owns the server LLM's state for the duration of the run
    (pulled from the :class:`repro.fed.server.Server` at construction);
    :meth:`sync_server` writes the merged parameters back for evaluation,
    and :meth:`broadcast_state` exposes the in-program-computed broadcast to
    the round loop.  Cold-server round 0 and all-dropped rounds are DATA
    (masks) inside the executable, not Python control flow, so one
    executable serves every round of a run (per power-of-two ``k_cap``
    bucket — see :func:`k_cap_bucket`).

    ``shard_clients=True`` places the client phase's cohort axis over the
    process's devices INSIDE the compiled round body (``shard_map`` in
    :func:`repro.fed.steps.make_fused_e2e_round_fn`); the server phase stays
    replicated.  Cohorts that do not divide the device count are padded with
    masked ``k = 0`` duplicate rows exactly like the fused client-phase
    engine — the pad transmits nothing, is excluded from aggregation by its
    all-False wire mask, and its advanced state is discarded before the
    scatter-back.

    :meth:`run_rounds` additionally scans R whole rounds inside one
    compiled call (steady-state dispatch fully amortised) and taps each
    round's server/client accuracy, server-distill loss and mean adaptive
    ``k`` as scanned outputs — a full :class:`RoundsTrajectory` instead of a
    blind block.  The scan carries the WHOLE fleet stack as a donated
    device operand, so it requires the device fleet store; a host store
    (O(cohort) device residency) runs the per-round driver instead.
    """

    name = "fused_e2e"
    handles_server = True

    def __init__(
        self,
        clients: list[Client],
        cfg: ModelConfig,
        *,
        server,
        num_classes: int,
        lr: float = 1e-3,
        distill_lr: float = 1e-3,
        temperature: float = 2.0,
        lam: float = 0.03,
        local_steps: int = 4,
        distill_steps: int = 2,
        server_distill_steps: int = 12,
        aggregation: str = "adaptive",
        restrict_to_support: bool = False,
        value_bits: int = 16,
        k_min: int = 1,
        last_only: bool = True,
        shard_clients: bool = False,
        use_kernels: bool = False,
        quantize_wire: bool = False,
        compute_dtype: str = "float32",
        fleet_store: "str | FleetStore" = "device",
    ):
        super().__init__(
            clients, cfg, num_classes=num_classes, lr=lr, distill_lr=distill_lr,
            temperature=temperature, lam=lam, local_steps=local_steps,
            distill_steps=distill_steps, restrict_to_support=restrict_to_support,
            value_bits=value_bits, k_min=k_min, last_only=last_only,
            use_kernels=use_kernels, quantize_wire=quantize_wire,
            compute_dtype=compute_dtype, fleet_store=fleet_store,
        )
        self.shard_clients = shard_clients
        self._fn_kwargs = dict(
            lr=lr, distill_lr=distill_lr, temperature=temperature, lam=lam,
            restrict_to_support=restrict_to_support, local_steps=local_steps,
            distill_steps=distill_steps,
            server_distill_steps=server_distill_steps,
            aggregation=aggregation, shared_backbone=self._shared,
            last_only=last_only, use_kernels=use_kernels,
            shard_clients=shard_clients, quantize=quantize_wire,
            compute_dtype=compute_dtype,
        )
        self._num_classes = num_classes
        self._init_server_state(server)
        self._steps: dict = {}
        self._drivers: dict = {}

    # -- compiled-step caches -------------------------------------------
    def _e2e_fn(self, k_cap: int, send_h: bool):
        """The unjitted whole-round body for one (k_cap, send_h) bucket."""
        return fed_steps.make_fused_e2e_round_fn(
            self.cfg, self.server.cfg, self._num_classes,
            k_cap=k_cap, send_h=send_h, **self._fn_kwargs,
        )

    def _e2e_step(self, k_cap: int, send_h: bool):
        key = (k_cap, send_h)
        if key not in self._steps:
            self._steps[key] = jax.jit(
                self._e2e_fn(k_cap, send_h), donate_argnums=(0, 2, 3, 5)
            )
        return self._steps[key]

    # -- single whole round: ONE compiled call ---------------------------
    def run_round(
        self,
        sel: Sequence[int],
        pub_tokens: jax.Array,
        bcast: BroadcastState | None,
        states: BatchedChannelState | Sequence[ChannelState],
        *,
        adaptive_k: bool,
        send_h: bool,
    ) -> ClientPhase:
        sel = check_unique_cohort(sel)
        cohort = [self.clients[i] for i in sel]
        states = list(states)
        batches = self._stacked_batches(cohort, step_major=False)
        pad, sel_call, batches = self._pad_cohort(sel, batches)
        idx, lora, frozen, opt = self._gather_cohort(sel_call)
        n_samples = int(pub_tokens.shape[0])
        ks = self._budgets(states, n_samples, adaptive_k, len(cohort), send_h)
        k_cap = k_cap_bucket(ks, self.cfg.vocab_size)

        if bcast is not None:
            g_tokens, g_logits, g_h = bcast.tokens, bcast.logits, bcast.h
            g_valid = True
        else:
            g_tokens, g_logits, g_h = self._cold_broadcast(pub_tokens, n_samples)
            g_valid = False

        step = self._e2e_step(k_cap, send_h)
        (lora, opt, self._s_lora, self._s_opt,
         values, indices, scale, b_logits, b_h, self._d_loss) = step(
            lora, frozen, opt, self._s_lora, self._s_frozen, self._s_opt,
            g_tokens, g_logits, g_h, jnp.asarray(g_valid),
            batches, pub_tokens, jnp.asarray(ks + [0] * pad, jnp.int32),
        )
        if pad:  # drop the padded rows before anything observes them
            lora, opt, values, indices, scale, idx = self._drop_pad(
                len(cohort), lora, opt, values, indices, scale, idx
            )
        self._b_tokens, self._b_logits, self._b_h = pub_tokens, b_logits, b_h

        active, payloads, _rank = self._upload_manifests(
            cohort, states, ks, n_samples, send_h
        )
        sparse = None
        if active:
            take = jnp.asarray(active)
            ks_active = jnp.asarray([ks[i] for i in active], jnp.int32)
            mask = (
                jnp.arange(k_cap, dtype=jnp.int32)[None, None, :]
                < ks_active[:, None, None]
            )
            mask = jnp.broadcast_to(mask, values[take].shape)
            if self.quantize_wire:
                sparse = QuantizedWire(
                    values=values[take], scale=scale[take],
                    indices=indices[take], mask=mask,
                    vocab=self.cfg.vocab_size,
                )
            else:
                sparse = SparseWire(
                    values=values[take], indices=indices[take], mask=mask,
                    vocab=self.cfg.vocab_size,
                )

        self._scatter_cohort(idx, lora, opt)
        return ClientPhase(dense=None, h=None, payloads=payloads, ks=ks, sparse=sparse)

    # -- multi-round scan driver ------------------------------------------
    def _rounds_driver(
        self, k_cap: int, send_h: bool, num_rounds: int, n_real: int,
        has_eval: bool, has_chan: bool,
    ):
        key = (k_cap, send_h, num_rounds, n_real, has_eval, has_chan)
        if key in self._drivers:
            return self._drivers[key]
        fn = self._e2e_fn(k_cap, send_h)
        has_h = self.server.cfg.lora is not None
        # in-scan channel replica: scenario dynamics as f32 data, so the
        # same executable serves every preset (rho=0 == i.i.d.)
        chan_step = fed_steps.make_channel_step_fn() if has_chan else None
        # in-scan eval tap: same last-position class-logit accuracy as the
        # host-side make_eval_fn, traced into the scanned round program
        server_eval = fed_steps.make_scan_eval_fn(
            self.server.cfg, self._num_classes, last_only=self.last_only
        )
        client_eval = fed_steps.make_scan_eval_fn(
            self.cfg, self._num_classes, last_only=self.last_only
        )

        shared = self._shared

        def driver(fleet_lora, fleet_opt, s_lora, s_opt, frozen, s_frozen,
                   g_tokens, g_logits, g_h, g_valid, sels, kss, pubs, batches,
                   chan, *eval_args):
            if has_chan:
                ch_z0, ch_bad0, ch_w, ch_u, ch_base, rho, p_gb, p_bg, fade = chan

            def body(carry, xs):
                (fleet_lora, fleet_opt, s_lora, s_opt,
                 g_tokens, g_logits, g_h, g_valid, ch_state) = carry
                sel, ks, pub, bat, ch_xs = xs
                lora = jax.tree.map(lambda x: x[sel], fleet_lora)
                opt = jax.tree.map(lambda x: x[sel], fleet_opt)
                # one shared W' broadcasts into the cohort; per-client
                # backbones are fleet-stacked and gather their cohort rows
                # exactly like the LoRA/opt state (frozen_ax=0 downstream)
                frz = frozen if shared else jax.tree.map(lambda x: x[sel], frozen)
                lora, opt, s_lora, s_opt, _v, _i, _sc, b_logits, b_h, d_loss = fn(
                    lora, frz, opt, s_lora, s_frozen, s_opt,
                    g_tokens, g_logits, g_h if has_h else None, g_valid,
                    bat, pub, ks,
                )
                # drop the shard-padding rows (duplicates of sel[0]) BEFORE
                # the scatter-back: .at[sel].set with duplicate indices has
                # unspecified ordering, and the pad's advanced state must
                # never be observed anyway
                lora, opt = self._drop_pad(n_real, lora, opt)
                sel_real = sel[:n_real]
                fleet_lora = jax.tree.map(
                    lambda full, new: full.at[sel_real].set(new), fleet_lora, lora
                )
                fleet_opt = jax.tree.map(
                    lambda full, new: full.at[sel_real].set(new), fleet_opt, opt
                )
                # -- the eval tap: this round's trajectory entry ----------
                tap = {
                    "distill_loss": d_loss,
                    "mean_k": jnp.mean(ks[:n_real].astype(jnp.float32)),
                }
                if has_eval:
                    ev_tokens, ev_labels = eval_args
                    tap["server_acc"] = server_eval(
                        s_lora, s_frozen, ev_tokens, ev_labels
                    )
                    tap["client_acc"] = client_eval(
                        jax.tree.map(lambda x: x[0], lora),
                        frz if shared else jax.tree.map(lambda x: x[0], frz),
                        ev_tokens, ev_labels,
                    )
                if has_chan:
                    # channel state advances as scan carry; the realised
                    # cohort SNR/outage are tapped as scanned outputs
                    ch_z, ch_bad = ch_state
                    w_t, u_t, base_t = ch_xs
                    ch_z, ch_bad, snr = chan_step(
                        ch_z, ch_bad, w_t, u_t, base_t, rho, p_gb, p_bg, fade
                    )
                    ch_state = (ch_z, ch_bad)
                    tap["snr_db"] = snr[sel[:n_real]]
                    tap["outage"] = ch_bad[sel[:n_real]]
                carry = (
                    fleet_lora, fleet_opt, s_lora, s_opt,
                    pub, b_logits, b_h if has_h else g_h, jnp.ones((), bool),
                    ch_state,
                )
                return carry, tap

            ch_state0 = (ch_z0, ch_bad0) if has_chan else ()
            ch_xs_all = (ch_w, ch_u, ch_base) if has_chan else ()
            carry, taps = jax.lax.scan(
                body,
                (fleet_lora, fleet_opt, s_lora, s_opt,
                 g_tokens, g_logits, g_h, g_valid, ch_state0),
                (sels, kss, pubs, batches, ch_xs_all),
                length=num_rounds,
            )
            return carry, taps

        jitted = jax.jit(driver, donate_argnums=(0, 1, 2, 3))
        self._drivers[key] = jitted
        return jitted

    def run_rounds(
        self,
        sels: Sequence[Sequence[int]],
        pubs: Sequence[jax.Array],
        states_per_round: Sequence,
        *,
        adaptive_k: bool,
        send_h: bool,
        eval_tokens: jax.Array | None = None,
        eval_labels: jax.Array | None = None,
        channel_scan: dict | None = None,
    ) -> "RoundsTrajectory":
        """Run R whole federated rounds as ONE compiled ``lax.scan`` — the
        steady-state amortised driver (dispatch cost O(1) for the block).

        ``channel_scan`` (a :meth:`ChannelSimulator.scan_channel_inputs`
        dict) additionally evolves the scenario channel state — AR(1)
        fading ``z``, Gilbert-Elliott outage — INSIDE the scan as carry,
        with every dynamics parameter an f32 data operand: one executable
        serves all scenario presets (``rho = 0`` replays i.i.d.).  The
        per-round realised cohort SNR/outage come back as scanned outputs
        (``RoundsTrajectory.snr_db``/``outage``); budgets stay host-side
        scalar math, priced from the same (seed, round, cid)-keyed chain.

        Per-round cohort selection/channel budgets stay host-side scalar
        math (ledger parity with the round-at-a-time path); the per-round
        observables — server/client accuracy on the given eval arrays, the
        server-distill loss, the mean adaptive ``k`` — are tapped INSIDE the
        scan as scanned outputs, so the block returns a full
        :class:`RoundsTrajectory` instead of running blind.
        Fleet/server/broadcast state advance in place exactly as R
        ``run_round`` calls would.

        ``eval_tokens``/``eval_labels`` (omit both to skip the accuracy tap)
        are evaluated after each round on the server model and on the
        round's first selected client — the same models the host loop's
        per-round evaluation reads.  The split is truncated to whole
        :data:`repro.fed.steps.EVAL_BATCH` batches exactly like the
        host-side evaluator (so the tap and ``make_eval_fn`` read the same
        samples); a split smaller than one batch is rejected.
        """
        if self.store_kind != "device":
            raise RuntimeError(
                "run_rounds scans the WHOLE fleet stack as a donated device "
                "carry, which only fleet_store='device' provides; a host "
                f"store (store_kind={self.store_kind!r}) keeps O(cohort) "
                "device residency — drive rounds one at a time with "
                "run_round instead (rounds.py falls back automatically)"
            )
        sels = [check_unique_cohort(sel) for sel in sels]
        if (eval_tokens is None) != (eval_labels is None):
            raise ValueError("pass eval_tokens and eval_labels together")
        has_eval = eval_tokens is not None
        has_chan = channel_scan is not None
        num_rounds = len(sels)
        if num_rounds == 0:  # degenerate no-op, like zero host-loop rounds
            return RoundsTrajectory(
                ks=[], payloads=[], mean_k=[], distill_loss=[],
                server_acc=[] if has_eval else None,
                client_acc=[] if has_eval else None,
                snr_db=[] if has_chan else None,
                outage=[] if has_chan else None,
            )
        n_samples = int(pubs[0].shape[0])
        n_real = len(sels[0])
        if any(len(sel) != n_real for sel in sels):
            raise ValueError("run_rounds requires equal-size cohorts")

        pad = 0
        all_ks, all_payloads, batch_list, sels_call = [], [], [], []
        for sel, states in zip(sels, states_per_round):
            cohort = [self.clients[i] for i in sel]
            states = list(states)
            ks = self._budgets(states, n_samples, adaptive_k, len(cohort), send_h)
            _active, payloads, _rank = self._upload_manifests(
                cohort, states, ks, n_samples, send_h
            )
            all_ks.append(ks)
            all_payloads.append(payloads)
            batch = self._stacked_batches(cohort, step_major=False)
            pad, sel_call, batch = self._pad_cohort(sel, batch)
            batch_list.append(batch)
            sels_call.append(sel_call)
        k_cap = k_cap_bucket([k for ks in all_ks for k in ks], self.cfg.vocab_size)

        sels_arr = jnp.asarray(np.asarray(sels_call), jnp.int32)  # (R, C+pad)
        kss_arr = jnp.asarray(  # (R, C+pad); pad rows transmit nothing
            np.asarray([ks + [0] * pad for ks in all_ks]), jnp.int32
        )
        pubs_arr = jnp.stack([jnp.asarray(p) for p in pubs])  # (R, P, L)
        batches = jax.tree.map(lambda *xs: jnp.stack(xs), *batch_list)

        if self._b_logits is not None:
            g_tokens, g_logits, g_h = self._b_tokens, self._b_logits, self._b_h
            g_valid = True
        else:
            g_tokens, g_logits, g_h = self._cold_broadcast(pubs_arr[0], n_samples)
            g_valid = False

        eval_args = ()
        if has_eval:
            # whole EVAL_BATCH batches only — the host evaluator's walk, and
            # the precondition of make_scan_eval_fn's bounded-memory chunking
            seen = (
                int(eval_tokens.shape[0]) // fed_steps.EVAL_BATCH
            ) * fed_steps.EVAL_BATCH
            if seen == 0:
                raise ValueError(
                    f"eval split of {int(eval_tokens.shape[0])} samples is "
                    f"smaller than one eval batch ({fed_steps.EVAL_BATCH})"
                )
            eval_args = (
                jnp.asarray(eval_tokens[:seen]), jnp.asarray(eval_labels[:seen])
            )
        chan_ops = _channel_scan_ops(channel_scan, num_rounds) if has_chan else ()
        driver = self._rounds_driver(
            k_cap, send_h, num_rounds, n_real, has_eval, has_chan
        )
        carry, taps = driver(
            self._lora, self._opt, self._s_lora, self._s_opt,
            self._frozen, self._s_frozen,
            g_tokens, g_logits, g_h, jnp.asarray(g_valid),
            sels_arr, kss_arr, pubs_arr, batches, chan_ops, *eval_args,
        )
        (self._lora, self._opt, self._s_lora, self._s_opt,
         self._b_tokens, self._b_logits, self._b_h, _valid, _chan) = carry
        self._d_loss = taps["distill_loss"][-1]

        def _tolist(name):
            return [float(x) for x in np.asarray(taps[name])]

        snr_db = outage = None
        if has_chan:
            snr_db = [[float(x) for x in row] for row in np.asarray(taps["snr_db"])]
            outage = [[bool(x) for x in row] for row in np.asarray(taps["outage"])]
        return RoundsTrajectory(
            ks=all_ks,
            payloads=all_payloads,
            mean_k=_tolist("mean_k"),
            distill_loss=_tolist("distill_loss"),
            server_acc=_tolist("server_acc") if has_eval else None,
            client_acc=_tolist("client_acc") if has_eval else None,
            snr_db=snr_db,
            outage=outage,
        )
