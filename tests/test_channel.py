"""Channel model: Shannon capacity, byte budgets, adaptive k (paper §III-A)."""

import math

import pytest

from repro.core.channel import (
    ChannelConfig,
    ChannelSimulator,
    ChannelState,
    bits_per_entry,
    capacity_bps,
    topk_budget,
)


def test_capacity_formula():
    # 1 MHz @ 0 dB SNR -> B*log2(2) = 1e6 bps exactly (paper eq. 5)
    assert capacity_bps(1e6, 0.0) == pytest.approx(1e6)
    # 10 dB -> log2(11)
    assert capacity_bps(1e6, 10.0) == pytest.approx(1e6 * math.log2(11))
    assert capacity_bps(0.0, 10.0) == 0.0


def test_capacity_monotone_in_snr_and_bandwidth():
    caps = [capacity_bps(1e6, snr) for snr in (-10, 0, 10, 20, 30)]
    assert caps == sorted(caps)
    assert capacity_bps(2e6, 5.0) == pytest.approx(2 * capacity_bps(1e6, 5.0))


def test_bits_per_entry():
    # 16-bit value + ceil(log2(vocab)) index bits
    assert bits_per_entry(16, 50_288) == 16 + 16
    assert bits_per_entry(16, 65_536) == 16 + 16
    assert bits_per_entry(16, 65_537) == 16 + 17
    assert bits_per_entry(8, 2) == 9


def test_topk_budget_floor_and_clamps():
    st = ChannelState(bandwidth_hz=1e6, snr_db=0.0, eta=0.5, deadline_s=1.0)
    # budget = 0.5 * 1e6 * 1 = 5e5 bits; d = 32 for vocab 50288
    k = topk_budget(st, vocab_size=50_288, num_samples=100)
    assert k == math.floor(5e5 / 32 / 100)
    # deep fade floors at k_min
    bad = ChannelState(bandwidth_hz=1e3, snr_db=-30.0, eta=0.01, deadline_s=0.1)
    assert topk_budget(bad, vocab_size=50_288, num_samples=1000) == 1
    # great channel caps at vocab
    good = ChannelState(bandwidth_hz=1e12, snr_db=60.0, eta=1.0, deadline_s=10.0)
    assert topk_budget(good, vocab_size=1000, num_samples=1) == 1000


def test_simulator_deterministic_and_per_client():
    sim1 = ChannelSimulator(20, ChannelConfig(), seed=3)
    sim2 = ChannelSimulator(20, ChannelConfig(), seed=3)
    s1 = sim1.states(5, [0, 3, 7])
    s2 = sim2.states(5, [0, 3, 7])
    assert [a.snr_db for a in s1] == [b.snr_db for b in s2]
    # different rounds -> different fading
    s3 = sim1.states(6, [0, 3, 7])
    assert [a.snr_db for a in s1] != [b.snr_db for b in s3]


def test_simulator_eta_default_splits_channel():
    sim = ChannelSimulator(10, ChannelConfig(eta=None), seed=0)
    st = sim.states(0, list(range(5)))
    assert all(s.eta == pytest.approx(1 / 5) for s in st)


# ---- PR 4 channel-realisation regression: (seed, round, cid) keying --------


def test_simulator_seed_enters_fading():
    """Different constructor seeds must produce different fading realisations
    (pre-fix, the fading stream was keyed by round_index only and two
    simulators with different seeds shared identical draws)."""
    cfg = ChannelConfig(shadowing_std_db=0.0)  # isolate the fading stream
    a = ChannelSimulator(10, cfg, seed=0)
    b = ChannelSimulator(10, cfg, seed=1)
    sa = [s.snr_db for s in a.states(3, [0, 1, 2])]
    sb = [s.snr_db for s in b.states(3, [0, 1, 2])]
    assert sa != sb


def test_simulator_cohort_composition_invariance():
    """A client's SNR in a round is a property of (seed, round, client) alone:
    invariant under cohort permutation, under which other clients were
    selected, and under repeated calls (pre-fix, fading was drawn
    sequentially per cohort POSITION)."""
    sim = ChannelSimulator(20, ChannelConfig(eta=0.1), seed=5)
    full = {cid: s.snr_db for cid, s in zip([0, 3, 7], sim.states(2, [0, 3, 7]))}
    perm = {cid: s.snr_db for cid, s in zip([7, 0, 3], sim.states(2, [7, 0, 3]))}
    assert full == perm
    # a different cohort containing client 3 sees the same realisation for 3
    other = {cid: s.snr_db for cid, s in zip([3, 11], sim.states(2, [3, 11]))}
    assert other[3] == full[3]
    # and a singleton query agrees too (call order / count is irrelevant)
    assert sim.states(2, [7])[0].snr_db == full[7]


def test_simulator_dropout_keyed_per_client_and_seed():
    """Outage draws share the same (seed, round, cid) keying: deterministic,
    seed-dependent, composition-independent — and enabling dropout never
    perturbs the fading realisation (disjoint stream domains)."""
    cfg = ChannelConfig(dropout_prob=0.5)
    sim = ChannelSimulator(30, cfg, seed=9)
    ids = list(range(30))
    drops = [math.isinf(s.snr_db) for s in sim.states(1, ids)]
    assert drops == [math.isinf(s.snr_db) for s in sim.states(1, ids)]
    assert any(drops) and not all(drops)
    # permuting the cohort permutes the outage pattern with it
    sub = [math.isinf(s.snr_db) for s in sim.states(1, [5, 17])]
    assert sub == [drops[5], drops[17]]
    # a different seed draws a different outage pattern
    other = [math.isinf(s.snr_db) for s in ChannelSimulator(30, cfg, seed=10).states(1, ids)]
    assert drops != other
    # alive clients' fading is untouched by the dropout feature being on
    no_drop = ChannelSimulator(30, ChannelConfig(), seed=9).states(1, ids)
    for s_with, s_without, dropped in zip(sim.states(1, ids), no_drop, drops):
        if not dropped:
            assert s_with.snr_db == s_without.snr_db


# ---- PR 4 budget regression: reserved bits (adald LoRA projection) ---------


def test_topk_budget_reserved_bits():
    """Reserving the LoRA-projection bits shrinks k so the REALIZED payload
    (projection included) fits the budget; an unaffordable reservation
    DROPS the round (no survival floor — a floored payload could not fit
    the link by construction)."""
    st = ChannelState(bandwidth_hz=1e6, snr_db=0.0, eta=0.5, deadline_s=1.0)
    # budget = 5e5 bits; d = 32 for vocab 50288
    base = topk_budget(st, vocab_size=50_288, num_samples=100)
    reserved = 100 * 8 * 16  # samples * rank * value_bits
    k = topk_budget(st, vocab_size=50_288, num_samples=100, reserved_bits=reserved)
    assert k == math.floor((5e5 - reserved) / 32 / 100) < base
    # realized payload (entries + projection) respects the budget
    assert 100 * k * 32 + reserved <= st.bit_budget
    # reservation >= budget: the round is dropped at ANY k_min — emitting a
    # k_min-floored payload whose reservation alone exceeds the link would
    # break PayloadSpec.fits-by-construction
    assert topk_budget(
        st, vocab_size=50_288, num_samples=100, reserved_bits=1e6
    ) == 0
    assert topk_budget(
        st, vocab_size=50_288, num_samples=100, reserved_bits=1e6, k_min=0
    ) == 0


# ---- PR 6 budget regression: survival floor vs unaffordable reservation ----


def test_topk_budget_reservation_exceeding_budget_drops_round():
    """ISSUE repro: a 100-bit link with a 1000-bit LoRA-projection
    reservation must yield k == 0 (drop the round entirely), never a
    k_min-floored payload that cannot fit the link.  The survival floor
    only applies to bare-entry links (no reservation)."""
    from repro.core.protocol import PayloadSpec

    link = ChannelState(bandwidth_hz=100.0, snr_db=0.0, eta=1.0, deadline_s=1.0)
    assert link.bit_budget == pytest.approx(100.0)
    # bare-entry link: floor keeps the client alive at k_min
    assert topk_budget(link, vocab_size=32, num_samples=10, k_min=1) == 1
    # 1000-bit reservation >> 100-bit budget: must drop, even at k_min >= 1
    assert (
        topk_budget(
            link, vocab_size=32, num_samples=10, k_min=1, reserved_bits=1000.0
        )
        == 0
    )
    # and every k > 0 the floor could have emitted indeed does NOT fit
    spec = PayloadSpec(num_samples=10, vocab=32, k=1, lora_rank=8, value_bits=16)
    assert not spec.fits(link)
    # partial-affordability boundary: reservation below budget but leaving
    # room for less than one entry -> still dropped (a floored payload
    # including the reservation would not fit either)
    d = bits_per_entry(16, 32)
    assert (
        topk_budget(
            link, vocab_size=32, num_samples=10, k_min=1,
            reserved_bits=link.bit_budget - 0.5 * d,
        )
        == 0
    )


def test_topk_for_lora_rank_reserves_projection():
    """ChannelSimulator.topk_for(lora_rank=r) reserves samples*r*value_bits
    per client before counting (value, index) entries."""
    sim = ChannelSimulator(4, ChannelConfig(bandwidth_hz=2e5, mean_snr_db=2.0), seed=0)
    plain = sim.topk_for(0, [0, 1, 2], vocab_size=1024, num_samples=64)
    shaved = sim.topk_for(0, [0, 1, 2], vocab_size=1024, num_samples=64, lora_rank=8)
    d = bits_per_entry(16, 1024)
    for s, k0, k1 in zip(sim.states(0, [0, 1, 2]), plain, shaved):
        assert k1 <= k0
        if k1 > sim.config.min_k:  # budget-derived, not the survival floor
            assert 64 * k1 * d + 64 * 8 * 16 <= s.bit_budget + 1e-6
