"""Wireless channel model driving the adaptive Top-k budget (paper §III-A).

The paper models each client's uplink as an AWGN channel.  Shannon capacity

    C = B * log2(1 + SNR)            [bits/s]          (paper eq. 5)

with bandwidth ``B`` (Hz) and linear SNR.  A client granted fraction
``eta`` of the channel for at most ``T`` seconds per round may transmit
``eta * C * T`` bits, which caps the number of (logit, index) pairs it can
upload:

    k = floor(eta * C * T / d)                          (paper §III-A)

where ``d`` is the number of bits to encode one logit value plus its
dimension index.

On TPU this module is a *deterministic byte-budget simulator*: the budget it
produces is enforced on the actual collective payload shapes by
:mod:`repro.core.protocol`, so communication accounting is exact even though
no radio exists.  Fading is simulated with a seeded PRNG so experiments are
reproducible (paper Table I: seeds 0, 1, 42).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.scenario import (
    ScenarioConfig,
    exp_to_gauss,
    gauss_to_exp_power,
    ge_stationary_bad,
    ge_step,
    trajectory_offset_db,
    uniform_to_gauss,
)

__all__ = [
    "ChannelState",
    "BatchedChannelState",
    "ChannelCarry",
    "ChannelConfig",
    "ChannelSimulator",
    "capacity_bps",
    "bit_budget",
    "topk_budget",
    "topk_budget_batch",
    "bits_per_entry",
]


def capacity_bps(bandwidth_hz: float, snr_db: float) -> float:
    """Shannon capacity of an AWGN link (paper eq. 5)."""
    if bandwidth_hz <= 0.0:
        return 0.0
    snr_linear = 10.0 ** (snr_db / 10.0)
    return bandwidth_hz * math.log2(1.0 + snr_linear)


def bits_per_entry(value_bits: int, vocab_size: int) -> int:
    """Bits ``d`` to encode one (logit, index) pair.

    A top-k entry is a value (``value_bits``, e.g. 16 for bf16) plus an index
    into the vocabulary, which needs ``ceil(log2(vocab))`` bits.
    """
    if vocab_size <= 1:
        index_bits = 1
    else:
        index_bits = int(math.ceil(math.log2(vocab_size)))
    return int(value_bits) + index_bits


@dataclasses.dataclass(frozen=True)
class ChannelState:
    """Instantaneous link state for one client in one round."""

    bandwidth_hz: float
    snr_db: float
    eta: float  # fraction of channel resource allocated to this client
    deadline_s: float  # T: max transmission time per round

    @property
    def capacity_bps(self) -> float:
        return capacity_bps(self.bandwidth_hz, self.snr_db)

    @property
    def bit_budget(self) -> float:
        return self.eta * self.capacity_bps * self.deadline_s


def bit_budget(state: ChannelState) -> float:
    return state.bit_budget


def topk_budget(
    state: ChannelState,
    *,
    vocab_size: int,
    num_samples: int,
    value_bits: int = 16,
    k_min: int = 1,
    k_max: int | None = None,
    reserved_bits: float = 0.0,
) -> int:
    """Maximum permissible k per sample: ``k = floor((eta*C*T - reserved)/d)``
    spread over ``num_samples`` public samples uploaded this round.

    The paper states the per-logit budget; with a batch of public samples the
    same budget divides across samples (each sample's sparse vector costs
    ``k*d`` bits).  Clamped to ``[k_min, min(k_max, vocab)]`` so a client in
    deep fade still sends its argmax rather than dropping out.

    ``reserved_bits`` is the fixed-cost part of the payload that rides on the
    SAME Shannon budget before any (value, index) entry does — for the paper's
    ``adald`` method the LoRA projection ``h``
    (:func:`repro.core.protocol.lora_projection_bits`).  Reserving it here is
    what makes ``PayloadSpec.fits`` hold by construction for the realized
    payload: without the reservation the projection rode on top of a
    budget-exact top-k and pushed the payload past capacity.  A budget that
    cannot cover the reservation plus ``k_min`` entries per sample behaves
    like deep fade: the client DROPS THE ROUND (k = 0) rather than emitting
    an unfittable payload.  (Before this fix the ``max(k_min, ...)``
    survival floor lifted the negative entry count back to ``k_min``, so a
    100-bit link with a 1000-bit LoRA reservation "transmitted" a payload
    several times its own capacity and broke the fits-by-construction
    invariant.  The floor is for links that can't afford ``k_min`` BARE
    entries — those still send their argmax; a link that can't afford its
    fixed reservation has nothing coherent to send.)

    A link in outage (zero bit budget) returns 0 regardless of ``k_min``:
    the survival floor exists for faded-but-alive links, but nothing can be
    transmitted over zero capacity — the client drops the round.
    """
    if state.bit_budget <= 0.0:
        return 0
    d = bits_per_entry(value_bits, vocab_size)
    total_entries = (state.bit_budget - float(reserved_bits)) / float(d)
    k = int(math.floor(total_entries / max(1, num_samples)))
    hi = vocab_size if k_max is None else min(k_max, vocab_size)
    if k < k_min and reserved_bits > 0.0:
        # Unaffordable reservation: deep fade.  The survival floor would
        # emit k_min entries ON TOP of a reservation the budget cannot
        # cover; drop the round instead (Client.upload and the engines'
        # _budgets agree — k == 0 clients transmit nothing).
        return 0
    return max(k_min, min(k, hi))


@dataclasses.dataclass(frozen=True)
class BatchedChannelState:
    """Link states for a whole round's selected cohort as arrays.

    The batched round engine consumes this directly; ``__iter__`` /
    ``__getitem__`` recover the scalar :class:`ChannelState` views so the
    sequential reference engine sees identical per-client states.
    """

    bandwidth_hz: np.ndarray  # (C,)
    snr_db: np.ndarray  # (C,)
    eta: np.ndarray  # (C,)
    deadline_s: np.ndarray  # (C,)

    @classmethod
    def from_states(cls, states: Sequence[ChannelState]) -> "BatchedChannelState":
        return cls(
            bandwidth_hz=np.array([s.bandwidth_hz for s in states], dtype=np.float64),
            snr_db=np.array([s.snr_db for s in states], dtype=np.float64),
            eta=np.array([s.eta for s in states], dtype=np.float64),
            deadline_s=np.array([s.deadline_s for s in states], dtype=np.float64),
        )

    def __len__(self) -> int:
        return int(self.snr_db.shape[0])

    def __getitem__(self, i: int) -> ChannelState:
        return ChannelState(
            bandwidth_hz=float(self.bandwidth_hz[i]),
            snr_db=float(self.snr_db[i]),
            eta=float(self.eta[i]),
            deadline_s=float(self.deadline_s[i]),
        )

    def __iter__(self):
        for i in range(len(self)):
            yield self[i]


def topk_budget_batch(
    states: "BatchedChannelState | Sequence[ChannelState]",
    *,
    vocab_size: int,
    num_samples: int,
    value_bits: int = 16,
    k_min: int = 1,
    k_max: int | None = None,
    reserved_bits: float = 0.0,
) -> list[int]:
    """Per-client adaptive budgets for a round's cohort.

    Evaluates the scalar :func:`topk_budget` per client (host-side, tiny N)
    rather than a vectorized reimplementation so the batched engine's ``k``
    is bit-identical to the sequential reference — a one-ulp difference in a
    vectorized log2 could flip a ``floor`` and desynchronise the engines.
    """
    return [
        topk_budget(
            s,
            vocab_size=vocab_size,
            num_samples=num_samples,
            value_bits=value_bits,
            k_min=k_min,
            k_max=k_max,
            reserved_bits=reserved_bits,
        )
        for s in states
    ]


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Fleet-level channel configuration.

    Defaults loosely follow an LTE-like uplink: 1 MHz effective bandwidth,
    mean SNR 10 dB with log-normal shadowing + Rayleigh-like fast fading,
    1 s round deadline, equal resource share ``eta = 1/num_selected``.

    Straggler / dropout scenarios: ``dropout_prob`` puts a selected client's
    link into outage (zero capacity -> k = 0, the client transmits nothing
    that round, regardless of ``min_k``), and ``min_k = 0`` additionally
    removes the survival floor so a faded-but-alive client whose budget
    cannot afford a single (value, index) entry also drops out.  The round
    engines exclude k == 0 clients from aggregation entirely instead of
    zero-padding them in.
    """

    bandwidth_hz: float = 1.0e6
    mean_snr_db: float = 10.0
    shadowing_std_db: float = 4.0
    fast_fading: bool = True
    deadline_s: float = 1.0
    eta: float | None = None  # None -> 1/num_clients per round
    value_bits: int = 16
    min_k: int = 1  # survival floor; 0 lets deep-fade clients drop the round
    dropout_prob: float = 0.0  # per-(round, client) outage probability
    # Channel dynamics (repro.core.scenario): None keeps the i.i.d.
    # per-round fading/dropout above; a ScenarioConfig upgrades the
    # simulator to time-correlated fading (Gauss-Markov / Jakes), bursty
    # Gilbert-Elliott outage, and deterministic SNR/mobility trajectories.
    # The default ScenarioConfig() is bit-identical to None.
    scenario: ScenarioConfig | None = None


@dataclasses.dataclass(frozen=True)
class ChannelCarry:
    """Per-fleet channel state between rounds (scenario dynamics).

    ``z`` is the Gaussian-copula AR(1) fading state and ``bad`` the
    Gilbert-Elliott outage state, one entry per fleet client.  The carry is
    a pure value: :meth:`ChannelSimulator.step_channel` maps the carry for
    round ``t-1`` plus the ``(seed, t, cid)``-keyed draws to the carry for
    round ``t`` — replaying from :meth:`ChannelSimulator.init_channel_carry`
    always reproduces the same trajectory, so realisations are independent
    of query order and cohort composition (PR-4's guarantees extended to
    stateful channels).
    """

    round_index: int  # the round this carry has evolved THROUGH (-1 = init)
    z: np.ndarray  # (N,) f64 AR(1) fading state
    bad: np.ndarray  # (N,) bool Gilbert-Elliott outage state


class ChannelSimulator:
    """Deterministic per-round channel realisation for N clients.

    ``states(round, client_ids)`` returns one :class:`ChannelState` per
    selected client.  SNR_n(t) = mean + shadowing_n + fading_n(t), with
    shadowing fixed per client (spatial) and fading redrawn per round
    (temporal), all from a seeded generator.

    Every temporal draw is keyed by ``(seed, round_index, cid)``: two
    simulators with the same seed produce identical realisations, different
    seeds produce different ones, and a client's fading/outage in a round is
    a property of THAT client and round alone — independent of which other
    clients were selected, of the cohort's ordering, and of call order.
    (Before PR 4 the streams were keyed by ``round_index`` only and drawn
    sequentially per cohort *position*, so the constructor seed never entered
    them and a client's SNR depended on its neighbours in the selection.)
    """

    # Stream domains: fading and outage draws must stay on disjoint keys so
    # enabling dropout never perturbs the fading realisation of a run.  The
    # scenario init states (AR(1) z_{-1}, Gilbert-Elliott stationary start)
    # live on their own domains for the same reason.
    _FADING_DOMAIN = 7
    _OUTAGE_DOMAIN = 8
    _FADING_INIT_DOMAIN = 9
    _GE_INIT_DOMAIN = 10

    def __init__(self, num_clients: int, config: ChannelConfig | None = None, *, seed: int = 0):
        self.num_clients = int(num_clients)
        self.config = config or ChannelConfig()
        self.seed = int(seed)
        self._rng = np.random.default_rng(seed)
        # Per-client static shadowing (log-normal in dB).
        self._shadowing_db = self._rng.normal(
            0.0, self.config.shadowing_std_db, size=self.num_clients
        )
        # Scenario replay cache: realised (snr_db, outage) arrays per round,
        # built by stepping the pure carry from round 0.  Contiguous replay
        # is what makes random-access ``states(t, ids)`` independent of the
        # order rounds are queried in.
        self._carry: ChannelCarry | None = None
        self._realised: list[tuple[np.ndarray, np.ndarray]] = []

    @property
    def scenario(self) -> ScenarioConfig | None:
        return self.config.scenario

    def _stream(self, domain: int, round_index: int, cid: int) -> np.random.Generator:
        """Fresh generator keyed by (seed, domain, round, client)."""
        return np.random.default_rng(
            np.random.SeedSequence(
                entropy=self.seed, spawn_key=(domain, int(round_index), int(cid))
            )
        )

    def _validate_query(self, round_index: int, client_ids: Sequence[int]) -> list[int]:
        """Shared hygiene for ``states``/``topk_for``: rounds are 0-based and
        a cohort is a set — silently accepting a negative round or duplicate
        ids would silently desynchronise the (seed, round, cid) keying."""
        if round_index < 0:
            raise ValueError(
                f"round_index must be >= 0, got {round_index} (rounds are "
                "0-based; the simulator has no pre-federation realisations)"
            )
        ids = [int(c) for c in client_ids]
        if len(set(ids)) != len(ids):
            dups = sorted({c for c in ids if ids.count(c) > 1})
            raise ValueError(
                f"duplicate client_ids in cohort: {dups} — a cohort selects "
                "each client at most once; duplicates would double-count "
                "budgets/payloads for one physical link"
            )
        return ids

    # -- scenario dynamics: pure carry API -------------------------------

    def init_channel_carry(self) -> ChannelCarry:
        """Fleet channel state BEFORE round 0 (stationary start).

        ``z_{-1} ~ N(0, 1)`` per client (own stream domain) makes the AR(1)
        fading chain stationary from the very first round — the round-0
        marginal already matches the i.i.d. model.  The Gilbert-Elliott
        state starts from its stationary distribution.  With no scenario
        (or the default one) both states are identically zero/False and
        never consulted.
        """
        sc = self.config.scenario or ScenarioConfig()
        n = self.num_clients
        z = np.zeros(n, dtype=np.float64)
        if self.config.fast_fading and sc.effective_rho > 0.0:
            z = uniform_to_gauss([
                self._stream(self._FADING_INIT_DOMAIN, 0, cid).random()
                for cid in range(n)
            ])
        bad = np.zeros(n, dtype=bool)
        if sc.p_gb is not None:
            pi_bad = ge_stationary_bad(*sc.ge_params(self.config.dropout_prob))
            if pi_bad > 0.0:
                bad = np.array([
                    self._stream(self._GE_INIT_DOMAIN, 0, cid).random() < pi_bad
                    for cid in range(n)
                ])
        return ChannelCarry(round_index=-1, z=z, bad=bad)

    def step_channel(
        self, carry: ChannelCarry, round_index: int
    ) -> tuple[ChannelCarry, np.ndarray, np.ndarray]:
        """Advance the fleet's channel state through one round (pure).

        Returns ``(carry', snr_db, outage)`` with per-fleet-client arrays:
        ``snr_db[cid]`` is client ``cid``'s realised SNR for ``round_index``
        (``-inf`` in outage) and ``outage`` the Gilbert-Elliott bad states.
        Draws are keyed ``(seed, round, cid)`` exactly like the i.i.d.
        simulator — same streams, same first draw — so ``rho = 0`` with the
        i.i.d.-equivalent outage chain reproduces the stateless simulator
        bit for bit.  The carry must be stepped contiguously (correlation
        makes round ``t`` depend on ``t-1``); random access goes through
        :meth:`states`, which replays and caches from round 0.
        """
        if round_index != carry.round_index + 1:
            raise ValueError(
                f"step_channel must advance contiguously: carry is at round "
                f"{carry.round_index}, got round_index {round_index}"
            )
        cfg = self.config
        sc = cfg.scenario or ScenarioConfig()
        n = self.num_clients
        snr = cfg.mean_snr_db + self._shadowing_db.astype(np.float64)
        if sc.snr_drift_db_per_round != 0.0 or sc.snr_amp_db != 0.0:
            snr = snr + np.array([
                trajectory_offset_db(sc, round_index, cid, n) for cid in range(n)
            ])
        z = carry.z
        if cfg.fast_fading:
            power = np.array([
                self._stream(self._FADING_DOMAIN, round_index, cid).exponential(1.0)
                for cid in range(n)
            ])
            rho = sc.effective_rho
            if rho > 0.0:
                # Gaussian-copula AR(1): stationary Exp(1) marginal at any
                # rho; rho = 0 keeps the RAW draw (bit-identical i.i.d.).
                z = rho * z + math.sqrt(1.0 - rho * rho) * exp_to_gauss(power)
                power = gauss_to_exp_power(z)
            snr = snr + np.array([
                10.0 * math.log10(max(1e-6, float(p))) for p in power
            ])
        bad = np.zeros(n, dtype=bool)
        if sc.p_gb is not None:
            p_gb, p_bg = sc.ge_params(cfg.dropout_prob)
            if p_gb > 0.0:
                u = np.array([
                    self._stream(self._OUTAGE_DOMAIN, round_index, cid).random()
                    for cid in range(n)
                ])
                bad = ge_step(carry.bad, u, p_gb, p_bg)
        elif cfg.dropout_prob > 0.0:
            # memoryless dropout coin — the i.i.d. simulator's exact branch
            u = np.array([
                self._stream(self._OUTAGE_DOMAIN, round_index, cid).random()
                for cid in range(n)
            ])
            bad = u < cfg.dropout_prob
        snr = np.where(bad, -np.inf, snr)
        return ChannelCarry(round_index=round_index, z=z, bad=bad), snr, bad

    def _ensure_realised(self, round_index: int) -> None:
        if self._carry is None:
            self._carry = self.init_channel_carry()
        while len(self._realised) <= round_index:
            self._carry, snr, bad = self.step_channel(
                self._carry, len(self._realised)
            )
            self._realised.append((snr, bad))

    def states(self, round_index: int, client_ids: Sequence[int]) -> list[ChannelState]:
        cfg = self.config
        client_ids = self._validate_query(round_index, client_ids)
        eta = cfg.eta if cfg.eta is not None else 1.0 / max(1, len(client_ids))
        if cfg.scenario is not None:
            if any(not 0 <= c < self.num_clients for c in client_ids):
                raise ValueError(
                    f"scenario channels track per-fleet state: client_ids "
                    f"must be in [0, {self.num_clients}), got {client_ids}"
                )
            self._ensure_realised(round_index)
            snr_all, _bad = self._realised[round_index]
            return [
                ChannelState(
                    bandwidth_hz=cfg.bandwidth_hz,
                    snr_db=float(snr_all[cid]),
                    eta=eta,
                    deadline_s=cfg.deadline_s,
                )
                for cid in client_ids
            ]
        out = []
        for cid in client_ids:
            snr = cfg.mean_snr_db + float(self._shadowing_db[cid % self.num_clients])
            if cfg.fast_fading:
                # Rayleigh power fading: 10*log10(Exp(1)) has mean ~ -2.5 dB.
                fade = self._stream(self._FADING_DOMAIN, round_index, cid)
                snr += 10.0 * math.log10(max(1e-6, fade.exponential(1.0)))
            if cfg.dropout_prob > 0.0:
                drop = self._stream(self._OUTAGE_DOMAIN, round_index, cid)
                if drop.random() < cfg.dropout_prob:
                    snr = -math.inf  # outage: zero capacity -> zero bit budget
            out.append(
                ChannelState(
                    bandwidth_hz=cfg.bandwidth_hz,
                    snr_db=snr,
                    eta=eta,
                    deadline_s=cfg.deadline_s,
                )
            )
        return out

    def scan_channel_inputs(self, num_rounds: int, *, start_round: int = 0) -> dict:
        """Host-precomputed operands for the in-scan channel replica.

        The compiled multi-round drivers evolve ``(z, bad)`` as scan carry
        from these f32 DATA operands (:func:`repro.fed.steps
        .make_channel_step_fn`): per-round copula normals ``w``, outage
        uniforms ``u`` and deterministic base SNR (mean + shadowing +
        trajectory), plus the scalar dynamics ``rho``/``p_gb``/``p_bg``/
        ``fade_scale``.  Because every scenario differs only through these
        operands, one executable serves all presets (``rho = 0`` is the
        i.i.d. case).  The draws come from the very streams the host
        realisation consumes, so the in-scan trajectory replays the host
        one (f32 vs f64 rounding aside).
        """
        if num_rounds < 0 or start_round < 0:
            raise ValueError("num_rounds and start_round must be >= 0")
        cfg = self.config
        sc = cfg.scenario or ScenarioConfig()
        n = self.num_clients
        carry = self.init_channel_carry()
        for t in range(start_round):
            carry, _snr, _bad = self.step_channel(carry, t)
        rho = sc.effective_rho if cfg.fast_fading else 0.0
        if sc.p_gb is not None:
            p_gb, p_bg = sc.ge_params(cfg.dropout_prob)
        else:
            p_gb, p_bg = float(cfg.dropout_prob), 1.0 - float(cfg.dropout_prob)
        outage_on = p_gb > 0.0
        w = np.zeros((num_rounds, n), dtype=np.float64)
        u = np.ones((num_rounds, n), dtype=np.float64)
        base = np.zeros((num_rounds, n), dtype=np.float64)
        shadow = cfg.mean_snr_db + self._shadowing_db.astype(np.float64)
        for r in range(num_rounds):
            t = start_round + r
            base[r] = shadow
            if sc.snr_drift_db_per_round != 0.0 or sc.snr_amp_db != 0.0:
                base[r] += np.array([
                    trajectory_offset_db(sc, t, cid, n) for cid in range(n)
                ])
            if cfg.fast_fading:
                p = np.array([
                    self._stream(self._FADING_DOMAIN, t, cid).exponential(1.0)
                    for cid in range(n)
                ])
                w[r] = exp_to_gauss(p)
            if outage_on:
                u[r] = np.array([
                    self._stream(self._OUTAGE_DOMAIN, t, cid).random()
                    for cid in range(n)
                ])
        return {
            "z0": carry.z.astype(np.float32),
            "bad0": carry.bad.copy(),
            "w": w.astype(np.float32),
            "u": u.astype(np.float32),
            "base_snr_db": base.astype(np.float32),
            "rho": np.float32(rho),
            "p_gb": np.float32(p_gb if outage_on else 0.0),
            "p_bg": np.float32(p_bg if outage_on else 1.0),
            "fade_scale": np.float32(1.0 if cfg.fast_fading else 0.0),
        }

    def states_batched(
        self, round_index: int, client_ids: Sequence[int]
    ) -> BatchedChannelState:
        """The same per-round realisation as :meth:`states`, stacked into the
        array form the batched round engine consumes."""
        return BatchedChannelState.from_states(self.states(round_index, client_ids))

    def topk_for(
        self,
        round_index: int,
        client_ids: Sequence[int],
        *,
        vocab_size: int,
        num_samples: int,
        k_min: int | None = None,
        k_max: int | None = None,
        lora_rank: int | None = None,
    ) -> list[int]:
        """Per-client adaptive k for this round (paper: 'based on real-time
        channel condition').  ``k_min`` defaults to the config's ``min_k`` so
        this agrees with the round engines' straggler semantics.

        ``lora_rank`` reserves the ``adald`` LoRA-projection bits
        (``num_samples * rank * value_bits``, §III-C) out of each client's
        budget before the (value, index) entries are counted, so the realized
        payload — projection included — respects the Shannon budget."""
        reserved = (
            float(num_samples * lora_rank * self.config.value_bits)
            if lora_rank is not None
            else 0.0
        )
        return [
            topk_budget(
                s,
                vocab_size=vocab_size,
                num_samples=num_samples,
                value_bits=self.config.value_bits,
                k_min=self.config.min_k if k_min is None else k_min,
                k_max=k_max,
                reserved_bits=reserved,
            )
            for s in self.states(round_index, client_ids)
        ]
