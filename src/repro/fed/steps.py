"""Jitted step functions for the FL runtime (Algorithm 1).

Task convention (paper §IV): decoder-only LM fine-tuned for Banking77
intent detection — class logits are the LM-head logits over the first
``num_classes`` vocab ids at the LAST sequence position.  Distillation
(paper eqs. 9-10) operates on the FULL last-position vocab logits (the
high-dimensional vector the adaptive Top-k sparsifies).

All steps train the LoRA subset only (paper §II-A): gradients flow through
``split_lora`` so the frozen backbone never enters the optimizer.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.aggregation import AggregationMode, aggregate_wire
from repro.core.distill import (
    kl_divergence_from_log_probs,
    teacher_log_probs,
    total_distill_loss,
)
from repro.core.topk import QuantizedWire, SparseWire, sparsify_wire, topk_mask_dynamic
from repro.lora import merge_lora, split_lora
from repro.models import forward
from repro.optim import AdamWState, adamw_init, adamw_update

__all__ = [
    "class_logits",
    "public_logits",
    "last_logits",
    "make_finetune_step",
    "make_distill_step",
    "make_batched_finetune_step",
    "make_batched_distill_step",
    "make_batched_public_logits",
    "make_fused_round_fn",
    "make_bucket_client_phase_fn",
    "make_server_phase_fn",
    "make_fused_e2e_round_fn",
    "make_eval_fn",
    "make_scan_eval_fn",
    "make_channel_step_fn",
    "init_lora_opt",
]


def class_logits(logits_last: jax.Array, num_classes: int) -> jax.Array:
    """(B, V) last-position logits -> (B, num_classes) class readout."""
    return logits_last[..., :num_classes]


def _cast_params(params, compute_dtype: str):
    """Cast float params to the round body's compute dtype (bf16-buffer
    pattern): the fp32 LoRA stays the master copy — this cast sits inside
    the differentiated graph, so its VJP accumulates the low-precision
    grads back into fp32 before AdamW sees them.  ``float32`` is the
    identity (no graph change)."""
    if compute_dtype == "float32":
        return params
    dt = jnp.dtype(compute_dtype)
    return jax.tree.map(
        lambda x: x.astype(dt) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params,
    )


def last_logits(
    params,
    cfg: ModelConfig,
    batch: dict,
    *,
    last_only: bool = True,
    head_cols: int | None = None,
):
    """(B, V) last-position logits + Aux, via the cheap head when enabled.

    ``last_only=True`` (default) computes the LM head on the final hidden
    state only — a ~seq_len× cut in head FLOPs/memory, which dominates at
    the paper's 50k+ vocabularies; ``False`` keeps the seed behaviour of
    materialising (B, T, V) and slicing (the PR-1 reference, benchmarked
    against in benchmarks/engine_bench.py — ``head_cols`` is ignored there
    so the historical reference keeps its full cost).

    ``head_cols=k`` (with ``last_only``) computes only the first k head
    columns — bit-identical to slicing, at k/V of the head FLOPs; the
    supervised class losses/eval read ``num_classes`` columns only.
    """
    if last_only:
        return forward(params, cfg, batch, last_only=True, head_cols=head_cols)
    logits, aux = forward(params, cfg, batch)
    return logits[:, -1, :], aux


@functools.partial(jax.jit, static_argnames=("cfg", "last_only"))
def public_logits(params, cfg: ModelConfig, tokens: jax.Array, *, last_only: bool = True):
    """Last-position vocab logits + pooled LoRA projection on a public batch.

    Returns (logits (B, V), h (B, r) or None) — the client/server upload
    content (Algorithm 1 lines 4, 14).
    """
    logits, aux = last_logits(params, cfg, {"tokens": tokens}, last_only=last_only)
    return logits, aux.lora_h


def init_lora_opt(params, cfg: ModelConfig) -> AdamWState:
    lora, _ = split_lora(params)
    return adamw_init(lora, state_dtype=cfg.optimizer_state_dtype)


def _finetune_loss_fn(
    cfg: ModelConfig,
    num_classes: int,
    last_only: bool = True,
    class_head_only: bool = True,
    compute_dtype: str = "float32",
) -> Callable:
    """loss(lora, frozen, batch) -> (nll + moe_aux, acc) — the shared core
    of the sequential step, the batched cohort step and the fused round.

    The supervised loss reads ``num_classes`` class logits only, so the
    last-only path restricts the LM head to those columns (``head_cols`` —
    bit-identical logits/gradients at num_classes/V of the head FLOPs).
    ``class_head_only=False`` restores the full-vocab head of the PR-2
    pipeline (kept benchable as the historical reference, like the PR-1
    full-(B,T,V) head before it)."""

    def loss_fn(lora, frozen, batch):
        params = _cast_params(merge_lora(lora, frozen), compute_dtype)
        last, aux = last_logits(
            params, cfg, {"tokens": batch["tokens"]}, last_only=last_only,
            head_cols=num_classes if (last_only and class_head_only) else None,
        )
        cls = class_logits(last, num_classes)
        logp = jax.nn.log_softmax(cls.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1).mean()
        acc = jnp.mean((jnp.argmax(cls, -1) == batch["labels"]).astype(jnp.float32))
        return nll + 0.01 * aux.moe_aux, acc

    return loss_fn


def _finetune_step_fn(
    cfg: ModelConfig,
    num_classes: int,
    lr: float,
    weight_decay: float,
    last_only: bool = True,
    class_head_only: bool = True,
) -> Callable:
    """Unjitted single-client fine-tune step over merged params."""

    loss_fn = _finetune_loss_fn(cfg, num_classes, last_only, class_head_only)

    def step(params, opt, batch):
        lora, frozen = split_lora(params)
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(lora, frozen, batch)
        new_lora, new_opt = adamw_update(
            grads, opt, lora, lr=lr, weight_decay=weight_decay
        )
        return merge_lora(new_lora, frozen), new_opt, {"loss": loss, "acc": acc}

    return step


@functools.lru_cache(maxsize=64)
def make_finetune_step(
    cfg: ModelConfig,
    num_classes: int,
    *,
    lr: float = 1e-3,
    weight_decay: float = 1e-3,
    last_only: bool = True,
    class_head_only: bool = True,
) -> Callable:
    """Supervised local fine-tuning on private data (paper eq. 2), LoRA-only.

    step(params, opt, batch{tokens,labels}) -> (params, opt, metrics)
    """
    return jax.jit(
        _finetune_step_fn(cfg, num_classes, lr, weight_decay, last_only, class_head_only)
    )


@functools.lru_cache(maxsize=64)
def make_batched_finetune_step(
    cfg: ModelConfig,
    num_classes: int,
    *,
    lr: float = 1e-3,
    weight_decay: float = 1e-3,
    shared_backbone: bool = True,
    last_only: bool = True,
    class_head_only: bool = True,
) -> Callable:
    """One fine-tune update for a whole cohort at once.

    step(lora (C,...), frozen, opt (C,...), batch {tokens (C,B,L), labels (C,B)})
    -> (lora, opt, metrics (C,))

    Client-axis vmap over the same loss/update core as
    :func:`make_finetune_step`, so every client's update (including its own
    grad-clip global norm) is computed exactly as in the sequential path.
    With ``shared_backbone`` (the paper's setting: one pretrained W' under
    per-client LoRA deltas) the frozen tree is broadcast (``in_axes=None``)
    — XLA then fuses the cohort's backbone matmuls into single wide ops
    instead of C small ones, which is where the batched engine's speedup
    comes from.  LoRA/opt buffers are donated.
    """

    loss_fn = _finetune_loss_fn(cfg, num_classes, last_only, class_head_only)

    def step(lora, frozen, opt, batch):
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(lora, frozen, batch)
        new_lora, new_opt = adamw_update(
            grads, opt, lora, lr=lr, weight_decay=weight_decay
        )
        return new_lora, new_opt, {"loss": loss, "acc": acc}

    frozen_ax = None if shared_backbone else 0
    return jax.jit(jax.vmap(step, in_axes=(0, frozen_ax, 0, 0)), donate_argnums=(0, 2))


def _distill_loss_fn(
    cfg: ModelConfig,
    temperature: float,
    lam: float,
    restrict_to_support: bool,
    last_only: bool = True,
    compute_dtype: str = "float32",
) -> Callable:
    """loss(lora, frozen, tokens, g_logits, g_h) -> (L_total, parts)."""

    use_h = cfg.lora is not None

    def loss_fn(lora, frozen, tokens, g_logits, g_h):
        params = _cast_params(merge_lora(lora, frozen), compute_dtype)
        own, aux = last_logits(params, cfg, {"tokens": tokens}, last_only=last_only)
        loss, parts = total_distill_loss(
            g_logits,
            own,
            g_h if use_h else None,
            aux.lora_h if use_h else None,
            temperature=temperature,
            lam=lam,
            restrict_to_support=restrict_to_support,
        )
        return loss + 0.01 * aux.moe_aux, parts

    return loss_fn


def _distill_loss_cached_fn(
    cfg: ModelConfig,
    temperature: float,
    lam: float,
    last_only: bool = True,
    compute_dtype: str = "float32",
) -> Callable:
    """loss(lora, frozen, tokens, t_logp, th_logp, support_mask) with the
    TEACHER log-probs precomputed (:func:`repro.core.distill.
    teacher_log_probs`) — the round-fused engines compute them once per
    round instead of once per (client, step).  Bit-identical losses and
    gradients to :func:`_distill_loss_fn` on the same teacher inputs (the
    teacher side is a constant of the round; only the student side carries
    gradients)."""

    use_h = cfg.lora is not None

    def loss_fn(lora, frozen, tokens, t_logp, th_logp, support_mask):
        params = _cast_params(merge_lora(lora, frozen), compute_dtype)
        own, aux = last_logits(params, cfg, {"tokens": tokens}, last_only=last_only)
        loss = kl_divergence_from_log_probs(
            t_logp, own, temperature, mask=support_mask
        )
        if use_h and th_logp is not None:
            loss = loss + lam * kl_divergence_from_log_probs(
                th_logp, aux.lora_h, temperature
            )
        return loss + 0.01 * aux.moe_aux, {}

    return loss_fn


def _distill_step_fn(
    cfg: ModelConfig,
    lr: float,
    temperature: float,
    lam: float,
    restrict_to_support: bool,
    last_only: bool = True,
) -> Callable:
    """Unjitted single-model distillation step over merged params."""

    loss_fn = _distill_loss_fn(cfg, temperature, lam, restrict_to_support, last_only)

    def step(params, opt, tokens, g_logits, g_h):
        lora, frozen = split_lora(params)
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            lora, frozen, tokens, g_logits, g_h
        )
        new_lora, new_opt = adamw_update(grads, opt, lora, lr=lr)
        return merge_lora(new_lora, frozen), new_opt, {"loss": loss, **parts}

    return step


@functools.lru_cache(maxsize=64)
def make_distill_step(
    cfg: ModelConfig,
    *,
    lr: float = 1e-3,
    temperature: float = 2.0,
    lam: float = 0.03,
    restrict_to_support: bool = False,
    last_only: bool = True,
) -> Callable:
    """Knowledge-distillation update against global teacher knowledge
    (Algorithm 1 lines 5-7 / 16): LoRA-only gradient on L_total (eq. 10).

    step(params, opt, public_tokens, g_logits, g_h) -> (params, opt, metrics)
    ``g_h`` may be None -> the λ-term drops (the 'Adaptive' baseline).
    """
    return jax.jit(
        _distill_step_fn(cfg, lr, temperature, lam, restrict_to_support, last_only)
    )


@functools.lru_cache(maxsize=64)
def make_batched_distill_step(
    cfg: ModelConfig,
    *,
    lr: float = 1e-3,
    temperature: float = 2.0,
    lam: float = 0.03,
    restrict_to_support: bool = False,
    shared_backbone: bool = True,
    last_only: bool = True,
) -> Callable:
    """Cohort distillation against one broadcast teacher.

    step(lora (C,...), frozen, opt (C,...), tokens (P,L), g_logits (P,V), g_h)
    -> (lora, opt, metrics (C,))

    Teacher knowledge AND public tokens are broadcast (in_axes=None) —
    every client distills against the same {K_g, h_g}, exactly as
    Algorithm 1 lines 5-7; with ``shared_backbone`` the frozen W' is
    broadcast too (see :func:`make_batched_finetune_step`).
    """
    loss_fn = _distill_loss_fn(cfg, temperature, lam, restrict_to_support, last_only)

    def step(lora, frozen, opt, tokens, g_logits, g_h):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            lora, frozen, tokens, g_logits, g_h
        )
        new_lora, new_opt = adamw_update(grads, opt, lora, lr=lr)
        return new_lora, new_opt, {"loss": loss, **parts}

    frozen_ax = None if shared_backbone else 0
    return jax.jit(
        jax.vmap(step, in_axes=(0, frozen_ax, 0, None, None, None)),
        donate_argnums=(0, 2),
    )


@functools.lru_cache(maxsize=64)
def make_batched_public_logits(
    cfg: ModelConfig, *, shared_backbone: bool = True, last_only: bool = True
) -> Callable:
    """Cohort public-set inference: (lora (C,...), frozen, tokens (P,L)) ->
    (logits (C,P,V), h (C,P,r) or None) — Algorithm 1 line 9 for the whole
    round's selected clients in one compiled call."""

    def one(lora, frozen, tokens):
        last, aux = last_logits(
            merge_lora(lora, frozen), cfg, {"tokens": tokens}, last_only=last_only
        )
        return last, aux.lora_h

    frozen_ax = None if shared_backbone else 0
    return jax.jit(jax.vmap(one, in_axes=(0, frozen_ax, None)))


def _client_round_core(
    cfg: ModelConfig,
    num_classes: int,
    *,
    lr: float,
    weight_decay: float,
    distill_lr: float,
    temperature: float,
    lam: float,
    restrict_to_support: bool,
    local_steps: int,
    distill_steps: int,
    last_only: bool,
    gate_distill: bool,
    kd_loss: Callable | None = None,
    class_head_only: bool = True,
    compute_dtype: str = "float32",
) -> Callable:
    """Per-client round body shared by the fused and fused-e2e round fns:
    ``distill_steps`` distillation updates, ``local_steps`` supervised
    updates (``lax.scan``), public last-position inference.

    ``gate_distill=True`` makes the cold-server round DATA instead of
    control flow: the distillation updates always run, and the traced bool
    ``g_valid`` selects between the distilled and the untouched
    (lora, opt) — one executable serves round 0 (no broadcast exists yet)
    and every later round.  With ``gate_distill=False`` the caller bakes
    ``distill_steps`` statically (the PR-2 two-variant scheme) and
    ``g_valid`` is ignored.

    ``kd_loss`` overrides the distillation loss; it is called as
    ``kd_loss(lora, frozen, g_tokens, *kd_args)`` where ``kd_args`` is the
    opaque teacher-knowledge tuple the caller threads through ``client_round``
    (default: ``(g_logits, g_h)`` into :func:`_distill_loss_fn`; the e2e
    round passes precomputed teacher log-probs into
    :func:`_distill_loss_cached_fn` instead).
    """
    ft_loss = _finetune_loss_fn(
        cfg, num_classes, last_only, class_head_only, compute_dtype
    )
    if kd_loss is None:
        kd_loss = _distill_loss_fn(
            cfg, temperature, lam, restrict_to_support, last_only, compute_dtype
        )

    def client_round(lora, frozen, opt, g_tokens, kd_args, g_valid, batches, pub_tokens):
        # -- lines 5-7: local distillation against the broadcast knowledge --
        lora0, opt0 = lora, opt
        for _ in range(distill_steps):
            (_, _), grads = jax.value_and_grad(kd_loss, has_aux=True)(
                lora, frozen, g_tokens, *kd_args
            )
            lora, opt = adamw_update(grads, opt, lora, lr=distill_lr)
        if gate_distill and distill_steps:
            pick = lambda new, old: jnp.where(g_valid, new, old)
            lora = jax.tree.map(pick, lora, lora0)
            opt = jax.tree.map(pick, opt, opt0)

        # -- line 8: local fine-tuning, scanned over the step axis --
        def train_body(carry, batch):
            lora, opt = carry
            (_, _), grads = jax.value_and_grad(ft_loss, has_aux=True)(
                lora, frozen, batch
            )
            lora, opt = adamw_update(grads, opt, lora, lr=lr, weight_decay=weight_decay)
            return (lora, opt), None

        (lora, opt), _ = jax.lax.scan(train_body, (lora, opt), batches, length=local_steps)

        # -- line 9: public last-position inference --
        last, aux = last_logits(
            _cast_params(merge_lora(lora, frozen), compute_dtype), cfg,
            {"tokens": pub_tokens}, last_only=last_only,
        )
        return lora, opt, last, aux.lora_h

    return client_round


@functools.lru_cache(maxsize=64)
def make_fused_round_fn(
    cfg: ModelConfig,
    num_classes: int,
    *,
    lr: float = 1e-3,
    weight_decay: float = 1e-3,
    distill_lr: float = 1e-3,
    temperature: float = 2.0,
    lam: float = 0.03,
    restrict_to_support: bool = False,
    local_steps: int = 4,
    distill_steps: int = 2,
    shared_backbone: bool = True,
    last_only: bool = True,
    use_kernels: bool = False,
    class_head_only: bool = True,
    compute_dtype: str = "float32",
) -> Callable:
    """The whole client phase of Algorithm 1 as ONE function.

    fn(lora (C,...), frozen, opt (C,...), g_tokens (P,L), g_logits (P,V),
       g_h (P,r)|None, batches {tokens (C,S,B,L), labels (C,S,B)},
       pub_tokens (P,L), ks (C,) int32)
    -> (lora, opt, dense (C,P,V), h (C,P,r)|None)

    Fuses lines 5-11 — ``distill_steps`` distillation updates against the
    broadcast knowledge, ``local_steps`` supervised updates (``lax.scan``
    over the per-step batch axis), public-set last-position inference (all
    vmapped over the client axis), and the per-client adaptive Top-k
    sparsification with the budget as DATA — so the round body is a single
    compiled program: per-round dispatches drop from
    O(distill_steps + local_steps + phases) to O(1) and no intermediate
    state round-trips through the host.  The sparsifier is the pure-jnp
    threshold bisection (:func:`repro.core.topk.topk_mask_dynamic`) or,
    with ``use_kernels``, the per-row-budget Pallas kernel
    (:func:`repro.kernels.ops.topk_mask_dynamic`) — identical threshold
    (ties-kept) semantics.  ``distill_steps=0`` builds the cold-start
    variant (round 0: no broadcast exists yet; the g_* operands are passed
    but unused and DCE'd).  Returned unjitted so the round engine chooses
    the compilation wrapper (plain ``jax.jit`` or a ``shard_map`` placement
    of the client axis over devices).
    """
    client_round = _client_round_core(
        cfg, num_classes, lr=lr, weight_decay=weight_decay, distill_lr=distill_lr,
        temperature=temperature, lam=lam, restrict_to_support=restrict_to_support,
        local_steps=local_steps, distill_steps=distill_steps, last_only=last_only,
        gate_distill=False, class_head_only=class_head_only,
        compute_dtype=compute_dtype,
    )

    frozen_ax = None if shared_backbone else 0
    vm = jax.vmap(client_round, in_axes=(0, frozen_ax, 0, None, None, None, 0, None))

    def fn(lora, frozen, opt, g_tokens, g_logits, g_h, batches, pub_tokens, ks):
        lora, opt, last, h = vm(
            lora, frozen, opt, g_tokens, (g_logits, g_h), True, batches, pub_tokens
        )
        # -- line 10: adaptive top-k, one budget per client row (k is data;
        # applied outside the client vmap so the Pallas path stays a plain
        # 2-D pallas_call) --
        if use_kernels:
            from repro.kernels import ops as kops

            dense = kops.topk_mask_dynamic(
                last, jnp.broadcast_to(ks[:, None], last.shape[:-1])
            )
        else:
            dense = topk_mask_dynamic(last, ks[:, None])
        return lora, opt, dense, h

    return fn


def _teacher_cache_fn(
    temperature: float, restrict_to_support: bool, use_h: bool
) -> Callable:
    """teacher_cache(logits, h) -> (t_logp, th_logp, support) — the once-per
    round softmax of a distillation teacher (eq. 9's constant side), shared
    by the e2e round, the bucketed hetero client phase and the server phase
    so every consumer of the same teacher computes the identical cache."""

    def teacher_cache(logits, h):
        support = (logits != 0) if restrict_to_support else None
        t_logp = teacher_log_probs(logits, temperature, mask=support)
        th_logp = (
            teacher_log_probs(h, temperature) if (use_h and h is not None) else None
        )
        return t_logp, th_logp, support

    return teacher_cache


@functools.lru_cache(maxsize=64)
def make_bucket_client_phase_fn(
    cfg: ModelConfig,
    num_classes: int,
    *,
    k_cap: int,
    lr: float = 1e-3,
    weight_decay: float = 1e-3,
    distill_lr: float = 1e-3,
    temperature: float = 2.0,
    lam: float = 0.03,
    restrict_to_support: bool = False,
    local_steps: int = 4,
    distill_steps: int = 2,
    shared_backbone: bool = True,
    last_only: bool = True,
    quantize: bool = False,
    compute_dtype: str = "float32",
) -> Callable:
    """One FAMILY BUCKET's whole client phase as ONE function: the vmapped
    per-client round bodies (distill -> fine-tune -> public inference) plus
    the sparse-wire sparsifier, for a homogeneous sub-cohort of clients that
    all run ``cfg``.

    fn(lora (C,...), frozen, opt (C,...), g_tokens (P,L), g_logits (P,V),
       g_h (P,r)|None, g_valid () bool,
       batches {tokens (C,S,B,L), labels (C,S,B)}, pub_tokens (P,L),
       ks (C,) int32)
    -> (lora, opt, values (C,P,k_cap), indices (C,P,k_cap),
        mask (C,P,k_cap), scale (C,P)|None, h (C,P,r)|None)

    ``quantize=True`` emits the int8 :class:`repro.core.topk.QuantizedWire`
    straight from the sparsifier — ``values`` is then int8 and ``scale``
    carries the per-(client, sample) dequantization factors (``None`` on
    the float wire).  ``compute_dtype`` selects the round body's forward/
    backward precision (bf16-buffer pattern: fp32 LoRA/optimizer master,
    low-precision compute).

    This is the per-bucket executable of the heterogeneous round engine
    (:class:`repro.fed.engine.HeteroFusedE2EEngine`): the fleet is
    partitioned into homogeneous family buckets (`repro.fed.cohort`), each
    bucket runs this function with its own ``cfg``/backbone layout
    (``shared_backbone=False`` stacks the frozen trees on the client axis —
    the same ``frozen_ax=0`` vmap the batched engine uses), and the buckets'
    wires are concatenated into one vocab-indexed union wire for the
    family-agnostic server phase (:func:`make_server_phase_fn`).  The
    broadcast teacher's log-softmax is computed once per bucket call —
    bit-identical per client to the homogeneous e2e round, because the
    teacher side is a constant of the round.  ``gate_distill`` semantics:
    the cold-server round is DATA (``g_valid``), one executable serves every
    round of a run (per ``k_cap`` bucket).
    """
    cached_kd = _distill_loss_cached_fn(
        cfg, temperature, lam, last_only, compute_dtype
    )
    client_round = _client_round_core(
        cfg, num_classes, lr=lr, weight_decay=weight_decay,
        distill_lr=distill_lr, temperature=temperature, lam=lam,
        restrict_to_support=restrict_to_support, local_steps=local_steps,
        distill_steps=distill_steps, last_only=last_only, gate_distill=True,
        kd_loss=cached_kd, compute_dtype=compute_dtype,
    )
    frozen_ax = None if shared_backbone else 0
    vm = jax.vmap(
        client_round, in_axes=(0, frozen_ax, 0, None, None, None, 0, None)
    )
    teacher_cache = _teacher_cache_fn(
        temperature, restrict_to_support, cfg.lora is not None
    )

    def fn(lora, frozen, opt, g_tokens, g_logits, g_h, g_valid, batches,
           pub_tokens, ks):
        t_cache = teacher_cache(g_logits, g_h)
        lora, opt, last, h = vm(
            lora, frozen, opt, g_tokens, t_cache, g_valid, batches, pub_tokens
        )
        wire = sparsify_wire(last, ks, k_cap, quantize=quantize)
        scale = wire.scale if quantize else None
        return lora, opt, wire.values, wire.indices, wire.mask, scale, h

    return fn


@functools.lru_cache(maxsize=64)
def make_server_phase_fn(
    server_cfg: ModelConfig,
    *,
    vocab: int,
    distill_lr: float = 1e-3,
    temperature: float = 2.0,
    lam: float = 0.03,
    restrict_to_support: bool = False,
    server_distill_steps: int = 12,
    aggregation: AggregationMode = "adaptive",
    send_h: bool = True,
    last_only: bool = True,
    use_kernels: bool = False,
    quantize: bool = False,
    compute_dtype: str = "float32",
) -> Callable:
    """The whole SERVER phase of one round as ONE function (Algorithm 1
    lines 13-16 + the next round's broadcast recompute), consuming the
    cohort's sparse uplink wire.

    fn(s_lora, s_frozen, s_opt,
       values (N,P,k_cap), indices (N,P,k_cap), mask (N,P,k_cap),
       scale (N,P)|None, h (N,P,r)|None, ks (N,) int32, pub_tokens (P,L))
    -> (s_lora, s_opt, b_logits (P,V), b_h (P,r)|None, d_loss ())

    ``quantize=True`` reads the uplink as the int8
    :class:`repro.core.topk.QuantizedWire` (``values`` int8 + per-row
    ``scale``); aggregation then runs the dequantize-fused route of
    :func:`repro.core.aggregation.aggregate_wire` (the Pallas kernel with
    ``use_kernels``) — the float wire ignores ``scale`` (pass ``None``).

    ``vocab`` is the fleet's SHARED vocabulary — the wire's indices address
    it directly, which is exactly why heterogeneous families interoperate
    here: the union wire of several family buckets aggregates identically to
    one homogeneous cohort's (the server never sees architectures, only
    vocab-indexed logits and rank-aligned eq.-8 projections).  A round where
    every client dropped (all ``ks == 0``) discards the server update as
    DATA and reports a NaN ``d_loss``; the broadcast still refreshes on the
    current public batch, exactly like the host round loop.
    """
    server_kd_loss = _distill_loss_cached_fn(
        server_cfg, temperature, lam, last_only, compute_dtype
    )
    teacher_cache = _teacher_cache_fn(temperature, restrict_to_support, True)

    def fn(s_lora, s_frozen, s_opt, values, indices, mask, scale, h, ks,
           pub_tokens):
        if quantize:
            wire = QuantizedWire(
                values=values, scale=scale, indices=indices, mask=mask,
                vocab=vocab,
            )
        else:
            wire = SparseWire(values=values, indices=indices, mask=mask, vocab=vocab)
        n_tx = jnp.sum((ks > 0).astype(jnp.int32))

        # -- line 15: aggregation from the wire (eqs. 6-7) --
        k_g = aggregate_wire(
            wire, aggregation, num_transmitters=n_tx, use_kernel=use_kernels
        )
        if send_h and h is not None:
            tx = (ks > 0).astype(h.dtype)[:, None, None]
            h_g = jnp.sum(h * tx, axis=0) / jnp.maximum(n_tx, 1).astype(h.dtype)
        else:
            h_g = None

        # -- line 16: server-side distillation, scanned over its steps; the
        # aggregated teacher is softmaxed ONCE for all steps --
        kg_logp, kg_h_logp, kg_support = teacher_cache(k_g, h_g)

        def server_body(carry, _):
            sl, so = carry
            (loss, _), grads = jax.value_and_grad(server_kd_loss, has_aux=True)(
                sl, s_frozen, pub_tokens, kg_logp, kg_h_logp, kg_support
            )
            sl, so = adamw_update(grads, so, sl, lr=distill_lr)
            return (sl, so), loss

        (new_sl, new_so), losses = jax.lax.scan(
            server_body, (s_lora, s_opt), None, length=server_distill_steps
        )
        # every selected client dropped -> no aggregation, no server update
        has_tx = n_tx > 0
        keep = lambda new, old: jnp.where(has_tx, new, old)  # noqa: E731
        s_lora = jax.tree.map(keep, new_sl, s_lora)
        s_opt = jax.tree.map(keep, new_so, s_opt)
        # observability tap: the final server-distill loss of the round
        # (NaN when no client transmitted — the server never distilled)
        d_loss = jnp.where(
            has_tx,
            losses[-1] if server_distill_steps else jnp.float32(jnp.nan),
            jnp.nan,
        )

        # -- lines 1-2 of the NEXT round: refreshed broadcast knowledge --
        b_last, b_aux = last_logits(
            _cast_params(merge_lora(s_lora, s_frozen), compute_dtype),
            server_cfg, {"tokens": pub_tokens}, last_only=last_only,
        )
        return s_lora, s_opt, b_last, b_aux.lora_h, d_loss

    return fn


@functools.lru_cache(maxsize=64)
def make_fused_e2e_round_fn(
    client_cfg: ModelConfig,
    server_cfg: ModelConfig,
    num_classes: int,
    *,
    k_cap: int,
    lr: float = 1e-3,
    weight_decay: float = 1e-3,
    distill_lr: float = 1e-3,
    temperature: float = 2.0,
    lam: float = 0.03,
    restrict_to_support: bool = False,
    local_steps: int = 4,
    distill_steps: int = 2,
    server_distill_steps: int = 12,
    aggregation: AggregationMode = "adaptive",
    send_h: bool = True,
    shared_backbone: bool = True,
    last_only: bool = True,
    use_kernels: bool = False,
    shard_clients: bool = False,
    quantize: bool = False,
    compute_dtype: str = "float32",
) -> Callable:
    """ONE whole federated round — client phase AND server phase — as ONE
    function (Fig. 1 steps 1-10 / Algorithm 1 lines 3-16).

    fn(lora (C,...), frozen, opt (C,...),
       s_lora, s_frozen, s_opt,                       # server LLM state
       g_tokens (P,L), g_logits (P,V), g_h (P,r)|None, g_valid () bool,
       batches {tokens (C,S,B,L), labels (C,S,B)}, pub_tokens (P,L),
       ks (C,) int32)
    -> (lora, opt, s_lora, s_opt,
        values (C,P,k_cap), indices (C,P,k_cap),      # sparse uplink wire
        scale (C,P)|None,                             # int8 wire dequant rows
        b_logits (P,V), b_h (P,r)|None,               # next-round broadcast
        d_loss ())                                    # last server-distill loss

    ``quantize=True`` carries the uplink as the int8
    :class:`repro.core.topk.QuantizedWire` (values int8 + per-(client,
    sample) scale) and aggregates through the dequantize-fused route;
    ``compute_dtype`` selects the round body's forward/backward precision
    (fp32 LoRA/optimizer state stays the master copy).

    Extends :func:`make_fused_round_fn` past the server boundary:

    * the uplink leaves the client phase as the sparse wire format
      ``(values, indices, transmit mask)`` of static width ``k_cap`` (one
      ``lax.top_k``; per-client adaptive ``k`` enters as int32 DATA and
      becomes the mask) — the ``(C, P, V)`` densified stack of the PR-2
      path is never built;
    * adaptive aggregation (eqs. 6-7) scatter-accumulates straight from the
      wire (:func:`repro.core.aggregation.aggregate_wire`; the Pallas
      scatter kernel with ``use_kernels``) — the single ``(P, V)``
      densification of the round is the aggregated teacher itself;
    * the server-side distillation (line 16) runs as a
      ``server_distill_steps``-long ``lax.scan``, and the next round's
      broadcast knowledge (line 1) is recomputed in-program;
    * the two data-dependent control decisions of the round loop are DATA,
      not Python branches: ``g_valid=False`` (cold server, round 0)
      discards the client distillation updates, and a round where every
      selected client dropped (all ``ks == 0``) discards the server
      update — the broadcast still refreshes on the current public batch,
      exactly as the host round loop behaves (``d_loss`` is NaN then, like
      the host ledger's never-written field).

    One executable therefore serves every round of a run (per ``k_cap``
    bucket), and a steady-state round is a single dispatch.

    ``shard_clients=True`` places the CLIENT phase's leading cohort axis over
    the process's devices with ``shard_map`` (mesh
    :func:`repro.sharding.cohort_mesh`): the per-client round bodies and the
    uplink sparsifier run device-parallel, and only the O(C·P·k_cap) sparse
    wire (plus the (C, P, r) projections) crosses back — the server phase
    (wire aggregation, the server-distill scan, the broadcast recompute) is
    a single-model computation and stays OUTSIDE the shard_map, replicated
    by XLA's SPMD partitioner.  The cohort size must divide the device
    count; the round engine pads short/odd cohorts with masked ``k = 0``
    duplicate rows (they transmit nothing, the all-False wire mask excludes
    them from aggregation, and the engine discards their advanced state), so
    the function body itself needs no padding logic.

    Round-level CSE the split pipeline cannot do: the teacher side of every
    distillation KL (eq. 9) is a CONSTANT of the round, so its log-softmax
    is computed ONCE here — the broadcast teacher is reused across all C
    clients × ``distill_steps`` updates, the aggregated teacher across all
    ``server_distill_steps`` — instead of once per (model, step) as the
    per-step host pipeline does.  Bit-identical losses/gradients (the
    teacher carries no gradient).
    """
    use_h = client_cfg.lora is not None
    cached_kd = _distill_loss_cached_fn(
        client_cfg, temperature, lam, last_only, compute_dtype
    )
    client_round = _client_round_core(
        client_cfg, num_classes, lr=lr, weight_decay=weight_decay,
        distill_lr=distill_lr, temperature=temperature, lam=lam,
        restrict_to_support=restrict_to_support, local_steps=local_steps,
        distill_steps=distill_steps, last_only=last_only, gate_distill=True,
        kd_loss=cached_kd, compute_dtype=compute_dtype,
    )
    frozen_ax = None if shared_backbone else 0
    vm = jax.vmap(
        client_round, in_axes=(0, frozen_ax, 0, None, None, None, 0, None)
    )
    teacher_cache = _teacher_cache_fn(temperature, restrict_to_support, use_h)
    server_phase = make_server_phase_fn(
        server_cfg, vocab=client_cfg.vocab_size, distill_lr=distill_lr,
        temperature=temperature, lam=lam,
        restrict_to_support=restrict_to_support,
        server_distill_steps=server_distill_steps, aggregation=aggregation,
        send_h=send_h, last_only=last_only, use_kernels=use_kernels,
        quantize=quantize, compute_dtype=compute_dtype,
    )

    def client_phase(lora, frozen, opt, g_tokens, t_cache, g_valid,
                     batches, pub_tokens, ks):
        """Lines 3-11 for (a device's shard of) the cohort: the vmapped
        per-client round bodies + the sparse-wire sparsifier.  Everything
        here is per-client-independent, so it shards cleanly over the
        cohort axis; the wire it returns (plus the quantized wire's scale
        rows) is the ONLY client-phase product the (replicated) server
        phase reads besides ``h``."""
        lora, opt, last, h = vm(
            lora, frozen, opt, g_tokens, t_cache, g_valid, batches, pub_tokens
        )
        wire = sparsify_wire(last, ks, k_cap, quantize=quantize)
        scale = wire.scale if quantize else None
        return lora, opt, wire.values, wire.indices, wire.mask, scale, h

    if shard_clients:
        from jax.experimental.shard_map import shard_map

        from repro.sharding import COHORT_AXIS, cohort_mesh

        c, r = jax.sharding.PartitionSpec(COHORT_AXIS), jax.sharding.PartitionSpec()
        frozen_spec = r if shared_backbone else c
        client_phase = shard_map(
            client_phase,
            mesh=cohort_mesh(),
            in_specs=(c, frozen_spec, c, r, r, r, c, r, c),
            out_specs=(c, c, c, c, c, c, c),
            check_rep=False,
        )

    def fn(lora, frozen, opt, s_lora, s_frozen, s_opt,
           g_tokens, g_logits, g_h, g_valid, batches, pub_tokens, ks):
        # -- client phase (lines 3-11); broadcast teacher softmaxed ONCE,
        # then the whole phase device-parallel over the cohort axis when
        # shard_clients; the uplink leaves it as the sparse wire --
        lora, opt, w_values, w_indices, w_mask, w_scale, h = client_phase(
            lora, frozen, opt, g_tokens, teacher_cache(g_logits, g_h), g_valid,
            batches, pub_tokens, ks
        )
        # -- server phase (lines 13-16 + next-round broadcast), replicated --
        s_lora, s_opt, b_last, b_h, d_loss = server_phase(
            s_lora, s_frozen, s_opt, w_values, w_indices, w_mask, w_scale, h,
            ks, pub_tokens,
        )
        return (lora, opt, s_lora, s_opt, w_values, w_indices, w_scale,
                b_last, b_h, d_loss)

    return fn


# Host-eval batch size: make_eval_fn walks whole batches of this size and
# drops the remainder; the in-scan eval tap truncates its eval arrays with
# the SAME constant so both paths read the same samples.
EVAL_BATCH = 64


def _eval_correct_fn(cfg: ModelConfig, num_classes: int, last_only: bool) -> Callable:
    """correct(params, tokens, labels) -> () float32 count of correct
    last-position class predictions — the ONE copy of the eval math shared
    by the host-side batched evaluator and the in-scan eval tap (their 1e-6
    parity contract rests on this being literally the same function)."""

    def correct(params, tokens, labels):
        last, _ = last_logits(
            params, cfg, {"tokens": tokens}, last_only=last_only,
            head_cols=num_classes if last_only else None,
        )
        cls = class_logits(last, num_classes)
        return jnp.sum((jnp.argmax(cls, -1) == labels).astype(jnp.float32))

    return correct


@functools.lru_cache(maxsize=64)
def make_scan_eval_fn(
    cfg: ModelConfig, num_classes: int, *, last_only: bool = True
) -> Callable:
    """Traceable accuracy for the in-scan eval tap (``run_rounds``).

    acc(lora, frozen, tokens (B, L), labels (B,)) -> () float32 — the same
    per-sample math as :func:`make_eval_fn`'s batched host loop (shared via
    :func:`_eval_correct_fn`), traceable inside a ``lax.scan`` body.
    Unjitted: the multi-round driver traces it into the scanned round
    program.  Eval splits that divide :data:`EVAL_BATCH` are walked in
    ``lax.map`` chunks of that size — the host loop's bounded activation
    footprint, not one (B, L, d) forward over the whole split inside the
    compiled program (the per-chunk correct-counts are integers, so the
    chunked sum is exact).
    """
    correct = _eval_correct_fn(cfg, num_classes, last_only)

    def acc(lora, frozen, tokens, labels):
        params = merge_lora(lora, frozen)
        n = int(labels.shape[0])
        if n == 0 or n % EVAL_BATCH:
            # fail at trace time rather than silently diverge from the host
            # evaluator's whole-batch walk (the 1e-6 parity contract)
            raise ValueError(
                f"eval split must be a non-empty multiple of "
                f"EVAL_BATCH={EVAL_BATCH}, got {n}"
            )
        if n == EVAL_BATCH:
            total = correct(params, tokens, labels)
        else:
            tb = tokens.reshape((n // EVAL_BATCH, EVAL_BATCH) + tokens.shape[1:])
            lb = labels.reshape(n // EVAL_BATCH, EVAL_BATCH)
            total = jnp.sum(
                jax.lax.map(lambda tl: correct(params, tl[0], tl[1]), (tb, lb))
            )
        return total / n

    return acc


@functools.lru_cache(maxsize=64)
def make_eval_fn(
    cfg: ModelConfig,
    num_classes: int,
    *,
    batch_size: int = EVAL_BATCH,
    last_only: bool = True,
) -> Callable:
    """Accuracy over an IntentDataset (numpy arrays), batched + jitted."""

    batch_acc = jax.jit(_eval_correct_fn(cfg, num_classes, last_only))

    def evaluate(params, tokens, labels) -> float:
        n = tokens.shape[0]
        correct = 0.0
        for i in range(0, n - batch_size + 1, batch_size):
            correct += float(
                batch_acc(params, tokens[i : i + batch_size], labels[i : i + batch_size])
            )
        seen = (n // batch_size) * batch_size
        return correct / max(1, seen)

    return evaluate


def make_channel_step_fn() -> Callable:
    """One in-scan channel-dynamics step (``repro.core.scenario`` replica).

    channel_step(z, bad, w, u, base_snr_db, rho, p_gb, p_bg, fade_scale)
        -> (z', bad', snr_db)

    Pure jnp, traced into the multi-round scan body: the AR(1) fading carry
    ``z`` and Gilbert-Elliott outage carry ``bad`` evolve from the host's
    precomputed copula normals ``w`` and outage uniforms ``u``
    (:meth:`repro.core.channel.ChannelSimulator.scan_channel_inputs`).  All
    scenario parameters are f32 DATA operands — ``rho = 0`` replays the
    i.i.d. channel, ``fade_scale = 0`` a fading-free one, the
    i.i.d.-equivalent ``(p_gb, p_bg)`` a memoryless dropout coin — so ONE
    compiled executable serves every scenario preset.

    This is the observability replica of the host-side f64 realisation
    (the k/byte budgets stay host-side scalar math, ledger-exact); it taps
    each round's realised SNR/outage into the trajectory.  f32 recursion
    tracks the f64 chain to ~1e-2 dB over a scan block (the AR(1) map is
    contracting, so rounding does not accumulate).
    """

    def channel_step(z, bad, w, u, base_snr_db, rho, p_gb, p_bg, fade_scale):
        z = rho * z + jnp.sqrt(jnp.maximum(1.0 - rho * rho, 0.0)) * w
        u_fade = jnp.clip(jax.scipy.special.ndtr(z), 1e-7, 1.0 - 1e-7)
        power = -jnp.log1p(-u_fade)
        fade_db = 10.0 * jnp.log10(jnp.maximum(power, 1e-6))
        bad = jnp.where(bad, u < 1.0 - p_bg, u < p_gb)
        snr_db = jnp.where(bad, -jnp.inf, base_snr_db + fade_scale * fade_db)
        return z, bad, snr_db

    return channel_step
