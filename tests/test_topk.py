"""Top-k sparsification (paper eqs. 3-4)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topk import densify, topk_mask_dense, topk_sparsify


def test_topk_matches_lax_topk():
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 100))
    s = topk_sparsify(x, 7)
    want_v, want_i = jax.lax.top_k(x, 7)
    np.testing.assert_array_equal(s.values, want_v)
    np.testing.assert_array_equal(s.indices, want_i)
    assert s.k == 7 and s.vocab == 100


def test_k_clamped_to_vocab():
    x = jnp.ones((2, 8))
    s = topk_sparsify(x, 99)
    assert s.k == 8


def test_densify_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 50))
    s = topk_sparsify(x, 50)  # full k
    np.testing.assert_allclose(densify(s), x, rtol=0, atol=0)


def test_densify_zeros_off_support():
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 64)) + 10.0  # all positive
    d = densify(topk_sparsify(x, 5))
    assert int(jnp.sum(d != 0)) == 4 * 5
    # kept entries are the largest
    kth = jnp.sort(x, axis=-1)[:, -5]
    assert bool(jnp.all(jnp.where(d != 0, x >= kth[:, None], True)))


def test_topk_mask_dense_equals_sparsify_densify():
    x = jax.random.normal(jax.random.PRNGKey(3), (6, 40))
    np.testing.assert_allclose(
        topk_mask_dense(x, 9), densify(topk_sparsify(x, 9)), atol=0
    )


def test_sparsify_idempotent():
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 30)) + 5.0
    once = densify(topk_sparsify(x, 6))
    twice = densify(topk_sparsify(once, 6))
    np.testing.assert_allclose(once, twice, atol=0)
