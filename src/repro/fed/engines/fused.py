"""The fused single-jit client-phase engine."""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.channel import BatchedChannelState, ChannelState
from repro.fed import steps as fed_steps
from repro.fed.client import Client
from repro.fed.engines.base import (
    BroadcastState,
    ClientPhase,
    check_unique_cohort,
    fake_quant_dense,
)
from repro.fed.engines.batched import BatchedEngine
from repro.fed.store import FleetStore

__all__ = ["FusedEngine"]


class FusedEngine(BatchedEngine):
    """Single-jit round-body executor: the batched engine's per-phase calls
    (distill steps, fine-tune steps, public inference, top-k) collapse into
    ONE donated, compiled step per round (`fed_steps.make_fused_round_fn`).

    Per-client adaptive ``k`` enters the program as DATA (int32 per client),
    so one executable serves every round regardless of the channel
    realisation; the uplink sparsifier is the threshold-semantics bisection
    (ties at the k-th value are kept) — pure-jnp ``topk_mask_dynamic`` by
    default, or the per-row-budget Pallas kernel with ``use_kernels=True``.
    Byte accounting still uses the exact host-side ``k``s, so the ledger is
    identical to the other engines.

    ``shard_clients=True`` additionally places the leading client axis over
    the process's devices with ``shard_map``; a cohort that does not divide
    the device count is padded with masked duplicate rows (``k = 0`` — they
    transmit nothing, are excluded from aggregation, and their advanced
    state is discarded before the scatter-back).  On CPU this is testable
    via ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """

    name = "fused"

    def __init__(
        self,
        clients: list[Client],
        cfg: ModelConfig,
        *,
        num_classes: int,
        lr: float = 1e-3,
        distill_lr: float = 1e-3,
        temperature: float = 2.0,
        lam: float = 0.03,
        local_steps: int = 4,
        distill_steps: int = 2,
        restrict_to_support: bool = False,
        value_bits: int = 16,
        k_min: int = 1,
        last_only: bool = True,
        shard_clients: bool = False,
        use_kernels: bool = False,
        class_head_only: bool = True,
        quantize_wire: bool = False,
        compute_dtype: str = "float32",
        fleet_store: "str | FleetStore" = "device",
    ):
        super().__init__(
            clients, cfg, num_classes=num_classes, lr=lr, distill_lr=distill_lr,
            temperature=temperature, lam=lam, local_steps=local_steps,
            distill_steps=distill_steps, restrict_to_support=restrict_to_support,
            value_bits=value_bits, k_min=k_min, last_only=last_only,
            class_head_only=class_head_only, quantize_wire=quantize_wire,
            fleet_store=fleet_store,
        )
        self.shard_clients = shard_clients
        self.compute_dtype = compute_dtype

        def fused(n_distill: int):
            fn = fed_steps.make_fused_round_fn(
                cfg, num_classes, lr=lr, distill_lr=distill_lr,
                temperature=temperature, lam=lam,
                restrict_to_support=restrict_to_support,
                local_steps=local_steps, distill_steps=n_distill,
                shared_backbone=self._shared, last_only=last_only,
                use_kernels=use_kernels, class_head_only=class_head_only,
                compute_dtype=compute_dtype,
            )
            if shard_clients:
                fn = self._shard_over_clients(fn)
            return jax.jit(fn, donate_argnums=(0, 2))

        self._fused_warm = fused(distill_steps)
        self._fused_cold = fused(0)  # round 0: no broadcast knowledge yet

    def _shard_over_clients(self, fn):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.sharding import COHORT_AXIS, cohort_mesh

        c, r = P(COHORT_AXIS), P()
        frozen_spec = r if self._shared else c
        return shard_map(
            fn,
            mesh=cohort_mesh(),
            in_specs=(c, frozen_spec, c, r, r, r, c, r, c),
            out_specs=(c, c, c, c),
            check_rep=False,
        )

    def _pad_cohort(self, sel: Sequence[int], batches: dict):
        """THE masked k = 0 shard-padding contract, in one place (used by the
        fused client-phase round, the e2e whole round, and the e2e
        multi-round scan): a cohort that does not divide the device count is
        extended with duplicate rows of client ``sel[0]`` that ride at
        ``k = 0`` — they compute alongside the cohort but transmit nothing,
        and every caller discards their advanced state before it can be
        observed.  Their batches are COPIES (``sel[0]``'s rng stream
        advances exactly once).  Returns ``(pad, sel + pad dups, padded
        batches)``; a no-op (pad 0) unless ``shard_clients``."""
        pad = (-len(sel)) % jax.device_count() if self.shard_clients else 0
        if not pad:
            return 0, list(sel), batches
        batches = {
            key: jnp.concatenate([v, jnp.repeat(v[:1], pad, axis=0)])
            for key, v in batches.items()
        }
        return pad, list(sel) + [sel[0]] * pad, batches

    def prefetch_cohort(self, sel: Sequence[int]) -> None:
        """Prefetch hint, shard-padding aware: the store must stage exactly
        the rows :meth:`run_round` will fetch (``sel`` + its pad
        duplicates), or the hint misses."""
        sel = list(sel)
        if self.shard_clients and sel:
            pad = (-len(sel)) % jax.device_count()
            sel = sel + [sel[0]] * pad
        self._store.prefetch(sel)

    @staticmethod
    def _drop_pad(n: int, *trees):
        """Inverse of :meth:`_pad_cohort`: truncate every given pytree (or
        array, or None) back to the ``n`` real leading-cohort rows — the one
        place the 'pad state must never be observed' side of the contract
        lives."""
        out = tuple(jax.tree.map(lambda x: x[:n], t) for t in trees)
        return out if len(out) > 1 else out[0]

    def run_round(
        self,
        sel: Sequence[int],
        pub_tokens: jax.Array,
        bcast: BroadcastState | None,
        states: BatchedChannelState | Sequence[ChannelState],
        *,
        adaptive_k: bool,
        send_h: bool,
    ) -> ClientPhase:
        sel = check_unique_cohort(sel)
        cohort = [self.clients[i] for i in sel]
        states = list(states)
        batches = self._stacked_batches(cohort, step_major=False)  # (C, S, ...)
        pad, sel_call, batches = self._pad_cohort(sel, batches)
        idx, lora, frozen, opt = self._gather_cohort(sel_call)
        n_samples = int(pub_tokens.shape[0])
        ks = self._budgets(states, n_samples, adaptive_k, len(cohort), send_h)

        # -- the whole client phase: ONE compiled, donated call --
        if bcast is not None:
            step = self._fused_warm
            g_tokens, g_logits, g_h = bcast.tokens, bcast.logits, bcast.h
        else:
            step = self._fused_cold  # g_* operands are unused and DCE'd
            g_tokens, g_logits, g_h = pub_tokens, jnp.zeros(
                (n_samples, self.cfg.vocab_size), jnp.float32), None
        lora, opt, dense_all, h_all = step(
            lora, frozen, opt, g_tokens, g_logits, g_h, batches, pub_tokens,
            jnp.asarray(ks + [0] * pad, jnp.int32),
        )
        if pad:  # drop the padded rows before anything observes them
            lora, opt, dense_all, h_all, idx = self._drop_pad(
                len(cohort), lora, opt, dense_all, h_all, idx
            )

        active, payloads, rank = self._upload_manifests(
            cohort, states, ks, n_samples, send_h
        )
        dense = h_out = None
        if active:
            take = jnp.asarray(active) if len(active) < len(cohort) else None
            dense = dense_all if take is None else dense_all[take]
            if self.quantize_wire:
                dense = fake_quant_dense(dense)
            if rank is not None and h_all is not None:
                h_out = h_all if take is None else h_all[take]

        self._scatter_cohort(idx, lora, opt)
        return ClientPhase(dense=dense, h=h_out, payloads=payloads, ks=ks)
