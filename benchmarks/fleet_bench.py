"""Fleet-scale FleetStore benchmark (PR 9) — writes BENCH_fleet[.quick].json.

The claim under test: with ``fleet_store="host"`` the per-round cost of a
federated round is a function of the COHORT, not the fleet — a 100k-client
fleet (cohort 10) runs each round at (within noise of) the 10-client
fleet's latency, with the device-resident fleet footprint independent of N
(the shared frozen backbone only; cohort and prefetch buffers are
transient).  Four readings:

* ``device_n10``    — the default device store at N=10: the pre-PR-9
                      layout, whose device footprint is the whole stacked
                      fleet (the O(N) curve the host store removes).
* ``host_bit_identical`` — a host-store N=10 run replays the same cohort
                      sequence as the device-store run: per-round adaptive
                      k, payload bytes, and the FINAL fleet lora/opt state
                      must match exactly (the streamed rows round-trip
                      host<->device losslessly).
* ``fleet``         — the scale sweep: N in {10, 1k, 10k, 100k} host-store
                      fleets (template-row lazy init past N=10; a pool of
                      10 real client datasets cycles mod 10 — client RNG
                      streams are pool state, fleet trainable state is the
                      store's) at fixed cohort 10, timing run_round with
                      the round driver's prefetch pattern (hint round r+1
                      BEFORE fetching round r).
* ``ratios``        — per-N latency vs the N=10 host run, and the
                      flatness of the device-resident fleet bytes.

benchmarks/check_bench.py gates on this record: bit-identity true, device
bytes flat across N, and every latency ratio <= 1.15.

Run:  PYTHONPATH=src python benchmarks/fleet_bench.py [--quick]
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

COHORT = 10  # the paper's clients_per_round


class _CyclingClients:
    """A pool of ``len(base)`` real clients presented as an N-client
    fleet: dataset shards and RNG streams cycle mod the pool size, while
    the per-client TRAINABLE state stays truly per-client in the store
    (the only state that scales with N)."""

    def __init__(self, base):
        self._base = list(base)

    def __getitem__(self, i):
        return self._base[int(i) % len(self._base)]

    def __len__(self):
        return len(self._base)


def _build():
    from repro.configs.base import LoRAConfig
    from repro.configs.gpt2_paper import REDUCED_CLIENT
    from repro.data import make_banking77_like
    from repro.fed.client import Client
    from repro.models import init as model_init

    lora = LoRAConfig(rank=4, alpha=32.0, dropout=0.0, targets=("q", "v", "head"))
    cfg = REDUCED_CLIENT.with_overrides(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=1024, max_seq_len=32, lora=lora,
    )
    ds = make_banking77_like(
        vocab_size=cfg.vocab_size, seq_len=16, total=60 * COHORT + 100, seed=0
    )
    backbone = model_init(jax.random.PRNGKey(123), cfg)

    def cohort():
        return [
            Client(i, cfg, ds.subset(np.arange(i * 60, (i + 1) * 60)),
                   num_classes=ds.num_classes, seed=i, local_steps=2,
                   distill_steps=1, initial_params=backbone)
            for i in range(COHORT)
        ]

    pub = jnp.asarray(ds.tokens[-64:])
    return cfg, ds, cohort, pub


def _mk_engine(cohort, cfg, num_classes, fleet_store):
    from repro.fed.engine import FusedEngine

    return FusedEngine(
        cohort(), cfg, num_classes=num_classes, local_steps=2,
        distill_steps=1, fleet_store=fleet_store,
    )


def _drive(engine, sels, pub, states_for, *, collect=False):
    """Run one round per sel with the round driver's prefetch pattern
    (hint round r+1 BEFORE running round r).  Returns per-round wall
    times, and (ks, payload bytes) per round when ``collect``."""
    times, rows = [], []
    for r, sel in enumerate(sels):
        if r + 1 < len(sels):
            engine.prefetch_cohort(sels[r + 1])
        states = states_for(r)
        t0 = time.time()
        phase = engine.run_round(sel, pub, None, states,
                                 adaptive_k=True, send_h=True)
        if phase.dense is not None:
            jax.block_until_ready(phase.dense)
        times.append(time.time() - t0)
        if collect:
            rows.append((list(phase.ks),
                         [p.bytes for p in phase.payloads]))
    return times, rows


def _fleet_leaves(store):
    state = store.state_dict()
    return [np.asarray(x)
            for k in ("lora", "opt")
            for x in jax.tree.leaves(state[k])]


def bench_fleet(quick: bool = True, out_json: str | None = None):
    from repro.core import ChannelConfig, ChannelSimulator
    from repro.fed.store import HostFleetStore
    from repro.lora import split_lora

    cfg, ds, cohort, pub = _build()
    sim = ChannelSimulator(
        COHORT, ChannelConfig(bandwidth_hz=5e5, mean_snr_db=5.0), seed=0
    )
    # channel realisations are per cohort POSITION here (the bench fixes
    # the physical link pool, like the client pool)
    states_for = lambda r: sim.states_batched(r % 20, list(range(COHORT)))  # noqa: E731

    rounds = 3 if quick else 5
    warmup = 1
    ns = [10, 1_000, 10_000] if quick else [10, 1_000, 10_000, 100_000]

    # -- bit-identity: device vs host at N=10, same cohort sequence -------
    rng = np.random.default_rng(7)
    id_sels = [[int(x) for x in rng.permutation(COHORT)] for _ in range(4)]
    dev_eng = _mk_engine(cohort, cfg, ds.num_classes, "device")
    host_eng = _mk_engine(cohort, cfg, ds.num_classes, "host")
    _, dev_rows = _drive(dev_eng, id_sels, pub, states_for, collect=True)
    _, host_rows = _drive(host_eng, id_sels, pub, states_for, collect=True)
    bit_identical = dev_rows == host_rows and all(
        np.array_equal(a, b)
        for a, b in zip(_fleet_leaves(dev_eng._store),
                        _fleet_leaves(host_eng._store))
    )
    assert bit_identical, (
        "host-store N=10 run diverged from the device-store run "
        f"(ks/bytes match: {dev_rows == host_rows})"
    )
    dev_bytes_n10 = dev_eng._store.device_bytes()

    # -- scale sweep: host store, fixed cohort, growing fleet -------------
    lora0, frozen0 = split_lora(cohort()[0].params)
    opt0 = cohort()[0].opt
    fleet = {}
    for n in ns:
        eng = _mk_engine(cohort, cfg, ds.num_classes, "host")
        if n > COHORT:
            eng._store = HostFleetStore.from_template(
                lora0, frozen0, opt0, num_clients=n
            )
            eng.clients = _CyclingClients(eng.clients)
        rng = np.random.default_rng(1)
        sels = [sorted(int(x) for x in rng.choice(n, COHORT, replace=False))
                for _ in range(warmup + rounds)]
        times, _ = _drive(eng, sels, pub, states_for)
        fleet[str(n)] = {
            "sec_per_round": round(min(times[warmup:]), 4),
            "fleet_device_bytes": eng._store.device_bytes(),
            "fleet_host_bytes": eng._store.host_bytes(),
        }

    base = fleet[str(COHORT)]["sec_per_round"]
    dev_flat = [fleet[str(n)]["fleet_device_bytes"] for n in ns]
    ratios = {
        "latency_vs_n10": {
            str(n): round(fleet[str(n)]["sec_per_round"] / base, 3) for n in ns
        },
        "host_device_bytes_flat": round(max(dev_flat) / min(dev_flat), 4),
    }
    shape = (f"cohort={COHORT};L2;d64;V{cfg.vocab_size};T16;P64;steps=2+1;"
             f"rank{cfg.lora.rank}")

    if out_json:
        record = {
            "bench": "fleet_store",
            "shape": shape,
            "quick": quick,
            "rounds_timed": rounds,
            "backend": jax.default_backend(),
            "cpu_count": os.cpu_count(),
            "host_bit_identical": bool(bit_identical),
            "device_n10": {
                "fleet_device_bytes": dev_bytes_n10,
                "note": "pre-PR-9 layout: whole fleet stacked on device "
                        "(grows O(N); at N=100k this tree would be "
                        f"~{dev_bytes_n10 // COHORT * 100_000 / 1e9:.1f} GB)",
            },
            "fleet": fleet,
            "ratios": ratios,
            "notes": (
                "Host-store fleets at fixed cohort 10: N>10 fleets use "
                "HostFleetStore.from_template (calloc-backed lazy rows; "
                "resident memory scales with committed rows) over a pool "
                "of 10 real client datasets cycling mod 10 — trainable "
                "state is truly per-client in the store.  Rounds run with "
                "the driver's prefetch pattern (hint r+1 before round r); "
                "min-of-rounds on this noisy CPU container.  "
                "fleet_device_bytes = device-RESIDENT fleet footprint "
                "between rounds (shared frozen backbone only for the host "
                "store — flat in N); fleet_host_bytes is the host stack's "
                "address-space size (calloc: mostly untouched pages at "
                "large N).  host_bit_identical: device- and host-store "
                "N=10 runs produced identical per-round k, payload bytes, "
                "and final fleet lora/opt state."
            ),
        }
        with open(out_json, "w") as f:
            json.dump(record, f, indent=1)

    rows = [("fleet_device_n10_bytes", dev_bytes_n10, shape)]
    for n in ns:
        e = fleet[str(n)]
        rows.append((
            f"fleet_host_n{n}_round",
            e["sec_per_round"] * 1e6,
            f"{shape};dev_bytes={e['fleet_device_bytes']}"
            f";vs_n10={ratios['latency_vs_n10'][str(n)]:.2f}x",
        ))
    return rows


if __name__ == "__main__":
    quick = "--quick" in sys.argv
    suffix = "quick.json" if quick else "json"
    out = os.path.join(_REPO_ROOT, f"BENCH_fleet.{suffix}")
    for name, us, derived in bench_fleet(quick=quick, out_json=out):
        print(f"{name},{us:.0f},{derived}")
    with open(out) as f:
        rec = json.load(f)
    for n, r in rec["ratios"]["latency_vs_n10"].items():
        print(f"latency N={n} vs N=10: {r:.2f}x")
    print(f"device-bytes flatness: {rec['ratios']['host_device_bytes_flat']}")
    print(f"-> {out}")
