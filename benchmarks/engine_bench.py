"""Round-engine benchmark: sequential vs batched vs fused client-phase
wall-clock, plus the PR-1 full-head batched engine as the historical
reference.

The paper's Algorithm 1 selects 10 of 50 clients per round.  Engines:

  sequential   — one jitted call per client per step (O(C*steps) dispatches)
  batched      — vmapped per-phase steps (O(steps) dispatches), last-only head
  batched_pr1  — the PR-1 batched engine: same structure but the LM head
                 materialises the full (B, T, V) logits each phase
  fused        — ONE donated jitted call for the whole client phase
                 (distill -> fine-tune -> public inference -> adaptive top-k
                 with k as data), last-only head

At vocab >= 8k the (B, T, V) head is the dominant FLOP term, so the
last-only head (a ~T× cut on that term) is where the fused/batched engines
gain; the fused engine additionally removes per-phase dispatch/host
round-trips.  The headline ratio is fused vs batched_pr1 — new engine
against what shipped in PR 1 on identical state.

Caveat for CPU readings: XLA's CPU backend lowers cohort-batched matmuls as
loops of per-client GEMMs, so client-axis batching itself is roughly neutral
here (see PR 1 README notes); the speedups below come from the head cut and
dispatch fusion, which ARE realised on this machine.  The ratio printed is
an honest measurement of THIS machine, not an accelerator projection.

Run:  PYTHONPATH=src python -m benchmarks.run --only engine
  or: PYTHONPATH=src python benchmarks/engine_bench.py [--quick]
      (writes BENCH_engine.json next to the repo root)
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _build(num_clients: int, *, d_model: int, vocab: int, seq_len: int):
    from repro.configs.base import LoRAConfig
    from repro.configs.gpt2_paper import REDUCED_CLIENT
    from repro.data import make_banking77_like
    from repro.fed.client import Client
    from repro.fed.engine import BatchedEngine, BroadcastState, FusedEngine, SequentialEngine

    lora = LoRAConfig(rank=8, alpha=32.0, dropout=0.0, targets=("q", "v", "head"))
    cfg = REDUCED_CLIENT.with_overrides(
        num_layers=2, d_model=d_model, num_heads=4, num_kv_heads=4,
        d_ff=2 * d_model, vocab_size=vocab, max_seq_len=max(seq_len, 32), lora=lora,
    )
    ds = make_banking77_like(vocab_size=vocab, seq_len=seq_len, total=60 * num_clients + 200, seed=0)

    # One shared pretrained-like backbone W' under per-client LoRA deltas —
    # the paper's setting, and what run_federated produces after pretraining.
    from repro.models import init as model_init

    backbone = model_init(jax.random.PRNGKey(123), cfg)

    def cohort():
        return [
            Client(i, cfg, ds.subset(np.arange(i * 60, (i + 1) * 60)),
                   num_classes=ds.num_classes, seed=i, local_steps=4, distill_steps=2,
                   initial_params=backbone)
            for i in range(num_clients)
        ]

    pub = jnp.asarray(ds.tokens[-64:])
    g_logits = jax.random.normal(jax.random.PRNGKey(0), (pub.shape[0], vocab))
    g_h = jax.random.normal(jax.random.PRNGKey(1), (pub.shape[0], lora.rank))
    bcast = BroadcastState(tokens=pub, logits=g_logits, h=g_h, bits=0)

    mk = dict(num_classes=ds.num_classes, local_steps=4, distill_steps=2)
    engines = {
        "sequential": SequentialEngine(cohort(), cfg),
        "batched": BatchedEngine(cohort(), cfg, **mk),
        "batched_pr1": BatchedEngine(cohort(), cfg, last_only=False, **mk),
        "fused": FusedEngine(cohort(), cfg, **mk),
    }
    return cfg, engines, pub, bcast


def _time_round(engine, sel, pub, bcast, states, reps: int) -> float:
    # warm-up: compile every step shape this engine will touch
    engine.run_round(sel, pub, bcast, states, adaptive_k=True, send_h=True)
    t0 = time.time()
    for _ in range(reps):
        phase = engine.run_round(sel, pub, bcast, states, adaptive_k=True, send_h=True)
        if phase.dense is not None:
            jax.block_until_ready(phase.dense)
    return (time.time() - t0) / reps * 1e6  # us per client phase


def bench(quick: bool = True, out_json: str | None = None):
    """Rows: (name, us_per_round_client_phase, derived)."""
    from repro.core import ChannelConfig, ChannelSimulator

    num_clients = 10  # the paper's clients_per_round
    # vocab >= 8k: the regime the last-only head targets (paper-scale heads
    # are 50k-256k; 8k keeps the full-head PR-1 reference benchable on CPU)
    d_model, vocab, seq_len = (64, 8192, 16) if quick else (128, 8192, 16)
    reps = 2 if quick else 3

    cfg, engines, pub, bcast = _build(
        num_clients, d_model=d_model, vocab=vocab, seq_len=seq_len
    )
    sim = ChannelSimulator(num_clients, ChannelConfig(bandwidth_hz=5e5, mean_snr_db=5.0), seed=0)
    sel = list(range(num_clients))
    states = sim.states_batched(0, sel)

    us = {
        name: _time_round(eng, sel, pub, bcast, states, reps)
        for name, eng in engines.items()
    }
    speedups = {
        "fused_vs_batched_pr1": us["batched_pr1"] / us["fused"],
        "fused_vs_batched": us["batched"] / us["fused"],
        "batched_vs_batched_pr1": us["batched_pr1"] / us["batched"],
        "fused_vs_sequential": us["sequential"] / us["fused"],
    }
    shape = f"C={num_clients};L2;d{d_model};V{vocab};T{seq_len};steps=4+2"

    if out_json:
        record = {
            "bench": "engine_round",
            "shape": shape,
            "quick": quick,
            "reps": reps,
            "backend": jax.default_backend(),
            "cpu_count": os.cpu_count(),
            "us_per_client_phase": {k: round(v) for k, v in us.items()},
            "speedups": {k: round(v, 2) for k, v in speedups.items()},
            "notes": (
                "batched_pr1 = PR-1 full-(B,T,V)-head batched engine; "
                "fused/batched use the last-only LM head.  CPU container "
                "measurement (XLA CPU lowers cohort-batched GEMMs as loops)."
            ),
        }
        with open(out_json, "w") as f:
            json.dump(record, f, indent=1)

    return [
        ("engine_sequential_round", us["sequential"], shape),
        ("engine_batched_round", us["batched"], shape),
        ("engine_batched_pr1_round", us["batched_pr1"], f"{shape};full-head"),
        ("engine_fused_round", us["fused"],
         f"{shape};vs_pr1={speedups['fused_vs_batched_pr1']:.2f}x"),
    ]


if __name__ == "__main__":
    quick = "--quick" in sys.argv
    # quick runs get their own file so they never clobber the committed
    # full-size record that README cites
    out = os.path.join(
        _REPO_ROOT, "BENCH_engine.quick.json" if quick else "BENCH_engine.json"
    )
    rows = bench(quick=quick, out_json=out)
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    with open(out) as f:
        rec = json.load(f)
    for k, v in rec["speedups"].items():
        print(f"{k}: {v:.2f}x")
    print(f"-> {out}")
