"""Client-phase execution engines for the federated round loop.

The paper's Algorithm 1 runs the selected cohort's client work (local
distillation, local fine-tuning, public-set inference + adaptive Top-k
upload) independently per client — embarrassingly parallel across the
cohort.  Two interchangeable engines execute that phase:

* :class:`SequentialEngine` — the reference implementation: a Python loop
  over clients, one jitted step per client (the seed repo's behaviour).
* :class:`BatchedEngine` — keeps the fleet's LoRA/optimizer state stacked
  along a leading client axis and runs every phase as a single
  ``jax.vmap``-ed, ``jax.jit``-compiled, donated-buffer step: host
  dispatches per round drop from O(C·steps) to O(steps), and the client
  axis is the handle accelerator backends parallelise over (vmap →
  pmap/shard_map), which is what stops wall-clock scaling linearly with
  ``clients_per_round`` at the paper's cohort sizes.
* :class:`FusedEngine` — collapses the batched engine's per-phase calls
  into ONE donated, jitted round body (distill → fine-tune → public
  last-position inference → adaptive Top-k with the budget as data): host
  dispatches per round drop to O(1), and the client axis can optionally be
  placed over devices with ``jax.experimental.shard_map``
  (``shard_clients=True``; testable on CPU via
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).

All engines are driven by :func:`repro.fed.rounds.run_federated`.
Sequential and batched are bit-compatible under the same seed; the fused
engine is tolerance-compatible: identical per-client adaptive ``k`` and
ledger bytes (the budget math is the same host-side scalar code), while
accuracies/logits may drift by float round-off because XLA fuses the whole
round into one program (different op scheduling) and the uplink
sparsifier uses threshold semantics (exact ties at the k-th value are all
kept — measure-zero for real logits).  Batches are drawn through the same
per-client RNG streams in every engine.

Straggler semantics (all engines): a client whose channel state yields
``k == 0`` transmits nothing — it contributes zero uplink bytes and is
excluded from the aggregation stack entirely rather than zero-padded in.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.channel import BatchedChannelState, ChannelState, topk_budget_batch
from repro.core.protocol import UplinkPayload, downlink_bits, lora_projection_bits
from repro.core.topk import (
    QUANT_LEVELS,
    QuantizedWire,
    SparseWire,
    concat_wires,
    densify,
    take_wire_rows,
    topk_mask_batch,
)
from repro.fed import steps as fed_steps
from repro.fed.client import Client, make_upload_payload
from repro.lora import merge_lora, split_lora

__all__ = [
    "BroadcastState",
    "ClientPhase",
    "RoundsTrajectory",
    "SequentialEngine",
    "BatchedEngine",
    "FusedEngine",
    "FusedE2EEngine",
    "HeteroClientEngine",
    "HeteroFusedE2EEngine",
    "make_engine",
    "tree_stack",
    "k_cap_bucket",
    "cohort_budgets",
]


def cohort_budgets(
    states,
    cfg: ModelConfig,
    n_samples: int,
    adaptive_k: bool,
    n_cohort: int,
    send_h: bool = False,
    *,
    value_bits: int = 16,
    k_min: int = 1,
    quantize_wire: bool = False,
) -> list[int]:
    """Per-client adaptive k for a cohort — ONE host-side scalar routine
    shared by every engine (and by the fault layer, which must price
    attempted uploads with exactly the engines' k math so HARQ retries and
    quarantine decisions can never drift from what the engine transmits).

    With ``send_h`` the LoRA-projection bits are reserved out of each
    budget first (see :meth:`repro.fed.client.Client.upload`).  Under
    ``quantize_wire`` the (value, index) entries are priced at 8 value
    bits — the same Shannon budget genuinely affords a larger k — while
    the unquantized projection stays at ``value_bits``.
    """
    if not adaptive_k:
        return [cfg.vocab_size] * n_cohort
    reserved = (
        lora_projection_bits(n_samples, cfg.lora.rank, value_bits)
        if (send_h and cfg.lora is not None)
        else 0
    )
    wire_bits = 8 if quantize_wire else value_bits
    return topk_budget_batch(
        states, vocab_size=cfg.vocab_size, num_samples=n_samples,
        value_bits=wire_bits, k_min=k_min, reserved_bits=reserved,
    )


def k_cap_bucket(ks: Sequence[int], vocab: int) -> int:
    """Static sparse-wire width for a round: the next power of two >=
    max(ks), clamped to the vocabulary.  Bucketing keeps the number of
    distinct compiled round executables at O(log2 V) while the adaptive
    budgets themselves stay DATA (the transmit mask)."""
    need = max([k for k in ks] + [1])
    cap = 1
    while cap < need:
        cap *= 2
    return min(cap, vocab)


def _channel_scan_ops(channel_scan: dict, num_rounds: int) -> tuple:
    """Validate + device-stage a ``scan_channel_inputs`` dict for the
    multi-round drivers: (z0, bad0, w, u, base_snr_db, rho, p_gb, p_bg,
    fade_scale).  Every element is DATA — the drivers compile one channel
    program for all scenarios."""
    try:
        w = np.asarray(channel_scan["w"])
    except KeyError as e:
        raise ValueError(f"channel_scan is missing key {e}") from None
    if w.ndim != 2 or w.shape[0] < num_rounds:
        raise ValueError(
            f"channel_scan covers {w.shape[0] if w.ndim == 2 else '?'} "
            f"rounds, need {num_rounds} "
            "(ChannelSimulator.scan_channel_inputs(num_rounds))"
        )
    return (
        jnp.asarray(channel_scan["z0"], jnp.float32),
        jnp.asarray(channel_scan["bad0"], bool),
        jnp.asarray(w[:num_rounds], jnp.float32),
        jnp.asarray(np.asarray(channel_scan["u"])[:num_rounds], jnp.float32),
        jnp.asarray(
            np.asarray(channel_scan["base_snr_db"])[:num_rounds], jnp.float32
        ),
        jnp.asarray(channel_scan["rho"], jnp.float32),
        jnp.asarray(channel_scan["p_gb"], jnp.float32),
        jnp.asarray(channel_scan["p_bg"], jnp.float32),
        jnp.asarray(channel_scan["fade_scale"], jnp.float32),
    )


def fake_quant_dense(dense: jax.Array) -> jax.Array:
    """Quantize-dequantize a densified top-k stack through the int8 wire's
    per-(client, sample)-row symmetric code — what the dense-path engines
    (batched/fused client phase) apply under ``quantize_wire`` so their
    uplink carries exactly the values the 8-bit-per-entry ledger prices.
    Zeros (off-support entries) map to exact zeros, so the support is
    preserved."""
    amax = jnp.max(jnp.abs(dense), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / QUANT_LEVELS, 1.0)
    q = jnp.clip(jnp.round(dense / scale), -QUANT_LEVELS, QUANT_LEVELS)
    return q * scale


def tree_stack(trees: Sequence) -> object:
    """Stack a list of identically-structured pytrees along a new leading
    (client) axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def shared_frozen_backbone(frozens: Sequence) -> bool:
    """True iff every client's frozen tree is literally the same arrays —
    the paper's setting (one pretrained W' under per-client LoRA deltas).
    Identity, not value comparison: O(leaves), no device work."""
    first = jax.tree.leaves(frozens[0])
    for other in frozens[1:]:
        leaves = jax.tree.leaves(other)
        if len(leaves) != len(first) or any(a is not b for a, b in zip(first, leaves)):
            return False
    return True


@dataclasses.dataclass(frozen=True)
class BroadcastState:
    """The server's knowledge broadcast carried across rounds (Fig. 1 step 1).

    Replaces the fragile ``pub_tokens_prev`` / ``g_bits`` forward references:
    the public tokens the knowledge was computed on travel *with* the logits
    they explain, and the downlink cost is accounted from the same object.
    """

    tokens: jax.Array  # (P, L) public batch the knowledge was inferred on
    logits: jax.Array  # (P, V) global logits K_g
    h: jax.Array | None  # (P, r) global LoRA projection h_g
    bits: int  # on-air size of one broadcast to one client


@dataclasses.dataclass
class ClientPhase:
    """Result of one round's client phase, engine-agnostic.

    ``dense``/``h`` hold only the ``num_transmitters`` clients that actually
    uploaded (leading axis), in cohort order; ``ks`` covers every *selected*
    client (0 marks a dropped straggler).  The fused-e2e engine reports the
    uplink as the sparse wire format instead (``sparse``; ``dense`` stays
    None — no (T, P, V) stack exists on that path).
    """

    dense: jax.Array | None  # (T, P, V) densified top-k logits
    h: jax.Array | None  # (T, P, r) LoRA projections
    payloads: list[UplinkPayload]
    ks: list[int]
    # (T, P, k_cap) wire — QuantizedWire under the engines' quantize_wire
    sparse: SparseWire | QuantizedWire | None = None

    @property
    def uplink_bytes(self) -> float:
        return float(sum(p.bytes for p in self.payloads))

    @property
    def num_transmitters(self) -> int:
        return len(self.payloads)


@dataclasses.dataclass
class RoundsTrajectory:
    """Per-round observables of one :meth:`FusedE2EEngine.run_rounds` block.

    ``ks``/``payloads`` are the host-side accounting (identical to what R
    ``run_round`` calls report); ``mean_k``, ``distill_loss`` and — when
    eval data was passed — ``server_acc``/``client_acc`` come from the
    IN-SCAN eval tap: they are scanned outputs of the single compiled
    multi-round dispatch, not host round-trips.  ``distill_loss`` is the
    round's final server-distill step loss (NaN for an all-dropped round —
    the server never distilled).

    Heterogeneous blocks (:meth:`HeteroFusedE2EEngine.run_rounds`)
    additionally fill ``family_client_acc``: per round, one accuracy per
    family bucket (fleet bucket order), each evaluated on that bucket's
    first selected client of the round (or its bucket-local client 0 when
    the family sat the round out).  ``client_acc`` remains the cohort's
    first selected client — the host loop's metric — which is always one of
    those family entries.
    """

    ks: list[list[int]]
    payloads: list[list[UplinkPayload]]
    mean_k: list[float]
    distill_loss: list[float]
    server_acc: list[float] | None = None
    client_acc: list[float] | None = None
    family_client_acc: list[list[float]] | None = None
    # Scenario runs only (``channel_scan`` passed): the in-scan channel
    # replica's per-round realised cohort SNR (dB, -inf in outage) and
    # Gilbert-Elliott outage flags — scanned outputs of the same compiled
    # dispatch, evolved from the channel carry (f32 replica of the host
    # realisation that priced ``ks``/``payloads``).
    snr_db: list[list[float]] | None = None
    outage: list[list[bool]] | None = None


class SequentialEngine:
    """Reference client-phase executor: one client at a time (Algorithm 1
    exactly as written)."""

    name = "sequential"

    def __init__(
        self,
        clients: list[Client],
        cfg: ModelConfig,
        *,
        value_bits: int = 16,
        k_min: int = 1,
        **_unused,
    ):
        self.clients = clients
        self.cfg = cfg
        self.value_bits = value_bits
        self.k_min = k_min

    def client_params(self, cid: int):
        """Current parameters of one client (for evaluation)."""
        return self.clients[cid].params

    def fleet_state(self) -> dict:
        """The whole fleet's trainable state as one checkpointable pytree.
        Per-client subtrees (not a stacked axis): the sequential engine
        serves mixed-architecture fleets natively, so client leaves need
        not share shapes."""
        return {
            f"client{i}": {"params": c.params, "opt": c.opt}
            for i, c in enumerate(self.clients)
        }

    def load_fleet_state(self, state: dict) -> None:
        for i, c in enumerate(self.clients):
            c.params = jax.tree.map(jnp.asarray, state[f"client{i}"]["params"])
            c.opt = jax.tree.map(jnp.asarray, state[f"client{i}"]["opt"])

    def run_round(
        self,
        sel: Sequence[int],
        pub_tokens: jax.Array,
        bcast: BroadcastState | None,
        states: BatchedChannelState | Sequence[ChannelState],
        *,
        adaptive_k: bool,
        send_h: bool,
    ) -> ClientPhase:
        cohort = [self.clients[i] for i in sel]
        if bcast is not None:
            for c in cohort:
                c.local_distill(bcast.tokens, bcast.logits, bcast.h)
        dense_rows, hs, payloads, ks = [], [], [], []
        for c, st in zip(cohort, states):
            c.local_train()
            up = c.upload(
                pub_tokens,
                st,
                value_bits=self.value_bits,
                k_override=None if adaptive_k else self.cfg.vocab_size,
                send_h=send_h,
                k_min=self.k_min,
            )
            if up is None:  # straggler in outage: transmits nothing
                ks.append(0)
                continue
            ks.append(up.k)
            dense_rows.append(densify(up.sparse))
            if up.h is not None:
                hs.append(up.h)
            payloads.append(up.payload)
        return ClientPhase(
            dense=jnp.stack(dense_rows) if dense_rows else None,
            h=jnp.stack(hs) if hs else None,
            payloads=payloads,
            ks=ks,
        )


class BatchedEngine:
    """Batched client-phase executor: the whole cohort advances through each
    phase as one compiled step over a leading client axis.

    The fleet's trainable state lives STACKED on this engine: at
    construction every client's LoRA tree and optimizer state are stacked
    along a leading ``(num_clients, ...)`` axis (the frozen backbone is kept
    as one shared tree when all clients ride the same pretrained W' — the
    paper's setting — or stacked otherwise).  A round then gathers the
    selected cohort's rows with ONE gather per leaf, runs the vmapped
    phases, and scatters the advanced rows back — no per-client
    stack/unstack/merge churn on the hot path.  The engine is the source of
    truth for client parameters while it is in use; read them back through
    :meth:`client_params`.
    """

    name = "batched"

    def __init__(
        self,
        clients: list[Client],
        cfg: ModelConfig,
        *,
        num_classes: int,
        lr: float = 1e-3,
        distill_lr: float = 1e-3,
        temperature: float = 2.0,
        lam: float = 0.03,
        local_steps: int = 4,
        distill_steps: int = 2,
        restrict_to_support: bool = False,
        value_bits: int = 16,
        k_min: int = 1,
        last_only: bool = True,
        class_head_only: bool = True,
        quantize_wire: bool = False,
    ):
        self.clients = clients
        self.cfg = cfg
        self.local_steps = local_steps
        self.distill_steps = distill_steps
        self.value_bits = value_bits
        self.k_min = k_min
        self.last_only = last_only
        self.quantize_wire = quantize_wire

        loras, frozens = zip(*(split_lora(c.params) for c in clients))
        self._shared = shared_frozen_backbone(frozens)
        self._lora = tree_stack(loras)  # (N, ...)
        self._frozen = frozens[0] if self._shared else tree_stack(frozens)
        self._opt = tree_stack([c.opt for c in clients])
        self._train = fed_steps.make_batched_finetune_step(
            cfg, num_classes, lr=lr, shared_backbone=self._shared, last_only=last_only,
            class_head_only=class_head_only,
        )
        self._distill = fed_steps.make_batched_distill_step(
            cfg, lr=distill_lr, temperature=temperature, lam=lam,
            restrict_to_support=restrict_to_support, shared_backbone=self._shared,
            last_only=last_only,
        )
        self._public = fed_steps.make_batched_public_logits(
            cfg, shared_backbone=self._shared, last_only=last_only
        )

    def client_params(self, cid: int):
        """Materialise one client's merged params (for evaluation)."""
        lora_i = jax.tree.map(lambda x: x[cid], self._lora)
        frozen_i = (
            self._frozen if self._shared
            else jax.tree.map(lambda x: x[cid], self._frozen)
        )
        return merge_lora(lora_i, frozen_i)

    def fleet_state(self) -> dict:
        """The engine-held fleet state as one checkpointable pytree.  The
        frozen backbone is included so a restored run never depends on the
        construction path reproducing it (it does today, but checkpoints
        should stand alone)."""
        return {"lora": self._lora, "opt": self._opt, "frozen": self._frozen}

    def load_fleet_state(self, state: dict) -> None:
        as_jax = lambda tree: jax.tree.map(jnp.asarray, tree)  # noqa: E731
        self._lora = as_jax(state["lora"])
        self._opt = as_jax(state["opt"])
        self._frozen = as_jax(state["frozen"])

    # -- round plumbing shared by the batched and fused engines ----------
    def _gather_cohort(self, sel: Sequence[int]):
        """One gather per leaf: the selected cohort's (lora, frozen, opt)."""
        idx = jnp.asarray(list(sel))
        lora = jax.tree.map(lambda x: x[idx], self._lora)
        opt = jax.tree.map(lambda x: x[idx], self._opt)
        frozen = (
            self._frozen if self._shared
            else jax.tree.map(lambda x: x[idx], self._frozen)
        )
        return idx, lora, frozen, opt

    def _scatter_cohort(self, idx, lora, opt) -> None:
        """Write the advanced cohort rows back into the fleet state."""
        self._lora = jax.tree.map(
            lambda full, new: full.at[idx].set(new), self._lora, lora
        )
        self._opt = jax.tree.map(
            lambda full, new: full.at[idx].set(new), self._opt, opt
        )

    def _budgets(
        self, states, n_samples: int, adaptive_k: bool, n_cohort: int,
        send_h: bool = False,
    ):
        """Per-client adaptive k — delegates to the module-level
        :func:`cohort_budgets` (the same host-side scalar math as the
        sequential reference, so k and bytes can never drift)."""
        return cohort_budgets(
            states, self.cfg, n_samples, adaptive_k, n_cohort, send_h,
            value_bits=self.value_bits, k_min=self.k_min,
            quantize_wire=self.quantize_wire,
        )

    def _upload_manifests(self, cohort, states, ks, n_samples: int, send_h: bool):
        """(active indices, payload manifests, lora rank) for the k > 0
        transmitters — dropped stragglers contribute nothing."""
        active = [i for i, k in enumerate(ks) if k > 0]
        payloads: list[UplinkPayload] = []
        rank = None
        for i in active:
            payload, rank = make_upload_payload(
                self.cfg, cohort[i].client_id, n_samples, ks[i],
                send_h=send_h, value_bits=self.value_bits,
                snr_db=states[i].snr_db, quantize=self.quantize_wire,
            )
            payloads.append(payload)
        return active, payloads, rank

    def _stacked_batches(self, cohort, *, step_major: bool):
        """Each client's next ``local_steps`` private batches, drawn through
        its OWN rng stream (identical to the sequential path).  Returns a
        list of step-major dicts (one per step) or one client-major dict
        with a (C, S, ...) leading layout."""
        per_client = [c.next_train_batches(self.local_steps) for c in cohort]
        keys = per_client[0][0].keys()
        if step_major:
            return [
                {key: jnp.asarray(np.stack([b[s][key] for b in per_client]))
                 for key in keys}
                for s in range(self.local_steps)
            ]
        return {
            key: jnp.asarray(
                np.stack([np.stack([b[s][key] for s in range(self.local_steps)])
                          for b in per_client])
            )
            for key in keys
        }

    def run_round(
        self,
        sel: Sequence[int],
        pub_tokens: jax.Array,
        bcast: BroadcastState | None,
        states: BatchedChannelState | Sequence[ChannelState],
        *,
        adaptive_k: bool,
        send_h: bool,
    ) -> ClientPhase:
        cohort = [self.clients[i] for i in sel]
        states = list(states)
        idx, lora, frozen, opt = self._gather_cohort(sel)

        # -- lines 5-7: cohort distillation against the shared broadcast --
        if bcast is not None:
            for _ in range(self.distill_steps):
                lora, opt, _ = self._distill(
                    lora, frozen, opt, bcast.tokens, bcast.logits, bcast.h
                )

        # -- line 8: local fine-tuning, one vmapped update per step --
        for jb in self._stacked_batches(cohort, step_major=True):
            lora, opt, _ = self._train(lora, frozen, opt, jb)

        # -- lines 9-11: public inference + per-client adaptive top-k --
        n_samples = int(pub_tokens.shape[0])
        ks = self._budgets(states, n_samples, adaptive_k, len(cohort), send_h)

        logits, h = self._public(lora, frozen, pub_tokens)  # (C, P, V), (C, P, r)|None

        active, payloads, rank = self._upload_manifests(
            cohort, states, ks, n_samples, send_h
        )
        dense = h_out = None
        if active:
            take = jnp.asarray(active) if len(active) < len(cohort) else None
            act_logits = logits if take is None else logits[take]
            dense = topk_mask_batch(act_logits, [ks[i] for i in active])
            if self.quantize_wire:
                dense = fake_quant_dense(dense)
            if rank is not None and h is not None:
                h_out = h if take is None else h[take]

        self._scatter_cohort(idx, lora, opt)
        return ClientPhase(dense=dense, h=h_out, payloads=payloads, ks=ks)


class FusedEngine(BatchedEngine):
    """Single-jit round-body executor: the batched engine's per-phase calls
    (distill steps, fine-tune steps, public inference, top-k) collapse into
    ONE donated, compiled step per round (`fed_steps.make_fused_round_fn`).

    Per-client adaptive ``k`` enters the program as DATA (int32 per client),
    so one executable serves every round regardless of the channel
    realisation; the uplink sparsifier is the threshold-semantics bisection
    (ties at the k-th value are kept) — pure-jnp ``topk_mask_dynamic`` by
    default, or the per-row-budget Pallas kernel with ``use_kernels=True``.
    Byte accounting still uses the exact host-side ``k``s, so the ledger is
    identical to the other engines.

    ``shard_clients=True`` additionally places the leading client axis over
    the process's devices with ``shard_map``; a cohort that does not divide
    the device count is padded with masked duplicate rows (``k = 0`` — they
    transmit nothing, are excluded from aggregation, and their advanced
    state is discarded before the scatter-back).  On CPU this is testable
    via ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """

    name = "fused"

    def __init__(
        self,
        clients: list[Client],
        cfg: ModelConfig,
        *,
        num_classes: int,
        lr: float = 1e-3,
        distill_lr: float = 1e-3,
        temperature: float = 2.0,
        lam: float = 0.03,
        local_steps: int = 4,
        distill_steps: int = 2,
        restrict_to_support: bool = False,
        value_bits: int = 16,
        k_min: int = 1,
        last_only: bool = True,
        shard_clients: bool = False,
        use_kernels: bool = False,
        class_head_only: bool = True,
        quantize_wire: bool = False,
        compute_dtype: str = "float32",
    ):
        super().__init__(
            clients, cfg, num_classes=num_classes, lr=lr, distill_lr=distill_lr,
            temperature=temperature, lam=lam, local_steps=local_steps,
            distill_steps=distill_steps, restrict_to_support=restrict_to_support,
            value_bits=value_bits, k_min=k_min, last_only=last_only,
            class_head_only=class_head_only, quantize_wire=quantize_wire,
        )
        self.shard_clients = shard_clients
        self.compute_dtype = compute_dtype

        def fused(n_distill: int):
            fn = fed_steps.make_fused_round_fn(
                cfg, num_classes, lr=lr, distill_lr=distill_lr,
                temperature=temperature, lam=lam,
                restrict_to_support=restrict_to_support,
                local_steps=local_steps, distill_steps=n_distill,
                shared_backbone=self._shared, last_only=last_only,
                use_kernels=use_kernels, class_head_only=class_head_only,
                compute_dtype=compute_dtype,
            )
            if shard_clients:
                fn = self._shard_over_clients(fn)
            return jax.jit(fn, donate_argnums=(0, 2))

        self._fused_warm = fused(distill_steps)
        self._fused_cold = fused(0)  # round 0: no broadcast knowledge yet

    def _shard_over_clients(self, fn):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.sharding import COHORT_AXIS, cohort_mesh

        c, r = P(COHORT_AXIS), P()
        frozen_spec = r if self._shared else c
        return shard_map(
            fn,
            mesh=cohort_mesh(),
            in_specs=(c, frozen_spec, c, r, r, r, c, r, c),
            out_specs=(c, c, c, c),
            check_rep=False,
        )

    def _pad_cohort(self, sel: Sequence[int], batches: dict):
        """THE masked k = 0 shard-padding contract, in one place (used by the
        fused client-phase round, the e2e whole round, and the e2e
        multi-round scan): a cohort that does not divide the device count is
        extended with duplicate rows of client ``sel[0]`` that ride at
        ``k = 0`` — they compute alongside the cohort but transmit nothing,
        and every caller discards their advanced state before it can be
        observed.  Their batches are COPIES (``sel[0]``'s rng stream
        advances exactly once).  Returns ``(pad, sel + pad dups, padded
        batches)``; a no-op (pad 0) unless ``shard_clients``."""
        pad = (-len(sel)) % jax.device_count() if self.shard_clients else 0
        if not pad:
            return 0, list(sel), batches
        batches = {
            key: jnp.concatenate([v, jnp.repeat(v[:1], pad, axis=0)])
            for key, v in batches.items()
        }
        return pad, list(sel) + [sel[0]] * pad, batches

    @staticmethod
    def _drop_pad(n: int, *trees):
        """Inverse of :meth:`_pad_cohort`: truncate every given pytree (or
        array, or None) back to the ``n`` real leading-cohort rows — the one
        place the 'pad state must never be observed' side of the contract
        lives."""
        out = tuple(jax.tree.map(lambda x: x[:n], t) for t in trees)
        return out if len(out) > 1 else out[0]

    def run_round(
        self,
        sel: Sequence[int],
        pub_tokens: jax.Array,
        bcast: BroadcastState | None,
        states: BatchedChannelState | Sequence[ChannelState],
        *,
        adaptive_k: bool,
        send_h: bool,
    ) -> ClientPhase:
        cohort = [self.clients[i] for i in sel]
        states = list(states)
        batches = self._stacked_batches(cohort, step_major=False)  # (C, S, ...)
        pad, sel_call, batches = self._pad_cohort(sel, batches)
        idx, lora, frozen, opt = self._gather_cohort(sel_call)
        n_samples = int(pub_tokens.shape[0])
        ks = self._budgets(states, n_samples, adaptive_k, len(cohort), send_h)

        # -- the whole client phase: ONE compiled, donated call --
        if bcast is not None:
            step = self._fused_warm
            g_tokens, g_logits, g_h = bcast.tokens, bcast.logits, bcast.h
        else:
            step = self._fused_cold  # g_* operands are unused and DCE'd
            g_tokens, g_logits, g_h = pub_tokens, jnp.zeros(
                (n_samples, self.cfg.vocab_size), jnp.float32), None
        lora, opt, dense_all, h_all = step(
            lora, frozen, opt, g_tokens, g_logits, g_h, batches, pub_tokens,
            jnp.asarray(ks + [0] * pad, jnp.int32),
        )
        if pad:  # drop the padded rows before anything observes them
            lora, opt, dense_all, h_all, idx = self._drop_pad(
                len(cohort), lora, opt, dense_all, h_all, idx
            )

        active, payloads, rank = self._upload_manifests(
            cohort, states, ks, n_samples, send_h
        )
        dense = h_out = None
        if active:
            take = jnp.asarray(active) if len(active) < len(cohort) else None
            dense = dense_all if take is None else dense_all[take]
            if self.quantize_wire:
                dense = fake_quant_dense(dense)
            if rank is not None and h_all is not None:
                h_out = h_all if take is None else h_all[take]

        self._scatter_cohort(idx, lora, opt)
        return ClientPhase(dense=dense, h=h_out, payloads=payloads, ks=ks)


class _ServerOwnerMixin:
    """Server-state plumbing shared by the end-to-end engines (homogeneous
    :class:`FusedE2EEngine` and bucketed :class:`HeteroFusedE2EEngine`):
    they own the server LLM's state for the duration of a run, compute the
    broadcast in-program, and sync back for evaluation/checkpointing.

    Expects the owner to maintain ``server``, ``_s_lora``/``_s_frozen``/
    ``_s_opt``, the broadcast carry ``_b_tokens``/``_b_logits``/``_b_h``
    and the observability tap ``_d_loss``.
    """

    handles_server = True

    def _init_server_state(self, server) -> None:
        self.server = server
        self._s_lora, self._s_frozen = split_lora(server.params)
        self._s_opt = server.opt
        # broadcast knowledge computed in-program, carried across rounds
        self._b_tokens: jax.Array | None = None
        self._b_logits: jax.Array | None = None
        self._b_h: jax.Array | None = None
        self._d_loss: jax.Array | None = None

    def _cold_broadcast(self, pub_tokens: jax.Array, n_samples: int):
        """Round-0 placeholder g_* operands (same arg structure as a warm
        round; ``g_valid=False`` discards their effect in-program)."""
        g_logits = jnp.zeros((n_samples, self.server.cfg.vocab_size), jnp.float32)
        if self.server.cfg.lora is not None:
            g_h = jnp.zeros((n_samples, self.server.cfg.lora.rank), jnp.float32)
        else:
            g_h = None
        return pub_tokens, g_logits, g_h

    def broadcast_state(self, pub_tokens: jax.Array) -> BroadcastState:
        """The in-program-refreshed broadcast of the LAST executed round, as
        the host-side carrier (byte accounting identical to
        :meth:`repro.fed.server.Server.broadcast`)."""
        assert self._b_logits is not None, "no round has run yet"
        rank = (
            self.server.cfg.lora.rank
            if (self.server.cfg.lora is not None and self._b_h is not None)
            else None
        )
        bits = downlink_bits(
            int(self._b_logits.shape[0]), int(self._b_logits.shape[-1]), rank
        )
        return BroadcastState(
            tokens=pub_tokens, logits=self._b_logits, h=self._b_h, bits=bits
        )

    @property
    def last_distill_loss(self) -> float:
        """The final server-distill step loss of the last executed round
        (computed in-program; NaN before any round ran or for an all-dropped
        round)."""
        return float("nan") if self._d_loss is None else float(self._d_loss)

    def sync_server(self) -> None:
        """Materialise the engine-held server state back onto the Server
        object (for evaluation / checkpointing)."""
        self.server.params = merge_lora(self._s_lora, self._s_frozen)
        self.server.opt = self._s_opt

    def server_state(self) -> dict:
        """The engine-held server state as one checkpointable pytree."""
        return {
            "s_lora": self._s_lora,
            "s_frozen": self._s_frozen,
            "s_opt": self._s_opt,
        }

    def load_server_state(self, state: dict) -> None:
        as_jax = lambda tree: jax.tree.map(jnp.asarray, tree)  # noqa: E731
        self._s_lora = as_jax(state["s_lora"])
        self._s_frozen = as_jax(state["s_frozen"])
        self._s_opt = as_jax(state["s_opt"])
        self.sync_server()

    def load_broadcast(self, tokens, logits, h=None) -> None:
        """Restore the in-program broadcast carry (the knowledge the NEXT
        round's cohort distills against) from a checkpoint."""
        self._b_tokens = jnp.asarray(tokens)
        self._b_logits = jnp.asarray(logits)
        self._b_h = None if h is None else jnp.asarray(h)


class FusedE2EEngine(_ServerOwnerMixin, FusedEngine):
    """Whole-round single-executable engine: client phase AND server phase
    (adaptive aggregation, server distillation, broadcast recomputation) as
    ONE donated, compiled call per round — and the uplink crosses the
    engine/server boundary as the sparse wire format ``(values, indices,
    transmit mask)`` of width ``k_cap`` instead of a densified ``(C, P, V)``
    stack, so the aggregation working set is O(C·P·k_cap).

    The engine owns the server LLM's state for the duration of the run
    (pulled from the :class:`repro.fed.server.Server` at construction);
    :meth:`sync_server` writes the merged parameters back for evaluation,
    and :meth:`broadcast_state` exposes the in-program-computed broadcast to
    the round loop.  Cold-server round 0 and all-dropped rounds are DATA
    (masks) inside the executable, not Python control flow, so one
    executable serves every round of a run (per power-of-two ``k_cap``
    bucket — see :func:`k_cap_bucket`).

    ``shard_clients=True`` places the client phase's cohort axis over the
    process's devices INSIDE the compiled round body (``shard_map`` in
    :func:`repro.fed.steps.make_fused_e2e_round_fn`); the server phase stays
    replicated.  Cohorts that do not divide the device count are padded with
    masked ``k = 0`` duplicate rows exactly like the fused client-phase
    engine — the pad transmits nothing, is excluded from aggregation by its
    all-False wire mask, and its advanced state is discarded before the
    scatter-back.

    :meth:`run_rounds` additionally scans R whole rounds inside one
    compiled call (steady-state dispatch fully amortised) and taps each
    round's server/client accuracy, server-distill loss and mean adaptive
    ``k`` as scanned outputs — a full :class:`RoundsTrajectory` instead of a
    blind block.
    """

    name = "fused_e2e"
    handles_server = True

    def __init__(
        self,
        clients: list[Client],
        cfg: ModelConfig,
        *,
        server,
        num_classes: int,
        lr: float = 1e-3,
        distill_lr: float = 1e-3,
        temperature: float = 2.0,
        lam: float = 0.03,
        local_steps: int = 4,
        distill_steps: int = 2,
        server_distill_steps: int = 12,
        aggregation: str = "adaptive",
        restrict_to_support: bool = False,
        value_bits: int = 16,
        k_min: int = 1,
        last_only: bool = True,
        shard_clients: bool = False,
        use_kernels: bool = False,
        quantize_wire: bool = False,
        compute_dtype: str = "float32",
    ):
        super().__init__(
            clients, cfg, num_classes=num_classes, lr=lr, distill_lr=distill_lr,
            temperature=temperature, lam=lam, local_steps=local_steps,
            distill_steps=distill_steps, restrict_to_support=restrict_to_support,
            value_bits=value_bits, k_min=k_min, last_only=last_only,
            use_kernels=use_kernels, quantize_wire=quantize_wire,
            compute_dtype=compute_dtype,
        )
        self.shard_clients = shard_clients
        self._fn_kwargs = dict(
            lr=lr, distill_lr=distill_lr, temperature=temperature, lam=lam,
            restrict_to_support=restrict_to_support, local_steps=local_steps,
            distill_steps=distill_steps,
            server_distill_steps=server_distill_steps,
            aggregation=aggregation, shared_backbone=self._shared,
            last_only=last_only, use_kernels=use_kernels,
            shard_clients=shard_clients, quantize=quantize_wire,
            compute_dtype=compute_dtype,
        )
        self._num_classes = num_classes
        self._init_server_state(server)
        self._steps: dict = {}
        self._drivers: dict = {}

    # -- compiled-step caches -------------------------------------------
    def _e2e_fn(self, k_cap: int, send_h: bool):
        """The unjitted whole-round body for one (k_cap, send_h) bucket."""
        return fed_steps.make_fused_e2e_round_fn(
            self.cfg, self.server.cfg, self._num_classes,
            k_cap=k_cap, send_h=send_h, **self._fn_kwargs,
        )

    def _e2e_step(self, k_cap: int, send_h: bool):
        key = (k_cap, send_h)
        if key not in self._steps:
            self._steps[key] = jax.jit(
                self._e2e_fn(k_cap, send_h), donate_argnums=(0, 2, 3, 5)
            )
        return self._steps[key]

    # -- single whole round: ONE compiled call ---------------------------
    def run_round(
        self,
        sel: Sequence[int],
        pub_tokens: jax.Array,
        bcast: BroadcastState | None,
        states: BatchedChannelState | Sequence[ChannelState],
        *,
        adaptive_k: bool,
        send_h: bool,
    ) -> ClientPhase:
        cohort = [self.clients[i] for i in sel]
        states = list(states)
        batches = self._stacked_batches(cohort, step_major=False)
        pad, sel_call, batches = self._pad_cohort(sel, batches)
        idx, lora, frozen, opt = self._gather_cohort(sel_call)
        n_samples = int(pub_tokens.shape[0])
        ks = self._budgets(states, n_samples, adaptive_k, len(cohort), send_h)
        k_cap = k_cap_bucket(ks, self.cfg.vocab_size)

        if bcast is not None:
            g_tokens, g_logits, g_h = bcast.tokens, bcast.logits, bcast.h
            g_valid = True
        else:
            g_tokens, g_logits, g_h = self._cold_broadcast(pub_tokens, n_samples)
            g_valid = False

        step = self._e2e_step(k_cap, send_h)
        (lora, opt, self._s_lora, self._s_opt,
         values, indices, scale, b_logits, b_h, self._d_loss) = step(
            lora, frozen, opt, self._s_lora, self._s_frozen, self._s_opt,
            g_tokens, g_logits, g_h, jnp.asarray(g_valid),
            batches, pub_tokens, jnp.asarray(ks + [0] * pad, jnp.int32),
        )
        if pad:  # drop the padded rows before anything observes them
            lora, opt, values, indices, scale, idx = self._drop_pad(
                len(cohort), lora, opt, values, indices, scale, idx
            )
        self._b_tokens, self._b_logits, self._b_h = pub_tokens, b_logits, b_h

        active, payloads, _rank = self._upload_manifests(
            cohort, states, ks, n_samples, send_h
        )
        sparse = None
        if active:
            take = jnp.asarray(active)
            ks_active = jnp.asarray([ks[i] for i in active], jnp.int32)
            mask = (
                jnp.arange(k_cap, dtype=jnp.int32)[None, None, :]
                < ks_active[:, None, None]
            )
            mask = jnp.broadcast_to(mask, values[take].shape)
            if self.quantize_wire:
                sparse = QuantizedWire(
                    values=values[take], scale=scale[take],
                    indices=indices[take], mask=mask,
                    vocab=self.cfg.vocab_size,
                )
            else:
                sparse = SparseWire(
                    values=values[take], indices=indices[take], mask=mask,
                    vocab=self.cfg.vocab_size,
                )

        self._scatter_cohort(idx, lora, opt)
        return ClientPhase(dense=None, h=None, payloads=payloads, ks=ks, sparse=sparse)

    # -- multi-round scan driver ------------------------------------------
    def _rounds_driver(
        self, k_cap: int, send_h: bool, num_rounds: int, n_real: int,
        has_eval: bool, has_chan: bool,
    ):
        key = (k_cap, send_h, num_rounds, n_real, has_eval, has_chan)
        if key in self._drivers:
            return self._drivers[key]
        fn = self._e2e_fn(k_cap, send_h)
        has_h = self.server.cfg.lora is not None
        # in-scan channel replica: scenario dynamics as f32 data, so the
        # same executable serves every preset (rho=0 == i.i.d.)
        chan_step = fed_steps.make_channel_step_fn() if has_chan else None
        # in-scan eval tap: same last-position class-logit accuracy as the
        # host-side make_eval_fn, traced into the scanned round program
        server_eval = fed_steps.make_scan_eval_fn(
            self.server.cfg, self._num_classes, last_only=self.last_only
        )
        client_eval = fed_steps.make_scan_eval_fn(
            self.cfg, self._num_classes, last_only=self.last_only
        )

        shared = self._shared

        def driver(fleet_lora, fleet_opt, s_lora, s_opt, frozen, s_frozen,
                   g_tokens, g_logits, g_h, g_valid, sels, kss, pubs, batches,
                   chan, *eval_args):
            if has_chan:
                ch_z0, ch_bad0, ch_w, ch_u, ch_base, rho, p_gb, p_bg, fade = chan

            def body(carry, xs):
                (fleet_lora, fleet_opt, s_lora, s_opt,
                 g_tokens, g_logits, g_h, g_valid, ch_state) = carry
                sel, ks, pub, bat, ch_xs = xs
                lora = jax.tree.map(lambda x: x[sel], fleet_lora)
                opt = jax.tree.map(lambda x: x[sel], fleet_opt)
                # one shared W' broadcasts into the cohort; per-client
                # backbones are fleet-stacked and gather their cohort rows
                # exactly like the LoRA/opt state (frozen_ax=0 downstream)
                frz = frozen if shared else jax.tree.map(lambda x: x[sel], frozen)
                lora, opt, s_lora, s_opt, _v, _i, _sc, b_logits, b_h, d_loss = fn(
                    lora, frz, opt, s_lora, s_frozen, s_opt,
                    g_tokens, g_logits, g_h if has_h else None, g_valid,
                    bat, pub, ks,
                )
                # drop the shard-padding rows (duplicates of sel[0]) BEFORE
                # the scatter-back: .at[sel].set with duplicate indices has
                # unspecified ordering, and the pad's advanced state must
                # never be observed anyway
                lora, opt = self._drop_pad(n_real, lora, opt)
                sel_real = sel[:n_real]
                fleet_lora = jax.tree.map(
                    lambda full, new: full.at[sel_real].set(new), fleet_lora, lora
                )
                fleet_opt = jax.tree.map(
                    lambda full, new: full.at[sel_real].set(new), fleet_opt, opt
                )
                # -- the eval tap: this round's trajectory entry ----------
                tap = {
                    "distill_loss": d_loss,
                    "mean_k": jnp.mean(ks[:n_real].astype(jnp.float32)),
                }
                if has_eval:
                    ev_tokens, ev_labels = eval_args
                    tap["server_acc"] = server_eval(
                        s_lora, s_frozen, ev_tokens, ev_labels
                    )
                    tap["client_acc"] = client_eval(
                        jax.tree.map(lambda x: x[0], lora),
                        frz if shared else jax.tree.map(lambda x: x[0], frz),
                        ev_tokens, ev_labels,
                    )
                if has_chan:
                    # channel state advances as scan carry; the realised
                    # cohort SNR/outage are tapped as scanned outputs
                    ch_z, ch_bad = ch_state
                    w_t, u_t, base_t = ch_xs
                    ch_z, ch_bad, snr = chan_step(
                        ch_z, ch_bad, w_t, u_t, base_t, rho, p_gb, p_bg, fade
                    )
                    ch_state = (ch_z, ch_bad)
                    tap["snr_db"] = snr[sel[:n_real]]
                    tap["outage"] = ch_bad[sel[:n_real]]
                carry = (
                    fleet_lora, fleet_opt, s_lora, s_opt,
                    pub, b_logits, b_h if has_h else g_h, jnp.ones((), bool),
                    ch_state,
                )
                return carry, tap

            ch_state0 = (ch_z0, ch_bad0) if has_chan else ()
            ch_xs_all = (ch_w, ch_u, ch_base) if has_chan else ()
            carry, taps = jax.lax.scan(
                body,
                (fleet_lora, fleet_opt, s_lora, s_opt,
                 g_tokens, g_logits, g_h, g_valid, ch_state0),
                (sels, kss, pubs, batches, ch_xs_all),
                length=num_rounds,
            )
            return carry, taps

        jitted = jax.jit(driver, donate_argnums=(0, 1, 2, 3))
        self._drivers[key] = jitted
        return jitted

    def run_rounds(
        self,
        sels: Sequence[Sequence[int]],
        pubs: Sequence[jax.Array],
        states_per_round: Sequence,
        *,
        adaptive_k: bool,
        send_h: bool,
        eval_tokens: jax.Array | None = None,
        eval_labels: jax.Array | None = None,
        channel_scan: dict | None = None,
    ) -> "RoundsTrajectory":
        """Run R whole federated rounds as ONE compiled ``lax.scan`` — the
        steady-state amortised driver (dispatch cost O(1) for the block).

        ``channel_scan`` (a :meth:`ChannelSimulator.scan_channel_inputs`
        dict) additionally evolves the scenario channel state — AR(1)
        fading ``z``, Gilbert-Elliott outage — INSIDE the scan as carry,
        with every dynamics parameter an f32 data operand: one executable
        serves all scenario presets (``rho = 0`` replays i.i.d.).  The
        per-round realised cohort SNR/outage come back as scanned outputs
        (``RoundsTrajectory.snr_db``/``outage``); budgets stay host-side
        scalar math, priced from the same (seed, round, cid)-keyed chain.

        Per-round cohort selection/channel budgets stay host-side scalar
        math (ledger parity with the round-at-a-time path); the per-round
        observables — server/client accuracy on the given eval arrays, the
        server-distill loss, the mean adaptive ``k`` — are tapped INSIDE the
        scan as scanned outputs, so the block returns a full
        :class:`RoundsTrajectory` instead of running blind.
        Fleet/server/broadcast state advance in place exactly as R
        ``run_round`` calls would.

        ``eval_tokens``/``eval_labels`` (omit both to skip the accuracy tap)
        are evaluated after each round on the server model and on the
        round's first selected client — the same models the host loop's
        per-round evaluation reads.  The split is truncated to whole
        :data:`repro.fed.steps.EVAL_BATCH` batches exactly like the
        host-side evaluator (so the tap and ``make_eval_fn`` read the same
        samples); a split smaller than one batch is rejected.
        """
        if (eval_tokens is None) != (eval_labels is None):
            raise ValueError("pass eval_tokens and eval_labels together")
        has_eval = eval_tokens is not None
        has_chan = channel_scan is not None
        num_rounds = len(sels)
        if num_rounds == 0:  # degenerate no-op, like zero host-loop rounds
            return RoundsTrajectory(
                ks=[], payloads=[], mean_k=[], distill_loss=[],
                server_acc=[] if has_eval else None,
                client_acc=[] if has_eval else None,
                snr_db=[] if has_chan else None,
                outage=[] if has_chan else None,
            )
        n_samples = int(pubs[0].shape[0])
        n_real = len(sels[0])
        if any(len(sel) != n_real for sel in sels):
            raise ValueError("run_rounds requires equal-size cohorts")

        pad = 0
        all_ks, all_payloads, batch_list, sels_call = [], [], [], []
        for sel, states in zip(sels, states_per_round):
            cohort = [self.clients[i] for i in sel]
            states = list(states)
            ks = self._budgets(states, n_samples, adaptive_k, len(cohort), send_h)
            _active, payloads, _rank = self._upload_manifests(
                cohort, states, ks, n_samples, send_h
            )
            all_ks.append(ks)
            all_payloads.append(payloads)
            batch = self._stacked_batches(cohort, step_major=False)
            pad, sel_call, batch = self._pad_cohort(sel, batch)
            batch_list.append(batch)
            sels_call.append(sel_call)
        k_cap = k_cap_bucket([k for ks in all_ks for k in ks], self.cfg.vocab_size)

        sels_arr = jnp.asarray(np.asarray(sels_call), jnp.int32)  # (R, C+pad)
        kss_arr = jnp.asarray(  # (R, C+pad); pad rows transmit nothing
            np.asarray([ks + [0] * pad for ks in all_ks]), jnp.int32
        )
        pubs_arr = jnp.stack([jnp.asarray(p) for p in pubs])  # (R, P, L)
        batches = jax.tree.map(lambda *xs: jnp.stack(xs), *batch_list)

        if self._b_logits is not None:
            g_tokens, g_logits, g_h = self._b_tokens, self._b_logits, self._b_h
            g_valid = True
        else:
            g_tokens, g_logits, g_h = self._cold_broadcast(pubs_arr[0], n_samples)
            g_valid = False

        eval_args = ()
        if has_eval:
            # whole EVAL_BATCH batches only — the host evaluator's walk, and
            # the precondition of make_scan_eval_fn's bounded-memory chunking
            seen = (
                int(eval_tokens.shape[0]) // fed_steps.EVAL_BATCH
            ) * fed_steps.EVAL_BATCH
            if seen == 0:
                raise ValueError(
                    f"eval split of {int(eval_tokens.shape[0])} samples is "
                    f"smaller than one eval batch ({fed_steps.EVAL_BATCH})"
                )
            eval_args = (
                jnp.asarray(eval_tokens[:seen]), jnp.asarray(eval_labels[:seen])
            )
        chan_ops = _channel_scan_ops(channel_scan, num_rounds) if has_chan else ()
        driver = self._rounds_driver(
            k_cap, send_h, num_rounds, n_real, has_eval, has_chan
        )
        carry, taps = driver(
            self._lora, self._opt, self._s_lora, self._s_opt,
            self._frozen, self._s_frozen,
            g_tokens, g_logits, g_h, jnp.asarray(g_valid),
            sels_arr, kss_arr, pubs_arr, batches, chan_ops, *eval_args,
        )
        (self._lora, self._opt, self._s_lora, self._s_opt,
         self._b_tokens, self._b_logits, self._b_h, _valid, _chan) = carry
        self._d_loss = taps["distill_loss"][-1]

        def _tolist(name):
            return [float(x) for x in np.asarray(taps[name])]

        snr_db = outage = None
        if has_chan:
            snr_db = [[float(x) for x in row] for row in np.asarray(taps["snr_db"])]
            outage = [[bool(x) for x in row] for row in np.asarray(taps["outage"])]
        return RoundsTrajectory(
            ks=all_ks,
            payloads=all_payloads,
            mean_k=_tolist("mean_k"),
            distill_loss=_tolist("distill_loss"),
            server_acc=_tolist("server_acc") if has_eval else None,
            client_acc=_tolist("client_acc") if has_eval else None,
            snr_db=snr_db,
            outage=outage,
        )


class HeteroClientEngine:
    """Family-bucketed CLIENT-phase engine for heterogeneous fleets.

    The fleet is partitioned into homogeneous family buckets
    (:func:`repro.fed.cohort.partition_fleet`); each bucket runs its own
    batched/fused sub-engine — one vmapped, donated executable per family —
    and a round's uploads merge in the model-agnostic logit space: the
    per-bucket densified stacks concatenate into one cohort-ordered
    ``(T, P, V)`` stack (vocab is the shared exchange contract, so the
    unchanged server aggregation consumes it exactly as a homogeneous
    cohort's).  ``ks``/payload accounting is reassembled in cohort order,
    so the ledger is bit-identical to the sequential reference over the
    same clients.
    """

    name = "hetero"

    def __init__(self, kind: str, clients: list[Client], **kwargs):
        from repro.fed.cohort import fleet_index, partition_fleet, validate_family_contracts

        self.buckets = partition_fleet(clients)
        validate_family_contracts(self.buckets)
        self.kind = kind
        sub_cls = {"batched": BatchedEngine, "fused": FusedEngine}[kind]
        sub_kwargs = dict(kwargs)
        if kind == "batched":
            sub_kwargs.pop("shard_clients", None)
            sub_kwargs.pop("use_kernels", None)
        self._engines = [
            sub_cls([clients[i] for i in b.client_ids], b.cfg, **sub_kwargs)
            for b in self.buckets
        ]
        self._where = fleet_index(self.buckets)

    def client_params(self, cid: int):
        bi, local = self._where[int(cid)]
        return self._engines[bi].client_params(local)

    def fleet_state(self) -> dict:
        return {f"bucket{i}": e.fleet_state() for i, e in enumerate(self._engines)}

    def load_fleet_state(self, state: dict) -> None:
        for i, e in enumerate(self._engines):
            e.load_fleet_state(state[f"bucket{i}"])

    def run_round(
        self,
        sel: Sequence[int],
        pub_tokens: jax.Array,
        bcast: BroadcastState | None,
        states: BatchedChannelState | Sequence[ChannelState],
        *,
        adaptive_k: bool,
        send_h: bool,
    ) -> ClientPhase:
        from repro.fed.cohort import split_cohort

        states = list(states)
        ks = [0] * len(sel)
        merged = []  # (cohort position, dense row, h row, payload)
        for b, pos, local in split_cohort(self.buckets, sel):
            phase = self._engines[b.index].run_round(
                local, pub_tokens, bcast, [states[p] for p in pos],
                adaptive_k=adaptive_k, send_h=send_h,
            )
            for p, k in zip(pos, phase.ks):
                ks[p] = k
            tx = [p for p, k in zip(pos, phase.ks) if k > 0]
            for j, p in enumerate(tx):
                merged.append((
                    p,
                    None if phase.dense is None else phase.dense[j],
                    None if phase.h is None else phase.h[j],
                    phase.payloads[j],
                ))
        # transmitters back into cohort order: the union stack then reads
        # exactly like a homogeneous engine's (and the payload manifest
        # order matches the sequential reference)
        merged.sort(key=lambda entry: entry[0])
        dense = jnp.stack([d for _, d, _, _ in merged]) if merged else None
        h = (
            jnp.stack([h_row for _, _, h_row, _ in merged])
            if merged and merged[0][2] is not None
            else None
        )
        return ClientPhase(
            dense=dense, h=h, payloads=[m[3] for m in merged], ks=ks
        )


class HeteroFusedE2EEngine(_ServerOwnerMixin):
    """Family-bucketed end-to-end engine: one fused client-phase executable
    PER FAMILY BUCKET, one union sparse wire, one compiled server phase.

    This is the paper's actual scenario — clients with different
    architectures federating through the shared logit space — served by the
    fast-engine machinery:

    * the fleet partitions into homogeneous family buckets
      (`repro.fed.cohort`); each bucket keeps its LoRA/opt state stacked on
      a leading client axis (a :class:`BatchedEngine` per bucket is the
      state holder) and runs its whole client phase — distill, fine-tune
      scan, public inference, sparse-wire top-k with per-client ``k`` as
      DATA — as one donated compiled call
      (:func:`repro.fed.steps.make_bucket_client_phase_fn`), with
      ``frozen_ax=0`` stacked backbones for buckets whose clients carry
      distinct frozen trees;
    * the buckets' wires concatenate into ONE vocab-indexed union wire
      (:func:`repro.core.topk.concat_wires` semantics, materialised
      in-order here), and the eq.-8 projections align across families by
      the shared LoRA rank — so the UNCHANGED server phase
      (:func:`repro.fed.steps.make_server_phase_fn`: wire aggregation,
      server-distill scan, broadcast recompute) runs exactly once per
      round, family-blind;
    * :meth:`run_rounds` scans R whole heterogeneous rounds inside one
      compiled dispatch: per-bucket fleet state rides in the scan carry
      (frozen stacks included), per-round variable family participation is
      handled by padding each bucket to its block-wide max cohort slice
      with masked ``k = 0`` rows that compute alongside the round but
      transmit nothing and scatter into a write-only scratch row, and the
      in-scan eval tap reports the server accuracy plus ONE accuracy PER
      FAMILY.
    """

    name = "hetero_fused_e2e"

    def __init__(
        self,
        clients: list[Client],
        *,
        server,
        num_classes: int,
        lr: float = 1e-3,
        distill_lr: float = 1e-3,
        temperature: float = 2.0,
        lam: float = 0.03,
        local_steps: int = 4,
        distill_steps: int = 2,
        server_distill_steps: int = 12,
        aggregation: str = "adaptive",
        restrict_to_support: bool = False,
        value_bits: int = 16,
        k_min: int = 1,
        last_only: bool = True,
        shard_clients: bool = False,
        use_kernels: bool = False,
        quantize_wire: bool = False,
        compute_dtype: str = "float32",
    ):
        from repro.fed.cohort import fleet_index, partition_fleet, validate_family_contracts

        if shard_clients:
            raise NotImplementedError(
                "shard_clients is not supported for heterogeneous fleets yet:"
                " each family bucket would need its own divisible client-axis"
                " placement"
            )
        self.buckets = partition_fleet(clients)
        validate_family_contracts(self.buckets, server_cfg=server.cfg)
        self._where = fleet_index(self.buckets)
        self.clients = clients
        self.vocab = self.buckets[0].cfg.vocab_size
        self.last_only = last_only
        self._num_classes = num_classes
        self._local_steps = local_steps
        self.quantize_wire = quantize_wire
        sub_kwargs = dict(
            num_classes=num_classes, lr=lr, distill_lr=distill_lr,
            temperature=temperature, lam=lam, local_steps=local_steps,
            distill_steps=distill_steps,
            restrict_to_support=restrict_to_support, value_bits=value_bits,
            k_min=k_min, last_only=last_only, quantize_wire=quantize_wire,
        )
        # one BatchedEngine per bucket as the stacked-fleet STATE HOLDER
        # (gather/scatter/budget/batch plumbing); its per-phase steps are
        # never invoked — the bucket client-phase executable below runs the
        # round
        self._b = [
            BatchedEngine([clients[i] for i in b.client_ids], b.cfg, **sub_kwargs)
            for b in self.buckets
        ]
        self._phase_kwargs = dict(
            lr=lr, distill_lr=distill_lr, temperature=temperature, lam=lam,
            restrict_to_support=restrict_to_support, local_steps=local_steps,
            distill_steps=distill_steps, last_only=last_only,
            quantize=quantize_wire, compute_dtype=compute_dtype,
        )
        self._server_kwargs = dict(
            vocab=self.vocab, distill_lr=distill_lr, temperature=temperature,
            lam=lam, restrict_to_support=restrict_to_support,
            server_distill_steps=server_distill_steps,
            aggregation=aggregation, last_only=last_only,
            use_kernels=use_kernels, quantize=quantize_wire,
            compute_dtype=compute_dtype,
        )
        self._init_server_state(server)
        self._client_steps: dict = {}
        self._server_steps: dict = {}
        self._drivers: dict = {}

    # -- compiled-step caches -------------------------------------------
    def _client_phase_fn(self, bi: int, k_cap: int):
        """One bucket's unjitted client-phase body (for the scan driver)."""
        b = self.buckets[bi]
        return fed_steps.make_bucket_client_phase_fn(
            b.cfg, self._num_classes, k_cap=k_cap,
            shared_backbone=self._b[bi]._shared, **self._phase_kwargs,
        )

    def _client_step(self, bi: int, k_cap: int):
        key = (bi, k_cap)
        if key not in self._client_steps:
            self._client_steps[key] = jax.jit(
                self._client_phase_fn(bi, k_cap), donate_argnums=(0, 2)
            )
        return self._client_steps[key]

    def _server_step(self, send_h: bool):
        if send_h not in self._server_steps:
            self._server_steps[send_h] = jax.jit(
                fed_steps.make_server_phase_fn(
                    self.server.cfg, send_h=send_h, **self._server_kwargs
                ),
                donate_argnums=(0, 2),
            )
        return self._server_steps[send_h]

    def client_params(self, cid: int):
        bi, local = self._where[int(cid)]
        return self._b[bi].client_params(local)

    def fleet_state(self) -> dict:
        return {f"bucket{i}": b.fleet_state() for i, b in enumerate(self._b)}

    def load_fleet_state(self, state: dict) -> None:
        for i, b in enumerate(self._b):
            b.load_fleet_state(state[f"bucket{i}"])

    # -- one whole heterogeneous round -----------------------------------
    def run_round(
        self,
        sel: Sequence[int],
        pub_tokens: jax.Array,
        bcast: BroadcastState | None,
        states: BatchedChannelState | Sequence[ChannelState],
        *,
        adaptive_k: bool,
        send_h: bool,
    ) -> ClientPhase:
        from repro.fed.cohort import split_cohort

        states = list(states)
        n_samples = int(pub_tokens.shape[0])
        parts = split_cohort(self.buckets, sel)

        # budgets first (host scalar math, cohort order — ledger parity)
        ks = [0] * len(sel)
        budgets = []
        for b, pos, local in parts:
            ks_b = self._b[b.index]._budgets(
                [states[p] for p in pos], n_samples, adaptive_k, len(pos), send_h
            )
            budgets.append(ks_b)
            for p, k in zip(pos, ks_b):
                ks[p] = k
        k_cap = k_cap_bucket(ks, self.vocab)

        if bcast is not None:
            g_tokens, g_logits, g_h = bcast.tokens, bcast.logits, bcast.h
            g_valid = True
        else:
            g_tokens, g_logits, g_h = self._cold_broadcast(pub_tokens, n_samples)
            g_valid = False
        g_valid_arr = jnp.asarray(g_valid)

        # -- client phase: one donated compiled call per family bucket --
        wires: list[SparseWire | QuantizedWire] = []
        h_parts: list = []
        order: list[int] = []  # cohort position of each bucket-concat row
        payloads_by_pos: dict[int, UplinkPayload] = {}
        for (b, pos, local), ks_b in zip(parts, budgets):
            be = self._b[b.index]
            cohort = [be.clients[j] for j in local]
            batches = be._stacked_batches(cohort, step_major=False)
            idx, lora, frozen, opt = be._gather_cohort(local)
            lora, opt, v, i, m, sc, h = self._client_step(b.index, k_cap)(
                lora, frozen, opt, g_tokens, g_logits, g_h, g_valid_arr,
                batches, pub_tokens, jnp.asarray(ks_b, jnp.int32),
            )
            be._scatter_cohort(idx, lora, opt)
            _active, pl, _rank = be._upload_manifests(
                cohort, [states[p] for p in pos], ks_b, n_samples, send_h
            )
            it = iter(pl)
            for j, p in enumerate(pos):
                if ks_b[j] > 0:
                    payloads_by_pos[p] = next(it)
            if self.quantize_wire:
                wires.append(QuantizedWire(
                    values=v, scale=sc, indices=i, mask=m, vocab=self.vocab
                ))
            else:
                wires.append(SparseWire(values=v, indices=i, mask=m, vocab=self.vocab))
            h_parts.append(h)
            order.extend(pos)

        # -- union wire: the buckets' wires merge in the shared vocab-indexed
        # logit space, rows permuted back into cohort order; then ONE
        # family-blind compiled server phase --
        inv = np.argsort(np.asarray(order))
        union = take_wire_rows(concat_wires(wires), inv)
        h_all = None
        if h_parts[0] is not None:
            h_all = jnp.concatenate(h_parts)[jnp.asarray(inv)]
        union_scale = union.scale if self.quantize_wire else None
        (self._s_lora, self._s_opt, b_logits, b_h, self._d_loss) = (
            self._server_step(send_h)(
                self._s_lora, self._s_frozen, self._s_opt,
                union.values, union.indices, union.mask, union_scale, h_all,
                jnp.asarray(ks, jnp.int32), pub_tokens,
            )
        )
        self._b_tokens, self._b_logits, self._b_h = pub_tokens, b_logits, b_h

        tx = [p for p in range(len(sel)) if ks[p] > 0]
        sparse = take_wire_rows(union, tx) if tx else None
        return ClientPhase(
            dense=None, h=None, payloads=[payloads_by_pos[p] for p in tx],
            ks=ks, sparse=sparse,
        )

    # -- R heterogeneous rounds as ONE compiled lax.scan ------------------
    def _hetero_rounds_driver(
        self, k_cap: int, send_h: bool, num_rounds: int, n_real: int,
        caps: tuple[int, ...], has_eval: bool, has_chan: bool,
    ):
        key = (k_cap, send_h, num_rounds, n_real, caps, has_eval, has_chan)
        if key in self._drivers:
            return self._drivers[key]
        chan_step = fed_steps.make_channel_step_fn() if has_chan else None
        fns = [self._client_phase_fn(bi, k_cap) for bi in range(len(self.buckets))]
        server_fn = fed_steps.make_server_phase_fn(
            self.server.cfg, send_h=send_h, **self._server_kwargs
        )
        has_h = self.server.cfg.lora is not None
        shared = [be._shared for be in self._b]
        sizes = [b.size for b in self.buckets]
        server_eval = fed_steps.make_scan_eval_fn(
            self.server.cfg, self._num_classes, last_only=self.last_only
        )
        family_evals = [
            fed_steps.make_scan_eval_fn(
                b.cfg, self._num_classes, last_only=self.last_only
            )
            for b in self.buckets
        ]

        def driver(fleet_loras, fleet_opts, s_lora, s_opt, frozens, s_frozen,
                   g_tokens, g_logits, g_h, g_valid,
                   gathers, scatters, kss_b, batches_b, kss_all, pubs,
                   chan, *eval_args):
            if has_chan:
                (ch_z0, ch_bad0, ch_w, ch_u, ch_base,
                 rho, p_gb, p_bg, fade, sels_data) = chan

            def body(carry, xs):
                (fleet_loras, fleet_opts, s_lora, s_opt,
                 g_tokens, g_logits, g_h, g_valid, ch_state) = carry
                gath, scat, ksb, bat, ks_all, pub, ch_xs = xs
                vs, idxs, ms, scs, hs = [], [], [], [], []
                new_loras, new_opts = [], []
                for f, fn in enumerate(fns):
                    # gather this round's (padded) bucket slice; pads
                    # duplicate a real row for COMPUTE but scatter into the
                    # write-only scratch row sizes[f], so their advanced
                    # state is never observable
                    lora = jax.tree.map(lambda x: x[gath[f]], fleet_loras[f])
                    opt = jax.tree.map(lambda x: x[gath[f]], fleet_opts[f])
                    frz = (
                        frozens[f] if shared[f]
                        else jax.tree.map(lambda x: x[gath[f]], frozens[f])
                    )
                    lora, opt, v, i, m, sc, h = fn(
                        lora, frz, opt, g_tokens, g_logits,
                        g_h if has_h else None, g_valid, bat[f], pub, ksb[f],
                    )
                    new_loras.append(jax.tree.map(
                        lambda full, new: full.at[scat[f]].set(new),
                        fleet_loras[f], lora,
                    ))
                    new_opts.append(jax.tree.map(
                        lambda full, new: full.at[scat[f]].set(new),
                        fleet_opts[f], opt,
                    ))
                    vs.append(v)
                    idxs.append(i)
                    ms.append(m)
                    scs.append(sc)
                    hs.append(h)
                # the union wire: bucket-concatenated rows, vocab-indexed —
                # aggregation is row-permutation-invariant, so no cohort
                # reordering is needed in-program
                v_all = jnp.concatenate(vs)
                i_all = jnp.concatenate(idxs)
                m_all = jnp.concatenate(ms)
                sc_all = jnp.concatenate(scs) if scs[0] is not None else None
                h_all = jnp.concatenate(hs) if hs[0] is not None else None
                s_lora, s_opt, b_logits, b_h, d_loss = server_fn(
                    s_lora, s_frozen, s_opt, v_all, i_all, m_all, sc_all,
                    h_all, ks_all, pub,
                )
                # pad rows ride at k = 0, so the real cohort's mean is just
                # the padded sum over the true cohort size
                tap = {
                    "distill_loss": d_loss,
                    "mean_k": jnp.sum(ks_all.astype(jnp.float32)) / n_real,
                }
                if has_eval:
                    ev_tokens, ev_labels = eval_args
                    tap["server_acc"] = server_eval(
                        s_lora, s_frozen, ev_tokens, ev_labels
                    )
                    fam = []
                    for f in range(len(fns)):
                        # post-scatter fleet row gath[f][0]: the family's
                        # first selected client this round (or its local
                        # client 0, untouched, when the family sat out)
                        lf = jax.tree.map(
                            lambda x: x[gath[f][0]], new_loras[f]
                        )
                        ff = (
                            frozens[f] if shared[f]
                            else jax.tree.map(lambda x: x[gath[f][0]], frozens[f])
                        )
                        fam.append(family_evals[f](lf, ff, ev_tokens, ev_labels))
                    tap["family_client_acc"] = jnp.stack(fam)
                if has_chan:
                    # hetero cohorts are bucket-local in-program; the global
                    # cohort ids ride along as data purely for the tap gather
                    ch_z, ch_bad = ch_state
                    w_t, u_t, base_t, sel_real = ch_xs
                    ch_z, ch_bad, snr = chan_step(
                        ch_z, ch_bad, w_t, u_t, base_t, rho, p_gb, p_bg, fade
                    )
                    ch_state = (ch_z, ch_bad)
                    tap["snr_db"] = snr[sel_real]
                    tap["outage"] = ch_bad[sel_real]
                carry = (
                    tuple(new_loras), tuple(new_opts), s_lora, s_opt,
                    pub, b_logits, b_h if has_h else g_h, jnp.ones((), bool),
                    ch_state,
                )
                return carry, tap

            ch_state0 = (ch_z0, ch_bad0) if has_chan else ()
            ch_xs_all = (ch_w, ch_u, ch_base, sels_data) if has_chan else ()
            carry, taps = jax.lax.scan(
                body,
                (fleet_loras, fleet_opts, s_lora, s_opt,
                 g_tokens, g_logits, g_h, g_valid, ch_state0),
                (gathers, scatters, kss_b, batches_b, kss_all, pubs,
                 ch_xs_all),
                length=num_rounds,
            )
            return carry, taps

        jitted = jax.jit(driver, donate_argnums=(0, 1, 2, 3))
        self._drivers[key] = jitted
        return jitted

    def run_rounds(
        self,
        sels: Sequence[Sequence[int]],
        pubs: Sequence[jax.Array],
        states_per_round: Sequence,
        *,
        adaptive_k: bool,
        send_h: bool,
        eval_tokens: jax.Array | None = None,
        eval_labels: jax.Array | None = None,
        channel_scan: dict | None = None,
    ) -> RoundsTrajectory:
        """Run R whole heterogeneous rounds as ONE compiled ``lax.scan``.

        ``channel_scan`` evolves the scenario channel state inside the scan
        exactly as on the homogeneous path (see
        :meth:`FusedE2EEngine.run_rounds`); the global cohort ids ride
        along as data so the per-round SNR/outage tap can gather the
        fleet-wide realisation into cohort order.

        Family participation varies per round, but every compiled shape is
        static: each bucket is padded to its block-wide maximum cohort slice
        (at least one row) with masked ``k = 0`` rows.  A pad row gathers a
        real client's state so the computation stays well-posed, contributes
        nothing to the union wire (all-False transmit mask), consumes no
        private batch (its batch rows are zeros), and scatters its advanced
        state into a write-only scratch row appended past the bucket's fleet
        — ``.at[sel].set`` duplicate-index hazards land only there.  Per
        round, the eval tap reports server accuracy and one accuracy per
        family bucket; ``client_acc`` is the cohort's first selected
        client's family entry (the host loop's metric).
        """
        from repro.fed.cohort import split_cohort

        if (eval_tokens is None) != (eval_labels is None):
            raise ValueError("pass eval_tokens and eval_labels together")
        has_eval = eval_tokens is not None
        has_chan = channel_scan is not None
        num_rounds = len(sels)
        if num_rounds == 0:
            return RoundsTrajectory(
                ks=[], payloads=[], mean_k=[], distill_loss=[],
                server_acc=[] if has_eval else None,
                client_acc=[] if has_eval else None,
                family_client_acc=[] if has_eval else None,
                snr_db=[] if has_chan else None,
                outage=[] if has_chan else None,
            )
        n_samples = int(pubs[0].shape[0])
        n_real = len(sels[0])
        if any(len(sel) != n_real for sel in sels):
            raise ValueError("run_rounds requires equal-size cohorts")

        F = len(self.buckets)
        # -- host pre-pass: budgets/payloads (ledger), per-bucket slices --
        all_ks, all_payloads = [], []
        per_round: list[list[tuple[list[int], list[int], list[int]]]] = []
        first_bucket: list[int] = []  # family of sel[0], per round
        for sel, states in zip(sels, states_per_round):
            states = list(states)
            parts = {b.index: (pos, local)
                     for b, pos, local in split_cohort(self.buckets, sel)}
            ks = [0] * len(sel)
            round_rows = []
            for f in range(F):
                pos, local = parts.get(f, ([], []))
                ks_b = self._b[f]._budgets(
                    [states[p] for p in pos], n_samples, adaptive_k,
                    len(pos), send_h,
                ) if pos else []
                for p, k in zip(pos, ks_b):
                    ks[p] = k
                round_rows.append((pos, local, ks_b))
            payloads = []
            for f, (pos, local, ks_b) in enumerate(round_rows):
                if not pos:
                    continue
                be = self._b[f]
                _a, pl, _r = be._upload_manifests(
                    [be.clients[j] for j in local],
                    [states[p] for p in pos], ks_b, n_samples, send_h,
                )
                it = iter(pl)
                payloads.extend(
                    (p, next(it)) for p, k in zip(pos, ks_b) if k > 0
                )
            payloads.sort(key=lambda t: t[0])
            all_ks.append(ks)
            all_payloads.append([pl for _, pl in payloads])
            per_round.append(round_rows)
            fb = [f for f, (pos, _l, _k) in enumerate(round_rows) if 0 in pos]
            first_bucket.append(fb[0])
        k_cap = k_cap_bucket(
            [k for ks in all_ks for k in ks], self.vocab
        )
        caps = tuple(
            max(max((len(per_round[r][f][0]) for r in range(num_rounds)),
                    default=0), 1)
            for f in range(F)
        )

        # -- per-bucket padded scan inputs (gather/scatter/ks/batches) --
        gathers, scatters, kss_b, batches_b = [], [], [], []
        for f in range(F):
            be = self._b[f]
            cap = caps[f]
            g_rows, s_rows, k_rows, b_rows = [], [], [], []
            for r in range(num_rounds):
                pos, local, ks_b = per_round[r][f]
                pad = cap - len(local)
                anchor = local[0] if local else 0
                g_rows.append(local + [anchor] * pad)
                s_rows.append(local + [self.buckets[f].size] * pad)
                k_rows.append(ks_b + [0] * pad)
                if local:
                    bat = be._stacked_batches(
                        [be.clients[j] for j in local], step_major=False
                    )
                    bat = {
                        key: np.concatenate(
                            [np.asarray(v)]
                            + [np.zeros_like(np.asarray(v[:1]))] * pad
                        ) if pad else np.asarray(v)
                        for key, v in bat.items()
                    }
                else:
                    # the family sits this round out: all-pad slice, zero
                    # batches (no client rng stream is consumed)
                    shapes = self._zero_batch_shapes(be)
                    bat = {
                        key: np.zeros((cap,) + shape, dtype)
                        for key, (shape, dtype) in shapes.items()
                    }
                b_rows.append(bat)
            gathers.append(jnp.asarray(np.asarray(g_rows), jnp.int32))
            scatters.append(jnp.asarray(np.asarray(s_rows), jnp.int32))
            kss_b.append(jnp.asarray(np.asarray(k_rows), jnp.int32))
            batches_b.append({
                key: jnp.asarray(np.stack([row[key] for row in b_rows]))
                for key in b_rows[0]
            })
        kss_all = jnp.asarray(  # (R, sum caps) in bucket-concat order
            np.concatenate([np.asarray(k) for k in kss_b], axis=1), jnp.int32
        )
        pubs_arr = jnp.stack([jnp.asarray(p) for p in pubs])

        # fleet state + one write-only scratch row per bucket (pad target)
        fleet_loras, fleet_opts, frozens = [], [], []
        for be in self._b:
            fleet_loras.append(jax.tree.map(
                lambda x: jnp.concatenate([x, jnp.zeros_like(x[:1])]), be._lora
            ))
            fleet_opts.append(jax.tree.map(
                lambda x: jnp.concatenate([x, jnp.zeros_like(x[:1])]), be._opt
            ))
            frozens.append(be._frozen)

        if self._b_logits is not None:
            g_tokens, g_logits, g_h = self._b_tokens, self._b_logits, self._b_h
            g_valid = True
        else:
            g_tokens, g_logits, g_h = self._cold_broadcast(pubs_arr[0], n_samples)
            g_valid = False

        eval_args = ()
        if has_eval:
            seen = (
                int(eval_tokens.shape[0]) // fed_steps.EVAL_BATCH
            ) * fed_steps.EVAL_BATCH
            if seen == 0:
                raise ValueError(
                    f"eval split of {int(eval_tokens.shape[0])} samples is "
                    f"smaller than one eval batch ({fed_steps.EVAL_BATCH})"
                )
            eval_args = (
                jnp.asarray(eval_tokens[:seen]), jnp.asarray(eval_labels[:seen])
            )

        chan_ops = ()
        if has_chan:
            chan_ops = _channel_scan_ops(channel_scan, num_rounds) + (
                jnp.asarray(np.asarray(sels), jnp.int32),  # (R, n_real)
            )
        driver = self._hetero_rounds_driver(
            k_cap, send_h, num_rounds, n_real, caps, has_eval, has_chan
        )
        carry, taps = driver(
            tuple(fleet_loras), tuple(fleet_opts),
            self._s_lora, self._s_opt, tuple(frozens), self._s_frozen,
            g_tokens, g_logits, g_h, jnp.asarray(g_valid),
            tuple(gathers), tuple(scatters), tuple(kss_b), tuple(batches_b),
            kss_all, pubs_arr, chan_ops, *eval_args,
        )
        (out_loras, out_opts, self._s_lora, self._s_opt,
         self._b_tokens, self._b_logits, self._b_h, _valid, _chan) = carry
        for be, lora, opt in zip(self._b, out_loras, out_opts):
            n = jax.tree.leaves(be._lora)[0].shape[0]
            be._lora = jax.tree.map(lambda x: x[:n], lora)
            be._opt = jax.tree.map(lambda x: x[:n], opt)
        self._d_loss = taps["distill_loss"][-1]

        def _tolist(name):
            return [float(x) for x in np.asarray(taps[name])]

        family_acc = client_acc = None
        if has_eval:
            fam = np.asarray(taps["family_client_acc"])  # (R, F)
            family_acc = [[float(a) for a in row] for row in fam]
            client_acc = [
                family_acc[r][first_bucket[r]] for r in range(num_rounds)
            ]
        snr_db = outage = None
        if has_chan:
            snr_db = [[float(x) for x in row] for row in np.asarray(taps["snr_db"])]
            outage = [[bool(x) for x in row] for row in np.asarray(taps["outage"])]
        return RoundsTrajectory(
            ks=all_ks,
            payloads=all_payloads,
            mean_k=_tolist("mean_k"),
            distill_loss=_tolist("distill_loss"),
            server_acc=_tolist("server_acc") if has_eval else None,
            client_acc=client_acc,
            family_client_acc=family_acc,
            snr_db=snr_db,
            outage=outage,
        )

    @staticmethod
    def _zero_batch_shapes(be: BatchedEngine) -> dict:
        """Per-sample batch shapes/dtypes of one bucket, WITHOUT consuming
        any client's rng stream (probed from the dataset layout)."""
        c = be.clients[0]
        seq_len = int(c.data.tokens.shape[1])
        bsz = c.batch_size  # epoch_batches always pads up to a full batch
        return {
            "tokens": ((be.local_steps, bsz, seq_len), c.data.tokens.dtype),
            "labels": ((be.local_steps, bsz), c.data.labels.dtype),
        }


def make_engine(kind: str, clients: list[Client], cfg: ModelConfig, **kwargs):
    """Build a round engine.  A fleet whose clients run more than one
    :class:`ModelConfig` (``client.cfg`` differs) is served by the
    family-bucketed heterogeneous engines for every fast ``kind`` — same
    interface, per-bucket executables — while ``sequential`` handles mixed
    fleets natively (each client runs its own architecture)."""
    if kind != "fused_e2e":
        for e2e_only in ("server", "server_distill_steps", "aggregation"):
            kwargs.pop(e2e_only, None)
    if kind == "sequential":
        if kwargs.get("quantize_wire"):
            raise NotImplementedError(
                "quantize_wire is not supported by the sequential reference"
                " engine — use 'batched', 'fused' or 'fused_e2e'"
            )
        if kwargs.get("compute_dtype", "float32") != "float32":
            raise NotImplementedError(
                "compute_dtype is not supported by the sequential reference"
                " engine — use 'fused' or 'fused_e2e'"
            )
        return SequentialEngine(
            clients, cfg,
            value_bits=kwargs.get("value_bits", 16), k_min=kwargs.get("k_min", 1),
        )
    hetero = len({c.cfg for c in clients}) > 1
    if kind == "batched":
        kwargs.pop("shard_clients", None)
        kwargs.pop("use_kernels", None)
        # the batched engine is the fp32 per-phase reference; the bf16 round
        # body exists only on the fused single-executable paths
        kwargs.pop("compute_dtype", None)
        if hetero:
            return HeteroClientEngine(kind, clients, **kwargs)
        return BatchedEngine(clients, cfg, **kwargs)
    if kind == "fused":
        if hetero:
            return HeteroClientEngine(kind, clients, **kwargs)
        return FusedEngine(clients, cfg, **kwargs)
    if kind == "fused_e2e":
        if hetero:
            return HeteroFusedE2EEngine(clients, **kwargs)
        return FusedE2EEngine(clients, cfg, **kwargs)
    raise ValueError(
        f"unknown engine: {kind!r} (expected 'sequential', 'batched', 'fused'"
        " or 'fused_e2e')"
    )
