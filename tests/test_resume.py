"""Crash-safe, bit-identical federated resume (PR 8).

The contract: kill a run after round r, resume from the round-granular
checkpoint, and the completed FedRun is BIT-IDENTICAL to an uninterrupted
run — same per-client adaptive k, same ledger bytes, same accuracies —
because device state round-trips losslessly through the f32 npz and the
host RNG chain is deterministically replayed through the completed rounds.
Tiny no-pretrain configs keep this in the fast tier; one pretrained case
covers the pretrain-skip path.
"""

import dataclasses

import pytest

from repro.checkpoint import latest_step, step_metadata
from repro.configs.base import LoRAConfig
from repro.configs.gpt2_paper import REDUCED_CLIENT, REDUCED_SERVER
from repro.core import ChannelConfig
from repro.data import make_banking77_like
from repro.fed import FedConfig, run_federated

LORA = LoRAConfig(rank=4, alpha=32.0, dropout=0.0, targets=("q", "v", "head"))
CLIENT = REDUCED_CLIENT.with_overrides(
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
    vocab_size=256, max_seq_len=32, lora=LORA,
)
SERVER = REDUCED_SERVER.with_overrides(
    num_layers=2, d_model=96, num_heads=2, num_kv_heads=2, d_ff=192,
    vocab_size=256, max_seq_len=32, lora=LORA,
)
CHAN = ChannelConfig(bandwidth_hz=2e5, mean_snr_db=2.0)


def _dataset():
    return make_banking77_like(vocab_size=CLIENT.vocab_size, seq_len=12, total=500, seed=0)


def _cfg(engine, rounds=4, local_steps=2, **kw):
    kw.setdefault("pretrain_steps", 0)
    return FedConfig(
        method="adald", engine=engine, num_clients=4, clients_per_round=2,
        rounds=rounds, public_size=64, public_batch=16, eval_size=64,
        local_steps=local_steps, distill_steps=1, server_distill_steps=2,
        seed=0, channel=CHAN, **kw,
    )


def _assert_identical(a, b):
    assert a.server_acc == b.server_acc
    assert a.client_acc == b.client_acc
    assert a.mean_k == b.mean_k
    assert a.per_client_k == b.per_client_k
    for ra, rb in zip(a.ledger.rounds, b.ledger.rounds):
        assert ra.uplink_bytes == rb.uplink_bytes
        assert ra.downlink_bytes == rb.downlink_bytes
        assert ra.num_transmitters == rb.num_transmitters


@pytest.mark.parametrize("engine", ["sequential", "batched", "fused", "fused_e2e"])
def test_kill_and_resume_bit_identical(engine, tmp_path):
    """Run 2 of 4 rounds ("the process was killed"), resume, compare to an
    uninterrupted 4-round run."""
    ds = _dataset()
    full = run_federated(CLIENT, SERVER, ds, _cfg(engine))
    d = str(tmp_path)
    run_federated(CLIENT, SERVER, ds, _cfg(engine, rounds=2), ckpt_dir=d)
    assert latest_step(d) == 2
    res = run_federated(CLIENT, SERVER, ds, _cfg(engine), ckpt_dir=d, resume=True)
    _assert_identical(res, full)


def test_kill_and_resume_scan_rounds(tmp_path):
    """The multi-round lax.scan driver checkpoints at block end and resumes
    a shorter scan bit-identically."""
    ds = _dataset()
    scan = lambda rounds: dataclasses.replace(  # noqa: E731
        _cfg("fused_e2e", rounds=rounds), scan_rounds=True
    )
    full = run_federated(CLIENT, SERVER, ds, scan(4))
    d = str(tmp_path)
    run_federated(CLIENT, SERVER, ds, scan(2), ckpt_dir=d)
    res = run_federated(CLIENT, SERVER, ds, scan(4), ckpt_dir=d, resume=True)
    _assert_identical(res, full)


def test_kill_and_resume_with_faults(tmp_path):
    """Fault streams are keyed by (seed, round, cid): the resumed half sees
    the exact realisation the uninterrupted run saw."""
    ds = _dataset()
    full = run_federated(CLIENT, SERVER, ds, _cfg("batched", faults="corruption"))
    d = str(tmp_path)
    run_federated(CLIENT, SERVER, ds,
                  _cfg("batched", rounds=2, faults="corruption"), ckpt_dir=d)
    res = run_federated(CLIENT, SERVER, ds,
                        _cfg("batched", faults="corruption"), ckpt_dir=d, resume=True)
    _assert_identical(res, full)
    assert res.num_quarantined == full.num_quarantined
    assert res.num_crashed == full.num_crashed
    assert res.retrans_bytes == full.retrans_bytes
    assert res.attempted_k == full.attempted_k


def test_kill_and_resume_with_pretraining(tmp_path):
    """Pretrained backbones ride the checkpoint: resume skips the pretrain
    COMPUTE yet stays bit-identical (the shared-backbone layout is
    reproduced before restore)."""
    ds = _dataset()
    cfg = lambda rounds: _cfg(  # noqa: E731
        "fused_e2e", rounds=rounds, pretrain_steps=4, server_pretrain_steps=4
    )
    full = run_federated(CLIENT, SERVER, ds, cfg(3))
    d = str(tmp_path)
    run_federated(CLIENT, SERVER, ds, cfg(1), ckpt_dir=d)
    res = run_federated(CLIENT, SERVER, ds, cfg(3), ckpt_dir=d, resume=True)
    _assert_identical(res, full)


def test_resume_empty_dir_is_fresh_run(tmp_path):
    """resume=True with no checkpoint present falls back to a fresh run."""
    ds = _dataset()
    base = run_federated(CLIENT, SERVER, ds, _cfg("batched", rounds=2))
    res = run_federated(CLIENT, SERVER, ds, _cfg("batched", rounds=2),
                        ckpt_dir=str(tmp_path), resume=True)
    _assert_identical(res, base)


def test_resume_requires_ckpt_dir():
    ds = _dataset()
    with pytest.raises(ValueError, match="ckpt_dir"):
        run_federated(CLIENT, SERVER, ds, _cfg("batched"), resume=True)


def test_resume_rejects_mismatched_config(tmp_path):
    """A checkpoint written under a different FedConfig must refuse to
    resume, naming the differing fields."""
    ds = _dataset()
    d = str(tmp_path)
    run_federated(CLIENT, SERVER, ds, _cfg("batched", rounds=1), ckpt_dir=d)
    with pytest.raises(ValueError, match="local_steps"):
        run_federated(CLIENT, SERVER, ds, _cfg("batched", local_steps=3),
                      ckpt_dir=d, resume=True)


def test_resume_rejects_exhausted_horizon(tmp_path):
    """Resuming a checkpoint that already holds >= rounds completed rounds
    is an error, not a silent no-op."""
    ds = _dataset()
    d = str(tmp_path)
    run_federated(CLIENT, SERVER, ds, _cfg("batched", rounds=2), ckpt_dir=d)
    with pytest.raises(ValueError, match="2 completed rounds"):
        run_federated(CLIENT, SERVER, ds, _cfg("batched", rounds=2),
                      ckpt_dir=d, resume=True)


def test_checkpoint_metadata_carries_history(tmp_path):
    """The sidecar holds the run history up to its step — what a resumed
    FedRun restores its lists from."""
    ds = _dataset()
    d = str(tmp_path)
    run = run_federated(CLIENT, SERVER, ds, _cfg("batched", rounds=2), ckpt_dir=d)
    meta = step_metadata(d, 2)
    assert meta is not None
    assert meta["server_acc"] == run.server_acc
    assert meta["per_client_k"] == run.per_client_k
    assert len(meta["ledger"]) == 2


def test_extended_horizon_resume(tmp_path):
    """rounds is excluded from the fingerprint: a finished run can be
    extended by resuming with a larger horizon, and the shared prefix is
    byte-stable."""
    ds = _dataset()
    d = str(tmp_path)
    short = run_federated(CLIENT, SERVER, ds, _cfg("batched", rounds=2), ckpt_dir=d)
    longer = run_federated(CLIENT, SERVER, ds, _cfg("batched", rounds=4),
                           ckpt_dir=d, resume=True)
    assert longer.server_acc[:2] == short.server_acc
    assert len(longer.server_acc) == 4
    full = run_federated(CLIENT, SERVER, ds, _cfg("batched", rounds=4))
    _assert_identical(longer, full)
