"""Serving driver: prefill a batch of prompts then decode with the KV cache.

Smoke-scale on CPU; the production decode shapes (decode_32k/long_500k with
the seq-sharded cache) are proven by the dry-run.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHITECTURES, get_smoke_config
from repro.launch.steps import make_serve_step
from repro.models import init as model_init, init_cache
from repro.models.frontends import synth_frontend_embeddings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHITECTURES), default="gpt2-paper")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    params = model_init(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)

    serve_step = jax.jit(make_serve_step(cfg))
    cache_len = args.prompt_len + args.tokens

    # prefill by teacher-forcing the prompt through decode steps (smoke-scale;
    # production prefill is the jitted prefill_step in the dry-run)
    enc_out = None
    if cfg.family == "audio":
        from repro.models.model import _run_encoder

        frontend = synth_frontend_embeddings(cfg, args.batch)
        enc_out = _run_encoder(params, cfg, frontend)
    cache = init_cache(cfg, args.batch, cache_len, enc_out=enc_out)
    logits = None
    t0 = time.time()
    for t in range(args.prompt_len):
        logits, cache = serve_step(params, cache, jnp.asarray(prompts[:, t]))
    out = []
    key = jax.random.PRNGKey(args.seed + 1)
    for t in range(args.tokens):
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits / args.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        out.append(np.asarray(nxt))
        logits, cache = serve_step(params, cache, nxt)
    dt = time.time() - t0
    gen = np.stack(out, axis=1)
    assert np.isfinite(np.asarray(logits)).all()
    print(f"[serve] {args.arch}: {args.batch}x{args.tokens} tokens in {dt:.1f}s "
          f"({args.batch * (args.prompt_len + args.tokens) / dt:.1f} tok/s)")
    print("[serve] sample:", gen[0, :16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
