"""Adaptive Top-k logit sparsification (paper §III-A, eqs. 3-4).

Each client keeps only the k largest logits per sample:

    K̃_{n,c}(x) = K_{n,c}(x) * 1[c in I_{n,k}(x)]        (eq. 4)

Two representations are used throughout the framework:

* **sparse** ``(values, indices)`` of shape ``(..., k)`` — what is actually
  "transmitted" (its size is exactly the paper's ``k * d`` bits);
* **dense** ``(..., vocab)`` with zeros off-support — what aggregation
  consumes (paper's server-side view).

Dense top-k masking for very large vocabularies (50k-256k in the assigned
architectures) is the compute hot-spot of the uplink path; a Pallas
bisection-select kernel (:mod:`repro.kernels.topk_select`) implements it
TPU-natively.  This module is the pure-jnp composable API; ``use_kernel=True``
routes to the kernel.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "BISECTION_ITERS",
    "QUANT_LEVELS",
    "SparseLogits",
    "SparseWire",
    "QuantizedWire",
    "topk_sparsify",
    "topk_mask_dense",
    "topk_mask_batch",
    "topk_mask_dynamic",
    "densify",
    "sparsify_batch",
    "sparsify_wire",
    "quantize_wire",
    "dequantize_wire",
    "pad_wire",
    "concat_wires",
    "take_wire_rows",
    "wire_densify",
    "wire_support",
    "payload_entries",
]


class SparseLogits(NamedTuple):
    """Transmitted sparse representation of one client's logits.

    values:  (..., k) top-k logit values, descending.
    indices: (..., k) vocab indices of those values (int32).
    k:       static python int — the channel-adaptive budget this round.
    vocab:   static python int — full dimensionality c.
    """

    values: jax.Array
    indices: jax.Array
    k: int
    vocab: int


def topk_sparsify(logits: jax.Array, k: int) -> SparseLogits:
    """Select the top-k logits per row (paper eq. 3).

    Works for any leading batch shape; the last axis is the vocab axis.
    """
    vocab = logits.shape[-1]
    k = int(min(k, vocab))
    values, indices = jax.lax.top_k(logits, k)
    return SparseLogits(values=values, indices=indices.astype(jnp.int32), k=k, vocab=vocab)


def densify(sparse: SparseLogits, *, fill: float = 0.0) -> jax.Array:
    """Scatter a sparse payload back to a dense ``(..., vocab)`` vector
    (paper eq. 4: zeros off the top-k support, unless ``fill`` overrides)."""
    batch_shape = sparse.values.shape[:-1]
    dense = jnp.full(batch_shape + (sparse.vocab,), fill, dtype=sparse.values.dtype)
    return _scatter_last(dense, sparse.indices, sparse.values)


def _scatter_last(dense: jax.Array, indices: jax.Array, values: jax.Array) -> jax.Array:
    """Scatter ``values`` into ``dense`` along the last axis at ``indices``."""
    # Flatten batch dims, vmap a 1-D scatter, restore shape.
    batch_shape = dense.shape[:-1]
    vocab = dense.shape[-1]
    flat_dense = dense.reshape((-1, vocab))
    flat_idx = indices.reshape((-1, indices.shape[-1]))
    flat_val = values.reshape((-1, values.shape[-1]))

    def scatter_row(row, idx, val):
        return row.at[idx].set(val)

    out = jax.vmap(scatter_row)(flat_dense, flat_idx, flat_val)
    return out.reshape(batch_shape + (vocab,))


def topk_mask_dense(logits: jax.Array, k: int, *, use_kernel: bool = False) -> jax.Array:
    """Dense top-k sparsification: keep top-k per row, zero elsewhere.

    Equivalent to ``densify(topk_sparsify(x, k))`` but computed without
    materialising indices when the Pallas kernel path is used.
    """
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.topk_mask(logits, k)
    sparse = topk_sparsify(logits, k)
    return densify(sparse)


def topk_mask_batch(logits: jax.Array, ks: Sequence[int]) -> jax.Array:
    """Per-client densified top-k of a stacked ``(C, ..., vocab)`` tensor with
    a *different* budget per client (the adaptive-k cohort of one round).

    One ``lax.top_k`` at ``max(ks)`` serves every client; client ``i``'s tail
    entries beyond its own ``ks[i]`` are zeroed before the scatter, so the
    result equals ``stack([densify(topk_sparsify(logits[i], ks[i]))])``
    bit-for-bit (``lax.top_k`` is a stable total-order select, so its first
    ``k_i`` entries at ``k_max`` are exactly its ``k_i`` entries at ``k_i``).
    """
    if logits.shape[0] != len(ks):
        raise ValueError(f"{len(ks)} budgets for {logits.shape[0]} clients")
    vocab = logits.shape[-1]
    ks = [int(min(k, vocab)) for k in ks]
    if min(ks) < 0:
        raise ValueError(f"negative top-k budget in {ks}")
    k_max = max(ks + [1])
    values, indices = jax.lax.top_k(logits, k_max)
    # (C, 1, ..., 1) against (k_max,) -> mask (C, 1, ..., k_max), which then
    # broadcasts over the sample axes of ``values``.
    karr = jnp.asarray(ks, jnp.int32).reshape((len(ks),) + (1,) * (logits.ndim - 1))
    mask = jnp.arange(k_max, dtype=jnp.int32) < karr
    values = jnp.where(mask, values, jnp.zeros_like(values))
    dense = jnp.zeros(logits.shape, dtype=logits.dtype)
    return _scatter_last(dense, indices.astype(jnp.int32), values)


# Threshold-bisection iteration count, shared with the Pallas kernel
# (repro.kernels.topk_select imports it): the jnp and kernel sparsifiers
# must converge identically or their documented exact-parity contract
# (test_fused_use_kernels_matches_jnp_sparsifier) silently breaks.
BISECTION_ITERS = 30


def topk_mask_dynamic(logits: jax.Array, k: jax.Array) -> jax.Array:
    """Dense top-k mask with a TRACED budget ``k`` (int32, broadcastable to
    ``logits.shape[:-1]`` — a scalar, or one budget per leading row).

    The fused round engine bakes the whole client phase into one compiled
    step, so the per-round adaptive ``k`` must be *data*, not a static shape
    — recompiling per distinct ``k`` would defeat the single-jit design.
    Implemented as the same vectorized threshold bisection as the Pallas
    kernel (~30 whole-row passes; an ``jnp.sort`` formulation is ~18x slower
    on XLA CPU): keeps every entry >= the k-th largest per row (threshold
    semantics — exact ties at the threshold are all kept, matching
    :func:`repro.kernels.ref.topk_mask_ref`); ``k == 0`` zeroes the row
    entirely (a dropped straggler transmits nothing).  For distinct values
    this equals ``topk_mask_dense(logits, k)`` exactly.
    """
    vocab = logits.shape[-1]
    x = logits.astype(jnp.float32)
    kk = jnp.broadcast_to(
        jnp.clip(jnp.asarray(k, jnp.int32), 0, vocab), x.shape[:-1]
    )
    lo = jnp.min(x, axis=-1)
    hi = jnp.max(x, axis=-1) + 1.0

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((x >= mid[..., None]).astype(jnp.int32), axis=-1)
        take = cnt >= kk  # mid keeps enough -> move lo up
        return jnp.where(take, mid, lo), jnp.where(take, hi, mid)

    lo, hi = jax.lax.fori_loop(0, BISECTION_ITERS, body, (lo, hi))
    keep = (x >= lo[..., None]) & (kk > 0)[..., None]
    return jnp.where(keep, logits, jnp.zeros_like(logits))


class SparseWire(NamedTuple):
    """The cohort's sparse uplink as ONE fixed-width wire format (PR-3).

    What the paper's clients actually put on the air is ``(value, index)``
    pairs; the server never needs the ``(N, B, V)`` densified stacks the
    dense engines build — at 50k+ vocabularies those stacks are the dominant
    aggregation memory traffic.  This triple carries every client's upload
    at a common static width ``k_cap`` (>= every client's adaptive ``k``),
    with the *explicit* per-entry transmit mask that the dense ``!= 0``
    sentinel could only approximate (a transmitted logit that is exactly 0.0
    is still transmitted):

    values:  (N, ..., k_cap) top-k logit values (0 where not transmitted).
    indices: (N, ..., k_cap) int32 vocab indices (valid even when masked).
    mask:    (N, ..., k_cap) bool — True for entries actually transmitted;
             client n's row budget ``k_n`` masks entries ``[k_n:]``; a
             dropped straggler (k == 0) is all-False.
    vocab:   static python int — full dimensionality c.
    """

    values: jax.Array
    indices: jax.Array
    mask: jax.Array
    vocab: int

    @property
    def k_cap(self) -> int:
        return int(self.values.shape[-1])


class QuantizedWire(NamedTuple):
    """The sparse wire with int8-quantized values (paper §III-A byte model
    at ``value_bits=8``): each row's values are symmetrically quantized to
    int8 against a per-(client, sample)-row float32 scale, so the same
    Shannon budget (eq. 5) buys more top-k entries than the 16-bit float
    wire.  ``indices``/``mask``/``vocab`` are exactly :class:`SparseWire`'s.

    values:  (N, ..., k_cap) int8 quantized logits (0 where not transmitted).
    scale:   (N, ...) float32 per-row dequantization scale, strictly > 0
             (1.0 for all-masked straggler rows, whose values are all 0).
    indices: (N, ..., k_cap) int32 vocab indices (valid even when masked).
    mask:    (N, ..., k_cap) bool transmit mask.
    vocab:   static python int — full dimensionality c.
    """

    values: jax.Array
    scale: jax.Array
    indices: jax.Array
    mask: jax.Array
    vocab: int

    @property
    def k_cap(self) -> int:
        return int(self.values.shape[-1])


Wire = SparseWire | QuantizedWire

# Symmetric int8 range: round(v / scale) lands in [-127, 127], so the scale
# amax/127 is exactly invertible at the extremes and -128 is never emitted.
QUANT_LEVELS = 127


def quantize_wire(wire: SparseWire) -> QuantizedWire:
    """Symmetric per-row int8 quantization of a float wire.

    The scale is ``max|v| / 127`` over each row's TRANSMITTED entries,
    clamped to 1.0 when the row transmits nothing (or only exact zeros) so
    it is strictly positive and dequantization is NaN-free for every input
    — including k=0 straggler rows, which round-trip to exact zeros.
    """
    v = jnp.where(wire.mask, wire.values, 0).astype(jnp.float32)
    amax = jnp.max(jnp.abs(v), axis=-1)
    scale = jnp.where(amax > 0, amax / QUANT_LEVELS, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(v / scale[..., None]), -QUANT_LEVELS, QUANT_LEVELS)
    return QuantizedWire(
        values=q.astype(jnp.int8),
        scale=scale,
        indices=wire.indices,
        mask=wire.mask,
        vocab=wire.vocab,
    )


def dequantize_wire(wire: QuantizedWire) -> SparseWire:
    """Reconstruct the float wire: ``values * scale`` per row, exact zeros
    off the transmit mask."""
    v = wire.values.astype(jnp.float32) * wire.scale[..., None]
    return SparseWire(
        values=jnp.where(wire.mask, v, 0.0),
        indices=wire.indices,
        mask=wire.mask,
        vocab=wire.vocab,
    )


def sparsify_wire(
    logits: jax.Array, ks: jax.Array, k_cap: int, *, quantize: bool = False
) -> Wire:
    """Per-client adaptive top-k of a stacked ``(N, ..., vocab)`` tensor as
    the sparse wire format, with the budgets ``ks`` as DATA (int32,
    broadcastable to ``logits.shape[:-1]``; typically ``(N,)`` — one budget
    per client).

    One ``lax.top_k`` at the static width ``k_cap`` serves every client;
    client i's entries beyond its own ``ks[i]`` are masked out.  Because
    ``lax.top_k`` is a stable total-order select, the unmasked entries equal
    ``topk_sparsify(logits[i], ks[i])`` exactly — including ties — so
    ``wire_densify(sparsify_wire(x, ks, k_cap)) == topk_mask_batch(x, ks)``
    bit-for-bit whenever ``k_cap >= max(ks)``.

    ``quantize=True`` emits the int8 :class:`QuantizedWire` directly (the
    §III-A byte model at ``value_bits=8``) instead of the float wire.
    """
    vocab = logits.shape[-1]
    k_cap = int(min(k_cap, vocab))
    values, indices = jax.lax.top_k(logits, k_cap)
    kk = jnp.clip(jnp.asarray(ks, jnp.int32), 0, vocab)
    # pad trailing sample axes so a (N,) budget broadcasts over (N, ..., k_cap)
    kk = kk.reshape(kk.shape + (1,) * (values.ndim - kk.ndim))
    mask = jnp.broadcast_to(
        jnp.arange(k_cap, dtype=jnp.int32) < kk, values.shape
    )
    wire = SparseWire(
        values=jnp.where(mask, values, jnp.zeros_like(values)),
        indices=indices.astype(jnp.int32),
        mask=mask,
        vocab=vocab,
    )
    return quantize_wire(wire) if quantize else wire


def pad_wire(wire: Wire, k_cap: int) -> Wire:
    """Widen a wire to ``k_cap`` entries per row by appending masked-out
    padding (value 0, index 0, mask False) — a no-op on the transmitted
    content (``wire_densify``/``aggregate_wire`` ignore masked entries).
    Used to bring several family buckets' wires to one common width before
    :func:`concat_wires`.  Handles both the float and the quantized wire
    (the per-row scale has no entry axis, so it is untouched)."""
    pad = k_cap - wire.k_cap
    if pad < 0:
        raise ValueError(f"cannot shrink a wire from {wire.k_cap} to {k_cap}")
    if pad == 0:
        return wire
    widths = [(0, 0)] * (wire.values.ndim - 1) + [(0, pad)]
    values = jnp.pad(wire.values, widths)
    indices = jnp.pad(wire.indices, widths)
    mask = jnp.pad(wire.mask, widths)
    if isinstance(wire, QuantizedWire):
        return QuantizedWire(values=values, scale=wire.scale, indices=indices,
                             mask=mask, vocab=wire.vocab)
    return SparseWire(values=values, indices=indices, mask=mask, vocab=wire.vocab)


def concat_wires(wires: Sequence[Wire]) -> Wire:
    """Union of several cohorts' uplinks as ONE wire: concatenate along the
    leading client axis, first padding every wire to the widest ``k_cap``.

    This is the heterogeneous round's merge point: each family bucket's
    client phase emits its own wire, and because the wire is VOCAB-indexed
    the union aggregates exactly as one homogeneous cohort would (paper
    eqs. 6-7 never see an architecture, only dimensions of the shared logit
    space).  All wires must share ``vocab``.
    """
    if not wires:
        raise ValueError("concat_wires needs at least one wire")
    vocabs = {w.vocab for w in wires}
    if len(vocabs) > 1:
        raise ValueError(f"wires address different vocabularies: {sorted(vocabs)}")
    formats = {type(w) for w in wires}
    if len(formats) > 1:
        raise ValueError("cannot union float and quantized wires — "
                         "quantize (or dequantize) every bucket first")
    k_cap = max(w.k_cap for w in wires)
    padded = [pad_wire(w, k_cap) for w in wires]
    values = jnp.concatenate([w.values for w in padded], axis=0)
    indices = jnp.concatenate([w.indices for w in padded], axis=0)
    mask = jnp.concatenate([w.mask for w in padded], axis=0)
    if isinstance(wires[0], QuantizedWire):
        scale = jnp.concatenate([w.scale for w in padded], axis=0)
        return QuantizedWire(values=values, scale=scale, indices=indices,
                             mask=mask, vocab=wires[0].vocab)
    return SparseWire(values=values, indices=indices, mask=mask, vocab=wires[0].vocab)


def take_wire_rows(wire: Wire, rows) -> Wire:
    """Gather/permute a wire's leading client axis (e.g. reorder a union
    wire's rows into cohort order, or keep transmitters only)."""
    take = jnp.asarray(rows, jnp.int32)
    if isinstance(wire, QuantizedWire):
        return QuantizedWire(
            values=wire.values[take],
            scale=wire.scale[take],
            indices=wire.indices[take],
            mask=wire.mask[take],
            vocab=wire.vocab,
        )
    return SparseWire(
        values=wire.values[take],
        indices=wire.indices[take],
        mask=wire.mask[take],
        vocab=wire.vocab,
    )


def _scatter_add_last(dense: jax.Array, indices: jax.Array, values: jax.Array) -> jax.Array:
    """Scatter-ADD ``values`` into ``dense`` along the last axis.

    Wire rows may carry DUPLICATE indices: ``pad_wire`` appends masked
    entries at index 0, so a padded row holds its genuine entries plus pad
    entries all pointing at vocab index 0.  ``.at[idx].set`` leaves the
    winner among duplicates unspecified (a pad entry can clobber a real
    index-0 logit); ``.at[idx].add`` is order-free, and the masked entries
    contribute exactly 0 — so it must be the wire densification primitive.
    (The genuine top-k indices within a row are distinct, so add == set
    for the transmitted content.)
    """
    batch_shape = dense.shape[:-1]
    vocab = dense.shape[-1]
    flat_dense = dense.reshape((-1, vocab))
    flat_idx = indices.reshape((-1, indices.shape[-1]))
    flat_val = values.reshape((-1, values.shape[-1]))

    def scatter_row(row, idx, val):
        return row.at[idx].add(val)

    out = jax.vmap(scatter_row)(flat_dense, flat_idx, flat_val)
    return out.reshape(batch_shape + (vocab,))


def wire_densify(wire: Wire) -> jax.Array:
    """Scatter a wire payload back to the dense ``(N, ..., vocab)`` stack the
    dense aggregation oracle consumes (zeros off the transmitted support).
    Quantized wires are dequantized first."""
    if isinstance(wire, QuantizedWire):
        wire = dequantize_wire(wire)
    batch_shape = wire.values.shape[:-1]
    dense = jnp.zeros(batch_shape + (wire.vocab,), dtype=wire.values.dtype)
    return _scatter_add_last(dense, wire.indices, jnp.where(wire.mask, wire.values, 0))


def wire_support(wire: Wire) -> jax.Array:
    """Dense ``(N, ..., vocab)`` bool transmit mask — which dimensions each
    client actually transmitted (the explicit-sentinel companion of
    :func:`wire_densify`; True even where the transmitted value is 0.0).
    Accumulate-and-threshold so masked pad entries at index 0 cannot
    clobber a genuine index-0 transmission."""
    batch_shape = wire.values.shape[:-1]
    dense = jnp.zeros(batch_shape + (wire.vocab,), dtype=jnp.float32)
    return _scatter_add_last(dense, wire.indices, wire.mask.astype(jnp.float32)) > 0


def sparsify_batch(logits: jax.Array, k: int) -> SparseLogits:
    """Alias of :func:`topk_sparsify` for (num_samples, vocab) batches —
    the per-round public-set upload of one client."""
    return topk_sparsify(logits, k)


def payload_entries(sparse: SparseLogits) -> int:
    """Number of (value, index) entries in a payload = samples * k."""
    n = 1
    for s in sparse.values.shape:
        n *= int(s)
    return n
