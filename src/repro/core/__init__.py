"""AdaLD core: the paper's contribution as composable JAX modules.

Public API:
  channel     — Shannon-capacity byte budgets (paper eq. 5, §III-A)
  scenario    — time-correlated channel dynamics (Gauss-Markov / Jakes
                fading, Gilbert-Elliott outage, mobility trajectories)
  faults      — fault injection, wire quarantine, HARQ retransmission
  topk        — adaptive Top-k sparsification (eqs. 3-4)
  aggregation — adaptive / zeropad / mean aggregation (eqs. 6-7)
  distill     — logits + LoRA-projection KL losses (eqs. 8-10)
  protocol    — exact communication accounting (§III-C, Fig. 3)
"""

from repro.core.aggregation import (
    aggregate,
    aggregate_adaptive,
    aggregate_mean_nonzero,
    aggregate_sparse,
    aggregate_zeropad,
)
from repro.core.channel import (
    BatchedChannelState,
    ChannelCarry,
    ChannelConfig,
    ChannelSimulator,
    ChannelState,
    bits_per_entry,
    capacity_bps,
    topk_budget,
    topk_budget_batch,
)
from repro.core.scenario import (
    SCENARIOS,
    ScenarioConfig,
    get_scenario,
    jakes_rho,
)
from repro.core.distill import (
    DEFAULT_LAMBDA,
    DEFAULT_TEMPERATURE,
    kl_divergence,
    logits_distill_loss,
    lora_projection_loss,
    soft_labels,
    total_distill_loss,
)
from repro.core.faults import (
    FAULTS,
    FaultCarry,
    FaultConfig,
    FaultResolution,
    FaultSimulator,
    corrupt_wire,
    get_faults,
    quarantine_wire,
    validate_dense,
    validate_wire,
)
from repro.core.protocol import (
    CommLedger,
    PayloadSpec,
    RoundStats,
    UplinkPayload,
    full_logits_bits,
    topk_upload_bits,
)
from repro.core.topk import (
    SparseLogits,
    densify,
    topk_mask_batch,
    topk_mask_dense,
    topk_sparsify,
)

__all__ = [
    "aggregate",
    "aggregate_adaptive",
    "aggregate_mean_nonzero",
    "aggregate_sparse",
    "aggregate_zeropad",
    "BatchedChannelState",
    "ChannelCarry",
    "ChannelConfig",
    "ChannelSimulator",
    "ChannelState",
    "bits_per_entry",
    "capacity_bps",
    "topk_budget",
    "topk_budget_batch",
    "SCENARIOS",
    "ScenarioConfig",
    "get_scenario",
    "jakes_rho",
    "FAULTS",
    "FaultCarry",
    "FaultConfig",
    "FaultResolution",
    "FaultSimulator",
    "corrupt_wire",
    "get_faults",
    "quarantine_wire",
    "validate_dense",
    "validate_wire",
    "DEFAULT_LAMBDA",
    "DEFAULT_TEMPERATURE",
    "kl_divergence",
    "logits_distill_loss",
    "lora_projection_loss",
    "soft_labels",
    "total_distill_loss",
    "CommLedger",
    "PayloadSpec",
    "RoundStats",
    "UplinkPayload",
    "full_logits_bits",
    "topk_upload_bits",
    "SparseLogits",
    "densify",
    "topk_mask_batch",
    "topk_mask_dense",
    "topk_sparsify",
]
