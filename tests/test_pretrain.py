"""Backbone pretraining stage (simulated pretrained W', DESIGN §1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.configs.gpt2_paper import REDUCED_CLIENT
from repro.data import make_fed_benchmark_dataset
from repro.fed.pretrain import pretrain_classifier, pretrain_lm
from repro.fed.steps import make_eval_fn
from repro.lora import split_lora

CFG = REDUCED_CLIENT.with_overrides(num_layers=2, d_model=128, num_heads=4, d_ff=256)


def test_supervised_pretrain_beats_chance():
    ds = make_fed_benchmark_dataset(CFG.vocab_size, seed=0, total=900)
    params = pretrain_classifier(CFG, ds.subset(np.arange(300)), num_classes=77,
                                 steps=40, seed=0)
    ev = make_eval_fn(CFG, 77)
    acc = ev(params, jnp.asarray(ds.tokens[300:556]), jnp.asarray(ds.labels[300:556]))
    assert acc > 5 / 77, acc


def test_pretrain_returns_zero_delta_lora():
    """FL must start from W' + B=0 (paper eq. 1): pretraining is absorbed
    into the frozen backbone, adapters reset."""
    ds = make_fed_benchmark_dataset(CFG.vocab_size, seed=1, total=400)
    params = pretrain_classifier(CFG, ds, num_classes=77, steps=5, seed=0)
    lora, _ = split_lora(params)
    for path, leaf in jax.tree_util.tree_leaves_with_path(lora):
        name = str(path[-1].key if hasattr(path[-1], "key") else path[-1])
        if name == "B":
            assert float(jnp.max(jnp.abs(leaf))) == 0.0, path


def test_pretrain_cached():
    ds = make_fed_benchmark_dataset(CFG.vocab_size, seed=2, total=400)
    a = pretrain_classifier(CFG, ds, num_classes=77, steps=3, seed=7)
    b = pretrain_classifier(CFG, ds, num_classes=77, steps=3, seed=7)
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_lm_pretrain_carries_no_label_info():
    """LM-only pretraining must leave classification at chance — the server
    curve then isolates what distillation transfers."""
    ds = make_fed_benchmark_dataset(CFG.vocab_size, seed=3, total=600)
    params = pretrain_lm(CFG, ds.subset(np.arange(200)), steps=15, seed=0)
    ev = make_eval_fn(CFG, 77)
    acc = ev(params, jnp.asarray(ds.tokens[300:556]), jnp.asarray(ds.labels[300:556]))
    assert acc < 6 / 77, f"LM pretrain leaked label info: {acc}"
