"""Batching pipeline: shuffled epochs, drop-remainder, numpy -> device."""

from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.data.synthetic import IntentDataset

__all__ = ["batch_iterator", "epoch_batches", "pad_to_batch"]


def pad_to_batch(arr: np.ndarray, batch: int) -> np.ndarray:
    """Cyclic-pad the leading axis up to ``batch`` (small-client case)."""
    n = arr.shape[0]
    if n >= batch:
        return arr[:batch]
    reps = int(np.ceil(batch / n))
    return np.concatenate([arr] * reps, axis=0)[:batch]


def epoch_batches(
    ds: IntentDataset, batch_size: int, *, rng: np.random.Generator, drop_last: bool = True
) -> Iterator[dict]:
    idx = rng.permutation(len(ds))
    n_full = len(ds) // batch_size
    if n_full == 0:
        # tiny client shard: one cyclically-padded batch
        sel = pad_to_batch(idx, batch_size)
        yield {"tokens": ds.tokens[sel], "labels": ds.labels[sel]}
        return
    for b in range(n_full):
        sel = idx[b * batch_size : (b + 1) * batch_size]
        yield {"tokens": ds.tokens[sel], "labels": ds.labels[sel]}
    if not drop_last and len(ds) % batch_size:
        sel = pad_to_batch(idx[n_full * batch_size :], batch_size)
        yield {"tokens": ds.tokens[sel], "labels": ds.labels[sel]}


def batch_iterator(
    ds: IntentDataset, batch_size: int, *, seed: int = 0, max_batches: int | None = None
) -> Iterator[dict]:
    """Endless (or capped) shuffled batch stream across epochs."""
    rng = np.random.default_rng(seed)
    count = 0
    while True:
        for batch in epoch_batches(ds, batch_size, rng=rng):
            yield batch
            count += 1
            if max_batches is not None and count >= max_batches:
                return
