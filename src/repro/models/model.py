"""Top-level model: embeddings + stack(s) + LM head, for every family.

API (all pure functions of (params, inputs)):

  init(rng, cfg)                                  -> params
  forward(params, cfg, batch)                     -> (logits, Aux)
  init_cache(cfg, batch, cache_len, window=None)  -> cache pytree
  decode_step(params, cfg, cache, token)          -> (logits, cache)
  prefill(params, cfg, batch, cache_len)          -> (logits, cache)

``batch``:
  tokens   (B, S) int32                        — always
  frontend (B, F, d_model) float               — vlm (prepended) / audio (encoder)

Aux carries moe load-balance loss and the pooled LoRA projection ``h``
(paper eq. 8) for the distillation objective.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.frontends import synth_frontend_embeddings
from repro.models.layers import embedding_init, norm_apply, norm_init
from repro.models.transformer import (
    init_stack_cache,
    stack_apply,
    stack_init,
)

__all__ = ["Aux", "init", "forward", "init_cache", "decode_step", "prefill", "input_token_len"]


class Aux(NamedTuple):
    moe_aux: jax.Array  # () load-balance loss
    lora_h: jax.Array | None  # (B, r) pooled LoRA projection (paper eq. 8)


def input_token_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text tokens per sample given the assigned shape's seq_len.

    For VLM the frontend patches occupy part of the sequence budget, so the
    text stream is seq_len - frontend_len (total processed length stays at
    the assigned seq_len).
    """
    if cfg.family == "vlm":
        return seq_len - cfg.frontend_len
    return seq_len


def init(rng: jax.Array, cfg: ModelConfig) -> dict:
    keys = jax.random.split(rng, 6)
    params: dict[str, Any] = {
        "embed": embedding_init(keys[0], cfg.vocab_size, cfg.d_model, dtype=cfg.param_dtype),
        "final_norm": norm_init(cfg.d_model, kind=cfg.norm, dtype=cfg.param_dtype),
        "stack": stack_init(keys[1], cfg, cfg.num_layers, cross=cfg.cross_attention),
    }
    if cfg.positional == "learned":
        params["pos_embed"] = embedding_init(keys[2], cfg.max_seq_len, cfg.d_model, dtype=cfg.param_dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = embedding_init(keys[3], cfg.vocab_size, cfg.d_model, dtype=cfg.param_dtype)
    if cfg.lora is not None and "head" in cfg.lora.targets:
        import jax.numpy as _jnp

        a = jax.random.normal(keys[5], (cfg.d_model, cfg.lora.rank), _jnp.float32)
        params["lora_head"] = {
            "A": (a / cfg.d_model**0.5).astype(_jnp.dtype(cfg.param_dtype)),
            "B": _jnp.zeros((cfg.lora.rank, cfg.vocab_size), _jnp.dtype(cfg.param_dtype)),
        }
    if cfg.encoder_layers > 0:
        params["encoder"] = stack_init(keys[4], cfg, cfg.encoder_layers, cross=False)
        params["enc_norm"] = norm_init(cfg.d_model, kind=cfg.norm, dtype=cfg.param_dtype)
    return params


def _embed_tokens(params: dict, cfg: ModelConfig, tokens: jax.Array, positions: jax.Array) -> jax.Array:
    from repro import sharding as _sh

    cd = jnp.dtype(cfg.compute_dtype)
    if _sh.rules_installed() and tokens.ndim == 2 and tokens.shape[1] > 1:
        # one-hot matmul instead of gather: SPMD partitions the contraction
        # over the vocab shards (a gather on the model-sharded table forces
        # involuntary replication of the whole embedding — §Perf iteration 5).
        # The one-hot MUST be vocab-sharded and rematerialised: an unsharded
        # (B,S,V) one-hot stored as a backward residual per microbatch cost
        # +32 GB/chip at seamless train (§Perf iteration 10 regression fix).
        def embed(tok, table):
            onehot = jax.nn.one_hot(tok, cfg.vocab_size, dtype=cd)
            onehot = _sh.constrain(onehot, "batch", None, "vocab")
            return jnp.einsum("bsv,vd->bsd", onehot, table.astype(cd))

        x = jax.checkpoint(embed)(tokens, params["embed"])
    else:
        x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    if cfg.positional == "learned":
        x = x + jnp.take(params["pos_embed"], positions, axis=0).astype(cd)
    return x


def _lm_logits(
    params: dict, cfg: ModelConfig, x: jax.Array, head_cols: int | None = None
) -> jax.Array:
    """LM-head logits; ``head_cols`` restricts the head to its FIRST
    ``head_cols`` vocab columns (each retained logit is the identical dot
    product, so this equals slicing the full output — at head_cols/V of the
    FLOPs).  The classification readout (paper §IV: class logits = the first
    num_classes vocab ids) only ever consumes those columns."""
    cd = jnp.dtype(cfg.compute_dtype)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    if head_cols is not None:
        head = head[:head_cols]
    logits = jnp.einsum("bsd,vd->bsv", x.astype(cd), head.astype(cd))
    if "lora_head" in params:  # LoRA on the LM head (PEFT-standard target)
        lh = params["lora_head"]
        if lh["A"].ndim == 3:  # per-request adapters (repro.serve): (B, d, r)
            lb = lh["B"] if head_cols is None else lh["B"][:, :, :head_cols]
            h = jnp.einsum("bsd,bdr->bsr", x.astype(cd), lh["A"].astype(cd))
            delta = jnp.einsum("bsr,brv->bsv", h, lb.astype(cd))
        else:
            lb = lh["B"] if head_cols is None else lh["B"][:, :head_cols]
            h = jnp.einsum("bsd,dr->bsr", x.astype(cd), lh["A"].astype(cd))
            delta = jnp.einsum("bsr,rv->bsv", h, lb.astype(cd))
        logits = logits + delta * (cfg.lora.alpha / cfg.lora.rank)
    return logits


def _run_encoder(params: dict, cfg: ModelConfig, frontend: jax.Array) -> jax.Array:
    pos = jnp.arange(frontend.shape[1], dtype=jnp.int32)
    st, _ = stack_apply(
        params["encoder"],
        frontend.astype(jnp.dtype(cfg.compute_dtype)),
        cfg,
        cfg.encoder_layers,
        positions=pos,
        causal=False,
    )
    return norm_apply(params["enc_norm"], st.x, kind=cfg.norm)


def backbone(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
    *,
    window: int | None = None,
    last_only: bool = False,
) -> tuple[jax.Array, Aux]:
    """Full-sequence hidden states (post final-norm, pre LM head).

    For VLM the returned hidden covers the TEXT region only (frontend
    positions are processed but dropped before the head).  Training uses
    this + chunked cross-entropy so (B, S, vocab) logits never materialise.

    ``last_only=True`` returns the FINAL text position only, shape
    ``(B, 1, d_model)``: the stack still processes every position (causal
    mixing needs them) but the final norm — and, downstream, the LM head —
    touch one position instead of S.  ``Aux`` is identical to the full
    forward: the pooled LoRA projection (paper eq. 8) always pools over the
    whole sequence.
    """
    tokens = batch["tokens"]
    b, s_text = tokens.shape
    window = window if window is not None else cfg.sliding_window

    enc_out = None
    if cfg.family == "audio":
        frontend = batch.get("frontend")
        if frontend is None:
            frontend = synth_frontend_embeddings(cfg, b)
        enc_out = _run_encoder(params, cfg, frontend)

    if cfg.family == "vlm":
        frontend = batch.get("frontend")
        if frontend is None:
            frontend = synth_frontend_embeddings(cfg, b)
        f = frontend.shape[1]
        pos = jnp.arange(f + s_text, dtype=jnp.int32)
        x_text = _embed_tokens(params, cfg, tokens, pos[f:])
        x = jnp.concatenate([frontend.astype(x_text.dtype), x_text], axis=1)
    else:
        pos = jnp.arange(s_text, dtype=jnp.int32)
        x = _embed_tokens(params, cfg, tokens, pos)

    st, _ = stack_apply(
        params["stack"], x, cfg, cfg.num_layers, positions=pos, window=window, enc_out=enc_out
    )
    x_out = st.x
    if cfg.family == "vlm":
        x_out = x_out[:, frontend.shape[1] :]  # text region only
    lora_h = st.lora_h
    # The SSM fallback projection pools over the FULL normalized sequence, so
    # that path must norm every position even under last_only.
    need_fallback_h = lora_h is None and "lora_head" in params
    if last_only and not need_fallback_h:
        h = norm_apply(params["final_norm"], x_out[:, -1:], kind=cfg.norm)
    else:
        h = norm_apply(params["final_norm"], x_out, kind=cfg.norm)
        if need_fallback_h:
            # attention-free families (SSM) have no q/v adapters; the paper's
            # projection h = A·x (eq. 8) comes from the head adapter instead —
            # any low-rank adapter satisfies the cross-family exchange contract.
            cd = jnp.dtype(cfg.compute_dtype)
            lora_h = jnp.mean(
                jnp.einsum("bsd,dr->bsr", h.astype(cd), params["lora_head"]["A"].astype(cd)),
                axis=1,
            )
        if last_only:
            h = h[:, -1:]
    return h, Aux(moe_aux=st.moe_aux, lora_h=lora_h)


def forward(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
    *,
    window: int | None = None,
    last_only: bool = False,
    head_cols: int | None = None,
) -> tuple[jax.Array, Aux]:
    """Full-sequence forward returning (B, S_text, vocab) logits.

    ``last_only=True`` computes the LM head on the final position only and
    returns ``(B, vocab)`` — identical (to float tolerance) to
    ``forward(...)[0][:, -1, :]`` at ~1/S of the head FLOPs/memory.  This is
    the mode every federated phase uses: the task convention (paper §IV)
    reads class and distillation logits at the last position exclusively.

    ``head_cols=k`` computes only the first k head columns (bit-identical to
    slicing ``[..., :k]`` of the full logits) — the supervised
    classification losses/eval read ``num_classes`` of the 50k+ vocab
    logits, a ~V/num_classes head-FLOP cut on those phases.
    """
    h, aux = backbone(params, cfg, batch, window=window, last_only=last_only)
    logits = _lm_logits(params, cfg, h, head_cols)
    if last_only:
        return logits[:, 0], aux
    return logits, aux


def init_cache(
    cfg: ModelConfig,
    batch: int,
    cache_len: int,
    *,
    window: int | None = None,
    enc_out: jax.Array | None = None,
) -> dict:
    """Decode cache: per-layer KV/SSM caches + absolute length + optional
    fixed encoder output (audio cross-attention)."""
    window = window if window is not None else cfg.sliding_window
    cache = {
        "layers": init_stack_cache(cfg, cfg.num_layers, batch, cache_len, window=window),
        "length": jnp.zeros((), jnp.int32),
    }
    if cfg.family == "audio":
        if enc_out is None:
            enc_out = jnp.zeros((batch, cfg.frontend_len, cfg.d_model), jnp.dtype(cfg.compute_dtype))
        cache["enc_out"] = enc_out
    return cache


def decode_step(
    params: dict,
    cfg: ModelConfig,
    cache: dict,
    token: jax.Array,  # (B,) int32 — the newly sampled token
    *,
    window: int | None = None,
) -> tuple[jax.Array, dict]:
    """One serving step: consume `token`, return next-token logits + cache."""
    window = window if window is not None else cfg.sliding_window
    b = token.shape[0]
    length = cache["length"]
    pos = jnp.broadcast_to(length[None], (1,)).astype(jnp.int32)
    x = _embed_tokens(params, cfg, token[:, None], pos)
    enc_out = cache.get("enc_out")

    st, new_layer_caches = stack_apply(
        params["stack"],
        x,
        cfg,
        cfg.num_layers,
        positions=pos,
        window=window,
        caches=cache["layers"],
        enc_out=enc_out,
    )
    h = norm_apply(params["final_norm"], st.x, kind=cfg.norm)
    logits = _lm_logits(params, cfg, h)[:, 0]  # (B, V)

    new_cache = dict(cache)
    new_cache["layers"] = new_layer_caches
    new_cache["length"] = length + 1
    return logits, new_cache


def prefill(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
    *,
    window: int | None = None,
) -> tuple[jax.Array, Aux]:
    """Prefill: full forward over the prompt, returning only the
    LAST-position logits (B, vocab) — what sampling needs.  (Cache writes
    during prefill are a serving-runtime concern; the full-sequence compute
    here dominates prefill cost, which is what the dry-run measures.)"""
    return forward(params, cfg, batch, window=window, last_only=True)
