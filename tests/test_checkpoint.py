"""Checkpoint save/restore."""

import jax
import numpy as np

from repro.checkpoint import latest_step, restore_step, save_step
from repro.configs import get_smoke_config
from repro.models import init


def test_roundtrip(tmp_path):
    cfg = get_smoke_config("granite-moe-1b-a400m")
    params = init(jax.random.PRNGKey(0), cfg)
    save_step(str(tmp_path), 5, {"params": params}, arch=cfg.name)
    save_step(str(tmp_path), 9, {"params": params}, arch=cfg.name)
    assert latest_step(str(tmp_path)) == 9
    restored, step = restore_step(str(tmp_path), {"params": params})
    assert step == 9
    for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_leaves_with_path({"params": params}),
        jax.tree_util.tree_leaves_with_path(restored),
    ):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_empty(tmp_path):
    assert latest_step(str(tmp_path / "nope")) is None
