"""MoE routing: capacity behaviour, gate normalisation, load-balance aux."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import moe_apply, moe_init

pytestmark = pytest.mark.slow  # model-zoo/layer suites ride the slow tier


def _cfg(experts=4, top_k=2, cf=1.25):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=64,
        moe=MoEConfig(num_experts=experts, top_k=top_k, d_ff=64, capacity_factor=cf),
    )


def test_moe_shapes_and_finite():
    cfg = _cfg()
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out, aux = moe_apply(params, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0.0


def test_aux_loss_near_one_for_balanced_router():
    """Switch aux = E * sum(f_e * p_e) ~= 1 when routing is uniform."""
    cfg = _cfg(experts=8, top_k=1)
    params = moe_init(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 256, 32))
    _, aux = moe_apply(params, x, cfg)
    assert 0.8 < float(aux) < 2.0  # heavily imbalanced would be >> E/2


def test_tiny_capacity_drops_tokens():
    """With capacity_factor→0 the capacity floor (4) binds and most tokens
    are dropped: output magnitude shrinks."""
    cfg_full = _cfg(cf=8.0)
    cfg_tiny = _cfg(cf=1e-6)
    params = moe_init(jax.random.PRNGKey(4), cfg_full)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 512, 32))
    out_full, _ = moe_apply(params, x, cfg_full)
    out_tiny, _ = moe_apply(params, x, cfg_tiny)
    assert float(jnp.mean(jnp.abs(out_tiny))) < float(jnp.mean(jnp.abs(out_full)))


def test_moe_differentiable():
    cfg = _cfg()
    params = moe_init(jax.random.PRNGKey(6), cfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 32, 32))

    def loss(p):
        out, aux = moe_apply(p, x, cfg)
        return jnp.sum(out**2) + aux

    grads = jax.grad(loss)(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gnorm > 0 and jnp.isfinite(gnorm)


def test_capacity_is_per_group():
    """Group-local dispatch: token count per group bounds the dispatch tensor
    (regression test for the O(T^2) ungrouped form)."""
    from repro.models.moe import GROUP_SIZE

    cfg = _cfg()
    params = moe_init(jax.random.PRNGKey(8), cfg)
    t = GROUP_SIZE * 2
    x = jax.random.normal(jax.random.PRNGKey(9), (1, t, 32))
    out, _ = moe_apply(params, x, cfg)
    assert out.shape == (1, t, 32)
