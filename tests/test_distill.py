"""Distillation losses (paper eqs. 8-10)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distill import (
    kl_divergence,
    logits_distill_loss,
    lora_projection_loss,
    soft_labels,
    total_distill_loss,
)


def test_identical_distributions_zero():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 100))
    assert float(kl_divergence(x, x)) == pytest.approx(0.0, abs=1e-5)


def test_kl_nonnegative():
    t = jax.random.normal(jax.random.PRNGKey(1), (16, 64)) * 3
    s = jax.random.normal(jax.random.PRNGKey(2), (16, 64)) * 3
    assert float(kl_divergence(t, s)) >= 0.0


def test_kl_asymmetric():
    t = jnp.array([[6.0, 0.0, -2.0]])
    s = jnp.array([[1.0, 1.0, 1.0]])
    assert float(kl_divergence(t, s)) != pytest.approx(float(kl_divergence(s, t)), rel=1e-3)


def test_temperature_scaling_identity():
    """With scale_by_t2, KL at T is comparable across T; at T→∞ it → 0
    relative to T=1 for the same logits."""
    t = jax.random.normal(jax.random.PRNGKey(3), (4, 50)) * 5
    s = jax.random.normal(jax.random.PRNGKey(4), (4, 50)) * 5
    kl_t1 = float(kl_divergence(t, s, 1.0))
    kl_t2_unscaled = float(kl_divergence(t, s, 2.0, scale_by_t2=False))
    kl_t2_scaled = float(kl_divergence(t, s, 2.0, scale_by_t2=True))
    assert kl_t2_scaled == pytest.approx(kl_t2_unscaled * 4.0, rel=1e-5)
    assert kl_t2_unscaled < kl_t1  # softer distributions are closer


def test_soft_labels_normalized():
    x = jax.random.normal(jax.random.PRNGKey(5), (6, 30))
    p = soft_labels(x, 2.0)
    np.testing.assert_allclose(jnp.sum(p, -1), jnp.ones(6), rtol=1e-5)


def test_total_loss_lambda_composition():
    t = jax.random.normal(jax.random.PRNGKey(6), (4, 40))
    s = jax.random.normal(jax.random.PRNGKey(7), (4, 40))
    th = jax.random.normal(jax.random.PRNGKey(8), (4, 8))
    sh = jax.random.normal(jax.random.PRNGKey(9), (4, 8))
    total, parts = total_distill_loss(t, s, th, sh, lam=0.5)
    assert float(total) == pytest.approx(
        float(parts["logits"]) + 0.5 * float(parts["lora"]), rel=1e-5
    )
    # no projections -> logits-only (the paper's 'Adaptive' baseline)
    total0, parts0 = total_distill_loss(t, s, None, None, lam=0.5)
    assert float(total0) == pytest.approx(float(parts0["logits"]), rel=1e-6)
    assert float(parts0["lora"]) == 0.0


def test_lora_projection_loss_matches_kl():
    th = jax.random.normal(jax.random.PRNGKey(10), (4, 8))
    sh = jax.random.normal(jax.random.PRNGKey(11), (4, 8))
    assert float(lora_projection_loss(th, sh)) == pytest.approx(
        float(kl_divergence(th, sh)), rel=1e-6
    )


def test_support_restriction_changes_loss_on_sparse_teacher():
    from repro.core.topk import densify, topk_sparsify

    full = jax.random.normal(jax.random.PRNGKey(12), (8, 200)) * 4
    sparse_teacher = densify(topk_sparsify(full, 10))
    student = jax.random.normal(jax.random.PRNGKey(13), (8, 200)) * 4
    plain = float(logits_distill_loss(sparse_teacher, student))
    restricted = float(logits_distill_loss(sparse_teacher, student, restrict_to_support=True))
    assert plain != pytest.approx(restricted, rel=1e-3)
    assert restricted >= 0.0


def test_grad_flows_to_student_only():
    t = jax.random.normal(jax.random.PRNGKey(14), (4, 30))
    s = jax.random.normal(jax.random.PRNGKey(15), (4, 30))
    g = jax.grad(lambda ss: kl_divergence(t, ss))(s)
    assert bool(jnp.any(g != 0))
