"""mamba2-130m — pure SSD (state-space duality) stack, attention-free.

[arXiv:2405.21060] Mamba-2: 24 layers, d_model=768, vocab 50280 (GPT-NeoX
tokenizer, padded), state N=128, head_dim P=64, expand=2 (d_inner=1536,
24 SSD heads/layer).  No attention, no separate MLP (the Mamba2 block is the
whole layer).  num_heads/num_kv_heads are nominal (unused by the ssm family).
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=0,
    vocab_size=50_288,  # 50280 padded +8 to divide the 16-way model axis
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=256),
    positional="none",
    norm="rmsnorm",
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    microbatches=4,
    max_seq_len=1_048_576,  # SSMs: O(1) state — long_500k runs natively
    cite="arXiv:2405.21060",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    param_dtype="float32", compute_dtype="float32",
    remat=False,
    name="mamba2-smoke",
    num_layers=2,
    d_model=256,
    vocab_size=512,
    ssm=SSMConfig(state_dim=32, head_dim=32, expand=2, chunk_size=32),
    max_seq_len=256,
)
