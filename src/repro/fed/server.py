"""FL server: sparse-logit aggregation + LLM distillation + broadcast
(Algorithm 1, server block: lines 1-2, 13-16)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.aggregation import AggregationMode, aggregate, aggregate_wire
from repro.core.protocol import downlink_bits
from repro.core.topk import SparseWire, densify
from repro.fed import steps as fed_steps
from repro.fed.client import ClientUpload
from repro.models import init as model_init

__all__ = ["Server"]


class Server:
    def __init__(
        self,
        cfg: ModelConfig,
        *,
        seed: int = 42,
        distill_lr: float = 1e-3,
        temperature: float = 2.0,
        lam: float = 0.03,
        aggregation: AggregationMode = "adaptive",
        distill_steps: int = 2,
        use_kernels: bool = False,
        restrict_to_support: bool = False,
        last_only: bool = True,
        initial_params=None,
    ):
        self.cfg = cfg
        self.aggregation: AggregationMode = aggregation
        self.distill_steps = distill_steps
        self.use_kernels = use_kernels
        self.last_only = last_only
        self.params = initial_params if initial_params is not None else model_init(jax.random.PRNGKey(seed), cfg)
        self.opt = fed_steps.init_lora_opt(self.params, cfg)
        self._distill_step = fed_steps.make_distill_step(
            cfg, lr=distill_lr, temperature=temperature, lam=lam,
            restrict_to_support=restrict_to_support, last_only=last_only,
        )

    # ---- Algorithm 1, line 15: aggregate client knowledge ----
    def aggregate_uploads(self, uploads: list[ClientUpload]):
        """Returns (K_g (P, V), h_g (P, r) or None)."""
        stack = jnp.stack([densify(u.sparse) for u in uploads])  # (N, P, V)
        hs = [u.h for u in uploads if u.h is not None]
        return self.aggregate_dense(stack, jnp.stack(hs) if hs else None)

    def aggregate_dense(
        self,
        stack: jax.Array,
        h_stack: jax.Array | None = None,
        *,
        mask: jax.Array | None = None,
    ):
        """Aggregate an already-densified (N, P, V) stack (+ optional (N, P, r)
        projection stack) — the batched engine's path; only clients that
        actually transmitted may appear in the stack (dropped stragglers are
        excluded, never zero-padded in).  ``mask`` is the optional explicit
        (N, P, V) transmit mask; without it "transmitted" falls back to the
        ``!= 0`` sentinel (which cannot see transmitted true zeros — see
        :mod:`repro.core.aggregation`)."""
        k_g = aggregate(stack, self.aggregation, mask=mask, use_kernel=self.use_kernels)
        h_g = jnp.mean(h_stack, axis=0) if h_stack is not None else None
        return k_g, h_g

    def aggregate_sparse_wire(
        self,
        wire: SparseWire,
        h_stack: jax.Array | None = None,
        *,
        validate: bool = False,
        budget_bits=None,
        value_bits: int = 16,
    ):
        """Aggregate straight from the sparse (values, indices, mask) wire
        format — O(N·P·k_cap) working set, no densified stack (the fused-e2e
        engine runs this same math inside its compiled round; this entry
        point serves callers holding a wire payload outside it).

        ``validate=True`` runs the server-side integrity gate
        (:func:`repro.core.faults.validate_wire`: non-finite values,
        out-of-range indices, and — with ``budget_bits`` — fits-violating
        byte counts) and quarantines offending client rows through the
        transmit-mask pattern before aggregating; their ``h`` rows are
        excluded from the projection mean too."""
        if validate:
            from repro.core.faults import quarantine_wire, validate_wire

            ok, _reasons = validate_wire(
                wire, value_bits=value_bits, budget_bits=budget_bits
            )
            if not bool(np.all(ok)):
                wire = quarantine_wire(wire, ok)
                if h_stack is not None:
                    keep = np.flatnonzero(ok)
                    h_stack = h_stack[jnp.asarray(keep)] if len(keep) else None
        k_g = aggregate_wire(wire, self.aggregation, use_kernel=self.use_kernels)
        h_g = jnp.mean(h_stack, axis=0) if h_stack is not None else None
        return k_g, h_g

    # ---- Algorithm 1, line 16: update the LLM by distilling K_g, h_g ----
    def distill(self, public_tokens, k_g, h_g) -> dict:
        metrics = {}
        for _ in range(self.distill_steps):
            self.params, self.opt, metrics = self._distill_step(
                self.params, self.opt, public_tokens, k_g, h_g
            )
        return {k: float(v) for k, v in metrics.items()}

    # ---- §II-B: broadcast the server's own refreshed knowledge ----
    def broadcast(self, public_tokens) -> tuple[jax.Array, jax.Array | None, int]:
        """Returns (K_down, h_down, downlink_bits).  The paper's workflow:
        after the server-side distillation update, the server re-infers the
        public set and broadcasts its logits + LoRA projection."""
        logits, h = fed_steps.public_logits(
            self.params, self.cfg, public_tokens, last_only=self.last_only
        )
        rank = self.cfg.lora.rank if (self.cfg.lora is not None and h is not None) else None
        bits = downlink_bits(logits.shape[0], logits.shape[-1], rank)
        return logits, h, bits
