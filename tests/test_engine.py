"""Round-engine parity (sequential vs batched vs fused) + straggler/dropout
scenarios.

The keystone of the batched/fused client-execution engines: under the same
seed the engines must agree round-for-round — identical per-client adaptive
k, identical ledger bytes, matching accuracies (sequential↔batched bitwise;
the fused single-jit body is tolerance-compatible, see fed/engine.py).
Tiny configs (no backbone pretraining) keep this in the fast tier.
"""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LoRAConfig
from repro.configs.gpt2_paper import REDUCED_CLIENT, REDUCED_SERVER
from repro.core import ChannelConfig
from repro.core.channel import BatchedChannelState, ChannelState
from repro.core.protocol import PayloadSpec
from repro.core.topk import wire_densify
from repro.data import make_banking77_like
from repro.fed import (
    BatchedEngine,
    FedConfig,
    FusedE2EEngine,
    FusedEngine,
    SequentialEngine,
    run_federated,
)
from repro.fed.client import Client
from repro.fed.server import Server

LORA = LoRAConfig(rank=4, alpha=32.0, dropout=0.0, targets=("q", "v", "head"))
CLIENT = REDUCED_CLIENT.with_overrides(
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
    vocab_size=256, max_seq_len=32, lora=LORA,
)
SERVER = REDUCED_SERVER.with_overrides(
    num_layers=2, d_model=96, num_heads=2, num_kv_heads=2, d_ff=192,
    vocab_size=256, max_seq_len=32, lora=LORA,
)
# Constrained uplink so the adaptive k actually varies per client/round.
CHAN = ChannelConfig(bandwidth_hz=2e5, mean_snr_db=2.0)


def _dataset():
    return make_banking77_like(vocab_size=CLIENT.vocab_size, seq_len=12, total=500, seed=0)


def _cfg(engine, method="adald", channel=CHAN, rounds=2, **kw):
    kw.setdefault("pretrain_steps", 0)
    return FedConfig(
        method=method, engine=engine, num_clients=4, clients_per_round=2,
        rounds=rounds, public_size=64, public_batch=16, eval_size=64,
        local_steps=2, distill_steps=1, server_distill_steps=2,
        seed=0, channel=channel, **kw,
    )


@pytest.mark.parametrize("method", ["adald", "adaptive", "zeropad", "all_logits"])
def test_engine_parity(method):
    """Batched engine == sequential engine under the same seed: per-client k,
    ledger bytes, and accuracies match in every round."""
    ds = _dataset()
    seq = run_federated(CLIENT, SERVER, ds, _cfg("sequential", method))
    bat = run_federated(CLIENT, SERVER, ds, _cfg("batched", method))
    assert seq.per_client_k == bat.per_client_k
    assert seq.mean_k == bat.mean_k
    for rs, rb in zip(seq.ledger.rounds, bat.ledger.rounds):
        assert rs.uplink_bytes == rb.uplink_bytes
        assert rs.downlink_bytes == rb.downlink_bytes
        assert rs.num_transmitters == rb.num_transmitters
    np.testing.assert_allclose(seq.server_acc, bat.server_acc, atol=1e-6)
    np.testing.assert_allclose(seq.client_acc, bat.client_acc, atol=1e-6)


@pytest.mark.parametrize("method", ["adald", "zeropad"])
def test_three_way_engine_parity(method):
    """sequential vs fused vs fused_e2e: identical per-client adaptive k and
    ledger bytes (host-side scalar math is shared); accuracies match to
    float tolerance (the fused engines compile the round — for fused_e2e the
    WHOLE round including aggregation/server distill/broadcast — as one
    program, so op scheduling may differ in the last ulp; the e2e path also
    aggregates from the sparse wire instead of the dense stack)."""
    ds = _dataset()
    runs = {
        e: run_federated(CLIENT, SERVER, ds, _cfg(e, method, rounds=2))
        for e in ("sequential", "batched", "fused", "fused_e2e")
    }
    seq = runs["sequential"]
    for name in ("batched", "fused", "fused_e2e"):
        other = runs[name]
        assert seq.per_client_k == other.per_client_k, name
        for rs, ro in zip(seq.ledger.rounds, other.ledger.rounds):
            assert rs.uplink_bytes == ro.uplink_bytes
            assert rs.downlink_bytes == ro.downlink_bytes
            assert rs.num_transmitters == ro.num_transmitters
        np.testing.assert_allclose(seq.server_acc, other.server_acc, atol=1e-6)
        np.testing.assert_allclose(seq.client_acc, other.client_acc, atol=1e-6)


@pytest.mark.parametrize("engine", ["sequential", "batched", "fused", "fused_e2e"])
def test_single_round_completes(engine):
    """Regression for the old pub_tokens_prev/g_bits forward references: a
    1-round run (no broadcast ever happens) must complete cleanly."""
    run = run_federated(CLIENT, SERVER, _dataset(), _cfg(engine, rounds=1))
    assert len(run.server_acc) == 1
    assert run.ledger.rounds[0].downlink_bytes == 0
    assert run.ledger.rounds[0].uplink_bytes > 0


@pytest.mark.parametrize("engine", ["sequential", "batched", "fused", "fused_e2e"])
def test_straggler_dropout(engine):
    """With min_k=0 + outages, dropped clients transmit zero bytes: each
    round's uplink equals the payload bytes of the k>0 clients only."""
    chan = ChannelConfig(bandwidth_hz=2e5, mean_snr_db=2.0, min_k=0, dropout_prob=0.5)
    run = run_federated(CLIENT, SERVER, _dataset(), _cfg(engine, channel=chan, rounds=3))
    all_ks = [k for ks in run.per_client_k for k in ks]
    assert 0 in all_ks, "expected at least one dropped client at p=0.5 over 6 slots"
    assert any(k > 0 for k in all_ks)
    for ks, stats in zip(run.per_client_k, run.ledger.rounds):
        expected = sum(
            PayloadSpec(num_samples=16, vocab=CLIENT.vocab_size, k=k,
                        lora_rank=LORA.rank).uplink_bytes
            for k in ks if k > 0
        )
        assert stats.uplink_bytes == expected
        assert stats.num_transmitters == sum(1 for k in ks if k > 0)
        assert stats.num_selected == len(ks)


@pytest.mark.parametrize("other", ["batched", "fused", "fused_e2e"])
def test_dropout_parity(other):
    """The engines agree on which clients drop and on everything else."""
    chan = ChannelConfig(bandwidth_hz=2e5, mean_snr_db=2.0, min_k=0, dropout_prob=0.5)
    ds = _dataset()
    seq = run_federated(CLIENT, SERVER, ds, _cfg("sequential", channel=chan, rounds=3))
    oth = run_federated(CLIENT, SERVER, ds, _cfg(other, channel=chan, rounds=3))
    assert seq.per_client_k == oth.per_client_k
    np.testing.assert_allclose(seq.server_acc, oth.server_acc, atol=1e-6)
    np.testing.assert_allclose(seq.client_acc, oth.client_acc, atol=1e-6)


@pytest.mark.parametrize("engine", ["sequential", "batched", "fused", "fused_e2e"])
def test_all_clients_dropped_round(engine):
    """A round where every selected client is in outage must complete: zero
    uplink, zero transmitters, no aggregation/distillation that round.
    Outage (zero capacity) drops the client even at the default min_k=1 —
    the survival floor only applies to links that can transmit at all."""
    chan = ChannelConfig(dropout_prob=1.0)
    run = run_federated(CLIENT, SERVER, _dataset(), _cfg(engine, channel=chan, rounds=2))
    for stats in run.ledger.rounds:
        assert stats.uplink_bytes == 0
        assert stats.num_transmitters == 0
    assert all(np.isfinite(a) for a in run.server_acc)


def _mini_cohort(n=3):
    ds = _dataset()
    clients = [
        Client(i, CLIENT, ds.subset(np.arange(i * 60, (i + 1) * 60)),
               num_classes=ds.num_classes, seed=i, local_steps=1, distill_steps=1)
        for i in range(n)
    ]
    return ds, clients


def test_dropped_client_absent_from_aggregation():
    """Engine-level: a client in outage is excluded from the dense stack fed
    to aggregation (not zero-padded in), so 'zeropad' averages over the
    transmitters only."""
    ds, clients = _mini_cohort(3)
    engine = BatchedEngine(
        clients, CLIENT, num_classes=ds.num_classes,
        local_steps=1, distill_steps=1, k_min=0,
    )
    good = ChannelState(bandwidth_hz=1e6, snr_db=10.0, eta=0.5, deadline_s=1.0)
    out = ChannelState(bandwidth_hz=1e6, snr_db=-float("inf"), eta=0.5, deadline_s=1.0)
    states = BatchedChannelState.from_states([good, out, good])
    pub = jnp.asarray(ds.tokens[:16])
    phase = engine.run_round([0, 1, 2], pub, None, states, adaptive_k=True, send_h=True)
    assert phase.ks[1] == 0 and phase.ks[0] > 0 and phase.ks[2] > 0
    assert phase.dense.shape[0] == 2  # only the two transmitters
    assert phase.h.shape[0] == 2
    assert [p.client_id for p in phase.payloads] == [0, 2]

    server = Server(SERVER, aggregation="zeropad", distill_steps=1)
    k_g, _ = server.aggregate_dense(phase.dense, phase.h)
    np.testing.assert_allclose(
        np.asarray(k_g), np.asarray(jnp.mean(phase.dense, axis=0)), rtol=1e-6
    )


@pytest.mark.parametrize("engine_cls", [BatchedEngine, FusedEngine])
def test_engines_preserve_client_state(engine_cls):
    """After a batched/fused round, each client's params advance exactly as
    the sequential engine's would (the engine is the source of truth; read
    back through client_params)."""
    ds, c_seq = _mini_cohort(2)
    _, c_oth = _mini_cohort(2)
    states = BatchedChannelState.from_states([
        ChannelState(1e6, 10.0, 0.5, 1.0), ChannelState(1e6, 0.0, 0.5, 1.0),
    ])
    pub = jnp.asarray(ds.tokens[:16])
    seq = SequentialEngine(c_seq, CLIENT)
    oth = engine_cls(c_oth, CLIENT, num_classes=ds.num_classes,
                     local_steps=1, distill_steps=1)
    ps = seq.run_round([0, 1], pub, None, states, adaptive_k=True, send_h=True)
    po = oth.run_round([0, 1], pub, None, states, adaptive_k=True, send_h=True)
    assert ps.ks == po.ks
    np.testing.assert_allclose(np.asarray(ps.dense), np.asarray(po.dense), atol=1e-6)
    import jax

    for i in range(2):
        for x, y in zip(jax.tree.leaves(seq.client_params(i)),
                        jax.tree.leaves(oth.client_params(i))):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)


def test_fused_use_kernels_matches_jnp_sparsifier():
    """use_kernels=True routes the fused uplink top-k through the per-row
    budget Pallas bisection kernel (interpret mode on CPU) — same threshold
    semantics, same dense output as the pure-jnp path."""
    ds, c_a = _mini_cohort(2)
    _, c_b = _mini_cohort(2)
    states = BatchedChannelState.from_states([
        ChannelState(1e6, 10.0, 0.5, 1.0), ChannelState(1e6, 0.0, 0.5, 1.0),
    ])
    pub = jnp.asarray(ds.tokens[:16])
    plain = FusedEngine(c_a, CLIENT, num_classes=ds.num_classes,
                        local_steps=1, distill_steps=1)
    kern = FusedEngine(c_b, CLIENT, num_classes=ds.num_classes,
                       local_steps=1, distill_steps=1, use_kernels=True)
    pp = plain.run_round([0, 1], pub, None, states, adaptive_k=True, send_h=True)
    pk = kern.run_round([0, 1], pub, None, states, adaptive_k=True, send_h=True)
    assert pp.ks == pk.ks
    np.testing.assert_allclose(np.asarray(pp.dense), np.asarray(pk.dense), atol=0)


def test_fused_dropped_client_absent_from_aggregation():
    """Fused engine: a k == 0 straggler yields a zeroed dense row inside the
    compiled body, and the host phase excludes it from the dense stack."""
    ds, clients = _mini_cohort(3)
    engine = FusedEngine(
        clients, CLIENT, num_classes=ds.num_classes,
        local_steps=1, distill_steps=1, k_min=0,
    )
    good = ChannelState(bandwidth_hz=1e6, snr_db=10.0, eta=0.5, deadline_s=1.0)
    out = ChannelState(bandwidth_hz=1e6, snr_db=-float("inf"), eta=0.5, deadline_s=1.0)
    states = BatchedChannelState.from_states([good, out, good])
    pub = jnp.asarray(ds.tokens[:16])
    phase = engine.run_round([0, 1, 2], pub, None, states, adaptive_k=True, send_h=True)
    assert phase.ks[1] == 0 and phase.ks[0] > 0 and phase.ks[2] > 0
    assert phase.dense.shape[0] == 2  # only the two transmitters
    assert phase.h.shape[0] == 2
    assert [p.client_id for p in phase.payloads] == [0, 2]


def _shared_cohort(n=3, seed=7):
    """Cohort riding ONE pretrained-like backbone W' (the paper's setting;
    what run_federated produces after pretraining) — required by the e2e
    multi-round scan driver."""
    import jax

    from repro.models import init as model_init

    ds = _dataset()
    backbone = model_init(jax.random.PRNGKey(seed), CLIENT)
    clients = [
        Client(i, CLIENT, ds.subset(np.arange(i * 60, (i + 1) * 60)),
               num_classes=ds.num_classes, seed=i, local_steps=1,
               distill_steps=1, initial_params=backbone)
        for i in range(n)
    ]
    return ds, clients


def _e2e_engine(clients, ds, **kw):
    from repro.fed.server import Server

    server = Server(SERVER, aggregation=kw.pop("aggregation", "adaptive"),
                    distill_steps=2)
    return FusedE2EEngine(
        clients, CLIENT, server=server, num_classes=ds.num_classes,
        local_steps=1, distill_steps=1, server_distill_steps=2, **kw,
    )


def test_fused_e2e_sparse_wire_matches_dense_uplink():
    """The e2e engine's sparse (values, indices, mask) uplink densifies to
    exactly the sequential engine's per-client dense upload (modulo float
    drift of the fused model math); a k == 0 straggler is absent from the
    wire, and each wire row carries exactly k transmitted entries."""
    ds, c_seq = _mini_cohort(3)
    _, c_e2e = _mini_cohort(3)
    good = ChannelState(bandwidth_hz=1e6, snr_db=10.0, eta=0.5, deadline_s=1.0)
    out = ChannelState(bandwidth_hz=1e6, snr_db=-float("inf"), eta=0.5, deadline_s=1.0)
    states = BatchedChannelState.from_states([good, out, good])
    pub = jnp.asarray(ds.tokens[:16])

    seq = SequentialEngine(c_seq, CLIENT, k_min=0)
    e2e = _e2e_engine(c_e2e, ds, k_min=0)
    ps = seq.run_round([0, 1, 2], pub, None, states, adaptive_k=True, send_h=True)
    pe = e2e.run_round([0, 1, 2], pub, None, states, adaptive_k=True, send_h=True)
    assert ps.ks == pe.ks and pe.ks[1] == 0
    assert pe.dense is None  # no densified stack exists on this path
    wire = pe.sparse
    assert wire.values.shape[0] == 2  # transmitters only
    # per-row transmitted-entry counts == the adaptive budgets
    counts = np.asarray(jnp.sum(wire.mask, axis=-1))
    assert set(np.unique(counts[0])) == {pe.ks[0]}
    assert set(np.unique(counts[1])) == {pe.ks[2]}
    np.testing.assert_allclose(
        np.asarray(wire_densify(wire)), np.asarray(ps.dense), atol=1e-5
    )

    # the Server's wire entry point == its dense path fed the densified wire
    server = Server(SERVER, aggregation="adaptive", distill_steps=1)
    k_g_wire, h_g = server.aggregate_sparse_wire(wire, ps.h)
    k_g_dense, h_g_dense = server.aggregate_dense(wire_densify(wire), ps.h)
    np.testing.assert_allclose(
        np.asarray(k_g_wire), np.asarray(k_g_dense), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(np.asarray(h_g), np.asarray(h_g_dense), atol=0)


def test_fused_e2e_run_rounds_matches_per_round():
    """run_rounds(R) — R whole rounds inside ONE lax.scan dispatch — leaves
    the fleet, the server and the broadcast exactly where R single
    run_round calls do, reports identical (ks, payload) accounting, and its
    IN-SCAN eval tap reproduces the per-round host evaluation at 1e-6."""
    import jax

    from repro.core import ChannelConfig as CC, ChannelSimulator
    from repro.fed.steps import make_eval_fn

    ds, c_a = _shared_cohort(4)
    _, c_b = _shared_cohort(4)
    a, b = _e2e_engine(c_a, ds), _e2e_engine(c_b, ds)
    sim = ChannelSimulator(4, CC(bandwidth_hz=2e5, mean_snr_db=2.0), seed=0)
    sels = [[0, 1], [2, 3]]
    pubs = [jnp.asarray(ds.tokens[:16]), jnp.asarray(ds.tokens[16:32])]
    states = [sim.states_batched(r, sels[r]) for r in range(2)]
    # one whole host-eval batch (64), so the host loop and the in-scan tap
    # read exactly the same samples
    ev_tok = jnp.asarray(ds.tokens[300:364])
    ev_lab = jnp.asarray(ds.labels[300:364])
    evaluate_s = make_eval_fn(SERVER, ds.num_classes)
    evaluate_c = make_eval_fn(CLIENT, ds.num_classes)

    # -- per-round reference: run_round + host-side eval after each round --
    want_s, want_c, want_d = [], [], []
    phases, bcast = [], None
    for r in range(2):
        phases.append(a.run_round(
            sels[r], pubs[r], bcast, states[r], adaptive_k=True, send_h=True
        ))
        bcast = a.broadcast_state(pubs[r])
        a.sync_server()
        want_s.append(evaluate_s(a.server.params, ev_tok, ev_lab))
        want_c.append(evaluate_c(a.client_params(sels[r][0]), ev_tok, ev_lab))
        want_d.append(a.last_distill_loss)
    p0, p1 = phases

    traj = b.run_rounds(
        sels, pubs, states, adaptive_k=True, send_h=True,
        eval_tokens=ev_tok, eval_labels=ev_lab,
    )
    b.sync_server()

    assert traj.ks == [p0.ks, p1.ks]
    assert [[p.bytes for p in pl] for pl in traj.payloads] == [
        [p.bytes for p in p0.payloads], [p.bytes for p in p1.payloads]
    ]
    # the in-scan eval tap == the per-round host evaluation
    np.testing.assert_allclose(traj.server_acc, want_s, atol=1e-6)
    np.testing.assert_allclose(traj.client_acc, want_c, atol=1e-6)
    np.testing.assert_allclose(traj.distill_loss, want_d, rtol=1e-4)
    np.testing.assert_allclose(
        traj.mean_k, [np.mean(p0.ks), np.mean(p1.ks)], rtol=1e-6
    )
    for i in range(4):
        for x, y in zip(jax.tree.leaves(a.client_params(i)),
                        jax.tree.leaves(b.client_params(i))):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=2e-5)
    for x, y in zip(jax.tree.leaves(a.server.params),
                    jax.tree.leaves(b.server.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=2e-5)
    np.testing.assert_allclose(
        np.asarray(a._b_logits), np.asarray(b._b_logits), atol=1e-4
    )


def test_e2e_aggregation_path_never_densifies_stack():
    """Trace-inspection acceptance check: at bench-like shapes, no
    intermediate of the sparse aggregation path — sub-jaxprs included —
    reaches the (N, B, V) dense stack's element count; the working set is
    O(N·B·k_cap) + the single (B, V) output.  Same shared inspection
    (max_intermediate_elems) as the BENCH_round.json record, for both the
    pure-jnp scatter and the Pallas kernel route."""
    import jax

    from repro.core.aggregation import aggregate_wire, max_intermediate_elems
    from repro.core.topk import SparseWire

    n, rows, vocab, k_cap = 10, 64, 8192, 256

    def make_agg(use_kernel):
        def agg(values, indices, mask, n_tx):
            wire = SparseWire(values=values, indices=indices, mask=mask, vocab=vocab)
            return aggregate_wire(
                wire, "adaptive", num_transmitters=n_tx, use_kernel=use_kernel
            )
        return agg

    for use_kernel in (False, True):
        jaxpr = jax.make_jaxpr(make_agg(use_kernel))(
            jnp.zeros((n, rows, k_cap)), jnp.zeros((n, rows, k_cap), jnp.int32),
            jnp.zeros((n, rows, k_cap), bool), jnp.int32(n),
        )
        worst = max_intermediate_elems(jaxpr)
        assert worst < n * rows * vocab, use_kernel
        # nothing bigger than the (B, V) output (num/den accumulators)
        assert worst <= rows * vocab, use_kernel


_SHARD_MAP_SCRIPT = textwrap.dedent(
    """
    import jax, numpy as np, jax.numpy as jnp
    assert jax.device_count() == 2, jax.device_count()
    from repro.configs.base import LoRAConfig
    from repro.configs.gpt2_paper import REDUCED_CLIENT
    from repro.core.channel import BatchedChannelState, ChannelState
    from repro.data import make_banking77_like
    from repro.fed.client import Client
    from repro.fed.engine import FusedEngine

    lora = LoRAConfig(rank=4, alpha=32.0, dropout=0.0, targets=("q", "v", "head"))
    cfg = REDUCED_CLIENT.with_overrides(
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
        vocab_size=256, max_seq_len=32, lora=lora,
    )
    ds = make_banking77_like(vocab_size=256, seq_len=12, total=200, seed=0)

    def cohort(n):
        return [Client(i, cfg, ds.subset(np.arange(i * 60, (i + 1) * 60)),
                       num_classes=ds.num_classes, seed=i,
                       local_steps=1, distill_steps=1) for i in range(n)]

    chans = [ChannelState(1e6, 10.0, 0.5, 1.0), ChannelState(1e6, 0.0, 0.5, 1.0),
             ChannelState(1e6, 5.0, 0.5, 1.0)]
    pub = jnp.asarray(ds.tokens[:16])
    # n=2 divides the 2 devices exactly; n=3 exercises the masked padding
    # (the pad row rides at k=0 and is discarded before the scatter-back).
    for n in (2, 3):
        states = BatchedChannelState.from_states(chans[:n])
        sel = list(range(n))
        plain = FusedEngine(cohort(n), cfg, num_classes=ds.num_classes,
                            local_steps=1, distill_steps=1)
        shard = FusedEngine(cohort(n), cfg, num_classes=ds.num_classes,
                            local_steps=1, distill_steps=1, shard_clients=True)
        pp = plain.run_round(sel, pub, None, states, adaptive_k=True, send_h=True)
        ps = shard.run_round(sel, pub, None, states, adaptive_k=True, send_h=True)
        assert pp.ks == ps.ks
        assert ps.dense.shape[0] == pp.dense.shape[0]
        np.testing.assert_allclose(np.asarray(pp.dense), np.asarray(ps.dense), atol=1e-5)
        for i in range(n):
            for a, b in zip(jax.tree.leaves(plain.client_params(i)),
                            jax.tree.leaves(shard.client_params(i))):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
        print(f"SHARD_MAP_OK_{n}")
    """
)


def _run_two_device_subprocess(script: str) -> str:
    """Run a test script under 2 forced host devices (XLA_FLAGS must be set
    before jax initialises, hence the subprocess)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=2"
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


def test_fused_shard_map_two_host_devices():
    """shard_clients=True places the client axis over devices (shard_map) and
    reproduces the single-device fused round — for an even cohort AND an odd
    cohort (client-axis padding)."""
    out = _run_two_device_subprocess(_SHARD_MAP_SCRIPT)
    assert "SHARD_MAP_OK_2" in out
    assert "SHARD_MAP_OK_3" in out


_E2E_SHARD_SCRIPT = textwrap.dedent(
    """
    import jax, numpy as np, jax.numpy as jnp
    assert jax.device_count() == 2, jax.device_count()
    from repro.configs.base import LoRAConfig
    from repro.configs.gpt2_paper import REDUCED_CLIENT, REDUCED_SERVER
    from repro.core import ChannelConfig, ChannelSimulator
    from repro.data import make_banking77_like
    from repro.fed.client import Client
    from repro.fed.engine import FusedE2EEngine
    from repro.fed.server import Server
    from repro.models import init as model_init

    lora = LoRAConfig(rank=4, alpha=32.0, dropout=0.0, targets=("q", "v", "head"))
    ccfg = REDUCED_CLIENT.with_overrides(
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
        vocab_size=256, max_seq_len=32, lora=lora,
    )
    scfg = REDUCED_SERVER.with_overrides(
        num_layers=2, d_model=96, num_heads=2, num_kv_heads=2, d_ff=192,
        vocab_size=256, max_seq_len=32, lora=lora,
    )
    ds = make_banking77_like(vocab_size=256, seq_len=12, total=500, seed=0)
    backbone = model_init(jax.random.PRNGKey(7), ccfg)

    def cohort(n):
        return [Client(i, ccfg, ds.subset(np.arange(i * 60, (i + 1) * 60)),
                       num_classes=ds.num_classes, seed=i, local_steps=1,
                       distill_steps=1, initial_params=backbone)
                for i in range(n)]

    def e2e(cl, shard):
        return FusedE2EEngine(
            cl, ccfg, server=Server(scfg, aggregation="adaptive", distill_steps=2),
            num_classes=ds.num_classes, local_steps=1, distill_steps=1,
            server_distill_steps=2, shard_clients=shard,
        )

    sim = ChannelSimulator(4, ChannelConfig(bandwidth_hz=2e5, mean_snr_db=2.0), seed=0)
    pub = jnp.asarray(ds.tokens[:16])
    # n=2 divides the 2 devices exactly; n=3 exercises the masked k=0 padding
    # INSIDE the whole-round executable.
    for n in (2, 3):
        sel = list(range(n))
        states = sim.states_batched(0, sel)
        plain, shard = e2e(cohort(n), False), e2e(cohort(n), True)
        pp = plain.run_round(sel, pub, None, states, adaptive_k=True, send_h=True)
        ps = shard.run_round(sel, pub, None, states, adaptive_k=True, send_h=True)
        assert pp.ks == ps.ks
        assert [p.bytes for p in pp.payloads] == [p.bytes for p in ps.payloads]
        np.testing.assert_allclose(
            np.asarray(ps.sparse.values), np.asarray(pp.sparse.values), atol=1e-5)
        np.testing.assert_array_equal(
            np.asarray(ps.sparse.mask), np.asarray(pp.sparse.mask))
        np.testing.assert_allclose(
            np.asarray(shard._b_logits), np.asarray(plain._b_logits), atol=1e-4)
        for i in range(n):
            for a, b in zip(jax.tree.leaves(plain.client_params(i)),
                            jax.tree.leaves(shard.client_params(i))):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
        for a, b in zip(jax.tree.leaves(plain._s_lora),
                        jax.tree.leaves(shard._s_lora)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
        print(f"E2E_SHARD_OK_{n}")

    # sharded run_rounds: odd cohorts padded inside the scanned executable,
    # eval tap matching the unsharded block at 1e-6
    sels = [[0, 1, 2], [1, 2, 3]]
    pubs = [jnp.asarray(ds.tokens[:16]), jnp.asarray(ds.tokens[16:32])]
    states = [sim.states_batched(r, sels[r]) for r in range(2)]
    ev_tok, ev_lab = jnp.asarray(ds.tokens[300:364]), jnp.asarray(ds.labels[300:364])
    a, b = e2e(cohort(4), False), e2e(cohort(4), True)
    ta = a.run_rounds(sels, pubs, states, adaptive_k=True, send_h=True,
                      eval_tokens=ev_tok, eval_labels=ev_lab)
    tb = b.run_rounds(sels, pubs, states, adaptive_k=True, send_h=True,
                      eval_tokens=ev_tok, eval_labels=ev_lab)
    assert ta.ks == tb.ks
    np.testing.assert_allclose(ta.server_acc, tb.server_acc, atol=1e-6)
    np.testing.assert_allclose(ta.client_acc, tb.client_acc, atol=1e-6)
    np.testing.assert_allclose(ta.distill_loss, tb.distill_loss, rtol=1e-4)
    print("E2E_SHARD_SCAN_OK")
    """
)


def test_fused_e2e_shard_map_two_host_devices():
    """fused_e2e + shard_clients=True: the client phase shards over 2 host
    devices INSIDE the whole-round executable (server phase replicated) and
    reproduces the unsharded engine — identical k/bytes, float-tolerance
    state — for an even cohort, an odd cohort (masked k=0 padding), and the
    multi-round run_rounds scan with its eval tap."""
    out = _run_two_device_subprocess(_E2E_SHARD_SCRIPT)
    assert "E2E_SHARD_OK_2" in out
    assert "E2E_SHARD_OK_3" in out
    assert "E2E_SHARD_SCAN_OK" in out


def test_same_seed_bit_identical_fedrun():
    """Channel-fix regression: two runs of the same config produce a
    bit-identical FedRun — per-client k, ledger bytes, accuracies.  (Before
    PR 4 this held only by accident of call order: the channel streams
    ignored the constructor seed and drew by cohort position.)"""
    ds = _dataset()
    r1 = run_federated(CLIENT, SERVER, ds, _cfg("batched", rounds=2))
    r2 = run_federated(CLIENT, SERVER, ds, _cfg("batched", rounds=2))
    assert r1.per_client_k == r2.per_client_k
    assert r1.server_acc == r2.server_acc
    assert r1.client_acc == r2.client_acc
    for a, b in zip(r1.ledger.rounds, r2.ledger.rounds):
        assert a.uplink_bytes == b.uplink_bytes
        assert a.downlink_bytes == b.downlink_bytes


def test_adald_payloads_respect_shannon_budget():
    """Budget-fix regression: with min_k=0 (no survival floor), every
    transmitted adald payload — LoRA projection included — fits the Shannon
    budget of the channel state it was computed from."""
    from repro.core import ChannelConfig as CC, ChannelSimulator

    ds, clients = _mini_cohort(3)
    engine = BatchedEngine(
        clients, CLIENT, num_classes=ds.num_classes,
        local_steps=1, distill_steps=1, k_min=0,
    )
    sim = ChannelSimulator(3, CC(bandwidth_hz=2e5, mean_snr_db=0.0, min_k=0), seed=1)
    pub = jnp.asarray(ds.tokens[:16])
    for rnd in range(3):
        states = sim.states_batched(rnd, [0, 1, 2])
        phase = engine.run_round(
            [0, 1, 2], pub, None, states, adaptive_k=True, send_h=True
        )
        for payload in phase.payloads:
            st = states[payload.client_id]
            assert payload.spec.fits(st), (rnd, payload.client_id, payload.spec)


def test_scan_rounds_matches_per_round_fedrun():
    """FedConfig.scan_rounds=True (one lax.scan dispatch for the whole run,
    in-scan eval tap) reproduces the per-round fused_e2e run: identical
    k/bytes, accuracies to float tolerance.  A (tiny) pretraining phase
    gives the fleet one shared backbone W' (the paper's setting)."""
    ds = _dataset()
    kw = dict(rounds=2, pretrain_steps=2, server_pretrain="none")
    loop = run_federated(CLIENT, SERVER, ds, _cfg("fused_e2e", **kw))
    scan = run_federated(
        CLIENT, SERVER, ds, _cfg("fused_e2e", scan_rounds=True, **kw)
    )
    assert loop.per_client_k == scan.per_client_k
    np.testing.assert_allclose(loop.mean_k, scan.mean_k, rtol=1e-6)
    for a, b in zip(loop.ledger.rounds, scan.ledger.rounds):
        assert a.uplink_bytes == b.uplink_bytes
        assert a.downlink_bytes == b.downlink_bytes
        assert a.num_transmitters == b.num_transmitters
    np.testing.assert_allclose(loop.server_acc, scan.server_acc, atol=1e-6)
    np.testing.assert_allclose(loop.client_acc, scan.client_acc, atol=1e-6)
    np.testing.assert_allclose(loop.distill_loss, scan.distill_loss, rtol=1e-4)


def test_scan_rounds_without_shared_backbone():
    """PR-5 guard lift: run_rounds no longer requires one shared frozen W'.
    With pretraining disabled every client carries its OWN random backbone
    (fleet-stacked frozens, frozen_ax=0 in the scanned executable); the
    multi-round scan still reproduces the per-round path exactly."""
    ds = _dataset()
    kw = dict(rounds=2, pretrain_steps=0)
    loop = run_federated(CLIENT, SERVER, ds, _cfg("fused_e2e", **kw))
    scan = run_federated(
        CLIENT, SERVER, ds, _cfg("fused_e2e", scan_rounds=True, **kw)
    )
    assert loop.per_client_k == scan.per_client_k
    for a, b in zip(loop.ledger.rounds, scan.ledger.rounds):
        assert a.uplink_bytes == b.uplink_bytes
        assert a.downlink_bytes == b.downlink_bytes
    np.testing.assert_allclose(loop.server_acc, scan.server_acc, atol=1e-6)
    np.testing.assert_allclose(loop.client_acc, scan.client_acc, atol=1e-6)
    np.testing.assert_allclose(loop.distill_loss, scan.distill_loss, rtol=1e-4)


# ---- PR 6: quantized wire + bf16 round body -------------------------------


def test_fused_e2e_quantized_wire_format_and_pricing():
    """quantize_wire=True swaps the e2e uplink to a QuantizedWire (int8
    values + per-row f32 scale), keeps the adaptive-k bookkeeping, prices
    every payload at 8-bit entries, and densifies to the float wire within
    the per-row quantization step (amax/127)."""
    from repro.core.topk import QUANT_LEVELS, QuantizedWire

    ds, c_q = _mini_cohort(3)
    _, c_f = _mini_cohort(3)
    # generous links: k saturates at vocab for both formats, so the wires
    # carry the SAME support and differ only in value encoding
    good = ChannelState(bandwidth_hz=1e7, snr_db=20.0, eta=0.5, deadline_s=1.0)
    states = BatchedChannelState.from_states([good] * 3)
    pub = jnp.asarray(ds.tokens[:16])

    quant = _e2e_engine(c_q, ds, quantize_wire=True)
    flt = _e2e_engine(c_f, ds)
    pq = quant.run_round([0, 1, 2], pub, None, states, adaptive_k=True, send_h=True)
    pf = flt.run_round([0, 1, 2], pub, None, states, adaptive_k=True, send_h=True)

    assert pq.ks == pf.ks  # identical k bookkeeping at saturated budgets
    assert pq.dense is None
    wire = pq.sparse
    assert isinstance(wire, QuantizedWire)
    assert wire.values.dtype == jnp.int8 and wire.scale.dtype == jnp.float32
    assert not isinstance(pf.sparse, QuantizedWire)

    # payload accounting: 8-bit entries, h kept at its own width, and a
    # strictly cheaper wire than the float run at identical k
    for qp, fp in zip(pq.payloads, pf.payloads):
        assert qp.spec.k == fp.spec.k
        assert qp.spec.value_bits == 8 and qp.spec.h_value_bits == 16
        assert qp.spec.uplink_bits < fp.spec.uplink_bits

    # the dequantized wire sits within one quantization step of the float
    # wire row-by-row (documented loosened tolerance for the int8 path)
    dq = np.asarray(wire_densify(wire))
    df = np.asarray(wire_densify(pf.sparse))
    step = np.max(np.abs(df), axis=-1, keepdims=True) / QUANT_LEVELS
    assert np.all(np.abs(dq - df) <= step + 1e-4)


def test_fused_e2e_quantized_run_matches_float_accuracy_shape():
    """Full fed run with quantize_wire=True: under the tight bench channel
    the 8-bit entry pricing buys a strictly LARGER adaptive k somewhere
    (never smaller anywhere), downlink is unchanged, and the accuracy
    trajectory stays within the loosened quant tolerance of the float
    run."""
    ds = _dataset()
    flt = run_federated(CLIENT, SERVER, ds, _cfg("fused_e2e"))
    qnt = run_federated(CLIENT, SERVER, ds, _cfg("fused_e2e", quantize_wire=True))

    kf = np.asarray(flt.per_client_k, dtype=float)
    kq = np.asarray(qnt.per_client_k, dtype=float)
    assert kq.shape == kf.shape
    assert np.all(kq >= kf), "8-bit pricing must never shrink k"
    assert np.any(kq > kf), "tight channel: cheaper entries must buy more k"
    for a, b in zip(flt.ledger.rounds, qnt.ledger.rounds):
        assert a.downlink_bytes == b.downlink_bytes
        assert a.num_transmitters == b.num_transmitters
    # same eval shape; quantization noise may move the tiny-scale accuracy
    # by a few eval samples, not wholesale
    np.testing.assert_allclose(qnt.server_acc, flt.server_acc, atol=0.15)
    np.testing.assert_allclose(qnt.client_acc, flt.client_acc, atol=0.15)


def test_fused_e2e_bf16_round_body_parity():
    """compute_dtype='bfloat16' (bf16 round body, fp32 master LoRA +
    optimizer state) keeps the k/bytes bookkeeping bit-identical to the
    fp32 run and the accuracies within the loosened bf16 tolerance."""
    ds = _dataset()
    f32 = run_federated(CLIENT, SERVER, ds, _cfg("fused_e2e"))
    bf = run_federated(
        CLIENT, SERVER, ds, _cfg("fused_e2e", compute_dtype="bfloat16")
    )
    # channel bookkeeping is value-independent: bit-identical
    assert f32.per_client_k == bf.per_client_k
    for a, b in zip(f32.ledger.rounds, bf.ledger.rounds):
        assert a.uplink_bytes == b.uplink_bytes
        assert a.downlink_bytes == b.downlink_bytes
    np.testing.assert_allclose(bf.server_acc, f32.server_acc, atol=0.15)
    np.testing.assert_allclose(bf.client_acc, f32.client_acc, atol=0.15)


def test_e2e_dequant_fused_aggregation_never_densifies_stack():
    """The quantized route's acceptance check, mirroring the float one: the
    dequantize-fused aggregation (int8 wire in, (B, V) teacher out) never
    materialises the (N, B, V) dense stack — dequantization lives inside
    the O(N·B·k_cap) working set — for both the pure-jnp scatter and the
    Pallas kernel route."""
    import jax

    from repro.core.aggregation import aggregate_wire, max_intermediate_elems
    from repro.core.topk import QuantizedWire

    n, rows, vocab, k_cap = 10, 64, 8192, 256

    def make_agg(use_kernel):
        def agg(values, scale, indices, mask, n_tx):
            wire = QuantizedWire(
                values=values, scale=scale, indices=indices, mask=mask, vocab=vocab
            )
            return aggregate_wire(
                wire, "adaptive", num_transmitters=n_tx, use_kernel=use_kernel
            )
        return agg

    for use_kernel in (False, True):
        jaxpr = jax.make_jaxpr(make_agg(use_kernel))(
            jnp.zeros((n, rows, k_cap), jnp.int8), jnp.ones((n, rows), jnp.float32),
            jnp.zeros((n, rows, k_cap), jnp.int32),
            jnp.zeros((n, rows, k_cap), bool), jnp.int32(n),
        )
        worst = max_intermediate_elems(jaxpr)
        assert worst < n * rows * vocab, use_kernel
        assert worst <= rows * vocab, use_kernel


# ---- PR 7: correlated-channel scenarios -----------------------------------


def test_four_way_engine_parity_correlated_scenario():
    """sequential/batched/fused/fused_e2e under a gauss_markov correlated
    channel with min_k=0 + memoryless outage (so straggler k=0 rounds
    occur): identical per-client adaptive k and ledger bytes, 1e-6
    accuracies.  The correlated budgets stay host-side scalar math shared
    by every engine, so correlation cannot split the engines."""
    ds = _dataset()
    chan = ChannelConfig(
        bandwidth_hz=2e5, mean_snr_db=2.0, min_k=0, dropout_prob=0.25
    )
    runs = {
        e: run_federated(
            CLIENT, SERVER, ds,
            _cfg(e, channel=chan, rounds=3, scenario="gauss_markov"),
        )
        for e in ("sequential", "batched", "fused", "fused_e2e")
    }
    ref = runs["sequential"]
    # the constrained correlated channel must actually produce stragglers
    assert any(k == 0 for ks in ref.per_client_k for k in ks)
    for name, run in runs.items():
        assert run.per_client_k == ref.per_client_k, name
        for a, b in zip(ref.ledger.rounds, run.ledger.rounds):
            assert a.uplink_bytes == b.uplink_bytes, name
            assert a.downlink_bytes == b.downlink_bytes, name
            assert a.num_transmitters == b.num_transmitters, name
        np.testing.assert_allclose(run.server_acc, ref.server_acc, atol=1e-6)
        np.testing.assert_allclose(run.client_acc, ref.client_acc, atol=1e-6)


def test_scan_rounds_correlated_matches_per_round_fedrun():
    """scan_rounds under a jakes scenario: the one-dispatch scan (channel
    state as carry) reproduces the per-round fused_e2e host loop's k/bytes
    bit-for-bit and accuracies at 1e-6, and only the scan exposes the
    in-scan (snr_db, outage) channel tap."""
    ds = _dataset()
    chan = ChannelConfig(
        bandwidth_hz=2e5, mean_snr_db=2.0, min_k=0, dropout_prob=0.25
    )
    kw = dict(channel=chan, rounds=3, scenario="jakes", pretrain_steps=0)
    loop = run_federated(CLIENT, SERVER, ds, _cfg("fused_e2e", **kw))
    scan = run_federated(
        CLIENT, SERVER, ds, _cfg("fused_e2e", scan_rounds=True, **kw)
    )
    assert loop.per_client_k == scan.per_client_k
    for a, b in zip(loop.ledger.rounds, scan.ledger.rounds):
        assert a.uplink_bytes == b.uplink_bytes
        assert a.downlink_bytes == b.downlink_bytes
        assert a.num_transmitters == b.num_transmitters
    np.testing.assert_allclose(loop.server_acc, scan.server_acc, atol=1e-6)
    np.testing.assert_allclose(loop.client_acc, scan.client_acc, atol=1e-6)
    # the tap is scan-only, shaped (rounds, cohort), outage <-> k == 0 of a
    # client whose budget was killed by -inf SNR
    assert loop.snr_db is None and loop.outage is None
    assert len(scan.snr_db) == 3 and len(scan.outage) == 3
    for ks, out in zip(scan.per_client_k, scan.outage):
        assert len(out) == len(ks)
        for k, o in zip(ks, out):
            if o:
                assert k == 0
