"""Pallas TPU kernel: per-row Top-k threshold selection by vectorized bisection.

The paper's uplink hot-spot is selecting the top-k of a 50k-256k-wide logit
vector per public sample (§III-A).  GPU implementations use radix select
(warp ballots, shared-memory histograms) — no TPU analogue.  The TPU-native
adaptation (DESIGN §2): the row fits VMEM, so we run a **vectorized binary
search on the threshold value**: ~`ITERS` rounds of

    cnt(θ) = Σ_v 1[x_v >= θ]        (one VPU pass over the row tile)

maintaining the invariant cnt(lo) >= k > cnt(hi), then emit
``x * 1[x >= lo]``.  30 iterations narrow [min,max] by 2^30 — below fp32
resolution for logit-scale inputs — so the threshold converges to the k-th
value and the kept count is exactly k for distinct entries (ties are all
kept, see ref).

Block layout: grid over row blocks; each step owns (ROWS_BLK, V) in VMEM —
V up to 256k fp32 = 1 MB/row, ROWS_BLK sized to keep in+out under ~8 MB.
The vocab axis is NOT tiled: bisection needs whole-row counts each
iteration, and a row always fits; this trades grid parallelism for zero
cross-tile reduction traffic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["topk_mask_pallas", "topk_mask_dynamic_pallas", "rows_block_for"]

# Single source of truth shared with the pure-jnp topk_mask_dynamic: the two
# bisections must converge identically (exact-parity contract).
from repro.core.topk import BISECTION_ITERS as ITERS  # noqa: E402


def rows_block_for(vocab: int, dtype=jnp.float32) -> int:
    """Rows per block so in+out tiles stay within ~8 MB of VMEM."""
    bytes_per_row = 2 * vocab * jnp.dtype(dtype).itemsize  # in + out
    budget = 8 * 1024 * 1024
    return max(1, min(8, budget // max(1, bytes_per_row)))


def _topk_kernel(x_ref, out_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)  # (R_b, V)
    lo = jnp.min(x, axis=-1)  # cnt(lo) = V >= k
    hi = jnp.max(x, axis=-1) + 1.0  # cnt(hi) = 0 < k (strictly above max)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((x >= mid[:, None]).astype(jnp.int32), axis=-1)
        take = cnt >= k  # mid keeps enough -> move lo up
        new_lo = jnp.where(take, mid, lo)
        new_hi = jnp.where(take, hi, mid)
        return new_lo, new_hi

    lo, hi = jax.lax.fori_loop(0, ITERS, body, (lo, hi))
    keep = x >= lo[:, None]
    out_ref[...] = jnp.where(keep, x_ref[...], jnp.zeros_like(x_ref[...]))


def _topk_dynamic_kernel(x_ref, k_ref, out_ref):
    """Per-row budget variant: k arrives as DATA (int32 per row), so one
    compiled program serves every round of adaptive budgets — the fused
    round engine's requirement (a static k would recompile per round)."""
    x = x_ref[...].astype(jnp.float32)  # (R_b, V)
    k = k_ref[...]  # (R_b,) int32, pre-clamped to [0, V]
    lo = jnp.min(x, axis=-1)
    hi = jnp.max(x, axis=-1) + 1.0

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((x >= mid[:, None]).astype(jnp.int32), axis=-1)
        take = cnt >= k
        return jnp.where(take, mid, lo), jnp.where(take, hi, mid)

    # For k == 0 the loop drives lo toward max+1 -> nothing kept, which is
    # exactly the dropped-straggler contract; the explicit k > 0 guard below
    # makes it robust to the last-ulp of the bisection regardless.
    lo, hi = jax.lax.fori_loop(0, ITERS, body, (lo, hi))
    keep = (x >= lo[:, None]) & (k > 0)[:, None]
    out_ref[...] = jnp.where(keep, x_ref[...], jnp.zeros_like(x_ref[...]))


@functools.partial(jax.jit, static_argnames=("interpret",))
def topk_mask_dynamic_pallas(
    logits: jax.Array, ks: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """Dense top-k mask of (rows, vocab) with a per-row int32 budget ``ks``
    (threshold semantics; ``ks[i] == 0`` zeroes row i entirely)."""
    assert logits.ndim == 2 and ks.ndim == 1, "fold batch dims before calling"
    rows, vocab = logits.shape
    ks = jnp.clip(ks.astype(jnp.int32), 0, vocab)
    rb = rows_block_for(vocab, logits.dtype)
    pad = (-rows) % rb
    x = jnp.pad(logits, ((0, pad), (0, 0))) if pad else logits
    kp = jnp.pad(ks, (0, pad)) if pad else ks
    grid = (x.shape[0] // rb,)

    out = pl.pallas_call(
        _topk_dynamic_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, vocab), lambda r: (r, 0)),
            pl.BlockSpec((rb,), lambda r: (r,)),
        ],
        out_specs=pl.BlockSpec((rb, vocab), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, logits.dtype),
        interpret=interpret,
    )(x, kp)
    return out[:rows] if pad else out


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_mask_pallas(logits: jax.Array, k: int, *, interpret: bool = False) -> jax.Array:
    """Dense top-k mask of a (rows, vocab) array (threshold semantics)."""
    assert logits.ndim == 2, "fold batch dims before calling"
    rows, vocab = logits.shape
    rb = rows_block_for(vocab, logits.dtype)
    # pad rows to a multiple of the block
    pad = (-rows) % rb
    x = jnp.pad(logits, ((0, pad), (0, 0))) if pad else logits
    grid = (x.shape[0] // rb,)

    out = pl.pallas_call(
        functools.partial(_topk_kernel, k=int(min(k, vocab))),
        grid=grid,
        in_specs=[pl.BlockSpec((rb, vocab), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((rb, vocab), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, logits.dtype),
        interpret=interpret,
    )(x)
    return out[:rows] if pad else out
