"""Model zoo: dense/GQA, MoE, Mamba2-SSD, hybrid, VLM and audio enc-dec
stacks, all as pure-pytree functional JAX models (see model.py for the API).
"""

from repro.models import model
from repro.models.model import Aux, backbone, decode_step, forward, init, init_cache, prefill

__all__ = ["model", "Aux", "backbone", "decode_step", "forward", "init", "init_cache", "prefill"]
