"""command-r-35b — dense GQA decoder, no biases, 256k vocab.

[hf:CohereForAI/c4ai-command-r-v01] 40 layers, d_model=8192, 64 q heads /
8 kv heads, d_ff=22528, vocab 256000, LayerNorm, no biases anywhere.
The 256k vocab makes this the paper-technique stress case: one sample's
logit vector is 512 KB — exactly the uplink the adaptive Top-k targets.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22_528,
    vocab_size=256_000,
    norm="layernorm",
    use_bias=False,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    microbatches=16,
    max_seq_len=131_072,
    cite="hf:CohereForAI/c4ai-command-r-v01",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    name="command-r-smoke", num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
    d_ff=512, vocab_size=512,
    param_dtype="float32", compute_dtype="float32", remat=False, max_seq_len=256,
)
