from repro.fed.client import Client, ClientUpload
from repro.fed.engine import (
    BatchedEngine,
    BroadcastState,
    ClientPhase,
    FusedE2EEngine,
    FusedEngine,
    RoundsTrajectory,
    SequentialEngine,
    make_engine,
)
from repro.fed.rounds import METHODS, FedConfig, FedRun, run_federated
from repro.fed.server import Server

__all__ = [
    "Client",
    "ClientUpload",
    "Server",
    "METHODS",
    "FedConfig",
    "FedRun",
    "run_federated",
    "BatchedEngine",
    "FusedEngine",
    "FusedE2EEngine",
    "SequentialEngine",
    "BroadcastState",
    "ClientPhase",
    "RoundsTrajectory",
    "make_engine",
]
