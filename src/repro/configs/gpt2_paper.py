"""The paper's own model pair (§IV): GPT-2 small clients / GPT-2 large server.

[Radford et al. 2019]  GPT-2 small: 12L, d=768, 12H, d_ff=3072; GPT-2
large: 36L, d=1280, 20H, d_ff=5120; vocab 50257, learned positions, GELU,
LayerNorm with biases, tied embeddings.  LoRA (r=8, α=32, dropout 0.1 —
paper Table I) on q/v projections.

REDUCED_* are width/depth-scaled same-family variants with a compact vocab,
used by the runnable end-to-end FL examples and Fig. 2/3 benchmarks on CPU
(DESIGN §1: the exact GPT-2 checkpoints are a data gate; the mechanisms and
method ordering are what we reproduce).
"""

from repro.configs.base import LoRAConfig, ModelConfig

_COMMON = dict(
    family="dense",
    positional="learned",
    norm="layernorm",
    activation="gelu",
    use_bias=True,
    tie_embeddings=True,
    max_seq_len=1024,
    cite="Radford et al. 2019 (GPT-2)",
)

GPT2_SMALL = ModelConfig(
    name="gpt2-small",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=50_257,
    lora=LoRAConfig(rank=8, alpha=32.0, dropout=0.1),
    **_COMMON,
)

GPT2_LARGE = ModelConfig(
    name="gpt2-large",
    num_layers=36,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=50_257,
    lora=LoRAConfig(rank=8, alpha=32.0, dropout=0.1),
    **_COMMON,
)

# CPU-runnable same-family pair for the end-to-end FL experiments.  The
# reduced backbones are shallow and trained from scratch (DESIGN §1), so the
# adapters carry more of the task than they would on real GPT-2: rank 16 on
# q/v/o + the LM head (all standard PEFT targets).  The full-size GPT2_*
# configs above keep the paper's exact r=8 q/v setting.
REDUCED_LORA = LoRAConfig(rank=16, alpha=32.0, dropout=0.1, targets=("q", "v", "o", "head"))
REDUCED_CLIENT = GPT2_SMALL.with_overrides(
    name="gpt2-reduced-client", num_layers=4, d_model=256, num_heads=4,
    num_kv_heads=4, d_ff=1024, vocab_size=1024, max_seq_len=128,
    lora=REDUCED_LORA,
)
REDUCED_SERVER = GPT2_LARGE.with_overrides(
    name="gpt2-reduced-server", num_layers=6, d_model=384, num_heads=6,
    num_kv_heads=6, d_ff=1536, vocab_size=1024, max_seq_len=128,
    lora=REDUCED_LORA,
)

CONFIG = GPT2_LARGE  # registry entry: the paper's server model
SMOKE_CONFIG = REDUCED_CLIENT.with_overrides(name="gpt2-smoke", num_layers=2)
