"""internvl2-76b — VLM: InternViT frontend (STUB) + LLM decoder backbone.

[arXiv:2404.16821] Language backbone (Llama-3-70B-derived): 80 layers,
d_model=8192, 64 q heads / 8 kv heads, d_ff=28672, vocab 128256.  The
InternViT-6B vision encoder + MLP projector are stubbed per the assignment:
``input_specs()`` supplies 256 pre-projected patch embeddings (pixel-shuffle
output length for one 448² tile) which the decoder consumes before the text
stream.  bf16 + remat for HBM fit.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28_672,
    vocab_size=128_256,
    frontend="vision",
    frontend_len=256,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optimizer_state_dtype="bfloat16",
    remat=True,
    microbatches=16,
    max_seq_len=32_768,
    cite="arXiv:2404.16821",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    name="internvl2-smoke", num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512, frontend_len=16,
    param_dtype="float32", compute_dtype="float32", optimizer_state_dtype="float32",
    remat=False, max_seq_len=256,
)
