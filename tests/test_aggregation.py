"""Adaptive aggregation (paper eqs. 6-7) vs baselines, dense and sparse-wire."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import (
    aggregate,
    aggregate_adaptive,
    aggregate_mean_nonzero,
    aggregate_sparse,
    aggregate_wire,
    aggregate_zeropad,
)
from repro.core.topk import (
    SparseWire,
    sparsify_wire,
    topk_mask_batch,
    topk_sparsify,
    wire_densify,
    wire_support,
)


def _sparse_stack(key, n=5, rows=4, vocab=64, keep=0.2):
    x = jax.random.normal(key, (n, rows, vocab))
    mask = jax.random.uniform(jax.random.fold_in(key, 1), x.shape) < keep
    return jnp.where(mask, x, 0.0)


def test_single_client_identity():
    """With one client, adaptive aggregation returns its logits unchanged."""
    stack = _sparse_stack(jax.random.PRNGKey(0), n=1)
    np.testing.assert_allclose(aggregate_adaptive(stack), stack[0], rtol=1e-5, atol=1e-7)


def test_untouched_dims_stay_zero():
    stack = _sparse_stack(jax.random.PRNGKey(1))
    out = aggregate_adaptive(stack)
    untouched = jnp.all(stack == 0, axis=0)
    assert bool(jnp.all(jnp.where(untouched, out == 0, True)))


def test_adaptive_in_convex_hull():
    """Per dimension, the adaptive aggregate lies within [min, max] of the
    transmitting clients' values (weights are a convex combination)."""
    stack = _sparse_stack(jax.random.PRNGKey(2), n=6)
    out = aggregate_adaptive(stack)
    transmitted = stack != 0
    big = jnp.where(transmitted, stack, jnp.inf).min(axis=0)
    small = jnp.where(transmitted, stack, -jnp.inf).max(axis=0)
    touched = transmitted.any(axis=0)
    assert bool(jnp.all(jnp.where(touched, (out >= big - 1e-5) & (out <= small + 1e-5), True)))


def test_zeropad_shrinks_vs_adaptive():
    """Zero-padding dilutes: |zeropad| <= |adaptive| on touched dims where a
    single client transmitted (the paper's sparsity-bias argument)."""
    stack = _sparse_stack(jax.random.PRNGKey(3), n=8, keep=0.1)
    single = (stack != 0).sum(axis=0) == 1
    zp = jnp.abs(aggregate_zeropad(stack))
    ad = jnp.abs(aggregate_adaptive(stack))
    assert bool(jnp.all(jnp.where(single, zp <= ad + 1e-6, True)))


def test_mean_nonzero_between():
    stack = _sparse_stack(jax.random.PRNGKey(4))
    mn = aggregate_mean_nonzero(stack)
    # all-positive values: adaptive >= mean_nonzero (confidence upweights)
    stack_pos = jnp.abs(stack)
    ad = aggregate_adaptive(stack_pos)
    mn = aggregate_mean_nonzero(stack_pos)
    assert bool(jnp.all(ad >= mn - 1e-5))


def test_sparse_equals_dense_aggregation():
    key = jax.random.PRNGKey(5)
    full = jax.random.normal(key, (4, 6, 50)) + 3.0
    sparse = topk_sparsify(full, 8)
    from repro.core.topk import densify

    stack = densify(sparse)  # (4, 6, 50): leading axis = clients
    for mode in ("adaptive", "zeropad", "mean_nonzero"):
        dense_out = aggregate(stack, mode)
        sparse_out = aggregate_sparse(sparse.values, sparse.indices, 50, mode)
        np.testing.assert_allclose(dense_out, sparse_out, rtol=1e-4, atol=1e-6)


def test_unknown_mode_raises():
    with pytest.raises(ValueError):
        aggregate(jnp.zeros((2, 3, 4)), "bogus")  # type: ignore[arg-type]
    with pytest.raises(ValueError):
        aggregate_wire(
            SparseWire(jnp.zeros((1, 1, 2)), jnp.zeros((1, 1, 2), jnp.int32),
                       jnp.ones((1, 1, 2), bool), 4),
            "bogus",  # type: ignore[arg-type]
        )


# ---- explicit transmit mask vs the `!= 0` sentinel (PR-3 satellite) --------


def test_true_zero_transmitted_logit_counts_with_explicit_mask():
    """REGRESSION: a transmitted logit that is exactly 0.0 was silently
    treated as untransmitted by the `stack != 0` sentinel — it fell out of
    the mean_nonzero denominator.  With the explicit transmit mask it counts
    (it was on the air): mean over {0.0, 3.0} is 1.5, not 3.0."""
    stack = jnp.asarray([[[0.0, 1.0]], [[3.0, 0.0]]])  # (N=2, B=1, V=2)
    # client 0 transmitted BOTH dims (dim 0 with value exactly 0.0);
    # client 1 transmitted dim 0 only.
    mask = jnp.asarray([[[True, True]], [[True, False]]])

    legacy = aggregate_mean_nonzero(stack)  # sentinel path
    np.testing.assert_allclose(np.asarray(legacy[0]), [3.0, 1.0], rtol=1e-6)

    fixed = aggregate_mean_nonzero(stack, mask=mask)
    np.testing.assert_allclose(np.asarray(fixed[0]), [1.5, 1.0], rtol=1e-6)

    # the sparse wire carries the mask natively -> same fixed result
    wire = SparseWire(
        values=jnp.asarray([[[0.0, 1.0]], [[3.0, 0.0]]]),
        indices=jnp.asarray([[[0, 1]], [[0, 1]]], jnp.int32),
        mask=mask,
        vocab=2,
    )
    np.testing.assert_allclose(
        np.asarray(aggregate_wire(wire, "mean_nonzero")[0]), [1.5, 1.0], rtol=1e-6
    )
    # adaptive/zeropad values are insensitive to the zero (|0| confidence /
    # zero summand) but must accept the mask without changing results
    for mode in ("adaptive", "zeropad"):
        np.testing.assert_allclose(
            np.asarray(aggregate(stack, mode, mask=mask)),
            np.asarray(aggregate(stack, mode)),
            rtol=1e-6,
        )


def test_true_zero_logit_round_trips_through_wire():
    """End-to-end through the wire format: a selected logit that is exactly
    0.0 stays masked-IN (sparsify_wire masks by RANK, not by value), so it
    drags the mean_nonzero average down exactly as an on-air zero should —
    the densified sentinel path would have averaged without it."""
    # client 0's top-2: values {5, 0} at dims {0, 2};
    # client 1's top-2: values {4, 1} at dims {2, 0}
    logits = jnp.asarray(
        [[[5.0, -1.0, 0.0, -2.0]], [[1.0, -3.0, 4.0, -1.0]]]
    )  # (2, 1, 4)
    wire = sparsify_wire(logits, jnp.asarray([2, 2], jnp.int32), 2)
    assert bool(jnp.all(wire.mask))  # all four entries transmitted
    sup = np.asarray(wire_support(wire))
    assert sup[0, 0, 2]  # the true-zero entry IS support
    out = aggregate_wire(wire, "mean_nonzero")
    # dim 2: client 0 sent 0.0 (counts!), client 1 sent 4.0 -> mean = 2.0;
    # the sentinel path would report 4.0 (zero invisible in the dense stack)
    np.testing.assert_allclose(np.asarray(out[0]), [3.0, 0.0, 2.0, 0.0], atol=1e-6)
    legacy = aggregate_mean_nonzero(wire_densify(wire))
    np.testing.assert_allclose(np.asarray(legacy[0]), [3.0, 0.0, 4.0, 0.0], atol=1e-6)


def test_wire_matches_dense_oracle_all_modes():
    """sparsify_wire -> aggregate_wire == topk_mask_batch -> masked dense
    aggregate, for mixed budgets including a k = 0 straggler."""
    key = jax.random.PRNGKey(5)
    logits = jax.random.normal(key, (4, 3, 50))
    ks = [8, 0, 50, 1]
    wire = sparsify_wire(logits, jnp.asarray(ks, jnp.int32), 50)
    np.testing.assert_allclose(
        np.asarray(wire_densify(wire)), np.asarray(topk_mask_batch(logits, ks)), atol=0
    )
    dense, sup = wire_densify(wire), wire_support(wire)
    active = jnp.asarray([0, 2, 3])
    for mode in ("adaptive", "zeropad", "mean_nonzero"):
        got = aggregate_wire(wire, mode)
        want = aggregate(dense[active], mode, mask=sup[active])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)
        gotk = aggregate_wire(wire, mode, use_kernel=True)
        np.testing.assert_allclose(np.asarray(gotk), np.asarray(got), rtol=1e-5, atol=1e-6)
