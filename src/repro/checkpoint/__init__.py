from repro.checkpoint.ckpt import (
    latest_step,
    restore,
    restore_step,
    save,
    save_step,
    step_metadata,
)

__all__ = [
    "latest_step",
    "restore",
    "restore_step",
    "save",
    "save_step",
    "step_metadata",
]
