"""Client-phase execution engines for the federated round loop.

The paper's Algorithm 1 runs the selected cohort's client work (local
distillation, local fine-tuning, public-set inference + adaptive Top-k
upload) independently per client — embarrassingly parallel across the
cohort.  Two interchangeable engines execute that phase:

* :class:`SequentialEngine` — the reference implementation: a Python loop
  over clients, one jitted step per client (the seed repo's behaviour).
* :class:`BatchedEngine` — keeps the fleet's LoRA/optimizer state stacked
  along a leading client axis and runs every phase as a single
  ``jax.vmap``-ed, ``jax.jit``-compiled, donated-buffer step: host
  dispatches per round drop from O(C·steps) to O(steps), and the client
  axis is the handle accelerator backends parallelise over (vmap →
  pmap/shard_map), which is what stops wall-clock scaling linearly with
  ``clients_per_round`` at the paper's cohort sizes.
* :class:`FusedEngine` — collapses the batched engine's per-phase calls
  into ONE donated, jitted round body (distill → fine-tune → public
  last-position inference → adaptive Top-k with the budget as data): host
  dispatches per round drop to O(1), and the client axis can optionally be
  placed over devices with ``jax.experimental.shard_map``
  (``shard_clients=True``; testable on CPU via
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).

All engines are driven by :func:`repro.fed.rounds.run_federated`.
Sequential and batched are bit-compatible under the same seed; the fused
engine is tolerance-compatible: identical per-client adaptive ``k`` and
ledger bytes (the budget math is the same host-side scalar code), while
accuracies/logits may drift by float round-off because XLA fuses the whole
round into one program (different op scheduling) and the uplink
sparsifier uses threshold semantics (exact ties at the k-th value are all
kept — measure-zero for real logits).  Batches are drawn through the same
per-client RNG streams in every engine.

Straggler semantics (all engines): a client whose channel state yields
``k == 0`` transmits nothing — it contributes zero uplink bytes and is
excluded from the aggregation stack entirely rather than zero-padded in.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.channel import BatchedChannelState, ChannelState, topk_budget_batch
from repro.core.protocol import UplinkPayload
from repro.core.topk import densify, topk_mask_batch
from repro.fed import steps as fed_steps
from repro.fed.client import Client, make_upload_payload
from repro.lora import merge_lora, split_lora

__all__ = [
    "BroadcastState",
    "ClientPhase",
    "SequentialEngine",
    "BatchedEngine",
    "FusedEngine",
    "make_engine",
    "tree_stack",
]


def tree_stack(trees: Sequence) -> object:
    """Stack a list of identically-structured pytrees along a new leading
    (client) axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def shared_frozen_backbone(frozens: Sequence) -> bool:
    """True iff every client's frozen tree is literally the same arrays —
    the paper's setting (one pretrained W' under per-client LoRA deltas).
    Identity, not value comparison: O(leaves), no device work."""
    first = jax.tree.leaves(frozens[0])
    for other in frozens[1:]:
        leaves = jax.tree.leaves(other)
        if len(leaves) != len(first) or any(a is not b for a, b in zip(first, leaves)):
            return False
    return True


@dataclasses.dataclass(frozen=True)
class BroadcastState:
    """The server's knowledge broadcast carried across rounds (Fig. 1 step 1).

    Replaces the fragile ``pub_tokens_prev`` / ``g_bits`` forward references:
    the public tokens the knowledge was computed on travel *with* the logits
    they explain, and the downlink cost is accounted from the same object.
    """

    tokens: jax.Array  # (P, L) public batch the knowledge was inferred on
    logits: jax.Array  # (P, V) global logits K_g
    h: jax.Array | None  # (P, r) global LoRA projection h_g
    bits: int  # on-air size of one broadcast to one client


@dataclasses.dataclass
class ClientPhase:
    """Result of one round's client phase, engine-agnostic.

    ``dense``/``h`` hold only the ``num_transmitters`` clients that actually
    uploaded (leading axis), in cohort order; ``ks`` covers every *selected*
    client (0 marks a dropped straggler).
    """

    dense: jax.Array | None  # (T, P, V) densified top-k logits
    h: jax.Array | None  # (T, P, r) LoRA projections
    payloads: list[UplinkPayload]
    ks: list[int]

    @property
    def uplink_bytes(self) -> float:
        return float(sum(p.bytes for p in self.payloads))

    @property
    def num_transmitters(self) -> int:
        return len(self.payloads)


class SequentialEngine:
    """Reference client-phase executor: one client at a time (Algorithm 1
    exactly as written)."""

    name = "sequential"

    def __init__(
        self,
        clients: list[Client],
        cfg: ModelConfig,
        *,
        value_bits: int = 16,
        k_min: int = 1,
        **_unused,
    ):
        self.clients = clients
        self.cfg = cfg
        self.value_bits = value_bits
        self.k_min = k_min

    def client_params(self, cid: int):
        """Current parameters of one client (for evaluation)."""
        return self.clients[cid].params

    def run_round(
        self,
        sel: Sequence[int],
        pub_tokens: jax.Array,
        bcast: BroadcastState | None,
        states: BatchedChannelState | Sequence[ChannelState],
        *,
        adaptive_k: bool,
        send_h: bool,
    ) -> ClientPhase:
        cohort = [self.clients[i] for i in sel]
        if bcast is not None:
            for c in cohort:
                c.local_distill(bcast.tokens, bcast.logits, bcast.h)
        dense_rows, hs, payloads, ks = [], [], [], []
        for c, st in zip(cohort, states):
            c.local_train()
            up = c.upload(
                pub_tokens,
                st,
                value_bits=self.value_bits,
                k_override=None if adaptive_k else self.cfg.vocab_size,
                send_h=send_h,
                k_min=self.k_min,
            )
            if up is None:  # straggler in outage: transmits nothing
                ks.append(0)
                continue
            ks.append(up.k)
            dense_rows.append(densify(up.sparse))
            if up.h is not None:
                hs.append(up.h)
            payloads.append(up.payload)
        return ClientPhase(
            dense=jnp.stack(dense_rows) if dense_rows else None,
            h=jnp.stack(hs) if hs else None,
            payloads=payloads,
            ks=ks,
        )


class BatchedEngine:
    """Batched client-phase executor: the whole cohort advances through each
    phase as one compiled step over a leading client axis.

    The fleet's trainable state lives STACKED on this engine: at
    construction every client's LoRA tree and optimizer state are stacked
    along a leading ``(num_clients, ...)`` axis (the frozen backbone is kept
    as one shared tree when all clients ride the same pretrained W' — the
    paper's setting — or stacked otherwise).  A round then gathers the
    selected cohort's rows with ONE gather per leaf, runs the vmapped
    phases, and scatters the advanced rows back — no per-client
    stack/unstack/merge churn on the hot path.  The engine is the source of
    truth for client parameters while it is in use; read them back through
    :meth:`client_params`.
    """

    name = "batched"

    def __init__(
        self,
        clients: list[Client],
        cfg: ModelConfig,
        *,
        num_classes: int,
        lr: float = 1e-3,
        distill_lr: float = 1e-3,
        temperature: float = 2.0,
        lam: float = 0.03,
        local_steps: int = 4,
        distill_steps: int = 2,
        restrict_to_support: bool = False,
        value_bits: int = 16,
        k_min: int = 1,
        last_only: bool = True,
    ):
        self.clients = clients
        self.cfg = cfg
        self.local_steps = local_steps
        self.distill_steps = distill_steps
        self.value_bits = value_bits
        self.k_min = k_min
        self.last_only = last_only

        loras, frozens = zip(*(split_lora(c.params) for c in clients))
        self._shared = shared_frozen_backbone(frozens)
        self._lora = tree_stack(loras)  # (N, ...)
        self._frozen = frozens[0] if self._shared else tree_stack(frozens)
        self._opt = tree_stack([c.opt for c in clients])
        self._train = fed_steps.make_batched_finetune_step(
            cfg, num_classes, lr=lr, shared_backbone=self._shared, last_only=last_only
        )
        self._distill = fed_steps.make_batched_distill_step(
            cfg, lr=distill_lr, temperature=temperature, lam=lam,
            restrict_to_support=restrict_to_support, shared_backbone=self._shared,
            last_only=last_only,
        )
        self._public = fed_steps.make_batched_public_logits(
            cfg, shared_backbone=self._shared, last_only=last_only
        )

    def client_params(self, cid: int):
        """Materialise one client's merged params (for evaluation)."""
        lora_i = jax.tree.map(lambda x: x[cid], self._lora)
        frozen_i = (
            self._frozen if self._shared
            else jax.tree.map(lambda x: x[cid], self._frozen)
        )
        return merge_lora(lora_i, frozen_i)

    # -- round plumbing shared by the batched and fused engines ----------
    def _gather_cohort(self, sel: Sequence[int]):
        """One gather per leaf: the selected cohort's (lora, frozen, opt)."""
        idx = jnp.asarray(list(sel))
        lora = jax.tree.map(lambda x: x[idx], self._lora)
        opt = jax.tree.map(lambda x: x[idx], self._opt)
        frozen = (
            self._frozen if self._shared
            else jax.tree.map(lambda x: x[idx], self._frozen)
        )
        return idx, lora, frozen, opt

    def _scatter_cohort(self, idx, lora, opt) -> None:
        """Write the advanced cohort rows back into the fleet state."""
        self._lora = jax.tree.map(
            lambda full, new: full.at[idx].set(new), self._lora, lora
        )
        self._opt = jax.tree.map(
            lambda full, new: full.at[idx].set(new), self._opt, opt
        )

    def _budgets(self, states, n_samples: int, adaptive_k: bool, n_cohort: int):
        """Per-client adaptive k — the same host-side scalar math as the
        sequential reference, so k (and bytes) can never drift."""
        if not adaptive_k:
            return [self.cfg.vocab_size] * n_cohort
        return topk_budget_batch(
            states, vocab_size=self.cfg.vocab_size, num_samples=n_samples,
            value_bits=self.value_bits, k_min=self.k_min,
        )

    def _upload_manifests(self, cohort, states, ks, n_samples: int, send_h: bool):
        """(active indices, payload manifests, lora rank) for the k > 0
        transmitters — dropped stragglers contribute nothing."""
        active = [i for i, k in enumerate(ks) if k > 0]
        payloads: list[UplinkPayload] = []
        rank = None
        for i in active:
            payload, rank = make_upload_payload(
                self.cfg, cohort[i].client_id, n_samples, ks[i],
                send_h=send_h, value_bits=self.value_bits,
                snr_db=states[i].snr_db,
            )
            payloads.append(payload)
        return active, payloads, rank

    def _stacked_batches(self, cohort, *, step_major: bool):
        """Each client's next ``local_steps`` private batches, drawn through
        its OWN rng stream (identical to the sequential path).  Returns a
        list of step-major dicts (one per step) or one client-major dict
        with a (C, S, ...) leading layout."""
        per_client = [c.next_train_batches(self.local_steps) for c in cohort]
        keys = per_client[0][0].keys()
        if step_major:
            return [
                {key: jnp.asarray(np.stack([b[s][key] for b in per_client]))
                 for key in keys}
                for s in range(self.local_steps)
            ]
        return {
            key: jnp.asarray(
                np.stack([np.stack([b[s][key] for s in range(self.local_steps)])
                          for b in per_client])
            )
            for key in keys
        }

    def run_round(
        self,
        sel: Sequence[int],
        pub_tokens: jax.Array,
        bcast: BroadcastState | None,
        states: BatchedChannelState | Sequence[ChannelState],
        *,
        adaptive_k: bool,
        send_h: bool,
    ) -> ClientPhase:
        cohort = [self.clients[i] for i in sel]
        states = list(states)
        idx, lora, frozen, opt = self._gather_cohort(sel)

        # -- lines 5-7: cohort distillation against the shared broadcast --
        if bcast is not None:
            for _ in range(self.distill_steps):
                lora, opt, _ = self._distill(
                    lora, frozen, opt, bcast.tokens, bcast.logits, bcast.h
                )

        # -- line 8: local fine-tuning, one vmapped update per step --
        for jb in self._stacked_batches(cohort, step_major=True):
            lora, opt, _ = self._train(lora, frozen, opt, jb)

        # -- lines 9-11: public inference + per-client adaptive top-k --
        n_samples = int(pub_tokens.shape[0])
        ks = self._budgets(states, n_samples, adaptive_k, len(cohort))

        logits, h = self._public(lora, frozen, pub_tokens)  # (C, P, V), (C, P, r)|None

        active, payloads, rank = self._upload_manifests(
            cohort, states, ks, n_samples, send_h
        )
        dense = h_out = None
        if active:
            take = jnp.asarray(active) if len(active) < len(cohort) else None
            act_logits = logits if take is None else logits[take]
            dense = topk_mask_batch(act_logits, [ks[i] for i in active])
            if rank is not None and h is not None:
                h_out = h if take is None else h[take]

        self._scatter_cohort(idx, lora, opt)
        return ClientPhase(dense=dense, h=h_out, payloads=payloads, ks=ks)


class FusedEngine(BatchedEngine):
    """Single-jit round-body executor: the batched engine's per-phase calls
    (distill steps, fine-tune steps, public inference, top-k) collapse into
    ONE donated, compiled step per round (`fed_steps.make_fused_round_fn`).

    Per-client adaptive ``k`` enters the program as DATA (int32 per client),
    so one executable serves every round regardless of the channel
    realisation; the uplink sparsifier is the threshold-semantics bisection
    (ties at the k-th value are kept) — pure-jnp ``topk_mask_dynamic`` by
    default, or the per-row-budget Pallas kernel with ``use_kernels=True``.
    Byte accounting still uses the exact host-side ``k``s, so the ledger is
    identical to the other engines.

    ``shard_clients=True`` additionally places the leading client axis over
    the process's devices with ``shard_map`` (cohort size must divide the
    device count); on CPU this is testable via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """

    name = "fused"

    def __init__(
        self,
        clients: list[Client],
        cfg: ModelConfig,
        *,
        num_classes: int,
        lr: float = 1e-3,
        distill_lr: float = 1e-3,
        temperature: float = 2.0,
        lam: float = 0.03,
        local_steps: int = 4,
        distill_steps: int = 2,
        restrict_to_support: bool = False,
        value_bits: int = 16,
        k_min: int = 1,
        last_only: bool = True,
        shard_clients: bool = False,
        use_kernels: bool = False,
    ):
        super().__init__(
            clients, cfg, num_classes=num_classes, lr=lr, distill_lr=distill_lr,
            temperature=temperature, lam=lam, local_steps=local_steps,
            distill_steps=distill_steps, restrict_to_support=restrict_to_support,
            value_bits=value_bits, k_min=k_min, last_only=last_only,
        )
        self.shard_clients = shard_clients

        def fused(n_distill: int):
            fn = fed_steps.make_fused_round_fn(
                cfg, num_classes, lr=lr, distill_lr=distill_lr,
                temperature=temperature, lam=lam,
                restrict_to_support=restrict_to_support,
                local_steps=local_steps, distill_steps=n_distill,
                shared_backbone=self._shared, last_only=last_only,
                use_kernels=use_kernels,
            )
            if shard_clients:
                fn = self._shard_over_clients(fn)
            return jax.jit(fn, donate_argnums=(0, 2))

        self._fused_warm = fused(distill_steps)
        self._fused_cold = fused(0)  # round 0: no broadcast knowledge yet

    def _shard_over_clients(self, fn):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P

        devs = jax.devices()
        mesh = Mesh(np.array(devs), ("clients",))
        c, r = P("clients"), P()
        frozen_spec = r if self._shared else c
        return shard_map(
            fn,
            mesh=mesh,
            in_specs=(c, frozen_spec, c, r, r, r, c, r, c),
            out_specs=(c, c, c, c),
            check_rep=False,
        )

    def run_round(
        self,
        sel: Sequence[int],
        pub_tokens: jax.Array,
        bcast: BroadcastState | None,
        states: BatchedChannelState | Sequence[ChannelState],
        *,
        adaptive_k: bool,
        send_h: bool,
    ) -> ClientPhase:
        cohort = [self.clients[i] for i in sel]
        states = list(states)
        if self.shard_clients and len(cohort) % jax.device_count() != 0:
            raise ValueError(
                f"shard_clients: cohort size {len(cohort)} must divide evenly "
                f"over {jax.device_count()} devices"
            )

        idx, lora, frozen, opt = self._gather_cohort(sel)
        batches = self._stacked_batches(cohort, step_major=False)  # (C, S, ...)
        n_samples = int(pub_tokens.shape[0])
        ks = self._budgets(states, n_samples, adaptive_k, len(cohort))

        # -- the whole client phase: ONE compiled, donated call --
        if bcast is not None:
            step = self._fused_warm
            g_tokens, g_logits, g_h = bcast.tokens, bcast.logits, bcast.h
        else:
            step = self._fused_cold  # g_* operands are unused and DCE'd
            g_tokens, g_logits, g_h = pub_tokens, jnp.zeros(
                (n_samples, self.cfg.vocab_size), jnp.float32), None
        lora, opt, dense_all, h_all = step(
            lora, frozen, opt, g_tokens, g_logits, g_h, batches, pub_tokens,
            jnp.asarray(ks, jnp.int32),
        )

        active, payloads, rank = self._upload_manifests(
            cohort, states, ks, n_samples, send_h
        )
        dense = h_out = None
        if active:
            take = jnp.asarray(active) if len(active) < len(cohort) else None
            dense = dense_all if take is None else dense_all[take]
            if rank is not None and h_all is not None:
                h_out = h_all if take is None else h_all[take]

        self._scatter_cohort(idx, lora, opt)
        return ClientPhase(dense=dense, h=h_out, payloads=payloads, ks=ks)


def make_engine(kind: str, clients: list[Client], cfg: ModelConfig, **kwargs):
    if kind == "sequential":
        return SequentialEngine(
            clients, cfg,
            value_bits=kwargs.get("value_bits", 16), k_min=kwargs.get("k_min", 1),
        )
    if kind == "batched":
        kwargs.pop("shard_clients", None)
        kwargs.pop("use_kernels", None)
        return BatchedEngine(clients, cfg, **kwargs)
    if kind == "fused":
        return FusedEngine(clients, cfg, **kwargs)
    raise ValueError(
        f"unknown engine: {kind!r} (expected 'sequential', 'batched' or 'fused')"
    )
