"""Pallas TPU kernels for the paper's compute hot-spots (DESIGN §2):

  topk_select      — channel-adaptive Top-k over 50k-256k vocab (bisection)
  distill_kl       — fused temperature-softmax KL with online logsumexp
  sparse_agg       — fused adaptive aggregation (eqs. 6-7), one HBM pass
  flash_attention  — blockwise causal attention for 32k prefill

Each kernel ships with a pure-jnp oracle in ref.py and a jit'd wrapper in
ops.py; on CPU the wrappers run interpret=True (see ops.interpret_mode).
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
