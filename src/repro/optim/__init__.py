from repro.optim.adamw import AdamWState, adamw_init, adamw_update, global_norm
from repro.optim.schedule import constant, warmup_cosine, warmup_linear

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "global_norm",
    "constant",
    "warmup_cosine",
    "warmup_linear",
]
