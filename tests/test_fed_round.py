"""Integration: complete federated rounds for every method preset.

Slow tier: full run_federated calls with backbone pretraining.  The fast
tier covers the same round machinery on tiny configs in test_engine.py.
"""

import numpy as np
import pytest

from repro.configs.gpt2_paper import REDUCED_CLIENT, REDUCED_SERVER
from repro.core.channel import ChannelConfig
from repro.data import make_banking77_like
from repro.fed import FedConfig, run_federated
from repro.fed.rounds import METHODS

pytestmark = pytest.mark.slow

CLIENT = REDUCED_CLIENT.with_overrides(num_layers=2, d_model=128, num_heads=4, d_ff=256)
SERVER = REDUCED_SERVER.with_overrides(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256
)


def _run(method, rounds=2, **kw):
    ds = make_banking77_like(vocab_size=CLIENT.vocab_size, seq_len=16, total=800, seed=0)
    fed = FedConfig(
        method=method, num_clients=4, clients_per_round=2, rounds=rounds,
        public_size=128, public_batch=32, eval_size=128, local_steps=1,
        distill_steps=1, seed=0, pretrain_steps=24, server_pretrain_steps=16, **kw,
    )
    return run_federated(CLIENT, SERVER, ds, fed)


@pytest.mark.parametrize("method", list(METHODS))
def test_method_round_runs(method):
    run = _run(method)
    assert len(run.server_acc) == 2
    assert all(np.isfinite(a) for a in run.server_acc)
    assert run.ledger.total_mb > 0
    if method == "all_logits":
        # full-vocab payloads every round
        assert all(k == CLIENT.vocab_size for k in run.mean_k)
    else:
        assert all(k < CLIENT.vocab_size for k in run.mean_k)


def test_topk_methods_cheaper_than_all_logits():
    """In the paper's bandwidth-constrained regime (k << vocab) the sparse
    uplink is several times cheaper than transmitting all logits."""
    chan = ChannelConfig(bandwidth_hz=2e5, mean_snr_db=5.0)
    mb = {m: _run(m, channel=chan).ledger.uplink_mb for m in ("adald", "all_logits")}
    assert mb["adald"] < mb["all_logits"] / 3, mb


def test_adald_uplink_includes_projection():
    """AdaLD uploads h (r floats/sample) on top of the sparse logits; with
    identical channels its uplink exceeds 'adaptive' by exactly the
    projection bytes."""
    a = _run("adald").ledger
    b = _run("adaptive").ledger
    per_round_diff = (a.uplink_mb - b.uplink_mb) / len(a.rounds)
    # clients_per_round x public_batch x rank x 16 bits
    expected = 2 * 32 * CLIENT.lora.rank * 2 / 1e6
    assert per_round_diff == pytest.approx(expected, rel=0.05)


def test_channel_conditions_move_k():
    """Worse channels must shrink the adaptive k."""
    good = _run("adald", channel=ChannelConfig(bandwidth_hz=5e6, mean_snr_db=20))
    bad = _run("adald", channel=ChannelConfig(bandwidth_hz=2e5, mean_snr_db=0))
    assert np.mean(bad.mean_k) < np.mean(good.mean_k)


def test_uplink_respects_channel_budget():
    """Property at the system level: each round's uplink fits the allocated
    Shannon budgets (modulo the k_min floor)."""
    run = _run("adald", rounds=3, channel=ChannelConfig(bandwidth_hz=1e6, mean_snr_db=5))
    for r, k in zip(run.ledger.rounds, run.mean_k):
        assert r.uplink_bytes < 10e6  # sanity ceiling
        assert k >= 1
