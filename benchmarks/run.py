"""Benchmark registry — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  fig2_accuracy — paper Fig. 2 (accuracy vs rounds, 4 methods, Non-IID)
  fig3_comm     — paper Fig. 3 (MB to accuracy thresholds, IID)
  agg_ablation  — §III-A aggregation-vs-sparsity analysis
  kernel_*      — Pallas kernel hot-spot microbenches

``python -m benchmarks.run`` runs quick variants (CI-speed); pass --full for
the long curves that populate EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="long runs (minutes)")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import agg_ablation, engine_bench, fig2_accuracy, fig3_comm, kernel_bench

    benches = {
        "kernel": kernel_bench.bench,
        "engine": engine_bench.bench,
        "round": engine_bench.bench_round,
        "hetero": engine_bench.bench_hetero,
        "quant": engine_bench.bench_quant,
        "agg": agg_ablation.bench,
        "fig2": fig2_accuracy.bench,
        "fig3": fig3_comm.bench,
    }
    if args.only:
        keep = set(args.only.split(","))
        benches = {k: v for k, v in benches.items() if k in keep}

    print("name,us_per_call,derived")
    failures = []
    for name, fn in benches.items():
        try:
            for row_name, us, derived in fn(quick=quick):
                print(f"{row_name},{us:.0f},{derived}")
                sys.stdout.flush()
        except Exception as e:  # pragma: no cover - surface in CI output
            failures.append((name, repr(e)))
            print(f"{name},-1,FAILED:{e!r}")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
