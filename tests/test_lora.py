"""LoRA: init semantics (B=0 -> identity), split/merge, LoRA-only training."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LoRAConfig, ModelConfig
from repro.lora import is_lora_path, lora_param_count, merge_lora, split_lora
from repro.models import forward, init


def _cfg(lora=True):
    return ModelConfig(
        name="t", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=128,
        lora=LoRAConfig(rank=4) if lora else None,
    )


def test_lora_b_zero_init_is_identity():
    """W' + B·A with B=0 must reproduce the frozen model exactly (eq. 1)."""
    cfg = _cfg(True)
    cfg0 = _cfg(False)
    params = init(jax.random.PRNGKey(0), cfg)
    params0 = init(jax.random.PRNGKey(0), cfg0)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 128)
    l1, aux = forward(params, cfg, {"tokens": tokens})
    l0, _ = forward(params0, cfg0, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l0), rtol=1e-5, atol=1e-5)
    assert aux.lora_h is not None and aux.lora_h.shape == (2, 4)


def test_split_merge_roundtrip():
    cfg = _cfg(True)
    params = init(jax.random.PRNGKey(0), cfg)
    lora, frozen = split_lora(params)
    merged = merge_lora(lora, frozen)
    flat_a = jax.tree_util.tree_leaves_with_path(params)
    flat_b = jax.tree_util.tree_leaves_with_path(merged)
    assert len(flat_a) == len(flat_b)
    for (pa, a), (pb, b) in zip(flat_a, flat_b):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lora_subset_is_small():
    cfg = _cfg(True)
    params = init(jax.random.PRNGKey(0), cfg)
    n_lora = lora_param_count(params)
    n_all = sum(int(x.size) for x in jax.tree.leaves(params))
    # targets q,v: per layer r*(D + Hq*hd) + r*(D + Kv*hd)
    assert n_lora == 2 * (4 * (64 + 64) + 4 * (64 + 32))
    assert n_lora < n_all * 0.05


def test_only_lora_grads_nonzero_in_distill_step():
    from repro.fed.steps import make_distill_step

    cfg = _cfg(True)
    params = init(jax.random.PRNGKey(0), cfg)
    step = make_distill_step(cfg, lr=1e-2)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0, 128)
    g_logits = jax.random.normal(jax.random.PRNGKey(3), (4, 128))
    g_h = jax.random.normal(jax.random.PRNGKey(4), (4, 4))
    from repro.fed.steps import init_lora_opt

    opt = init_lora_opt(params, cfg)
    new_params, _, metrics = step(params, opt, tokens, g_logits, g_h)
    changed = jax.tree_util.tree_map_with_path(
        lambda p, a, b: bool(jnp.any(a != b)), params, new_params
    )
    for path, c in jax.tree_util.tree_leaves_with_path(changed):
        if c:
            assert is_lora_path(path), f"non-LoRA param changed: {path}"
    assert float(metrics["loss"]) > 0
