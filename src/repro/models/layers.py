"""Primitive layers: norms, RoPE, embeddings, dense MLPs.

Pure-functional pytree style (no flax dependency): every layer is an
``init_*(rng, ...) -> params`` plus an ``apply`` function.  Weights use
truncated-normal fan-in init; compute happens in ``config.compute_dtype``
while params are stored in ``config.param_dtype``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "dense_apply",
    "norm_init",
    "norm_apply",
    "embedding_init",
    "rope_frequencies",
    "apply_rope",
    "mlp_init",
    "mlp_apply",
]


def _dtype(name: str):
    return jnp.dtype(name)


def dense_init(
    rng: jax.Array,
    in_dim: int,
    out_dim: int,
    *,
    use_bias: bool = False,
    dtype: str = "float32",
    scale: float | None = None,
) -> dict:
    std = (scale if scale is not None else 1.0) / (in_dim**0.5)
    w = jax.random.truncated_normal(rng, -2.0, 2.0, (in_dim, out_dim), jnp.float32) * std
    params = {"w": w.astype(_dtype(dtype))}
    if use_bias:
        params["b"] = jnp.zeros((out_dim,), _dtype(dtype))
    return params


def dense_apply(params: dict, x: jax.Array, *, compute_dtype: str = "float32") -> jax.Array:
    cd = _dtype(compute_dtype)
    y = jnp.einsum("...i,io->...o", x.astype(cd), params["w"].astype(cd))
    if "b" in params:
        y = y + params["b"].astype(cd)
    return y


def norm_init(dim: int, *, kind: str = "rmsnorm", dtype: str = "float32") -> dict:
    params = {"scale": jnp.ones((dim,), _dtype(dtype))}
    if kind == "layernorm":
        params["bias"] = jnp.zeros((dim,), _dtype(dtype))
    return params


def norm_apply(
    params: dict, x: jax.Array, *, kind: str = "rmsnorm", eps: float = 1e-6
) -> jax.Array:
    # Norm statistics in fp32 for stability regardless of compute dtype.
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps)
    else:
        mean = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32)
    if "bias" in params:
        y = y + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def embedding_init(
    rng: jax.Array, vocab: int, dim: int, *, dtype: str = "float32"
) -> jax.Array:
    return (jax.random.normal(rng, (vocab, dim), jnp.float32) * 0.02).astype(_dtype(dtype))


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    """Inverse frequencies for RoPE, shape (head_dim // 2,)."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)


def apply_rope(
    x: jax.Array, positions: jax.Array, *, theta: float = 10000.0
) -> jax.Array:
    """Rotate (..., seq, heads, head_dim) by position-dependent angles.

    ``positions``: (..., seq) int32 absolute positions (supports decode where
    the single query sits at position ``cache_len``).
    """
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mlp_init(
    rng: jax.Array,
    d_model: int,
    d_ff: int,
    *,
    activation: str = "swiglu",
    use_bias: bool = False,
    dtype: str = "float32",
) -> dict:
    keys = jax.random.split(rng, 3)
    params = {
        "up": dense_init(keys[0], d_model, d_ff, use_bias=use_bias, dtype=dtype),
        "down": dense_init(keys[1], d_ff, d_model, use_bias=use_bias, dtype=dtype),
    }
    if activation == "swiglu":
        params["gate"] = dense_init(keys[2], d_model, d_ff, use_bias=use_bias, dtype=dtype)
    return params


def mlp_apply(
    params: dict,
    x: jax.Array,
    *,
    activation: str = "swiglu",
    compute_dtype: str = "float32",
) -> jax.Array:
    up = dense_apply(params["up"], x, compute_dtype=compute_dtype)
    if activation == "swiglu":
        gate = dense_apply(params["gate"], x, compute_dtype=compute_dtype)
        hidden = jax.nn.silu(gate) * up
    else:
        hidden = jax.nn.gelu(up)
    return dense_apply(params["down"], hidden, compute_dtype=compute_dtype)
