"""Communication protocol & byte accounting (paper §III-C + Fig. 3).

Everything a round transmits is described here, with its exact on-air size,
so the framework can reproduce the paper's Fig. 3 (communication cost to
reach accuracy thresholds) to the byte.

Paper cost model:
  * full logits upload:   samples * vocab * value_bits            (All-logits)
  * top-k upload:         samples * k * (value_bits + index_bits)
  * LoRA projection:      samples * r * value_bits                (h = A·x)
  * downlink (broadcast): samples * vocab * value_bits  (global logits)
                        + samples * r * value_bits      (global projection)

Zero-padding does not change the on-air size of a top-k upload (padding is a
server-side artifact), so "ZeroPad" and "Adaptive" differ in *rounds needed*,
not bytes/round — exactly how the paper's Fig. 3 separates them.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from repro.core.channel import ChannelState, bits_per_entry

__all__ = [
    "PayloadSpec",
    "UplinkPayload",
    "RoundStats",
    "CommLedger",
    "topk_upload_bits",
    "full_logits_bits",
    "lora_projection_bits",
    "wire_uplink_bits",
    "downlink_bits",
    "total_round_bytes",
]


def full_logits_bits(num_samples: int, vocab: int, value_bits: int = 16) -> int:
    return num_samples * vocab * value_bits


def topk_upload_bits(num_samples: int, k: int, vocab: int, value_bits: int = 16) -> int:
    return num_samples * k * bits_per_entry(value_bits, vocab)


def lora_projection_bits(num_samples: int, rank: int, value_bits: int = 16) -> int:
    return num_samples * rank * value_bits


def wire_uplink_bits(
    num_samples: int, ks: Iterable[int], vocab: int, value_bits: int = 16
) -> int:
    """On-air bits of a whole cohort's sparse wire payload
    (:class:`repro.core.topk.SparseWire`): only the MASKED-IN (value, index)
    entries are transmitted — the static ``k_cap`` padding is a server-side
    representation artifact, exactly like dense zero-padding, so the wire
    format costs byte-for-byte what the per-client top-k manifests say:
    ``Σ_n samples · k_n · d`` (k == 0 stragglers contribute nothing)."""
    return sum(
        topk_upload_bits(num_samples, k, vocab, value_bits) for k in ks if k > 0
    )


@dataclasses.dataclass(frozen=True)
class PayloadSpec:
    """Static description of what one client sends per round.

    ``value_bits`` prices the (value, index) top-k entries — 8 for the
    int8-quantized wire, 16 for the float wire — while ``h_value_bits``
    prices the (unquantized) LoRA projection ``h`` separately; it defaults
    to ``value_bits`` so homogeneous-precision payloads are unchanged.
    """

    num_samples: int
    vocab: int
    k: int
    lora_rank: int | None = None  # None -> no projection exchanged
    value_bits: int = 16
    h_value_bits: int | None = None  # None -> value_bits

    @property
    def uplink_bits(self) -> int:
        bits = topk_upload_bits(self.num_samples, self.k, self.vocab, self.value_bits)
        if self.lora_rank is not None:
            h_bits = self.value_bits if self.h_value_bits is None else self.h_value_bits
            bits += lora_projection_bits(self.num_samples, self.lora_rank, h_bits)
        return bits

    @property
    def uplink_bytes(self) -> float:
        return self.uplink_bits / 8.0

    def fits(self, channel: ChannelState) -> bool:
        """Does the payload respect the Shannon budget?  (enforced invariant —
        property-tested)."""
        return self.uplink_bits <= channel.bit_budget + 1e-6


@dataclasses.dataclass
class UplinkPayload:
    """One client's realized upload for a round (arrays live elsewhere;
    this is the manifest used for accounting).

    ``attempts`` is the number of HARQ transmissions actually made (PR 8):
    every attempt re-spends the full payload on the air, so the ledger
    bytes are ``attempts * spec.uplink_bytes``.  ``delivered=False`` marks
    a quarantined upload whose attempts were spent without a usable copy
    arriving — the bytes still count (they were transmitted), the payload
    just contributes nothing to aggregation.
    """

    client_id: int
    spec: PayloadSpec
    snr_db: float = float("nan")
    attempts: int = 1
    delivered: bool = True

    @property
    def bytes(self) -> float:
        return self.attempts * self.spec.uplink_bytes


@dataclasses.dataclass
class RoundStats:
    """Per-round ledger entry."""

    round_index: int
    uplink_bytes: float = 0.0
    downlink_bytes: float = 0.0
    server_accuracy: float = float("nan")
    client_accuracy: float = float("nan")
    distill_loss: float = float("nan")
    mean_k: float = float("nan")
    # Clients that actually uploaded this round (straggler/dropout scenarios
    # can leave selected clients with k == 0 -> they transmit nothing and are
    # excluded from aggregation).  None -> engine predates this field.
    num_selected: int | None = None
    num_transmitters: int | None = None
    # Fault-tolerance taps (PR 8; None/0.0 when fault injection is off).
    # num_quarantined counts uploads the server rejected (corruption that
    # exhausted HARQ retries, or wire validation failures) — distinct from
    # num_crashed, whose uploads never arrived at all.  fault_counts breaks
    # the losses down per reason ("crash" | "corrupt" | "invalid_wire");
    # retrans_bytes is the on-air cost beyond each delivered payload's first
    # copy (HARQ retries + quarantined attempts), already included in
    # uplink_bytes.
    num_quarantined: int | None = None
    num_crashed: int | None = None
    fault_counts: dict | None = None
    retrans_bytes: float = 0.0

    @property
    def total_bytes(self) -> float:
        return self.uplink_bytes + self.downlink_bytes


class CommLedger:
    """Accumulates communication volume across rounds (drives Fig. 3)."""

    def __init__(self) -> None:
        self.rounds: list[RoundStats] = []

    def record(self, stats: RoundStats) -> None:
        self.rounds.append(stats)

    @property
    def total_mb(self) -> float:
        return sum(r.total_bytes for r in self.rounds) / 1e6

    @property
    def uplink_mb(self) -> float:
        return sum(r.uplink_bytes for r in self.rounds) / 1e6

    def mb_to_reach(self, accuracy: float, *, which: str = "server") -> float | None:
        """MB of total communication until the (server|client) accuracy first
        reaches ``accuracy`` — the paper's Fig. 3 metric.  None if never."""
        acc_field = "server_accuracy" if which == "server" else "client_accuracy"
        total = 0.0
        for r in self.rounds:
            total += r.total_bytes
            acc = getattr(r, acc_field)
            if not math.isnan(acc) and acc >= accuracy:
                return total / 1e6
        return None

    def summary(self) -> dict[str, float]:
        return {
            "rounds": float(len(self.rounds)),
            "total_mb": self.total_mb,
            "uplink_mb": self.uplink_mb,
            "final_server_acc": (
                self.rounds[-1].server_accuracy if self.rounds else float("nan")
            ),
        }


def downlink_bits(
    num_samples: int, vocab: int, rank: int | None, value_bits: int = 16
) -> int:
    """Server broadcast: global logits (+ global projection)."""
    bits = full_logits_bits(num_samples, vocab, value_bits)
    if rank is not None:
        bits += lora_projection_bits(num_samples, rank, value_bits)
    return bits


def total_round_bytes(payloads: Iterable[UplinkPayload], downlink_bits_: int) -> float:
    return sum(p.bytes for p in payloads) + downlink_bits_ / 8.0
