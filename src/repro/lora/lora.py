"""LoRA parameter-tree utilities (paper §II-A, eq. 1).

Adapters are initialised inside the model zoo (transformer._lora_init:
A ~ N(0, 1/d), B = 0, so W' + B·A starts at W') and live at paths
``stack/posJ/lora/<target>/{A,B}``.  This module provides the
trainable/frozen split used by client fine-tuning — the paper trains ONLY
θ_n = {A_n, B_n} on-device — plus merge and projection helpers.
"""

from __future__ import annotations

from typing import Any, Callable

import jax

__all__ = [
    "is_lora_path",
    "path_strings",
    "split_lora",
    "merge_lora",
    "lora_param_count",
    "map_lora",
    "lora_template",
]


def _path_strings(path) -> tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        elif hasattr(p, "idx"):
            out.append(f"idx{p.idx}")
        else:
            out.append(str(p))
    return tuple(out)


def is_lora_path(path) -> bool:
    return any(part == "lora" or part.startswith("lora_") for part in _path_strings(path))


def split_lora(params: Any) -> tuple[Any, Any]:
    """(trainable lora-only tree, frozen tree) — same structure, with None
    at the complementary positions (suitable for jax.grad over the first)."""
    lora = jax.tree_util.tree_map_with_path(
        lambda p, x: x if is_lora_path(p) else None, params
    )
    frozen = jax.tree_util.tree_map_with_path(
        lambda p, x: None if is_lora_path(p) else x, params
    )
    return lora, frozen


def merge_lora(lora: Any, frozen: Any) -> Any:
    """Inverse of split_lora."""
    return jax.tree.map(
        lambda a, b: a if a is not None else b,
        lora,
        frozen,
        is_leaf=lambda x: x is None,
    )


def lora_param_count(params: Any) -> int:
    lora, _ = split_lora(params)
    return sum(int(x.size) for x in jax.tree.leaves(lora))


def map_lora(fn: Callable[[jax.Array], jax.Array], params: Any) -> Any:
    """Apply ``fn`` to LoRA leaves only (e.g. zeroing non-LoRA grads)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: fn(x) if is_lora_path(p) else x, params
    )


def lora_template(params: Any) -> Any:
    """Shape/dtype skeleton of the adapter subtree (``split_lora()[0]`` with
    ``jax.ShapeDtypeStruct`` leaves) — the ``like`` argument the serving
    AdapterCache validates fleet rows against."""
    lora, _ = split_lora(params)
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), lora)


# public alias: serving (repro.serve) dispatches on path segments ("stack"
# subtrees are stacked over layer repeats) using the same normalisation
path_strings = _path_strings
