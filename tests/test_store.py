"""FleetStore (PR 9): out-of-core fleet state + streaming cohorts.

The contract under test: the ``"host"`` store runs every fast engine
BIT-IDENTICALLY to the default ``"device"`` store (same per-client
adaptive k, ledger bytes, accuracies) while keeping only the current
cohort on device; prefetch overlap never changes results (dirty-row
patching); checkpoints written under either store restore under the
other (the fleet rides per-client-range npz shards for the host store);
and duplicate cohort selections are rejected at the engine boundary
instead of resolving ``.at[sel].set`` writes in unspecified order.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LoRAConfig
from repro.configs.gpt2_paper import REDUCED_CLIENT, REDUCED_SERVER
from repro.core import ChannelConfig
from repro.core.channel import BatchedChannelState, ChannelState
from repro.data import make_banking77_like
from repro.fed import FedConfig, run_federated
from repro.fed.client import Client
from repro.fed.engine import BatchedEngine, FusedE2EEngine, make_engine
from repro.fed.server import Server
from repro.fed.store import DeviceFleetStore, HostFleetStore, make_fleet_store

LORA = LoRAConfig(rank=4, alpha=32.0, dropout=0.0, targets=("q", "v", "head"))
CLIENT = REDUCED_CLIENT.with_overrides(
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
    vocab_size=256, max_seq_len=32, lora=LORA,
)
SERVER = REDUCED_SERVER.with_overrides(
    num_layers=2, d_model=96, num_heads=2, num_kv_heads=2, d_ff=192,
    vocab_size=256, max_seq_len=32, lora=LORA,
)
# Two dense families (different widths) for the bucketed hetero engines.
FAM_A = CLIENT.with_overrides(name="fam-a")
FAM_B = CLIENT.with_overrides(name="fam-b", d_model=96, d_ff=192)
# Constrained uplink so the adaptive k actually varies per client/round.
CHAN = ChannelConfig(bandwidth_hz=2e5, mean_snr_db=2.0)


def _dataset():
    return make_banking77_like(vocab_size=CLIENT.vocab_size, seq_len=12, total=500, seed=0)


def _cfg(engine, rounds=2, **kw):
    kw.setdefault("pretrain_steps", 0)
    return FedConfig(
        method="adald", engine=engine, num_clients=4, clients_per_round=2,
        rounds=rounds, public_size=64, public_batch=16, eval_size=64,
        local_steps=2, distill_steps=1, server_distill_steps=2,
        seed=0, channel=CHAN, **kw,
    )


def _assert_identical(a, b):
    assert a.server_acc == b.server_acc
    assert a.client_acc == b.client_acc
    assert a.per_client_k == b.per_client_k
    for ra, rb in zip(a.ledger.rounds, b.ledger.rounds):
        assert ra.uplink_bytes == rb.uplink_bytes
        assert ra.downlink_bytes == rb.downlink_bytes
        assert ra.num_transmitters == rb.num_transmitters


# ---------------------------------------------------------------------------
# raw-store unit tests (toy pytrees; no model in the loop)
# ---------------------------------------------------------------------------


def _toy(n, seed=0):
    """n deterministic per-client (lora, opt) rows + one shared frozen."""
    rng = np.random.default_rng(seed)
    row = lambda: {  # noqa: E731
        "w": rng.normal(size=(3, 2)).astype(np.float32),
        "b": {"v": rng.normal(size=(4,)).astype(np.float32)},
    }
    loras = [row() for _ in range(n)]
    opts = [row() for _ in range(n)]
    frozen = row()
    return loras, [frozen] * n, opts


def _mk_host(n=6, **kw):
    loras, frozens, opts = _toy(n)
    return HostFleetStore(loras, frozens, opts, shared=True, **kw)


def _assert_cohort_equal(a, b):
    """Compare two fetch results' lora+opt trees exactly."""
    for xa, xb in zip(jax.tree.leaves((a[1], a[3])), jax.tree.leaves((b[1], b[3]))):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


def test_host_fetch_commit_roundtrip():
    loras, _, opts = _toy(6)
    st = _mk_host(prefetch=False)
    idx, lora, frozen, opt = st.fetch([1, 3])
    np.testing.assert_array_equal(np.asarray(idx), [1, 3])
    np.testing.assert_array_equal(np.asarray(lora["w"][0]), loras[1]["w"])
    np.testing.assert_array_equal(np.asarray(opt["b"]["v"][1]), opts[3]["b"]["v"])
    bump = lambda t: jax.tree.map(lambda x: x * 2.0 + 1.0, t)  # noqa: E731
    st.commit(idx, bump(lora), bump(opt))
    _, lora2, _, opt2 = st.fetch([1, 3])
    np.testing.assert_array_equal(np.asarray(lora2["w"]), np.asarray(bump(lora)["w"]))
    np.testing.assert_array_equal(
        np.asarray(opt2["b"]["v"]), np.asarray(bump(opt)["b"]["v"])
    )
    # untouched rows unaffected
    _, lora0, _, _ = st.fetch([0])
    np.testing.assert_array_equal(np.asarray(lora0["w"][0]), loras[0]["w"])


def test_host_prefetch_overlap_bit_identity():
    """A prefetched fetch returns exactly what an unprefetched one would,
    even when the prefetched cohort overlaps rows committed AFTER the
    prefetch snapshot (the dirty-row patch)."""
    a, b = _mk_host(prefetch=True), _mk_host(prefetch=False)
    sel0, sel1 = [0, 1], [1, 2]  # round r, round r+1 — overlap on client 1
    fa, fb = a.fetch(sel0), b.fetch(sel0)
    a.prefetch(sel1)  # staged BEFORE round r's rows are committed
    bump = lambda t: jax.tree.map(lambda x: x * 2.0 + 1.0, t)  # noqa: E731
    a.commit(fa[0], bump(fa[1]), bump(fa[3]))
    b.commit(fb[0], bump(fb[1]), bump(fb[3]))
    _assert_cohort_equal(a.fetch(sel1), b.fetch(sel1))


def test_host_prefetch_double_buffer_driver_order():
    """The round driver hints round r+1 BEFORE it fetches round r's
    already-staged cohort (rounds.py draws the next cohort first).  The
    store must hold BOTH staged entries — the later hint must not evict
    the current round's — and stay bit-identical to no prefetch."""
    a, b = _mk_host(prefetch=True), _mk_host(prefetch=False)
    bump = lambda t: jax.tree.map(lambda x: x * 2.0 + 1.0, t)  # noqa: E731
    sels = [[0, 1], [1, 2], [2, 3], [0, 3]]  # consecutive overlaps
    a.prefetch(sels[0])
    for r, sel in enumerate(sels):
        if r + 1 < len(sels):
            a.prefetch(sels[r + 1])  # the driver's order: hint, THEN fetch
        assert tuple(sel) in a._pf  # this round's entry survived the hint
        fa, fb = a.fetch(sel), b.fetch(sel)
        assert tuple(sel) not in a._pf  # consumed, not re-staged
        _assert_cohort_equal(fa, fb)
        a.commit(fa[0], bump(fa[1]), bump(fa[3]))
        b.commit(fb[0], bump(fb[1]), bump(fb[3]))


def test_host_prefetch_hint_miss_falls_back():
    """A prefetch hint for a DIFFERENT cohort (even a reordering) is
    discarded; the fetch still returns the right rows."""
    a, b = _mk_host(prefetch=True), _mk_host(prefetch=False)
    a.prefetch([2, 3])
    _assert_cohort_equal(a.fetch([3, 2]), b.fetch([3, 2]))


def test_host_commit_duplicate_rejected():
    st = _mk_host(prefetch=False)
    idx, lora, _, opt = st.fetch([1, 1])  # reads may repeat; writes may not
    with pytest.raises(ValueError, match="duplicate"):
        st.commit(idx, lora, opt)


def test_host_store_has_no_stacked_device_tree():
    st = _mk_host()
    with pytest.raises(RuntimeError, match="scan"):
        st.lora  # noqa: B018
    with pytest.raises(RuntimeError, match="scan"):
        st.opt  # noqa: B018


def test_shard_roundtrip_cross_store(tmp_path):
    """Sharded fleet persistence is store-agnostic: shards written by the
    device store restore into the host store bit-identically, and back."""
    loras, frozens, opts = _toy(5)
    blank = lambda rows: [jax.tree.map(np.zeros_like, r) for r in rows]  # noqa: E731
    dev = DeviceFleetStore(loras, frozens, opts, shared=True)
    dev.shard_size = 2  # 3 shard files for 5 clients
    d1 = str(tmp_path / "dev")
    dev.save_shards(d1)
    assert sorted(os.listdir(d1)) == [
        "fleet_00000000_00000002.npz", "fleet_00000002_00000004.npz",
        "fleet_00000004_00000005.npz", "fleet_frozen.npz",
    ]
    host = HostFleetStore(
        blank(loras), blank(frozens), blank(opts), shared=True, prefetch=False
    )
    host.load_shards(d1)
    for k in ("lora", "opt", "frozen"):
        for xa, xb in zip(jax.tree.leaves(dev.state_dict()[k]),
                          jax.tree.leaves(host.state_dict()[k])):
            np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
    # and back: host shards (different shard_size) -> fresh device store
    host.shard_size = 3
    d2 = str(tmp_path / "host")
    host.save_shards(d2)
    dev2 = DeviceFleetStore(blank(loras), blank(frozens), blank(opts), shared=True)
    dev2.load_shards(d2)
    for k in ("lora", "opt", "frozen"):
        for xa, xb in zip(jax.tree.leaves(dev.state_dict()[k]),
                          jax.tree.leaves(dev2.state_dict()[k])):
            np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


def test_incomplete_shards_rejected(tmp_path):
    loras, frozens, opts = _toy(5)
    st = _mk_host(5, prefetch=False)
    st.shard_size = 2
    d = str(tmp_path)
    st.save_shards(d)
    os.remove(os.path.join(d, "fleet_00000002_00000004.npz"))
    with pytest.raises(ValueError, match="cover"):
        _mk_host(5, prefetch=False).load_shards(d)


def test_spill_dir_pages_fleet_to_disk(tmp_path):
    """spill_dir mode: host stacks live as npz shards (host_bytes == 0);
    commits across more shards than the cache cap force write-back
    eviction, and every row still round-trips exactly."""
    ref = _mk_host(10, prefetch=False)
    sp = _mk_host(10, prefetch=False, spill_dir=str(tmp_path), shard_size=1)
    assert sp.host_bytes() == 0
    assert any(f.startswith("spill_") for f in os.listdir(str(tmp_path)))
    bump = lambda t: jax.tree.map(lambda x: x + 1.0, t)  # noqa: E731
    for cid in range(10):  # 10 shards > cache cap of 4
        for st in (ref, sp):
            idx, lora, _, opt = st.fetch([cid])
            st.commit(idx, bump(lora), bump(opt))
    for cid in range(10):
        _assert_cohort_equal(sp.fetch([cid]), ref.fetch([cid]))


def test_from_template_lazy_rows():
    """from_template: every row reads the template until its first commit;
    committed rows persist; device residency is independent of N."""
    loras, frozens, opts = _toy(1, seed=7)
    mk = lambda n: HostFleetStore.from_template(  # noqa: E731
        loras[0], frozens[0], opts[0], num_clients=n, prefetch=False
    )
    st = mk(8)
    _, lora, _, opt = st.fetch([2, 5])
    for j in range(2):
        np.testing.assert_array_equal(np.asarray(lora["w"][j]), loras[0]["w"])
        np.testing.assert_array_equal(
            np.asarray(opt["b"]["v"][j]), opts[0]["b"]["v"]
        )
    new_l = jax.tree.map(lambda x: x[:1] * 3.0, lora)
    new_o = jax.tree.map(lambda x: x[:1] * 3.0, opt)
    st.commit(jnp.asarray([2]), new_l, new_o)
    _, lora2, _, _ = st.fetch([2])
    np.testing.assert_array_equal(np.asarray(lora2["w"]), np.asarray(new_l["w"]))
    _, lora5, _, _ = st.fetch([5])  # still the template
    np.testing.assert_array_equal(np.asarray(lora5["w"][0]), loras[0]["w"])
    # O(1)-in-N construction and device residency (the shared backbone)
    big = mk(100_000)
    assert big.device_bytes() == st.device_bytes()
    assert big.num_clients == 100_000


def test_make_fleet_store_spec():
    loras, frozens, opts = _toy(3)
    kw = dict(loras=loras, frozens=frozens, opts=opts, shared=True)
    assert make_fleet_store(None, **kw).kind == "device"
    assert make_fleet_store("device", **kw).kind == "device"
    assert make_fleet_store("host", **kw).kind == "host"
    st = HostFleetStore(loras, frozens, opts, shared=True)
    assert make_fleet_store(st, **kw) is st
    with pytest.raises(ValueError, match="fleet_store"):
        make_fleet_store("gpu", **kw)


# ---------------------------------------------------------------------------
# engine + driver integration
# ---------------------------------------------------------------------------


def _cohort(n, ds, cfgs=None):
    cfgs = cfgs or [CLIENT]
    return [
        Client(i, cfgs[i % len(cfgs)], ds.subset(np.arange(i * 60, (i + 1) * 60)),
               num_classes=ds.num_classes, seed=i, local_steps=1, distill_steps=1)
        for i in range(n)
    ]


@pytest.mark.parametrize("engine", ["batched", "fused", "fused_e2e"])
def test_host_store_run_parity(engine):
    """fleet_store='host' reproduces the device-store run bit-identically:
    the streamed cohort rows round-trip host<->device losslessly and feed
    the exact same compiled round."""
    ds = _dataset()
    dev = run_federated(CLIENT, SERVER, ds, _cfg(engine))
    host = run_federated(CLIENT, SERVER, ds, _cfg(engine, fleet_store="host"))
    _assert_identical(host, dev)


@pytest.mark.parametrize("engine", ["batched", "fused_e2e"])
def test_hetero_host_store_run_parity(engine):
    """Family-bucketed engines stream per-bucket cohorts through host
    stores (one store per bucket) at bit-parity with the device stores."""
    ds = _dataset()
    fams = [FAM_A, FAM_B]
    dev = run_federated(fams, SERVER, ds, _cfg(engine))
    host = run_federated(fams, SERVER, ds, _cfg(engine, fleet_store="host"))
    _assert_identical(host, dev)


def test_scan_rounds_host_store_falls_back():
    """scan_rounds needs the fleet as a donated device scan carry; with a
    host store the driver falls back to the per-round loop and must match
    the explicit per-round host run bit-identically."""
    ds = _dataset()
    loop = run_federated(CLIENT, SERVER, ds,
                         _cfg("fused_e2e", rounds=3, fleet_store="host"))
    scan = run_federated(
        CLIENT, SERVER, ds,
        _cfg("fused_e2e", rounds=3, scan_rounds=True, fleet_store="host"),
    )
    _assert_identical(scan, loop)


def test_engine_rejects_duplicate_cohort():
    ds = _dataset()
    eng = BatchedEngine(
        _cohort(4, ds), CLIENT, num_classes=ds.num_classes,
        local_steps=1, distill_steps=1,
    )
    pub = jnp.asarray(ds.tokens[:16])
    states = BatchedChannelState.from_states(
        [ChannelState(1e6, 10.0, 0.5, 1.0)] * 2
    )
    with pytest.raises(ValueError, match="duplicate client ids"):
        eng.run_round([1, 1], pub, None, states, adaptive_k=True, send_h=True)


def test_run_rounds_requires_device_store():
    """The multi-round lax.scan driver donates the stacked fleet into one
    compiled scan — it must refuse a host store up front (rounds.py falls
    back to the per-round driver instead)."""
    ds = _dataset()
    eng = FusedE2EEngine(
        _cohort(4, ds), CLIENT,
        server=Server(SERVER, aggregation="adaptive", distill_steps=2),
        num_classes=ds.num_classes, local_steps=1, distill_steps=1,
        server_distill_steps=2, fleet_store="host",
    )
    pub = jnp.asarray(ds.tokens[:16])
    states = BatchedChannelState.from_states(
        [ChannelState(1e6, 10.0, 0.5, 1.0)] * 2
    )
    with pytest.raises(RuntimeError, match="fleet_store='device'"):
        eng.run_rounds([[0, 1]], [pub], [states], adaptive_k=True, send_h=True)


def test_sequential_engine_rejects_host_store():
    ds = _dataset()
    with pytest.raises(NotImplementedError, match="sequential"):
        make_engine("sequential", _cohort(2, ds), CLIENT,
                    num_classes=ds.num_classes, fleet_store="host")


# ---------------------------------------------------------------------------
# sharded checkpoints + resume
# ---------------------------------------------------------------------------


def test_host_store_sharded_resume_bit_identical(tmp_path):
    """Kill after round 2, resume to 4: bit-identical to an uninterrupted
    host-store run — with the fleet persisted as per-client shards in a
    step-side .fleet dir, never as one monolithic tree in the step npz."""
    from repro.checkpoint import step_metadata

    ds = _dataset()
    full = run_federated(CLIENT, SERVER, ds,
                         _cfg("batched", rounds=4, fleet_store="host"))
    d = str(tmp_path)
    run_federated(CLIENT, SERVER, ds,
                  _cfg("batched", rounds=2, fleet_store="host"), ckpt_dir=d)
    fleet_dir = os.path.join(d, "step_00000002.fleet")
    assert os.path.isdir(fleet_dir)
    assert any(f.startswith("fleet_") for f in os.listdir(fleet_dir))
    assert step_metadata(d, 2)["fleet_sharded"] is True
    res = run_federated(CLIENT, SERVER, ds,
                        _cfg("batched", rounds=4, fleet_store="host"),
                        ckpt_dir=d, resume=True)
    _assert_identical(res, full)


def test_cross_store_resume(tmp_path):
    """fleet_store is excluded from the resume fingerprint: a checkpoint
    written under the host store (sharded) resumes under the device store
    bit-identically, and vice versa."""
    ds = _dataset()
    full = run_federated(CLIENT, SERVER, ds, _cfg("batched", rounds=4))
    # host-sharded checkpoint -> device-store resume
    d1 = str(tmp_path / "h2d")
    run_federated(CLIENT, SERVER, ds,
                  _cfg("batched", rounds=2, fleet_store="host"), ckpt_dir=d1)
    res = run_federated(CLIENT, SERVER, ds, _cfg("batched", rounds=4),
                        ckpt_dir=d1, resume=True)
    _assert_identical(res, full)
    # monolithic device checkpoint -> host-store resume
    d2 = str(tmp_path / "d2h")
    run_federated(CLIENT, SERVER, ds, _cfg("batched", rounds=2), ckpt_dir=d2)
    res = run_federated(CLIENT, SERVER, ds,
                        _cfg("batched", rounds=4, fleet_store="host"),
                        ckpt_dir=d2, resume=True)
    _assert_identical(res, full)


def test_hetero_host_store_sharded_resume(tmp_path):
    """Bucketed fleets persist per-bucket shard prefixes in one .fleet dir
    and resume bit-identically over host stores."""
    ds = _dataset()
    fams = [FAM_A, FAM_B]
    full = run_federated(fams, SERVER, ds,
                         _cfg("batched", rounds=4, fleet_store="host"))
    d = str(tmp_path)
    run_federated(fams, SERVER, ds,
                  _cfg("batched", rounds=2, fleet_store="host"), ckpt_dir=d)
    fleet_dir = os.path.join(d, "step_00000002.fleet")
    names = os.listdir(fleet_dir)
    assert any(f.startswith("bucket0_") for f in names)
    assert any(f.startswith("bucket1_") for f in names)
    res = run_federated(fams, SERVER, ds,
                        _cfg("batched", rounds=4, fleet_store="host"),
                        ckpt_dir=d, resume=True)
    _assert_identical(res, full)
