"""Federated AdaLD driver — the paper's experiment as a CLI.

  PYTHONPATH=src python -m repro.launch.fed_train --method adald --rounds 10

Reduced-scale GPT-2-family models on the synthetic Banking77-statistics
dataset (DESIGN §1); writes a JSON history consumable by benchmarks/fig2/3.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os

from repro.configs import get_smoke_config
from repro.configs.gpt2_paper import REDUCED_CLIENT, REDUCED_SERVER
from repro.data import make_banking77_like
from repro.fed import FedConfig, run_federated
from repro.fed.rounds import METHODS


def family_configs(spec: str, seq_len: int):
    """Resolve ``--families`` into per-family model configs aligned to the
    shared exchange contracts (one vocab, one LoRA rank — paper §II): each
    comma-separated arch id's smoke config is re-based onto the reduced
    experiment's vocab/LoRA; SSM families get a chunk size dividing the
    experiment sequence length."""
    fams = []
    for arch in spec.split(","):
        arch = arch.strip()
        if not arch:
            continue
        smoke = get_smoke_config(arch)
        over = dict(
            name=f"fam-{arch}",
            vocab_size=REDUCED_CLIENT.vocab_size,
            lora=REDUCED_CLIENT.lora,
            max_seq_len=max(seq_len, 32),
        )
        if smoke.ssm is not None:
            chunk = next(c for c in (8, 4, 2, 1) if seq_len % c == 0)
            over["ssm"] = dataclasses.replace(smoke.ssm, chunk_size=chunk)
        fams.append(smoke.with_overrides(**over))
    if not fams:
        raise SystemExit(f"--families {spec!r} names no architectures")
    return fams


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", choices=list(METHODS), default="adald")
    ap.add_argument("--engine",
                    choices=["sequential", "batched", "fused", "fused_e2e"],
                    default="batched",
                    help="round executor (batched = vmapped per-phase cohort "
                         "steps; fused = one jitted CLIENT-phase body; "
                         "fused_e2e = one jitted call for the WHOLE round — "
                         "sparse-wire aggregation + server distill + "
                         "broadcast folded in)")
    ap.add_argument("--full-head", action="store_true",
                    help="materialise full (B,T,V) logits instead of the "
                         "last-only LM head (the pre-PR-2 behaviour)")
    ap.add_argument("--shard-clients", action="store_true",
                    help="fused/fused_e2e engines: place the client axis "
                         "over jax devices via shard_map (for fused_e2e the "
                         "placement lives inside the whole-round executable; "
                         "the server phase stays replicated)")
    ap.add_argument("--scan-rounds", action="store_true",
                    help="fused_e2e only: run ALL rounds as one compiled "
                         "lax.scan dispatch with the per-round eval tapped "
                         "inside the scan")
    ap.add_argument("--families", default=None,
                    help="comma-separated arch ids from repro.configs (e.g. "
                         "'gpt2-paper,mamba2-130m'): heterogeneous fleet — "
                         "clients cycle these families round-robin, served "
                         "by the family-bucketed engines; smoke configs are "
                         "re-based onto the shared vocab/LoRA exchange "
                         "contract.  Default: homogeneous REDUCED_CLIENT")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--fleet-size", type=int, default=None,
                    help="alias for --clients aimed at fleet-scale runs "
                         "(takes precedence when both are given); pair with "
                         "--fleet-store host so device memory stays "
                         "O(cohort) regardless of this number")
    ap.add_argument("--fleet-store", choices=["device", "host"],
                    default="device",
                    help="fleet-state residency (repro.fed.store): 'device' "
                         "keeps the whole fleet stacked on the accelerator; "
                         "'host' keeps it in host memory and streams only "
                         "each round's cohort to the device, prefetching "
                         "round r+1's cohort under round r's compute")
    ap.add_argument("--per-round", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--iid", action="store_true")
    ap.add_argument("--lam", type=float, default=0.03)
    ap.add_argument("--use-kernels", action="store_true")
    ap.add_argument("--quantize-wire", action="store_true",
                    help="int8-quantize the sparse uplink wire (one fp32 "
                         "scale per (client, sample) row): entries are "
                         "priced at 8 bits, so the same Shannon budget "
                         "affords a larger adaptive k at fixed SNR")
    ap.add_argument("--compute-dtype", choices=["float32", "bfloat16"],
                    default="float32",
                    help="fused engines: round-body compute dtype; fp32 "
                         "master LoRA/optimizer state is kept either way")
    ap.add_argument("--scenario", default=None,
                    help="channel-dynamics preset from repro.core.scenario "
                         "(iid | gauss_markov | jakes | gilbert_elliott | "
                         "mobility): time-correlated fading / bursty outage "
                         "/ mobility trajectories.  Default: the i.i.d. "
                         "per-round channel")
    ap.add_argument("--faults", default=None,
                    help="fault-injection preset from repro.core.faults "
                         "(none | corruption | crashes | bursty | lossy): "
                         "payload corruption with HARQ retransmission, "
                         "client crashes mid-round, Gilbert-Elliott fault "
                         "bursts.  Default: no faults")
    ap.add_argument("--ckpt-dir", default=None,
                    help="write an atomic round-granular checkpoint after "
                         "every completed round (crash-safe: a kill mid-"
                         "save never corrupts the latest step)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest valid checkpoint in "
                         "--ckpt-dir; the resumed run is bit-identical to "
                         "an uninterrupted one (same k, bytes, accuracies)")
    ap.add_argument("--public-batch", type=int, default=128)
    ap.add_argument("--out", default="experiments/fed")
    args = ap.parse_args(argv)

    if args.resume and args.ckpt_dir is None:
        ap.error("--resume requires --ckpt-dir")

    seq_len = 24
    ds = make_banking77_like(vocab_size=REDUCED_CLIENT.vocab_size, seq_len=seq_len, seed=args.seed)
    client_cfg = (
        family_configs(args.families, seq_len) if args.families else REDUCED_CLIENT
    )
    fed = FedConfig(
        method=args.method,
        engine=args.engine,
        fleet_store=args.fleet_store,
        num_clients=(
            args.fleet_size if args.fleet_size is not None else args.clients
        ),
        clients_per_round=args.per_round,
        rounds=args.rounds,
        public_size=512,
        public_batch=args.public_batch,
        eval_size=512,
        non_iid=not args.iid,
        seed=args.seed,
        lam=args.lam,
        use_kernels=args.use_kernels,
        quantize_wire=args.quantize_wire,
        compute_dtype=args.compute_dtype,
        last_only=not args.full_head,
        shard_clients=args.shard_clients,
        scan_rounds=args.scan_rounds,
        scenario=args.scenario,
        faults=args.faults,
    )
    run = run_federated(
        client_cfg, REDUCED_SERVER, ds, fed, verbose=True,
        ckpt_dir=args.ckpt_dir, resume=args.resume,
    )

    os.makedirs(args.out, exist_ok=True)
    rec = {
        "method": args.method,
        "families": args.families,
        "family_client_acc": run.family_client_acc,
        "scenario": args.scenario,
        # scenario scan runs only: the in-scan channel tap (-inf SNR in
        # outage is not valid JSON; clamp to a sentinel)
        "snr_db": None if run.snr_db is None else [
            [x if math.isfinite(x) else -1e9 for x in row] for row in run.snr_db
        ],
        "outage": run.outage,
        "faults": args.faults,
        "num_quarantined": run.num_quarantined,
        "num_crashed": run.num_crashed,
        "retrans_bytes": run.retrans_bytes,
        "fed": {k: v for k, v in dataclasses.asdict(fed).items() if not isinstance(v, dict)},
        "server_acc": run.server_acc,
        "client_acc": run.client_acc,
        "mean_k": run.mean_k,
        # null, not bare NaN (engines without the in-program tap report NaN;
        # bare NaN is not RFC-8259 JSON)
        "distill_loss": [
            None if math.isnan(x) else x for x in run.distill_loss
        ],
        "uplink_mb_per_round": [r.uplink_bytes / 1e6 for r in run.ledger.rounds],
        "downlink_mb_per_round": [r.downlink_bytes / 1e6 for r in run.ledger.rounds],
        "summary": run.summary(),
    }
    path = os.path.join(args.out, f"{args.method}_seed{args.seed}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[fed] {args.method}: final server acc "
          f"{run.server_acc[-1]:.3f}, total {run.ledger.total_mb:.2f} MB -> {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
