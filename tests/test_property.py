"""Hypothesis property tests on the system's invariants.

Skipped cleanly (not a collection error) when hypothesis isn't installed —
it is a dev-only dependency (see requirements-dev.txt).
"""

import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import (
    aggregate,
    aggregate_adaptive,
    aggregate_wire,
    aggregate_zeropad,
)
from repro.core.channel import ChannelState, bits_per_entry, topk_budget
from repro.core.distill import kl_divergence
from repro.core.protocol import (
    CommLedger,
    PayloadSpec,
    RoundStats,
    UplinkPayload,
    wire_uplink_bits,
)
from repro.core.topk import (
    densify,
    sparsify_wire,
    topk_mask_batch,
    topk_mask_dense,
    topk_mask_dynamic,
    topk_sparsify,
    wire_densify,
    wire_support,
)

SETTINGS = settings(max_examples=30, deadline=None)


def _distinct_logits(rows: int, vocab: int, seed: int) -> jax.Array:
    """Rows of pairwise-distinct values (a scaled random permutation), so
    static top-k and the threshold-semantics dynamic mask agree exactly
    (ties are the only divergence point and are measure-zero for real
    logits)."""
    key = jax.random.PRNGKey(seed)
    perms = jnp.stack(
        [jax.random.permutation(jax.random.fold_in(key, r), vocab) for r in range(rows)]
    )
    offset = jax.random.normal(jax.random.fold_in(key, 10_000), (rows, 1))
    return perms.astype(jnp.float32) * 0.37 + offset


@given(
    bandwidth=st.floats(1e3, 1e9),
    snr_db=st.floats(-20, 40),
    eta=st.floats(0.01, 1.0),
    deadline=st.floats(0.01, 10.0),
    vocab=st.integers(2, 300_000),
    samples=st.integers(1, 5000),
    rank=st.one_of(st.none(), st.integers(1, 64)),
)
@SETTINGS
def test_topk_payload_respects_shannon_budget(
    bandwidth, snr_db, eta, deadline, vocab, samples, rank
):
    """INVARIANT (paper §III-A + §III-C): the REALIZED adaptive payload —
    LoRA projection included when ``rank`` is set (the ``adald`` method) —
    never exceeds the channel's bit budget, except via the k_min survival
    floor.  ``topk_budget(reserved_bits=...)`` must reserve the projection
    out of the budget before counting (value, index) entries."""
    state = ChannelState(bandwidth, snr_db, eta, deadline)
    reserved = samples * rank * 16 if rank is not None else 0
    k = topk_budget(
        state, vocab_size=vocab, num_samples=samples, reserved_bits=reserved
    )
    spec = PayloadSpec(num_samples=samples, vocab=vocab, k=k, lora_rank=rank)
    # the survival floor's payload is ONE entry per sample (plus projection)
    floor_bits = samples * 1 * bits_per_entry(16, vocab) + reserved
    assert spec.uplink_bits <= max(state.bit_budget, floor_bits) + 1e-6
    # without the floor, every transmitted payload fits by construction
    k0 = topk_budget(
        state, vocab_size=vocab, num_samples=samples, k_min=0,
        reserved_bits=reserved,
    )
    if k0 > 0:
        spec0 = PayloadSpec(num_samples=samples, vocab=vocab, k=k0, lora_rank=rank)
        assert spec0.fits(state)


@given(
    n=st.integers(1, 8),
    rows=st.integers(1, 4),
    vocab=st.integers(4, 128),
    keep=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**30),
)
@SETTINGS
def test_adaptive_aggregation_convexity(n, rows, vocab, keep, seed):
    """INVARIANT (eqs. 6-7): per dim, output is a convex combination of the
    transmitting clients' values; untouched dims stay exactly zero."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, rows, vocab))
    mask = jax.random.uniform(jax.random.fold_in(key, 1), x.shape) < keep
    stack = jnp.where(mask, x, 0.0)
    out = aggregate_adaptive(stack)
    transmitted = stack != 0
    touched = transmitted.any(axis=0)
    lo = jnp.where(transmitted, stack, jnp.inf).min(axis=0)
    hi = jnp.where(transmitted, stack, -jnp.inf).max(axis=0)
    assert bool(jnp.all(jnp.where(touched, (out >= lo - 1e-4) & (out <= hi + 1e-4), out == 0)))


@given(
    rows=st.integers(1, 4),
    vocab=st.integers(8, 256),
    k=st.integers(1, 64),
    seed=st.integers(0, 2**30),
)
@SETTINGS
def test_sparsify_preserves_topk_and_is_idempotent(rows, vocab, k, seed):
    k = min(k, vocab)
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, vocab)) + 10.0
    d = densify(topk_sparsify(x, k))
    # exactly k nonzeros per row (values are strictly positive)
    assert int(jnp.sum(d != 0)) == rows * k
    d2 = densify(topk_sparsify(d, k))
    np.testing.assert_allclose(d, d2, atol=0)


@given(
    rows=st.integers(1, 4),
    vocab=st.integers(2, 128),
    temp=st.floats(0.5, 10.0),
    seed=st.integers(0, 2**30),
)
@SETTINGS
def test_kl_nonnegative_property(rows, vocab, temp, seed):
    key = jax.random.PRNGKey(seed)
    t = jax.random.normal(key, (rows, vocab)) * 5
    s = jax.random.normal(jax.random.fold_in(key, 1), (rows, vocab)) * 5
    assert float(kl_divergence(t, s, temp)) >= -1e-5


# ---- fused-path round-trip invariants -------------------------------------


@given(
    n=st.integers(1, 5),
    vocab=st.integers(8, 96),
    seed=st.integers(0, 2**30),
    data=st.data(),
)
@SETTINGS
def test_dynamic_topk_equals_dense_reference_per_client(n, vocab, seed, data):
    """INVARIANT (fused engine): the traced-k sparsifier applied per client
    (k == 0 dropout included) equals both the static per-client reference
    and the batched k_max path, on distinct-valued rows."""
    ks = data.draw(st.lists(st.integers(0, vocab), min_size=n, max_size=n))
    logits = jnp.stack([_distinct_logits(3, vocab, seed + i) for i in range(n)])
    got = jnp.stack(
        [topk_mask_dynamic(logits[i], jnp.int32(k)) for i, k in enumerate(ks)]
    )
    want = jnp.stack(
        [
            topk_mask_dense(logits[i], k) if k > 0 else jnp.zeros_like(logits[i])
            for i, k in enumerate(ks)
        ]
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)
    np.testing.assert_allclose(
        np.asarray(topk_mask_batch(logits, ks)), np.asarray(want), atol=0
    )


@given(
    n=st.integers(1, 5),
    vocab=st.integers(8, 96),
    seed=st.integers(0, 2**30),
    mode=st.sampled_from(["adaptive", "zeropad"]),
    data=st.data(),
)
@SETTINGS
def test_sparse_aggregation_of_transmitters_matches_dense(n, vocab, seed, mode, data):
    """INVARIANT (round pipeline): aggregating only the k > 0 transmitters of
    the batched top-k equals aggregating the per-client densified uploads —
    dropped stragglers never enter the stack."""
    ks = data.draw(st.lists(st.integers(0, vocab), min_size=n, max_size=n))
    logits = jnp.stack([_distinct_logits(2, vocab, seed + 7 * i) for i in range(n)])
    dense_all = topk_mask_batch(logits, ks)
    active = [i for i, k in enumerate(ks) if k > 0]
    if not active:
        assert float(jnp.sum(jnp.abs(dense_all))) == 0.0
        return
    got = aggregate(dense_all[jnp.asarray(active)], mode)
    want = aggregate(
        jnp.stack([densify(topk_sparsify(logits[i], ks[i])) for i in active]), mode
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-7)


@given(
    n=st.integers(1, 6),
    vocab=st.integers(4, 50_000),
    samples=st.integers(1, 512),
    rank=st.one_of(st.none(), st.integers(1, 16)),
    value_bits=st.sampled_from([8, 16, 32]),
    data=st.data(),
)
@SETTINGS
def test_uplink_byte_accounting_matches_ledger(n, vocab, samples, rank, value_bits, data):
    """INVARIANT (§III-C): the ledger total equals the closed-form bit cost
    of the k > 0 payloads — k == 0 stragglers contribute exactly nothing."""
    ks = data.draw(st.lists(st.integers(0, vocab), min_size=n, max_size=n))
    payloads = [
        UplinkPayload(
            client_id=i,
            spec=PayloadSpec(
                num_samples=samples, vocab=vocab, k=k,
                lora_rank=rank, value_bits=value_bits,
            ),
        )
        for i, k in enumerate(ks)
        if k > 0
    ]
    ledger = CommLedger()
    ledger.record(
        RoundStats(round_index=0, uplink_bytes=sum(p.bytes for p in payloads))
    )
    d = bits_per_entry(value_bits, vocab)
    h_bits = samples * rank * value_bits if rank is not None else 0
    expect_bits = sum(samples * k * d + h_bits for k in ks if k > 0)
    assert ledger.uplink_mb * 1e6 == pytest.approx(expect_bits / 8.0)
    assert ledger.rounds[0].total_bytes == pytest.approx(expect_bits / 8.0)
    # the sparse wire's cohort accounting (k_cap padding is free) must agree
    # with the manifests' logit term exactly
    n_h = sum(1 for k in ks if k > 0)
    assert wire_uplink_bits(samples, ks, vocab, value_bits) == expect_bits - n_h * h_bits


@given(
    n=st.integers(1, 5),
    rows=st.integers(1, 3),
    vocab=st.integers(8, 96),
    mode=st.sampled_from(["adaptive", "zeropad", "mean_nonzero"]),
    tie_levels=st.integers(2, 6),
    seed=st.integers(0, 2**30),
    data=st.data(),
)
@SETTINGS
def test_wire_aggregation_matches_masked_dense(
    n, rows, vocab, mode, tie_levels, seed, data
):
    """INVARIANT (PR-3 sparse uplink): aggregating straight from the
    (values, indices, mask) wire equals the dense-stack oracle fed the SAME
    explicit transmit mask, in all three modes — on deliberately hostile
    inputs: heavy ties (few distinct levels), transmitted TRUE-ZERO logits,
    and random per-client budgets including k = 0 stragglers.  (The Pallas
    scatter kernel route is pinned separately at fixed shapes in
    tests/test_kernel_parity.py — per-example interpret-mode compiles are
    too slow for a property sweep.)"""
    ks = data.draw(st.lists(st.integers(0, vocab), min_size=n, max_size=n))
    key = jax.random.PRNGKey(seed)
    # few distinct integer levels spanning zero -> many exact ties AND
    # selected entries whose transmitted value is exactly 0.0
    levels = jax.random.randint(key, (n, rows, vocab), -1, tie_levels - 1)
    logits = levels.astype(jnp.float32)
    k_cap = max(max(ks), 1)
    wire = sparsify_wire(logits, jnp.asarray(ks, jnp.int32), k_cap)

    got = aggregate_wire(wire, mode)

    dense = wire_densify(wire)
    support = wire_support(wire)
    active = [i for i, k in enumerate(ks) if k > 0]
    if not active:
        assert float(jnp.sum(jnp.abs(got))) == 0.0
        return
    take = jnp.asarray(active)
    want = aggregate(dense[take], mode, mask=support[take])
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
    )


@given(
    n=st.integers(2, 6),
    vocab=st.integers(4, 64),
    seed=st.integers(0, 2**30),
)
@SETTINGS
def test_aggregation_modes_agree_on_dense_stacks(n, vocab, seed):
    """With NO sparsity, adaptive and zeropad agree when all values are equal
    (degenerate case), and both return finite values generally."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 2, vocab))
    assert bool(jnp.all(jnp.isfinite(aggregate_adaptive(x))))
    assert bool(jnp.all(jnp.isfinite(aggregate_zeropad(x))))
    same = jnp.broadcast_to(x[0], x.shape)
    np.testing.assert_allclose(
        aggregate_adaptive(same), aggregate_zeropad(same), rtol=1e-4, atol=1e-5
    )


# ---- PR 6: quantized wire + budget-floor correctness -----------------------


@given(
    bandwidth=st.floats(1e2, 1e9),
    snr_db=st.floats(-20, 40),
    eta=st.floats(0.01, 1.0),
    deadline=st.floats(0.01, 10.0),
    vocab=st.integers(2, 300_000),
    samples=st.integers(1, 5000),
    rank=st.integers(1, 64),
)
@SETTINGS
def test_reserved_payload_fits_by_construction_at_k_min_one(
    bandwidth, snr_db, eta, deadline, vocab, samples, rank
):
    """INVARIANT (PR-6 budget fix): with a projection reservation, EVERY
    transmitted payload fits the Shannon budget — even at ``k_min == 1``.
    The survival floor never manufactures an unfittable payload; when the
    reservation cannot ride the link, the round is dropped (k == 0)."""
    state = ChannelState(bandwidth, snr_db, eta, deadline)
    reserved = samples * rank * 16
    k = topk_budget(
        state, vocab_size=vocab, num_samples=samples, k_min=1,
        reserved_bits=reserved,
    )
    if k > 0:
        spec = PayloadSpec(num_samples=samples, vocab=vocab, k=k, lora_rank=rank)
        assert spec.fits(state)
    else:
        # dropped: even the k = 1 floor payload would not have fit
        floor = PayloadSpec(num_samples=samples, vocab=vocab, k=1, lora_rank=rank)
        assert not floor.fits(state)


@given(
    n=st.integers(1, 5),
    rows=st.integers(1, 3),
    vocab=st.integers(8, 96),
    scale_pow=st.integers(-20, 20),
    seed=st.integers(0, 2**30),
    data=st.data(),
)
@SETTINGS
def test_quantize_wire_roundtrip_properties(n, rows, vocab, scale_pow, seed, data):
    """INVARIANTS (PR-6 quantized wire): for any budgets (k = 0 stragglers
    included) and logit magnitudes across 40 binary orders of magnitude —
    the scale is strictly positive, dequantization is NaN-free, straggler
    rows round-trip to exact zeros, and the per-entry error is bounded by
    one quantization step (amax/127) per row."""
    from repro.core.topk import QUANT_LEVELS, dequantize_wire

    ks = data.draw(st.lists(st.integers(0, vocab), min_size=n, max_size=n))
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, rows, vocab))
    x = x * (2.0 ** scale_pow)
    k_cap = max(max(ks), 1)
    w = sparsify_wire(x, jnp.asarray(ks, jnp.int32), k_cap)
    q = sparsify_wire(x, jnp.asarray(ks, jnp.int32), k_cap, quantize=True)

    assert bool(jnp.all(q.scale > 0))
    back = dequantize_wire(q)
    assert bool(jnp.all(jnp.isfinite(back.values)))
    # error bound per row: one step of the symmetric int8 code
    amax = jnp.max(jnp.abs(jnp.where(w.mask, w.values, 0.0)), axis=-1)
    err = jnp.max(jnp.abs(back.values - jnp.where(w.mask, w.values, 0.0)), axis=-1)
    assert bool(jnp.all(err <= amax / QUANT_LEVELS + 1e-30))
    # straggler rows: all-masked -> exact zeros and unit scale
    for i, k in enumerate(ks):
        if k == 0:
            assert float(jnp.sum(jnp.abs(back.values[i]))) == 0.0
            np.testing.assert_array_equal(np.asarray(q.scale[i]), 1.0)


@given(
    n=st.integers(1, 5),
    rows=st.integers(1, 3),
    vocab=st.integers(8, 96),
    mode=st.sampled_from(["adaptive", "zeropad", "mean_nonzero"]),
    seed=st.integers(0, 2**30),
    data=st.data(),
)
@SETTINGS
def test_quantized_aggregate_wire_close_to_float(n, rows, vocab, mode, seed, data):
    """INVARIANT (PR-6): aggregating the int8 wire lands within quantization
    tolerance of aggregating the float wire, in all three modes.  The
    loosened tolerance is the documented quant parity bound: aggregation is
    convex in the client values (adaptive re-weights by |v|, hence the
    softer relative bound), and each value moves at most amax/127."""
    ks = data.draw(st.lists(st.integers(0, vocab), min_size=n, max_size=n))
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, rows, vocab)) * 5.0
    k_cap = max(max(ks), 1)
    w = sparsify_wire(x, jnp.asarray(ks, jnp.int32), k_cap)
    q = sparsify_wire(x, jnp.asarray(ks, jnp.int32), k_cap, quantize=True)

    got = aggregate_wire(q, mode)
    want = aggregate_wire(w, mode)
    assert bool(jnp.all(jnp.isfinite(got)))
    step = float(jnp.max(jnp.abs(x))) / 127.0
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=2.5 * step + 1e-6,
        rtol=0.05,
    )
