from repro.checkpoint.ckpt import (
    fleet_shard_dir,
    fleet_shard_name,
    latest_step,
    list_fleet_shards,
    restore,
    restore_step,
    save,
    save_step,
    step_metadata,
)

__all__ = [
    "fleet_shard_dir",
    "fleet_shard_name",
    "latest_step",
    "list_fleet_shards",
    "restore",
    "restore_step",
    "save",
    "save_step",
    "step_metadata",
]
