"""Distillation losses (paper §III-B, eqs. 9-10).

    L_logits = mean_x KL( softmax(K_g(x)/T) || softmax(K_n(x)/T) )   (eq. 9)
    L_h      = KL over the LoRA projection h = A·x                    (eq. 8)
    L_total  = L_logits + λ · L_h                                     (eq. 10)

Teacher distribution comes first in the KL (forward KL: teacher || student),
matching eq. 9 where K_g is the aggregated global (teacher) knowledge and
K̄_n the local model's logits.  Temperature T defaults to the paper's 2.0;
λ to the paper's tuned 0.03 (favorable range reported: [0.03, 0.5]).

The large-vocab logits KL is memory-bound (three passes over a
(batch, 50k-256k) tensor); :mod:`repro.kernels.distill_kl` provides a fused
one-pass Pallas implementation with online logsumexp (``use_kernel=True``).

Support-restricted softmax: when the teacher vector is sparse (union of
client top-ks), the paper softmaxes the densified vector directly — zeros
off-support receive exp(0) mass.  We implement that faithfully as the
default and expose ``restrict_to_support=True`` as a beyond-paper option
that renormalises over the transmitted support only (masking zeros to -inf),
which removes the artificial uniform mass; its effect is measured in the
benchmarks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "kl_divergence",
    "teacher_log_probs",
    "kl_divergence_from_log_probs",
    "logits_distill_loss",
    "lora_projection_loss",
    "total_distill_loss",
    "soft_labels",
]

DEFAULT_TEMPERATURE = 2.0
DEFAULT_LAMBDA = 0.03


def _log_softmax(x: jax.Array, axis: int = -1) -> jax.Array:
    return x - jax.scipy.special.logsumexp(x, axis=axis, keepdims=True)


def soft_labels(logits: jax.Array, temperature: float = DEFAULT_TEMPERATURE) -> jax.Array:
    """Global soft-label distribution σ(K/T) (paper §II-B)."""
    return jax.nn.softmax(logits / temperature, axis=-1)


def kl_divergence(
    teacher_logits: jax.Array,
    student_logits: jax.Array,
    temperature: float = DEFAULT_TEMPERATURE,
    *,
    mask: jax.Array | None = None,
    scale_by_t2: bool = True,
) -> jax.Array:
    """KL(σ(t/T) || σ(s/T)), mean over all leading (batch) axes.

    ``mask``: optional boolean (..., vocab) support mask; masked-out entries
    are excluded from *both* distributions (support-restricted variant).
    ``scale_by_t2`` multiplies by T² (Hinton et al. 2015 gradient-scale
    correction) so λ stays comparable across temperatures.
    """
    t = teacher_logits / temperature
    s = student_logits / temperature
    if mask is not None:
        neg = jnp.asarray(-1e30, dtype=t.dtype)
        t = jnp.where(mask, t, neg)
        s = jnp.where(mask, s, neg)
    log_p = _log_softmax(t)
    log_q = _log_softmax(s)
    p = jnp.exp(log_p)
    per_row = jnp.sum(p * (log_p - log_q), axis=-1)
    kl = jnp.mean(per_row)
    if scale_by_t2:
        kl = kl * (temperature**2)
    return kl


def teacher_log_probs(
    logits: jax.Array,
    temperature: float = DEFAULT_TEMPERATURE,
    *,
    mask: jax.Array | None = None,
) -> jax.Array:
    """Precompute the TEACHER side of eq. 9, ``log σ(t/T)``.

    Within one round the teacher (the broadcast K_g for the clients, the
    aggregated K_g for the server) is a constant: recomputing its softmax
    inside every client's vmapped loss and every distill step is pure waste
    — the fused-e2e round computes it ONCE and reuses it across the whole
    cohort and every server step.  Bit-identical to the log-softmax
    :func:`kl_divergence` performs internally on the same inputs.
    """
    t = logits / temperature
    if mask is not None:
        t = jnp.where(mask, t, jnp.asarray(-1e30, dtype=t.dtype))
    return _log_softmax(t)


def kl_divergence_from_log_probs(
    teacher_log_p: jax.Array,
    student_logits: jax.Array,
    temperature: float = DEFAULT_TEMPERATURE,
    *,
    mask: jax.Array | None = None,
    scale_by_t2: bool = True,
) -> jax.Array:
    """:func:`kl_divergence` with the teacher distribution precomputed by
    :func:`teacher_log_probs` (same ``mask``/``temperature``); identical
    math on the student side, so the two agree bit-for-bit."""
    s = student_logits / temperature
    if mask is not None:
        s = jnp.where(mask, s, jnp.asarray(-1e30, dtype=s.dtype))
    log_q = _log_softmax(s)
    p = jnp.exp(teacher_log_p)
    per_row = jnp.sum(p * (teacher_log_p - log_q), axis=-1)
    kl = jnp.mean(per_row)
    if scale_by_t2:
        kl = kl * (temperature**2)
    return kl


def logits_distill_loss(
    global_logits: jax.Array,
    client_logits: jax.Array,
    temperature: float = DEFAULT_TEMPERATURE,
    *,
    restrict_to_support: bool = False,
    use_kernel: bool = False,
) -> jax.Array:
    """Paper eq. 9 over a public batch: ``(num_samples, vocab)`` inputs."""
    if use_kernel and not restrict_to_support:
        from repro.kernels import ops as kops

        return kops.distill_kl(global_logits, client_logits, temperature)
    mask = (global_logits != 0) if restrict_to_support else None
    return kl_divergence(global_logits, client_logits, temperature, mask=mask)


def lora_projection_loss(
    global_h: jax.Array,
    client_h: jax.Array,
    temperature: float = DEFAULT_TEMPERATURE,
) -> jax.Array:
    """Paper §III-B: KL between softmaxed LoRA projections h = A·x ∈ R^r.

    The paper treats the r-dim projection as a distribution after softmax
    and reuses eq. 9.  r is tiny (8) so no kernel is needed.
    """
    return kl_divergence(global_h, client_h, temperature)


def total_distill_loss(
    global_logits: jax.Array,
    client_logits: jax.Array,
    global_h: jax.Array | None = None,
    client_h: jax.Array | None = None,
    *,
    temperature: float = DEFAULT_TEMPERATURE,
    lam: float = DEFAULT_LAMBDA,
    restrict_to_support: bool = False,
    use_kernel: bool = False,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Paper eq. 10: ``L_total = L_logits + λ·L_h``.

    Returns (loss, aux dict with the two components).  When either projection
    is None the λ-term is dropped (the paper's "Adaptive" baseline).
    """
    l_logits = logits_distill_loss(
        global_logits,
        client_logits,
        temperature,
        restrict_to_support=restrict_to_support,
        use_kernel=use_kernel,
    )
    if global_h is None or client_h is None:
        zero = jnp.zeros((), dtype=l_logits.dtype)
        return l_logits, {"logits": l_logits, "lora": zero}
    l_h = lora_projection_loss(global_h, client_h, temperature)
    total = l_logits + lam * l_h
    return total, {"logits": l_logits, "lora": l_h}
