"""Server-side logit aggregation schemes (paper §III-A, eqs. 6-7).

Given N clients' sparse logit uploads (densified: zeros off-support), the
paper's *adaptive* aggregation weights each client's contribution per
dimension by its confidence share:

    s_{n,c}   = |K̃_{n,c}(x)|                     (confidence score)
    S[c]      = Σ_n s_{n,c}
    w_{n,c}   = s_{n,c} / S[c]                    (eq. 6)
    K_{g,c}   = Σ_n w_{n,c} * K̃_{n,c}(x)         (eq. 7)

Only clients that actually transmitted dimension c contribute, so the
zero-padding bias of naive averaging disappears.  Baselines implemented for
the paper's comparison: ``zeropad`` (mean over all N including zeros — the
paper's "ZeroPad"), and ``mean_nonzero`` (mean over transmitting clients
only; an ablation between ZeroPad and Adaptive).

Shapes: ``stack`` is ``(N, ..., vocab)`` — leading client axis, then any
batch shape, vocab last.  All functions are jit/pjit friendly; the fused
single-HBM-pass version lives in :mod:`repro.kernels.sparse_agg`.
"""

from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

__all__ = [
    "aggregate_adaptive",
    "aggregate_zeropad",
    "aggregate_mean_nonzero",
    "aggregate",
    "aggregate_sparse",
]

_EPS = 1e-12


def aggregate_adaptive(stack: jax.Array, *, eps: float = _EPS) -> jax.Array:
    """Paper eqs. 6-7: dimension-wise confidence-weighted aggregation.

    Dimensions no client transmitted stay exactly 0.
    """
    s = jnp.abs(stack)  # (N, ..., V) confidence scores
    total = jnp.sum(s, axis=0)  # (..., V) S[c]
    w = s / (total[None] + eps)  # (N, ..., V) w_{n,c}
    return jnp.sum(w * stack, axis=0)


def aggregate_zeropad(stack: jax.Array) -> jax.Array:
    """Paper's 'ZeroPad' baseline: plain mean including zero padding."""
    return jnp.mean(stack, axis=0)


def aggregate_mean_nonzero(stack: jax.Array, *, eps: float = _EPS) -> jax.Array:
    """Mean over transmitting clients only (uniform, support-aware)."""
    mask = (stack != 0).astype(stack.dtype)
    count = jnp.sum(mask, axis=0)
    return jnp.sum(stack, axis=0) / (count + eps)


AggregationMode = Literal["adaptive", "zeropad", "mean_nonzero"]


def aggregate(stack: jax.Array, mode: AggregationMode = "adaptive", *, use_kernel: bool = False) -> jax.Array:
    """Dispatch on aggregation mode; ``use_kernel`` routes the adaptive path
    through the fused Pallas kernel."""
    if mode == "adaptive":
        if use_kernel:
            from repro.kernels import ops as kops

            return kops.sparse_aggregate(stack)
        return aggregate_adaptive(stack)
    if mode == "zeropad":
        return aggregate_zeropad(stack)
    if mode == "mean_nonzero":
        return aggregate_mean_nonzero(stack)
    raise ValueError(f"unknown aggregation mode: {mode!r}")


def aggregate_sparse(
    values: jax.Array,
    indices: jax.Array,
    vocab: int,
    mode: AggregationMode = "adaptive",
    *,
    eps: float = _EPS,
) -> jax.Array:
    """Aggregate directly from sparse (value, index) payloads without first
    densifying each client — O(N*k) scatter instead of O(N*V) memory.

    values/indices: ``(N, ..., k)``.  This is what the server actually does
    on-device: scatter-add the weighted values and the confidence mass.
    """
    n_clients = values.shape[0]
    batch_shape = values.shape[1:-1]
    k = values.shape[-1]

    flat_vals = values.reshape((n_clients, -1, k))
    flat_idx = indices.reshape((n_clients, -1, k))
    rows = flat_vals.shape[1]

    def per_row(vals_nk, idx_nk):
        # vals_nk, idx_nk: (N, k) for one (sample) row.
        sum_sv = jnp.zeros((vocab,), dtype=vals_nk.dtype)  # Σ s*K = Σ |K|*K
        sum_s = jnp.zeros((vocab,), dtype=vals_nk.dtype)  # Σ |K|
        sum_k = jnp.zeros((vocab,), dtype=vals_nk.dtype)  # Σ K (for baselines)
        cnt = jnp.zeros((vocab,), dtype=vals_nk.dtype)

        def body(n, carry):
            sum_sv, sum_s, sum_k, cnt = carry
            v = vals_nk[n]
            i = idx_nk[n]
            s = jnp.abs(v)
            sum_sv = sum_sv.at[i].add(s * v)
            sum_s = sum_s.at[i].add(s)
            sum_k = sum_k.at[i].add(v)
            cnt = cnt.at[i].add(jnp.ones_like(v))
            return sum_sv, sum_s, sum_k, cnt

        sum_sv, sum_s, sum_k, cnt = jax.lax.fori_loop(
            0, n_clients, body, (sum_sv, sum_s, sum_k, cnt)
        )
        if mode == "adaptive":
            return sum_sv / (sum_s + eps)
        if mode == "zeropad":
            return sum_k / float(n_clients)
        return sum_k / (cnt + eps)

    out = jax.vmap(per_row, in_axes=(1, 1))(flat_vals, flat_idx)  # (rows, vocab)
    del rows  # rows == prod(batch_shape); reshape below restores it
    return out.reshape(batch_shape + (vocab,))
