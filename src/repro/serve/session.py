"""ServeSession: the public serving API (ROADMAP "Personalized-adapter
serving at fleet scale").

One session = one shared frozen backbone + one decode cache + (optionally)
an :class:`repro.serve.AdapterCache` of tenant adapters.  The redesign
replaces the hand-rolled ``make_serve_step`` loops in ``launch/serve.py``
and ``launch/dryrun.py`` (kept importable via shims):

    cfg = ServeConfig(model=model_cfg, batch=8, slots=8)
    sess = ServeSession(cfg, params, adapters=cache)
    sess.attach([17, 3, 3, 99, ...])      # tenant id per request
    sess.prefill(prompts)                  # (B, L) int32
    tokens = sess.decode(32)               # (B, 32) greedy/sampled
    sess.stats()                           # cache hits/misses, timing, ...

Compilation contract: a session compiles at most TWO decode executables —
the single-adapter step (detached mode) and the stacked multi-tenant step
(attached mode).  Tenant mix, slot assignment, and token values are all
traced data; prefill teacher-forces the prompt through the SAME decode
executable, so serving any number of tenants costs one compile.  Both
steps donate the decode cache (in-place ring-buffer update).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.lora import split_lora
from repro.models import init_cache
from repro.models.frontends import synth_frontend_embeddings
from repro.models.model import _run_encoder
from repro.serve.cache import AdapterCache
from repro.serve.steps import make_decode_step, make_stacked_decode_step

__all__ = ["ServeConfig", "ServeSession"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Frozen serving knobs (hashable; safe to close jits over)."""

    model: ModelConfig
    batch: int = 4  # requests per decode step
    cache_len: int = 128  # decode-cache capacity (prompt + generated)
    temperature: float = 0.0  # 0 = greedy
    window: int | None = None  # sliding-window override (None = cfg default)
    seed: int = 0  # sampling PRNG seed


class ServeSession:
    """Stateful serving loop over pure jitted steps (see module docstring)."""

    def __init__(
        self,
        cfg: ServeConfig,
        params: Any,
        *,
        adapters: AdapterCache | None = None,
    ):
        self.cfg = cfg
        self.params = params
        self.adapters = adapters
        self._lora, self._frozen = split_lora(params)
        mc = cfg.model
        self._single = jax.jit(
            make_decode_step(mc, window=cfg.window), donate_argnums=(1,)
        )
        self._stacked = jax.jit(
            make_stacked_decode_step(mc, window=cfg.window), donate_argnums=(3,)
        )
        self._slot_idx: jax.Array | None = None  # (B,) int32 when attached
        self._cache: dict | None = None
        self._logits: jax.Array | None = None
        self._key = jax.random.PRNGKey(cfg.seed)
        self.tokens_decoded = 0
        # per-executable first-call (compile) wall time + steady accumulators
        self._first_s: dict[str, float] = {}
        self._steady_s = 0.0
        self._steady_steps = 0

    # -- adapter attach / evict -----------------------------------------
    def attach(self, adapter_ids: Sequence[int], *, reset: bool = True) -> np.ndarray:
        """Bind tenant ``adapter_ids[b]`` to request b (len == batch),
        paging misses through the AdapterCache.  Resets the decode cache by
        default — new tenants mean new requests.  Returns the slot map."""
        if self.adapters is None:
            raise ValueError(
                "ServeSession was built without an AdapterCache — pass "
                "adapters= to serve per-request tenants"
            )
        if len(adapter_ids) != self.cfg.batch:
            raise ValueError(
                f"got {len(adapter_ids)} adapter ids for batch {self.cfg.batch}"
            )
        slots = self.adapters.lookup(adapter_ids)
        self._slot_idx = jnp.asarray(slots, jnp.int32)
        if reset:
            self.reset()
        return slots

    def detach(self) -> None:
        """Back to single-adapter mode (the session's own ``params``)."""
        self._slot_idx = None

    @property
    def attached(self) -> bool:
        return self._slot_idx is not None

    # -- decode-cache lifecycle -----------------------------------------
    def reset(self, *, frontend: jax.Array | None = None) -> None:
        """Fresh decode cache (and encoder pass for audio families)."""
        mc = self.cfg.model
        enc_out = None
        if mc.family == "audio":
            if frontend is None:
                frontend = synth_frontend_embeddings(mc, self.cfg.batch)
            enc_out = _run_encoder(self.params, mc, frontend)
        self._cache = init_cache(
            mc, self.cfg.batch, self.cfg.cache_len,
            window=self.cfg.window, enc_out=enc_out,
        )
        self._logits = None

    # -- the one decode step --------------------------------------------
    def _timed(self, name: str, fn, *args):
        t0 = time.perf_counter()
        logits, cache = fn(*args)
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        if name not in self._first_s:
            self._first_s[name] = dt  # compile + first run
        else:
            self._steady_s += dt
            self._steady_steps += 1
        return logits, cache

    def step(self, tokens) -> jax.Array:
        """Feed one token per request, return next-token logits (B, V)."""
        if self._cache is None:
            self.reset()
        tok = jnp.asarray(tokens, jnp.int32)
        if self._slot_idx is not None:
            self._logits, self._cache = self._timed(
                "stacked", self._stacked,
                self._frozen, self.adapters.slab, self._slot_idx,
                self._cache, tok,
            )
        else:
            self._logits, self._cache = self._timed(
                "single", self._single, self.params, self._cache, tok
            )
        return self._logits

    # -- serving loops ---------------------------------------------------
    def prefill(self, prompts) -> jax.Array:
        """Teacher-force ``prompts (B, L) int32`` through the decode step
        (resetting the cache first); returns last-position logits (B, V).
        Smoke-scale prefill — the production full-sequence prefill shapes
        are proven by the dry-run (``make_prefill_step``)."""
        prompts = np.asarray(prompts)
        self.reset()
        for t in range(prompts.shape[1]):
            logits = self.step(prompts[:, t])
        return logits

    def decode(self, num_tokens: int, *, temperature: float | None = None):
        """Generate ``num_tokens`` per request from the current state.
        Returns ``(tokens (B, num_tokens) np.int32, last logits)``."""
        if self._logits is None:
            raise RuntimeError("decode() before prefill()/step() — no logits yet")
        temp = self.cfg.temperature if temperature is None else temperature
        out = []
        logits = self._logits
        for _ in range(num_tokens):
            if temp > 0:
                self._key, sub = jax.random.split(self._key)
                nxt = jax.random.categorical(sub, logits / temp, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            out.append(np.asarray(nxt))
            logits = self.step(nxt)
        self.tokens_decoded += num_tokens * self.cfg.batch
        return np.stack(out, axis=1).astype(np.int32), logits

    # -- stats taps ------------------------------------------------------
    def executables(self) -> dict:
        """Compiled decode-executable count per mode (the 'one donated
        decode step' invariant: stays at 1 per mode across tenant mixes)."""
        out = {}
        for name, fn in (("single", self._single), ("stacked", self._stacked)):
            size = getattr(fn, "_cache_size", None)
            out[name] = int(size()) if callable(size) else -1
        return out

    def stats(self) -> dict:
        steady = (
            self._steady_s / self._steady_steps if self._steady_steps else 0.0
        )
        s = {
            "tokens_decoded": self.tokens_decoded,
            "first_step_s": dict(self._first_s),
            "steady_step_s": steady,
            "steady_steps": self._steady_steps,
            "executables": self.executables(),
            "attached": self.attached,
        }
        if self.adapters is not None:
            s["adapter_cache"] = self.adapters.stats.as_dict()
            s["adapter_slots"] = self.adapters.slots
            s["resident_adapters"] = list(self.adapters.resident())
        return s
