"""Stub modality frontends (per assignment carve-out).

``[audio]`` and ``[vlm]`` architectures specify the transformer backbone
only; the mel-spectrogram/conv feature extractor (audio) and the
ViT/projector (vision) are STUBS: ``input_specs()`` supplies precomputed
frame/patch embeddings of the right shape, and for runnable CPU smoke tests
this module synthesises deterministic embeddings with the correct statistics
(zero-mean, unit-ish variance, d_model width).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

__all__ = ["frontend_embedding_shape", "synth_frontend_embeddings"]


def frontend_embedding_shape(cfg: ModelConfig, batch: int) -> tuple[int, int, int]:
    """(B, frontend_len, d_model) for the stubbed modality stream."""
    assert cfg.frontend != "none"
    return (batch, cfg.frontend_len, cfg.d_model)


def synth_frontend_embeddings(
    cfg: ModelConfig, batch: int, *, seed: int = 0, dtype: str | None = None
) -> jax.Array:
    """Deterministic stand-in embeddings (what the real ViT/codec would emit)."""
    shape = frontend_embedding_shape(cfg, batch)
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(jnp.dtype(dtype or cfg.compute_dtype))
