"""Bench-regression gate (blocking CI step).

The whole-round benchmark used to be informational-only, which let its two
committed guarantees rot silently: the sparse aggregation path staying
dense-stack-free, and the one-call e2e round staying faster than the split
host pipeline.  This gate re-checks a FRESH quick bench record against the
committed full record and fails loudly on:

1. ``aggregation.agg_dense_stack_free`` false — the trace-inspection proof
   that no intermediate reaches the (N, B, V) dense stack regressed;
2. ``speedups.e2e_vs_fused_host`` below a floor — committed record says
   1.36x on this repo's reference box; the default floor 1.10x leaves a
   generous CI-noise margin while still catching a real regression to <= 1x;
3. ``aggregation.sparse_wire_bytes`` above the committed record's — the wire
   format's on-air shape grew (k_cap bucketing or layout regressed).  The
   wire bytes are deterministic for the bench's seeded channel, so this is
   an equality-shaped check: a legitimate format change must refresh the
   committed BENCH_round.json in the same PR.

Run (CI does exactly this):

    python benchmarks/engine_bench.py --quick --round-only
    python benchmarks/check_bench.py

Pure stdlib; exits non-zero with a one-line reason per failed check.
"""

from __future__ import annotations

import argparse
import json
import os

_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")


def check(fresh: dict, committed: dict, *, min_speedup: float) -> list[str]:
    """Returns a list of human-readable failures (empty = gate passes)."""
    failures = []

    agg = fresh.get("aggregation", {})
    if agg.get("agg_dense_stack_free") is not True:
        failures.append(
            "agg_dense_stack_free is not true: the sparse aggregation path "
            "materialised an (N, B, V)-sized intermediate "
            f"(max_agg_intermediate_elems={agg.get('max_agg_intermediate_elems')}, "
            f"dense_stack_elems={agg.get('dense_stack_elems')})"
        )

    speedup = fresh.get("speedups", {}).get("e2e_vs_fused_host")
    if speedup is None:
        failures.append("fresh record has no speedups.e2e_vs_fused_host")
    elif speedup < min_speedup:
        committed_speedup = committed.get("speedups", {}).get("e2e_vs_fused_host")
        failures.append(
            f"e2e_vs_fused_host speedup {speedup:.2f}x fell below the gate "
            f"floor {min_speedup:.2f}x (committed record: "
            f"{committed_speedup}x) — the one-call round regressed vs the "
            "split host pipeline"
        )

    fresh_wire = fresh.get("aggregation", {}).get("sparse_wire_bytes")
    committed_wire = committed.get("aggregation", {}).get("sparse_wire_bytes")
    if fresh_wire is None or committed_wire is None:
        failures.append(
            "missing aggregation.sparse_wire_bytes "
            f"(fresh={fresh_wire}, committed={committed_wire})"
        )
    elif fresh_wire > committed_wire:
        failures.append(
            f"sparse_wire_bytes regressed: {fresh_wire} > committed "
            f"{committed_wire} — the wire's on-air shape grew; if the format "
            "change is intentional, refresh BENCH_round.json in this PR"
        )

    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--fresh",
        default=os.path.join(_REPO_ROOT, "BENCH_round.quick.json"),
        help="record written by the quick bench run just executed",
    )
    ap.add_argument(
        "--committed",
        default=os.path.join(_REPO_ROOT, "BENCH_round.json"),
        help="the committed full-size reference record",
    )
    ap.add_argument(
        "--min-speedup", type=float, default=1.10,
        help="floor for speedups.e2e_vs_fused_host (committed: 1.36; the "
             "default leaves a generous CI-noise margin)",
    )
    args = ap.parse_args(argv)

    for path in (args.fresh, args.committed):
        if not os.path.exists(path):
            print(f"[check_bench] FAIL: {path} does not exist "
                  "(run benchmarks/engine_bench.py --quick --round-only first)")
            return 2
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.committed) as f:
        committed = json.load(f)

    failures = check(fresh, committed, min_speedup=args.min_speedup)
    if failures:
        for msg in failures:
            print(f"[check_bench] FAIL: {msg}")
        return 1
    print(
        "[check_bench] OK: dense-stack-free, "
        f"e2e_vs_fused_host={fresh['speedups']['e2e_vs_fused_host']}x >= "
        f"{args.min_speedup}x, sparse_wire_bytes="
        f"{fresh['aggregation']['sparse_wire_bytes']} <= committed "
        f"{committed['aggregation']['sparse_wire_bytes']}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
