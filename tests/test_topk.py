"""Top-k sparsification (paper eqs. 3-4)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topk import densify, topk_mask_dense, topk_sparsify


def test_topk_matches_lax_topk():
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 100))
    s = topk_sparsify(x, 7)
    want_v, want_i = jax.lax.top_k(x, 7)
    np.testing.assert_array_equal(s.values, want_v)
    np.testing.assert_array_equal(s.indices, want_i)
    assert s.k == 7 and s.vocab == 100


def test_k_clamped_to_vocab():
    x = jnp.ones((2, 8))
    s = topk_sparsify(x, 99)
    assert s.k == 8


def test_densify_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 50))
    s = topk_sparsify(x, 50)  # full k
    np.testing.assert_allclose(densify(s), x, rtol=0, atol=0)


def test_densify_zeros_off_support():
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 64)) + 10.0  # all positive
    d = densify(topk_sparsify(x, 5))
    assert int(jnp.sum(d != 0)) == 4 * 5
    # kept entries are the largest
    kth = jnp.sort(x, axis=-1)[:, -5]
    assert bool(jnp.all(jnp.where(d != 0, x >= kth[:, None], True)))


def test_topk_mask_dense_equals_sparsify_densify():
    x = jax.random.normal(jax.random.PRNGKey(3), (6, 40))
    np.testing.assert_allclose(
        topk_mask_dense(x, 9), densify(topk_sparsify(x, 9)), atol=0
    )


def test_sparsify_idempotent():
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 30)) + 5.0
    once = densify(topk_sparsify(x, 6))
    twice = densify(topk_sparsify(once, 6))
    np.testing.assert_allclose(once, twice, atol=0)


def test_wire_union_helpers_pad_concat_take():
    """pad_wire/concat_wires/take_wire_rows — the heterogeneous engines'
    union-wire merge point: padding is a no-op on transmitted content,
    concatenation of two cohorts' wires densifies to the stacked per-cohort
    densifications, and row gather/permutation round-trips."""
    from repro.core.topk import (
        concat_wires, pad_wire, sparsify_wire, take_wire_rows, wire_densify,
    )

    x1 = jax.random.normal(jax.random.PRNGKey(5), (2, 3, 32))
    x2 = jax.random.normal(jax.random.PRNGKey(6), (3, 3, 32))
    w1 = sparsify_wire(x1, jnp.asarray([4, 0]), k_cap=4)     # incl. a dropout
    w2 = sparsify_wire(x2, jnp.asarray([8, 2, 5]), k_cap=8)  # wider bucket

    padded = pad_wire(w1, 8)
    assert padded.k_cap == 8 and padded.vocab == w1.vocab
    np.testing.assert_allclose(wire_densify(padded), wire_densify(w1), atol=0)
    assert pad_wire(w2, 8) is w2  # already at width: identity

    union = concat_wires([w1, w2])
    assert union.values.shape == (5, 3, 8)
    np.testing.assert_allclose(
        wire_densify(union),
        jnp.concatenate([wire_densify(w1), wire_densify(w2)]),
        atol=0,
    )

    perm = [3, 0, 4]
    taken = take_wire_rows(union, perm)
    np.testing.assert_allclose(
        wire_densify(taken), wire_densify(union)[jnp.asarray(perm)], atol=0
    )

    import pytest

    with pytest.raises(ValueError):
        pad_wire(w2, 4)  # cannot shrink
    with pytest.raises(ValueError):
        concat_wires([w1, sparsify_wire(x1, jnp.asarray([1, 1]), 2)._replace(vocab=64)])


# ---- PR 6 regression: padded-wire index-0 clobber -------------------------


def test_padded_wire_preserves_index_zero_entry():
    """ISSUE repro: ``pad_wire`` appends masked entries at (value 0,
    index 0); a ``.at[idx].set`` densification scatter leaves the winner
    among duplicate indices unspecified, so a pad entry could CLOBBER a
    genuine vocab-index-0 top-k entry.  The wire scatter must be
    order-free (``.add``): index 0's logit must survive padding."""
    from repro.core.topk import (
        concat_wires, pad_wire, sparsify_wire, wire_densify, wire_support,
    )

    # index 0 holds the LARGEST logit, so it is always in the top-k support
    x = jnp.asarray([[5.0, 1.0, 0.5, 0.2]])
    w = sparsify_wire(x, jnp.asarray([2]), k_cap=2)
    padded = pad_wire(w, 4)  # two masked (0, index 0) pad entries per row

    d = wire_densify(padded)
    assert float(d[0, 0]) == 5.0, "pad entry clobbered the index-0 logit"
    np.testing.assert_allclose(d, jnp.asarray([[5.0, 1.0, 0.0, 0.0]]), atol=0)

    s = wire_support(padded)
    assert bool(s[0, 0]), "pad entry clobbered the index-0 support bit"
    np.testing.assert_array_equal(s, jnp.asarray([[True, True, False, False]]))

    # the same hazard through the hetero union path: a narrow bucket padded
    # up to a wider one, then concatenated — index-0 entries must survive
    y = jnp.asarray([[3.0, 2.0, 1.0, 0.5]])
    wide = sparsify_wire(y, jnp.asarray([4]), k_cap=4)
    union = concat_wires([w, wide])
    du = wire_densify(union)
    assert float(du[0, 0]) == 5.0 and float(du[1, 0]) == 3.0


# ---- PR 6: int8 quantized wire --------------------------------------------


def test_quantize_wire_roundtrip_bounds():
    """Dequantized values sit within amax/127 of the float wire per row,
    the scale is strictly positive, and off-mask entries stay exact zeros."""
    from repro.core.topk import (
        QUANT_LEVELS, dequantize_wire, quantize_wire, sparsify_wire, wire_densify,
    )

    x = jax.random.normal(jax.random.PRNGKey(7), (3, 4, 64)) * 10.0
    ks = jnp.asarray([5, 0, 64])  # incl. a dropped straggler row
    w = sparsify_wire(x, ks, k_cap=64)
    q = quantize_wire(w)
    assert q.values.dtype == jnp.int8 and q.scale.dtype == jnp.float32
    assert bool(jnp.all(q.scale > 0))

    back = dequantize_wire(q)
    amax = jnp.max(jnp.abs(jnp.where(w.mask, w.values, 0.0)), axis=-1)
    err = jnp.max(jnp.abs(back.values - w.values), axis=-1)
    # half-step rounding bound: |v - q*s| <= s/2 = amax/254
    assert bool(jnp.all(err <= amax / QUANT_LEVELS))
    # straggler row (k = 0): exact zeros, scale clamped to 1
    np.testing.assert_array_equal(np.asarray(back.values[1]), 0.0)
    np.testing.assert_array_equal(np.asarray(q.scale[1]), 1.0)
    # support is preserved exactly (quantization never moves the mask)
    np.testing.assert_array_equal(np.asarray(q.mask), np.asarray(w.mask))
    np.testing.assert_allclose(
        np.asarray(wire_densify(q)),
        np.asarray(wire_densify(back)),
        atol=0,
    )


def test_sparsify_wire_quantize_emits_quantized():
    from repro.core.topk import QuantizedWire, quantize_wire, sparsify_wire

    x = jax.random.normal(jax.random.PRNGKey(8), (2, 3, 32))
    ks = jnp.asarray([4, 7])
    direct = sparsify_wire(x, ks, k_cap=8, quantize=True)
    assert isinstance(direct, QuantizedWire)
    two_step = quantize_wire(sparsify_wire(x, ks, k_cap=8))
    for a, b in zip(direct[:4], two_step[:4]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quantized_wire_pad_concat_take():
    """pad/concat/take are format-polymorphic; mixing formats raises."""
    import pytest

    from repro.core.topk import (
        concat_wires, pad_wire, sparsify_wire, take_wire_rows, wire_densify,
    )

    x1 = jax.random.normal(jax.random.PRNGKey(9), (2, 3, 32))
    x2 = jax.random.normal(jax.random.PRNGKey(10), (3, 3, 32))
    q1 = sparsify_wire(x1, jnp.asarray([4, 0]), k_cap=4, quantize=True)
    q2 = sparsify_wire(x2, jnp.asarray([8, 2, 5]), k_cap=8, quantize=True)

    padded = pad_wire(q1, 8)
    assert padded.k_cap == 8
    np.testing.assert_allclose(
        np.asarray(wire_densify(padded)), np.asarray(wire_densify(q1)), atol=0
    )
    union = concat_wires([q1, q2])
    assert union.values.shape == (5, 3, 8) and union.scale.shape == (5, 3)
    np.testing.assert_allclose(
        np.asarray(wire_densify(union)),
        np.concatenate(
            [np.asarray(wire_densify(q1)), np.asarray(wire_densify(q2))]
        ),
        atol=0,
    )
    taken = take_wire_rows(union, [3, 0])
    np.testing.assert_allclose(
        np.asarray(wire_densify(taken)),
        np.asarray(wire_densify(union))[[3, 0]],
        atol=0,
    )
    with pytest.raises(ValueError):
        concat_wires([q1, sparsify_wire(x2, jnp.asarray([1, 1, 1]), 4)])
