"""Launch-layer steps: chunked CE correctness, train convergence, microbatching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.gpt2_paper import REDUCED_CLIENT
from repro.launch.steps import chunked_lm_loss, make_serve_step, make_train_step
from repro.models import backbone, init
from repro.models.model import _lm_logits
from repro.optim import adamw_init

pytestmark = pytest.mark.slow  # model-zoo/layer suites ride the slow tier


def test_chunked_ce_equals_naive():
    cfg = get_smoke_config("yi-9b")
    params = init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (3, 37), 0, cfg.vocab_size)
    h, _ = backbone(params, cfg, {"tokens": tokens})
    targets = tokens[:, 1:]
    mask = jnp.ones_like(targets, jnp.float32)
    got = chunked_lm_loss(params, cfg, h[:, :-1], targets, mask)

    logits = _lm_logits(params, cfg, h[:, :-1]).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    want = -jnp.take_along_axis(logp, targets[..., None], -1).mean()
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_train_loss_decreases():
    cfg = REDUCED_CLIENT.with_overrides(num_layers=2, d_model=128, num_heads=4,
                                        num_kv_heads=4, d_ff=256, lora=None)
    params = init(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=3e-3))
    # overfit one small batch
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
    losses = []
    for _ in range(12):
        params, opt, m = step(params, opt, {"tokens": tokens})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_microbatched_grads_match_full():
    cfg = get_smoke_config("stablelm-1.6b")
    params = init(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)

    p1, _, m1 = jax.jit(make_train_step(cfg.with_overrides(microbatches=1), lr=1e-3))(
        params, opt, {"tokens": tokens}
    )
    p4, _, m4 = jax.jit(make_train_step(cfg.with_overrides(microbatches=4), lr=1e-3))(
        params, opt, {"tokens": tokens}
    )
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-4)
    # parameters after one step agree (fp32 accumulation at smoke scale)
    for (ka, a), (kb, b) in zip(
        jax.tree_util.tree_leaves_with_path(p1), jax.tree_util.tree_leaves_with_path(p4)
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-5)


def test_serve_step_updates_length():
    cfg = get_smoke_config("stablelm-1.6b")
    params = init(jax.random.PRNGKey(0), cfg)
    from repro.models import init_cache

    cache = init_cache(cfg, 2, 16)
    step = jax.jit(make_serve_step(cfg))
    logits, cache = step(params, cache, jnp.array([1, 2]))
    assert logits.shape == (2, cfg.vocab_size)
    assert int(cache["length"]) == 1
