"""Channel model: Shannon capacity, byte budgets, adaptive k (paper §III-A)."""

import math

import pytest

from repro.core.channel import (
    ChannelConfig,
    ChannelSimulator,
    ChannelState,
    bits_per_entry,
    capacity_bps,
    topk_budget,
)


def test_capacity_formula():
    # 1 MHz @ 0 dB SNR -> B*log2(2) = 1e6 bps exactly (paper eq. 5)
    assert capacity_bps(1e6, 0.0) == pytest.approx(1e6)
    # 10 dB -> log2(11)
    assert capacity_bps(1e6, 10.0) == pytest.approx(1e6 * math.log2(11))
    assert capacity_bps(0.0, 10.0) == 0.0


def test_capacity_monotone_in_snr_and_bandwidth():
    caps = [capacity_bps(1e6, snr) for snr in (-10, 0, 10, 20, 30)]
    assert caps == sorted(caps)
    assert capacity_bps(2e6, 5.0) == pytest.approx(2 * capacity_bps(1e6, 5.0))


def test_bits_per_entry():
    # 16-bit value + ceil(log2(vocab)) index bits
    assert bits_per_entry(16, 50_288) == 16 + 16
    assert bits_per_entry(16, 65_536) == 16 + 16
    assert bits_per_entry(16, 65_537) == 16 + 17
    assert bits_per_entry(8, 2) == 9


def test_topk_budget_floor_and_clamps():
    st = ChannelState(bandwidth_hz=1e6, snr_db=0.0, eta=0.5, deadline_s=1.0)
    # budget = 0.5 * 1e6 * 1 = 5e5 bits; d = 32 for vocab 50288
    k = topk_budget(st, vocab_size=50_288, num_samples=100)
    assert k == math.floor(5e5 / 32 / 100)
    # deep fade floors at k_min
    bad = ChannelState(bandwidth_hz=1e3, snr_db=-30.0, eta=0.01, deadline_s=0.1)
    assert topk_budget(bad, vocab_size=50_288, num_samples=1000) == 1
    # great channel caps at vocab
    good = ChannelState(bandwidth_hz=1e12, snr_db=60.0, eta=1.0, deadline_s=10.0)
    assert topk_budget(good, vocab_size=1000, num_samples=1) == 1000


def test_simulator_deterministic_and_per_client():
    sim1 = ChannelSimulator(20, ChannelConfig(), seed=3)
    sim2 = ChannelSimulator(20, ChannelConfig(), seed=3)
    s1 = sim1.states(5, [0, 3, 7])
    s2 = sim2.states(5, [0, 3, 7])
    assert [a.snr_db for a in s1] == [b.snr_db for b in s2]
    # different rounds -> different fading
    s3 = sim1.states(6, [0, 3, 7])
    assert [a.snr_db for a in s1] != [b.snr_db for b in s3]


def test_simulator_eta_default_splits_channel():
    sim = ChannelSimulator(10, ChannelConfig(eta=None), seed=0)
    st = sim.states(0, list(range(5)))
    assert all(s.eta == pytest.approx(1 / 5) for s in st)
