"""Multi-tenant personalized-adapter serving (PR 10).

The contract under test: ONE donated jitted decode step + ONE shared
frozen backbone serve a mixed batch of tenants — each request applying its
own client's LoRA adapter via a slab gather — BIT-IDENTICALLY to running
every request alone (batch 1) with its adapter merged the classic way;
the AdapterCache pages adapters through LRU slots with exact hit/miss/
eviction accounting and re-pages evicted adapters to identical outputs;
and a federation checkpoint (``step_N.fleet/`` shards or monolithic npz)
is directly servable through ``export_adapters`` with no new format.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import LoRAConfig, SSMConfig
from repro.configs.gpt2_paper import REDUCED_CLIENT
from repro.configs.mamba2_130m import SMOKE_CONFIG as MAMBA_SMOKE
from repro.fed.store import DeviceFleetStore, HostFleetStore
from repro.lora import lora_template, map_lora, merge_lora, split_lora
from repro.models import init as model_init
from repro.serve import (
    AdapterCache,
    ServeConfig,
    ServeSession,
    export_adapters,
    serving_params,
)
from repro.serve.export import MonolithicSource, ShardDirSource

LORA = LoRAConfig(rank=4, alpha=32.0, dropout=0.0, targets=("q", "v", "o", "head"))
DENSE = REDUCED_CLIENT.with_overrides(
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
    vocab_size=256, max_seq_len=64, lora=LORA,
)
# attention-free family: adapters exist only on the LM head
SSM = MAMBA_SMOKE.with_overrides(
    d_model=64, vocab_size=256, max_seq_len=64,
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, chunk_size=16),
    lora=LoRAConfig(rank=4, alpha=32.0, dropout=0.0, targets=("head",)),
)

PROMPT, GEN = 4, 6


def _adapter_row(params, cid: int, scale=0.05):
    """A distinct nontrivial adapter per tenant: randomize A AND B (the
    fresh init has B = 0, which would make every adapter's delta vanish
    and the parity suite vacuous)."""
    lora, _ = split_lora(params)
    key = jax.random.fold_in(jax.random.PRNGKey(7), cid)
    counter = [0]

    def rnd(x):
        counter[0] += 1
        return scale * jax.random.normal(
            jax.random.fold_in(key, counter[0]), x.shape
        ).astype(x.dtype)

    return map_lora(rnd, lora)


class ListSource:
    def __init__(self, rows):
        self.rows = list(rows)
        self.num_adapters = len(rows)
        self.reads = 0

    def lora_row(self, cid: int):
        self.reads += 1
        return self.rows[int(cid)]


def _session(cfg, params, *, batch, rows=None, slots=None):
    adapters = None
    if rows is not None:
        adapters = AdapterCache(
            ListSource(rows), like=lora_template(params), slots=slots or len(rows)
        )
    scfg = ServeConfig(model=cfg, batch=batch, cache_len=PROMPT + GEN)
    return ServeSession(scfg, params, adapters=adapters)


def _decode(sess, prompts):
    sess.prefill(prompts)
    toks, logits = sess.decode(GEN)
    return toks, np.asarray(logits)


def _prompts(cfg, batch, seed=3):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, (batch, PROMPT)).astype(np.int32)


# ---------------------------------------------------------------------------
# multi-tenant parity: mixed batch == per-request batch-1, bit for bit
# ---------------------------------------------------------------------------


# The dense baseline runs each request truly ALONE (batch 1).  The SSM
# baseline runs at equal batch: the Mamba2 backbone is not bit-stable
# across batch SIZES on CPU XLA even with zero adapters in play (fusion
# orders a reduction differently; measured ~1 ulp on the seed build), so
# the invariant the adapter machinery can and must guarantee is that the
# per-request slab gather adds ZERO deviation over the classic
# merge_lora'd single-adapter decode at the same batch.
@pytest.mark.parametrize("cfg,solo", [(DENSE, True), (SSM, False)], ids=["dense", "ssm"])
def test_stacked_batch_bit_identical_to_single_adapter(cfg, solo):
    n = 8
    params = model_init(jax.random.PRNGKey(0), cfg)
    rows = [_adapter_row(params, c) for c in range(n)]
    prompts = _prompts(cfg, n)

    sess = _session(cfg, params, batch=n, rows=rows)
    ids = list(range(n))
    sess.attach(ids)
    toks, logits = _decode(sess, prompts)
    assert sess.stats()["executables"]["stacked"] == 1

    # a different tenant permutation reuses the SAME compiled step
    sess.attach(ids[::-1])
    _decode(sess, prompts)
    assert sess.stats()["executables"]["stacked"] == 1

    # baselines: tenant b's adapter merged the classic single-adapter way
    _, frozen = split_lora(params)
    for b in range(n):
        base = merge_lora(rows[b], frozen)
        if solo:
            s1 = _session(cfg, base, batch=1)
            t1, l1 = _decode(s1, prompts[b : b + 1])
            t1, l1 = t1[0], l1[0]
        else:
            s1 = _session(cfg, base, batch=n)
            t1, l1 = _decode(s1, prompts)
            t1, l1 = t1[b], l1[b]
        np.testing.assert_array_equal(toks[b], t1)
        np.testing.assert_array_equal(logits[b], l1)


def test_distinct_tenants_distinct_outputs():
    """The parity suite would pass trivially if adapters had no effect —
    check different tenants actually diverge on the same prompt."""
    params = model_init(jax.random.PRNGKey(0), DENSE)
    rows = [_adapter_row(params, c, scale=0.3) for c in range(2)]
    prompts = np.broadcast_to(_prompts(DENSE, 1), (2, PROMPT)).copy()
    sess = _session(DENSE, params, batch=2, rows=rows)
    sess.attach([0, 1])
    _, logits = _decode(sess, prompts)
    assert not np.array_equal(logits[0], logits[1])


# ---------------------------------------------------------------------------
# AdapterCache: LRU accounting, eviction re-page parity, capacity-1 thrash
# ---------------------------------------------------------------------------


def test_cache_hit_miss_eviction_counts():
    params = model_init(jax.random.PRNGKey(0), DENSE)
    rows = [_adapter_row(params, c) for c in range(3)]
    src = ListSource(rows)
    cache = AdapterCache(src, like=lora_template(params), slots=2)

    cache.lookup([0, 1])  # cold: two misses
    assert (cache.stats.hits, cache.stats.misses, cache.stats.evictions) == (0, 2, 0)
    assert src.reads == 2

    cache.lookup([0, 1])  # warm: zero host traffic
    assert (cache.stats.hits, cache.stats.misses, cache.stats.evictions) == (2, 2, 0)
    assert src.reads == 2

    # 0 hits (and becomes MRU); 2 misses and evicts the LRU tenant 1
    cache.lookup([0, 2])
    assert (cache.stats.hits, cache.stats.misses, cache.stats.evictions) == (3, 3, 1)
    assert src.reads == 3
    assert set(cache.resident()) == {0, 2}

    # duplicates within a batch share a slot and count once
    cache.lookup([2, 2])
    assert cache.stats.hits == 4

    with pytest.raises(ValueError, match="distinct adapters"):
        cache.lookup([0, 1, 2])  # 3 distinct tenants > 2 slots


def test_lookup_never_evicts_pinned_slot():
    """A batch that hits slot A then misses must not evict slot A even
    when A is the LRU entry."""
    params = model_init(jax.random.PRNGKey(0), DENSE)
    rows = [_adapter_row(params, c) for c in range(3)]
    cache = AdapterCache(ListSource(rows), like=lora_template(params), slots=2)
    cache.lookup([0, 1])
    # 0 is LRU *before* this batch touches it; batch = [0, 2]: the miss on 2
    # must evict 1, not the just-pinned 0
    slots = cache.lookup([0, 2])
    assert set(cache.resident()) == {0, 2}
    assert len({int(s) for s in slots}) == 2


def test_evicted_adapter_repages_bit_identical():
    params = model_init(jax.random.PRNGKey(0), DENSE)
    rows = [_adapter_row(params, c) for c in range(3)]
    prompts = _prompts(DENSE, 2)
    sess = _session(DENSE, params, batch=2, rows=rows, slots=2)

    sess.attach([1, 1])
    t_before, l_before = _decode(sess, prompts)

    sess.attach([0, 2])  # evicts tenant 1
    _decode(sess, prompts)
    assert 1 not in sess.adapters.resident()

    sess.attach([1, 1])  # re-page from the source
    t_after, l_after = _decode(sess, prompts)
    np.testing.assert_array_equal(t_before, t_after)
    np.testing.assert_array_equal(l_before, l_after)
    st = sess.adapters.stats
    assert st.evictions >= 2
    assert sess.stats()["executables"]["stacked"] == 1


def test_capacity_one_thrash():
    params = model_init(jax.random.PRNGKey(0), DENSE)
    rows = [_adapter_row(params, c) for c in range(2)]
    src = ListSource(rows)
    cache = AdapterCache(src, like=lora_template(params), slots=1)
    for cid in (0, 1, 0, 1):
        cache.lookup([cid])
    assert (cache.stats.hits, cache.stats.misses, cache.stats.evictions) == (0, 4, 3)
    assert src.reads == 4
    with pytest.raises(ValueError, match="distinct adapters"):
        cache.lookup([0, 1])


# ---------------------------------------------------------------------------
# export_adapters: fleet checkpoints are directly servable
# ---------------------------------------------------------------------------


def _fleet_store(params, n, kind="host"):
    lora0, frozen = split_lora(params)
    loras = [_adapter_row(params, c) for c in range(n)]
    opts = [jax.tree.map(lambda x: np.zeros_like(np.asarray(x)), lora0)] * n
    cls = HostFleetStore if kind == "host" else DeviceFleetStore
    kw = {"prefetch": False} if kind == "host" else {}
    return cls(loras, [frozen] * n, opts, shared=True, **kw), loras


def _assert_rows_equal(src, loras):
    for c, row in enumerate(loras):
        got = src.lora_row(c)
        for a, b in zip(jax.tree.leaves(row), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_store_lora_rows_contract():
    params = model_init(jax.random.PRNGKey(0), DENSE)
    for kind in ("host", "device"):
        store, loras = _fleet_store(params, 3, kind)
        stacked = store.lora_rows([2, 0])
        want = jax.tree.map(lambda a, b: np.stack([a, b]), loras[2], loras[0])
        for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(stacked)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_export_from_shard_dir(tmp_path):
    params = model_init(jax.random.PRNGKey(0), DENSE)
    store, loras = _fleet_store(params, 3)
    d = str(tmp_path / "step_00000002.fleet")
    store.save_shards(d)

    src = export_adapters(d)
    assert isinstance(src, ShardDirSource)
    assert src.num_adapters == 3
    _assert_rows_equal(src, loras)
    # the shared backbone round-trips into full serving params
    rebuilt = serving_params(src, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(rebuilt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_export_from_monolithic_ckpt(tmp_path):
    from repro.checkpoint.ckpt import save_step

    params = model_init(jax.random.PRNGKey(0), DENSE)
    store, loras = _fleet_store(params, 3)
    d = str(tmp_path)
    save_step(d, 1, {"fleet": store.state_dict()})

    src = export_adapters(d)
    assert isinstance(src, MonolithicSource)
    assert src.num_adapters == 3
    _assert_rows_equal(src, loras)
    rebuilt = serving_params(src, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(rebuilt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_export_prefers_shards_over_monolithic(tmp_path):
    from repro.checkpoint.ckpt import fleet_shard_dir, save_step

    params = model_init(jax.random.PRNGKey(0), DENSE)
    store, loras = _fleet_store(params, 3)
    d = str(tmp_path)
    store.save_shards(fleet_shard_dir(d, 2))
    save_step(d, 2, {"server": {"x": np.zeros(1)}}, fleet_sharded=True)
    src = export_adapters(d)
    assert isinstance(src, ShardDirSource)
    _assert_rows_equal(src, loras)


def test_fed_ckpt_directly_servable(tmp_path):
    """End to end: fed_train-style run with a host fleet store + ckpt_dir,
    then serve every client's personalized adapter straight from the
    checkpoint, parity-checked against the live store's rows."""
    from repro.core import ChannelConfig
    from repro.data import make_banking77_like
    from repro.fed import FedConfig, run_federated

    ds = make_banking77_like(vocab_size=DENSE.vocab_size, seq_len=12, total=300, seed=0)
    server = DENSE.with_overrides(name="srv", d_model=96, d_ff=192)
    # pretrain_steps > 0: one pretrained backbone SHARED by the family's
    # clients (the paper's W' + per-client LoRA setting) — that shared tree
    # is what multi-tenant serving stacks the adapters against
    fed = FedConfig(
        method="adald", engine="batched", num_clients=4, clients_per_round=4,
        rounds=1, public_size=32, public_batch=16, eval_size=32,
        local_steps=1, distill_steps=1, server_distill_steps=1,
        pretrain_steps=1, seed=0, fleet_store="host",
        channel=ChannelConfig(bandwidth_hz=2e5, mean_snr_db=2.0),
    )
    d = str(tmp_path)
    run_federated(DENSE, server, ds, fed, ckpt_dir=d)

    src = export_adapters(d)
    assert isinstance(src, ShardDirSource)
    assert src.num_adapters == 4

    params = serving_params(src, model_init(jax.random.PRNGKey(0), DENSE))
    cache = AdapterCache(src, like=lora_template(params), slots=2)
    scfg = ServeConfig(model=DENSE, batch=2, cache_len=PROMPT + GEN)
    sess = ServeSession(scfg, params, adapters=cache)
    prompts = _prompts(DENSE, 2)
    sess.attach([0, 1])
    _, logits = _decode(sess, prompts)
    assert np.isfinite(logits).all()
    sess.attach([2, 3])  # pages the cold half of the fleet through eviction
    _, logits = _decode(sess, prompts)
    assert np.isfinite(logits).all()
    assert cache.stats.misses == 4 and cache.stats.evictions == 2
    # trained adapters are nontrivial: B left zero would make tenants equal
    row = src.lora_row(0)
    assert any(
        float(np.abs(np.asarray(x)).max()) > 0 for x in jax.tree.leaves(row)
    )


# ---------------------------------------------------------------------------
# api_redesign shims
# ---------------------------------------------------------------------------


def test_launch_steps_shims():
    from repro.launch.steps import make_prefill_step, make_serve_step
    from repro.serve.steps import (
        make_decode_step,
        make_prefill_step as serve_prefill,
    )

    assert make_serve_step is make_decode_step
    assert make_prefill_step is serve_prefill


def test_serve_config_frozen_and_hashable():
    scfg = ServeConfig(model=DENSE, batch=2)
    hash(scfg)
    with pytest.raises(dataclasses.FrozenInstanceError):
        scfg.batch = 4
