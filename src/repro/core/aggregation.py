"""Server-side logit aggregation schemes (paper §III-A, eqs. 6-7).

Given N clients' sparse logit uploads, the paper's *adaptive* aggregation
weights each client's contribution per dimension by its confidence share:

    s_{n,c}   = |K̃_{n,c}(x)|                     (confidence score)
    S[c]      = Σ_n s_{n,c}
    w_{n,c}   = s_{n,c} / S[c]                    (eq. 6)
    K_{g,c}   = Σ_n w_{n,c} * K̃_{n,c}(x)         (eq. 7)

Only clients that actually transmitted dimension c contribute, so the
zero-padding bias of naive averaging disappears.  Baselines implemented for
the paper's comparison: ``zeropad`` (mean over all N including zeros — the
paper's "ZeroPad"), and ``mean_nonzero`` (mean over transmitting clients
only; an ablation between ZeroPad and Adaptive).

Two input representations:

* **dense** ``(N, ..., vocab)`` stacks (zeros off-support) — the reference
  oracle the sequential/batched engines feed.  Every dense mode accepts an
  optional explicit ``mask`` (same shape, True = transmitted): without it,
  "transmitted" is inferred from the ``!= 0`` sentinel, which silently
  treats a transmitted logit that is exactly 0.0 as untransmitted (it then
  drops out of the ``mean_nonzero`` denominator).  The sparse wire path
  always carries the explicit mask.
* **sparse wire** :class:`repro.core.topk.SparseWire` ``(values, indices,
  mask)`` of width ``k_cap`` — what the fused end-to-end round consumes.
  :func:`aggregate_wire` scatter-accumulates straight from the wire into
  ONE ``(..., vocab)`` output, so the aggregation working set is
  O(N·B·k_cap) instead of the dense stack's O(N·B·V); the Pallas
  scatter-accumulate kernel (:mod:`repro.kernels.sparse_agg`) is the
  ``use_kernel=True`` route.

Shapes: ``stack`` is ``(N, ..., vocab)`` — leading client axis, then any
batch shape, vocab last.  All functions are jit/pjit friendly.
"""

from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.topk import QuantizedWire, SparseWire

__all__ = [
    "aggregate_adaptive",
    "aggregate_zeropad",
    "aggregate_mean_nonzero",
    "aggregate",
    "aggregate_sparse",
    "aggregate_wire",
    "scatter_wire_sums",
    "scatter_wire_sums_dequant",
    "max_intermediate_elems",
]

_EPS = 1e-12


def _support(stack: jax.Array, mask: jax.Array | None) -> jax.Array:
    """Transmit mask as the stack's dtype: explicit when given, else the
    legacy ``!= 0`` sentinel (which cannot see transmitted true zeros)."""
    if mask is None:
        return (stack != 0).astype(stack.dtype)
    return mask.astype(stack.dtype)


def aggregate_adaptive(
    stack: jax.Array, *, mask: jax.Array | None = None, eps: float = _EPS
) -> jax.Array:
    """Paper eqs. 6-7: dimension-wise confidence-weighted aggregation.

    Dimensions no client transmitted stay exactly 0.  The confidence score
    of a transmitted 0.0 is 0, so the explicit ``mask`` does not change the
    value here — it is threaded for API uniformity (and so masked-out
    garbage can never leak in).
    """
    m = _support(stack, mask)
    s = jnp.abs(stack) * m  # (N, ..., V) confidence scores
    total = jnp.sum(s, axis=0)  # (..., V) S[c]
    w = s / (total[None] + eps)  # (N, ..., V) w_{n,c}
    return jnp.sum(w * stack, axis=0)


def aggregate_zeropad(stack: jax.Array, *, mask: jax.Array | None = None) -> jax.Array:
    """Paper's 'ZeroPad' baseline: plain mean including zero padding."""
    if mask is not None:
        stack = stack * mask.astype(stack.dtype)
    return jnp.mean(stack, axis=0)


def aggregate_mean_nonzero(
    stack: jax.Array, *, mask: jax.Array | None = None, eps: float = _EPS
) -> jax.Array:
    """Mean over transmitting clients only (uniform, support-aware).

    With the explicit ``mask``, a transmitted logit that is exactly 0.0
    counts toward the denominator (it was on the air); the legacy sentinel
    fallback silently dropped it.
    """
    m = _support(stack, mask)
    count = jnp.sum(m, axis=0)
    return jnp.sum(stack * m, axis=0) / (count + eps)


AggregationMode = Literal["adaptive", "zeropad", "mean_nonzero"]


def aggregate(
    stack: jax.Array,
    mode: AggregationMode = "adaptive",
    *,
    mask: jax.Array | None = None,
    use_kernel: bool = False,
) -> jax.Array:
    """Dispatch on aggregation mode; ``use_kernel`` routes the adaptive path
    through the fused Pallas kernel.  ``mask`` is the optional explicit
    (N, ..., vocab) transmit mask (see module docstring)."""
    if mode == "adaptive":
        if use_kernel:
            from repro.kernels import ops as kops

            x = stack if mask is None else stack * mask.astype(stack.dtype)
            return kops.sparse_aggregate(x)
        return aggregate_adaptive(stack, mask=mask)
    if mode == "zeropad":
        return aggregate_zeropad(stack, mask=mask)
    if mode == "mean_nonzero":
        return aggregate_mean_nonzero(stack, mask=mask)
    raise ValueError(f"unknown aggregation mode: {mode!r}")


def scatter_wire_sums(
    a: jax.Array, b: jax.Array, indices: jax.Array, vocab: int
) -> tuple[jax.Array, jax.Array]:
    """Scatter-accumulate two channels of per-entry contributions
    ``a, b: (N, ..., k)`` at ``indices`` into ``(..., vocab)`` sums.

    The one primitive every aggregation mode reduces to — a single XLA
    scatter-add over the O(N·B·k) wire entries; nothing of size
    O(N·B·vocab) is ever materialised.  Masked-out entries must already be
    zeroed (adding 0 at a valid index is a no-op).
    """
    n, k = a.shape[0], a.shape[-1]
    batch_shape = a.shape[1:-1]
    af = a.reshape((n, -1, k))
    bf = b.reshape((n, -1, k))
    idf = indices.reshape((n, -1, k))
    rows = af.shape[1]
    row_ix = jnp.broadcast_to(
        jnp.arange(rows, dtype=jnp.int32)[None, :, None], idf.shape
    )
    num = jnp.zeros((rows, vocab), a.dtype).at[row_ix, idf].add(af)
    den = jnp.zeros((rows, vocab), b.dtype).at[row_ix, idf].add(bf)
    return (
        num.reshape(batch_shape + (vocab,)),
        den.reshape(batch_shape + (vocab,)),
    )


def scatter_wire_sums_dequant(
    q_values: jax.Array,
    scale: jax.Array,
    mask: jax.Array,
    indices: jax.Array,
    vocab: int,
    mode: AggregationMode = "adaptive",
) -> tuple[jax.Array, jax.Array]:
    """Dequantize-fused variant of :func:`scatter_wire_sums` for the int8
    :class:`~repro.core.topk.QuantizedWire`: reconstruct each entry's float
    value (``q * scale`` per row) and scatter the mode's two contribution
    channels in one pass, without ever materialising a separate float wire
    on the caller's side.

    The dequantized values live only as an O(N·B·k_cap) intermediate — the
    same order as the wire itself — so the dense-stack-free O(N·B·k_cap)
    contract of the sparse aggregation path is preserved (trace-asserted by
    the bench and tests/test_engine.py).
    """
    m = mask.astype(jnp.float32)
    v = q_values.astype(jnp.float32) * scale[..., None] * m
    if mode == "adaptive":
        a, b = jnp.abs(v) * v, jnp.abs(v)
    elif mode in ("zeropad", "mean_nonzero"):
        a, b = v, m
    else:
        raise ValueError(f"unknown aggregation mode: {mode!r}")
    return scatter_wire_sums(a, b, indices, vocab)


def aggregate_wire(
    wire: SparseWire | QuantizedWire,
    mode: AggregationMode = "adaptive",
    *,
    num_transmitters: jax.Array | None = None,
    eps: float = _EPS,
    use_kernel: bool = False,
) -> jax.Array:
    """Aggregate straight from the sparse wire format (values, indices,
    mask) — O(N·B·k_cap) work and memory, one (..., vocab) densification at
    the very end (the output itself).

    Float-tolerance-consistent with the dense reference fed
    ``wire_densify(wire)`` + ``mask=wire_support(wire)`` in all three modes,
    including k == 0 clients (all-False mask rows contribute nothing) and
    true-zero transmitted logits.  ``num_transmitters`` (zeropad's
    denominator: clients with k > 0) may be passed as traced data when the
    caller already knows it; derived from the mask otherwise.  The dense
    oracle's stack holds ONLY transmitting clients, so its ``mean(axis=0)``
    divides by the same count.

    A :class:`~repro.core.topk.QuantizedWire` routes through the
    dequantize-fused scatter (:func:`scatter_wire_sums_dequant` /
    :func:`repro.kernels.ops.scatter_wire_sums_dequant`), which reconstructs
    the float values in the same O(N·B·k_cap) pass.

    ``use_kernel=True`` routes the scatter-accumulate through the Pallas
    kernels (:mod:`repro.kernels.sparse_agg`).
    """
    if mode not in ("adaptive", "zeropad", "mean_nonzero"):
        raise ValueError(f"unknown aggregation mode: {mode!r}")
    if isinstance(wire, QuantizedWire):
        if use_kernel:
            from repro.kernels import ops as kops

            num, den = kops.scatter_wire_sums_dequant(
                wire.values, wire.scale, wire.mask, wire.indices, wire.vocab, mode
            )
        else:
            num, den = scatter_wire_sums_dequant(
                wire.values, wire.scale, wire.mask, wire.indices, wire.vocab, mode
            )
    else:
        m = wire.mask.astype(wire.values.dtype)
        v = wire.values * m  # belt-and-braces: sparsify_wire already zeroed
        if mode == "adaptive":
            s = jnp.abs(v)  # confidence; 0 for masked entries
            a, b = s * v, s
        else:
            a, b = v, m

        if use_kernel:
            from repro.kernels import ops as kops

            num, den = kops.scatter_wire_sums(a, b, wire.indices, wire.vocab)
        else:
            num, den = scatter_wire_sums(a, b, wire.indices, wire.vocab)

    if mode == "zeropad":
        if num_transmitters is None:
            client_axes = tuple(range(1, wire.mask.ndim))
            num_transmitters = jnp.sum(
                jnp.any(wire.mask, axis=client_axes).astype(jnp.int32)
            )
        denom = jnp.maximum(num_transmitters, 1).astype(num.dtype)
        return num / denom
    return num / (den + eps)


def max_intermediate_elems(jaxpr) -> int:
    """Largest element count of any equation output anywhere in a jaxpr —
    sub-jaxprs (pjit / scan / cond bodies) included.

    This is the inspection behind the sparse path's memory contract: the
    whole-round benchmark and ``tests/test_engine.py`` both assert that
    ``max_intermediate_elems(jax.make_jaxpr(aggregate_wire-ish)(...))``
    stays below the dense ``(N, B, V)`` stack's element count (ONE shared
    implementation, so the committed BENCH_round.json proof and the CI test
    can never diverge).  Accepts a ``ClosedJaxpr`` or a raw ``Jaxpr``.
    """
    from jax import core as jax_core

    inner = getattr(jaxpr, "jaxpr", jaxpr)
    worst = 0
    for eqn in inner.eqns:
        for v in eqn.outvars:
            shape = getattr(v.aval, "shape", ())
            n = 1
            for s in shape:
                n *= int(s)
            worst = max(worst, n)
        for sub in jax_core.jaxprs_in_params(eqn.params):
            worst = max(worst, max_intermediate_elems(sub))
    return worst


def aggregate_sparse(
    values: jax.Array,
    indices: jax.Array,
    vocab: int,
    mode: AggregationMode = "adaptive",
    *,
    eps: float = _EPS,
) -> jax.Array:
    """Aggregate directly from sparse (value, index) payloads without first
    densifying each client — the per-row ``fori_loop`` reference formulation
    (every entry assumed transmitted; see :func:`aggregate_wire` for the
    masked wire-format fast path the round engine uses).

    values/indices: ``(N, ..., k)``.
    """
    n_clients = values.shape[0]
    batch_shape = values.shape[1:-1]
    k = values.shape[-1]

    flat_vals = values.reshape((n_clients, -1, k))
    flat_idx = indices.reshape((n_clients, -1, k))
    rows = flat_vals.shape[1]

    def per_row(vals_nk, idx_nk):
        # vals_nk, idx_nk: (N, k) for one (sample) row.
        sum_sv = jnp.zeros((vocab,), dtype=vals_nk.dtype)  # Σ s*K = Σ |K|*K
        sum_s = jnp.zeros((vocab,), dtype=vals_nk.dtype)  # Σ |K|
        sum_k = jnp.zeros((vocab,), dtype=vals_nk.dtype)  # Σ K (for baselines)
        cnt = jnp.zeros((vocab,), dtype=vals_nk.dtype)

        def body(n, carry):
            sum_sv, sum_s, sum_k, cnt = carry
            v = vals_nk[n]
            i = idx_nk[n]
            s = jnp.abs(v)
            sum_sv = sum_sv.at[i].add(s * v)
            sum_s = sum_s.at[i].add(s)
            sum_k = sum_k.at[i].add(v)
            cnt = cnt.at[i].add(jnp.ones_like(v))
            return sum_sv, sum_s, sum_k, cnt

        sum_sv, sum_s, sum_k, cnt = jax.lax.fori_loop(
            0, n_clients, body, (sum_sv, sum_s, sum_k, cnt)
        )
        if mode == "adaptive":
            return sum_sv / (sum_s + eps)
        if mode == "zeropad":
            return sum_k / float(n_clients)
        return sum_k / (cnt + eps)

    out = jax.vmap(per_row, in_axes=(1, 1))(flat_vals, flat_idx)  # (rows, vocab)
    del rows  # rows == prod(batch_shape); reshape below restores it
    return out.reshape(batch_shape + (vocab,))
