"""Fault suite: federated learning under injected faults (PR 8).

Runs every fault preset from ``repro.core.faults`` (no faults, i.i.d.
payload corruption with HARQ retransmission, client crashes mid-upload,
Gilbert-Elliott fault bursts, the lossy kitchen sink) through the batched
engine and records per-preset curves: server accuracy, per-round uplink
bytes (retransmitted copies included), quarantine/crash counts and
retransmission bytes.  The record is the committed ``BENCH_faults.json``
gated by ``benchmarks/check_bench.py``.

Determinism contract (what makes the gate equality-shaped): fault draws are
keyed per ``(seed, domain, round, cid)`` and cohort draws are consumed
round-by-round from one seeded rng, so a ``--quick`` run's rounds are a
PREFIX of the full run's — per-round uplink bytes and quarantine counts at
quick scale must equal the committed record's leading rounds exactly.  The
``none`` preset doubles as the bit-identity witness: the suite re-runs with
``faults=None`` and records whether the two are indistinguishable.

Run:  PYTHONPATH=src python examples/fault_suite.py            # full record
      PYTHONPATH=src python examples/fault_suite.py --quick    # CI gate
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.configs.base import LoRAConfig  # noqa: E402
from repro.configs.gpt2_paper import REDUCED_CLIENT, REDUCED_SERVER  # noqa: E402
from repro.core import FAULTS, ChannelConfig  # noqa: E402
from repro.data import make_banking77_like  # noqa: E402
from repro.fed import FedConfig, run_federated  # noqa: E402

_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

LORA = LoRAConfig(rank=4, alpha=32.0, dropout=0.0, targets=("q", "v", "head"))
CLIENT = REDUCED_CLIENT.with_overrides(
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
    vocab_size=256, max_seq_len=32, lora=LORA,
)
SERVER = REDUCED_SERVER.with_overrides(
    num_layers=2, d_model=96, num_heads=2, num_kv_heads=2, d_ff=192,
    vocab_size=256, max_seq_len=32, lora=LORA,
)
# Constrained uplink so the adaptive k varies and HARQ retries actually
# price against a finite Shannon budget.
CHAN = ChannelConfig(bandwidth_hz=2e5, mean_snr_db=2.0, min_k=0, dropout_prob=0.1)
FULL_ROUNDS = 10
QUICK_ROUNDS = 4


def _fed(rounds: int, faults) -> FedConfig:
    return FedConfig(
        method="adald", engine="batched", num_clients=6, clients_per_round=3,
        rounds=rounds, public_size=64, public_batch=16, eval_size=64,
        pretrain_steps=0, local_steps=2, distill_steps=1, seed=0,
        channel=CHAN, faults=faults,
    )


def run_preset(ds, rounds: int, faults):
    run = run_federated(CLIENT, SERVER, ds, _fed(rounds, faults))
    uplink = [r.uplink_bytes for r in run.ledger.rounds]
    out = {
        "server_acc": [float(a) for a in run.server_acc],
        "uplink_bytes": [float(b) for b in uplink],
        "cum_uplink_mb": [float(b) / 1e6 for b in np.cumsum(uplink)],
        "mean_k": [float(k) for k in run.mean_k],
        "final_acc": float(run.server_acc[-1]),
        "total_uplink_mb": float(sum(uplink)) / 1e6,
    }
    if run.num_quarantined is not None:
        out["num_quarantined"] = [int(n) for n in run.num_quarantined]
        out["num_crashed"] = [int(n) for n in run.num_crashed]
        out["retrans_bytes"] = [float(b) for b in run.retrans_bytes]
    return run, out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help=f"{QUICK_ROUNDS} rounds instead of {FULL_ROUNDS} "
                         "(a prefix of the full record; writes "
                         "BENCH_faults.quick.json for the CI gate)")
    ap.add_argument("--out", default=None, help="output JSON path override")
    args = ap.parse_args(argv)

    rounds = QUICK_ROUNDS if args.quick else FULL_ROUNDS
    ds = make_banking77_like(vocab_size=CLIENT.vocab_size, seq_len=12,
                            total=500, seed=0)

    record = {"quick": bool(args.quick), "rounds": rounds, "presets": {}}
    print(f"{'preset':>12} {'uplink MB':>10} {'quar':>5} {'crash':>6} "
          f"{'retrans MB':>11} {'final acc':>10}")
    runs = {}
    for name in FAULTS:
        run, out = run_preset(ds, rounds, name)
        runs[name] = run
        record["presets"][name] = out
        quar = sum(out.get("num_quarantined", [0]))
        crash = sum(out.get("num_crashed", [0]))
        retrans = sum(out.get("retrans_bytes", [0.0])) / 1e6
        print(f"{name:>12} {out['total_uplink_mb']:10.3f} {quar:5d} "
              f"{crash:6d} {retrans:11.4f} {out['final_acc']:10.3f}")

    # The disabled-machinery guarantee with teeth: the `none` preset must be
    # bit-identical to a run with NO faults configured at all.
    baseline, base_out = run_preset(ds, rounds, None)
    none = runs["none"]
    record["no_fault_bit_identical"] = bool(
        none.per_client_k == baseline.per_client_k
        and record["presets"]["none"]["uplink_bytes"] == base_out["uplink_bytes"]
        and none.server_acc == baseline.server_acc
    )
    print(f"\nnone preset vs faults=None bit-identical: "
          f"{record['no_fault_bit_identical']}")

    suffix = "quick.json" if args.quick else "json"
    path = args.out or os.path.join(_REPO_ROOT, f"BENCH_faults.{suffix}")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
