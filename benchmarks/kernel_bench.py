"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp reference.

On CPU the interesting number is the REFERENCE path (the jnp oracle is what
a TPU would fall back to without the kernel); interpret-mode timings measure
the Python-executed kernel body and are NOT TPU performance — the roofline
for kernels comes from BlockSpec arithmetic, printed as 'derived'.
"""

from __future__ import annotations

import sys
import time
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.kernels import ref  # noqa: E402
from repro.kernels.topk_select import rows_block_for  # noqa: E402


def _time(fn, *args, reps=5) -> float:
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6  # us


def bench(quick: bool = True):
    rows = []
    vocab = 50_288 if quick else 202_048
    n_rows = 32

    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (n_rows, vocab))

    # top-k: jnp oracle timing + kernel VMEM-tiling arithmetic
    topk_ref = jax.jit(lambda x: ref.topk_mask_ref(x, 128))
    us = _time(topk_ref, logits)
    rb = rows_block_for(vocab)
    hbm_passes = 2  # read + write, single pass by construction
    derived = f"rows_blk={rb};hbm_bytes={hbm_passes * n_rows * vocab * 4}"
    rows.append(("topk_ref_jnp", us, derived))

    t = jax.random.normal(key, (n_rows, vocab))
    s = jax.random.normal(jax.random.fold_in(key, 1), (n_rows, vocab))
    kl_ref = jax.jit(lambda a, b: jnp.mean(ref.distill_kl_ref(a, b, 2.0)))
    us = _time(kl_ref, t, s)
    # fused kernel: 1 read of each operand vs ~3 for the naive path
    rows.append(("distill_kl_ref_jnp", us, f"fused_hbm_reads=2x{n_rows * vocab * 4}B_vs_6x"))

    stack = jax.random.normal(key, (10, n_rows, vocab))
    stack = jnp.where(jax.random.uniform(jax.random.fold_in(key, 2), stack.shape) < 0.1, stack, 0.0)
    agg_ref = jax.jit(ref.sparse_agg_ref)
    us = _time(agg_ref, stack)
    rows.append(("sparse_agg_ref_jnp", us, f"stack_bytes={stack.size * 4}"))

    q = jax.random.normal(key, (4, 1024, 128))
    kk = jax.random.normal(jax.random.fold_in(key, 3), (4, 1024, 128))
    v = jax.random.normal(jax.random.fold_in(key, 4), (4, 1024, 128))
    fa_ref = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c))
    us = _time(fa_ref, q, kk, v)
    rows.append(("flash_attn_ref_jnp", us, "blocks=128x128;vmem_per_step~200KB"))

    if not quick:
        # interpret-mode correctness timing (kernel body in Python)
        from repro.kernels import ops

        us = _time(lambda x: ops.topk_mask(x, 128), logits, reps=1)
        rows.append(("topk_pallas_interpret", us, "correctness-mode, not TPU perf"))
    return rows


if __name__ == "__main__":
    for name, us, derived in bench(quick=False):
        print(f"{name},{us:.0f},{derived}")
