"""Family-bucket partitioning of a (possibly heterogeneous) client fleet.

The paper's central claim is that federated *distillation* — exchanging
vocab-indexed logits and rank-aligned LoRA projections instead of
parameters — lets clients with DIFFERENT architectures participate in one
federation (PAPER.md; Fig. 1's shared logit space).  The fast engines,
however, execute a cohort as ONE vmapped program over a leading client
axis, which requires every stacked client to share a parameter tree
layout.  This module is the bridge: it partitions the fleet into
homogeneous **family buckets** — maximal groups of clients running the
same :class:`~repro.configs.base.ModelConfig` — so the round engines can
run one compiled, donated client-phase executable *per bucket* and merge
the buckets' uploads in the model-agnostic logit space (the union
:class:`~repro.core.topk.SparseWire` is vocab-indexed, so an SSM bucket
and a dense bucket aggregate exactly as the paper's eqs. 6-7 prescribe).

Within a bucket the frozen backbones may still differ per client (e.g. no
shared pretrained W'): the bucket then carries its frozen trees STACKED on
the client axis (``shared_backbone=False`` -> ``frozen_ax=0`` in the
vmapped round bodies), which is the existing batched-engine contract.

The only cross-family contracts are the paper's own (§II): a shared
vocabulary (the logit exchange space) and — when the ``adald`` projection
loss is used — a shared LoRA rank r (eq. 8's h = A·x lives in R^r).
:func:`validate_family_contracts` enforces both at engine construction,
fail-fast, instead of letting a shape error surface mid-round.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.configs.base import ModelConfig
from repro.fed.client import Client

__all__ = [
    "FamilyBucket",
    "partition_fleet",
    "fleet_index",
    "split_cohort",
    "validate_family_contracts",
]


@dataclasses.dataclass(frozen=True)
class FamilyBucket:
    """One homogeneous slice of the fleet: every member runs ``cfg``.

    ``client_ids`` are GLOBAL fleet indices in fleet order; a client's
    bucket-local index is its position in this tuple.  ``shared_backbone``
    is the identity test the batched engine already uses: True iff every
    member's frozen tree is literally the same arrays (one pretrained W'
    under per-client LoRA deltas — the paper's setting); False means the
    bucket stacks its frozen trees along the client axis.
    """

    index: int
    cfg: ModelConfig
    client_ids: tuple[int, ...]
    shared_backbone: bool

    @property
    def size(self) -> int:
        return len(self.client_ids)

    def local(self, global_id: int) -> int:
        """Bucket-local index of a global fleet id."""
        return self.client_ids.index(global_id)


def partition_fleet(clients: Sequence[Client]) -> list[FamilyBucket]:
    """Group the fleet into family buckets by :class:`ModelConfig`, in order
    of first appearance (stable: a homogeneous fleet is exactly one bucket,
    and the engines built on top of this reduce to their PR-4 behaviour).

    Bucketing is by config value, not backbone identity: a same-config fleet
    with per-client random backbones stays ONE bucket with
    ``shared_backbone=False`` (stacked frozens) rather than fragmenting into
    singletons — the vmapped executable still serves it.
    """
    from repro.fed.engine import shared_frozen_backbone
    from repro.lora import split_lora

    order: list[ModelConfig] = []
    members: dict[ModelConfig, list[int]] = {}
    for i, c in enumerate(clients):
        if c.cfg not in members:
            order.append(c.cfg)
            members[c.cfg] = []
        members[c.cfg].append(i)

    buckets = []
    for bi, cfg in enumerate(order):
        ids = members[cfg]
        frozens = [split_lora(clients[i].params)[1] for i in ids]
        buckets.append(
            FamilyBucket(
                index=bi,
                cfg=cfg,
                client_ids=tuple(ids),
                shared_backbone=shared_frozen_backbone(frozens),
            )
        )
    return buckets


def fleet_index(
    buckets: Sequence[FamilyBucket],
) -> dict[int, tuple[int, int]]:
    """O(1) lookup table ``global fleet id -> (bucket index, bucket-local
    index)`` — the one mapping both heterogeneous engines route client
    reads through."""
    return {
        cid: (b.index, j)
        for b in buckets
        for j, cid in enumerate(b.client_ids)
    }


def split_cohort(
    buckets: Sequence[FamilyBucket], sel: Sequence[int]
) -> list[tuple[FamilyBucket, list[int], list[int]]]:
    """Partition one round's selected cohort across its family buckets.

    Returns ``(bucket, cohort_positions, local_ids)`` for every bucket with
    at least one selected client, preserving cohort order within each bucket
    (so the first selected client of a bucket is that bucket's row 0 — the
    invariant the per-family eval tap and the payload reassembly rely on).
    ``cohort_positions`` index into ``sel``; ``local_ids`` are bucket-local
    client indices.
    """
    where = {cid: b for b in buckets for cid in b.client_ids}
    parts: list[tuple[FamilyBucket, list[int], list[int]]] = []
    for b in buckets:
        pos = [p for p, cid in enumerate(sel) if where[int(cid)] is b]
        if pos:
            parts.append((b, pos, [b.local(int(sel[p])) for p in pos]))
    return parts


def validate_family_contracts(
    buckets: Sequence[FamilyBucket], *, server_cfg: ModelConfig | None = None
) -> None:
    """Enforce the paper's cross-family exchange contracts (§II):

    * one shared vocabulary — the logit space every upload/broadcast is
      indexed in (eq. 4's dimension c);
    * one shared LoRA rank (or LoRA disabled everywhere) — eq. 8's
      projection h = A·x must have a common dimensionality to be
      aggregated/distilled across families.

    ``server_cfg`` (when given) is held to the same contracts — the server's
    broadcast rides the identical spaces in the other direction.
    """
    cfgs = [b.cfg for b in buckets]
    if server_cfg is not None:
        cfgs.append(server_cfg)
    vocabs = {c.vocab_size for c in cfgs}
    if len(vocabs) > 1:
        raise ValueError(
            f"heterogeneous fleet must share one vocabulary (the logit "
            f"exchange space), got vocab sizes {sorted(vocabs)}"
        )
    ranks = {None if c.lora is None else c.lora.rank for c in cfgs}
    if len(ranks) > 1:
        raise ValueError(
            "heterogeneous fleet must share one LoRA rank for the eq.-8 "
            f"projection exchange (or disable LoRA everywhere), got {ranks}"
        )
