"""Quickstart: one AdaLD communication round, end to end, in ~a minute on CPU.

Walks the paper's Algorithm 1 explicitly with the public API:
  1. clients fine-tune LoRA on private non-IID data          (eq. 2)
  2. clients infer the public set and adaptively Top-k their
     logits by live channel state                            (eqs. 3-5)
  3. server aggregates sparse logits adaptively              (eqs. 6-7)
  4. server distills into its (larger) LLM                   (eqs. 9-10)
  5. server broadcasts; clients distill locally

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.configs.gpt2_paper import REDUCED_CLIENT, REDUCED_SERVER  # noqa: E402
from repro.core import ChannelConfig, ChannelSimulator  # noqa: E402
from repro.data import dirichlet_partition, make_banking77_like, split_public_private  # noqa: E402
from repro.fed.client import Client  # noqa: E402
from repro.fed.server import Server  # noqa: E402

# --- data: synthetic Banking77 statistics (77 intents), Dirichlet non-IID ---
dataset = make_banking77_like(vocab_size=REDUCED_CLIENT.vocab_size, seq_len=20,
                              total=1200, seed=0)
public, private = split_public_private(dataset, 256, seed=0)
parts = dirichlet_partition(private.labels, num_clients=3, gamma=0.5, seed=0)

clients = [
    Client(i, REDUCED_CLIENT, private.subset(parts[i]), num_classes=77,
           seed=i, local_steps=4)
    for i in range(3)
]
server = Server(REDUCED_SERVER, aggregation="adaptive")
channel = ChannelSimulator(3, ChannelConfig(bandwidth_hz=1e6, mean_snr_db=10), seed=0)

pub_tokens = jnp.asarray(public.tokens[:64])

# --- 1. local fine-tuning (paper eq. 2) ---
for c in clients:
    m = c.local_train()
    print(f"client {c.client_id}: local fine-tune loss={m['loss']:.3f} acc={m['acc']:.3f}")

# --- 2. channel-adaptive Top-k upload (paper §III-A) ---
uploads = []
for c, state in zip(clients, channel.states(0, [0, 1, 2])):
    up = c.upload(pub_tokens, state)
    uploads.append(up)
    print(f"client {c.client_id}: SNR={state.snr_db:5.1f}dB -> k={up.k:5d} "
          f"({up.payload.bytes / 1e3:.1f} kB uplink of "
          f"{64 * REDUCED_CLIENT.vocab_size * 2 / 1e3:.0f} kB dense)")

# --- 3+4. adaptive aggregation + server distillation (eqs. 6-10) ---
k_g, h_g = server.aggregate_uploads(uploads)
metrics = server.distill(pub_tokens, k_g, h_g)
print(f"server: distill loss={metrics['loss']:.4f} "
      f"(logits={metrics['logits']:.4f}, lora={metrics['lora']:.4f})")

# --- 5. broadcast + client-side distillation ---
g_logits, g_h, bits = server.broadcast(pub_tokens)
for c in clients:
    m = c.local_distill(pub_tokens, g_logits, g_h)
    print(f"client {c.client_id}: local distill loss={m['loss']:.4f}")
print(f"downlink: {bits / 8 / 1e3:.1f} kB broadcast")
print("OK — one full AdaLD round.")
