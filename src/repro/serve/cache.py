"""AdapterCache: hot/cold LRU paging of tenant adapters over device slots.

The tenant population is O(fleet) — the federation fine-tunes one adapter
per client — but device memory holds a slab of only ``slots`` adapter rows.
:meth:`AdapterCache.lookup` maps a batch of tenant ids to slot indices:

* **hit** — the tenant's adapter already sits in a slot: zero host traffic,
  the slot index is returned and the tenant moves to most-recently-used;
* **miss** — the least-recently-used unpinned slot is evicted (a pure slot
  reassignment: adapter rows are read-only at serve time, nothing is
  written back) and the tenant's row is paged in from the
  :class:`AdapterSource` (a live ``FleetStore`` or a ``step_N.fleet``
  shard directory, see :mod:`repro.serve.export`) via ONE jitted donated
  slab write.

Slots referenced earlier in the same batch are pinned: a lookup never
evicts an adapter the batch it is resolving still needs.  A batch with
more DISTINCT tenants than slots cannot be scheduled and raises.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Protocol, Sequence

import jax
import numpy as np

from repro.serve.adapters import canonicalize_row, slab_init, slab_set_row

__all__ = ["AdapterSource", "CacheStats", "AdapterCache"]


class AdapterSource(Protocol):
    """Where cold adapters live (host memory, npz shards, a FleetStore)."""

    num_adapters: int

    def lora_row(self, cid: int) -> Any:
        """Tenant ``cid``'s LoRA row tree (host or device leaves)."""
        ...


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    lookups: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class AdapterCache:
    """LRU tenant-adapter cache over a device slab of ``slots`` rows.

    ``like`` is the adapter-row skeleton (``repro.lora.lora_template`` of
    the served model's params); every paged row is validated against it.
    """

    def __init__(self, source: AdapterSource, *, like: Any, slots: int):
        if slots < 1:
            raise ValueError(f"AdapterCache needs >= 1 slot, got {slots}")
        self.source = source
        self.slots = int(slots)
        self._like = like
        self.slab = slab_init(like, self.slots)
        self._slot_of: OrderedDict[int, int] = OrderedDict()  # cid -> slot, LRU order
        self._free = list(range(self.slots))
        self.stats = CacheStats()
        # one compiled write executable for every (slot, tenant): the slab is
        # donated (in-place page-in) and the slot index is traced data
        self._write = jax.jit(slab_set_row, donate_argnums=(0,))

    # -- introspection --------------------------------------------------
    def resident(self) -> tuple[int, ...]:
        """Tenant ids currently in slots, LRU -> MRU order."""
        return tuple(self._slot_of)

    def reset_stats(self) -> None:
        self.stats = CacheStats()

    # -- the serving read path ------------------------------------------
    def _page_in(self, cid: int, pinned: set[int]) -> int:
        if self._free:
            slot = self._free.pop()
        else:
            victim = next(
                (c for c in self._slot_of if c not in pinned), None
            )
            if victim is None:  # unreachable: distinct-id count checked first
                raise RuntimeError("all slots pinned by the current batch")
            slot = self._slot_of.pop(victim)
            self.stats.evictions += 1
        row = canonicalize_row(self.source.lora_row(cid), self._like)
        self.slab = self._write(self.slab, row, np.int32(slot))
        self._slot_of[cid] = slot
        return slot

    def lookup(self, ids: Sequence[int]) -> np.ndarray:
        """Slot index per request: ``ids (B,)`` tenant ids -> ``(B,) int32``
        slab slots, paging misses in from the source.  Duplicate ids within
        a batch share a slot (the first occurrence decides hit vs miss)."""
        ids = [int(i) for i in ids]
        distinct = len(set(ids))
        if distinct > self.slots:
            raise ValueError(
                f"batch needs {distinct} distinct adapters but the cache "
                f"has {self.slots} slots — raise ServeConfig.slots or "
                "shrink the batch"
            )
        self.stats.lookups += 1
        pinned: set[int] = set()
        out = np.empty(len(ids), np.int32)
        for b, cid in enumerate(ids):
            if cid in self._slot_of:
                if cid not in pinned:  # duplicates count once per batch
                    self.stats.hits += 1
                self._slot_of.move_to_end(cid)
                out[b] = self._slot_of[cid]
            else:
                self.stats.misses += 1
                out[b] = self._page_in(cid, pinned)
            pinned.add(cid)
        return out
