"""The batched (vmapped per-phase) cohort engine — and the fleet-state
plumbing every fast engine inherits (store-routed since PR 9)."""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.channel import BatchedChannelState, ChannelState
from repro.core.protocol import UplinkPayload
from repro.core.topk import topk_mask_batch
from repro.fed import steps as fed_steps
from repro.fed.client import Client, make_upload_payload
from repro.fed.engines.base import (
    BroadcastState,
    ClientPhase,
    check_unique_cohort,
    cohort_budgets,
    fake_quant_dense,
    shared_frozen_backbone,
)
from repro.fed.store import FleetStore, make_fleet_store
from repro.lora import merge_lora, split_lora

__all__ = ["BatchedEngine"]


class BatchedEngine:
    """Batched client-phase executor: the whole cohort advances through each
    phase as one compiled step over a leading client axis.

    The fleet's trainable state lives in a :class:`repro.fed.store.FleetStore`
    picked by ``fleet_store``: ``"device"`` (default) keeps every client's
    LoRA tree and optimizer state stacked along a leading
    ``(num_clients, ...)`` device axis exactly as before the store refactor
    (the frozen backbone is one shared tree when all clients ride the same
    pretrained W' — the paper's setting — or stacked otherwise);
    ``"host"`` keeps the fleet in host numpy and stages only the selected
    cohort onto the device per round (O(cohort) device memory, any fleet
    size).  A round fetches the selected cohort's rows from the store, runs
    the vmapped phases, and commits the advanced rows back — no per-client
    stack/unstack/merge churn on the hot path.  The engine is the source of
    truth for client parameters while it is in use; read them back through
    :meth:`client_params`.
    """

    name = "batched"

    def __init__(
        self,
        clients: list[Client],
        cfg: ModelConfig,
        *,
        num_classes: int,
        lr: float = 1e-3,
        distill_lr: float = 1e-3,
        temperature: float = 2.0,
        lam: float = 0.03,
        local_steps: int = 4,
        distill_steps: int = 2,
        restrict_to_support: bool = False,
        value_bits: int = 16,
        k_min: int = 1,
        last_only: bool = True,
        class_head_only: bool = True,
        quantize_wire: bool = False,
        fleet_store: "str | FleetStore" = "device",
    ):
        self.clients = clients
        self.cfg = cfg
        self.local_steps = local_steps
        self.distill_steps = distill_steps
        self.value_bits = value_bits
        self.k_min = k_min
        self.last_only = last_only
        self.quantize_wire = quantize_wire

        loras, frozens = zip(*(split_lora(c.params) for c in clients))
        self._shared = shared_frozen_backbone(frozens)
        self._store = make_fleet_store(
            fleet_store, loras=loras, frozens=frozens,
            opts=[c.opt for c in clients], shared=self._shared,
        )
        self._train = fed_steps.make_batched_finetune_step(
            cfg, num_classes, lr=lr, shared_backbone=self._shared, last_only=last_only,
            class_head_only=class_head_only,
        )
        self._distill = fed_steps.make_batched_distill_step(
            cfg, lr=distill_lr, temperature=temperature, lam=lam,
            restrict_to_support=restrict_to_support, shared_backbone=self._shared,
            last_only=last_only,
        )
        self._public = fed_steps.make_batched_public_logits(
            cfg, shared_backbone=self._shared, last_only=last_only
        )

    # -- fleet-state ownership: delegated to the store -------------------
    # The stacked-tree attributes stay addressable (the scan-carry drivers
    # read/donate and reassign them) but only exist on the device store;
    # the host store raises with the scan_rounds tradeoff spelled out.
    @property
    def store_kind(self) -> str:
        return self._store.kind

    @property
    def _lora(self):
        return self._store.lora

    @_lora.setter
    def _lora(self, tree):
        self._store.lora = tree

    @property
    def _opt(self):
        return self._store.opt

    @_opt.setter
    def _opt(self, tree):
        self._store.opt = tree

    @property
    def _frozen(self):
        return self._store.frozen

    @_frozen.setter
    def _frozen(self, tree):
        self._store.frozen = tree

    def client_params(self, cid: int):
        """Materialise one client's merged params (for evaluation)."""
        lora_i, frozen_i = self._store.client_row(cid)
        return merge_lora(lora_i, frozen_i)

    def fleet_state(self) -> dict:
        """The engine-held fleet state as one checkpointable pytree.  The
        frozen backbone is included so a restored run never depends on the
        construction path reproducing it (it does today, but checkpoints
        should stand alone)."""
        return self._store.state_dict()

    def load_fleet_state(self, state: dict) -> None:
        self._store.load_state_dict(state)

    def save_fleet_shards(self, dir_path: str, *, prefix: str = "fleet") -> None:
        """Persist the fleet as per-client-range shards (fleet-scale
        checkpoints: never materializes the fleet as one tree).  The hetero
        engines pass a per-bucket ``prefix`` so buckets share one dir."""
        self._store.save_shards(dir_path, prefix=prefix)

    def load_fleet_shards(self, dir_path: str, *, prefix: str = "fleet") -> None:
        self._store.load_shards(dir_path, prefix=prefix)

    # -- round plumbing shared by the batched and fused engines ----------
    def _gather_cohort(self, sel: Sequence[int]):
        """The selected cohort's (idx, lora, frozen, opt) from the store."""
        return self._store.fetch(sel)

    def _scatter_cohort(self, idx, lora, opt) -> None:
        """Write the advanced cohort rows back into the fleet state."""
        self._store.commit(idx, lora, opt)

    def prefetch_cohort(self, sel: Sequence[int]) -> None:
        """Hint the NEXT round's cohort: a host store starts staging its
        host->device transfer now, overlapping the current round's compute
        (no-op on the device store)."""
        self._store.prefetch(sel)

    def _budgets(
        self, states, n_samples: int, adaptive_k: bool, n_cohort: int,
        send_h: bool = False,
    ):
        """Per-client adaptive k — delegates to the module-level
        :func:`cohort_budgets` (the same host-side scalar math as the
        sequential reference, so k and bytes can never drift)."""
        return cohort_budgets(
            states, self.cfg, n_samples, adaptive_k, n_cohort, send_h,
            value_bits=self.value_bits, k_min=self.k_min,
            quantize_wire=self.quantize_wire,
        )

    def _upload_manifests(self, cohort, states, ks, n_samples: int, send_h: bool):
        """(active indices, payload manifests, lora rank) for the k > 0
        transmitters — dropped stragglers contribute nothing."""
        active = [i for i, k in enumerate(ks) if k > 0]
        payloads: list[UplinkPayload] = []
        rank = None
        for i in active:
            payload, rank = make_upload_payload(
                self.cfg, cohort[i].client_id, n_samples, ks[i],
                send_h=send_h, value_bits=self.value_bits,
                snr_db=states[i].snr_db, quantize=self.quantize_wire,
            )
            payloads.append(payload)
        return active, payloads, rank

    def _stacked_batches(self, cohort, *, step_major: bool):
        """Each client's next ``local_steps`` private batches, drawn through
        its OWN rng stream (identical to the sequential path).  Returns a
        list of step-major dicts (one per step) or one client-major dict
        with a (C, S, ...) leading layout."""
        per_client = [c.next_train_batches(self.local_steps) for c in cohort]
        keys = per_client[0][0].keys()
        if step_major:
            return [
                {key: jnp.asarray(np.stack([b[s][key] for b in per_client]))
                 for key in keys}
                for s in range(self.local_steps)
            ]
        return {
            key: jnp.asarray(
                np.stack([np.stack([b[s][key] for s in range(self.local_steps)])
                          for b in per_client])
            )
            for key in keys
        }

    def run_round(
        self,
        sel: Sequence[int],
        pub_tokens: jax.Array,
        bcast: BroadcastState | None,
        states: BatchedChannelState | Sequence[ChannelState],
        *,
        adaptive_k: bool,
        send_h: bool,
    ) -> ClientPhase:
        sel = check_unique_cohort(sel)
        cohort = [self.clients[i] for i in sel]
        states = list(states)
        idx, lora, frozen, opt = self._gather_cohort(sel)

        # -- lines 5-7: cohort distillation against the shared broadcast --
        if bcast is not None:
            for _ in range(self.distill_steps):
                lora, opt, _ = self._distill(
                    lora, frozen, opt, bcast.tokens, bcast.logits, bcast.h
                )

        # -- line 8: local fine-tuning, one vmapped update per step --
        for jb in self._stacked_batches(cohort, step_major=True):
            lora, opt, _ = self._train(lora, frozen, opt, jb)

        # -- lines 9-11: public inference + per-client adaptive top-k --
        n_samples = int(pub_tokens.shape[0])
        ks = self._budgets(states, n_samples, adaptive_k, len(cohort), send_h)

        logits, h = self._public(lora, frozen, pub_tokens)  # (C, P, V), (C, P, r)|None

        active, payloads, rank = self._upload_manifests(
            cohort, states, ks, n_samples, send_h
        )
        dense = h_out = None
        if active:
            take = jnp.asarray(active) if len(active) < len(cohort) else None
            act_logits = logits if take is None else logits[take]
            dense = topk_mask_batch(act_logits, [ks[i] for i in active])
            if self.quantize_wire:
                dense = fake_quant_dense(dense)
            if rank is not None and h is not None:
                h_out = h if take is None else h[take]

        self._scatter_cohort(idx, lora, opt)
        return ClientPhase(dense=dense, h=h_out, payloads=payloads, ks=ks)
