"""Serving step factories (pure functions, jitted by ServeSession).

  decode_step         — one token against the cache, single shared adapter
                        (the pre-redesign ``make_serve_step``, re-exported
                        from ``repro.launch.steps`` for compatibility).
  stacked_decode_step — one token, per-request adapters: gathers row
                        ``idx[b]`` of the adapter slab for request b and
                        merges into the shared frozen backbone INSIDE the
                        step, so one compiled executable serves any tenant
                        mix (``idx`` is traced int32 data).
  prefill_step        — full forward over a prompt, last-position logits.
"""

from __future__ import annotations

from typing import Callable

from repro.configs.base import ModelConfig
from repro.lora import merge_lora
from repro.models import decode_step as model_decode_step, prefill as model_prefill
from repro.serve.adapters import gather_adapters

__all__ = ["make_decode_step", "make_stacked_decode_step", "make_prefill_step"]


def make_decode_step(cfg: ModelConfig, *, window: int | None = None) -> Callable:
    def decode_step(params, cache, token):
        return model_decode_step(params, cfg, cache, token, window=window)

    return decode_step


def make_stacked_decode_step(cfg: ModelConfig, *, window: int | None = None) -> Callable:
    """(frozen, slab, idx, cache, token) -> (logits, cache) — the
    multi-tenant decode step.  ``frozen``: the shared backbone
    (split_lora()[1]); ``slab``: the adapter slab (slots leading axis);
    ``idx (B,) int32``: slab slot per request."""

    def stacked_decode_step(frozen, slab, idx, cache, token):
        params = merge_lora(gather_adapters(slab, idx), frozen)
        return model_decode_step(params, cfg, cache, token, window=window)

    return stacked_decode_step


def make_prefill_step(cfg: ModelConfig, *, window: int | None = None) -> Callable:
    def prefill_step(params, batch):
        logits, _ = model_prefill(params, cfg, batch, window=window)
        return logits

    return prefill_step
