"""Top-k sparsification (paper eqs. 3-4)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topk import densify, topk_mask_dense, topk_sparsify


def test_topk_matches_lax_topk():
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 100))
    s = topk_sparsify(x, 7)
    want_v, want_i = jax.lax.top_k(x, 7)
    np.testing.assert_array_equal(s.values, want_v)
    np.testing.assert_array_equal(s.indices, want_i)
    assert s.k == 7 and s.vocab == 100


def test_k_clamped_to_vocab():
    x = jnp.ones((2, 8))
    s = topk_sparsify(x, 99)
    assert s.k == 8


def test_densify_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 50))
    s = topk_sparsify(x, 50)  # full k
    np.testing.assert_allclose(densify(s), x, rtol=0, atol=0)


def test_densify_zeros_off_support():
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 64)) + 10.0  # all positive
    d = densify(topk_sparsify(x, 5))
    assert int(jnp.sum(d != 0)) == 4 * 5
    # kept entries are the largest
    kth = jnp.sort(x, axis=-1)[:, -5]
    assert bool(jnp.all(jnp.where(d != 0, x >= kth[:, None], True)))


def test_topk_mask_dense_equals_sparsify_densify():
    x = jax.random.normal(jax.random.PRNGKey(3), (6, 40))
    np.testing.assert_allclose(
        topk_mask_dense(x, 9), densify(topk_sparsify(x, 9)), atol=0
    )


def test_sparsify_idempotent():
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 30)) + 5.0
    once = densify(topk_sparsify(x, 6))
    twice = densify(topk_sparsify(once, 6))
    np.testing.assert_allclose(once, twice, atol=0)


def test_wire_union_helpers_pad_concat_take():
    """pad_wire/concat_wires/take_wire_rows — the heterogeneous engines'
    union-wire merge point: padding is a no-op on transmitted content,
    concatenation of two cohorts' wires densifies to the stacked per-cohort
    densifications, and row gather/permutation round-trips."""
    from repro.core.topk import (
        concat_wires, pad_wire, sparsify_wire, take_wire_rows, wire_densify,
    )

    x1 = jax.random.normal(jax.random.PRNGKey(5), (2, 3, 32))
    x2 = jax.random.normal(jax.random.PRNGKey(6), (3, 3, 32))
    w1 = sparsify_wire(x1, jnp.asarray([4, 0]), k_cap=4)     # incl. a dropout
    w2 = sparsify_wire(x2, jnp.asarray([8, 2, 5]), k_cap=8)  # wider bucket

    padded = pad_wire(w1, 8)
    assert padded.k_cap == 8 and padded.vocab == w1.vocab
    np.testing.assert_allclose(wire_densify(padded), wire_densify(w1), atol=0)
    assert pad_wire(w2, 8) is w2  # already at width: identity

    union = concat_wires([w1, w2])
    assert union.values.shape == (5, 3, 8)
    np.testing.assert_allclose(
        wire_densify(union),
        jnp.concatenate([wire_densify(w1), wire_densify(w2)]),
        atol=0,
    )

    perm = [3, 0, 4]
    taken = take_wire_rows(union, perm)
    np.testing.assert_allclose(
        wire_densify(taken), wire_densify(union)[jnp.asarray(perm)], atol=0
    )

    import pytest

    with pytest.raises(ValueError):
        pad_wire(w2, 4)  # cannot shrink
    with pytest.raises(ValueError):
        concat_wires([w1, sparsify_wire(x1, jnp.asarray([1, 1]), 2)._replace(vocab=64)])
