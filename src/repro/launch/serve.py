"""Serving CLI: a thin driver over :class:`repro.serve.ServeSession`.

Single-adapter smoke (the pre-redesign behaviour, honest timing):

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --tokens 32

Multi-tenant: give every request its own tenant adapter, paged through the
AdapterCache — from a federation checkpoint (``fed_train --ckpt-dir``) or
from synthetic random adapters when no checkpoint is given:

  PYTHONPATH=src python -m repro.launch.serve --adapters 8 --slots 8
  PYTHONPATH=src python -m repro.launch.serve --adapters 8 --from-ckpt runs/fed

Timing is split: the first decode step (jit compile + run) is reported
separately, throughput is STEADY-STATE decode tokens/sec after that warmup
— the pre-redesign script started its clock before the first jitted call
and folded ~seconds of XLA compile into tok/s.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHITECTURES, get_smoke_config
from repro.lora import lora_template, map_lora, split_lora
from repro.serve import (
    AdapterCache,
    ServeConfig,
    ServeSession,
    export_adapters,
    serving_params,
)


class _RandomAdapters:
    """Synthetic tenant population: tenant cid = adapter with randomized
    A AND B (fresh-init B is zero — the delta would vanish)."""

    def __init__(self, params, num_adapters: int, seed: int):
        self._lora, _ = split_lora(params)
        self.num_adapters = int(num_adapters)
        self._seed = seed

    def lora_row(self, cid: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self._seed), int(cid))
        counter = [0]

        def rnd(x):
            counter[0] += 1
            k = jax.random.fold_in(key, counter[0])
            return 0.05 * jax.random.normal(k, x.shape).astype(x.dtype)

        return map_lora(rnd, self._lora)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHITECTURES), default="gpt2-paper")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--adapters", type=int, default=0,
                    help="serve this many distinct tenants (0 = single-adapter)")
    ap.add_argument("--slots", type=int, default=8,
                    help="device adapter-cache slots")
    ap.add_argument("--from-ckpt", default=None,
                    help="page tenant adapters from this fed_train --ckpt-dir "
                         "(default: synthetic random adapters)")
    args = ap.parse_args(argv)

    if args.from_ckpt is not None and args.arch == "gpt2-paper":
        # fed_train trains REDUCED_CLIENT by default — the smoke config's
        # shapes (2 layers) would not match the checkpointed backbone
        from repro.configs.gpt2_paper import REDUCED_CLIENT as cfg
    else:
        cfg = get_smoke_config(args.arch)
    from repro.models import init as model_init

    params = model_init(jax.random.PRNGKey(args.seed), cfg)
    scfg = ServeConfig(
        model=cfg, batch=args.batch, cache_len=args.prompt_len + args.tokens,
        temperature=args.temperature, seed=args.seed,
    )

    adapters = None
    if args.adapters > 0:
        if cfg.lora is None:
            raise SystemExit(f"--adapters needs a LoRA-enabled arch; "
                             f"{args.arch} smoke config has none")
        if args.from_ckpt is not None:
            source = export_adapters(args.from_ckpt)
            params = serving_params(source, params)
        else:
            source = _RandomAdapters(params, args.adapters, args.seed)
        adapters = AdapterCache(
            source, like=lora_template(params), slots=args.slots
        )

    sess = ServeSession(scfg, params, adapters=adapters)
    if adapters is not None:
        tenant_ids = [i % source.num_adapters for i in range(args.batch)]
        slots = sess.attach(tenant_ids)
        print(f"[serve] tenants {tenant_ids} -> slots {slots.tolist()}")

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)
    ).astype(np.int32)

    sess.prefill(prompts)  # also warms up + compiles the decode step
    gen, logits = sess.decode(args.tokens)
    assert np.isfinite(np.asarray(logits)).all()

    s = sess.stats()
    mode = "stacked" if sess.attached else "single"
    steady = s["steady_step_s"]
    tok_s = args.batch / steady if steady > 0 else float("inf")
    print(f"[serve] {args.arch} ({mode}): compile+first step "
          f"{s['first_step_s'].get(mode, 0.0):.2f}s, steady decode "
          f"{steady * 1e3:.1f} ms/step = {tok_s:.1f} tok/s "
          f"({args.batch}x{args.tokens} tokens)")
    if adapters is not None:
        print(f"[serve] adapter cache: {s['adapter_cache']} "
              f"(slots={s['adapter_slots']})")
    print(f"[serve] decode executables: {s['executables']}")
    print("[serve] sample:", gen[0, :16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
