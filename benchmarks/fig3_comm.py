"""Paper Fig. 3: total communication cost (MB) to reach accuracy thresholds,
IID setting (the paper uses IID here 'due to the large variance under
Non-IID').

Reproduced claim: AdaLD reaches each threshold with the least uplink MB;
All-logits is 1-2 orders of magnitude more expensive.  Thresholds are
scaled to the reduced models' accuracy range.
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.gpt2_paper import REDUCED_CLIENT, REDUCED_SERVER  # noqa: E402
from repro.fed import FedConfig, run_federated  # noqa: E402
from repro.fed.rounds import METHODS  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "fig3.json")
THRESHOLDS = (0.05, 0.08, 0.12)  # reduced-scale counterparts of 0.70/0.75/0.79


def run(rounds: int = 10, quick: bool = False):
    if quick:
        rounds = 2
    client = REDUCED_CLIENT.with_overrides(num_layers=2, d_model=128, num_heads=4, d_ff=512)
    server = REDUCED_SERVER.with_overrides(
        num_layers=3, d_model=192, num_heads=4, num_kv_heads=4, d_ff=768
    )
    results: dict[str, dict] = {}
    for method in METHODS:
        from repro.data import make_fed_benchmark_dataset

        ds = make_fed_benchmark_dataset(client.vocab_size, seed=0)
        fed = FedConfig(
            method=method, num_clients=6, clients_per_round=3, rounds=rounds,
            public_size=256, public_batch=96, eval_size=256, local_steps=10,
            distill_steps=1, server_distill_steps=25, lr=2e-3, seed=0,
            non_iid=False,  # paper: IID for Fig. 3
        )
        r = run_federated(client, server, ds, fed)
        results[method] = {
            "mb_to_reach": {str(t): r.ledger.mb_to_reach(t) for t in THRESHOLDS},
            "uplink_mb_total": r.ledger.uplink_mb,
            "total_mb": r.ledger.total_mb,
            "mean_k": sum(r.mean_k) / len(r.mean_k),
            "best_acc": max(r.server_acc),
        }
        print(f"[fig3] {method:10s} uplink={r.ledger.uplink_mb:8.3f}MB "
              f"mean_k={results[method]['mean_k']:7.1f} "
              f"mb_to_reach={results[method]['mb_to_reach']}")
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(results, f, indent=1)
    return results


def bench(quick: bool = True):
    t0 = time.time()
    results = run(quick=quick)
    us = (time.time() - t0) * 1e6
    adald = results["adald"]["uplink_mb_total"]
    full = results["all_logits"]["uplink_mb_total"]
    return [("fig3_comm", us, f"adald_vs_all_logits_uplink={adald:.3f}MB/{full:.3f}MB")]


if __name__ == "__main__":
    run(rounds=int(sys.argv[1]) if len(sys.argv) > 1 else 10)
