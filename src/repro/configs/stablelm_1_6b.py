"""stablelm-1.6b — dense decoder, full multi-head attention (kv = q = 32).

[hf:stabilityai/stablelm-2-1_6b] 24 layers, d_model=2048, 32 heads
(num_kv_heads=32 → plain MHA), d_ff=5632, vocab 100352, LayerNorm,
rotary embeddings (partial in the release; full RoPE here), SiLU-gated MLP.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100_352,
    norm="layernorm",
    activation="swiglu",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    microbatches=4,
    max_seq_len=32_768,
    cite="hf:stabilityai/stablelm-2-1_6b",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    param_dtype="float32", compute_dtype="float32",
    remat=False,
    name="stablelm-smoke", num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
    d_ff=512, vocab_size=512, max_seq_len=256,
)
