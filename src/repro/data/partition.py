"""Non-IID client partitioning (paper §IV: Dirichlet, γ = 0.5)."""

from __future__ import annotations

import numpy as np

from repro.data.synthetic import IntentDataset

__all__ = ["dirichlet_partition", "iid_partition", "split_public_private"]


def dirichlet_partition(
    labels: np.ndarray, num_clients: int, *, gamma: float = 0.5, seed: int = 0, min_per_client: int = 2
) -> list[np.ndarray]:
    """Partition sample indices by class with a Dirichlet(γ) draw per class
    (the paper's heterogeneity model).  Returns one index array per client."""
    rng = np.random.default_rng(seed)
    num_classes = int(labels.max()) + 1
    client_indices: list[list[int]] = [[] for _ in range(num_clients)]
    for c in range(num_classes):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(num_clients, gamma))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for client, part in enumerate(np.split(idx, cuts)):
            client_indices[client].extend(part.tolist())
    out = []
    for client in range(num_clients):
        ids = np.array(sorted(client_indices[client]), dtype=np.int64)
        if ids.size < min_per_client:  # rebalance pathological draws
            donor = int(np.argmax([len(ci) for ci in client_indices]))
            take = np.array(client_indices[donor][:min_per_client], dtype=np.int64)
            client_indices[donor] = client_indices[donor][min_per_client:]
            ids = np.concatenate([ids, take])
        out.append(ids)
    return out


def iid_partition(n: int, num_clients: int, *, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    return [np.sort(part) for part in np.array_split(idx, num_clients)]


def split_public_private(
    ds: IntentDataset, public_size: int, *, seed: int = 0
) -> tuple[IntentDataset, IntentDataset]:
    """Carve out the shared public set (paper: 2,000 unlabeled samples)."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(ds))
    return ds.subset(idx[:public_size]), ds.subset(idx[public_size:])
