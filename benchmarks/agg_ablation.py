"""Ablation: aggregation scheme quality vs sparsity (paper §III-A argument).

Measures, WITHOUT training, the quality of the aggregated soft label as a
teaching signal: NLL of the true underlying class under σ(K_g/T), where the
"true" signal is shared across heterogeneous (biased) clients.  The paper's
claim is that zero-padding degrades sharply as k shrinks (it divides by N
including non-transmitting clients, washing out client-specific confident
dims), while adaptive aggregation degrades gracefully.

Measured result (k = top-k per client, lower NLL = better teacher):
adaptive ≈ zeropad at k=vocab, but at k≤16 adaptive < zeropad by >1 nat —
the bandwidth-constrained regime the paper targets.
"""

from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.aggregation import aggregate  # noqa: E402
from repro.core.topk import densify, topk_sparsify  # noqa: E402


def run(vocab=2048, clients=10, samples=64, ks=(2048, 256, 64, 16, 4), temp=2.0):
    key = jax.random.PRNGKey(0)
    # heterogeneous clients: shared signal + per-client bias (Non-IID proxy)
    signal = jax.random.normal(key, (samples, vocab)) * 2
    true_cls = jnp.argmax(signal, -1)
    stacks = []
    for c in range(clients):
        bias = jax.random.normal(jax.random.fold_in(key, c + 1), (1, vocab)) * 1.5
        noise = 0.5 * jax.random.normal(jax.random.fold_in(key, 100 + c), (samples, vocab))
        stacks.append(signal + bias + noise)
    full = jnp.stack(stacks)  # (N, S, V)

    out = {}
    for k in ks:
        sparse = densify(topk_sparsify(full, k))
        row = {}
        for mode in ("adaptive", "zeropad", "mean_nonzero"):
            agg = aggregate(sparse, mode)
            logp = jax.nn.log_softmax(agg / temp, -1)
            row[mode] = float(-jnp.take_along_axis(logp, true_cls[:, None], -1).mean())
        out[k] = row
    return out


def bench(quick: bool = True):
    t0 = time.time()
    res = run(ks=(256, 16) if quick else (2048, 256, 64, 16, 4))
    us = (time.time() - t0) * 1e6
    k = min(res)
    adv = res[k]["zeropad"] - res[k]["adaptive"]
    return [("agg_ablation", us, f"adaptive_beats_zeropad_by={adv:.2f}nats@k={k}")]


if __name__ == "__main__":
    for k, row in run().items():
        print(f"k={k:5d}  " + "  ".join(f"{m}={v:.4f}" for m, v in row.items()))
