"""Launchers: mesh construction, multi-pod dry-run, train/serve/fed drivers.

NOTE: importing ``repro.launch.dryrun`` sets XLA_FLAGS for 512 host devices —
import it only in a dedicated process (its CLI).  Everything else here is
import-safe.
"""

from repro.launch.mesh import V5E, make_host_mesh, make_production_mesh

__all__ = ["V5E", "make_host_mesh", "make_production_mesh"]
