"""Model / run configuration schema.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (the exact published spec, cited) and ``SMOKE_CONFIG`` (a reduced
same-family variant: ≤2 layers, d_model ≤ 512, ≤4 experts) for CPU tests.

Families:
  dense   — decoder-only transformer (GQA), optionally every-layer MoE off
  moe     — decoder-only with MoE MLPs
  ssm     — attention-free Mamba2 / SSD stack
  hybrid  — interleaved Mamba + attention (Jamba-style), optional MoE
  vlm     — dense decoder consuming text tokens + stub patch embeddings
  audio   — encoder-decoder; encoder consumes stub frame embeddings
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int  # per-expert hidden width
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss weight (Switch/GShard)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128  # N (SSD state size)
    head_dim: int = 64  # P
    expand: int = 2  # d_inner = expand * d_model
    chunk_size: int = 256  # SSD block length Q
    conv_width: int = 4
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    rank: int = 8
    alpha: float = 32.0
    dropout: float = 0.1
    # Which projections carry adapters.  'qv' matches standard practice and
    # the paper's GPT-2 setup.
    targets: tuple[str, ...] = ("q", "v")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None  # default d_model // num_heads
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # hybrid: layer i is attention iff i % attn_every == attn_offset,
    # else Mamba.  Jamba uses 1:7 (one attn per 8 layers).
    attn_every: int = 1
    attn_offset: int = 0
    # hybrid/moe interleave: layer i uses MoE MLP iff moe is set and
    # i % moe_every == moe_offset.  1 -> every layer.
    moe_every: int = 1
    moe_offset: int = 0
    # enc-dec (audio family): encoder_layers of bidirectional self-attn over
    # frontend embeddings; num_layers counts DECODER layers.
    encoder_layers: int = 0
    cross_attention: bool = False
    frontend: Literal["none", "vision", "audio"] = "none"
    # number of stub frontend embeddings (patches / frames) prepended or
    # encoded; used by input_specs.
    frontend_len: int = 256
    positional: Literal["rope", "learned", "none"] = "rope"
    rope_theta: float = 10000.0
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    activation: Literal["swiglu", "gelu"] = "swiglu"
    use_bias: bool = False
    tie_embeddings: bool = False
    # sliding-window attention (tokens).  None = full causal.  The launcher
    # enables window=4096 for full-attention archs at long_500k (DESIGN §5).
    sliding_window: int | None = None
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # Adam moment dtype; big configs use bfloat16 to fit HBM (DESIGN §4).
    optimizer_state_dtype: str = "float32"
    remat: bool = False
    # gradient-accumulation microbatches per train step (memory lever)
    microbatches: int = 1
    lora: LoRAConfig | None = None
    max_seq_len: int = 8192
    cite: str = ""

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(1, self.num_kv_heads) == 0, (
            "q heads must be a multiple of kv heads (GQA)"
        )
        if self.family in ("ssm",):
            assert self.ssm is not None
        if self.family == "hybrid":
            assert self.ssm is not None and self.attn_every > 1
        if self.family == "audio":
            assert self.encoder_layers > 0 and self.cross_attention

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def is_attention_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            return i % self.attn_every == self.attn_offset
        return True

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        return i % self.moe_every == self.moe_offset

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- analytic parameter counts (for roofline MODEL_FLOPS = 6·N·D) ----

    def _attn_params(self) -> int:
        hd = self.head_dim
        q = self.d_model * self.num_heads * hd
        kv = 2 * self.d_model * self.num_kv_heads * hd
        o = self.num_heads * hd * self.d_model
        return q + kv + o

    def _dense_mlp_params(self) -> int:
        mult = 3 if self.activation == "swiglu" else 2
        return mult * self.d_model * self.d_ff

    def _moe_mlp_params(self, active_only: bool) -> int:
        assert self.moe is not None
        mult = 3 if self.activation == "swiglu" else 2
        per_expert = mult * self.d_model * self.moe.d_ff
        router = self.d_model * self.moe.num_experts
        n = self.moe.top_k if active_only else self.moe.num_experts
        return n * per_expert + router

    def _ssm_params(self) -> int:
        assert self.ssm is not None
        d_in = self.ssm.expand * self.d_model
        nheads = d_in // self.ssm.head_dim
        n = self.ssm.state_dim
        # in_proj -> [z, x, B, C, dt], out_proj, conv, A, D, norms
        in_proj = self.d_model * (2 * d_in + 2 * n + nheads)
        out_proj = d_in * self.d_model
        conv = self.ssm.conv_width * (d_in + 2 * n)
        return in_proj + out_proj + conv + 2 * nheads

    def param_count(self, *, active_only: bool = False) -> int:
        """Approximate parameter count (embeddings + blocks).

        ``active_only=True`` counts only top-k experts per MoE layer —
        the N_active used for MoE MODEL_FLOPS.
        """
        total = self.vocab_size * self.d_model  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model  # lm head
        layers = 0
        for i in range(self.num_layers):
            if self.is_attention_layer(i):
                layers += self._attn_params()
            else:
                layers += self._ssm_params()
            if self.family == "ssm":
                # Mamba2 blocks have no separate MLP
                continue
            if self.is_moe_layer(i):
                layers += self._moe_mlp_params(active_only)
            else:
                layers += self._dense_mlp_params()
            layers += 2 * self.d_model  # norms
        total += layers
        # encoder stack (audio)
        for _ in range(self.encoder_layers):
            total += self._attn_params() + self._dense_mlp_params() + 2 * self.d_model
        if self.cross_attention:
            total += self.num_layers * (self._attn_params() + self.d_model)
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
