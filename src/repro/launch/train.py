"""LM training driver (the non-FL substrate path).

Runs real steps on whatever devices exist: on this CPU container use the
smoke configs; on a pod pass --production to build the 16x16 mesh and the
full config (the same code path the dry-run proves).

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --steps 20 \
      --batch 8 --seq 128   # smoke-scale real run
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import save_step
from repro.configs import ARCHITECTURES, get_config, get_smoke_config
from repro.data import make_lm_stream
from repro.launch.steps import make_train_step
from repro.models import init as model_init
from repro.models.frontends import synth_frontend_embeddings
from repro.optim import adamw_init


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHITECTURES), default="gpt2-paper")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--production", action="store_true",
                    help="full config on the production mesh (pod hardware)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.production:
        from repro.launch.mesh import make_production_mesh  # noqa: F401 (pod path)

        cfg = get_config(args.arch)
        raise SystemExit(
            "--production requires pod hardware; this container is CPU-only. "
            "The dry-run (repro.launch.dryrun) proves this path compiles."
        )
    cfg = get_smoke_config(args.arch)

    seq = min(args.seq, cfg.max_seq_len)
    tokens = make_lm_stream(
        vocab_size=cfg.vocab_size, seq_len=seq, num_samples=args.batch * args.steps,
        seed=args.seed,
    )
    params = model_init(jax.random.PRNGKey(args.seed), cfg)
    opt = adamw_init(params, state_dtype=cfg.optimizer_state_dtype)
    step_fn = jax.jit(make_train_step(cfg, lr=args.lr))

    losses = []
    t0 = time.time()
    for i in range(args.steps):
        batch = {"tokens": tokens[i * args.batch : (i + 1) * args.batch]}
        if cfg.frontend != "none":
            batch["frontend"] = synth_frontend_embeddings(cfg, args.batch, seed=i)
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
        print(f"step {i:4d}  loss {losses[-1]:.4f}")
    dt = time.time() - t0
    print(f"[train] {args.arch}: {args.steps} steps in {dt:.1f}s "
          f"({args.steps * args.batch * seq / dt:.0f} tok/s), "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert np.isfinite(losses).all(), "NaN loss"
    if args.ckpt_dir:
        path = save_step(args.ckpt_dir, args.steps, {"params": params})
        print(f"[train] checkpoint -> {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
