"""Optimizer + schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw_init, adamw_update, constant, global_norm, warmup_cosine, warmup_linear


def test_adamw_minimises_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, opt = adamw_update(grads, opt, params, lr=5e-2, weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_adamw_bf16_state_roundtrip():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    opt = adamw_init(params, state_dtype="bfloat16")
    assert opt.m["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.ones((4,), jnp.bfloat16)}
    new_p, new_opt = adamw_update(grads, opt, params, lr=1e-2)
    assert new_p["w"].dtype == jnp.bfloat16
    assert int(new_opt.count) == 1
    assert bool(jnp.all(new_p["w"] < params["w"]))


def test_grad_clipping():
    params = {"w": jnp.zeros((3,))}
    opt = adamw_init(params)
    huge = {"w": jnp.full((3,), 1e9)}
    new_p, _ = adamw_update(huge, opt, params, lr=1.0, grad_clip=1.0)
    assert bool(jnp.all(jnp.isfinite(new_p["w"])))
    assert float(jnp.max(jnp.abs(new_p["w"]))) <= 1.5  # one adam step, clipped


def test_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


def test_schedules():
    sc = warmup_cosine(1.0, 10, 100)
    assert float(sc(0)) == 0.0
    assert float(sc(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(sc(100)) == pytest.approx(0.1, rel=1e-2)  # final_frac
    lin = warmup_linear(2.0, 5, 50)
    assert float(lin(5)) == pytest.approx(2.0)
    assert float(lin(50)) == pytest.approx(0.0, abs=1e-6)
    assert float(constant(0.3)(123)) == pytest.approx(0.3)


def test_adamw_master_tracks_fp32_reference():
    """PR-6 bf16-buffer pattern: bf16 live params + fp32 master in the
    optimizer state stay close to the all-fp32 reference trajectory, while
    masterless bf16 params lose tiny updates to rounding."""
    key = jax.random.PRNGKey(0)
    w0 = jax.random.normal(key, (64,))
    g_keys = jax.random.split(jax.random.fold_in(key, 1), 20)

    # fp32 reference
    p32 = {"w": w0}
    o32 = adamw_init(p32)
    # bf16 live params with an fp32 master
    pbf = {"w": w0.astype(jnp.bfloat16)}
    obf = adamw_init(pbf, master_dtype="float32")
    assert obf.master["w"].dtype == jnp.float32

    for gk in g_keys:
        g = jax.random.normal(gk, (64,)) * 1e-3
        p32, o32 = adamw_update({"w": g}, o32, p32, lr=1e-3)
        pbf, obf = adamw_update(
            {"w": g.astype(jnp.bfloat16)}, obf, pbf, lr=1e-3
        )

    assert pbf["w"].dtype == jnp.bfloat16
    assert obf.master["w"].dtype == jnp.float32
    drift = float(jnp.max(jnp.abs(obf.master["w"] - p32["w"])))
    assert drift < 0.02, f"master drifted {drift} from the fp32 reference"
    # the live params are exactly the master's cast — never stale
    np.testing.assert_array_equal(
        np.asarray(pbf["w"]),
        np.asarray(obf.master["w"].astype(jnp.bfloat16)),
    )


def test_adamw_masterless_path_unchanged():
    """master=None (the default, and every pre-existing checkpoint) must be
    bitwise the pre-master behaviour — same arrays, master stays None."""
    params = {"w": jnp.array([1.0, -2.0, 0.5])}
    opt = adamw_init(params)
    assert opt.master is None
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    new_p, new_opt = adamw_update(g, opt, params, lr=1e-2)
    assert new_opt.master is None
    # hand-rolled single fp32 AdamW step (b1=.9, b2=.999, step 1 bias corr)
    m = 0.1 * jnp.asarray([0.1, 0.2, -0.3])
    v = 0.001 * jnp.asarray([0.1, 0.2, -0.3]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    want = params["w"] - 1e-2 * mhat / (jnp.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), np.asarray(want), rtol=1e-6)
