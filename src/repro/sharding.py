"""Logical-axis sharding rules (DESIGN §4).

Physical mesh axes:
  single-pod: ("data", "model") = (16, 16)
  multi-pod:  ("pod", "data", "model") = (2, 16, 16)

Logical roles:
  BATCH  — activation batch; shards over ("pod","data")
  FSDP   — weight-shard axis (ZeRO-3 style); shards over ("pod","data") so
           optimizer state for 398B-param configs fits HBM
  TENSOR — heads / d_ff / experts / vocab; shards over ("model",)
  SEQ    — decode KV-cache sequence axis; shards over ("model",)
           (flash-decoding layout, DESIGN §4)

Parameter specs are derived from pytree *paths* (the zoo's naming is the
contract; tested in tests/test_sharding.py).  A leading stacked-layer axis
(from scan-over-layers) is automatically skipped.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "COHORT_AXIS",
    "axis_names",
    "batch_axes",
    "cohort_mesh",
    "fsdp_axes",
    "param_specs",
    "batch_specs",
    "cache_specs",
    "opt_state_specs",
    "named",
    "tree_named",
]

# ---------------------------------------------------------------------------
# federated cohort axis
#
# The FL round engines' leading client axis is embarrassingly parallel
# (Algorithm 1 runs each selected client independently), so its device
# placement is a plain 1-D mesh — orthogonal to the production data/model
# mesh above.  One shared name + constructor keeps the fused client-phase
# shard_map and the fused-e2e in-body shard_map on the same axis contract.
# ---------------------------------------------------------------------------

COHORT_AXIS = "clients"


def cohort_mesh() -> Mesh:
    """1-D mesh over every addressable device, axis :data:`COHORT_AXIS` —
    where the round engines place the selected cohort (``shard_clients``).
    On CPU, exercised via ``XLA_FLAGS=--xla_force_host_platform_device_count``.
    """
    import numpy as np

    return Mesh(np.array(jax.devices()), (COHORT_AXIS,))


def axis_names(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fsdp_axes(mesh: Mesh):
    return batch_axes(mesh)


def _param_spec_for(path: tuple[str, ...], shape: tuple[int, ...], mesh: Mesh) -> P:
    """Spec for one parameter leaf given its path and shape."""
    ndim = len(shape)
    model_size = mesh.shape["model"]
    fsdp = fsdp_axes(mesh)
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""
    gparent = path[-3] if len(path) >= 3 else ""

    # ---- stacked-layer leading axes (posJ dicts under "stack"/"encoder") ----
    lead: tuple = ()
    core_ndim = ndim
    if any(p.startswith("pos") and p[3:].isdigit() for p in path):
        lead = (None,)
        core_ndim = ndim - 1

    def spec(*axes):
        assert len(axes) == core_ndim, (path, ndim, axes)
        return P(*(lead + axes))

    # ---- embeddings / heads ----
    if name in ("embed", "lm_head", "pos_embed"):
        return P("model", fsdp)  # (V, D): vocab tensor-sharded, D fsdp

    # ---- norms / scalars / vectors ----
    if parent in ("norm1", "norm2", "norm_x", "final_norm", "enc_norm") or name in (
        "scale",
        "bias",
    ) and core_ndim == 1 and parent not in ("gate_norm",):
        return spec(*([None] * core_ndim))
    if parent == "gate_norm":  # (d_inner,) — model-sharded like its activations
        return spec("model")

    # ---- attention projections ----
    if gparent in ("attn", "cross") or parent in ("attn", "cross"):
        if name == "b":
            return spec("model") if parent != "wo" else spec(None)
        if parent in ("wq", "wk", "wv"):
            return spec(fsdp, "model")
        if parent == "wo":
            return spec("model", fsdp)

    # ---- LoRA ----
    if name == "A":
        return spec(fsdp, None)
    if name == "B" and core_ndim == 2 and parent not in ("in_proj", "out_proj"):
        return spec(None, "model")

    # ---- MoE ----
    if parent == "router":
        return spec(fsdp, None) if core_ndim == 2 else spec(None)
    if name in ("up", "gate", "down") and core_ndim == 3:
        # 2D weight-stationary sharding (§Perf iteration 7): experts over
        # model, per-expert F over fsdp.  The expert einsums then need NO
        # weight all-gathers (the contraction dims are unsharded or match),
        # only an activation-sized all-reduce after `down` — replacing the
        # GB-scale gathered-weight buffers the scan held live.
        return spec("model", None, fsdp) if name != "down" else spec("model", fsdp, None)

    # ---- dense MLP ----
    if parent in ("up", "gate"):
        if name == "w":
            return spec(fsdp, "model")
        return spec("model")
    if parent == "down":
        if name == "w":
            return spec("model", fsdp)
        return spec(None)

    # ---- SSM (Mamba2) ----
    if parent in ("w_z", "w_x"):
        # (D, d_inner): inner dim tensor-sharded
        return spec(fsdp, "model") if name == "w" else spec("model")
    if parent == "w_bc":
        # B/C (2N wide): replicated — O(N) small
        return spec(fsdp, None) if name == "w" else spec(None)
    if parent == "w_dt":
        # dt heads: shard over model when divisible (jamba H=256), else
        # replicate (mamba2-130m H=24) — keeping dt/a head-sharded keeps the
        # (B,H,nc,Q,Q) SSD decay tensors head-sharded (§Perf iteration 4)
        div = shape[-1] % model_size == 0
        if name == "w":
            return spec(fsdp, "model") if div else spec(fsdp, None)
        return spec("model") if div else spec(None)
    if parent == "out_proj":
        if name == "w":
            return spec("model", fsdp)
        return spec(None)
    if name == "conv_x_w":
        return spec(None, "model")
    if name == "conv_x_b":
        return spec("model")
    if name in ("conv_bc_w",):
        return spec(None, None)
    if name in ("dt_bias", "a_log", "d_skip"):
        return spec("model") if shape[-1] % model_size == 0 else spec(None)
    if name == "conv_bc_b":
        return spec(None)

    # fallback: replicate
    return spec(*([None] * core_ndim))


def _path_strings(path) -> tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        elif hasattr(p, "idx"):
            out.append(f"idx{p.idx}")
        else:
            out.append(str(p))
    return tuple(out)


def param_specs(params_shape: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching a params (shape) pytree."""

    def one(path, leaf):
        return _param_spec_for(_path_strings(path), tuple(leaf.shape), mesh)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_state_specs(params_specs: Any, count_spec: P | None = None) -> Any:
    """AdamW state: moments shard exactly like params; count replicated."""
    from repro.optim.adamw import AdamWState

    return AdamWState(
        m=params_specs,
        v=params_specs,
        count=count_spec if count_spec is not None else P(),
    )


def batch_specs(mesh: Mesh, *, batch_shardable: bool = True, with_frontend: bool = False,
                with_labels: bool = True) -> dict:
    """Input batch: tokens/labels (B, S) batch-sharded (unless B=1)."""
    b = batch_axes(mesh) if batch_shardable else None
    out = {"tokens": P(b, None)}
    if with_labels:
        out["labels"] = P(b, None)
    if with_frontend:
        out["frontend"] = P(b, None, None)
    return out


def cache_specs(cache_shape: Any, mesh: Mesh, *, batch_shardable: bool = True) -> Any:
    """Decode cache: KV k/v (B, C, Kv, Dh) -> seq-sharded over model;
    SSM conv (B, W-1, ch) -> ch over model; state (B,H,P,N) -> H over model.
    All have a leading stacked-repeats axis from scan-over-layers."""
    b = batch_axes(mesh) if batch_shardable else None

    def one(path, leaf):
        names = _path_strings(path)
        nd = len(leaf.shape)
        last = names[-1]
        if last in ("k", "v") and nd == 5:  # (R, B, C, Kv, Dh)
            return P(None, b, "model", None, None)
        if last == "pos":  # (R, C)
            return P(None, "model")
        if last == "length":
            return P() if nd == 0 else P(None)
        if last == "conv_x":  # (R, B, W-1, d_inner)
            return P(None, b, None, "model")
        if last == "conv_bc":  # (R, B, W-1, 2N)
            return P(None, b, None, None)
        if last == "state":  # (R, B, H, P, N): H is not mesh-divisible for
            # every arch (mamba2-130m has 24 heads); N=128 always divides.
            return P(None, b, None, None, "model")
        if last == "enc_out":  # (B, F, D)
            return P(b, None, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


# ---------------------------------------------------------------------------
# activation sharding constraints (perf: §Perf iteration 1)
#
# XLA's sharding propagation loses the head axis through the GQA reshapes,
# replicating (B, H, S, T) attention scores on every device.  The launcher
# installs logical->mesh rules here; model code calls ``constrain`` at the
# few places propagation needs anchoring.  Default None = no-op (single-
# device tests, FL runtime).
# ---------------------------------------------------------------------------

_ACTIVATION_RULES: dict | None = None


def set_activation_sharding(mesh: Mesh | None) -> None:
    """Install (or clear, with None) activation-constraint rules."""
    global _ACTIVATION_RULES
    if mesh is None:
        _ACTIVATION_RULES = None
        return
    _ACTIVATION_RULES = {
        "batch": batch_axes(mesh),
        "heads": "model",
        "dff": "model",
        "vocab": "model",
        "kv": None,
    }


def rules_installed() -> bool:
    return _ACTIVATION_RULES is not None


def constrain(x, *logical: str | None):
    """with_sharding_constraint by logical axis names; no-op when rules are
    uninstalled.  Must run under the mesh context (the launcher's ``with
    mesh:``)."""
    if _ACTIVATION_RULES is None:
        return x
    spec = P(*[(_ACTIVATION_RULES.get(l) if l else None) for l in logical])
    return jax.lax.with_sharding_constraint(x, spec)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_named(mesh: Mesh, specs: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
