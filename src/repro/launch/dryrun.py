import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import sys  # noqa: E402

if "--cost-mode" in sys.argv:
    # python-unroll inner chunk loops BEFORE model modules import, so HLO
    # cost analysis sees every op (XLA counts while bodies once).
    os.environ["REPRO_UNROLL"] = "1"

"""Multi-pod dry-run: prove every (arch x shape x mesh) lowers + compiles.

For each combination this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod);
  2. builds ShapeDtypeStruct stand-ins for every model input (input_specs);
  3. ``jax.jit(step, in_shardings=..., out_shardings=...).lower(...).compile()``;
  4. prints ``compiled.memory_analysis()`` (HBM fit proof) and
     ``compiled.cost_analysis()`` (FLOPs/bytes for the roofline);
  5. parses the post-SPMD HLO for collective ops and sums their payload
     bytes (cost_analysis does not report collectives);
  6. writes one JSON record to experiments/dryrun/ for benchmarks/roofline.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--jobs 4]
"""

import argparse
import json
import re
import subprocess
import sys
import time

import jax
import numpy as np

from repro.configs import ARCHITECTURES, INPUT_SHAPES
from repro.configs.base import ModelConfig
from repro.launch.mesh import V5E, make_production_mesh
from repro.launch.steps import make_train_step
from repro.serve import make_decode_step as make_serve_step, make_prefill_step
from repro import sharding as sh

DEFAULT_OUT = "experiments/dryrun"

from repro.launch.policy import arch_shape_config, input_specs, window_for  # noqa: E402


# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(bf16|f32|f16|f64|s32|u32|s8|u8|pred|s64|u64)\[([\d,]*)\]")
_DTYPE_BYTES = {
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
    "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8,
}
COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)


def _bytes_of_shape_str(text: str) -> int:
    """Sum byte sizes of every typed buffer in an HLO result type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind result-payload bytes from post-SPMD HLO."""
    out: dict[str, int] = {c: 0 for c in COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result-defining lines look like:  %name = TYPE op-name(...)
        m = re.match(r"%?[\w\.\-]+ = (\([^)]*\)|[^ ]+) ([\w\-]+)\(", stripped)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        if op.rstrip("-start") in COLLECTIVES or op in COLLECTIVES:
            kind = op[: -len("-start")] if op.endswith("-start") else op
            if kind not in out:
                continue
            out[kind] += _bytes_of_shape_str(result_type)
            out["count"] += 1
    return out


# ---------------------------------------------------------------------------
# lowering per step kind
# ---------------------------------------------------------------------------


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool, cfg_override=None):
    shape = INPUT_SHAPES[shape_name]
    cfg = cfg_override if cfg_override is not None else arch_shape_config(arch, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    w = window_for(cfg, shape)

    from repro.models import init as model_init

    params_shape = jax.eval_shape(lambda k: model_init(k, cfg), jax.random.key(0))
    pspecs = sh.param_specs(params_shape, mesh)
    p_sh = sh.tree_named(mesh, pspecs)

    batch_shardable = shape.global_batch % int(np.prod([mesh.shape[a] for a in sh.batch_axes(mesh)])) == 0

    sh.set_activation_sharding(mesh)
    with mesh:
        if shape.kind == "train":
            from repro.optim import adamw_init

            opt_shape = jax.eval_shape(
                lambda p: adamw_init(p, state_dtype=cfg.optimizer_state_dtype), params_shape
            )
            ospecs = sh.opt_state_specs(pspecs)
            o_sh = sh.tree_named(mesh, ospecs)
            bspecs = sh.batch_specs(
                mesh, batch_shardable=batch_shardable,
                with_frontend=cfg.frontend != "none", with_labels=False,
            )
            b_sh = sh.tree_named(mesh, bspecs)
            step = make_train_step(cfg)
            specs = input_specs(cfg, shape, mesh)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_shape, opt_shape, specs["batch"])
        elif shape.kind == "prefill":
            bspecs = sh.batch_specs(
                mesh, batch_shardable=batch_shardable,
                with_frontend=cfg.frontend != "none", with_labels=False,
            )
            b_sh = sh.tree_named(mesh, bspecs)
            step = make_prefill_step(cfg, window=w)
            specs = input_specs(cfg, shape, mesh)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh), out_shardings=None)
            lowered = jitted.lower(params_shape, specs["batch"])
        else:  # decode
            specs = input_specs(cfg, shape, mesh)
            cspecs = sh.cache_specs(specs["cache"], mesh, batch_shardable=batch_shardable)
            c_sh = sh.tree_named(mesh, cspecs)
            t_sh = sh.tree_named(
                mesh, sh.batch_specs(mesh, batch_shardable=batch_shardable, with_labels=False)
            )["tokens"]
            # token is (B,): 1-D spec
            from jax.sharding import NamedSharding, PartitionSpec as P

            t_sh = NamedSharding(mesh, P(sh.batch_axes(mesh) if batch_shardable else None))
            step = make_serve_step(cfg, window=w)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, c_sh, t_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_shape, specs["cache"], specs["token"])
    sh.set_activation_sharding(None)
    return cfg, shape, mesh, lowered


def run_one(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str) -> dict:
    t0 = time.time()
    cfg, shape, mesh, lowered = lower_combo(arch, shape_name, multi_pod=multi_pod)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_rec = {}
    if mem is not None:
        for field in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            mem_rec[field] = int(getattr(mem, field, 0) or 0)
        print("memory_analysis:", mem_rec)

    cost = compiled.cost_analysis() or {}
    cost_rec = {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))}
    print("cost_analysis flops=%.4g bytes=%.4g" % (
        cost_rec.get("flops", -1), cost_rec.get("bytes accessed", -1)))

    coll = collective_bytes(compiled.as_text())
    print("collectives:", {k: f"{v/1e6:.1f}MB" for k, v in coll.items() if k != "count" and v},
          "count:", coll["count"])

    n_chips = int(np.prod(list(mesh.shape.values())))
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi_pod" if multi_pod else "single_pod",
        "chips": n_chips,
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "memory": mem_rec,
        "cost": cost_rec,
        "collectives": coll,
        "model_params": cfg.param_count(),
        "model_params_active": cfg.param_count(active_only=True),
        "microbatches": cfg.microbatches,
        "hw": V5E,
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{arch}__{shape_name}__{record['mesh']}.json"
    )
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"[dryrun] OK {arch} x {shape_name} x {record['mesh']} "
          f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s) -> {path}")
    return record


def _depth_reduced(cfg: ModelConfig, n: int) -> ModelConfig:
    """Config with n periods of layers (and n encoder layers), microbatch 1."""
    from repro.models.transformer import period_of

    p = period_of(cfg)
    kw = dict(num_layers=n * p, microbatches=1)
    if cfg.encoder_layers:
        kw["encoder_layers"] = n
    return cfg.with_overrides(**kw)


def run_cost(arch: str, shape_name: str, *, out_dir: str) -> dict:
    """Loop-corrected HLO cost estimation (roofline numerators).

    XLA's cost analysis counts while-loop bodies ONCE, so a scan-over-layers
    program under-reports FLOPs by ~num_layers x.  We lower the SAME step at
    depths of 1 and 2 layer-periods with inner chunk loops python-unrolled
    (REPRO_UNROLL=1), isolate the per-period body cost as the difference, and
    extrapolate:  total = f(P) + (R-1) * (f(2P) - f(P)).
    """
    assert os.environ.get("REPRO_UNROLL") == "1", "run via --cost-mode CLI"
    from repro.models.transformer import period_of

    shape = INPUT_SHAPES[shape_name]
    base_cfg = arch_shape_config(arch, shape)
    repeats = base_cfg.num_layers // period_of(base_cfg)
    if base_cfg.encoder_layers:
        assert base_cfg.encoder_layers // 1 == repeats, (
            "body extrapolation assumes equal encoder/decoder repeat counts"
        )

    results = []
    for n in (1, 2):
        cfg_n = _depth_reduced(base_cfg, n)
        _, _, mesh, lowered = lower_combo(
            arch, shape_name, multi_pod=False, cfg_override=cfg_n
        )
        compiled = lowered.compile()
        cost = compiled.cost_analysis() or {}
        coll = collective_bytes(compiled.as_text())
        results.append(
            {
                "flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "collectives": coll,
            }
        )
        print(f"[cost] {arch} x {shape_name} depth n={n}: "
              f"flops={results[-1]['flops']:.4g} coll={coll['count']}")

    f1, f2 = results

    def extrap(a, b):
        return a + (repeats - 1) * (b - a)

    est = {
        "flops": extrap(f1["flops"], f2["flops"]),
        "bytes": extrap(f1["bytes"], f2["bytes"]),
        "collectives": {
            k: extrap(f1["collectives"][k], f2["collectives"][k])
            for k in f1["collectives"]
        },
    }
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "single_pod",
        "kind": shape.kind,
        "repeats": repeats,
        "depth1": f1,
        "depth2": f2,
        "estimate": est,
        "model_params": base_cfg.param_count(),
        "model_params_active": base_cfg.param_count(active_only=True),
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__cost.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"[cost] OK {arch} x {shape_name}: est flops/device "
          f"{est['flops']:.4g} -> {path}")
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHITECTURES), default=None)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--cost-mode", action="store_true",
                    help="loop-corrected HLO cost estimation (single-pod)")
    ap.add_argument("--all", action="store_true", help="run the full matrix via subprocesses")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    if args.all:
        combos = [
            (a, s)
            for a in ARCHITECTURES
            if a != "gpt2-paper"
            for s in INPUT_SHAPES
        ]
        procs: list[tuple[tuple, subprocess.Popen]] = []
        failures = []
        pending = list(combos)
        while pending or procs:
            while pending and len(procs) < args.jobs:
                a, s = pending.pop(0)
                cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
                       "--shape", s, "--out", args.out]
                if args.multi_pod:
                    cmd.append("--multi-pod")
                if args.cost_mode:
                    cmd.append("--cost-mode")
                procs.append(((a, s), subprocess.Popen(cmd)))
            done = [(c, p) for c, p in procs if p.poll() is not None]
            procs = [(c, p) for c, p in procs if p.poll() is None]
            for c, p in done:
                if p.returncode != 0:
                    failures.append(c)
                    print(f"[dryrun] FAIL {c}")
            time.sleep(1.0)
        print(f"[dryrun] matrix done, {len(failures)} failures: {failures}")
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch and --shape (or --all) required"
    if args.cost_mode:
        run_cost(args.arch, args.shape, out_dir=args.out)
    else:
        run_one(args.arch, args.shape, multi_pod=args.multi_pod, out_dir=args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
