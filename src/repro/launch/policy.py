"""Shape/arch launch policy — import-safe (no jax device-state effects).

Shared by the dry-run, tests and benchmarks so the window/skip policy and
input stand-ins are defined exactly once.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig, ShapeConfig

__all__ = ["window_for", "arch_shape_config", "input_specs"]


def window_for(cfg: ModelConfig, shape: ShapeConfig) -> int | None:
    """DESIGN §5: full-attention archs get sliding window 4096 at long_500k;
    SSM/hybrid run natively (SSM state is O(1); jamba's sparse attention
    layers use the seq-sharded cache)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return 4096
    return None


def arch_shape_config(arch: str, shape: ShapeConfig) -> ModelConfig:
    cfg = get_config(arch)
    # decode/prefill don't train: microbatching is a train-only lever.
    if shape.kind != "train":
        cfg = cfg.with_overrides(microbatches=1)
    return cfg


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh=None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this step kind
    (weak-type-correct, shardable, no device allocation)."""
    from repro.models import init_cache
    from repro.models.model import input_token_len

    b = shape.global_batch
    cdt = np.dtype(cfg.compute_dtype)
    specs: dict = {}
    if shape.kind in ("train", "prefill"):
        s_text = input_token_len(cfg, shape.seq_len)
        specs["batch"] = {"tokens": jax.ShapeDtypeStruct((b, s_text), np.int32)}
        if cfg.frontend != "none":
            specs["batch"]["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.d_model), cdt
            )
    else:  # decode
        w = window_for(cfg, shape)
        specs["token"] = jax.ShapeDtypeStruct((b,), np.int32)
        specs["cache"] = jax.eval_shape(lambda: init_cache(cfg, b, shape.seq_len, window=w))
    return specs
