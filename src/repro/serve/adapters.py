"""Adapter slab: per-tenant LoRA rows stacked on device, gathered per request.

The serving memory model (ROADMAP "Personalized-adapter serving at fleet
scale"):

* ONE frozen backbone lives on device, shared by every tenant;
* a **slab** holds ``slots`` adapter rows stacked along a new leading axis —
  slot s of every leaf is tenant s's LoRA tree, in the exact
  :func:`repro.lora.split_lora` structure (None at frozen positions);
* a decode step receives the slab plus a per-request int32 slot index
  ``idx (B,)`` and gathers row ``idx[b]`` for request b — so one compiled
  executable serves a mixed batch of tenants.

Axis discipline: a client's LoRA row stores ``stack/posJ/lora/...`` leaves
stacked over layer REPEATS, ``(repeats, d, r)``; the decode ``fori_loop``
(transformer.stack_apply) slices axis 0 per repeat.  A slab gather yields
``(B, repeats, ...)`` — :func:`gather_adapters` therefore moves the batch
axis INSIDE the repeats axis for stack subtrees (``(repeats, B, ...)``) so
the per-repeat slice hands the attention LoRA a ``(B, d, r)`` batched
adapter, while top-level ``lora_head`` leaves stay ``(B, d, r)``.  The
batched contraction for row b is the same einsum over the same operands as
the single-adapter path (models/attention._lora_delta, models/model
._lm_logits), so stacked multi-tenant decode is bit-identical to serving
each request alone with its own adapter.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.lora import path_strings

__all__ = [
    "slab_init",
    "slab_set_row",
    "gather_adapters",
    "canonicalize_row",
]


def _is_stack_path(path) -> bool:
    return "stack" in path_strings(path)


def slab_init(like: Any, slots: int) -> Any:
    """Zeroed adapter slab: every leaf of ``like`` (an adapter-row tree or
    ShapeDtypeStruct skeleton, split_lora structure) gains a leading
    ``(slots,)`` axis."""
    return jax.tree.map(
        lambda x: jnp.zeros((slots,) + tuple(x.shape), x.dtype), like
    )


def slab_set_row(slab: Any, row: Any, slot: jax.Array) -> Any:
    """Write one adapter row into ``slab[slot]`` (pure; the AdapterCache
    jits this with the slab donated, so a page-in updates in place and the
    executable is compiled once — ``slot`` is traced data, not a constant)."""
    return jax.tree.map(
        lambda s, r: jax.lax.dynamic_update_slice_in_dim(
            s, r[None].astype(s.dtype), slot, axis=0
        ),
        slab,
        row,
    )


def gather_adapters(slab: Any, idx: jax.Array) -> Any:
    """Per-request adapter gather: leaf rows ``idx (B,)`` out of the slab.

    Returns a BATCHED adapter tree — stack-subtree leaves ``(repeats, B,
    ...)``, top-level leaves ``(B, ...)`` — ready to ``merge_lora`` into the
    shared frozen backbone for one mixed-tenant decode step.
    """

    def gather(path, leaf):
        rows = jnp.take(leaf, idx, axis=0)  # (B, ...)
        if _is_stack_path(path):
            rows = jnp.moveaxis(rows, 0, 1)  # (repeats, B, ...)
        return rows

    return jax.tree_util.tree_map_with_path(gather, slab)


def _dig(raw: Any, parts: tuple[str, ...]):
    node = raw
    for part in parts:
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def canonicalize_row(raw: Any, like: Any) -> Any:
    """Coerce a raw adapter row (e.g. the nested-dict tree a shard npz
    unflattens to, which omits frozen positions entirely) into the
    split_lora structure of ``like``, validating shapes/dtypes.  Rows that
    already have the canonical structure pass through unchanged — both are
    plain nested dicts, navigated by path."""

    def pick(path, leaf):
        parts = path_strings(path)
        val = _dig(raw, parts)
        if val is None:
            raise KeyError(
                f"adapter row is missing leaf {'/'.join(parts)!r} — the "
                "source does not match the model's LoRA structure"
            )
        if tuple(val.shape) != tuple(leaf.shape):
            raise ValueError(
                f"adapter leaf {'/'.join(parts)!r} has shape "
                f"{tuple(val.shape)}, model expects {tuple(leaf.shape)}"
            )
        return jnp.asarray(val, dtype=leaf.dtype)

    return jax.tree_util.tree_map_with_path(pick, like)
