"""seamless-m4t-large-v2 — encoder-decoder, multimodal (audio STUB).

[arXiv:2308.11596] SeamlessM4T v2 large transformer backbone: 24 encoder +
24 decoder layers, d_model=1024, 16 heads (MHA, kv=16), d_ff=8192, vocab
256206 (NLLB tokenizer).  The w2v-BERT speech frontend (mel + conv) is
stubbed per the assignment: ``input_specs()`` provides 1024 precomputed
frame embeddings consumed by the encoder; the decoder cross-attends to the
encoder output.  Decode shapes run the decoder (one token + KV cache) with
the fixed encoder output — enc-dec has a decoder, so no decode-shape skip.
Adaptation note (DESIGN §5): relative position bias → RoPE.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,  # decoder layers; + 24 encoder layers below
    encoder_layers=24,
    cross_attention=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256_208,  # 256206 padded +2 to divide the 16-way model axis
    frontend="audio",
    frontend_len=1024,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    microbatches=4,
    max_seq_len=32_768,
    cite="arXiv:2308.11596",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    param_dtype="float32", compute_dtype="float32",
    remat=False,
    name="seamless-smoke", num_layers=2, encoder_layers=2, d_model=256,
    num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=512, frontend_len=16,
    max_seq_len=256,
)
