"""Bench-regression gate (blocking CI step).

The whole-round benchmark used to be informational-only, which let its two
committed guarantees rot silently: the sparse aggregation path staying
dense-stack-free, and the one-call e2e round staying faster than the split
host pipeline.  This gate re-checks a FRESH quick bench record against the
committed full record and fails loudly on:

1. ``aggregation.agg_dense_stack_free`` false — the trace-inspection proof
   that no intermediate reaches the (N, B, V) dense stack regressed;
2. ``speedups.e2e_vs_fused_host`` below a floor — committed record says
   1.36x on this repo's reference box; the default floor 1.10x leaves a
   generous CI-noise margin while still catching a real regression to <= 1x;
3. ``aggregation.sparse_wire_bytes`` above the committed record's — the wire
   format's on-air shape grew (k_cap bucketing or layout regressed).  The
   wire bytes are deterministic for the bench's seeded channel, so this is
   an equality-shaped check: a legitimate format change must refresh the
   committed BENCH_round.json in the same PR.

The PR-6 quantized-wire record (BENCH_quant) is gated too, fresh AND
committed (see ``check_quant``): the dequantize-fused aggregation route must
stay dense-stack-free, the int8 wire must be strictly cheaper than the float
wire at equal shape, and the 8-bit entry pricing must never shrink the
adaptive mean k at the same Shannon budget.

The PR-7 scenario record (BENCH_scenario, written by examples/
scenario_suite.py) is gated as well (see ``check_scenario``): every channel-
dynamics preset must have well-formed accuracy-vs-communication curves, the
``iid`` preset must be bit-identical to the legacy no-scenario path, and —
because channel draws are keyed per (seed, round, cid) and cohorts are
prefix-stable — the quick run's per-round uplink bytes must match the
committed record's leading rounds byte-for-byte (a payload-bytes regression
gate; an intentional format change must refresh BENCH_scenario.json in the
same PR).

The PR-8 fault record (BENCH_faults, written by examples/fault_suite.py) is
gated too (see ``check_faults``): the ``none`` preset must stay
bit-identical to a run with no faults configured at all (both records), the
committed ``corruption`` run must actually engage (quarantined uploads > 0
with retransmission bytes on the ledger), the ``crashes`` run must crash
someone, and — fault draws being keyed per (seed, domain, round, cid) — the
quick run's per-round uplink bytes and quarantine counts must equal the
committed record's leading rounds exactly.

The PR-9 fleet record (BENCH_fleet, written by benchmarks/fleet_bench.py)
is gated fresh AND committed (see ``check_fleet``): the host fleet store
must stay bit-identical to the device store at N=10, its between-round
device footprint must stay flat as the fleet grows (and strictly below
the device store's stacked fleet), and the per-round latency at any fleet
size must stay within 1.15x of the 10-client shape — the out-of-core
round cost is O(cohort), not O(N).

The PR-10 serve record (BENCH_serve, written by benchmarks/serve_bench.py)
is gated fresh AND committed (see ``check_serve``): the stacked multi-
tenant decode must reproduce the classic merged single-adapter decode bit
for bit at >= 8 distinct adapters per batch through ONE compiled decode
executable, its steady throughput must stay within 0.9x of the single-
adapter baseline at equal batch, and the cache-thrash regime must actually
page (misses AND evictions on the adapter cache).

Run (CI does exactly this):

    python benchmarks/engine_bench.py --quick --round-only
    python benchmarks/engine_bench.py --quick --quant-only
    PYTHONPATH=src python examples/scenario_suite.py --quick
    PYTHONPATH=src python examples/fault_suite.py --quick
    PYTHONPATH=src python benchmarks/fleet_bench.py --quick
    PYTHONPATH=src python benchmarks/serve_bench.py --quick
    python benchmarks/check_bench.py

Pure stdlib; exits non-zero with a one-line reason per failed check.
"""

from __future__ import annotations

import argparse
import json
import os

_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")


def check(fresh: dict, committed: dict, *, min_speedup: float) -> list[str]:
    """Returns a list of human-readable failures (empty = gate passes)."""
    failures = []

    agg = fresh.get("aggregation", {})
    if agg.get("agg_dense_stack_free") is not True:
        failures.append(
            "agg_dense_stack_free is not true: the sparse aggregation path "
            "materialised an (N, B, V)-sized intermediate "
            f"(max_agg_intermediate_elems={agg.get('max_agg_intermediate_elems')}, "
            f"dense_stack_elems={agg.get('dense_stack_elems')})"
        )

    speedup = fresh.get("speedups", {}).get("e2e_vs_fused_host")
    if speedup is None:
        failures.append("fresh record has no speedups.e2e_vs_fused_host")
    elif speedup < min_speedup:
        committed_speedup = committed.get("speedups", {}).get("e2e_vs_fused_host")
        failures.append(
            f"e2e_vs_fused_host speedup {speedup:.2f}x fell below the gate "
            f"floor {min_speedup:.2f}x (committed record: "
            f"{committed_speedup}x) — the one-call round regressed vs the "
            "split host pipeline"
        )

    fresh_wire = fresh.get("aggregation", {}).get("sparse_wire_bytes")
    committed_wire = committed.get("aggregation", {}).get("sparse_wire_bytes")
    if fresh_wire is None or committed_wire is None:
        failures.append(
            "missing aggregation.sparse_wire_bytes "
            f"(fresh={fresh_wire}, committed={committed_wire})"
        )
    elif fresh_wire > committed_wire:
        failures.append(
            f"sparse_wire_bytes regressed: {fresh_wire} > committed "
            f"{committed_wire} — the wire's on-air shape grew; if the format "
            "change is intentional, refresh BENCH_round.json in this PR"
        )

    return failures


def check_quant(record: dict, label: str) -> list[str]:
    """Gate on a BENCH_quant record (applied to BOTH the fresh quick record
    and the committed full one — the guarantees are scale-independent):

    1. ``aggregation.agg_dense_stack_free`` true — the dequantize-fused
       aggregation route stayed free of the (N, B, V) dense stack;
    2. ``equal_shape`` — the int8 wire strictly cheaper than the float wire
       at the same (num_samples, k): the whole point of the format;
    3. ``speedups.quant_vs_float_mean_k`` >= 1 — the 8-bit entry pricing
       must never BUY LESS adaptive k at the same Shannon budget.
    """
    failures = []

    agg = record.get("aggregation", {})
    if agg.get("agg_dense_stack_free") is not True:
        failures.append(
            f"[{label}] agg_dense_stack_free is not true: the dequant-fused "
            "aggregation materialised an (N, B, V)-sized intermediate "
            f"(max_agg_intermediate_elems={agg.get('max_agg_intermediate_elems')}, "
            f"dense_stack_elems={agg.get('dense_stack_elems')})"
        )

    eq = record.get("equal_shape", {})
    q_bytes, f_bytes = eq.get("quant_uplink_bytes"), eq.get("float_uplink_bytes")
    if q_bytes is None or f_bytes is None:
        failures.append(
            f"[{label}] missing equal_shape bytes "
            f"(quant={q_bytes}, float={f_bytes})"
        )
    elif not q_bytes < f_bytes:
        failures.append(
            f"[{label}] quant wire not strictly cheaper at equal shape: "
            f"{q_bytes} >= {f_bytes} bytes at k={eq.get('k')}"
        )

    k_ratio = record.get("speedups", {}).get("quant_vs_float_mean_k")
    if k_ratio is None:
        failures.append(f"[{label}] record has no speedups.quant_vs_float_mean_k")
    elif k_ratio < 1.0:
        failures.append(
            f"[{label}] quant mean k fell BELOW the float run's "
            f"({k_ratio}x < 1x): 8-bit pricing must never shrink the "
            "adaptive k at the same budget"
        )

    return failures


_SCENARIO_PRESETS = ("iid", "gauss_markov", "jakes", "gilbert_elliott", "mobility")


def check_scenario(fresh: dict, committed: dict) -> list[str]:
    """Gate on the scenario-suite records (fresh quick run vs the committed
    full one):

    1. every preset's curves are present and well-formed in BOTH records —
       equal-length server_acc / cum_uplink_mb / uplink_bytes arrays,
       accuracies in [0, 1], cumulative uplink non-decreasing;
    2. ``iid_bit_identical`` true in BOTH — the ``iid`` preset stayed
       bit-identical (per-client k, uplink bytes, 1e-6 accuracies) to the
       legacy no-scenario i.i.d. path;
    3. the committed ``gilbert_elliott`` run actually burst (outage_rate
       > 0) — the two-state chain is engaged, not silently disabled;
    4. no payload-bytes regression: the quick run is a prefix of the full
       one (same seed, same per-(seed, round, cid) channel keying), so each
       fresh round's uplink bytes must not exceed the committed record's
       same-round bytes, per scenario.
    """
    failures = []

    for label, record in (("fresh", fresh), ("committed", committed)):
        scen = record.get("scenarios", {})
        missing = [p for p in _SCENARIO_PRESETS if p not in scen]
        if missing:
            failures.append(f"[scenario-{label}] missing presets: {missing}")
            continue
        for name in _SCENARIO_PRESETS:
            s = scen[name]
            acc = s.get("server_acc") or []
            cum = s.get("cum_uplink_mb") or []
            raw = s.get("uplink_bytes") or []
            if not acc or not (len(acc) == len(cum) == len(raw)):
                failures.append(
                    f"[scenario-{label}] {name}: malformed curves "
                    f"(len acc={len(acc)}, cum={len(cum)}, bytes={len(raw)})"
                )
                continue
            if not all(0.0 <= a <= 1.0 for a in acc):
                failures.append(
                    f"[scenario-{label}] {name}: server_acc out of [0, 1]"
                )
            if any(b > a for a, b in zip(cum[1:], cum)):
                failures.append(
                    f"[scenario-{label}] {name}: cum_uplink_mb not "
                    "non-decreasing"
                )
        if record.get("iid_bit_identical") is not True:
            failures.append(
                f"[scenario-{label}] iid_bit_identical is not true: the iid "
                "preset diverged from the legacy no-scenario i.i.d. path"
            )

    ge = committed.get("scenarios", {}).get("gilbert_elliott", {})
    if not ge.get("outage_rate", 0.0) > 0.0:
        failures.append(
            "[scenario-committed] gilbert_elliott outage_rate is not > 0: "
            "the burst chain never engaged"
        )

    for name in _SCENARIO_PRESETS:
        fb = fresh.get("scenarios", {}).get(name, {}).get("uplink_bytes") or []
        cb = committed.get("scenarios", {}).get(name, {}).get("uplink_bytes") or []
        if len(fb) > len(cb):
            failures.append(
                f"[scenario] {name}: fresh run has more rounds ({len(fb)}) "
                f"than the committed record ({len(cb)}) — cannot prefix-check"
            )
            continue
        for r, (f_bytes, c_bytes) in enumerate(zip(fb, cb)):
            if f_bytes > c_bytes:
                failures.append(
                    f"[scenario] {name} round {r}: uplink bytes regressed "
                    f"({f_bytes} > committed {c_bytes}) — if the payload "
                    "change is intentional, refresh BENCH_scenario.json in "
                    "this PR"
                )
                break

    return failures


_FAULT_PRESETS = ("none", "corruption", "crashes", "bursty", "lossy")


def check_faults(fresh: dict, committed: dict) -> list[str]:
    """Gate on the fault-suite records (fresh quick run vs the committed
    full one):

    1. every preset's curves are present and well-formed in BOTH records;
    2. ``no_fault_bit_identical`` true in BOTH — the ``none`` preset stayed
       indistinguishable from a run with no fault machinery configured;
    3. the committed ``corruption`` run actually engaged: quarantined
       uploads > 0 AND retransmission bytes > 0 on the ledger; the
       committed ``crashes`` run crashed someone;
    4. determinism prefix: fault draws are keyed per (seed, domain, round,
       cid), so each fresh round's uplink bytes and quarantine/crash counts
       must EQUAL the committed record's same-round values, per preset.
    """
    failures = []

    for label, record in (("fresh", fresh), ("committed", committed)):
        presets = record.get("presets", {})
        missing = [p for p in _FAULT_PRESETS if p not in presets]
        if missing:
            failures.append(f"[faults-{label}] missing presets: {missing}")
            continue
        for name in _FAULT_PRESETS:
            s = presets[name]
            acc = s.get("server_acc") or []
            raw = s.get("uplink_bytes") or []
            if not acc or len(acc) != len(raw):
                failures.append(
                    f"[faults-{label}] {name}: malformed curves "
                    f"(len acc={len(acc)}, bytes={len(raw)})"
                )
        if record.get("no_fault_bit_identical") is not True:
            failures.append(
                f"[faults-{label}] no_fault_bit_identical is not true: the "
                "'none' preset diverged from a run with no fault machinery"
            )

    corr = committed.get("presets", {}).get("corruption", {})
    if not sum(corr.get("num_quarantined") or [0]) > 0:
        failures.append(
            "[faults-committed] corruption preset never quarantined an "
            "upload: the fault injection is not engaging"
        )
    if not sum(corr.get("retrans_bytes") or [0.0]) > 0.0:
        failures.append(
            "[faults-committed] corruption preset shows no retransmission "
            "bytes: HARQ retries are not reaching the ledger"
        )
    crashes = committed.get("presets", {}).get("crashes", {})
    if not sum(crashes.get("num_crashed") or [0]) > 0:
        failures.append(
            "[faults-committed] crashes preset never crashed a client"
        )

    for name in _FAULT_PRESETS:
        fp = fresh.get("presets", {}).get(name, {})
        cp = committed.get("presets", {}).get(name, {})
        for field in ("uplink_bytes", "num_quarantined", "num_crashed"):
            fv = fp.get(field)
            cv = cp.get(field)
            if fv is None or cv is None:
                continue  # taps absent for the disabled 'none' preset
            if len(fv) > len(cv):
                failures.append(
                    f"[faults] {name}: fresh run has more rounds "
                    f"({len(fv)}) than the committed record ({len(cv)})"
                )
                break
            for r, (f_val, c_val) in enumerate(zip(fv, cv)):
                if f_val != c_val:
                    failures.append(
                        f"[faults] {name} round {r}: {field} diverged from "
                        f"the committed record ({f_val} != {c_val}) — fault "
                        "realisations are keyed, so this is a determinism "
                        "or accounting regression; an intentional change "
                        "must refresh BENCH_faults.json in this PR"
                    )
                    break

    return failures


def check_fleet(record: dict, label: str, *, max_latency_ratio: float = 1.15) -> list[str]:
    """Gate on a BENCH_fleet record (fresh quick AND committed full — the
    out-of-core guarantees are scale-independent):

    1. ``host_bit_identical`` true — the host-store N=10 run reproduced
       the device-store run exactly (per-round k, payload bytes, final
       fleet state);
    2. device-resident fleet bytes FLAT across N (ratio <= 1.01): the
       host store's between-round device footprint must not grow with the
       fleet — and must sit strictly below the device store's N=10 stack
       (the fleet actually left the device);
    3. per-round latency ratio vs the N=10 host run <= ``max_latency_ratio``
       for every N: streaming the cohort costs O(cohort), not O(N).
    """
    failures = []

    if record.get("host_bit_identical") is not True:
        failures.append(
            f"[{label}] host_bit_identical is not true: the host store "
            "diverged from the device store at N=10"
        )

    fleet = record.get("fleet", {})
    if len(fleet) < 2:
        failures.append(f"[{label}] fleet sweep has < 2 sizes: {sorted(fleet)}")
        return failures

    flat = record.get("ratios", {}).get("host_device_bytes_flat")
    if flat is None or flat > 1.01:
        failures.append(
            f"[{label}] host-store device bytes not flat across N "
            f"(max/min = {flat}): the between-round device footprint is "
            "scaling with the fleet"
        )
    dev_n10 = record.get("device_n10", {}).get("fleet_device_bytes")
    host_bytes = [e.get("fleet_device_bytes") for e in fleet.values()]
    if dev_n10 is None or any(b is None or not b < dev_n10 for b in host_bytes):
        failures.append(
            f"[{label}] host-store device bytes {host_bytes} not strictly "
            f"below the device store's N=10 stack ({dev_n10})"
        )

    for n, ratio in (record.get("ratios", {}).get("latency_vs_n10") or {}).items():
        if ratio > max_latency_ratio:
            failures.append(
                f"[{label}] N={n} per-round latency {ratio}x the N=10 host "
                f"run exceeds the {max_latency_ratio}x gate: round cost is "
                "no longer O(cohort)"
            )

    return failures


def check_serve(record: dict, label: str, *, min_ratio: float = 0.9) -> list[str]:
    """Gate on a BENCH_serve record (fresh quick AND committed full — the
    multi-tenant serving guarantees are size-independent):

    1. ``parity.multi_tenant_bit_identical`` true with >= 8 distinct
       adapters per batch: the stacked slab-gather decode reproduced the
       classic merged single-adapter decode bit for bit, per row;
    2. ONE stacked decode executable — serving any tenant mix costs one
       compile (slot assignment is traced data, not a trace constant);
    3. stacked steady throughput >= ``min_ratio`` x single-adapter at
       equal batch: per-request personalization is not a serving tax;
    4. the thrash regime actually thrashed (misses AND evictions on the
       adapter cache after warmup) and still decoded.
    """
    failures = []

    parity = record.get("parity", {})
    if parity.get("multi_tenant_bit_identical") is not True:
        failures.append(
            f"[{label}] multi_tenant_bit_identical is not true: the "
            "stacked decode diverged from classic merged decode"
        )
    if (parity.get("adapters_per_batch") or 0) < 8:
        failures.append(
            f"[{label}] parity probed only "
            f"{parity.get('adapters_per_batch')} adapters/batch (< 8)"
        )

    regimes = record.get("regimes", {})
    stacked = regimes.get("stacked_multi_tenant", {})
    if stacked.get("decode_executables") != 1:
        failures.append(
            f"[{label}] stacked decode compiled "
            f"{stacked.get('decode_executables')} executables (want 1): "
            "the tenant mix leaked into the trace"
        )
    if (stacked.get("adapters_per_batch") or 0) < 8:
        failures.append(
            f"[{label}] stacked regime served only "
            f"{stacked.get('adapters_per_batch')} adapters/batch (< 8)"
        )
    for name in ("single_adapter", "stacked_multi_tenant"):
        if not (regimes.get(name, {}).get("tok_s") or 0) > 0:
            failures.append(f"[{label}] regime {name} has no throughput")

    ratio = record.get("speedups", {}).get("stacked_vs_single")
    if ratio is None or ratio < min_ratio:
        failures.append(
            f"[{label}] stacked throughput {ratio}x single-adapter is "
            f"below the {min_ratio}x gate: per-request adapters became a "
            "serving tax"
        )

    thrash = regimes.get("cache_thrash", {})
    tc = thrash.get("cache", {})
    if not ((tc.get("misses") or 0) > 0 and (tc.get("evictions") or 0) > 0):
        failures.append(
            f"[{label}] thrash regime did not thrash (misses="
            f"{tc.get('misses')}, evictions={tc.get('evictions')}): the "
            "paging path went unexercised"
        )
    if not (thrash.get("tok_s_incl_paging") or 0) > 0:
        failures.append(f"[{label}] thrash regime has no throughput")

    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--fresh",
        default=os.path.join(_REPO_ROOT, "BENCH_round.quick.json"),
        help="record written by the quick bench run just executed",
    )
    ap.add_argument(
        "--committed",
        default=os.path.join(_REPO_ROOT, "BENCH_round.json"),
        help="the committed full-size reference record",
    )
    ap.add_argument(
        "--min-speedup", type=float, default=1.10,
        help="floor for speedups.e2e_vs_fused_host (committed: 1.36; the "
             "default leaves a generous CI-noise margin)",
    )
    ap.add_argument(
        "--quant-fresh",
        default=os.path.join(_REPO_ROOT, "BENCH_quant.quick.json"),
        help="quant record written by the quick bench run just executed",
    )
    ap.add_argument(
        "--quant-committed",
        default=os.path.join(_REPO_ROOT, "BENCH_quant.json"),
        help="the committed full-size quant reference record",
    )
    ap.add_argument(
        "--scenario-fresh",
        default=os.path.join(_REPO_ROOT, "BENCH_scenario.quick.json"),
        help="scenario record written by the quick suite run just executed",
    )
    ap.add_argument(
        "--scenario-committed",
        default=os.path.join(_REPO_ROOT, "BENCH_scenario.json"),
        help="the committed full-size scenario reference record",
    )
    ap.add_argument(
        "--faults-fresh",
        default=os.path.join(_REPO_ROOT, "BENCH_faults.quick.json"),
        help="fault record written by the quick suite run just executed",
    )
    ap.add_argument(
        "--faults-committed",
        default=os.path.join(_REPO_ROOT, "BENCH_faults.json"),
        help="the committed full-size fault reference record",
    )
    ap.add_argument(
        "--fleet-fresh",
        default=os.path.join(_REPO_ROOT, "BENCH_fleet.quick.json"),
        help="fleet record written by the quick bench run just executed",
    )
    ap.add_argument(
        "--fleet-committed",
        default=os.path.join(_REPO_ROOT, "BENCH_fleet.json"),
        help="the committed full-size fleet reference record",
    )
    ap.add_argument(
        "--fleet-max-latency-ratio", type=float, default=1.15,
        help="ceiling for the host store's per-round latency at any fleet "
             "size vs its N=10 run",
    )
    ap.add_argument(
        "--serve-fresh",
        default=os.path.join(_REPO_ROOT, "BENCH_serve.quick.json"),
        help="serve record written by the quick bench run just executed",
    )
    ap.add_argument(
        "--serve-committed",
        default=os.path.join(_REPO_ROOT, "BENCH_serve.json"),
        help="the committed full-size serve reference record",
    )
    ap.add_argument(
        "--serve-min-ratio", type=float, default=0.9,
        help="floor for stacked multi-tenant decode throughput vs the "
             "single-adapter baseline at equal batch (committed: 1.00)",
    )
    args = ap.parse_args(argv)

    for path in (args.fresh, args.committed):
        if not os.path.exists(path):
            print(f"[check_bench] FAIL: {path} does not exist "
                  "(run benchmarks/engine_bench.py --quick --round-only first)")
            return 2
    for path in (args.quant_fresh, args.quant_committed):
        if not os.path.exists(path):
            print(f"[check_bench] FAIL: {path} does not exist "
                  "(run benchmarks/engine_bench.py --quick --quant-only first)")
            return 2
    for path in (args.scenario_fresh, args.scenario_committed):
        if not os.path.exists(path):
            print(f"[check_bench] FAIL: {path} does not exist "
                  "(run examples/scenario_suite.py --quick first)")
            return 2
    for path in (args.faults_fresh, args.faults_committed):
        if not os.path.exists(path):
            print(f"[check_bench] FAIL: {path} does not exist "
                  "(run examples/fault_suite.py --quick first)")
            return 2
    for path in (args.fleet_fresh, args.fleet_committed):
        if not os.path.exists(path):
            print(f"[check_bench] FAIL: {path} does not exist "
                  "(run benchmarks/fleet_bench.py --quick first)")
            return 2
    for path in (args.serve_fresh, args.serve_committed):
        if not os.path.exists(path):
            print(f"[check_bench] FAIL: {path} does not exist "
                  "(run benchmarks/serve_bench.py --quick first)")
            return 2
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.committed) as f:
        committed = json.load(f)
    with open(args.quant_fresh) as f:
        quant_fresh = json.load(f)
    with open(args.quant_committed) as f:
        quant_committed = json.load(f)
    with open(args.scenario_fresh) as f:
        scenario_fresh = json.load(f)
    with open(args.scenario_committed) as f:
        scenario_committed = json.load(f)
    with open(args.faults_fresh) as f:
        faults_fresh = json.load(f)
    with open(args.faults_committed) as f:
        faults_committed = json.load(f)
    with open(args.fleet_fresh) as f:
        fleet_fresh = json.load(f)
    with open(args.fleet_committed) as f:
        fleet_committed = json.load(f)
    with open(args.serve_fresh) as f:
        serve_fresh = json.load(f)
    with open(args.serve_committed) as f:
        serve_committed = json.load(f)

    failures = check(fresh, committed, min_speedup=args.min_speedup)
    failures += check_quant(quant_fresh, "quant-fresh")
    failures += check_quant(quant_committed, "quant-committed")
    failures += check_scenario(scenario_fresh, scenario_committed)
    failures += check_faults(faults_fresh, faults_committed)
    failures += check_fleet(fleet_fresh, "fleet-fresh",
                            max_latency_ratio=args.fleet_max_latency_ratio)
    failures += check_fleet(fleet_committed, "fleet-committed",
                            max_latency_ratio=args.fleet_max_latency_ratio)
    failures += check_serve(serve_fresh, "serve-fresh",
                            min_ratio=args.serve_min_ratio)
    failures += check_serve(serve_committed, "serve-committed",
                            min_ratio=args.serve_min_ratio)
    if failures:
        for msg in failures:
            print(f"[check_bench] FAIL: {msg}")
        return 1
    print(
        "[check_bench] OK: dense-stack-free, "
        f"e2e_vs_fused_host={fresh['speedups']['e2e_vs_fused_host']}x >= "
        f"{args.min_speedup}x, sparse_wire_bytes="
        f"{fresh['aggregation']['sparse_wire_bytes']} <= committed "
        f"{committed['aggregation']['sparse_wire_bytes']}; quant gate: "
        "dequant dense-stack-free, equal-shape bytes "
        f"{quant_fresh['equal_shape']['quant_uplink_bytes']} < "
        f"{quant_fresh['equal_shape']['float_uplink_bytes']}, mean-k ratio "
        f"{quant_fresh['speedups']['quant_vs_float_mean_k']}x >= 1x; "
        f"scenario gate: {len(_SCENARIO_PRESETS)} preset curves well-formed, "
        "iid bit-identical to legacy, no per-round uplink-bytes regression; "
        f"fault gate: {len(_FAULT_PRESETS)} presets, none bit-identical to "
        "fault-free, corruption quarantines with retrans bytes on the "
        "ledger, per-round realisations match the committed record; fleet "
        "gate: host store bit-identical to device at N=10, device bytes "
        f"flat across {sorted(int(n) for n in fleet_fresh['fleet'])} "
        "clients, per-round latency within "
        f"{args.fleet_max_latency_ratio}x of the 10-client shape; serve "
        "gate: stacked multi-tenant decode bit-identical to classic "
        f"merged at {serve_fresh['parity']['adapters_per_batch']} "
        "adapters/batch in one executable, throughput "
        f"{serve_fresh['speedups']['stacked_vs_single']}x single-adapter "
        f">= {args.serve_min_ratio}x, adapter-cache thrash paged with "
        "evictions"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
