"""Edge-case parity: Pallas top-k kernel vs jnp oracle, sparse vs dense
aggregation, the wire scatter-accumulate kernel, and the batched per-client
top-k used by the round engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import aggregate, aggregate_sparse, scatter_wire_sums
from repro.core.topk import densify, topk_mask_batch, topk_mask_dynamic, topk_sparsify
from repro.kernels import ref
from repro.kernels.sparse_agg import scatter_wire_sums_pallas
from repro.kernels.topk_select import topk_mask_dynamic_pallas, topk_mask_pallas


class TestTopkKernelEdges:
    """topk_mask_pallas(interpret=True) vs kernels/ref.py on the cases the
    bisection is most likely to get wrong."""

    def test_ties_at_threshold(self):
        # four-way tie exactly at the k-th value: threshold semantics keeps
        # every tied entry, in kernel and oracle alike
        x = jnp.array([[5.0, 3.0, 3.0, 3.0, 3.0, 1.0, 0.0, -1.0]])
        for k in (2, 3, 4):
            got = topk_mask_pallas(x, k, interpret=True)
            want = ref.topk_mask_ref(x, k)
            np.testing.assert_allclose(got, want, atol=0)
            assert int(jnp.sum(got != 0)) == 5  # 5.0 + the four tied 3.0s

    def test_k_equals_one(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 333))
        got = topk_mask_pallas(x, 1, interpret=True)
        want = ref.topk_mask_ref(x, 1)
        np.testing.assert_allclose(got, want, atol=0)
        assert int(jnp.sum(got != 0)) == 4

    def test_k_equals_vocab(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (3, 64))
        got = topk_mask_pallas(x, 64, interpret=True)
        np.testing.assert_allclose(got, x, atol=0)
        # k > vocab clamps
        got = topk_mask_pallas(x, 1000, interpret=True)
        np.testing.assert_allclose(got, x, atol=0)

    def test_all_negative_logits(self):
        # masked-out entries become 0 which is LARGER than every kept value;
        # the kernel must still threshold on the k-th value, not on zero
        x = -jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (5, 200))) - 1.0
        for k in (1, 7, 200):
            got = topk_mask_pallas(x, k, interpret=True)
            want = ref.topk_mask_ref(x, k)
            np.testing.assert_allclose(got, want, atol=0)
            if k < 200:
                assert int(jnp.sum(got != 0)) == 5 * k

    def test_mixed_sign_and_constant_rows(self):
        const = jnp.full((2, 32), 3.5)
        got = topk_mask_pallas(const, 4, interpret=True)
        want = ref.topk_mask_ref(const, 4)
        np.testing.assert_allclose(got, want, atol=0)
        assert int(jnp.sum(got != 0)) == 2 * 32  # all tied -> all kept


class TestTopkDynamicKernelEdges:
    """topk_mask_dynamic_pallas(interpret=True) — the per-row-budget
    bisection (k as data, the fused engine's uplink sparsifier) — vs the
    jnp oracle and the pure-jnp traced-k implementation."""

    def test_mixed_budgets_per_row(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (5, 257))
        ks = jnp.asarray([0, 1, 17, 257, 300], jnp.int32)  # incl. 0 and > vocab
        got = topk_mask_dynamic_pallas(x, ks, interpret=True)
        want = ref.topk_mask_dynamic_ref(x, ks)
        np.testing.assert_allclose(got, want, atol=0)
        assert int(jnp.sum(got[0] != 0)) == 0  # k == 0: dropped straggler row
        assert int(jnp.sum(got[1] != 0)) == 1
        assert int(jnp.sum(got[3] != 0)) == 257  # k == vocab keeps everything

    def test_matches_pure_jnp_traced_k(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (3, 101)) * 4.0
        for k in (0, 1, 33, 101):
            got = topk_mask_dynamic_pallas(
                x, jnp.full((3,), k, jnp.int32), interpret=True
            )
            want = topk_mask_dynamic(x, jnp.int32(k))
            np.testing.assert_allclose(got, want, atol=0)

    def test_ties_at_threshold(self):
        x = jnp.array([[5.0, 3.0, 3.0, 3.0, 3.0, 1.0, 0.0, -1.0]])
        for k in (2, 3, 4):
            got = topk_mask_dynamic_pallas(x, jnp.asarray([k], jnp.int32), interpret=True)
            np.testing.assert_allclose(got, ref.topk_mask_ref(x, k), atol=0)
            assert int(jnp.sum(got != 0)) == 5  # threshold keeps all tied 3.0s

    def test_all_negative_rows(self):
        x = -jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (4, 64))) - 1.0
        ks = jnp.asarray([1, 7, 0, 64], jnp.int32)
        got = topk_mask_dynamic_pallas(x, ks, interpret=True)
        np.testing.assert_allclose(got, ref.topk_mask_dynamic_ref(x, ks), atol=0)
        assert int(jnp.sum(got[2] != 0)) == 0


class TestSparseVsDenseAggregation:
    """aggregate_sparse on raw (values, indices) payloads must equal the
    densify-then-aggregate path for every mode."""

    @pytest.mark.parametrize("mode", ["adaptive", "zeropad", "mean_nonzero"])
    @pytest.mark.parametrize("n,rows,vocab,k", [(3, 4, 96, 9), (5, 2, 128, 17), (2, 1, 64, 1)])
    def test_random_payloads(self, mode, n, rows, vocab, k):
        key = jax.random.PRNGKey(n * rows + vocab)
        logits = jax.random.normal(key, (n, rows, vocab)) * 3.0  # mixed sign
        sparse = topk_sparsify(logits, k)
        dense_out = aggregate(densify(sparse), mode)
        sparse_out = aggregate_sparse(sparse.values, sparse.indices, vocab, mode)
        np.testing.assert_allclose(dense_out, sparse_out, rtol=1e-4, atol=1e-6)

    @pytest.mark.parametrize("mode", ["adaptive", "zeropad", "mean_nonzero"])
    def test_full_k(self, mode):
        logits = jax.random.normal(jax.random.PRNGKey(9), (4, 3, 50))
        sparse = topk_sparsify(logits, 50)
        np.testing.assert_allclose(
            aggregate(densify(sparse), mode),
            aggregate_sparse(sparse.values, sparse.indices, 50, mode),
            rtol=1e-4, atol=1e-6,
        )


class TestScatterWireKernel:
    """scatter_wire_sums_pallas(interpret=True) vs the jnp oracle and the
    XLA scatter-add used inside the e2e round — the two-channel
    scatter-accumulate every wire aggregation mode reduces to."""

    @pytest.mark.parametrize(
        "n,rows,vocab,k", [(3, 4, 96, 9), (5, 2, 2048, 64), (1, 1, 33, 1), (2, 9, 64, 64)]
    )
    def test_random_wires(self, n, rows, vocab, k):
        key = jax.random.PRNGKey(n * 7 + rows + vocab)
        vals = jax.random.normal(key, (n, rows, k)) * 3.0
        idx = jax.vmap(
            lambda kk: jax.vmap(
                lambda kk2: jax.random.permutation(kk2, vocab)[:k]
            )(jax.random.split(kk, rows))
        )(jax.random.split(key, n)).astype(jnp.int32)
        a = jnp.abs(vals) * vals
        b = jnp.abs(vals)
        got_n, got_d = scatter_wire_sums_pallas(a, b, idx, vocab, interpret=True)
        ref_n, ref_d = ref.scatter_wire_sums_ref(a, b, idx, vocab)
        np.testing.assert_allclose(np.asarray(got_n), np.asarray(ref_n), rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(got_d), np.asarray(ref_d), rtol=1e-6, atol=1e-6)
        jnp_n, jnp_d = scatter_wire_sums(a, b, idx, vocab)
        np.testing.assert_allclose(np.asarray(got_n), np.asarray(jnp_n), rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(got_d), np.asarray(jnp_d), rtol=1e-6, atol=1e-6)

    def test_masked_entries_contribute_nothing(self):
        # zeroed contributions at an arbitrary valid index are no-ops — the
        # contract masked wire entries rely on
        a = jnp.asarray([[[2.0, 0.0]], [[0.0, 0.0]]])
        b = jnp.asarray([[[1.0, 0.0]], [[0.0, 0.0]]])
        idx = jnp.asarray([[[3, 0]], [[0, 0]]], jnp.int32)
        num, den = scatter_wire_sums_pallas(a, b, idx, 5, interpret=True)
        np.testing.assert_allclose(np.asarray(num[0]), [0, 0, 0, 2.0, 0], atol=0)
        np.testing.assert_allclose(np.asarray(den[0]), [0, 0, 0, 1.0, 0], atol=0)

    def test_duplicate_indices_across_clients_accumulate(self):
        # different clients hitting the same dim must ADD (the Σ_n of eq. 7)
        a = jnp.asarray([[[1.0]], [[2.0]], [[4.0]]])
        b = jnp.ones((3, 1, 1))
        idx = jnp.zeros((3, 1, 1), jnp.int32)
        num, den = scatter_wire_sums_pallas(a, b, idx, 4, interpret=True)
        assert float(num[0, 0]) == 7.0 and float(den[0, 0]) == 3.0

    def test_row_padding_isolated(self):
        # rows that land in the same grid block must not bleed into each other
        n, rows, vocab, k = 2, 5, 16, 3
        key = jax.random.PRNGKey(0)
        vals = jax.random.normal(key, (n, rows, k))
        idx = jax.random.randint(jax.random.fold_in(key, 1), (n, rows, k), 0, vocab)
        num, den = scatter_wire_sums_pallas(vals, vals, idx.astype(jnp.int32), vocab, interpret=True)
        ref_n, _ = ref.scatter_wire_sums_ref(vals, vals, idx.astype(jnp.int32), vocab)
        np.testing.assert_allclose(np.asarray(num), np.asarray(ref_n), rtol=1e-6, atol=1e-6)


class TestTopkMaskBatch:
    """The batched engine's per-client top-k must equal the stacked
    per-client reference bit-for-bit."""

    def test_matches_per_client_path(self):
        logits = jax.random.normal(jax.random.PRNGKey(3), (4, 5, 128))
        ks = [1, 17, 128, 64]
        got = topk_mask_batch(logits, ks)
        want = jnp.stack([densify(topk_sparsify(logits[i], k)) for i, k in enumerate(ks)])
        np.testing.assert_allclose(got, want, atol=0)

    def test_zero_budget_row_is_empty(self):
        logits = jax.random.normal(jax.random.PRNGKey(4), (3, 2, 32)) + 5.0
        got = topk_mask_batch(logits, [4, 0, 2])
        assert int(jnp.sum(got[1] != 0)) == 0
        assert int(jnp.sum(got[0] != 0)) == 2 * 4
        assert int(jnp.sum(got[2] != 0)) == 2 * 2

    def test_rejects_mismatched_budgets(self):
        with pytest.raises(ValueError):
            topk_mask_batch(jnp.zeros((2, 3, 8)), [1])


class TestScatterWireDequantKernel:
    """scatter_wire_sums_dequant_pallas(interpret=True) vs the jnp oracle and
    the pure-jnp route — the dequantize-fused aggregation primitive of the
    int8 quantized wire (values rebuilt from q * scale INSIDE the kernel;
    nothing of size O(N·B·V) is ever formed)."""

    @staticmethod
    def _quant_wire(n, rows, vocab, k, seed=0):
        from repro.core.topk import sparsify_wire

        key = jax.random.PRNGKey(seed)
        logits = jax.random.normal(key, (n, rows, vocab)) * 4.0
        ks = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, k + 1)
        return sparsify_wire(logits, ks, k, quantize=True)

    @pytest.mark.parametrize(
        "mode", ["adaptive", "zeropad", "mean_nonzero"]
    )
    @pytest.mark.parametrize("n,rows,vocab,k", [(3, 4, 96, 9), (2, 2, 512, 32)])
    def test_modes_match_ref_and_jnp(self, mode, n, rows, vocab, k):
        from repro.core.aggregation import scatter_wire_sums_dequant
        from repro.kernels.sparse_agg import scatter_wire_sums_dequant_pallas

        q = self._quant_wire(n, rows, vocab, k, seed=n + k)
        got_n, got_d = scatter_wire_sums_dequant_pallas(
            q.values, q.scale, q.mask.astype(jnp.int8), q.indices, vocab,
            mode, interpret=True,
        )
        ref_n, ref_d = ref.scatter_wire_sums_dequant_ref(
            q.values, q.scale, q.mask, q.indices, vocab, mode
        )
        np.testing.assert_allclose(np.asarray(got_n), np.asarray(ref_n), rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(got_d), np.asarray(ref_d), rtol=1e-6, atol=1e-6)
        jnp_n, jnp_d = scatter_wire_sums_dequant(
            q.values, q.scale, q.mask, q.indices, vocab, mode
        )
        np.testing.assert_allclose(np.asarray(got_n), np.asarray(jnp_n), rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(got_d), np.asarray(jnp_d), rtol=1e-6, atol=1e-6)

    def test_equals_dequantize_then_scatter(self):
        # fusing the dequant into the scatter must equal dequantizing the
        # wire first and feeding the float scatter (the unfused reference)
        from repro.core.topk import dequantize_wire
        from repro.kernels.sparse_agg import scatter_wire_sums_dequant_pallas

        q = self._quant_wire(3, 2, 64, 8, seed=11)
        f = dequantize_wire(q)
        v = jnp.where(f.mask, f.values, 0.0)
        a, b = jnp.abs(v) * v, jnp.abs(v)
        want_n, want_d = ref.scatter_wire_sums_ref(a, b, f.indices, 64)
        got_n, got_d = scatter_wire_sums_dequant_pallas(
            q.values, q.scale, q.mask.astype(jnp.int8), q.indices, 64,
            "adaptive", interpret=True,
        )
        np.testing.assert_allclose(np.asarray(got_n), np.asarray(want_n), rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d), rtol=1e-6, atol=1e-6)

    def test_straggler_rows_contribute_nothing(self):
        from repro.kernels.sparse_agg import scatter_wire_sums_dequant_pallas

        q = self._quant_wire(4, 1, 32, 4, seed=3)
        # zero out one client's mask entirely: must contribute nothing even
        # though its (stale) indices/values remain in the buffers
        mask = q.mask.at[1].set(False)
        num, den = scatter_wire_sums_dequant_pallas(
            q.values, q.scale, mask.astype(jnp.int8), q.indices, 32,
            "adaptive", interpret=True,
        )
        ref_n, ref_d = ref.scatter_wire_sums_dequant_ref(
            q.values.at[1].set(0), q.scale, mask, q.indices, 32, "adaptive"
        )
        np.testing.assert_allclose(np.asarray(num), np.asarray(ref_n), rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(den), np.asarray(ref_d), rtol=1e-6, atol=1e-6)
