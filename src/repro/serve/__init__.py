"""Multi-tenant personalized-adapter serving (the deploy half of the paper's
federation): one shared frozen backbone + a device slab of per-tenant LoRA
adapters, served by ONE donated jitted decode step per mode.

Public API:

  ServeConfig / ServeSession     — the serving loop (session.py)
  AdapterCache / CacheStats      — LRU slot paging over the slab (cache.py)
  export_adapters / serving_params — checkpoint -> serving handoff (export.py)
  make_decode_step / make_stacked_decode_step / make_prefill_step — the pure
                                   step factories (steps.py)
"""

from repro.serve.adapters import (
    canonicalize_row,
    gather_adapters,
    slab_init,
    slab_set_row,
)
from repro.serve.cache import AdapterCache, AdapterSource, CacheStats
from repro.serve.export import export_adapters, serving_params
from repro.serve.session import ServeConfig, ServeSession
from repro.serve.steps import (
    make_decode_step,
    make_prefill_step,
    make_stacked_decode_step,
)

__all__ = [
    "ServeConfig",
    "ServeSession",
    "AdapterCache",
    "AdapterSource",
    "CacheStats",
    "export_adapters",
    "serving_params",
    "make_decode_step",
    "make_stacked_decode_step",
    "make_prefill_step",
    "slab_init",
    "slab_set_row",
    "gather_adapters",
    "canonicalize_row",
]
