"""Pallas TPU kernel: fused adaptive logit aggregation (paper eqs. 6-7).

The jnp reference materialises three (N, rows, V) temporaries (|K|, weights,
weighted stack) — four HBM passes over N x rows x V.  This kernel reads each
input tile once and emits the aggregated tile directly:

    out = ( Σ_n |x_n| · x_n ) / ( Σ_n |x_n| + ε )

Grid: (row_blocks, vocab_tiles); each step owns an (N, R_b, V_b) input block
(the client axis N is small — the paper selects 10 clients/round — so it
rides whole in VMEM) and the (R_b, V_b) output tile.  Pure VPU elementwise +
client-axis reduction: the canonical memory-bound fusion.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["sparse_agg_pallas"]

ROWS_BLK = 8
VOCAB_BLK = 2048
EPS = 1e-12


def _agg_kernel(stack_ref, out_ref):
    x = stack_ref[...].astype(jnp.float32)  # (N, R_b, V_b)
    s = jnp.abs(x)
    num = jnp.sum(s * x, axis=0)
    den = jnp.sum(s, axis=0)
    out_ref[...] = (num / (den + EPS)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparse_agg_pallas(stack: jax.Array, *, interpret: bool = False) -> jax.Array:
    """(N, rows, vocab) densified sparse logits -> (rows, vocab) fp32."""
    assert stack.ndim == 3
    n, rows, vocab = stack.shape
    rb = min(ROWS_BLK, rows)
    vb = min(VOCAB_BLK, vocab)
    rpad = (-rows) % rb
    vpad = (-vocab) % vb
    x = jnp.pad(stack, ((0, 0), (0, rpad), (0, vpad))) if (rpad or vpad) else stack
    r_all, v_all = x.shape[1:]
    grid = (r_all // rb, v_all // vb)

    out = pl.pallas_call(
        _agg_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n, rb, vb), lambda r, j: (0, r, j))],
        out_specs=pl.BlockSpec((rb, vb), lambda r, j: (r, j)),
        out_shape=jax.ShapeDtypeStruct((r_all, v_all), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:rows, :vocab]
