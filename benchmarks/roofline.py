"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape) on the single-pod mesh, derive the three terms:

  compute    = HLO_FLOPs_per_chip / peak_bf16_FLOPs          [s]
  memory     = HLO_bytes_per_chip / HBM_bandwidth            [s]
  collective = collective_bytes_per_chip / ICI_link_bw       [s]

Sources: loop-corrected cost records (experiments/dryrun/*__cost.json —
XLA counts while bodies once, so the dry-run extrapolates per-period body
cost to full depth; see launch/dryrun.run_cost) + the baseline compile
records (memory_analysis, compile proof).  MODEL_FLOPS = 6·N·D for train,
2·N·D for inference (N = active params for MoE), D = processed tokens.

Outputs a markdown table (EXPERIMENTS.md §Roofline body) + CSV.
"""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import INPUT_SHAPES  # noqa: E402
from repro.launch.mesh import V5E  # noqa: E402

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(dryrun_dir: str = DRYRUN_DIR):
    base, cost = {}, {}
    for path in glob.glob(os.path.join(dryrun_dir, "*.json")):
        with open(path) as f:
            rec = json.load(f)
        key = (rec["arch"], rec["shape"])
        if path.endswith("__cost.json"):
            cost[key] = rec
        elif rec.get("mesh") == "single_pod":
            base[key] = rec
    return base, cost


def model_flops(rec_cost: dict, shape_name: str) -> float:
    """Analytic MODEL_FLOPS (global): 6·N·D train, 2·N·D inference."""
    shape = INPUT_SHAPES[shape_name]
    n = rec_cost["model_params_active"]
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    # decode: ONE token per sequence in the batch
    return 2.0 * n * shape.global_batch


def analyze_one(base: dict, cost: dict) -> dict:
    chips = base["chips"]
    est = cost["estimate"]
    flops_dev = est["flops"]  # per-device (SPMD program)
    bytes_dev = est["bytes"]
    coll = est["collectives"]
    coll_bytes_dev = sum(v for k, v in coll.items() if k != "count")

    t_compute = flops_dev / V5E["peak_bf16_flops"]
    t_memory = bytes_dev / V5E["hbm_bandwidth"]
    t_collective = coll_bytes_dev / V5E["ici_link_bandwidth"]

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_collective}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cost, base["shape"])
    hlo_global = flops_dev * chips
    ratio = mf / hlo_global if hlo_global else float("nan")

    mem = base.get("memory", {})
    hbm_used = mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)

    return {
        "arch": base["arch"],
        "shape": base["shape"],
        "chips": chips,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": ratio,
        "hbm_per_device_gb": hbm_used / 1e9,
        "fits_hbm": hbm_used <= V5E["hbm_bytes"],
        "collective_breakdown": {k: v for k, v in coll.items() if k != "count" and v},
        "compile_s": base.get("compile_s", float("nan")),
    }


def bottleneck_hint(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        if row["useful_ratio"] < 0.5:
            return "compute-bound but low useful ratio: cut remat/recompute or fuse non-matmul ops"
        return "compute-bound: near roofline; only lower-precision or better MXU tiling helps"
    if d == "memory":
        return "memory-bound: raise arithmetic intensity (fusion, bigger per-chip batch, bf16 IO)"
    return "collective-bound: reshard to cut all-gathers (FSDP prefetch, reduce-scatter grads) or overlap"


def main() -> int:
    base, cost = load_records()
    keys = sorted(set(base) & set(cost))
    missing = sorted(set(base) - set(cost))
    rows = [analyze_one(base[k], cost[k]) for k in keys]

    csv_lines = ["arch,shape,t_compute_s,t_memory_s,t_collective_s,dominant,"
                 "model_flops,hlo_flops_global,useful_ratio,hbm_gb,fits"]
    md = ["| arch | shape | compute s | memory s | collective s | dominant | "
          "MODEL/HLO | HBM GB/chip | fits |",
          "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        csv_lines.append(
            f"{r['arch']},{r['shape']},{r['t_compute_s']:.4g},{r['t_memory_s']:.4g},"
            f"{r['t_collective_s']:.4g},{r['dominant']},{r['model_flops']:.4g},"
            f"{r['hlo_flops_global']:.4g},{r['useful_ratio']:.3f},"
            f"{r['hbm_per_device_gb']:.2f},{r['fits_hbm']}"
        )
        md.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.3f} | {r['hbm_per_device_gb']:.2f} | "
            f"{'yes' if r['fits_hbm'] else 'NO'} |"
        )

    out_dir = os.path.join(os.path.dirname(__file__), "..", "experiments")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "roofline.csv"), "w") as f:
        f.write("\n".join(csv_lines) + "\n")
    with open(os.path.join(out_dir, "roofline.md"), "w") as f:
        f.write("\n".join(md) + "\n\n")
        f.write("### Dominant-term hints\n\n")
        for r in rows:
            f.write(f"- **{r['arch']} x {r['shape']}**: {bottleneck_hint(r)}\n")
    with open(os.path.join(out_dir, "roofline_rows.json"), "w") as f:
        json.dump(rows, f, indent=1)

    print("\n".join(md))
    if missing:
        print(f"\n[roofline] WARNING: no cost record yet for {missing}")
    print(f"\n[roofline] {len(rows)} rows -> experiments/roofline.{{csv,md}}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
