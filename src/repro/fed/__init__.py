from repro.fed.client import Client, ClientUpload
from repro.fed.cohort import (
    FamilyBucket,
    partition_fleet,
    split_cohort,
    validate_family_contracts,
)
from repro.fed.engine import (
    BatchedEngine,
    BroadcastState,
    ClientPhase,
    FusedE2EEngine,
    FusedEngine,
    HeteroClientEngine,
    HeteroFusedE2EEngine,
    RoundsTrajectory,
    SequentialEngine,
    make_engine,
)
from repro.fed.rounds import METHODS, FedConfig, FedRun, run_federated
from repro.fed.server import Server

__all__ = [
    "Client",
    "ClientUpload",
    "Server",
    "METHODS",
    "FedConfig",
    "FedRun",
    "run_federated",
    "BatchedEngine",
    "FusedEngine",
    "FusedE2EEngine",
    "HeteroClientEngine",
    "HeteroFusedE2EEngine",
    "SequentialEngine",
    "BroadcastState",
    "ClientPhase",
    "RoundsTrajectory",
    "FamilyBucket",
    "partition_fleet",
    "split_cohort",
    "validate_family_contracts",
    "make_engine",
]
