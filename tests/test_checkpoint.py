"""Checkpoint save/restore + PR-8 crash-safety contracts (atomic writes,
torn-file skipping, loud structure mismatches, metadata sidecars)."""

import os

import jax
import numpy as np
import pytest

from repro.checkpoint import (
    latest_step,
    restore,
    restore_step,
    save,
    save_step,
    step_metadata,
)
from repro.configs import get_smoke_config
from repro.models import init


def test_roundtrip(tmp_path):
    cfg = get_smoke_config("granite-moe-1b-a400m")
    params = init(jax.random.PRNGKey(0), cfg)
    save_step(str(tmp_path), 5, {"params": params}, arch=cfg.name)
    save_step(str(tmp_path), 9, {"params": params}, arch=cfg.name)
    assert latest_step(str(tmp_path)) == 9
    restored, step = restore_step(str(tmp_path), {"params": params})
    assert step == 9
    for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_leaves_with_path({"params": params}),
        jax.tree_util.tree_leaves_with_path(restored),
    ):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_empty(tmp_path):
    assert latest_step(str(tmp_path / "nope")) is None


def test_save_is_atomic_no_temp_residue(tmp_path):
    """save stages through a temp file and os.replace; after it returns the
    directory holds only the final names (no .tmp leftovers)."""
    path = str(tmp_path / "ck.npz")
    save(path, {"a": np.arange(4)}, metadata={"step": 1})
    names = sorted(os.listdir(tmp_path))
    assert names == ["ck.npz", "ck.npz.meta.json"]


def test_latest_step_skips_torn_files(tmp_path):
    """A truncated/corrupt step file (crash mid-copy) must not win: resume
    falls back to the newest LOADABLE step."""
    save_step(str(tmp_path), 3, {"a": np.arange(4)})
    # a torn "newer" checkpoint: right name, garbage bytes
    with open(tmp_path / "step_00000007.npz", "wb") as f:
        f.write(b"not a zip archive")
    assert latest_step(str(tmp_path)) == 3
    tree, step = restore_step(str(tmp_path), {"a": np.zeros(4, np.int64)})
    assert step == 3
    np.testing.assert_array_equal(tree["a"], np.arange(4))


def test_restore_missing_key_raises(tmp_path):
    path = str(tmp_path / "ck.npz")
    save(path, {"a": np.arange(4)})
    with pytest.raises(ValueError, match="no entry for 'b'"):
        restore(path, {"a": np.zeros(4, np.int64), "b": np.zeros(2)})


def test_restore_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "ck.npz")
    save(path, {"a": np.arange(4)})
    with pytest.raises(ValueError) as ei:
        restore(path, {"a": np.zeros((2, 2), np.int64)})
    # the error names the key and both shapes
    msg = str(ei.value)
    assert "'a'" in msg and "(4,)" in msg and "(2, 2)" in msg


def test_step_metadata(tmp_path):
    save_step(str(tmp_path), 2, {"a": np.arange(3)}, note="hello", acc=[0.1])
    meta = step_metadata(str(tmp_path), 2)
    assert meta == {"step": 2, "note": "hello", "acc": [0.1]}
    assert step_metadata(str(tmp_path), 9) is None
    # a torn sidecar is advisory: None, never an exception
    with open(tmp_path / "step_00000002.npz.meta.json", "w") as f:
        f.write("{truncated")
    assert step_metadata(str(tmp_path), 2) is None
