"""granite-moe-1b-a400m — small MoE, 32 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base] 24 layers, d_model=1024,
16 q heads / 8 kv heads, per-expert d_ff=512, vocab 49155, 32 experts
top-8 (~400M active of 1.3B).  The natural *client-side* model for the
paper's FL setting (SLM class).
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49_168,  # 49155 padded +13 to divide the 16-way model axis
    moe=MoEConfig(num_experts=32, top_k=8, d_ff=512, capacity_factor=1.25),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    microbatches=4,
    max_seq_len=8192,
    cite="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    param_dtype="float32", compute_dtype="float32",
    remat=False,
    name="granite-smoke", num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, moe=MoEConfig(num_experts=4, top_k=2, d_ff=128),
    max_seq_len=256,
)
