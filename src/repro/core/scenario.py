"""Declarative wireless scenarios: time-correlated fading, bursty outage,
and per-client SNR/mobility trajectories (paper §III-A generalised).

The paper's channel model draws i.i.d. Rayleigh-like fading per round; real
uplinks are time-correlated.  A :class:`ScenarioConfig` attached to
:class:`repro.core.channel.ChannelConfig` upgrades the simulator to a
*stateful* channel while keeping every guarantee of the i.i.d. model:

* **Gauss-Markov fading** — an AR(1) chain through a Gaussian copula.  Let
  ``p_t ~ Exp(1)`` be the i.i.d. Rayleigh power draws the simulator already
  makes.  Map each into a standard normal ``w_t = Phi^{-1}(1 - exp(-p_t))``,
  run the stationary recursion

      z_t = rho * z_{t-1} + sqrt(1 - rho^2) * w_t,    z_{-1} ~ N(0, 1)

  and map back: ``power_t = -log(1 - Phi(z_t))``.  Because ``z_t ~ N(0,1)``
  for every ``t``, the *marginal* of ``power_t`` is exactly the Exp(1)
  Rayleigh power of the i.i.d. model at any ``rho`` — correlation changes
  the trajectory, never the per-round distribution (so Shannon budgets stay
  calibrated).  The lag-1 autocorrelation of ``z`` is exactly ``rho``.
  ``rho = 0`` short-circuits to the RAW exponential draw — bit-identical to
  the i.i.d. simulator, not merely equal in distribution.

* **Jakes Doppler correlation** — classical Clarke/Jakes fading gives the
  channel gain an autocorrelation of ``J_0(2 pi f_d tau)`` at lag ``tau``,
  with Doppler ``f_d = v * f_c / c``.  A scenario parameterised by client
  velocity and carrier frequency derives the AR(1) ``rho`` from that
  closed form (one round = one coherence slot ``slot_s``).

* **Gilbert-Elliott outage** — a two-state (good/bad) Markov chain per
  client replaces the i.i.d. dropout coin:

      P(good -> bad) = p_gb,      P(bad -> good) = p_bg

  Bad state = outage (zero capacity, k = 0).  Mean bad-burst length is the
  closed form ``1 / p_bg``; the stationary bad probability is
  ``p_gb / (p_gb + p_bg)``.  Leaving ``p_gb``/``p_bg`` unset derives the
  i.i.d.-equivalent chain ``(dropout_prob, 1 - dropout_prob)`` whose two
  transition thresholds coincide, so the chain's draws are bit-identical to
  the memoryless dropout coin.

* **Deterministic SNR/mobility trajectories** — a per-client mean-SNR
  offset ``drift * t + amp * sin(2 pi (t / period + cid / N))`` modelling
  slow approach/retreat from the base station; pure data, no randomness.

Everything here is HOST-side f64 math (numpy + stdlib, no jax, no scipy) —
the same pure chain is replayed inside the compiled multi-round scan from
f32 data operands by :func:`repro.fed.steps.make_channel_step_fn`, so one
executable serves every scenario (``rho`` etc. enter as data, not as code).
"""

from __future__ import annotations

import dataclasses
import math
from statistics import NormalDist

import numpy as np

__all__ = [
    "ScenarioConfig",
    "SCENARIOS",
    "get_scenario",
    "bessel_j0",
    "jakes_rho",
    "uniform_to_gauss",
    "exp_to_gauss",
    "gauss_to_exp_power",
    "ar1_step",
    "ge_step",
    "ge_stationary_bad",
    "ge_mean_burst",
    "trajectory_offset_db",
]

_NORM = NormalDist()
# Copula clips: keep CDF values strictly inside (0, 1) so the inverse maps
# stay finite.  1 - 1e-16 is the largest f64 strictly below 1.
_U_LO = 1e-300
_U_HI = 1.0 - 1e-16
_SPEED_OF_LIGHT = 299_792_458.0


def bessel_j0(x: float) -> float:
    """Bessel function of the first kind, order zero.

    Abramowitz & Stegun 9.4.1 / 9.4.3 polynomial approximations (|err| <
    1.6e-7 over the real line) — enough for a fading correlation
    coefficient, without a scipy dependency the CI image doesn't ship.
    """
    ax = abs(float(x))
    if ax < 8.0:
        y = ax * ax
        num = 57568490574.0 + y * (-13362590354.0 + y * (651619640.7 + y * (
            -11214424.18 + y * (77392.33017 + y * -184.9052456))))
        den = 57568490411.0 + y * (1029532985.0 + y * (9494680.718 + y * (
            59272.64853 + y * (267.8532712 + y))))
        return num / den
    z = 8.0 / ax
    y = z * z
    p0 = 1.0 + y * (-0.1098628627e-2 + y * (0.2734510407e-4 + y * (
        -0.2073370639e-5 + y * 0.2093887211e-6)))
    q0 = -0.1562499995e-1 + y * (0.1430488765e-3 + y * (
        -0.6911147651e-5 + y * (0.7621095161e-6 + y * -0.934935152e-7)))
    xx = ax - 0.785398164
    return math.sqrt(0.636619772 / ax) * (
        math.cos(xx) * p0 - z * math.sin(xx) * q0
    )


def jakes_rho(velocity_mps: float, carrier_hz: float, slot_s: float) -> float:
    """AR(1) coefficient matching Jakes' Doppler autocorrelation.

    Clarke/Jakes: the fading autocorrelation at lag ``tau`` is
    ``J_0(2 pi f_d tau)`` with maximum Doppler shift ``f_d = v f_c / c``.
    One federated round advances the channel by one coherence slot
    ``slot_s``, so the round-to-round correlation is ``J_0(2 pi f_d T)``.
    Clipped to ``[0, 1)`` — past the first Bessel zero the closed form goes
    negative (anti-correlated fading), which the AR(1) surrogate does not
    model; such fast mobility is effectively i.i.d. round to round.
    """
    f_d = abs(velocity_mps) * carrier_hz / _SPEED_OF_LIGHT
    rho = bessel_j0(2.0 * math.pi * f_d * slot_s)
    return min(max(rho, 0.0), 1.0 - 1e-9)


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """Declarative channel-dynamics scenario.

    The default instance (``rho = 0``, no Gilbert-Elliott parameters, flat
    trajectory) reproduces the i.i.d. simulator bit for bit; every field is
    a *data* knob, so the compiled multi-round scan serves all scenarios
    from one executable.

    ``rho`` is the AR(1) fading correlation; setting ``velocity_mps``
    derives it from Jakes' model instead (``carrier_hz``/``slot_s``).
    ``p_gb``/``p_bg`` are the Gilbert-Elliott good->bad / bad->good
    transition probabilities; both-``None`` derives the i.i.d.-equivalent
    chain from ``ChannelConfig.dropout_prob``.  The trajectory fields add a
    deterministic per-client mean-SNR offset
    ``drift * t + amp * sin(2 pi (t / period + cid / num_clients))``.
    """

    name: str = "iid"
    rho: float = 0.0
    velocity_mps: float | None = None
    carrier_hz: float = 2.6e9
    slot_s: float = 5e-3
    p_gb: float | None = None
    p_bg: float | None = None
    snr_drift_db_per_round: float = 0.0
    snr_amp_db: float = 0.0
    snr_period_rounds: float = 50.0

    def __post_init__(self):
        if not (0.0 <= self.rho < 1.0):
            raise ValueError(f"rho must be in [0, 1), got {self.rho}")
        for field in ("p_gb", "p_bg"):
            v = getattr(self, field)
            if v is not None and not (0.0 <= v <= 1.0):
                raise ValueError(f"{field} must be in [0, 1], got {v}")
        if (self.p_gb is None) != (self.p_bg is None):
            raise ValueError("set p_gb and p_bg together (or neither)")
        if self.snr_period_rounds <= 0.0:
            raise ValueError("snr_period_rounds must be positive")

    @property
    def effective_rho(self) -> float:
        """AR(1) coefficient actually driving the fading chain."""
        if self.velocity_mps is not None:
            return jakes_rho(self.velocity_mps, self.carrier_hz, self.slot_s)
        return self.rho

    def ge_params(self, dropout_prob: float) -> tuple[float, float]:
        """(p_gb, p_bg), deriving the i.i.d.-equivalent chain when unset.

        ``(dropout_prob, 1 - dropout_prob)`` makes both transition
        thresholds equal to ``dropout_prob``, so the chain degenerates to
        the memoryless coin regardless of its state.
        """
        if self.p_gb is not None:
            return float(self.p_gb), float(self.p_bg)
        return float(dropout_prob), 1.0 - float(dropout_prob)

    def outage_active(self, dropout_prob: float) -> bool:
        p_gb, _ = self.ge_params(dropout_prob)
        return p_gb > 0.0


def uniform_to_gauss(u: np.ndarray | float) -> np.ndarray:
    """Map uniform draws to standard normals: ``z = Phi^{-1}(u)``."""
    u = np.clip(np.asarray(u, dtype=np.float64), _U_LO, _U_HI)
    flat = np.array([_NORM.inv_cdf(float(v)) for v in np.atleast_1d(u).ravel()])
    return flat.reshape(np.atleast_1d(u).shape)


def exp_to_gauss(p: np.ndarray | float) -> np.ndarray:
    """Map Exp(1) draws to standard normals through the shared copula:
    ``w = Phi^{-1}(1 - exp(-p))`` (f64, stdlib NormalDist — no scipy)."""
    u = np.clip(-np.expm1(-np.asarray(p, dtype=np.float64)), _U_LO, _U_HI)
    flat = np.array([_NORM.inv_cdf(float(v)) for v in np.atleast_1d(u).ravel()])
    return flat.reshape(np.atleast_1d(u).shape)


def gauss_to_exp_power(z: np.ndarray | float) -> np.ndarray:
    """Inverse copula map: ``power = -log(1 - Phi(z))`` — Exp(1) whenever
    ``z ~ N(0, 1)``, so the AR(1) chain's stationary marginal is exactly
    the i.i.d. model's Rayleigh power."""
    za = np.atleast_1d(np.asarray(z, dtype=np.float64))
    u = np.array([_NORM.cdf(float(v)) for v in za.ravel()]).reshape(za.shape)
    return -np.log1p(-np.clip(u, 0.0, _U_HI))


def ar1_step(z: np.ndarray, w: np.ndarray, rho: float) -> np.ndarray:
    """One stationary AR(1) update: ``z' = rho z + sqrt(1 - rho^2) w``."""
    return rho * np.asarray(z) + math.sqrt(max(0.0, 1.0 - rho * rho)) * np.asarray(w)


def ge_step(
    bad: np.ndarray, u: np.ndarray, p_gb: float, p_bg: float
) -> np.ndarray:
    """One Gilbert-Elliott transition from uniform draws ``u``.

    ``bad' = u < 1 - p_bg`` from the bad state (stay-bad probability),
    ``bad' = u < p_gb`` from the good state.  With the i.i.d.-equivalent
    parameters both thresholds are ``dropout_prob``, making the chain's
    draws bit-identical to the memoryless dropout coin.
    """
    return np.where(np.asarray(bad), u < 1.0 - p_bg, u < p_gb)


def ge_stationary_bad(p_gb: float, p_bg: float) -> float:
    """Stationary P(bad) = p_gb / (p_gb + p_bg) (0 when the chain never
    leaves the good state)."""
    denom = p_gb + p_bg
    return p_gb / denom if denom > 0.0 else 0.0


def ge_mean_burst(p_bg: float) -> float:
    """Closed-form mean bad-burst length: geometric escape, ``1 / p_bg``."""
    return 1.0 / p_bg if p_bg > 0.0 else math.inf


def trajectory_offset_db(
    scenario: ScenarioConfig, round_index: int, cid: int, num_clients: int
) -> float:
    """Deterministic mean-SNR offset of client ``cid`` at round ``t``:
    linear drift plus a per-client phase-shifted sinusoid (mobility around
    the cell).  Identically zero for the default scenario."""
    if scenario.snr_drift_db_per_round == 0.0 and scenario.snr_amp_db == 0.0:
        return 0.0
    phase = round_index / scenario.snr_period_rounds + cid / max(1, num_clients)
    return (
        scenario.snr_drift_db_per_round * round_index
        + scenario.snr_amp_db * math.sin(2.0 * math.pi * phase)
    )


# ---------------------------------------------------------------------------
# Named presets (the scenario suite's axes).  ``iid`` is today's behaviour;
# every other preset differs ONLY through data knobs, so all of them share
# one compiled multi-round executable.
# ---------------------------------------------------------------------------

SCENARIOS: dict[str, ScenarioConfig] = {
    # i.i.d. per-round fading + memoryless dropout — bit-identical to a
    # ChannelConfig without any scenario attached.
    "iid": ScenarioConfig(name="iid"),
    # Strongly time-correlated fading: a client in deep fade tends to stay
    # there for ~1/(1-rho) rounds (correlated stragglers).
    "gauss_markov": ScenarioConfig(name="gauss_markov", rho=0.9),
    # Pedestrian mobility at 2.6 GHz: rho = J0(2 pi f_d T) ~ 0.98 for
    # v = 1 m/s, T = 5 ms — slower-than-GM decorrelation.
    "jakes": ScenarioConfig(name="jakes", velocity_mps=1.0),
    # Bursty outage: mean bad burst 1/p_bg = 4 rounds, stationary outage
    # probability p_gb/(p_gb+p_bg) ~ 0.29.
    "gilbert_elliott": ScenarioConfig(
        name="gilbert_elliott", p_gb=0.1, p_bg=0.25
    ),
    # Correlated fading + deterministic per-client mobility: clients orbit
    # the base station (+/- 6 dB sinusoid) while slowly drifting away.
    "mobility": ScenarioConfig(
        name="mobility", rho=0.9, snr_amp_db=6.0,
        snr_drift_db_per_round=-0.05, snr_period_rounds=40.0,
    ),
}


def get_scenario(name: "str | ScenarioConfig | None") -> ScenarioConfig | None:
    """Resolve a scenario by preset name (pass-through for configs/None)."""
    if name is None or isinstance(name, ScenarioConfig):
        return name
    try:
        return SCENARIOS[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; known presets: {sorted(SCENARIOS)}"
        ) from None
