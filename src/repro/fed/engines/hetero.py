"""Family-bucketed engines for heterogeneous fleets."""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import BatchedChannelState, ChannelState
from repro.core.protocol import UplinkPayload
from repro.core.topk import (
    QuantizedWire,
    SparseWire,
    concat_wires,
    take_wire_rows,
)
from repro.fed import steps as fed_steps
from repro.fed.client import Client
from repro.fed.engines.base import (
    BroadcastState,
    ClientPhase,
    RoundsTrajectory,
    _channel_scan_ops,
    _ServerOwnerMixin,
    check_unique_cohort,
    k_cap_bucket,
)
from repro.fed.engines.batched import BatchedEngine
from repro.fed.engines.fused import FusedEngine
from repro.fed.store import FleetStore

__all__ = ["HeteroClientEngine", "HeteroFusedE2EEngine"]


class HeteroClientEngine:
    """Family-bucketed CLIENT-phase engine for heterogeneous fleets.

    The fleet is partitioned into homogeneous family buckets
    (:func:`repro.fed.cohort.partition_fleet`); each bucket runs its own
    batched/fused sub-engine — one vmapped, donated executable per family —
    and a round's uploads merge in the model-agnostic logit space: the
    per-bucket densified stacks concatenate into one cohort-ordered
    ``(T, P, V)`` stack (vocab is the shared exchange contract, so the
    unchanged server aggregation consumes it exactly as a homogeneous
    cohort's).  ``ks``/payload accounting is reassembled in cohort order,
    so the ledger is bit-identical to the sequential reference over the
    same clients.

    Fleet-state ownership (including ``fleet_store="host"``) lives in the
    per-bucket sub-engines: each bucket carries its own
    :class:`repro.fed.store.FleetStore`, so a heterogeneous fleet streams
    cohorts bucket-by-bucket with O(cohort) device residency.
    """

    name = "hetero"

    def __init__(self, kind: str, clients: list[Client], **kwargs):
        from repro.fed.cohort import fleet_index, partition_fleet, validate_family_contracts

        self.buckets = partition_fleet(clients)
        validate_family_contracts(self.buckets)
        self.kind = kind
        sub_cls = {"batched": BatchedEngine, "fused": FusedEngine}[kind]
        sub_kwargs = dict(kwargs)
        if kind == "batched":
            sub_kwargs.pop("shard_clients", None)
            sub_kwargs.pop("use_kernels", None)
        self._engines = [
            sub_cls([clients[i] for i in b.client_ids], b.cfg, **sub_kwargs)
            for b in self.buckets
        ]
        self._where = fleet_index(self.buckets)

    @property
    def store_kind(self) -> str:
        return self._engines[0].store_kind

    def client_params(self, cid: int):
        bi, local = self._where[int(cid)]
        return self._engines[bi].client_params(local)

    def fleet_state(self) -> dict:
        return {f"bucket{i}": e.fleet_state() for i, e in enumerate(self._engines)}

    def load_fleet_state(self, state: dict) -> None:
        for i, e in enumerate(self._engines):
            e.load_fleet_state(state[f"bucket{i}"])

    def save_fleet_shards(self, dir_path: str) -> None:
        """Shard every bucket's fleet into ONE directory (per-bucket
        ``bucket{i}_*`` prefixes keep the ranges disjoint)."""
        for i, e in enumerate(self._engines):
            e.save_fleet_shards(dir_path, prefix=f"bucket{i}")

    def load_fleet_shards(self, dir_path: str) -> None:
        for i, e in enumerate(self._engines):
            e.load_fleet_shards(dir_path, prefix=f"bucket{i}")

    def prefetch_cohort(self, sel: Sequence[int]) -> None:
        """Forward the next-round hint bucket-locally, exactly as
        :meth:`run_round` will fetch it."""
        from repro.fed.cohort import split_cohort

        for b, _pos, local in split_cohort(self.buckets, sel):
            self._engines[b.index].prefetch_cohort(local)

    def run_round(
        self,
        sel: Sequence[int],
        pub_tokens: jax.Array,
        bcast: BroadcastState | None,
        states: BatchedChannelState | Sequence[ChannelState],
        *,
        adaptive_k: bool,
        send_h: bool,
    ) -> ClientPhase:
        from repro.fed.cohort import split_cohort

        sel = check_unique_cohort(sel)
        states = list(states)
        ks = [0] * len(sel)
        merged = []  # (cohort position, dense row, h row, payload)
        for b, pos, local in split_cohort(self.buckets, sel):
            phase = self._engines[b.index].run_round(
                local, pub_tokens, bcast, [states[p] for p in pos],
                adaptive_k=adaptive_k, send_h=send_h,
            )
            for p, k in zip(pos, phase.ks):
                ks[p] = k
            tx = [p for p, k in zip(pos, phase.ks) if k > 0]
            for j, p in enumerate(tx):
                merged.append((
                    p,
                    None if phase.dense is None else phase.dense[j],
                    None if phase.h is None else phase.h[j],
                    phase.payloads[j],
                ))
        # transmitters back into cohort order: the union stack then reads
        # exactly like a homogeneous engine's (and the payload manifest
        # order matches the sequential reference)
        merged.sort(key=lambda entry: entry[0])
        dense = jnp.stack([d for _, d, _, _ in merged]) if merged else None
        h = (
            jnp.stack([h_row for _, _, h_row, _ in merged])
            if merged and merged[0][2] is not None
            else None
        )
        return ClientPhase(
            dense=dense, h=h, payloads=[m[3] for m in merged], ks=ks
        )


class HeteroFusedE2EEngine(_ServerOwnerMixin):
    """Family-bucketed end-to-end engine: one fused client-phase executable
    PER FAMILY BUCKET, one union sparse wire, one compiled server phase.

    This is the paper's actual scenario — clients with different
    architectures federating through the shared logit space — served by the
    fast-engine machinery:

    * the fleet partitions into homogeneous family buckets
      (`repro.fed.cohort`); each bucket keeps its LoRA/opt state in its own
      :class:`repro.fed.store.FleetStore` (a :class:`BatchedEngine` per
      bucket is the state holder) and runs its whole client phase —
      distill, fine-tune scan, public inference, sparse-wire top-k with
      per-client ``k`` as DATA — as one donated compiled call
      (:func:`repro.fed.steps.make_bucket_client_phase_fn`), with
      ``frozen_ax=0`` stacked backbones for buckets whose clients carry
      distinct frozen trees;
    * the buckets' wires concatenate into ONE vocab-indexed union wire
      (:func:`repro.core.topk.concat_wires` semantics, materialised
      in-order here), and the eq.-8 projections align across families by
      the shared LoRA rank — so the UNCHANGED server phase
      (:func:`repro.fed.steps.make_server_phase_fn`: wire aggregation,
      server-distill scan, broadcast recompute) runs exactly once per
      round, family-blind;
    * :meth:`run_rounds` scans R whole heterogeneous rounds inside one
      compiled dispatch: per-bucket fleet state rides in the scan carry
      (frozen stacks included — device store only; a host store falls back
      to the per-round driver), per-round variable family participation is
      handled by padding each bucket to its block-wide max cohort slice
      with masked ``k = 0`` rows that compute alongside the round but
      transmit nothing and scatter into a write-only scratch row, and the
      in-scan eval tap reports the server accuracy plus ONE accuracy PER
      FAMILY.
    """

    name = "hetero_fused_e2e"

    def __init__(
        self,
        clients: list[Client],
        *,
        server,
        num_classes: int,
        lr: float = 1e-3,
        distill_lr: float = 1e-3,
        temperature: float = 2.0,
        lam: float = 0.03,
        local_steps: int = 4,
        distill_steps: int = 2,
        server_distill_steps: int = 12,
        aggregation: str = "adaptive",
        restrict_to_support: bool = False,
        value_bits: int = 16,
        k_min: int = 1,
        last_only: bool = True,
        shard_clients: bool = False,
        use_kernels: bool = False,
        quantize_wire: bool = False,
        compute_dtype: str = "float32",
        fleet_store: "str | FleetStore" = "device",
    ):
        from repro.fed.cohort import fleet_index, partition_fleet, validate_family_contracts

        if shard_clients:
            raise NotImplementedError(
                "shard_clients is not supported for heterogeneous fleets yet:"
                " each family bucket would need its own divisible client-axis"
                " placement"
            )
        self.buckets = partition_fleet(clients)
        validate_family_contracts(self.buckets, server_cfg=server.cfg)
        self._where = fleet_index(self.buckets)
        self.clients = clients
        self.vocab = self.buckets[0].cfg.vocab_size
        self.last_only = last_only
        self._num_classes = num_classes
        self._local_steps = local_steps
        self.quantize_wire = quantize_wire
        sub_kwargs = dict(
            num_classes=num_classes, lr=lr, distill_lr=distill_lr,
            temperature=temperature, lam=lam, local_steps=local_steps,
            distill_steps=distill_steps,
            restrict_to_support=restrict_to_support, value_bits=value_bits,
            k_min=k_min, last_only=last_only, quantize_wire=quantize_wire,
            fleet_store=fleet_store,
        )
        # one BatchedEngine per bucket as the stacked-fleet STATE HOLDER
        # (gather/scatter/budget/batch plumbing); its per-phase steps are
        # never invoked — the bucket client-phase executable below runs the
        # round
        self._b = [
            BatchedEngine([clients[i] for i in b.client_ids], b.cfg, **sub_kwargs)
            for b in self.buckets
        ]
        self._phase_kwargs = dict(
            lr=lr, distill_lr=distill_lr, temperature=temperature, lam=lam,
            restrict_to_support=restrict_to_support, local_steps=local_steps,
            distill_steps=distill_steps, last_only=last_only,
            quantize=quantize_wire, compute_dtype=compute_dtype,
        )
        self._server_kwargs = dict(
            vocab=self.vocab, distill_lr=distill_lr, temperature=temperature,
            lam=lam, restrict_to_support=restrict_to_support,
            server_distill_steps=server_distill_steps,
            aggregation=aggregation, last_only=last_only,
            use_kernels=use_kernels, quantize=quantize_wire,
            compute_dtype=compute_dtype,
        )
        self._init_server_state(server)
        self._client_steps: dict = {}
        self._server_steps: dict = {}
        self._drivers: dict = {}

    # -- compiled-step caches -------------------------------------------
    def _client_phase_fn(self, bi: int, k_cap: int):
        """One bucket's unjitted client-phase body (for the scan driver)."""
        b = self.buckets[bi]
        return fed_steps.make_bucket_client_phase_fn(
            b.cfg, self._num_classes, k_cap=k_cap,
            shared_backbone=self._b[bi]._shared, **self._phase_kwargs,
        )

    def _client_step(self, bi: int, k_cap: int):
        key = (bi, k_cap)
        if key not in self._client_steps:
            self._client_steps[key] = jax.jit(
                self._client_phase_fn(bi, k_cap), donate_argnums=(0, 2)
            )
        return self._client_steps[key]

    def _server_step(self, send_h: bool):
        if send_h not in self._server_steps:
            self._server_steps[send_h] = jax.jit(
                fed_steps.make_server_phase_fn(
                    self.server.cfg, send_h=send_h, **self._server_kwargs
                ),
                donate_argnums=(0, 2),
            )
        return self._server_steps[send_h]

    @property
    def store_kind(self) -> str:
        return self._b[0].store_kind

    def client_params(self, cid: int):
        bi, local = self._where[int(cid)]
        return self._b[bi].client_params(local)

    def fleet_state(self) -> dict:
        return {f"bucket{i}": b.fleet_state() for i, b in enumerate(self._b)}

    def load_fleet_state(self, state: dict) -> None:
        for i, b in enumerate(self._b):
            b.load_fleet_state(state[f"bucket{i}"])

    def save_fleet_shards(self, dir_path: str) -> None:
        for i, b in enumerate(self._b):
            b.save_fleet_shards(dir_path, prefix=f"bucket{i}")

    def load_fleet_shards(self, dir_path: str) -> None:
        for i, b in enumerate(self._b):
            b.load_fleet_shards(dir_path, prefix=f"bucket{i}")

    def prefetch_cohort(self, sel: Sequence[int]) -> None:
        from repro.fed.cohort import split_cohort

        for b, _pos, local in split_cohort(self.buckets, sel):
            self._b[b.index].prefetch_cohort(local)

    # -- one whole heterogeneous round -----------------------------------
    def run_round(
        self,
        sel: Sequence[int],
        pub_tokens: jax.Array,
        bcast: BroadcastState | None,
        states: BatchedChannelState | Sequence[ChannelState],
        *,
        adaptive_k: bool,
        send_h: bool,
    ) -> ClientPhase:
        from repro.fed.cohort import split_cohort

        sel = check_unique_cohort(sel)
        states = list(states)
        n_samples = int(pub_tokens.shape[0])
        parts = split_cohort(self.buckets, sel)

        # budgets first (host scalar math, cohort order — ledger parity)
        ks = [0] * len(sel)
        budgets = []
        for b, pos, local in parts:
            ks_b = self._b[b.index]._budgets(
                [states[p] for p in pos], n_samples, adaptive_k, len(pos), send_h
            )
            budgets.append(ks_b)
            for p, k in zip(pos, ks_b):
                ks[p] = k
        k_cap = k_cap_bucket(ks, self.vocab)

        if bcast is not None:
            g_tokens, g_logits, g_h = bcast.tokens, bcast.logits, bcast.h
            g_valid = True
        else:
            g_tokens, g_logits, g_h = self._cold_broadcast(pub_tokens, n_samples)
            g_valid = False
        g_valid_arr = jnp.asarray(g_valid)

        # -- client phase: one donated compiled call per family bucket --
        wires: list[SparseWire | QuantizedWire] = []
        h_parts: list = []
        order: list[int] = []  # cohort position of each bucket-concat row
        payloads_by_pos: dict[int, UplinkPayload] = {}
        for (b, pos, local), ks_b in zip(parts, budgets):
            be = self._b[b.index]
            cohort = [be.clients[j] for j in local]
            batches = be._stacked_batches(cohort, step_major=False)
            idx, lora, frozen, opt = be._gather_cohort(local)
            lora, opt, v, i, m, sc, h = self._client_step(b.index, k_cap)(
                lora, frozen, opt, g_tokens, g_logits, g_h, g_valid_arr,
                batches, pub_tokens, jnp.asarray(ks_b, jnp.int32),
            )
            be._scatter_cohort(idx, lora, opt)
            _active, pl, _rank = be._upload_manifests(
                cohort, [states[p] for p in pos], ks_b, n_samples, send_h
            )
            it = iter(pl)
            for j, p in enumerate(pos):
                if ks_b[j] > 0:
                    payloads_by_pos[p] = next(it)
            if self.quantize_wire:
                wires.append(QuantizedWire(
                    values=v, scale=sc, indices=i, mask=m, vocab=self.vocab
                ))
            else:
                wires.append(SparseWire(values=v, indices=i, mask=m, vocab=self.vocab))
            h_parts.append(h)
            order.extend(pos)

        # -- union wire: the buckets' wires merge in the shared vocab-indexed
        # logit space, rows permuted back into cohort order; then ONE
        # family-blind compiled server phase --
        inv = np.argsort(np.asarray(order))
        union = take_wire_rows(concat_wires(wires), inv)
        h_all = None
        if h_parts[0] is not None:
            h_all = jnp.concatenate(h_parts)[jnp.asarray(inv)]
        union_scale = union.scale if self.quantize_wire else None
        (self._s_lora, self._s_opt, b_logits, b_h, self._d_loss) = (
            self._server_step(send_h)(
                self._s_lora, self._s_frozen, self._s_opt,
                union.values, union.indices, union.mask, union_scale, h_all,
                jnp.asarray(ks, jnp.int32), pub_tokens,
            )
        )
        self._b_tokens, self._b_logits, self._b_h = pub_tokens, b_logits, b_h

        tx = [p for p in range(len(sel)) if ks[p] > 0]
        sparse = take_wire_rows(union, tx) if tx else None
        return ClientPhase(
            dense=None, h=None, payloads=[payloads_by_pos[p] for p in tx],
            ks=ks, sparse=sparse,
        )

    # -- R heterogeneous rounds as ONE compiled lax.scan ------------------
    def _hetero_rounds_driver(
        self, k_cap: int, send_h: bool, num_rounds: int, n_real: int,
        caps: tuple[int, ...], has_eval: bool, has_chan: bool,
    ):
        key = (k_cap, send_h, num_rounds, n_real, caps, has_eval, has_chan)
        if key in self._drivers:
            return self._drivers[key]
        chan_step = fed_steps.make_channel_step_fn() if has_chan else None
        fns = [self._client_phase_fn(bi, k_cap) for bi in range(len(self.buckets))]
        server_fn = fed_steps.make_server_phase_fn(
            self.server.cfg, send_h=send_h, **self._server_kwargs
        )
        has_h = self.server.cfg.lora is not None
        shared = [be._shared for be in self._b]
        sizes = [b.size for b in self.buckets]
        server_eval = fed_steps.make_scan_eval_fn(
            self.server.cfg, self._num_classes, last_only=self.last_only
        )
        family_evals = [
            fed_steps.make_scan_eval_fn(
                b.cfg, self._num_classes, last_only=self.last_only
            )
            for b in self.buckets
        ]

        def driver(fleet_loras, fleet_opts, s_lora, s_opt, frozens, s_frozen,
                   g_tokens, g_logits, g_h, g_valid,
                   gathers, scatters, kss_b, batches_b, kss_all, pubs,
                   chan, *eval_args):
            if has_chan:
                (ch_z0, ch_bad0, ch_w, ch_u, ch_base,
                 rho, p_gb, p_bg, fade, sels_data) = chan

            def body(carry, xs):
                (fleet_loras, fleet_opts, s_lora, s_opt,
                 g_tokens, g_logits, g_h, g_valid, ch_state) = carry
                gath, scat, ksb, bat, ks_all, pub, ch_xs = xs
                vs, idxs, ms, scs, hs = [], [], [], [], []
                new_loras, new_opts = [], []
                for f, fn in enumerate(fns):
                    # gather this round's (padded) bucket slice; pads
                    # duplicate a real row for COMPUTE but scatter into the
                    # write-only scratch row sizes[f], so their advanced
                    # state is never observable
                    lora = jax.tree.map(lambda x: x[gath[f]], fleet_loras[f])
                    opt = jax.tree.map(lambda x: x[gath[f]], fleet_opts[f])
                    frz = (
                        frozens[f] if shared[f]
                        else jax.tree.map(lambda x: x[gath[f]], frozens[f])
                    )
                    lora, opt, v, i, m, sc, h = fn(
                        lora, frz, opt, g_tokens, g_logits,
                        g_h if has_h else None, g_valid, bat[f], pub, ksb[f],
                    )
                    new_loras.append(jax.tree.map(
                        lambda full, new: full.at[scat[f]].set(new),
                        fleet_loras[f], lora,
                    ))
                    new_opts.append(jax.tree.map(
                        lambda full, new: full.at[scat[f]].set(new),
                        fleet_opts[f], opt,
                    ))
                    vs.append(v)
                    idxs.append(i)
                    ms.append(m)
                    scs.append(sc)
                    hs.append(h)
                # the union wire: bucket-concatenated rows, vocab-indexed —
                # aggregation is row-permutation-invariant, so no cohort
                # reordering is needed in-program
                v_all = jnp.concatenate(vs)
                i_all = jnp.concatenate(idxs)
                m_all = jnp.concatenate(ms)
                sc_all = jnp.concatenate(scs) if scs[0] is not None else None
                h_all = jnp.concatenate(hs) if hs[0] is not None else None
                s_lora, s_opt, b_logits, b_h, d_loss = server_fn(
                    s_lora, s_frozen, s_opt, v_all, i_all, m_all, sc_all,
                    h_all, ks_all, pub,
                )
                # pad rows ride at k = 0, so the real cohort's mean is just
                # the padded sum over the true cohort size
                tap = {
                    "distill_loss": d_loss,
                    "mean_k": jnp.sum(ks_all.astype(jnp.float32)) / n_real,
                }
                if has_eval:
                    ev_tokens, ev_labels = eval_args
                    tap["server_acc"] = server_eval(
                        s_lora, s_frozen, ev_tokens, ev_labels
                    )
                    fam = []
                    for f in range(len(fns)):
                        # post-scatter fleet row gath[f][0]: the family's
                        # first selected client this round (or its local
                        # client 0, untouched, when the family sat out)
                        lf = jax.tree.map(
                            lambda x: x[gath[f][0]], new_loras[f]
                        )
                        ff = (
                            frozens[f] if shared[f]
                            else jax.tree.map(lambda x: x[gath[f][0]], frozens[f])
                        )
                        fam.append(family_evals[f](lf, ff, ev_tokens, ev_labels))
                    tap["family_client_acc"] = jnp.stack(fam)
                if has_chan:
                    # hetero cohorts are bucket-local in-program; the global
                    # cohort ids ride along as data purely for the tap gather
                    ch_z, ch_bad = ch_state
                    w_t, u_t, base_t, sel_real = ch_xs
                    ch_z, ch_bad, snr = chan_step(
                        ch_z, ch_bad, w_t, u_t, base_t, rho, p_gb, p_bg, fade
                    )
                    ch_state = (ch_z, ch_bad)
                    tap["snr_db"] = snr[sel_real]
                    tap["outage"] = ch_bad[sel_real]
                carry = (
                    tuple(new_loras), tuple(new_opts), s_lora, s_opt,
                    pub, b_logits, b_h if has_h else g_h, jnp.ones((), bool),
                    ch_state,
                )
                return carry, tap

            ch_state0 = (ch_z0, ch_bad0) if has_chan else ()
            ch_xs_all = (ch_w, ch_u, ch_base, sels_data) if has_chan else ()
            carry, taps = jax.lax.scan(
                body,
                (fleet_loras, fleet_opts, s_lora, s_opt,
                 g_tokens, g_logits, g_h, g_valid, ch_state0),
                (gathers, scatters, kss_b, batches_b, kss_all, pubs,
                 ch_xs_all),
                length=num_rounds,
            )
            return carry, taps

        jitted = jax.jit(driver, donate_argnums=(0, 1, 2, 3))
        self._drivers[key] = jitted
        return jitted

    def run_rounds(
        self,
        sels: Sequence[Sequence[int]],
        pubs: Sequence[jax.Array],
        states_per_round: Sequence,
        *,
        adaptive_k: bool,
        send_h: bool,
        eval_tokens: jax.Array | None = None,
        eval_labels: jax.Array | None = None,
        channel_scan: dict | None = None,
    ) -> RoundsTrajectory:
        """Run R whole heterogeneous rounds as ONE compiled ``lax.scan``.

        ``channel_scan`` evolves the scenario channel state inside the scan
        exactly as on the homogeneous path (see
        :meth:`FusedE2EEngine.run_rounds`); the global cohort ids ride
        along as data so the per-round SNR/outage tap can gather the
        fleet-wide realisation into cohort order.

        Family participation varies per round, but every compiled shape is
        static: each bucket is padded to its block-wide maximum cohort slice
        (at least one row) with masked ``k = 0`` rows.  A pad row gathers a
        real client's state so the computation stays well-posed, contributes
        nothing to the union wire (all-False transmit mask), consumes no
        private batch (its batch rows are zeros), and scatters its advanced
        state into a write-only scratch row appended past the bucket's fleet
        — ``.at[sel].set`` duplicate-index hazards land only there.  Per
        round, the eval tap reports server accuracy and one accuracy per
        family bucket; ``client_acc`` is the cohort's first selected
        client's family entry (the host loop's metric).
        """
        from repro.fed.cohort import split_cohort

        if self.store_kind != "device":
            raise RuntimeError(
                "run_rounds scans every bucket's WHOLE fleet stack as a "
                "donated device carry, which only fleet_store='device' "
                f"provides; a host store (store_kind={self.store_kind!r}) "
                "keeps O(cohort) device residency — drive rounds one at a "
                "time with run_round instead (rounds.py falls back "
                "automatically)"
            )
        sels = [check_unique_cohort(sel) for sel in sels]
        if (eval_tokens is None) != (eval_labels is None):
            raise ValueError("pass eval_tokens and eval_labels together")
        has_eval = eval_tokens is not None
        has_chan = channel_scan is not None
        num_rounds = len(sels)
        if num_rounds == 0:
            return RoundsTrajectory(
                ks=[], payloads=[], mean_k=[], distill_loss=[],
                server_acc=[] if has_eval else None,
                client_acc=[] if has_eval else None,
                family_client_acc=[] if has_eval else None,
                snr_db=[] if has_chan else None,
                outage=[] if has_chan else None,
            )
        n_samples = int(pubs[0].shape[0])
        n_real = len(sels[0])
        if any(len(sel) != n_real for sel in sels):
            raise ValueError("run_rounds requires equal-size cohorts")

        F = len(self.buckets)
        # -- host pre-pass: budgets/payloads (ledger), per-bucket slices --
        all_ks, all_payloads = [], []
        per_round: list[list[tuple[list[int], list[int], list[int]]]] = []
        first_bucket: list[int] = []  # family of sel[0], per round
        for sel, states in zip(sels, states_per_round):
            states = list(states)
            parts = {b.index: (pos, local)
                     for b, pos, local in split_cohort(self.buckets, sel)}
            ks = [0] * len(sel)
            round_rows = []
            for f in range(F):
                pos, local = parts.get(f, ([], []))
                ks_b = self._b[f]._budgets(
                    [states[p] for p in pos], n_samples, adaptive_k,
                    len(pos), send_h,
                ) if pos else []
                for p, k in zip(pos, ks_b):
                    ks[p] = k
                round_rows.append((pos, local, ks_b))
            payloads = []
            for f, (pos, local, ks_b) in enumerate(round_rows):
                if not pos:
                    continue
                be = self._b[f]
                _a, pl, _r = be._upload_manifests(
                    [be.clients[j] for j in local],
                    [states[p] for p in pos], ks_b, n_samples, send_h,
                )
                it = iter(pl)
                payloads.extend(
                    (p, next(it)) for p, k in zip(pos, ks_b) if k > 0
                )
            payloads.sort(key=lambda t: t[0])
            all_ks.append(ks)
            all_payloads.append([pl for _, pl in payloads])
            per_round.append(round_rows)
            fb = [f for f, (pos, _l, _k) in enumerate(round_rows) if 0 in pos]
            first_bucket.append(fb[0])
        k_cap = k_cap_bucket(
            [k for ks in all_ks for k in ks], self.vocab
        )
        caps = tuple(
            max(max((len(per_round[r][f][0]) for r in range(num_rounds)),
                    default=0), 1)
            for f in range(F)
        )

        # -- per-bucket padded scan inputs (gather/scatter/ks/batches) --
        gathers, scatters, kss_b, batches_b = [], [], [], []
        for f in range(F):
            be = self._b[f]
            cap = caps[f]
            g_rows, s_rows, k_rows, b_rows = [], [], [], []
            for r in range(num_rounds):
                pos, local, ks_b = per_round[r][f]
                pad = cap - len(local)
                anchor = local[0] if local else 0
                g_rows.append(local + [anchor] * pad)
                s_rows.append(local + [self.buckets[f].size] * pad)
                k_rows.append(ks_b + [0] * pad)
                if local:
                    bat = be._stacked_batches(
                        [be.clients[j] for j in local], step_major=False
                    )
                    bat = {
                        key: np.concatenate(
                            [np.asarray(v)]
                            + [np.zeros_like(np.asarray(v[:1]))] * pad
                        ) if pad else np.asarray(v)
                        for key, v in bat.items()
                    }
                else:
                    # the family sits this round out: all-pad slice, zero
                    # batches (no client rng stream is consumed)
                    shapes = self._zero_batch_shapes(be)
                    bat = {
                        key: np.zeros((cap,) + shape, dtype)
                        for key, (shape, dtype) in shapes.items()
                    }
                b_rows.append(bat)
            gathers.append(jnp.asarray(np.asarray(g_rows), jnp.int32))
            scatters.append(jnp.asarray(np.asarray(s_rows), jnp.int32))
            kss_b.append(jnp.asarray(np.asarray(k_rows), jnp.int32))
            batches_b.append({
                key: jnp.asarray(np.stack([row[key] for row in b_rows]))
                for key in b_rows[0]
            })
        kss_all = jnp.asarray(  # (R, sum caps) in bucket-concat order
            np.concatenate([np.asarray(k) for k in kss_b], axis=1), jnp.int32
        )
        pubs_arr = jnp.stack([jnp.asarray(p) for p in pubs])

        # fleet state + one write-only scratch row per bucket (pad target)
        fleet_loras, fleet_opts, frozens = [], [], []
        for be in self._b:
            fleet_loras.append(jax.tree.map(
                lambda x: jnp.concatenate([x, jnp.zeros_like(x[:1])]), be._lora
            ))
            fleet_opts.append(jax.tree.map(
                lambda x: jnp.concatenate([x, jnp.zeros_like(x[:1])]), be._opt
            ))
            frozens.append(be._frozen)

        if self._b_logits is not None:
            g_tokens, g_logits, g_h = self._b_tokens, self._b_logits, self._b_h
            g_valid = True
        else:
            g_tokens, g_logits, g_h = self._cold_broadcast(pubs_arr[0], n_samples)
            g_valid = False

        eval_args = ()
        if has_eval:
            seen = (
                int(eval_tokens.shape[0]) // fed_steps.EVAL_BATCH
            ) * fed_steps.EVAL_BATCH
            if seen == 0:
                raise ValueError(
                    f"eval split of {int(eval_tokens.shape[0])} samples is "
                    f"smaller than one eval batch ({fed_steps.EVAL_BATCH})"
                )
            eval_args = (
                jnp.asarray(eval_tokens[:seen]), jnp.asarray(eval_labels[:seen])
            )

        chan_ops = ()
        if has_chan:
            chan_ops = _channel_scan_ops(channel_scan, num_rounds) + (
                jnp.asarray(np.asarray(sels), jnp.int32),  # (R, n_real)
            )
        driver = self._hetero_rounds_driver(
            k_cap, send_h, num_rounds, n_real, caps, has_eval, has_chan
        )
        carry, taps = driver(
            tuple(fleet_loras), tuple(fleet_opts),
            self._s_lora, self._s_opt, tuple(frozens), self._s_frozen,
            g_tokens, g_logits, g_h, jnp.asarray(g_valid),
            tuple(gathers), tuple(scatters), tuple(kss_b), tuple(batches_b),
            kss_all, pubs_arr, chan_ops, *eval_args,
        )
        (out_loras, out_opts, self._s_lora, self._s_opt,
         self._b_tokens, self._b_logits, self._b_h, _valid, _chan) = carry
        for be, lora, opt in zip(self._b, out_loras, out_opts):
            n = jax.tree.leaves(be._lora)[0].shape[0]
            be._lora = jax.tree.map(lambda x: x[:n], lora)
            be._opt = jax.tree.map(lambda x: x[:n], opt)
        self._d_loss = taps["distill_loss"][-1]

        def _tolist(name):
            return [float(x) for x in np.asarray(taps[name])]

        family_acc = client_acc = None
        if has_eval:
            fam = np.asarray(taps["family_client_acc"])  # (R, F)
            family_acc = [[float(a) for a in row] for row in fam]
            client_acc = [
                family_acc[r][first_bucket[r]] for r in range(num_rounds)
            ]
        snr_db = outage = None
        if has_chan:
            snr_db = [[float(x) for x in row] for row in np.asarray(taps["snr_db"])]
            outage = [[bool(x) for x in row] for row in np.asarray(taps["outage"])]
        return RoundsTrajectory(
            ks=all_ks,
            payloads=all_payloads,
            mean_k=_tolist("mean_k"),
            distill_loss=_tolist("distill_loss"),
            server_acc=_tolist("server_acc") if has_eval else None,
            client_acc=client_acc,
            family_client_acc=family_acc,
            snr_db=snr_db,
            outage=outage,
        )

    @staticmethod
    def _zero_batch_shapes(be: BatchedEngine) -> dict:
        """Per-sample batch shapes/dtypes of one bucket, WITHOUT consuming
        any client's rng stream (probed from the dataset layout)."""
        c = be.clients[0]
        seq_len = int(c.data.tokens.shape[1])
        bsz = c.batch_size  # epoch_batches always pads up to a full batch
        return {
            "tokens": ((be.local_steps, bsz, seq_len), c.data.tokens.dtype),
            "labels": ((be.local_steps, bsz), c.data.labels.dtype),
        }
