"""Backbone pretraining for the FL experiments.

The paper fine-tunes PRETRAINED GPT-2 (small on clients, large on the
server); LoRA's low-rank delta rides on meaningful features.  In this
offline container the checkpoints are a data gate (DESIGN §1), so we
*simulate pretraining*: full-parameter supervised training on a disjoint
pretraining split of the synthetic corpus, stopped at moderate accuracy so
federated distillation still has headroom to demonstrate transfer.  The
resulting backbone is the shared frozen W' of paper eq. 1; FL then trains
only θ_n = {A_n, B_n}.

Pretrained params are cached per (config, seed, steps) so the four method
presets in the benchmarks reuse one backbone.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import epoch_batches
from repro.data.synthetic import IntentDataset
from repro.fed import steps as fed_steps
from repro.lora import split_lora
from repro.models import forward, init as model_init
from repro.optim import adamw_init, adamw_update

__all__ = ["pretrain_classifier"]

_CACHE: dict = {}


def _owned(tree):
    """Deep-copy the leaf buffers of a cached param tree.

    Callers hand pretrained params to engines whose compiled steps DONATE
    input buffers (fused/fused_e2e); returning the cache's own arrays lets
    the first donation delete the cached buffers and poison every later
    run in the process ("buffer has been deleted or donated").  An
    identity ``tree.map`` is NOT enough — it copies the tree structure but
    aliases the same device buffers."""
    return jax.tree.map(jnp.copy, tree)


def _supervised_step(cfg: ModelConfig, num_classes: int, lr: float, last_only: bool):
    def loss_fn(params, batch):
        # last_only head: classification reads the final position exclusively,
        # and only the num_classes head columns (bit-identical to slicing)
        logits, aux = forward(
            params, cfg, {"tokens": batch["tokens"]}, last_only=last_only,
            head_cols=num_classes if last_only else None,
        )
        last = logits if last_only else logits[:, -1, :]
        cls = fed_steps.class_logits(last, num_classes)
        logp = jax.nn.log_softmax(cls.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1).mean()
        acc = jnp.mean((jnp.argmax(cls, -1) == batch["labels"]).astype(jnp.float32))
        return nll + 0.01 * aux.moe_aux, acc

    @jax.jit
    def step(params, opt, batch):
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, opt = adamw_update(grads, opt, params, lr=lr, weight_decay=1e-4)
        return params, opt, {"loss": loss, "acc": acc}

    return step


def pretrain_classifier(
    cfg: ModelConfig,
    pretrain_data: IntentDataset,
    *,
    num_classes: int,
    steps: int = 150,
    lr: float = 2e-3,
    batch_size: int = 64,
    seed: int = 0,
    last_only: bool = True,
    verbose: bool = False,
):
    """Full-parameter supervised pretraining; returns params with fresh
    (zero-delta) LoRA adapters on top — the shared W' + θ_0 of eq. 1."""
    key = (cfg.name, cfg.num_layers, cfg.d_model, steps, lr, seed, len(pretrain_data),
           num_classes, batch_size, last_only)
    if key in _CACHE:
        return _owned(_CACHE[key])

    params = model_init(jax.random.PRNGKey(seed), cfg)
    opt = adamw_init(params, state_dtype=cfg.optimizer_state_dtype)
    step = _supervised_step(cfg, num_classes, lr, last_only)
    rng = np.random.default_rng(seed)
    done = 0
    metrics = {}
    while done < steps:
        for batch in epoch_batches(pretrain_data, batch_size, rng=rng):
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt, metrics = step(params, opt, jb)
            done += 1
            if verbose and done % 25 == 0:
                print(f"[pretrain {cfg.name}] step {done}: "
                      f"loss={float(metrics['loss']):.3f} acc={float(metrics['acc']):.3f}")
            if done >= steps:
                break

    # reset LoRA to the zero-delta init (pretraining moved A/B too; the FL
    # protocol starts from W' + B=0)
    fresh = model_init(jax.random.PRNGKey(seed + 1), cfg)
    fresh_lora, _ = split_lora(fresh)
    from repro.lora import merge_lora

    _, frozen = split_lora(params)
    params = merge_lora(fresh_lora, frozen)

    _CACHE[key] = params
    return _owned(params)


def pretrain_lm(
    cfg: ModelConfig,
    pretrain_data: IntentDataset,
    *,
    steps: int = 60,
    lr: float = 2e-3,
    batch_size: int = 64,
    seed: int = 0,
    verbose: bool = False,
):
    """LM-only (next-token) pretraining: builds token/keyword FEATURES with
    no label information — the paper's server LLM analogue (a generically
    pretrained model whose task knowledge arrives via distillation)."""
    key = ("lm", cfg.name, cfg.num_layers, cfg.d_model, steps, lr, seed, len(pretrain_data))
    if key in _CACHE:
        return _owned(_CACHE[key])

    from repro.launch.steps import make_train_step

    params = model_init(jax.random.PRNGKey(seed), cfg)
    opt = adamw_init(params, state_dtype=cfg.optimizer_state_dtype)
    step = jax.jit(make_train_step(cfg, lr=lr, weight_decay=1e-4))
    rng = np.random.default_rng(seed)
    done = 0
    while done < steps:
        for batch in epoch_batches(pretrain_data, batch_size, rng=rng):
            params, opt, metrics = step(params, opt, {"tokens": jnp.asarray(batch["tokens"])})
            done += 1
            if verbose and done % 25 == 0:
                print(f"[pretrain-lm {cfg.name}] step {done}: loss={float(metrics['loss']):.3f}")
            if done >= steps:
                break

    fresh_lora, _ = split_lora(model_init(jax.random.PRNGKey(seed + 1), cfg))
    from repro.lora import merge_lora

    _, frozen = split_lora(params)
    params = merge_lora(fresh_lora, frozen)
    _CACHE[key] = params
    return _owned(params)
