"""Dry-run machinery unit tests (parser + policy; no 512-device compile)."""

import jax
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.policy import arch_shape_config, input_specs, window_for

# collective parser is defined inside dryrun; re-test its logic via a copy of
# the regexes on a synthetic HLO snippet without importing the module (which
# would set XLA_FLAGS in-process).
HLO = """
ENTRY %main {
  %ag = bf16[16,512]{1,0} all-gather(bf16[1,512]{1,0} %p0), replica_groups={}
  %ar.1 = f32[1024]{0} all-reduce(f32[1024]{0} %x), to_apply=%add
  %a2a = (f32[8,4]{1,0}, f32[8,4]{1,0}) all-to-all(f32[8,4]{1,0} %y, f32[8,4]{1,0} %z)
  %cp = u32[2]{0} collective-permute(u32[2]{0} %c), source_target_pairs={{0,1}}
  %rs = bf16[64]{0} reduce-scatter(bf16[1024]{0} %w), to_apply=%add
  %dot = f32[128,128]{1,0} dot(f32[128,64]{1,0} %a, f32[64,128]{1,0} %b)
}
"""


def _parser():
    # load dryrun without executing jax-device side effects? XLA_FLAGS set
    # is harmless after jax is already initialised in this process.
    from repro.launch import dryrun

    return dryrun.collective_bytes


def test_collective_parser_counts_and_bytes():
    collective_bytes = _parser()
    out = collective_bytes(HLO)
    assert out["all-gather"] == 16 * 512 * 2
    assert out["all-reduce"] == 1024 * 4
    assert out["all-to-all"] == 8 * 4 * 4 * 2  # tuple of two buffers
    assert out["collective-permute"] == 2 * 4
    assert out["reduce-scatter"] == 64 * 2
    assert out["count"] == 5


def test_window_policy():
    shapes = INPUT_SHAPES
    assert window_for(get_config("command-r-35b"), shapes["long_500k"]) == 4096
    assert window_for(get_config("mamba2-130m"), shapes["long_500k"]) is None
    assert window_for(get_config("jamba-1.5-large-398b"), shapes["long_500k"]) is None
    assert window_for(get_config("command-r-35b"), shapes["decode_32k"]) is None


@pytest.mark.parametrize("arch", ["yi-9b", "internvl2-76b", "seamless-m4t-large-v2"])
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_input_specs_shapes(arch, shape_name):
    shape = INPUT_SHAPES[shape_name]
    cfg = arch_shape_config(arch, shape)
    specs = input_specs(cfg, shape)
    if shape.kind in ("train", "prefill"):
        tok = specs["batch"]["tokens"]
        assert tok.shape[0] == shape.global_batch
        if cfg.family == "vlm":
            # patches + text tokens together occupy the assigned seq_len
            assert tok.shape[1] + cfg.frontend_len == shape.seq_len
        else:
            assert tok.shape[1] == shape.seq_len
        if cfg.frontend != "none":
            fe = specs["batch"]["frontend"]
            assert fe.shape == (shape.global_batch, cfg.frontend_len, cfg.d_model)
    else:
        assert specs["token"].shape == (shape.global_batch,)
        layers = specs["cache"]["layers"]
        assert layers  # per-layer caches exist
        # no allocation happened: these are ShapeDtypeStructs
        leaf = jax.tree.leaves(specs["cache"])[0]
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_decode_cache_ring_bounded_by_window():
    shape = INPUT_SHAPES["long_500k"]
    cfg = arch_shape_config("command-r-35b", shape)
    specs = input_specs(cfg, shape)
    k = specs["cache"]["layers"]["pos0"].k
    assert k.shape[2] == 4096  # ring buffer, not 524288
    cfg_j = arch_shape_config("jamba-1.5-large-398b", shape)
    specs_j = input_specs(cfg_j, shape)
    # jamba attention position carries the full-length cache
    attn_pos = f"pos{cfg_j.attn_offset}"
    assert specs_j["cache"]["layers"][attn_pos].k.shape[2] == 524288
