"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute in ``interpret=True`` mode — the
kernel body runs in Python per grid step against the same BlockSpec tiling,
validating the TPU program's logic; on a TPU backend they compile to Mosaic.
Batch-dim folding/unfolding lives here so callers pass natural shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.distill_kl import distill_kl_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.sparse_agg import (
    scatter_wire_sums_dequant_pallas,
    scatter_wire_sums_pallas,
    sparse_agg_pallas,
)
from repro.kernels.topk_select import topk_mask_dynamic_pallas, topk_mask_pallas

__all__ = [
    "topk_mask",
    "topk_mask_dynamic",
    "distill_kl",
    "sparse_aggregate",
    "scatter_wire_sums",
    "scatter_wire_sums_dequant",
    "flash_attention",
    "interpret_mode",
]


def interpret_mode() -> bool:
    return jax.default_backend() == "cpu"


def _fold(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape((-1, x.shape[-1])), lead


def topk_mask(logits: jax.Array, k: int) -> jax.Array:
    """Dense top-k sparsification of (..., vocab) logits (paper eq. 4)."""
    flat, lead = _fold(logits)
    out = topk_mask_pallas(flat, k, interpret=interpret_mode())
    return out.reshape(lead + (logits.shape[-1],))


def topk_mask_dynamic(logits: jax.Array, ks: jax.Array) -> jax.Array:
    """Per-row-budget top-k mask: ``logits (..., vocab)`` with int32 budgets
    ``ks`` matching the leading shape — the fused round engine's uplink
    sparsifier (k is data, so one compiled program serves every round)."""
    flat, lead = _fold(logits)
    out = topk_mask_dynamic_pallas(
        flat, ks.reshape((-1,)), interpret=interpret_mode()
    )
    return out.reshape(lead + (logits.shape[-1],))


def distill_kl(teacher: jax.Array, student: jax.Array, temperature: float = 2.0) -> jax.Array:
    """Mean KL(σ(t/T)||σ(s/T)) with Hinton T² scaling — matches
    ``repro.core.distill.kl_divergence`` on (..., vocab) inputs."""
    t_flat, _ = _fold(teacher)
    s_flat, _ = _fold(student)
    per_row = distill_kl_pallas(t_flat, s_flat, float(temperature), interpret=interpret_mode())
    return jnp.mean(per_row) * (temperature**2)


def sparse_aggregate(stack: jax.Array) -> jax.Array:
    """Adaptive aggregation of (N, ..., vocab) -> (..., vocab) (eqs. 6-7)."""
    n = stack.shape[0]
    vocab = stack.shape[-1]
    flat = stack.reshape((n, -1, vocab))
    out = sparse_agg_pallas(flat, interpret=interpret_mode())
    return out.reshape(stack.shape[1:]).astype(stack.dtype)


def scatter_wire_sums(
    a: jax.Array, b: jax.Array, indices: jax.Array, vocab: int
) -> tuple[jax.Array, jax.Array]:
    """Two-channel scatter-accumulate from the sparse uplink wire format:
    ``a, b, indices (N, ..., k)`` -> ``(num, den)`` each ``(..., vocab)`` —
    the O(N·B·k) aggregation primitive (no dense (N, B, V) stack is ever
    formed; see :func:`repro.core.aggregation.aggregate_wire`)."""
    n, k = a.shape[0], a.shape[-1]
    lead = a.shape[1:-1]
    fold = lambda x: x.reshape((n, -1, k))
    num, den = scatter_wire_sums_pallas(
        fold(a), fold(b), fold(indices), vocab, interpret=interpret_mode()
    )
    return (
        num.reshape(lead + (vocab,)).astype(a.dtype),
        den.reshape(lead + (vocab,)).astype(b.dtype),
    )


def scatter_wire_sums_dequant(
    q_values: jax.Array,
    scale: jax.Array,
    mask: jax.Array,
    indices: jax.Array,
    vocab: int,
    mode: str = "adaptive",
) -> tuple[jax.Array, jax.Array]:
    """Dequantize-fused scatter-accumulate from the int8 quantized wire:
    ``q_values/mask/indices (N, ..., k)`` + per-row ``scale (N, ...)`` ->
    ``(num, den)`` each ``(..., vocab)`` fp32 for the given aggregation
    mode.  The float values and both contribution channels are rebuilt
    inside the kernel per grid step — the wire crosses HBM at 1 byte/value
    and nothing of size O(N·B·V) is ever formed."""
    n, k = q_values.shape[0], q_values.shape[-1]
    lead = q_values.shape[1:-1]
    fold = lambda x: x.reshape((n, -1, k))
    num, den = scatter_wire_sums_dequant_pallas(
        fold(q_values),
        scale.reshape((n, -1)),
        fold(mask.astype(jnp.int8)),
        fold(indices),
        vocab,
        mode,
        interpret=interpret_mode(),
    )
    return num.reshape(lead + (vocab,)), den.reshape(lead + (vocab,))


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal attention, (B, H, S, D) or (B, S, D)."""
    if q.ndim == 4:
        b, h, s, d = q.shape
        fold = lambda x: x.reshape((b * h, s, d))
        out = flash_attention_pallas(fold(q), fold(k), fold(v), interpret=interpret_mode())
        return out.reshape((b, h, s, d))
    return flash_attention_pallas(q, k, v, interpret=interpret_mode())
