"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates its REDUCED same-family variant, runs one forward and one
train step on CPU, asserting output shapes + no NaNs; plus decode-vs-forward
equivalence for each mixer type."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_smoke_config
from repro.launch.steps import make_train_step
from repro.models import decode_step, forward, init, init_cache
from repro.models.frontends import synth_frontend_embeddings
from repro.optim import adamw_init

pytestmark = pytest.mark.slow  # model-zoo/layer suites ride the slow tier

ALL_ARCHS = list(ARCHITECTURES)


def _batch(cfg, b=2, s=32, seed=0):
    tokens = jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.frontend != "none":
        batch["frontend"] = synth_frontend_embeddings(cfg, b, seed=seed)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    params = init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = forward(params, cfg, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux.moe_aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params, state_dtype=cfg.optimizer_state_dtype)
    step = jax.jit(make_train_step(cfg, lr=1e-3))
    batch = _batch(cfg)
    p1, o1, m1 = step(params, opt, batch)
    p2, o2, m2 = step(p1, o1, _batch(cfg, seed=1))
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    # params actually moved
    moved = jax.tree.reduce(
        lambda acc, ab: acc or bool(jnp.any(ab)),
        jax.tree.map(lambda a, b: jnp.any(a != b), params, p1),
        False,
    )
    assert moved, f"{arch}: train step did not update parameters"


@pytest.mark.parametrize(
    "arch", ["stablelm-1.6b", "mamba2-130m", "jamba-1.5-large-398b", "granite-moe-1b-a400m",
             "seamless-m4t-large-v2", "internvl2-76b"]
)
def test_decode_matches_forward(arch):
    """KV-cache / SSM-state decode reproduces the teacher-forced forward."""
    cfg = get_smoke_config(arch)
    if cfg.family == "vlm":
        pytest.skip("vlm decode starts after a prefill with patches; covered by serve path")
    if cfg.moe is not None:
        # decouple from Switch capacity-drop semantics: decode routes tiny
        # groups (nothing dropped) while full-seq groups may drop tokens at
        # popular experts — a legitimate difference, not a cache bug.
        import dataclasses

        cfg = cfg.with_overrides(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init(jax.random.PRNGKey(0), cfg)
    steps = 10
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, steps), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    enc_out = None
    if cfg.family == "audio":
        from repro.models.model import _run_encoder

        batch["frontend"] = synth_frontend_embeddings(cfg, 2)
        enc_out = _run_encoder(params, cfg, batch["frontend"])
    full, _ = forward(params, cfg, batch)
    cache = init_cache(cfg, 2, 32, enc_out=enc_out)
    outs = []
    for t in range(steps):
        lg, cache = decode_step(params, cfg, cache, tokens[:, t])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-3, atol=2e-3)


def test_sliding_window_decode_matches():
    cfg = get_smoke_config("yi-9b").with_overrides(sliding_window=6)
    params = init(jax.random.PRNGKey(0), cfg)
    steps = 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, steps), 0, cfg.vocab_size)
    full, _ = forward(params, cfg, {"tokens": tokens})
    cache = init_cache(cfg, 1, 64)  # ring buffer sized by window
    assert cache["layers"]["pos0"].k.shape[2] == 6
    outs = []
    for t in range(steps):
        lg, cache = decode_step(params, cfg, cache, tokens[:, t])
        outs.append(lg)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1)), np.asarray(full), rtol=2e-3, atol=2e-3
    )


def test_vlm_consumes_patches():
    cfg = get_smoke_config("internvl2-76b")
    params = init(jax.random.PRNGKey(0), cfg)
    b = _batch(cfg)
    logits, _ = forward(params, cfg, b)
    # changing the image must change text logits (early fusion is real)
    b2 = dict(b)
    b2["frontend"] = b["frontend"] + 1.0
    logits2, _ = forward(params, cfg, b2)
    assert float(jnp.max(jnp.abs(logits - logits2))) > 1e-4


def test_audio_encoder_feeds_decoder():
    cfg = get_smoke_config("seamless-m4t-large-v2")
    params = init(jax.random.PRNGKey(0), cfg)
    b = _batch(cfg)
    logits, _ = forward(params, cfg, b)
    b2 = dict(b)
    b2["frontend"] = b["frontend"] * -1.0
    logits2, _ = forward(params, cfg, b2)
    assert float(jnp.max(jnp.abs(logits - logits2))) > 1e-4
