"""Checkpointing: pytree <-> .npz with path-flattened keys.

Good enough for single-host CPU runs and tests; on a real pod this module
would be swapped for a tensorstore-backed async writer, but the API
(save/restore/latest) is the deployment-shaped one.
"""

from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "save_step", "restore_step"]

_SEP = "__"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"idx{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, tree: Any, *, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)
    if metadata is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(metadata, f)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes preserved)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_keys, leaf in paths:
        key = _SEP.join(_path_str(p) for p in path_keys)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_step(ckpt_dir: str, step: int, tree: Any, **meta) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    save(path, tree, metadata={"step": step, **meta})
    return path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(ckpt_dir)
        if (m := re.match(r"step_(\d+)\.npz$", f))
    ]
    return max(steps) if steps else None


def restore_step(ckpt_dir: str, like: Any, step: int | None = None) -> tuple[Any, int]:
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    return restore(path, like), step
