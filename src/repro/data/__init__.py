from repro.data.partition import dirichlet_partition, iid_partition, split_public_private
from repro.data.pipeline import batch_iterator, epoch_batches
from repro.data.synthetic import IntentDataset, make_banking77_like, make_fed_benchmark_dataset, make_lm_stream

__all__ = [
    "dirichlet_partition",
    "iid_partition",
    "split_public_private",
    "batch_iterator",
    "epoch_batches",
    "IntentDataset",
    "make_banking77_like",
    "make_fed_benchmark_dataset",
    "make_lm_stream",
]
