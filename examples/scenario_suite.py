"""Scenario suite: accuracy-vs-communication curves under time-correlated
channels (grown from the old channel_sweep example).

Runs every channel-dynamics preset from ``repro.core.scenario`` (i.i.d.,
Gauss-Markov AR(1) fading, Jakes/Doppler fading, Gilbert-Elliott bursty
outage, mobility trajectories) through the one-dispatch ``fused_e2e``
multi-round scan and records fig2/fig3-style curves per scenario: server
accuracy against cumulative uplink MB, the per-round adaptive k, and the
in-scan outage tap.  The record is the committed ``BENCH_scenario.json``
gated by ``benchmarks/check_bench.py``.

Determinism contract (what makes the gate equality-shaped): channel draws
are keyed per ``(seed, round, cid)`` and cohort draws are consumed
round-by-round from one seeded rng, so a ``--quick`` run's rounds are a
PREFIX of the full run's — per-round uplink bytes at quick scale must equal
the committed record's leading rounds byte-for-byte.

Run:  PYTHONPATH=src python examples/scenario_suite.py            # full record
      PYTHONPATH=src python examples/scenario_suite.py --quick    # CI gate
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.configs.gpt2_paper import REDUCED_CLIENT, REDUCED_SERVER  # noqa: E402
from repro.core import SCENARIOS, ChannelConfig  # noqa: E402
from repro.data import make_banking77_like  # noqa: E402
from repro.fed import FedConfig, run_federated  # noqa: E402

_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

CLIENT = REDUCED_CLIENT.with_overrides(
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
    vocab_size=256, max_seq_len=32,
)
SERVER = REDUCED_SERVER.with_overrides(
    num_layers=2, d_model=96, num_heads=2, num_kv_heads=2, d_ff=192,
    vocab_size=256, max_seq_len=32,
)
# Constrained uplink so the adaptive k actually moves with the fading, plus
# a nonzero memoryless dropout so the i.i.d. presets exercise outage too.
CHAN = ChannelConfig(bandwidth_hz=2e5, mean_snr_db=2.0, min_k=0, dropout_prob=0.1)
FULL_ROUNDS = 10
QUICK_ROUNDS = 4


def _fed(rounds: int, scenario) -> FedConfig:
    return FedConfig(
        method="adald", engine="fused_e2e", num_clients=6, clients_per_round=3,
        rounds=rounds, public_size=64, public_batch=16, eval_size=64,
        pretrain_steps=0, local_steps=2, distill_steps=1, seed=0,
        channel=CHAN, scenario=scenario, scan_rounds=True,
    )


def run_scenario(ds, rounds: int, scenario):
    run = run_federated(CLIENT, SERVER, ds, _fed(rounds, scenario))
    uplink = [r.uplink_bytes for r in run.ledger.rounds]
    out = {
        "server_acc": [float(a) for a in run.server_acc],
        "cum_uplink_mb": [float(b) / 1e6 for b in np.cumsum(uplink)],
        "uplink_bytes": [int(b) for b in uplink],
        "mean_k": [float(k) for k in run.mean_k],
        "final_acc": float(run.server_acc[-1]),
        "best_acc": float(max(run.server_acc)),
        "total_uplink_mb": float(sum(uplink)) / 1e6,
    }
    if run.outage is not None:
        flat = [o for row in run.outage for o in row]
        out["outage_rate"] = float(np.mean(flat)) if flat else 0.0
    return run, out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help=f"{QUICK_ROUNDS} rounds instead of {FULL_ROUNDS} "
                         "(a prefix of the full record; writes "
                         "BENCH_scenario.quick.json for the CI gate)")
    ap.add_argument("--out", default=None, help="output JSON path override")
    args = ap.parse_args(argv)

    rounds = QUICK_ROUNDS if args.quick else FULL_ROUNDS
    ds = make_banking77_like(vocab_size=CLIENT.vocab_size, seq_len=12,
                            total=500, seed=0)

    record = {"quick": bool(args.quick), "rounds": rounds, "scenarios": {}}
    print(f"{'scenario':>16} {'mean k':>8} {'uplink MB':>10} {'outage':>7} "
          f"{'best acc':>9}")
    runs = {}
    for name in SCENARIOS:
        run, out = run_scenario(ds, rounds, name)
        runs[name] = run
        record["scenarios"][name] = out
        print(f"{name:>16} {np.mean(out['mean_k']):8.0f} "
              f"{out['total_uplink_mb']:10.3f} {out['outage_rate']:7.2f} "
              f"{out['best_acc']:9.3f}")

    # The rho=0 guarantee with teeth: the `iid` preset must be bit-identical
    # to a run with NO scenario at all (the legacy per-round i.i.d. path).
    legacy, legacy_out = run_scenario(ds, rounds, None)
    iid = runs["iid"]
    record["iid_bit_identical"] = bool(
        iid.per_client_k == legacy.per_client_k
        and record["scenarios"]["iid"]["uplink_bytes"] == legacy_out["uplink_bytes"]
        and np.allclose(iid.server_acc, legacy.server_acc, atol=1e-6)
    )
    print(f"\niid preset vs legacy i.i.d. path bit-identical: "
          f"{record['iid_bit_identical']}")

    suffix = "quick.json" if args.quick else "json"
    path = args.out or os.path.join(_REPO_ROOT, f"BENCH_scenario.{suffix}")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
