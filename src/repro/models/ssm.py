"""Mamba2 / SSD (state-space duality) mixer [arXiv:2405.21060].

Implements the *chunked SSD* algorithm (paper's Listing 1, "ssd_minimal")
in JAX:

  * intra-chunk: dense (Q x Q) masked matmuls — MXU-friendly;
  * inter-chunk: chunk-state recurrence via an exponential-decay matmul over
    chunk indices (O(nc^2) but tiny next to the intra-chunk work);
  * decode: the dual recurrent form, O(1) per token:
      state <- state * exp(dt*A) + dt * (B outer x);   y = C . state + D*x

Block structure follows Mamba2: projections to [z | x | B | C | dt]
(kept as SEPARATE weights so each can carry its own sharding — packing them
would slice tensor-parallel shards across segment boundaries), causal
depthwise conv (width 4) over x and (B,C), softplus dt with learned bias,
SSD core over heads of size P, skip D, gated RMSNorm(y * silu(z)), out_proj.

Sharding (DESIGN §4): the inner dim d_inner (z, x, conv_x, gate_norm,
out_proj rows) shards over ``model``; B/C (width 2N=256) and dt (width H,
not generally divisible by the mesh) stay replicated — they are O(N) wide.
The decode state (B,H,P,N) shards its N axis over ``model``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, norm_apply, norm_init

__all__ = ["SSMCache", "ssm_init", "ssm_apply", "init_ssm_cache"]


class SSMCache(NamedTuple):
    conv_x: jax.Array  # (B, W-1, d_inner) — pre-conv x history
    conv_bc: jax.Array  # (B, W-1, 2N) — pre-conv B/C history
    state: jax.Array  # (B, H, P, N) — SSM recurrent state


def _dims(cfg: ModelConfig):
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    nheads = d_inner // ssm.head_dim
    return ssm, d_inner, nheads


def ssm_init(rng: jax.Array, cfg: ModelConfig) -> dict:
    ssm, d_inner, nheads = _dims(cfg)
    keys = jax.random.split(rng, 8)
    pdt = jnp.dtype(cfg.param_dtype)
    n2 = 2 * ssm.state_dim

    # dt bias: softplus^{-1}(u), u ~ logUniform[dt_min, dt_max]
    u = jnp.exp(
        jax.random.uniform(keys[2], (nheads,), jnp.float32)
        * (jnp.log(ssm.dt_max) - jnp.log(ssm.dt_min))
        + jnp.log(ssm.dt_min)
    )
    dt_bias = u + jnp.log(-jnp.expm1(-u))  # inverse softplus

    a_init = jax.random.uniform(keys[3], (nheads,), jnp.float32, 1.0, 16.0)

    return {
        "w_z": dense_init(keys[0], cfg.d_model, d_inner, use_bias=cfg.use_bias, dtype=cfg.param_dtype),
        "w_x": dense_init(keys[1], cfg.d_model, d_inner, use_bias=cfg.use_bias, dtype=cfg.param_dtype),
        "w_bc": dense_init(keys[4], cfg.d_model, n2, use_bias=cfg.use_bias, dtype=cfg.param_dtype),
        "w_dt": dense_init(keys[5], cfg.d_model, nheads, use_bias=cfg.use_bias, dtype=cfg.param_dtype),
        "out_proj": dense_init(keys[6], d_inner, cfg.d_model, use_bias=cfg.use_bias, dtype=cfg.param_dtype),
        "conv_x_w": (jax.random.normal(keys[7], (ssm.conv_width, d_inner), jnp.float32) * 0.1).astype(pdt),
        "conv_x_b": jnp.zeros((d_inner,), pdt),
        "conv_bc_w": (jax.random.normal(jax.random.fold_in(keys[7], 1), (ssm.conv_width, n2), jnp.float32) * 0.1).astype(pdt),
        "conv_bc_b": jnp.zeros((n2,), pdt),
        "dt_bias": dt_bias.astype(pdt),
        "a_log": jnp.log(a_init).astype(pdt),
        "d_skip": jnp.ones((nheads,), pdt),
        "gate_norm": norm_init(d_inner, kind="rmsnorm", dtype=cfg.param_dtype),
    }


def init_ssm_cache(cfg: ModelConfig, batch: int, *, dtype: str | None = None) -> SSMCache:
    ssm, d_inner, nheads = _dims(cfg)
    dt = jnp.dtype(dtype or cfg.compute_dtype)
    return SSMCache(
        conv_x=jnp.zeros((batch, ssm.conv_width - 1, d_inner), dt),
        conv_bc=jnp.zeros((batch, ssm.conv_width - 1, 2 * ssm.state_dim), dt),
        state=jnp.zeros((batch, nheads, ssm.head_dim, ssm.state_dim), jnp.float32),
    )


def _segsum(a: jax.Array) -> jax.Array:
    """Lower-triangular pairwise cumulative sums: out[..., i, j] = sum_{j<m<=i} a[m].

    a: (..., Q) -> (..., Q, Q), upper triangle = -inf.
    """
    q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    i = jnp.arange(q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_chunked(
    x: jax.Array,  # (B, S, H, P) — already multiplied by dt
    a: jax.Array,  # (B, S, H)    — dt * A (negative log-decay per step)
    b_mat: jax.Array,  # (B, S, N)
    c_mat: jax.Array,  # (B, S, N)
    chunk: int,
    init_state: jax.Array | None = None,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nc = s // chunk

    xc = x.reshape(bsz, nc, chunk, h, p)
    ac = a.reshape(bsz, nc, chunk, h).transpose(0, 3, 1, 2)  # (B,H,nc,Q)
    bc = b_mat.reshape(bsz, nc, chunk, n)
    cc = c_mat.reshape(bsz, nc, chunk, n)

    a_cumsum = jnp.cumsum(ac, axis=-1)  # (B,H,nc,Q)

    # 1) intra-chunk (diagonal blocks)
    l_mat = jnp.exp(_segsum(ac))  # (B,H,nc,Q,Q)
    y_diag = jnp.einsum("bcqn,bckn,bhcqk,bckhp->bcqhp", cc, bc, l_mat, xc)

    # 2) per-chunk final states
    decay_states = jnp.exp(a_cumsum[..., -1:] - a_cumsum)  # (B,H,nc,Q)
    states = jnp.einsum("bckn,bhck,bckhp->bchpn", bc, decay_states, xc)

    # 3) inter-chunk recurrence over chunk states
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), states.dtype)
    states = jnp.concatenate([init_state[:, None], states], axis=1)  # (B,nc+1,H,P,N)
    chunk_decay = a_cumsum[..., -1]  # (B,H,nc) total decay per chunk
    padded = jnp.pad(chunk_decay, ((0, 0), (0, 0), (1, 0)))
    decay_chunk = jnp.exp(_segsum(padded))  # (B,H,nc+1,nc+1)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states)
    prev_states = new_states[:, :-1]  # state entering each chunk
    final_state = new_states[:, -1]

    # 4) inter-chunk (off-diagonal) output contribution
    state_decay_out = jnp.exp(a_cumsum)  # (B,H,nc,Q)
    y_off = jnp.einsum("bcqn,bchpn,bhcq->bcqhp", cc, prev_states, state_decay_out)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, final_state


def _proj(params: dict, name: str, x: jax.Array, cd) -> jax.Array:
    w = params[name]
    y = jnp.einsum("bsd,dk->bsk", x.astype(cd), w["w"].astype(cd))
    if "b" in w:
        y = y + w["b"].astype(cd)
    return y


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, history: jax.Array | None):
    """Depthwise causal conv over seq.  history: (B, W-1, C) or None (zeros)."""
    w32 = w.astype(jnp.float32)  # (W, C)
    width = w32.shape[0]
    x32 = x.astype(jnp.float32)
    if history is None:
        pad = jnp.zeros((x32.shape[0], width - 1, x32.shape[-1]), x32.dtype)
    else:
        pad = history.astype(jnp.float32)
    xp = jnp.concatenate([pad, x32], axis=1)  # (B, S+W-1, C)
    s = x.shape[1]
    out = sum(xp[:, i : i + s] * w32[i] for i in range(width))
    out = out + b.astype(jnp.float32)
    new_history = xp[:, -(width - 1) :] if width > 1 else xp[:, :0]
    return jax.nn.silu(out), new_history


def ssm_apply(
    params: dict,
    x_in: jax.Array,
    cfg: ModelConfig,
    *,
    cache: SSMCache | None = None,
) -> tuple[jax.Array, SSMCache | None]:
    """Mamba2 block.  Full-sequence when cache is None, else one-token decode.

    Returns (output (B,S,D), updated cache or None).
    """
    ssm, d_inner, nheads = _dims(cfg)
    cd = jnp.dtype(cfg.compute_dtype)
    n = ssm.state_dim
    p = ssm.head_dim

    z = _proj(params, "w_z", x_in, cd)
    x_pre = _proj(params, "w_x", x_in, cd)
    bc_pre = _proj(params, "w_bc", x_in, cd)
    dt_raw = _proj(params, "w_dt", x_in, cd)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a_neg = -jnp.exp(params["a_log"].astype(jnp.float32))  # (H,) negative

    if cache is None:
        from repro import sharding as _sh

        xs, _ = _causal_conv(x_pre, params["conv_x_w"], params["conv_x_b"], None)
        bc, _ = _causal_conv(bc_pre, params["conv_bc_w"], params["conv_bc_b"], None)
        b_mat, c_mat = jnp.split(bc, 2, axis=-1)
        bsz, s, _ = xs.shape
        xh = xs.reshape(bsz, s, nheads, p)
        # anchor head sharding so the (B,H,nc,Q,Q) SSD decay tensors shard
        # by head instead of replicating (§Perf iteration 4)
        xh = _sh.constrain(xh, "batch", None, "heads", None)
        dt = _sh.constrain(dt, "batch", None, "heads")
        x_dt = xh * dt[..., None]  # discretized input
        a_dt = dt * a_neg  # (B,S,H)
        y, _ = _ssd_chunked(x_dt, a_dt, b_mat, c_mat, min(ssm.chunk_size, s), None)
        new_cache = None
    else:
        # one-token decode: conv from cached history, recurrent state update
        xs, hist_x = _causal_conv(x_pre, params["conv_x_w"], params["conv_x_b"], cache.conv_x)
        bc, hist_bc = _causal_conv(bc_pre, params["conv_bc_w"], params["conv_bc_b"], cache.conv_bc)
        b_mat, c_mat = jnp.split(bc, 2, axis=-1)
        bsz = xs.shape[0]
        xh1 = xs.reshape(bsz, 1, nheads, p)[:, 0]  # (B,H,P)
        dt1 = dt[:, 0]  # (B,H)
        da = jnp.exp(dt1 * a_neg)  # (B,H)
        bu = jnp.einsum("bhp,bn->bhpn", xh1 * dt1[..., None], b_mat[:, 0])
        state = cache.state * da[..., None, None] + bu
        y = jnp.einsum("bhpn,bn->bhp", state, c_mat[:, 0])[:, None]  # (B,1,H,P)
        new_cache = SSMCache(
            conv_x=hist_x.astype(cache.conv_x.dtype),
            conv_bc=hist_bc.astype(cache.conv_bc.dtype),
            state=state,
        )
        xh = xh1[:, None]  # for the skip term below

    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] * xh

    bsz, s = y.shape[0], y.shape[1]
    y = y.reshape(bsz, s, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = norm_apply(params["gate_norm"], y.astype(cd), kind="rmsnorm")
    out = jnp.einsum("bsk,kd->bsd", y.astype(cd), params["out_proj"]["w"].astype(cd))
    if "b" in params["out_proj"]:
        out = out + params["out_proj"]["b"].astype(cd)
    return out.astype(x_in.dtype), new_cache
