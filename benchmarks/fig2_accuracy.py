"""Paper Fig. 2: accuracy vs training rounds for the four methods
(AdaLD / Adaptive / ZeroPad / All-logits), Non-IID Dirichlet γ=0.5.

Reduced scale (DESIGN §1): GPT-2-family reduced models on the synthetic
Banking77-statistics dataset.  The reproduced claim is the ORDERING
AdaLD ≥ Adaptive > All-logits > ZeroPad, not the absolute 0.85.
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.gpt2_paper import REDUCED_CLIENT, REDUCED_SERVER  # noqa: E402
from repro.fed import FedConfig, run_federated  # noqa: E402
from repro.fed.rounds import METHODS  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "fig2.json")


def run(rounds: int = 8, seeds=(0,), quick: bool = False):
    if quick:
        rounds, seeds = 2, (0,)
    client = REDUCED_CLIENT.with_overrides(num_layers=2, d_model=128, num_heads=4, d_ff=512)
    server = REDUCED_SERVER.with_overrides(
        num_layers=3, d_model=192, num_heads=4, num_kv_heads=4, d_ff=768
    )
    results: dict[str, dict] = {}
    for method in METHODS:
        accs, t0 = [], time.time()
        for seed in seeds:
            from repro.data import make_fed_benchmark_dataset

            ds = make_fed_benchmark_dataset(client.vocab_size, seed=seed)
            fed = FedConfig(
                method=method, num_clients=6, clients_per_round=3, rounds=rounds,
                public_size=256, public_batch=96, eval_size=256, local_steps=10,
                distill_steps=1, server_distill_steps=25, lr=2e-3, seed=seed,
            )
            run_ = run_federated(client, server, ds, fed)
            accs.append(run_.server_acc)
        mean_acc = [sum(col) / len(col) for col in zip(*accs)]
        results[method] = {
            "server_acc": mean_acc,
            "final": mean_acc[-1],
            "best": max(mean_acc),
            "wall_s": time.time() - t0,
        }
        print(f"[fig2] {method:10s} best={max(mean_acc):.3f} "
              f"trajectory={['%.3f' % a for a in mean_acc]}")
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(results, f, indent=1)
    return results


def bench(quick: bool = True):
    """run.py hook: name,us_per_call,derived rows."""
    t0 = time.time()
    results = run(quick=quick)
    us = (time.time() - t0) * 1e6
    best = max(results, key=lambda m: results[m]["best"])
    return [("fig2_accuracy", us, f"best_method={best}:{results[best]['best']:.3f}")]


if __name__ == "__main__":
    run(rounds=int(sys.argv[1]) if len(sys.argv) > 1 else 8)
