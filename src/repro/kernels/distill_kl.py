"""Pallas TPU kernel: fused temperature-softmax KL divergence over vocab tiles.

The distillation loss (paper eq. 9) over a large vocab is memory-bound: the
naive form reads the (rows, V) teacher and student tensors ~3x (logsumexp,
softmax, reduction) and materialises two (rows, V) intermediates.  This
kernel streams both operands tile-by-tile ONCE, carrying online-rescaled
accumulators (flash-attention-style):

    m_t, Z_t : running max / scaled partition of teacher logits t̃ = t/T
    m_s, Z_s : same for student
    U        : Σ exp(t̃ - m_t) · (t̃ - s̃)

and finishes with  KL = U/Z_t - (m_t + log Z_t) + (m_s + log Z_s).

Grid: (row_blocks, vocab_tiles) — vocab innermost so the scratch
accumulators (SMEM/VMEM-resident (R_b,) vectors) persist across the
sequential tile sweep; the per-row KL is emitted at the last tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["distill_kl_pallas"]

ROWS_BLK = 8
VOCAB_BLK = 2048


def _kl_kernel(t_ref, s_ref, out_ref, mt, zt, u, ms, zs, *, inv_temp: float, n_tiles: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        mt[...] = jnp.full_like(mt[...], -jnp.inf)
        zt[...] = jnp.zeros_like(zt[...])
        u[...] = jnp.zeros_like(u[...])
        ms[...] = jnp.full_like(ms[...], -jnp.inf)
        zs[...] = jnp.zeros_like(zs[...])

    t = t_ref[...].astype(jnp.float32) * inv_temp  # (R, Vb)
    s = s_ref[...].astype(jnp.float32) * inv_temp

    # --- teacher online logsumexp + weighted (t - s) accumulator ---
    mt_old = mt[...]
    mt_new = jnp.maximum(mt_old, jnp.max(t, axis=-1))
    scale_t = jnp.exp(mt_old - mt_new)
    w = jnp.exp(t - mt_new[:, None])
    zt[...] = zt[...] * scale_t + jnp.sum(w, axis=-1)
    u[...] = u[...] * scale_t + jnp.sum(w * (t - s), axis=-1)
    mt[...] = mt_new

    # --- student online logsumexp ---
    ms_old = ms[...]
    ms_new = jnp.maximum(ms_old, jnp.max(s, axis=-1))
    zs[...] = zs[...] * jnp.exp(ms_old - ms_new) + jnp.sum(jnp.exp(s - ms_new[:, None]), axis=-1)
    ms[...] = ms_new

    @pl.when(j == n_tiles - 1)
    def _finish():
        lse_t = mt[...] + jnp.log(zt[...])
        lse_s = ms[...] + jnp.log(zs[...])
        out_ref[...] = u[...] / zt[...] - lse_t + lse_s


@functools.partial(jax.jit, static_argnames=("temperature", "interpret"))
def distill_kl_pallas(
    teacher: jax.Array,
    student: jax.Array,
    temperature: float = 2.0,
    *,
    interpret: bool = False,
) -> jax.Array:
    """Per-row KL(σ(t/T) || σ(s/T)) for (rows, vocab) inputs -> (rows,) fp32."""
    assert teacher.shape == student.shape and teacher.ndim == 2
    rows, vocab = teacher.shape
    rb = min(ROWS_BLK, rows)
    vb = min(VOCAB_BLK, vocab)
    rpad = (-rows) % rb
    vpad = (-vocab) % vb
    if rpad or vpad:
        # pad vocab with -inf-like values that contribute nothing
        t = jnp.pad(teacher, ((0, rpad), (0, vpad)), constant_values=-1e30)
        s = jnp.pad(student, ((0, rpad), (0, vpad)), constant_values=-1e30)
    else:
        t, s = teacher, student
    r_all, v_all = t.shape
    n_tiles = v_all // vb
    grid = (r_all // rb, n_tiles)

    out = pl.pallas_call(
        functools.partial(_kl_kernel, inv_temp=1.0 / temperature, n_tiles=n_tiles),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, vb), lambda r, j: (r, j)),
            pl.BlockSpec((rb, vb), lambda r, j: (r, j)),
        ],
        out_specs=pl.BlockSpec((rb,), lambda r, j: (r,)),
        out_shape=jax.ShapeDtypeStruct((r_all,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((rb,), jnp.float32),
            pltpu.VMEM((rb,), jnp.float32),
            pltpu.VMEM((rb,), jnp.float32),
            pltpu.VMEM((rb,), jnp.float32),
            pltpu.VMEM((rb,), jnp.float32),
        ],
        interpret=interpret,
    )(t, s)
    return out[:rows]
