"""Communication accounting (paper §III-C, Fig. 3)."""

import pytest

from repro.core.channel import ChannelState
from repro.core.protocol import (
    CommLedger,
    PayloadSpec,
    RoundStats,
    UplinkPayload,
    full_logits_bits,
    lora_projection_bits,
    topk_upload_bits,
)


def test_topk_vs_full_savings():
    """Top-k with k << V is far cheaper than full logits; the paper's ~50%
    claim combines top-k + fewer rounds."""
    v, n = 50_288, 2000
    full = full_logits_bits(n, v)
    topk = topk_upload_bits(n, 100, v)
    assert topk < full / 100


def test_lora_projection_is_cheap():
    # r=8 projection << even a k=100 top-k payload (paper §III-C)
    assert lora_projection_bits(2000, 8) < topk_upload_bits(2000, 100, 50_288) / 10


def test_payload_spec_bits():
    spec = PayloadSpec(num_samples=10, vocab=65_536, k=5, lora_rank=8)
    # d = 16 + 16 index bits; + 8*16 bits of h per sample
    assert spec.uplink_bits == 10 * 5 * 32 + 10 * 8 * 16
    assert spec.uplink_bytes == spec.uplink_bits / 8


def test_fits_budget_invariant():
    st = ChannelState(bandwidth_hz=1e6, snr_db=0.0, eta=1.0, deadline_s=1.0)
    ok = PayloadSpec(num_samples=100, vocab=1024, k=10)  # 100*10*26 = 26k bits
    too_big = PayloadSpec(num_samples=100_000, vocab=1024, k=1000)
    assert ok.fits(st)
    assert not too_big.fits(st)


def test_ledger_threshold_metric():
    led = CommLedger()
    for i, acc in enumerate([0.2, 0.5, 0.72, 0.8]):
        led.record(RoundStats(round_index=i, uplink_bytes=1e6, downlink_bytes=1e6,
                              server_accuracy=acc))
    assert led.mb_to_reach(0.7) == pytest.approx(6.0)  # 3 rounds x 2 MB
    assert led.mb_to_reach(0.95) is None
    assert led.total_mb == pytest.approx(8.0)


def test_uplink_payload_bytes():
    spec = PayloadSpec(num_samples=4, vocab=256, k=2, lora_rank=None)
    up = UplinkPayload(client_id=0, spec=spec)
    assert up.bytes == spec.uplink_bytes
